"""Packed-pair megakernel (kernels/packed_pair.py, DESIGN.md §8) tests:
planner round-trip, parity sweeps (tile budgets / odd batches / bf16),
first-layer one-hot elimination exactness, oversized-query routing,
MicroBatcher flush stats, and pad-neutrality of the shared kernel bodies.

Tolerance policy: the fp32 packed path must match the pure-jnp reference at
the 1e-6 acceptance bound (scores, post-sigmoid); bf16 inputs at the 2e-2
bound from tests/test_megakernel.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.batching import (bucket_for, bucket_pairs, pack_pairs,
                                 pad_graphs, unpack_pair_scores, EdgeBatch)
from repro.core.simgnn import (SimGNNConfig, init_simgnn_params, pair_score,
                               pair_score_from_labels)
from repro.data.graphs import random_graph
from repro.kernels import ops
from repro.kernels.common import normalize_adjacency_block

CFG = SimGNNConfig()
PARAMS = init_simgnn_params(jax.random.PRNGKey(0), CFG)


def _mixed_pairs(seed, n_pairs, max_n=64):
    rng = np.random.default_rng(seed)
    return [(random_graph(rng, int(rng.integers(5, max_n + 1))),
             random_graph(rng, int(rng.integers(5, max_n + 1))))
            for _ in range(n_pairs)]


def _reference_scores(params, pairs, n_labels=CFG.n_node_labels):
    out = np.zeros(len(pairs), np.float32)
    for b, (lhs, rhs, idxs) in bucket_pairs(pairs, n_labels,
                                            allow_oversize=True).items():
        s = pair_score(params, lhs.adj, lhs.feats, lhs.mask,
                       rhs.adj, rhs.feats, rhs.mask)
        out[idxs] = np.asarray(s)
    return out


# ------------------------------------------------------------------- planner

def test_pack_pairs_round_trip_layout():
    pairs = _mixed_pairs(0, 17)
    packed, stats = pack_pairs(pairs, 64)
    adj = [np.asarray(packed.adj1), np.asarray(packed.adj2)]
    lab = [np.asarray(packed.labels1), np.asarray(packed.labels2)]
    mask = [np.asarray(packed.mask1), np.asarray(packed.mask2)]
    seg = [np.asarray(packed.seg1), np.asarray(packed.seg2)]
    pm, pidx = np.asarray(packed.pair_mask), np.asarray(packed.pair_index)

    assert pm.sum() == len(pairs)
    placed = sorted(pidx[pm > 0].tolist())
    assert placed == list(range(len(pairs)))      # each pair exactly once
    for t in range(pm.shape[0]):
        for side in (0, 1):
            assert mask[side][t].sum() <= 64      # node budget respected
        for p in np.flatnonzero(pm[t] > 0):
            i = pidx[t, p]
            for side, g in enumerate(pairs[i]):
                rows = np.flatnonzero((seg[side][t] == p) & (mask[side][t] > 0))
                n = g["adj"].shape[0]
                assert len(rows) == n             # contiguous segment range
                assert (np.diff(rows) == 1).all()
                o = rows[0]
                np.testing.assert_array_equal(adj[side][t, o:o + n, o:o + n],
                                              g["adj"])
                np.testing.assert_array_equal(lab[side][t, o:o + n],
                                              g["labels"])
    # adjacency is block-diagonal: nothing outside own segment's range
    for side in (0, 1):
        same_seg = (seg[side][:, :, None] == seg[side][:, None, :])
        assert (adj[side] * ~same_seg == 0).all()
    assert 0 < stats["occupancy_lhs"] <= 1.0
    assert stats["slots_per_tile"] % 8 == 0


def test_pack_pairs_rejects_oversize():
    pairs = [(random_graph(np.random.default_rng(0), 80),
              random_graph(np.random.default_rng(1), 10))]
    with pytest.raises(ValueError):
        pack_pairs(pairs, 64)


# -------------------------------------------------------------------- parity

@pytest.mark.parametrize("node_budget", [64, 96, 128])
def test_packed_parity_across_tile_budgets(node_budget):
    pairs = _mixed_pairs(1, 24)
    packed, _ = pack_pairs(pairs, node_budget)
    s = ops.pair_score_packed(PARAMS, packed, interpret=True)
    out = unpack_pair_scores(s, packed, len(pairs))
    np.testing.assert_allclose(out, _reference_scores(PARAMS, pairs),
                               rtol=0, atol=1e-6)


@pytest.mark.parametrize("batch", [1, 7, 13])
def test_packed_parity_odd_batches(batch):
    """Any pair count works: T pads to a tile_block multiple, pad tiles and
    pad pair slots never leak into outputs."""
    pairs = _mixed_pairs(2 + batch, batch)
    packed, _ = pack_pairs(pairs, 64)
    s = ops.pair_score_packed(PARAMS, packed, interpret=True)
    out = unpack_pair_scores(s, packed, len(pairs))
    assert out.shape == (batch,)
    np.testing.assert_allclose(out, _reference_scores(PARAMS, pairs),
                               rtol=0, atol=1e-6)


def test_packed_bf16_inputs():
    """bf16 in / fp32 accumulate: within the 2e-2 bound (labels stay int32)."""
    pairs = _mixed_pairs(5, 12)
    packed, _ = pack_pairs(pairs, 64)
    to16 = lambda t: jax.tree.map(
        lambda x: x.astype(jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, t)
    s16 = ops.pair_score_packed(to16(PARAMS), to16(packed), interpret=True)
    assert s16.dtype == jnp.bfloat16
    out = unpack_pair_scores(s16.astype(jnp.float32), packed, len(pairs))
    ref = _reference_scores(PARAMS, pairs)
    rel = np.max(np.abs(out - ref)) / (np.max(np.abs(ref)) + 1e-9)
    assert rel < 2e-2


def test_packed_variadic_gcn_depth():
    cfg = SimGNNConfig(gcn_dims=(64, 48, 32, 16))
    params = init_simgnn_params(jax.random.PRNGKey(2), cfg)
    pairs = _mixed_pairs(6, 9, max_n=32)
    packed, _ = pack_pairs(pairs, 64)
    s = ops.pair_score_packed(params, packed, interpret=True)
    out = unpack_pair_scores(s, packed, len(pairs))
    ref = np.zeros(len(pairs), np.float32)
    for b, (lhs, rhs, idxs) in bucket_pairs(pairs, cfg.n_node_labels).items():
        ref[idxs] = np.asarray(pair_score(params, lhs.adj, lhs.feats, lhs.mask,
                                          rhs.adj, rhs.feats, rhs.mask))
    np.testing.assert_allclose(out, ref, rtol=0, atol=1e-6)


# ------------------------------------------- first-layer one-hot elimination

def test_label_gather_first_layer_is_exact():
    """one_hot(labels) @ W1 == W1[labels] bit-exactly, end to end."""
    pairs = _mixed_pairs(7, 10)
    lhs = pad_graphs([p[0] for p in pairs], CFG.n_node_labels, 64)
    rhs = pad_graphs([p[1] for p in pairs], CFG.n_node_labels, 64)
    s_feats = pair_score(PARAMS, lhs.adj, lhs.feats, lhs.mask,
                         rhs.adj, rhs.feats, rhs.mask)
    s_labels = pair_score_from_labels(PARAMS, lhs.adj, lhs.labels, lhs.mask,
                                      rhs.adj, rhs.labels, rhs.mask)
    np.testing.assert_array_equal(np.asarray(s_feats), np.asarray(s_labels))


def test_pad_graphs_carries_int_labels():
    g = random_graph(np.random.default_rng(11), 9)
    gb = pad_graphs([g], CFG.n_node_labels, 16)
    assert gb.labels.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(gb.labels[0, :9]), g["labels"])
    assert (np.asarray(gb.labels[0, 9:]) == 0).all()
    # feats is the one-hot of labels on real rows
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(gb.feats[0, :9], -1)), g["labels"])


# ------------------------------------------------------ oversized query path

def test_bucket_for_oversize_power_of_two():
    assert bucket_for(65, allow_oversize=True) == 128
    assert bucket_for(200, allow_oversize=True) == 256
    with pytest.raises(ValueError):
        bucket_for(65)


def test_server_scores_oversized_graphs():
    """Regression: a query beyond the largest bucket / node budget must not
    kill score() — it routes to power-of-two overflow buckets."""
    from repro.serve.batching import simgnn_query_server

    rng = np.random.default_rng(13)
    pairs = _mixed_pairs(14, 6) + [(random_graph(rng, 90),
                                    random_graph(rng, 20))]
    ref_server = simgnn_query_server(PARAMS, CFG)
    kern_server = simgnn_query_server(PARAMS, CFG, use_kernels=True)
    out_ref = ref_server(pairs)
    out_k = kern_server(pairs)
    assert out_ref.shape == out_k.shape == (7,)
    assert (out_ref > 0).all()
    np.testing.assert_allclose(out_k, out_ref, rtol=1e-4, atol=1e-5)
    assert 128 in kern_server.bucket_fns        # oversize fell back to bucket


def test_server_packed_routing_and_stats():
    from repro.serve.batching import simgnn_query_server

    pairs = _mixed_pairs(15, 20)
    packed_server = simgnn_query_server(PARAMS, CFG, use_kernels=True)
    bucketed_server = simgnn_query_server(PARAMS, CFG, use_kernels=True,
                                          packing=False)
    out_p = packed_server(pairs)
    out_b = bucketed_server(pairs)
    np.testing.assert_allclose(out_p, _reference_scores(PARAMS, pairs),
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(out_p, out_b, rtol=1e-5, atol=1e-6)
    st = packed_server.last_pack_stats
    assert st is not None and st["n_pairs"] == 20
    assert 0 < st["occupancy_lhs"] <= 1.0
    assert not packed_server.bucket_fns          # nothing fell back
    assert bucketed_server.bucket_fns            # bucketed path kept buckets


# ------------------------------------------------------- MicroBatcher stats

def test_microbatcher_flush_stats():
    from repro.serve.batching import MicroBatcher

    now = [0.0]
    mb = MicroBatcher(lambda reqs: list(reqs), max_batch=4, max_wait_s=1.0,
                      clock=lambda: now[0])
    for i in range(8):                 # two size-triggered flushes
        mb.submit(i)
    mb.submit(8)
    now[0] = 2.0                       # deadline passes with 1 pending
    assert mb.poll() == [8]
    mb.submit(9)
    assert mb.flush() == [9]           # manual, occupancy 1/4
    assert mb.flush() is None          # empty queue: nothing ran (None, not
    assert mb.poll() is None           # an empty result list) ...
    st = mb.stats
    assert st.batches == 4 and st.requests == 10
    assert st.size_flushes == 2
    assert st.deadline_flushes == 1
    assert st.manual_flushes == 1      # ... and does not count as a flush
    assert st.mean_occupancy == pytest.approx((1 + 1 + 0.25 + 0.25) / 4)


# ------------------------------------------------- kernel-body pad neutrality

def test_edge_aggregate_pad_edges_are_neutral():
    """Pad edge slots (senders=0, weight 0) must contribute exact zeros to
    receiver row 0 — the slot every pad edge points at."""
    from repro.core.batching import edge_aggregate

    rng = np.random.default_rng(17)
    n, e_real, e_pad = 6, 4, 12
    senders = np.zeros((1, e_real + e_pad), np.int32)
    receivers = np.zeros((1, e_real + e_pad), np.int32)
    weights = np.zeros((1, e_real + e_pad), np.float32)
    emask = np.zeros((1, e_real + e_pad), np.float32)
    senders[0, :e_real] = [1, 2, 3, 4]
    receivers[0, :e_real] = [2, 0, 1, 0]
    weights[0, :e_real] = rng.uniform(0.5, 1.5, e_real)
    emask[0, :e_real] = 1.0
    eb = EdgeBatch(jnp.asarray(senders), jnp.asarray(receivers),
                   jnp.asarray(weights), jnp.asarray(emask))
    hw = jnp.asarray(rng.normal(size=(1, n, 3)).astype(np.float32))
    out = np.asarray(edge_aggregate(eb, hw))
    expect = np.zeros((1, n, 3), np.float32)
    for s, r, w in zip(senders[0, :e_real], receivers[0, :e_real],
                       weights[0, :e_real]):
        expect[0, r] += w * np.asarray(hw)[0, s]
    np.testing.assert_array_equal(out, expect)    # exact, incl. row 0


def test_normalize_adjacency_block_isolated_and_masked_nodes():
    """Isolated real nodes get the self-loop weight 1; masked (pad) node
    rows/cols are exactly zero even though the in-kernel identity covers
    the whole tile."""
    adj = np.zeros((1, 6, 6), np.float32)
    adj[0, 0, 1] = adj[0, 1, 0] = 1.0   # one edge; node 2 isolated but real
    mask = np.asarray([[1, 1, 1, 0, 0, 0]], np.float32)
    a = np.asarray(normalize_adjacency_block(jnp.asarray(adj),
                                             jnp.asarray(mask)))
    assert a[0, 2, 2] == 1.0                       # isolated: D^-1/2 I D^-1/2
    assert (a[0, 3:, :] == 0).all() and (a[0, :, 3:] == 0).all()
    np.testing.assert_allclose(a[0, 0, 1], 0.5, atol=1e-6)  # deg 2 <-> deg 2
    # parity with the core (non-kernel) normalization on the same block
    from repro.core.gcn import normalized_adjacency
    np.testing.assert_allclose(
        a, np.asarray(normalized_adjacency(jnp.asarray(adj),
                                           jnp.asarray(mask))),
        rtol=1e-6, atol=1e-7)
