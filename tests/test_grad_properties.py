"""Hypothesis drivers for the pad-slot VJP-zero properties (DESIGN.md §11):
the custom backward passes of the packed aggregation/pooling bodies must
give pad slots EXACTLY zero cotangents over the whole (seed, size, budget)
space — the plain seeded checks live in tests/test_grad.py and run without
hypothesis; here hypothesis explores the space in CI."""

import pytest

from test_grad import (check_csr_vjp_of_pad_slots_is_exactly_zero,
                       check_segment_att_pool_vjp_of_pad_nodes_is_exactly_zero)

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 2**31 - 1), st.integers(4, 12), st.integers(1, 3))
def test_csr_vjp_of_pad_slots_is_exactly_zero(seed, n, d):
    check_csr_vjp_of_pad_slots_is_exactly_zero(seed, n, d)


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 2**31 - 1), st.integers(4, 10), st.integers(1, 3))
def test_segment_att_pool_vjp_of_pad_nodes_is_exactly_zero(seed, n, p):
    check_segment_att_pool_vjp_of_pad_nodes_is_exactly_zero(seed, n, p)
