"""GCN / SimGNN core behaviour + property-based invariants (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.batching import GraphBatch, to_edge_batch, edge_aggregate
from repro.core.gcn import (activation_sparsity, gcn_stack,
                            normalized_adjacency)
from repro.core.simgnn import (SimGNNConfig, init_simgnn_params, pair_score,
                               pair_score_serial_baseline)
from repro.data.graphs import pair_stream, random_graph

CFG = SimGNNConfig()
PARAMS = init_simgnn_params(jax.random.PRNGKey(0), CFG)


def _rand_graph_batch(rng, b=4, n=16):
    adj = (rng.random((b, n, n)) > 0.7).astype(np.float32)
    adj = np.triu(adj, 1)
    adj = adj + adj.transpose(0, 2, 1)
    n_nodes = rng.integers(2, n + 1, b)
    mask = (np.arange(n)[None] < n_nodes[:, None]).astype(np.float32)
    adj = adj * mask[:, :, None] * mask[:, None, :]
    feats = rng.random((b, n, CFG.n_node_labels)).astype(np.float32)
    feats = feats * mask[..., None]
    return jnp.asarray(adj), jnp.asarray(feats), jnp.asarray(mask)


def test_normalized_adjacency_properties():
    rng = np.random.default_rng(0)
    adj, _, mask = _rand_graph_batch(rng)
    a = normalized_adjacency(adj, mask)
    # symmetric, zero on padded rows/cols, spectral radius <= 1
    np.testing.assert_allclose(np.asarray(a), np.asarray(a.transpose(0, 2, 1)),
                               atol=1e-6)
    pad = 1.0 - np.asarray(mask)
    assert np.abs(np.asarray(a) * pad[:, :, None]).max() == 0.0
    eig = np.linalg.eigvalsh(np.asarray(a))
    assert eig.max() <= 1.0 + 1e-5


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_permutation_equivariance(seed):
    """GCN node embeddings are permutation-equivariant; the SimGNN score is
    invariant to node relabeling of either input graph."""
    rng = np.random.default_rng(seed)
    adj, feats, mask = _rand_graph_batch(rng, b=2, n=12)
    n = adj.shape[-1]
    n_valid = int(np.asarray(mask)[0].sum())
    perm = np.arange(n)
    perm[:n_valid] = rng.permutation(n_valid)   # permute only real nodes
    p_adj = adj[:, perm][:, :, perm]
    p_feats = feats[:, perm]
    p_mask = mask[:, perm]

    a1 = normalized_adjacency(adj, mask)
    a2 = normalized_adjacency(p_adj, p_mask)
    h1 = gcn_stack(PARAMS["gcn"], a1, feats, mask)
    h2 = gcn_stack(PARAMS["gcn"], a2, p_feats, p_mask)
    np.testing.assert_allclose(np.asarray(h1[:, perm]), np.asarray(h2),
                               rtol=2e-3, atol=2e-4)

    s1 = pair_score(PARAMS, adj, feats, mask, adj, feats, mask)
    s2 = pair_score(PARAMS, p_adj, p_feats, p_mask, adj, feats, mask)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-3,
                               atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_padding_invariance(seed):
    """Embedding a graph padded to 16 vs 32 nodes gives identical scores —
    the correctness condition behind size-bucketing (DESIGN.md §2)."""
    rng = np.random.default_rng(seed)
    g = random_graph(rng, n_nodes=int(rng.integers(4, 14)))
    from repro.core.batching import pad_graphs
    b16 = pad_graphs([g], CFG.n_node_labels, 16)
    b32 = pad_graphs([g], CFG.n_node_labels, 32)
    s16 = pair_score(PARAMS, b16.adj, b16.feats, b16.mask,
                     b16.adj, b16.feats, b16.mask)
    s32 = pair_score(PARAMS, b32.adj, b32.feats, b32.mask,
                     b32.adj, b32.feats, b32.mask)
    np.testing.assert_allclose(np.asarray(s16), np.asarray(s32), rtol=1e-5,
                               atol=1e-6)


def test_fused_equals_serial():
    b = next(pair_stream(0, 8))
    args = [jnp.asarray(b[k]) for k in
            ("adj1", "feats1", "mask1", "adj2", "feats2", "mask2")]
    np.testing.assert_allclose(
        np.asarray(pair_score(PARAMS, *args)),
        np.asarray(pair_score_serial_baseline(PARAMS, *args)), atol=1e-6)


def test_edge_aggregation_equals_dense():
    rng = np.random.default_rng(3)
    adj, feats, mask = _rand_graph_batch(rng, b=3, n=20)
    gb = GraphBatch(feats, adj, mask, jnp.sum(mask, -1).astype(jnp.int32))
    eb = to_edge_batch(gb, max_edges=20 * 21)
    hw = jax.random.normal(jax.random.PRNGKey(1), feats.shape)
    dense = jnp.einsum("bnm,bmf->bnf", normalized_adjacency(adj, mask), hw)
    sparse = edge_aggregate(eb, hw)
    np.testing.assert_allclose(np.asarray(sparse), np.asarray(dense),
                               rtol=1e-4, atol=1e-5)


def test_scores_in_unit_interval_and_identity_high():
    b = next(pair_stream(5, 16))
    args = [jnp.asarray(b[k]) for k in
            ("adj1", "feats1", "mask1", "adj2", "feats2", "mask2")]
    s = np.asarray(pair_score(PARAMS, *args))
    assert (s > 0).all() and (s < 1).all()


def test_activation_sparsity_measured():
    """The paper reports 52%/47% post-ReLU sparsity on layers 2/3; with
    random init we only assert the measurement machinery: sparsity in [0,1)
    and nonzero after ReLU layers."""
    b = next(pair_stream(7, 8))
    a = normalized_adjacency(jnp.asarray(b["adj1"]), jnp.asarray(b["mask1"]))
    h = gcn_stack(PARAMS["gcn"], a, jnp.asarray(b["feats1"]),
                  jnp.asarray(b["mask1"]))
    sp = float(activation_sparsity(h, jnp.asarray(b["mask1"])))
    assert 0.0 < sp < 1.0
