"""Cross-cutting property tests (hypothesis) on the LM substrate invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs import reduced_config
from repro.distributed.sharding import Runtime
from repro.models import layers, lm
from repro.models.init import init_params

RT = Runtime(mesh=None)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 50))
def test_rope_relative_position_property(seed, shift):
    """RoPE'd q.k products depend only on relative position: shifting all
    positions by a constant leaves the attention scores unchanged."""
    key = jax.random.PRNGKey(seed)
    b, t, h, d = 1, 8, 2, 32
    q = jax.random.normal(key, (b, t, h, d))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, t, h, d))
    pos = jnp.broadcast_to(jnp.arange(t), (b, t))
    s0 = jnp.einsum("bthd,bshd->bhts", layers.rope(q, pos, 1e4),
                    layers.rope(k, pos, 1e4))
    s1 = jnp.einsum("bthd,bshd->bhts", layers.rope(q, pos + shift, 1e4),
                    layers.rope(k, pos + shift, 1e4))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), rtol=2e-4,
                               atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_causality_property(seed):
    """Changing a future token never changes logits at earlier positions —
    for a dense arch and for the SSM (rwkv) arch."""
    for arch in ("qwen1.5-4b", "rwkv6-7b"):
        cfg = reduced_config(arch)
        params = init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(seed)
        tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 12)), jnp.int32)
        tok2 = tok.at[0, -1].set((tok[0, -1] + 7) % cfg.vocab_size)
        l1, _ = lm.forward(params, cfg, RT, tok)
        l2, _ = lm.forward(params, cfg, RT, tok2)
        np.testing.assert_allclose(np.asarray(l1[:, :-1]),
                                   np.asarray(l2[:, :-1]), atol=1e-5)


def test_chunked_attention_equals_dense():
    """The online-softmax KV-chunk scan (flash recurrence in XLA) matches the
    dense attention core exactly — with window + softcap."""
    cfg = reduced_config("gemma2-9b")
    b, t = 2, 64
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jax.random.normal(jax.random.PRNGKey(0), (b, t, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, t, kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, t, kv, hd))
    pos = jnp.broadcast_to(jnp.arange(t), (b, t))
    import repro.models.layers as L
    old = L.KV_CHUNK
    try:
        L.KV_CHUNK = 16
        out_c = L.chunked_attention_core(q, k, v, cfg, q_pos=pos, kv_pos=pos,
                                         causal=True, window=8)
    finally:
        L.KV_CHUNK = old
    mask = L._mask(pos, pos, causal=True, window=8)
    out_d = L.attention_core(q, k, v, cfg, mask)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_d),
                               rtol=2e-4, atol=2e-5)


def test_quantize_kv_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 4, 32)) * 3.0
    q, s = layers.quantize_kv(x)
    back = q.astype(jnp.float32) * s[..., None]
    err = np.abs(np.asarray(back - x))
    bound = np.asarray(s[..., None]) * 0.5 + 1e-6
    assert (err <= bound).all()


def test_vocab_padding_masked_everywhere():
    """Padded vocab ids get -1e9 logits in forward, prefill and decode."""
    cfg = reduced_config("granite-moe-3b-a800m")     # vocab 256 -> padded 512
    assert cfg.vocab_padded > cfg.vocab_size
    params = init_params(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    logits, _ = lm.forward(params, cfg, RT, tok)
    assert float(logits[..., cfg.vocab_size:].max()) <= -1e8
    last, caches, pos = lm.prefill(params, cfg, RT, tok, cache_len=12)
    assert float(last[..., cfg.vocab_size:].max()) <= -1e8
