"""Two-stage retrieval correctness (kernels/retrieval.py, the engine's
prefilter seam, serve/search.py two_stage path — DESIGN.md §14): blocked
streaming top-M scan parity vs dense references, the
never-materialize-[Q, N] block guard, shard-aligned block sizing, NaN-row
exclusion, the query-side NTN collapse algebra, calibration fit recovery,
M=N bit-parity with the exact scan, recall monotonicity in M, fault-seam
degradation to exact, and the top-k k-clamp regressions.
"""

import jax
import numpy as np
import pytest

from repro.core.simgnn import (SimGNNConfig, fcn_head, init_simgnn_params,
                               ntn_scores)
from repro.data.graphs import random_graph, zipf_corpus, zipf_query_stream
from repro.kernels.retrieval import (NEG_FILL, RETRIEVAL_MAX_BLOCK_COLS,
                                     blocked_topm, blocked_topm_ntn,
                                     collapse_query_ntn,
                                     fit_prefilter_calibration,
                                     ntn_logit_reference,
                                     prefilter_query_vectors,
                                     retrieval_block_cols, topm_reference)
from repro.serve.search import SimilaritySearchServer
from repro.testing import faults

CFG = SimGNNConfig()
PARAMS = init_simgnn_params(jax.random.PRNGKey(0), CFG)
F = CFG.gcn_dims[-1]
K = CFG.ntn_k


def _emb(rng, n):
    return rng.standard_normal((n, F)).astype(np.float32)


# ------------------------------------------------------------ kernel parity

@pytest.mark.parametrize("m", [1, 10, 137, 200])
def test_blocked_topm_matches_reference(m):
    rng = np.random.default_rng(0)
    qv, corpus = _emb(rng, 5), _emb(rng, 137)   # N not a block multiple
    s, i = blocked_topm(qv, corpus, m, block_cols=32)
    rs, ri = topm_reference(qv, corpus, m)
    np.testing.assert_array_equal(i, ri)
    np.testing.assert_allclose(s, rs, rtol=0, atol=1e-5)
    assert s.shape == i.shape == (5, min(m, 137))
    assert np.all(np.diff(s, axis=1) <= 0)      # rows descending


def test_blocked_topm_nan_rows_rank_last_never_pad():
    rng = np.random.default_rng(1)
    qv, corpus = _emb(rng, 3), _emb(rng, 40)
    corpus[[4, 17, 31]] = np.nan                # dropped embed rows (§12)
    s, i = blocked_topm(qv, corpus, 40, block_cols=16)
    rs, ri = topm_reference(qv, corpus, 40)
    np.testing.assert_array_equal(i, ri)
    # NaN rows surface LAST with the finite sentinel — never as NaN, never
    # displaced by -inf init placeholders or padded corpus columns.
    assert np.isfinite(s).all()
    np.testing.assert_array_equal(np.sort(i[:, -3:], axis=1),
                                  [[4, 17, 31]] * 3)
    np.testing.assert_allclose(s[:, -3:], NEG_FILL)
    # With m below the finite count, no NaN row makes the shortlist.
    _, i10 = blocked_topm(qv, corpus, 10, block_cols=16)
    assert not np.isin(i10, [4, 17, 31]).any()


def test_blocked_topm_all_nan_corpus_stays_finite():
    rng = np.random.default_rng(2)
    qv = _emb(rng, 2)
    corpus = np.full((12, F), np.nan, np.float32)
    s, i = blocked_topm(qv, corpus, 4, block_cols=8)
    np.testing.assert_allclose(s, NEG_FILL)
    # Ties resolve to the ascending corpus index (the stable-sort order).
    np.testing.assert_array_equal(i, [[0, 1, 2, 3]] * 2)


def test_blocked_topm_ntn_matches_reference_and_exact_head():
    rng = np.random.default_rng(3)
    hq, corpus = _emb(rng, 4), _emb(rng, 100)
    uq, dq = collapse_query_ntn(PARAMS["ntn"], hq)
    s, i = blocked_topm_ntn(uq, dq, corpus, PARAMS["fcn"], 100,
                            block_cols=32)
    rs, ri = ntn_logit_reference(uq, dq, corpus, PARAMS["fcn"], 100)
    np.testing.assert_array_equal(i, ri)
    np.testing.assert_allclose(s, rs, rtol=0, atol=1e-4)
    # The streamed logit ranking IS the exact pairwise head's ranking
    # (sigmoid is monotone): exact prefilter by construction.
    h1 = np.repeat(hq, 100, axis=0)
    h2 = np.tile(corpus, (4, 1))
    exact = np.asarray(fcn_head(PARAMS["fcn"], ntn_scores(
        PARAMS["ntn"], h1, h2))).reshape(4, 100)
    np.testing.assert_array_equal(
        i, np.argsort(-exact.astype(np.float32), axis=1, kind="stable"))


def test_collapse_query_ntn_algebra():
    """uq·h_c + dq reproduces the NTN pre-activations exactly (the §14
    per-query fold: pay K·F² once, then K·F per candidate)."""
    rng = np.random.default_rng(4)
    hq, hc = _emb(rng, 6), _emb(rng, 6)
    uq, dq = collapse_query_ntn(PARAMS["ntn"], hq)
    folded = np.maximum(
        np.einsum("qkf,qf->qk", uq.reshape(6, K, F), hc) + dq, 0.0)
    ref = np.asarray(ntn_scores(PARAMS["ntn"], hq, hc))
    np.testing.assert_allclose(folded, ref, rtol=0, atol=1e-5)


# ----------------------------------------------------- block guard / sizing

def test_block_guard_rejects_materializing_widths():
    rng = np.random.default_rng(5)
    qv, corpus = _emb(rng, 2), _emb(rng, 4096)
    with pytest.raises(ValueError, match="materializes"):
        blocked_topm(qv, corpus, 8, block_cols=4096)
    uq, dq = collapse_query_ntn(PARAMS["ntn"], qv)
    with pytest.raises(ValueError, match="materializes"):
        blocked_topm_ntn(uq, dq, corpus, PARAMS["fcn"], 8, block_cols=2048)


def test_retrieval_block_cols_aligns_with_shards():
    # Store-backed: the block IS the persisted shard when it fits...
    assert retrieval_block_cols(100_000, shard_rows=256) == 256
    assert retrieval_block_cols(512, shard_rows=1024) == 1024
    # ...and oversized shards halve until they fit, still nesting evenly.
    b = retrieval_block_cols(1 << 20, shard_rows=8192)
    assert b <= RETRIEVAL_MAX_BLOCK_COLS and 8192 % b == 0
    # Store-less: corpus rounded up to a power of two, capped.
    assert retrieval_block_cols(300) == 512
    assert retrieval_block_cols(3) == 8
    assert retrieval_block_cols(1 << 20) == RETRIEVAL_MAX_BLOCK_COLS
    with pytest.raises(ValueError, match=">= 1"):
        retrieval_block_cols(0)


def test_scan_shape_validation_and_empty():
    rng = np.random.default_rng(6)
    with pytest.raises(ValueError, match="shape mismatch"):
        blocked_topm(_emb(rng, 2), rng.standard_normal((4, F + 1)), 2)
    with pytest.raises(ValueError, match="not \\[Q, K\\*F\\]"):
        blocked_topm_ntn(np.zeros((2, 7)), np.zeros((2, K)),
                         _emb(rng, 4), PARAMS["fcn"], 2)
    s, i = blocked_topm(np.zeros((0, F)), _emb(rng, 4), 2)
    assert s.shape == i.shape == (0, 0)


# -------------------------------------------------------------- calibration

def test_calibration_recovers_linear_model():
    """When the head IS the linear feature model, the ridge fit recovers
    it and the calibrated query vectors rank candidates exactly."""
    rng = np.random.default_rng(7)
    w = np.asarray(PARAMS["ntn"]["w"])
    hq, hc = _emb(rng, 64), _emb(rng, 64)
    alpha = rng.standard_normal(K).astype(np.float32)
    beta = rng.standard_normal(F).astype(np.float32)
    phi = np.einsum("qf,kfg,qg->qk", hq, w, hc)
    logits = phi @ alpha + hc @ beta
    y = 1.0 / (1.0 + np.exp(-logits))
    calib = fit_prefilter_calibration(w, hq, hc, y)
    assert calib["r2"] > 0.99 and calib["n_samples"] == 64
    # One query against a candidate set: qv·hc equals the true logit up
    # to the fit's per-query constant, so the ranking matches exactly.
    # One query against a candidate set: qv·hc tracks the true logit up
    # to ridge shrinkage and a per-query constant — near-ties may swap,
    # but the top-10 SET must be recovered exactly (recall@10 == 1.0,
    # the metric the serving ladder gates on).
    qv = prefilter_query_vectors(w, hq[:1], calib)
    cand = hc
    true_logit = (np.einsum("f,kfg,ng->nk", hq[0], w, cand) @ alpha
                  + cand @ beta)
    _, i = topm_reference(qv, cand, 10)
    want = np.argsort(-true_logit.astype(np.float32), kind="stable")[:10]
    assert set(i[0].tolist()) == set(want.tolist())


def test_calibration_needs_enough_finite_samples():
    rng = np.random.default_rng(8)
    w = np.asarray(PARAMS["ntn"]["w"])
    hq, hc = _emb(rng, K + 4), _emb(rng, K + 4)
    y = rng.uniform(0.1, 0.9, K + 4)
    y[: 8] = np.nan                           # finite filter drops these
    with pytest.raises(ValueError, match="finite calibration pairs"):
        fit_prefilter_calibration(w, hq, hc, y)


# ------------------------------------------------------- server: two-stage

def _server(seed, n_corpus, **kw):
    srv = SimilaritySearchServer(PARAMS, CFG, **kw)
    srv.index(zipf_corpus(seed, n_corpus))
    return srv


def _queries(seed, n):
    stream = zipf_query_stream(seed, 2, n_corpus=16)
    return [next(stream)["query"] for _ in range(n)]


def test_two_stage_m_equals_n_bit_identical():
    srv = _server(40, 48)
    for q in _queries(41, 3):
        ei, es = srv.topk(q, k=10, mode="exact")
        ti, ts = srv.topk(q, k=10, mode="two_stage", prefilter_m=48)
        np.testing.assert_array_equal(ei, ti)
        assert np.asarray(es).tobytes() == np.asarray(ts).tobytes()


def test_two_stage_recall_monotone_in_m():
    """Shortlists are nested in M, so any true top-k hit at M stays a hit
    at M' > M: recall@k must be monotone non-decreasing, reaching 1.0 at
    M = N."""
    srv = _server(42, 96)
    queries = _queries(43, 4)
    exact = srv.search(queries, k=10, mode="exact")
    last = -1.0
    for m in (4, 8, 16, 32, 96):
        got = srv.search(queries, k=10, mode="two_stage", prefilter_m=m)
        rec = float(np.mean([
            len(set(g[0].tolist()) & set(e[0].tolist())) / len(e[0])
            for g, e in zip(got, exact)]))
        assert rec >= last - 1e-12, f"recall dropped at M={m}"
        last = rec
    assert last == 1.0                        # M = N: the full corpus


def test_two_stage_batch_equals_single():
    srv = _server(44, 64)
    queries = _queries(45, 3)
    batched = srv.search(queries, k=5, mode="two_stage", prefilter_m=16)
    for q, (bi, bs) in zip(queries, batched):
        si, ss = srv.search([q], k=5, mode="two_stage", prefilter_m=16)[0]
        np.testing.assert_array_equal(bi, si)
        assert np.asarray(bs).tobytes() == np.asarray(ss).tobytes()


def test_two_stage_plan_stats_and_health():
    srv = _server(46, 64, recall_sample_every=2)
    queries = _queries(47, 4)
    srv.search(queries, k=5, mode="two_stage", prefilter_m=16)
    plan = srv.engine.last_plan
    assert plan.prefilter_m == 16
    assert "two-stage retrieval" in plan.reason
    assert srv.stats.prefilter_queries == 4
    assert srv.stats.pairs_scored >= 4 * 16
    assert srv.engine.counters["prefilter_calls"] >= 1
    assert srv.engine.counters["prefilter_queries"] >= 4
    pf = srv.health()["prefilter"]
    assert pf["proxy"] in ("linear", "ntn_exact")
    assert pf["queries"] == 4 and pf["degraded"] == 0
    # recall_sample_every=2 served half the queries exactly as well and
    # recorded the top-k overlap.
    assert srv.stats.recall_samples == 2
    assert srv.stats.recall_mean == 1.0       # exact proxy or tiny corpus
    with pytest.raises(ValueError, match="mode"):
        srv.search(queries, k=5, mode="fuzzy")


@pytest.mark.parametrize("mode", ["raise", "nan"])
def test_prefilter_fault_degrades_to_exact(mode):
    srv = _server(48, 48)
    queries = _queries(49, 3)
    exact = srv.search(queries, k=5, mode="exact")
    with faults.inject("prefilter", mode=mode) as plan:
        got = srv.search(queries, k=5, mode="two_stage", prefilter_m=8)
    assert plan.triggered >= 1
    # Degraded queries are served through the exact full scan — same
    # results, and the degradation is counted for health()/dashboards.
    for (gi, gs), (ei, es) in zip(got, exact):
        np.testing.assert_array_equal(gi, ei)
        np.testing.assert_array_equal(gs, es)
    assert srv.stats.prefilter_degraded == 3
    assert srv.engine.counters["prefilter_degraded"] == 3
    assert srv.engine.counters["errors:prefilter"] >= 1
    assert srv.health()["prefilter"]["degraded"] == 3


# --------------------------------------------------------- k-clamp contract

def test_topk_oversized_k_returns_all_ranked():
    srv = _server(50, 12)
    for mode in ("exact", "two_stage"):
        idx, scores = srv.topk(_queries(51, 1)[0], k=40, mode=mode)
        assert len(idx) == len(scores) == 12      # clamped to N, no crash
        assert sorted(idx.tolist()) == list(range(12))
        assert np.all(np.diff(scores) <= 0)


def test_topk_k_zero_and_all_nan_corpus():
    srv = _server(52, 8)
    q = _queries(53, 1)[0]
    idx, scores = srv.topk(q, k=0)
    assert len(idx) == 0 and len(scores) == 0
    # Every corpus embedding failed (§12 worst case): oversized k still
    # returns all N in ascending-index order, scores kept NaN so callers
    # see failure — in BOTH modes (two_stage raises its shortlist to
    # cover k, and the kernel's NEG_FILL sentinel keeps the NaN corpus
    # from poisoning the scan).
    srv.corpus_emb = np.full_like(srv.corpus_emb, np.nan)
    idx, scores = srv.topk(q, k=99, mode="exact")
    np.testing.assert_array_equal(idx, np.arange(8))
    assert np.isnan(scores).all()
    idx2, scores2 = srv.topk(q, k=99, mode="two_stage", prefilter_m=4)
    np.testing.assert_array_equal(idx2, np.arange(8))
    assert np.isnan(scores2).all()
