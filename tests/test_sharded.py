"""Device-sharded execution layer tests (DESIGN.md §16).

The parity matrix: every sharded executor — packed score (dense + sparse),
data-parallel loss_and_grad, per-shard search scans — against its
single-device twin at device counts {1, 2, 8}. Scores are pinned BITWISE
(same block tiles, same dot products, tile-independent programs); grads at
the 1e-6 gate (the cross-device psum re-associates the chunk sums).

Multi-device rows need simulated host devices; run the full matrix with

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest -q tests/test_sharded.py

(CI does — see the tier-1 `sharded` step). Under a plain single-device run
the multi-device rows skip and the policy/dtype/span rows still execute.
"""

import jax
import numpy as np
import pytest

from repro.core.batching import EdgeBatch, PackedEdges, pack_pairs
from repro.core.engine import ScoringEngine
from repro.core.profile import TraceRecorder, cost_key
from repro.core.simgnn import SimGNNConfig, init_simgnn_params
from repro.data.graphs import random_graph
from repro.distributed.sharding import TILE_AXIS, tile_mesh, tile_runtime
from repro.kernels import ops
from repro.serve.search import SimilaritySearchServer
from repro.testing import faults

NDEV = jax.local_device_count()

def needs(n):
    return pytest.mark.skipif(
        NDEV < n, reason=f"needs {n} host devices (run under "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


CFG = SimGNNConfig()
PARAMS = init_simgnn_params(jax.random.PRNGKey(0), CFG)
DEVICE_COUNTS = [1, 2, 8]


def _mixed_pairs(seed, n_pairs, max_n=32, avg_degree=4):
    rng = np.random.default_rng(seed)
    return [(random_graph(rng, int(rng.integers(5, max_n + 1)),
                          avg_degree=avg_degree),
             random_graph(rng, int(rng.integers(5, max_n + 1)),
                          avg_degree=avg_degree))
            for _ in range(n_pairs)]


PAIRS = _mixed_pairs(0, 48)
TARGETS = np.linspace(0.0, 1.0, len(PAIRS)).astype(np.float32)

_BASE = {}


def _single_device_engine(path):
    if path not in _BASE:
        _BASE[path] = ScoringEngine(PARAMS, CFG, path=path)
    return _BASE[path]


# ----------------------------------------------------------- shape policy

def test_sharded_tile_plan_balances_tiles_over_devices():
    """Few tiles on many devices shrink tile_block instead of padding to
    devices x policy-block (the planner's tile -> device balance)."""
    nb = ops.packed_node_budget(CFG.max_nodes)
    policy = ops.sharded_tile_block(nb, sparse=True)
    target, tb = ops.sharded_tile_plan(20, nb, 8, sparse=True)
    assert target == 32 and tb == min(policy, 4)
    # every device owns a whole number of tile_block programs
    for t in (1, 7, 20, 51, 128):
        for nd in DEVICE_COUNTS:
            target, tb = ops.sharded_tile_plan(t, nb, nd, sparse=True)
            assert target >= t and target % (nd * tb) == 0
            assert tb <= policy
    # one device degenerates to the unsharded power-of-two pad
    target, tb = ops.sharded_tile_plan(20, nb, 1)
    assert target == 32 and tb == ops.sharded_tile_block(nb)


def test_plan_devices_clamps_small_batches():
    """Tiny batches don't spread over the mesh: each device must see at
    least MIN_PACK_PAIRS pairs, halving the count until it does."""
    off_mesh = ScoringEngine(PARAMS, CFG, path="packed_sparse")
    assert off_mesh.plan(PAIRS).devices == 1


# ------------------------------------------------- score parity matrix

@pytest.mark.parametrize("nd", DEVICE_COUNTS)
@pytest.mark.parametrize("path", ["packed_dense", "packed_sparse"])
def test_score_parity_bitwise(path, nd):
    if NDEV < nd:
        pytest.skip(f"needs {nd} host devices")
    ref = _single_device_engine(path).score(PAIRS)
    eng = ScoringEngine(PARAMS, CFG, path=path, runtime=tile_runtime(nd))
    plan = eng.plan(PAIRS)
    assert plan.devices == nd
    got = eng.score(PAIRS)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    if nd > 1:
        ps = eng.last_pack_stats
        assert ps["devices"] == nd
        assert len(ps["device_occupancy"]) == nd
        assert 0.0 < sum(ps["device_occupancy"]) / nd <= 1.0
        assert eng.last_plan.devices == nd


@pytest.mark.parametrize("nd", [2, 8])
def test_standalone_wrapper_parity_bitwise(nd):
    if NDEV < nd:
        pytest.skip(f"needs {nd} host devices")
    nb = ops.packed_node_budget(CFG.max_nodes)
    packed, _ = pack_pairs(PAIRS, nb, slots_per_tile=max(8, nb // 4),
                           with_edges=True)
    mesh = tile_mesh(nd)
    ref = ops.pair_score_packed(PARAMS, packed)
    got = ops.pair_score_packed_sharded(PARAMS, packed, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    ref = ops.pair_score_sparse(PARAMS, packed)
    got = ops.pair_score_sparse_sharded(PARAMS, packed, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------- train parity matrix

@pytest.mark.parametrize("nd", DEVICE_COUNTS)
def test_grad_parity(nd):
    if NDEV < nd:
        pytest.skip(f"needs {nd} host devices")
    base = _single_device_engine("packed_sparse")
    ref_s, ref_g = base.loss_and_grad(PAIRS, TARGETS)
    eng = ScoringEngine(PARAMS, CFG, path="packed_sparse",
                        runtime=tile_runtime(nd))
    s, g = eng.loss_and_grad(PAIRS, TARGETS)
    assert float(np.max(np.abs(np.asarray(s) - np.asarray(ref_s)))) <= 1e-6
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(ref_g)):
        assert float(np.max(np.abs(np.asarray(a) - np.asarray(b)))) <= 1e-6


# --------------------------------------------------- degradation ladder

@needs(2)
def test_dead_shard_collapses_to_single_device():
    """A dead shard (fault at the sharded executor) costs the mesh, never
    the batch: the §12 ladder's new rung re-serves the call single-device,
    bitwise equal to an unsharded engine, and the degradation is counted
    under the `path@Nd` rung name on health()."""
    ref = _single_device_engine("packed_sparse").score(PAIRS)
    eng = ScoringEngine(PARAMS, CFG, path="packed_sparse",
                        runtime=tile_runtime(2))
    with faults.inject("sharded:packed_sparse", "raise", times=1):
        got = eng.score(PAIRS)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert "packed_sparse@2d" in eng.last_plan.degraded_from
    h = eng.health()
    assert h["counters"]["errors:packed_sparse@2d"] == 1
    assert any(k.startswith("packed_sparse@2d[") for k in h["breakers"])
    # healthy mesh next call: sharded again, no residual degradation
    got = eng.score(PAIRS)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert eng.last_plan.degraded_from == ()


@needs(2)
def test_dead_shard_in_training_collapses():
    base = _single_device_engine("packed_sparse")
    ref_s, ref_g = base.loss_and_grad(PAIRS, TARGETS)
    eng = ScoringEngine(PARAMS, CFG, path="packed_sparse",
                        runtime=tile_runtime(2))
    with faults.inject("sharded:train:packed_sparse", "raise", times=1):
        s, g = eng.loss_and_grad(PAIRS, TARGETS)
    assert float(np.max(np.abs(np.asarray(s) - np.asarray(ref_s)))) <= 1e-6
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(ref_g)):
        assert float(np.max(np.abs(np.asarray(a) - np.asarray(b)))) <= 1e-6
    assert "packed_sparse@2d" in eng.last_plan.degraded_from
    assert eng.health()["counters"]["errors:train:packed_sparse@2d"] == 1


# -------------------------------------------------- int16 index planes

def test_int16_edge_planes_bitwise():
    """Narrow neighbor-plane dtype (node_budget < 2**15 -> int16): the
    planes ARE int16 and score bit-identically to an int32 copy."""
    nb = ops.packed_node_budget(CFG.max_nodes)
    assert nb < 2 ** 15
    packed, _ = pack_pairs(PAIRS, nb, slots_per_tile=max(8, nb // 4),
                           with_edges=True)
    e = packed.edges
    for side in (e.edges1, e.edges2):
        assert np.asarray(side.senders).dtype == np.int16
    for side in (e.overflow1, e.overflow2):
        assert np.asarray(side.senders).dtype == np.int16
        assert np.asarray(side.receivers).dtype == np.int16

    def widen(eb):
        return EdgeBatch(np.asarray(eb.senders, np.int32),
                         np.asarray(eb.receivers, np.int32),
                         eb.weights, eb.edge_mask)

    wide = packed._replace(edges=PackedEdges(
        widen(e.edges1), widen(e.edges2),
        widen(e.overflow1), widen(e.overflow2)))
    np.testing.assert_array_equal(
        np.asarray(ops.pair_score_sparse(PARAMS, packed)),
        np.asarray(ops.pair_score_sparse(PARAMS, wide)))


# ------------------------------------------------- per-shard search scans

def _corpus_and_queries():
    rng = np.random.default_rng(7)
    corpus = [random_graph(rng, int(rng.integers(6, 24)), avg_degree=4)
              for _ in range(64)]
    queries = [random_graph(rng, int(rng.integers(6, 24)), avg_degree=4)
               for _ in range(4)]
    return corpus, queries


@needs(8)
@pytest.mark.parametrize("nd", [2, 8])
def test_sharded_search_topk_bit_identical(nd):
    """Per-shard prefilter scans + host merge return the same top-k,
    bit-for-bit (indices AND scores), as the unsharded two-stage path."""
    corpus, queries = _corpus_and_queries()
    ref = SimilaritySearchServer(PARAMS, CFG, shard_rows=8)
    ref.index(corpus)
    srv = SimilaritySearchServer(PARAMS, CFG, shard_rows=8,
                                 runtime=tile_runtime(nd))
    srv.index(corpus)
    assert srv.health()["prefilter"]["spans"] == nd
    want = ref.search(queries, k=10, mode="two_stage", prefilter_m=16)
    got = srv.search(queries, k=10, mode="two_stage", prefilter_m=16)
    for (wi, ws), (gi, gs) in zip(want, got):
        np.testing.assert_array_equal(gi, wi)
        np.testing.assert_array_equal(gs, ws)
    assert srv.engine.counters["prefilter_span_scans"] > 0
    assert srv.engine.last_plan.devices == nd


@needs(2)
def test_sharded_search_dead_span_degrades():
    corpus, queries = _corpus_and_queries()
    srv = SimilaritySearchServer(PARAMS, CFG, shard_rows=8,
                                 runtime=tile_runtime(2))
    srv.index(corpus)
    exact = srv.search(queries, k=10, mode="exact")
    with faults.inject("prefilter", "raise", times=1):
        got = srv.search(queries, k=10, mode="two_stage", prefilter_m=16)
    for (wi, ws), (gi, gs) in zip(exact, got):
        np.testing.assert_array_equal(gi, wi)
    assert srv.stats.prefilter_degraded == len(queries)
    assert srv.health()["counters"]["prefilter_degraded"] == len(queries)


def test_prefilter_spans_block_aligned():
    srv = SimilaritySearchServer(PARAMS, CFG, shard_rows=8)
    srv.engine.n_devices = 4                  # spans follow the mesh width
    spans = srv._prefilter_spans(70, 8)
    assert spans[0][0] == 0 and spans[-1][1] == 70
    for (lo, hi), (lo2, _) in zip(spans, spans[1:]):
        assert hi == lo2 and lo % 8 == 0
    assert len(spans) <= 4
    # fewer blocks than devices collapses to fewer spans
    srv.engine.n_devices = 8
    assert len(srv._prefilter_spans(10, 8)) == 2


# ----------------------------------------------------- profile schema v2

@needs(8)
def test_trace_records_carry_device_count():
    rec = TraceRecorder(capacity=64)
    eng = ScoringEngine(PARAMS, CFG, path="packed_sparse",
                        runtime=tile_runtime(8), recorder=rec)
    eng.score(PAIRS)
    rows = [r for r in rec.records() if r.kind == "score"]
    assert rows and rows[-1].n_devices == 8
    assert cost_key(rows[-1].path, rows[-1].n_devices) == "packed_sparse@8d"


def test_cost_key_single_device_is_bare_path():
    assert cost_key("packed_dense", 1) == "packed_dense"
    assert cost_key("train:packed_dense", 4) == "train:packed_dense@4d"
