"""Trace recording + measured cost-model planner (DESIGN.md §15).

Covers: the TraceRecorder ring/flush/load round trip, the golden-pinned
profile format digest (ManifestError-style refusal of unknown versions or
schemas, never a mis-parse), garbled-line skip-and-count, the injectable
clock making recorded walls deterministic, the ridge cost model recovering
planted latency structure, order-invariance of the fit (property test),
the cold-planner threshold fallback pinned as a decision table, the warm
planner flipping a decision the thresholds get wrong, and the chaos seams:
a failing recorder (executor site "profile") or a torn profile flush (fs
site "profile") must never fail scoring.
"""

import json
import os

import jax
import numpy as np
import pytest

from repro.core.engine import TRAIN_PATHS, ScoringEngine, WorkloadStats
from repro.core.profile import (PROFILE_FORMAT_VERSION, ProfileError,
                                TraceRecord, TraceRecorder, fit_cost_model,
                                read_profile, schema_digest, trace_features,
                                v1_schema_digest)
from repro.core.simgnn import SimGNNConfig, init_simgnn_params
from repro.data.graphs import random_graph
from repro.testing import faults

CFG = SimGNNConfig()
PARAMS = init_simgnn_params(jax.random.PRNGKey(0), CFG)

#: Golden digest of (PROFILE_FORMAT_VERSION, TRACE_SCHEMA) — the persisted
#: profile format contract, pinned the way tests/test_cache.py pins the
#: WL `graph_key` hashes. If this fails you changed the record schema:
#: bump `PROFILE_FORMAT_VERSION` so old profiles are refused loudly, then
#: re-pin. The v1 digest stays pinned too — v1 profiles (the committed
#: golden file among them) must keep loading, as `n_devices=1` facts,
#: until the back-compat window closes.
GOLDEN_SCHEMA_DIGEST = "24529d8af2998a3dc6305bddb4486072"
GOLDEN_V1_SCHEMA_DIGEST = "c142c827c37d33b733ec10816d76b8c8"
GOLDEN_PROFILE = os.path.join(os.path.dirname(__file__), "data",
                              "golden_profile.jsonl")


class _FakeClock:
    def __init__(self, step=0.5):
        self.t = 0.0
        self.step = step

    def __call__(self):
        self.t += self.step
        return self.t


def _pairs(seed, n, max_n=24, avg_degree=2.0):
    rng = np.random.default_rng(seed)
    return [(random_graph(rng, int(rng.integers(5, max_n + 1)),
                          avg_degree=avg_degree),
             random_graph(rng, int(rng.integers(5, max_n + 1)),
                          avg_degree=avg_degree))
            for _ in range(n)]


def _rec(path="packed_sparse", *, n_pairs=8, mean_nodes=16.0,
         avg_degree=2.0, wall_s=0.01, seq=0, degraded_from=(), **kw):
    return TraceRecord(kind=kw.pop("kind", "score"), path=path,
                       n_pairs=n_pairs, max_nodes=kw.pop("max_nodes", 24),
                       mean_nodes=mean_nodes, avg_degree=avg_degree,
                       density=kw.pop("density", 0.1),
                       occupancy=kw.pop("occupancy", 0.0),
                       to_embed=kw.pop("to_embed", 0),
                       degraded_from=tuple(degraded_from),
                       attempts=kw.pop("attempts", 1),
                       wall_s=wall_s, seq=seq)


def _profile_for(paths, *, per_path=10, noise=0.0, seed=0):
    """Synthetic clean profile with planted per-path linear latency:
    wall = base[path] + per_pair[path] * n_pairs (+ optional noise)."""
    rng = np.random.default_rng(seed)
    base = {p: 0.002 * (i + 1) for i, p in enumerate(paths)}
    slope = {p: 0.0005 * (i + 1) for i, p in enumerate(paths)}
    out = []
    seq = 0
    for p in paths:
        for j in range(per_path):
            n = 4 + 3 * j
            w = base[p] + slope[p] * n
            if noise:
                w *= 1.0 + rng.uniform(-noise, noise)
            out.append(_rec(p, n_pairs=n, wall_s=w, seq=seq))
            seq += 1
    return out


# ------------------------------------------------------------ recorder core


def test_recorder_ring_capacity_and_total():
    rec = TraceRecorder(capacity=4)
    for i in range(10):
        rec.record(kind="score", path="reference", n_pairs=1, max_nodes=8,
                   mean_nodes=8.0, avg_degree=1.0, density=0.1,
                   wall_s=0.001 * (i + 1))
    assert len(rec) == 4
    assert rec.total_records == 10
    # oldest evicted, newest kept, seq strictly increasing
    walls = [r.wall_s for r in rec.records()]
    assert walls == pytest.approx([0.007, 0.008, 0.009, 0.01])
    seqs = [r.seq for r in rec.records()]
    assert seqs == sorted(seqs) and seqs[-1] == 9


def test_recorder_never_raises_on_bad_fields():
    rec = TraceRecorder()
    out = rec.record(kind="score", path="reference", n_pairs="not an int",
                     max_nodes=8, mean_nodes=8.0, avg_degree=1.0,
                     density=0.1)
    assert out is None
    assert rec.counters["record_errors"] == 1
    assert len(rec) == 0


def test_flush_and_load_round_trip(tmp_path):
    path = str(tmp_path / "profile.jsonl")
    rec = TraceRecorder(path=path)
    for i in range(5):
        rec.record(kind="score", path="packed_dense", n_pairs=2 + i,
                   max_nodes=16, mean_nodes=12.0, avg_degree=2.0,
                   density=0.2, wall_s=0.01 * (i + 1))
    assert rec.flush() == 5
    assert rec.flush() == 0                     # nothing new pending
    loaded = TraceRecorder.load(path)
    assert [r.wall_s for r in loaded.records()] == \
        [r.wall_s for r in rec.records()]
    assert loaded.total_records == 5
    # appending through the loaded recorder extends, not duplicates
    loaded.record(kind="score", path="packed_dense", n_pairs=9,
                  max_nodes=16, mean_nodes=12.0, avg_degree=2.0,
                  density=0.2, wall_s=0.06)
    assert loaded.flush() == 1
    records, dropped = read_profile(path)
    assert len(records) == 6 and dropped == 0
    assert records[-1].seq == 5                 # load resumes the sequence


def test_auto_flush_every(tmp_path):
    path = str(tmp_path / "profile.jsonl")
    rec = TraceRecorder(path=path, flush_every=3)
    for i in range(7):
        rec.record(kind="score", path="reference", n_pairs=1, max_nodes=8,
                   mean_nodes=8.0, avg_degree=1.0, density=0.1,
                   wall_s=0.001)
    assert rec.counters["flushes"] == 2         # at 3 and 6
    assert len(read_profile(path)[0]) == 6


# ----------------------------------------------------- format golden pins


def test_schema_digest_golden_pinned():
    assert PROFILE_FORMAT_VERSION == 2
    assert schema_digest() == GOLDEN_SCHEMA_DIGEST
    assert v1_schema_digest() == GOLDEN_V1_SCHEMA_DIGEST


def test_golden_profile_reads_clean():
    """The committed trace (a past run's v1 profile) must stay readable:
    every v1 record ran single-device, so it loads with `n_devices=1`."""
    records, dropped = read_profile(GOLDEN_PROFILE)
    assert dropped == 0
    assert [r.path for r in records] == [
        "packed_sparse", "packed_dense", "bucketed_mega",
        "embedding_cache", "packed_dense", "train:packed_sparse",
        "train_step"]
    assert records[4].degraded_from == ("packed_sparse",)
    assert records[3].to_embed == 1
    assert all(r.n_devices == 1 for r in records)
    header = json.loads(open(GOLDEN_PROFILE).readline())
    assert header == {"profile_format_version": 1,
                      "schema_digest": GOLDEN_V1_SCHEMA_DIGEST}


def test_v1_profile_upgrades_to_v2_on_flush(tmp_path):
    """Appending to a v1 profile rewrites it in the current format: v2
    header, every record carrying an explicit `n_devices` — and the
    upgraded file re-reads bit-compatibly (same records, no drops)."""
    path = str(tmp_path / "old.jsonl")
    with open(path, "w") as f:
        f.write(open(GOLDEN_PROFILE).read())
    before, _ = read_profile(path)
    rec = TraceRecorder.load(path)
    rec.record(kind="score", path="packed_sparse", n_pairs=4, max_nodes=8,
               mean_nodes=8.0, avg_degree=2.0, density=0.2, wall_s=0.002,
               n_devices=8)
    assert rec.flush() == 1
    header = json.loads(open(path).readline())
    assert header == {"profile_format_version": PROFILE_FORMAT_VERSION,
                      "schema_digest": GOLDEN_SCHEMA_DIGEST}
    records, dropped = read_profile(path)
    assert dropped == 0
    assert len(records) == len(before) + 1
    assert [r.n_devices for r in records] == [1] * len(before) + [8]
    for line in open(path).read().splitlines()[1:]:
        assert "n_devices" in json.loads(line)


@pytest.mark.parametrize("mutate", ["version", "digest", "not_json",
                                    "not_object"])
def test_unknown_profile_refused_structured(tmp_path, mutate):
    """Header-level damage/misversioning is refused with ProfileError —
    never guessed at (the ManifestError contract, DESIGN.md §13/§15)."""
    src = open(GOLDEN_PROFILE).read().splitlines()
    head = json.loads(src[0])
    if mutate == "version":
        head["profile_format_version"] = PROFILE_FORMAT_VERSION + 1
        src[0] = json.dumps(head)
    elif mutate == "digest":
        head["schema_digest"] = "0" * 32
        src[0] = json.dumps(head)
    elif mutate == "not_json":
        src[0] = "{torn header"
    else:
        src[0] = json.dumps(["not", "an", "object"])
    path = str(tmp_path / "bad.jsonl")
    with open(path, "w") as f:
        f.write("\n".join(src) + "\n")
    with pytest.raises(ProfileError):
        TraceRecorder.load(path)


def test_missing_profile_refused():
    with pytest.raises(ProfileError):
        TraceRecorder.load("/nonexistent/profile.jsonl")


def test_garbled_record_lines_skipped_and_counted(tmp_path):
    """Per-line damage loses samples, never the profile: torn JSON, wrong
    fields, wrong types are each dropped-and-counted."""
    lines = open(GOLDEN_PROFILE).read().splitlines()
    bad = json.loads(lines[1])
    bad["n_pairs"] = "eight"                    # wrong type
    extra = json.loads(lines[1])
    extra["surprise"] = 1                       # foreign field
    doctored = ([lines[0], lines[1][: len(lines[1]) // 2]]  # torn record
                + lines[2:4] + [json.dumps(bad), json.dumps(extra)]
                + lines[4:])
    path = str(tmp_path / "garbled.jsonl")
    with open(path, "w") as f:
        f.write("\n".join(doctored) + "\n")
    records, dropped = read_profile(path)
    assert dropped == 3
    assert len(records) == len(lines) - 1 - 1   # header + torn line
    # a flush through the damaged file self-heals: re-read keeps only the
    # valid lines plus the new append, and drops are counted once more
    rec = TraceRecorder.load(path)
    rec.record(kind="score", path="reference", n_pairs=1, max_nodes=8,
               mean_nodes=8.0, avg_degree=1.0, density=0.1, wall_s=0.001)
    assert rec.flush() == 1
    records2, dropped2 = read_profile(path)
    assert dropped2 == 0                        # healed on disk
    assert len(records2) == len(records) + 1


# ------------------------------------------------------------ engine traces


def test_engine_records_score_trace_with_injectable_clock():
    clock = _FakeClock(step=0.25)
    eng = ScoringEngine(PARAMS, CFG, path="packed_sparse", clock=clock)
    pairs = _pairs(0, 6)
    eng.score(pairs)
    recs = eng.recorder.records()
    assert len(recs) == 1
    r = recs[0]
    assert (r.kind, r.path, r.n_pairs) == ("score", "packed_sparse", 6)
    # the fake clock ticks 0.25 per read; other reads (breakers etc.) may
    # land between t0 and t1 so the wall is a positive multiple of 0.25
    assert r.wall_s > 0 and r.wall_s % 0.25 == pytest.approx(0.0)
    assert r.mean_nodes == pytest.approx(eng.last_plan.stats.mean_nodes)
    assert r.avg_degree == pytest.approx(eng.last_plan.stats.avg_degree)
    assert 0.0 < r.occupancy <= 1.0             # packed path measured pack
    assert r.degraded_from == ()


def test_engine_records_train_trace():
    eng = ScoringEngine(PARAMS, CFG, path="packed_sparse",
                        clock=_FakeClock())
    pairs = _pairs(1, 6)
    targets = np.linspace(0.1, 0.9, 6).astype(np.float32)
    eng.loss_and_grad(pairs, targets)
    recs = eng.recorder.records()
    assert [(r.kind, r.path) for r in recs] == \
        [("train", "train:packed_sparse")]
    assert recs[0].wall_s > 0


def test_degraded_call_records_tail_and_is_excluded_from_fit():
    eng = ScoringEngine(PARAMS, CFG, path="auto", clock=_FakeClock())
    pairs = _pairs(2, 8, avg_degree=2.0)        # auto -> packed_sparse
    with faults.inject("packed_sparse", mode="raise"):
        eng.score(pairs)
    r = eng.recorder.records()[-1]
    assert "packed_sparse" in r.degraded_from
    assert r.path != "packed_sparse"            # the rung that served
    model = fit_cost_model([r], min_support=1)
    assert model.weights == {}                  # polluted timing: not clean


def test_health_reports_planner_state():
    eng = ScoringEngine(PARAMS, CFG, path="packed_sparse",
                        clock=_FakeClock())
    eng.score(_pairs(3, 5))
    h = eng.health()["planner"]
    assert h["mode"] == "measured"
    assert h["enabled"] is False                # no model yet
    assert h["records"] == 1
    assert h.get("model") is None               # snapshot only once fitted


# -------------------------------------------------------------- cost model


def test_fit_recovers_planted_latency_model():
    paths = ("bucketed_mega", "packed_dense", "packed_sparse")
    model = fit_cost_model(_profile_for(paths), min_support=8)
    assert model.supports(paths)
    for i, p in enumerate(paths):
        # noiseless data: residual is only the (tiny) ridge-penalty bias
        assert model.residual_medape[p] < 1e-2
        for n in (5, 17, 40):
            want = 0.002 * (i + 1) + 0.0005 * (i + 1) * n
            got = model.predict(p, trace_features(n, 16.0, 2.0))
            assert got == pytest.approx(want, rel=1e-2)


def test_fit_ignores_underdsupported_and_dirty_paths():
    records = _profile_for(("packed_dense",), per_path=10)
    records += [_rec("packed_sparse", wall_s=0.01, seq=100 + i)
                for i in range(3)]              # under min_support
    records += [_rec("bucketed_mega", wall_s=0.01, seq=200 + i,
                     degraded_from=("packed_sparse",)) for i in range(10)]
    records += [_rec("two_kernel", wall_s=0.0, seq=300 + i)
                for i in range(10)]             # zero wall: not clean
    model = fit_cost_model(records, min_support=8)
    assert set(model.weights) == {"packed_dense"}
    assert model.support["packed_dense"] == 10


def test_predictions_clamped_positive():
    # steeply decreasing walls force a negative extrapolation at large n
    records = [_rec("packed_dense", n_pairs=n, wall_s=0.1 / n, seq=i)
               for i, n in enumerate(range(4, 24))]
    model = fit_cost_model(records, min_support=8)
    assert model.predict("packed_dense",
                         trace_features(10_000, 16.0, 2.0)) >= 1e-9


@pytest.mark.parametrize("seed", range(25))
def test_fit_invariant_under_record_order(seed):
    """Property: the argmin the planner takes must not depend on arrival
    order — any permutation of the records produces bit-identical weights
    (fit rows are sorted internally before any linear algebra). Written as
    a seeded shuffle sweep so the invariant runs without hypothesis, like
    tests/test_pack_properties.py's note."""
    records = _profile_for(("packed_dense", "packed_sparse"),
                           per_path=9, noise=0.3, seed=7)
    base = fit_cost_model(records, min_support=8)
    shuffled = list(records)
    np.random.default_rng(seed).shuffle(shuffled)
    other = fit_cost_model(shuffled, min_support=8)
    assert set(base.weights) == set(other.weights)
    for p in base.weights:
        assert base.weights[p].tobytes() == other.weights[p].tobytes()
        assert base.residual_medape[p] == other.residual_medape[p]


# ------------------------------------------------- planner decision rule


#: The threshold decision table the cold planner must reproduce — one row
#: per folklore rule (DESIGN.md §15 pins these as the fallback contract).
COLD_DECISIONS = [
    # (stats, hit_frac, train) -> path
    (WorkloadStats(n_pairs=8, max_nodes=24, mean_nodes=16.0,
                   avg_degree=2.0, density=0.1), 0.0, False,
     "packed_sparse"),                     # degree <= 4: sparse
    (WorkloadStats(n_pairs=8, max_nodes=24, mean_nodes=16.0,
                   avg_degree=6.0, density=0.4), 0.0, False,
     "packed_dense"),                      # degree > 4: dense
    (WorkloadStats(n_pairs=3, max_nodes=24, mean_nodes=16.0,
                   avg_degree=2.0, density=0.1), 0.0, False,
     "bucketed_mega"),                     # batch < MIN_PACK_PAIRS
    (WorkloadStats(n_pairs=8, max_nodes=24, mean_nodes=16.0,
                   avg_degree=2.0, density=0.1), 0.6, False,
     "embedding_cache"),                   # >= 50% resident
    (WorkloadStats(n_pairs=3, max_nodes=24, mean_nodes=16.0,
                   avg_degree=2.0, density=0.1), 0.0, True,
     "reference"),                         # train small batch
    (WorkloadStats(n_pairs=8, max_nodes=24, mean_nodes=16.0,
                   avg_degree=6.0, density=0.4), 0.9, True,
     "packed_dense"),                      # train never reads the cache
]


@pytest.mark.parametrize("stats,hit_frac,train,want", COLD_DECISIONS)
def test_cold_planner_decision_table(stats, hit_frac, train, want):
    """Empty profile: `_select` must be bit-identical (path AND reason) to
    the threshold rules for every folklore regime."""
    measured = ScoringEngine(PARAMS, CFG, planner="measured")
    threshold = ScoringEngine(PARAMS, CFG, planner="threshold")
    got = measured._select(stats, hit_frac, train=train)
    ref = threshold._select(stats, hit_frac, train=train)
    assert got == ref
    assert got[0] == want
    assert got[2] == {}                         # no estimates when cold


def test_partial_support_falls_back_whole():
    """A profile covering SOME candidates must not steer: comparing a
    measured path against an unmeasured one is meaningless."""
    eng = ScoringEngine(PARAMS, CFG, planner="measured")
    for r in _profile_for(("packed_dense", "packed_sparse")):
        eng.recorder._ring.append(r)
        eng.recorder.total_records += 1
    stats = WorkloadStats(n_pairs=8, max_nodes=24, mean_nodes=16.0,
                          avg_degree=2.0, density=0.1)
    got = eng._select(stats, 0.0)
    ref = ScoringEngine(PARAMS, CFG,
                        planner="threshold")._select(stats, 0.0)
    assert got == ref                           # bucketed_mega missing


def test_warm_planner_overrides_threshold_rule():
    """With full support and bucketed_mega measured cheapest, the planner
    must flip a low-degree batch away from the sparse threshold rule, and
    publish its estimates on the plan."""
    paths = ("bucketed_mega", "packed_dense", "packed_sparse")
    eng = ScoringEngine(PARAMS, CFG, planner="measured")
    for r in _profile_for(paths):               # bucketed_mega cheapest
        eng.recorder._ring.append(r)
        eng.recorder.total_records += 1
    pairs = _pairs(4, 8, avg_degree=2.0)
    plan = eng.plan(pairs)
    assert plan.path == "bucketed_mega"
    assert "cost model" in plan.reason
    assert set(plan.cost_estimates) == set(paths)
    assert plan.cost_estimates["bucketed_mega"] == \
        min(plan.cost_estimates.values())
    # threshold engine on the same batch keeps the folklore rule
    ref = ScoringEngine(PARAMS, CFG, planner="threshold").plan(pairs)
    assert ref.path == "packed_sparse"
    assert ref.cost_estimates == {}
    # and health now reports the fitted model
    h = eng.health()["planner"]
    assert h["enabled"] is True
    assert set(h["model"]["support"]) >= set(paths)


def test_train_planner_uses_train_keyed_model():
    eng = ScoringEngine(PARAMS, CFG, planner="measured")
    train_keys = tuple(f"train:{p}" for p in TRAIN_PATHS)
    for r in _profile_for(train_keys):          # train:reference cheapest
        eng.recorder._ring.append(r)
        eng.recorder.total_records += 1
    plan = eng.plan(_pairs(5, 8, avg_degree=2.0), train=True)
    assert plan.path == "reference"
    assert set(plan.cost_estimates) == set(TRAIN_PATHS)


def test_planner_refit_cadence():
    eng = ScoringEngine(PARAMS, CFG, path="packed_sparse",
                        clock=_FakeClock())
    pairs = _pairs(6, 5)
    for _ in range(3):
        eng.score(pairs)
    assert eng.counters["planner_refits"] == 0  # < PLANNER_MIN_SUPPORT
    for _ in range(6):
        eng.score(pairs)
    eng._cost_model()
    refits = eng.counters["planner_refits"]
    assert refits == 1                          # first fit at >= support
    for _ in range(eng.PLANNER_REFIT_EVERY):
        eng.score(pairs)
    eng._cost_model()
    assert eng.counters["planner_refits"] == refits + 1


# ------------------------------------------------------------- chaos seams


def test_recorder_failure_never_fails_scoring():
    """Executor seam site "profile": a crashing recorder is counted and
    swallowed — the scores still come back finite."""
    eng = ScoringEngine(PARAMS, CFG, path="packed_sparse",
                        clock=_FakeClock())
    pairs = _pairs(7, 6)
    with faults.inject("profile", mode="raise") as plan:
        out = eng.score(pairs)
    assert plan.triggered >= 1
    assert np.isfinite(out).all()
    assert eng.counters["profile_record_errors"] >= 1
    assert len(eng.recorder) == 0               # nothing recorded
    # recorder works again once the fault clears
    eng.score(pairs)
    assert len(eng.recorder) == 1


def test_torn_profile_flush_self_heals(tmp_path):
    """Fs seam site "profile": a torn flush loses at most the tail — the
    next read skips-and-counts the damaged line and the next flush
    rewrites a clean file."""
    path = str(tmp_path / "profile.jsonl")
    rec = TraceRecorder(path=path)
    for i in range(4):
        rec.record(kind="score", path="reference", n_pairs=1 + i,
                   max_nodes=8, mean_nodes=8.0, avg_degree=1.0,
                   density=0.1, wall_s=0.001)
    with faults.fs_inject("profile", mode="torn") as plan:
        rec.flush()
    assert plan.triggered == 1
    records, dropped = read_profile(path)       # torn mid-file
    assert dropped >= 1
    assert len(records) < 4
    rec2 = TraceRecorder.load(path)
    rec2.record(kind="score", path="reference", n_pairs=9, max_nodes=8,
                mean_nodes=8.0, avg_degree=1.0, density=0.1, wall_s=0.002)
    assert rec2.flush() == 1
    records2, dropped2 = read_profile(path)
    assert dropped2 == 0                        # healed
    assert records2[-1].n_pairs == 9


def test_missing_profile_write_keeps_pending(tmp_path):
    """A dropped flush (site "profile", mode "missing") leaves no file —
    and the recorder still holds the ring so nothing is lost in memory."""
    path = str(tmp_path / "profile.jsonl")
    rec = TraceRecorder(path=path)
    rec.record(kind="score", path="reference", n_pairs=1, max_nodes=8,
               mean_nodes=8.0, avg_degree=1.0, density=0.1, wall_s=0.001)
    with faults.fs_inject("profile", mode="missing"):
        rec.flush()
    assert not os.path.exists(path)
    assert len(rec) == 1
    rec.record(kind="score", path="reference", n_pairs=2, max_nodes=8,
               mean_nodes=8.0, avg_degree=1.0, density=0.1, wall_s=0.001)
    rec.flush()                                 # clean retry persists all
    assert len(read_profile(path)[0]) >= 1
