"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step asserting output shapes + finiteness, plus prefill/decode consistency —
the assignment's required smoke coverage for all 10 archs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.distributed.sharding import Runtime
from repro.models import encdec, lm
from repro.models.init import init_params

RT = Runtime(mesh=None)


def _setup(arch, seed=0, b=2, s=16):
    cfg = reduced_config(arch)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(seed + 1), (b, s), 0,
                             cfg.vocab_size)
    return cfg, params, tok


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    """One full train step (fwd+bwd+AdamW) — shapes preserved, loss finite."""
    from repro.train.optimizer import adamw_init
    from repro.train.step import build_train_step

    cfg, params, tok = _setup(arch)
    if cfg.is_enc_dec:
        batch = {"frames": jax.random.normal(jax.random.PRNGKey(2),
                                             (2, 16, cfg.d_model)),
                 "tokens": tok}
    elif cfg.frontend == "vision":
        batch = {"tokens": tok,
                 "embeds": jax.random.normal(jax.random.PRNGKey(2),
                                             (2, cfg.frontend_len,
                                              cfg.d_model))}
    else:
        batch = {"tokens": tok}
    opt = adamw_init(params, cfg.opt_state_dtype)
    step = build_train_step(cfg, RT)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(opt2.step) == 1
    # params changed but kept structure/shapes
    jax.tree.map(lambda a, b_: None if a.shape == b_.shape else 1 / 0,
                 params, params2)
    diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                   - b_.astype(jnp.float32))))
             for a, b_ in zip(jax.tree.leaves(params),
                              jax.tree.leaves(params2))]
    assert max(diffs) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_consistency(arch):
    """prefill(S-1) + decode(1) logits == full forward's last logits.
    MoE archs use ample capacity so routing drops cannot differ."""
    cfg = reduced_config(arch)
    if cfg.moe_period:
        cfg = cfg.with_(capacity_factor=16.0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    tok = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    if cfg.is_enc_dec:
        frames = jax.random.normal(jax.random.PRNGKey(2), (b, 24, cfg.d_model))
        full, _ = encdec.forward_encdec(params, cfg, RT, frames, tok)
        last, enc_out, caches, pos = encdec.prefill_encdec(
            params, cfg, RT, frames, tok[:, :-1], cache_len=s)
        dec, _, pos2 = encdec.decode_step_encdec(params, cfg, RT, tok[:, -1:],
                                                 enc_out, caches, pos)
    else:
        embeds = None
        if cfg.frontend == "vision":
            embeds = jax.random.normal(jax.random.PRNGKey(2),
                                       (b, cfg.frontend_len, cfg.d_model))
        full, _ = lm.forward(params, cfg, RT, tok, embeds=embeds)
        last, caches, pos = lm.prefill(params, cfg, RT, tok[:, :-1],
                                       embeds=embeds,
                                       cache_len=s + (cfg.frontend_len or 0))
        dec, _, pos2 = lm.decode_step(params, cfg, RT, tok[:, -1:], caches, pos)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -2]),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)
    expected_pos = s + (cfg.frontend_len if cfg.frontend == "vision" else 0)
    assert int(pos2[0]) == expected_pos


def test_sliding_window_ring_cache():
    """SWA decode with ring cache == decode with a full-length cache."""
    cfg = reduced_config("h2o-danube-3-4b")          # window 8
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 1, 24
    tok = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    full, _ = lm.forward(params, cfg, RT, tok)
    # ring cache is min(window, cache_len) = 8 slots
    last, caches, pos = lm.prefill(params, cfg, RT, tok[:, :-1], cache_len=s)
    assert caches[0]["attn"]["k"].shape[2] == cfg.sliding_window
    dec, _, _ = lm.decode_step(params, cfg, RT, tok[:, -1:], caches, pos)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_multi_step_greedy_generation():
    from repro.serve.step import greedy_generate
    cfg = reduced_config("qwen1.5-4b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    # generate against a cache with headroom
    last, caches, pos = lm.prefill(params, cfg, RT, prompt, cache_len=32)
    toks = [jnp.argmax(last, -1)]
    for _ in range(4):
        logits, caches, pos = lm.decode_step(params, cfg, RT,
                                             toks[-1][:, None], caches, pos)
        toks.append(jnp.argmax(logits, -1))
    out = jnp.stack(toks, 1)
    assert out.shape == (2, 5)
    assert bool(jnp.all(out < cfg.vocab_size))      # pad ids never sampled


def test_gemma2_softcap_and_postnorm_active():
    cfg = reduced_config("gemma2-9b").with_(final_softcap=5.0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    logits, _ = lm.forward(params, cfg, RT, tok)
    real = np.asarray(logits)[..., :cfg.vocab_size]
    assert np.abs(real).max() <= 5.0 + 1e-3


def test_full_configs_param_counts():
    """Full (non-reduced) configs match published totals within 5%."""
    published = {"granite-moe-3b-a800m": 3.3e9, "phi3.5-moe-42b-a6.6b": 41.9e9,
                 "gemma2-9b": 9.2e9, "phi3-mini-3.8b": 3.8e9,
                 "h2o-danube-3-4b": 3.9e9, "qwen1.5-4b": 4.0e9,
                 "rwkv6-7b": 7.5e9, "jamba-1.5-large-398b": 398e9,
                 "internvl2-2b": 1.7e9}
    for arch, target in published.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < 0.05, (arch, n, target)


def test_int8_kv_cache_accuracy():
    """int8 KV cache (cell-C serving optimization): decode logits within
    quantization tolerance of the bf16-cache path and the full forward."""
    cfg = reduced_config("gemma2-9b").with_(kv_cache_dtype="int8")
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 24
    tok = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    full, _ = lm.forward(params, cfg, RT, tok)
    last, caches, pos = lm.prefill(params, cfg, RT, tok[:, :-1], cache_len=s)
    assert caches[0]["attn"]["k"].dtype == jnp.int8
    dec, new_caches, _ = lm.decode_step(params, cfg, RT, tok[:, -1:], caches,
                                        pos)
    assert new_caches[0]["attn"]["k"].dtype == jnp.int8
    err = float(jnp.max(jnp.abs(dec - full[:, -1])))
    assert err < 0.05, err
