"""Seed-determinism regression for the data/graphs.py streams (no
hypothesis required — these must run everywhere tier-1 runs).

The host data pipeline is the FPGA host-preprocessing role: the same seed
must realize the same graphs, the same measured density/degree annotations
and — for the Zipf search stream — the same corpus and pick sequence, or
benchmark/bench-gate numbers stop being comparable across runs.
"""

import numpy as np

from repro.data.graphs import (pair_stream, search_pairs, zipf_corpus,
                               zipf_query_stream)


def _same_graph(a: dict, b: dict) -> bool:
    return (np.array_equal(a["adj"], b["adj"])
            and np.array_equal(a["labels"], b["labels"])
            and a["density"] == b["density"]
            and a["avg_degree"] == b["avg_degree"])


def test_pair_stream_seed_deterministic():
    a = next(pair_stream(9, 6, avg_degree=3.0))
    b = next(pair_stream(9, 6, avg_degree=3.0))
    np.testing.assert_array_equal(np.asarray(a["adj1"]),
                                  np.asarray(b["adj1"]))
    np.testing.assert_array_equal(a["target"], b["target"])
    assert a["density"] == b["density"]
    assert a["avg_degree"] == b["avg_degree"]
    c = next(pair_stream(10, 6, avg_degree=3.0))
    assert not np.array_equal(np.asarray(a["adj1"]), np.asarray(c["adj1"]))


def test_search_pairs_seed_deterministic():
    a = search_pairs(4, 5, avg_degree=2.1)
    b = search_pairs(4, 5, avg_degree=2.1)
    assert all(_same_graph(x, y) for (x, _), (y, _) in zip(a, b))
    assert all(_same_graph(x, y) for (_, x), (_, y) in zip(a, b))


def test_zipf_stream_seed_deterministic():
    sa, sb = (zipf_query_stream(17, 24, n_corpus=32) for _ in range(2))
    for _ in range(3):
        a, b = next(sa), next(sb)
        np.testing.assert_array_equal(a["corpus_idx"], b["corpus_idx"])
        assert _same_graph(a["query"], b["query"])
        assert all(_same_graph(x, y) for (_, x), (_, y)
                   in zip(a["pairs"], b["pairs"]))
        assert a["unique_frac"] == b["unique_frac"]
    other = next(zipf_query_stream(18, 24, n_corpus=32))
    assert not np.array_equal(next(sa)["corpus_idx"], other["corpus_idx"])


def test_zipf_stream_matches_zipf_corpus():
    """`zipf_corpus(seed)` IS the stream's corpus: an indexing service can
    embed exactly the graphs the stream will request."""
    corpus = zipf_corpus(19, 16)
    batch = next(zipf_query_stream(19, 20, n_corpus=16))
    for (_, g), i in zip(batch["pairs"], batch["corpus_idx"]):
        assert _same_graph(g, corpus[i])
        assert g["adj"].shape == corpus[i]["adj"].shape


def test_zipf_stream_is_skewed_and_reuses_corpus():
    batch = next(zipf_query_stream(20, 128, n_corpus=64, exponent=1.2))
    idx = batch["corpus_idx"]
    # heavy reuse: far fewer unique graphs than picks, and the most popular
    # graph drawn well above the uniform expectation (2 picks/graph)
    assert batch["unique_frac"] < 0.8
    assert np.bincount(idx).max() >= 6
    # all pairs share the single query object (1-vs-N shape)
    assert all(p[0] is batch["pairs"][0][0] for p in batch["pairs"])
