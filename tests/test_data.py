"""Data pipeline: determinism, host sharding, GED label properties."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.data.graphs import (edit_graph, ged_target, pair_stream,
                               query_pairs, random_graph)
from repro.data.tokens import batch_for_step


def test_tokens_deterministic_per_step():
    cfg = get_config("qwen1.5-4b")
    a = batch_for_step(cfg, 7, global_batch=8, seq_len=32)
    b = batch_for_step(cfg, 7, global_batch=8, seq_len=32)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = batch_for_step(cfg, 8, global_batch=8, seq_len=32)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_tokens_host_sharding_partitions_global_batch():
    cfg = get_config("qwen1.5-4b")
    full = [batch_for_step(cfg, 3, global_batch=8, seq_len=16,
                           process_index=i, process_count=4)["tokens"]
            for i in range(4)]
    assert all(f.shape == (2, 16) for f in full)
    # distinct shards (with overwhelming probability)
    assert not np.array_equal(full[0], full[1])


def test_tokens_in_vocab_range():
    for arch in ("gemma2-9b", "seamless-m4t-large-v2", "internvl2-2b"):
        cfg = get_config(arch)
        b = batch_for_step(cfg, 0, global_batch=4, seq_len=512)
        assert b["tokens"].max() < cfg.vocab_size
        assert b["tokens"].min() >= 0


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_graph_generator_properties(seed):
    rng = np.random.default_rng(seed)
    g = random_graph(rng)
    n = g["adj"].shape[0]
    assert 5 <= n <= 64
    # symmetric, no self loops
    np.testing.assert_array_equal(g["adj"], g["adj"].T)
    assert np.trace(g["adj"]) == 0
    # connected (spanning-tree construction)
    reach = np.linalg.matrix_power(g["adj"] + np.eye(n), n) > 0
    assert reach.all()
    # edit preserves symmetry and node count
    g2 = edit_graph(rng, g, 4)
    assert g2["adj"].shape == g["adj"].shape
    np.testing.assert_array_equal(g2["adj"], g2["adj"].T)


def test_ged_target_range_and_monotonic():
    assert ged_target(0, 10, 10) == 1.0
    vals = [ged_target(k, 20, 20) for k in range(6)]
    assert all(0 < v <= 1 for v in vals)
    assert all(a > b for a, b in zip(vals, vals[1:]))


def test_pair_stream_batch_shapes():
    b = next(pair_stream(0, 6, max_nodes=32))
    assert b["adj1"].shape == (6, 32, 32)
    assert b["feats1"].shape[2] == 29
    assert 0 < b["target"].min() <= b["target"].max() <= 1.0


def test_query_pairs_deterministic():
    a = query_pairs(5, 4)
    b = query_pairs(5, 4)
    np.testing.assert_array_equal(a[2][0]["adj"], b[2][0]["adj"])
