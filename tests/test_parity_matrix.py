"""Engine-wide parity matrix: every scoring path × dtype × odd/even batch,
asserted against ONE dense-reference score vector from a single source of
truth (this file's `REF` + `ATOL` tables) — replacing the per-file ad-hoc
comparisons as the parity contract.

f32 bounds: the pure-jnp reference and the packed/embedding-cached kernels
hold 1e-6 (post-sigmoid scores); the two bucketed fused-GCN paths
(two_kernel, bucketed_mega) re-derive normalization inside the kernel in a
different contraction order and hold 2e-5 — the bound their own seed tests
established. bf16 inputs hold the 2e-2 band everywhere (fp32 accumulation).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.batching import bucket_pairs
from repro.core.engine import PATHS, ScoringEngine
from repro.core.simgnn import SimGNNConfig, init_simgnn_params, pair_score
from repro.data.graphs import random_graph

CFG = SimGNNConfig()

#: single source of truth for the f32 parity bound of every path.
ATOL_F32 = {
    "reference": 1e-6,
    "two_kernel": 2e-5,
    "bucketed_mega": 2e-5,
    "packed_dense": 1e-6,
    "packed_sparse": 1e-6,
    "embedding_cache": 1e-6,
}
ATOL_BF16 = 2e-2
BATCHES = (7, 12)        # odd (pads every block policy) and even


@functools.lru_cache(maxsize=None)
def _params(dtype: str):
    p = init_simgnn_params(jax.random.PRNGKey(0), CFG)
    if dtype == "bfloat16":
        p = jax.tree.map(
            lambda x: x.astype(jnp.bfloat16)
            if x.dtype == jnp.float32 else x, p)
    return p


@functools.lru_cache(maxsize=None)
def _pairs(batch: int):
    rng = np.random.default_rng(100 + batch)
    return tuple((random_graph(rng, int(rng.integers(5, 65))),
                  random_graph(rng, int(rng.integers(5, 65))))
                 for _ in range(batch))


@functools.lru_cache(maxsize=None)
def _reference(batch: int) -> tuple:
    """The dense f32 reference: bucketed pure-jnp `pair_score`."""
    out = np.zeros(batch, np.float32)
    for b, (lhs, rhs, idxs) in bucket_pairs(
            _pairs(batch), CFG.n_node_labels, allow_oversize=True).items():
        out[idxs] = np.asarray(pair_score(
            _params("float32"), lhs.adj, lhs.feats, lhs.mask,
            rhs.adj, rhs.feats, rhs.mask))
    return tuple(out)


@pytest.mark.parametrize("batch", BATCHES)
@pytest.mark.parametrize("dtype", ("float32", "bfloat16"))
@pytest.mark.parametrize("path", PATHS)
def test_parity_matrix(path, dtype, batch):
    assert path in ATOL_F32, f"new path {path} missing a parity bound"
    engine = ScoringEngine(_params(dtype), CFG, path=path)
    out = engine.score(list(_pairs(batch)))
    ref = np.asarray(_reference(batch), np.float32)
    atol = ATOL_F32[path] if dtype == "float32" else ATOL_BF16
    np.testing.assert_allclose(out, ref, rtol=0, atol=atol)
    assert engine.last_plan.path == path


def test_matrix_covers_every_engine_path():
    """The matrix and the engine registry cannot drift apart silently."""
    assert set(ATOL_F32) == set(PATHS)


@pytest.mark.parametrize("batch", BATCHES)
def test_cold_measured_planner_matches_threshold(batch):
    """With an empty profile the measured planner (the default) must be
    BIT-identical to the legacy threshold rules — same auto-dispatch path,
    same reason, byte-equal scores (DESIGN.md §15 cold-fallback contract).
    The planner may only change decisions once it has fitted a model."""
    pairs = list(_pairs(batch))
    measured = ScoringEngine(_params("float32"), CFG, planner="measured")
    threshold = ScoringEngine(_params("float32"), CFG, planner="threshold")
    out_m = np.asarray(measured.score(pairs))
    out_t = np.asarray(threshold.score(pairs))
    assert measured.last_plan.path == threshold.last_plan.path
    assert measured.last_plan.reason == threshold.last_plan.reason
    assert not measured.last_plan.cost_estimates      # cold: no predictions
    assert out_m.tobytes() == out_t.tobytes()
