"""Chaos suite (DESIGN.md §12): every fault-tolerance transition driven
deterministically through `repro.testing.faults`.

Covers: the degradation ladder on all six scoring paths (degraded output
stays within the healthy parity band), all three fault modes (raise / oom /
nan), circuit-breaker open -> half-open -> closed with an injected clock,
input quarantine (lenient NaN + structured records, strict raise), the
guarded training ladder (grad parity after degrade, NaN-step skip with
bit-identical optimizer state), MicroBatcher per-request deadlines and
retry-with-backoff, the search server surviving failed corpus embeds, the
mid-stream checkpoint resume contract, and the warn-once reset hook.

CI runs this file as its own step so a robustness regression is
distinguishable from a functional one at a glance.
"""

import shutil
import warnings

import jax
import numpy as np
import pytest

from repro.core.engine import (DEGRADE_LADDER, PATHS, ScoringEngine,
                               tree_all_finite)
from repro.core.health import CLOSED, HALF_OPEN, OPEN, CircuitBreaker
from repro.core.simgnn import SimGNNConfig, init_simgnn_params
from repro.core.validate import GraphValidationError, graph_problems
from repro.data.graphs import random_graph, search_pairs
from repro.testing import faults

CFG = SimGNNConfig()
PARAMS = init_simgnn_params(jax.random.PRNGKey(0), CFG)


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _pairs(seed, n, max_n=24, avg_degree=2.0):
    rng = np.random.default_rng(seed)
    return [(random_graph(rng, int(rng.integers(5, max_n + 1)),
                          avg_degree=avg_degree),
             random_graph(rng, int(rng.integers(5, max_n + 1)),
                          avg_degree=avg_degree))
            for _ in range(n)]


def _engine(path="auto", **kw):
    kw.setdefault("clock", _FakeClock())
    return ScoringEngine(PARAMS, CFG, path=path, **kw)


def _ref_scores(pairs):
    return ScoringEngine(PARAMS, CFG, path="reference").score(pairs)


# ---------------------------------------------------- ladder: scoring paths

@pytest.mark.parametrize("path,atol", [
    ("packed_sparse", 1e-6), ("packed_dense", 2e-5),
    ("bucketed_mega", 2e-5), ("two_kernel", 2e-5)])
def test_degraded_call_matches_reference(path, atol):
    """Forcing the planned path's executor to crash completes the call on
    the next rung, within the reference parity band, and records the
    degradation on the republished plan."""
    pairs = _pairs(0, 12)
    eng = _engine(path)
    with faults.inject(path) as plan:
        out = eng.score(pairs)
    assert plan.triggered >= 1
    np.testing.assert_allclose(out, _ref_scores(pairs), rtol=0, atol=atol)
    assert eng.last_plan.degraded_from[0] == path
    assert eng.last_plan.attempts >= 2
    assert eng.counters[f"errors:{path}"] == 1


def test_embedding_cache_degrades_on_total_embed_failure():
    """When the embed executor AND its reference retry both die, the cached
    path's scores are NaN -> the ladder treats the rung as failed and the
    bucketed megakernel recomputes the batch from raw graphs."""
    pairs = _pairs(1, 8)
    eng = _engine("embedding_cache")
    with faults.inject("embed"), faults.inject("embed_fallback"):
        out = eng.score(pairs)
    np.testing.assert_allclose(out, _ref_scores(pairs), rtol=0, atol=2e-5)
    assert eng.last_plan.degraded_from[0] == "embedding_cache"
    assert eng.counters["embed_dropped_graphs"] > 0


def test_reference_is_terminal_fault_propagates():
    """The reference rung has no fallback: a fault there exhausts the
    ladder and the original error propagates (never a silent wrong answer)."""
    eng = _engine("reference")
    with faults.inject("reference"):
        with pytest.raises(faults.FaultError):
            eng.score(_pairs(2, 4))


def test_whole_ladder_walk_on_cascading_faults():
    """packed_sparse -> packed_dense -> bucketed_mega all dead: the call
    still completes on the dense jnp reference."""
    pairs = _pairs(3, 8)
    eng = _engine("packed_sparse")
    with faults.inject("packed_sparse"), faults.inject("packed_dense"), \
            faults.inject("bucketed_mega"):
        out = eng.score(pairs)
    np.testing.assert_allclose(out, _ref_scores(pairs), rtol=0, atol=1e-6)
    assert eng.last_plan.degraded_from == ("packed_sparse", "packed_dense",
                                           "bucketed_mega")
    assert eng.last_plan.attempts == 4


def test_degrade_false_pins_path():
    eng = _engine("packed_sparse", degrade=False)
    with faults.inject("packed_sparse"):
        with pytest.raises(faults.FaultError):
            eng.score(_pairs(4, 8))


@pytest.mark.parametrize("mode", ["oom", "nan"])
def test_oom_and_nan_modes_degrade(mode):
    """RESOURCE_EXHAUSTED and silently-NaN-ing kernels both count as rung
    failures — the NaN case via the engine's finite-output check, since a
    corrupting kernel raises nothing on its own."""
    pairs = _pairs(5, 8)
    eng = _engine("packed_dense")
    with faults.inject("packed_dense", mode=mode) as plan:
        out = eng.score(pairs)
    assert plan.triggered == 1
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out, _ref_scores(pairs), rtol=0, atol=2e-5)
    assert eng.last_plan.degraded_from == ("packed_dense",)


def test_ladder_covers_every_path():
    """Every dispatchable path reaches the terminal reference rung."""
    for path in PATHS:
        rungs = (path,) + DEGRADE_LADDER[path]
        assert rungs[-1] == "reference"


# --------------------------------------------------------- circuit breakers

def test_breaker_state_machine():
    clk = _FakeClock()
    br = CircuitBreaker(failure_threshold=2, cooldown_s=10.0, clock=clk)
    assert br.state == CLOSED and br.allow()
    br.record_failure()
    assert br.state == CLOSED          # 1 < threshold
    br.record_failure()
    assert br.state == OPEN
    assert not br.allow() and br.rejections == 1
    clk.t = 9.9
    assert not br.allow()
    clk.t = 10.0                       # cool-down elapsed: probe allowed
    assert br.allow() and br.state == HALF_OPEN
    br.record_failure()                # probe fails: reopen, backoff x2
    assert br.state == OPEN and br.current_cooldown() == 20.0
    clk.t = 10.0 + 20.0
    assert br.allow()
    br.record_success()                # probe succeeds: closed, backoff reset
    assert br.state == CLOSED and br.open_count == 0
    assert br.current_cooldown() == 10.0


def test_breaker_opens_and_cools_down_through_engine():
    """3 consecutive packed_sparse failures open its breaker; while open
    the rung is skipped without an attempt; after the cool-down one probe
    runs and (healthy again) closes it."""
    clk = _FakeClock()
    pairs = _pairs(6, 8)
    eng = _engine("packed_sparse", clock=clk, breaker_threshold=3,
                  breaker_cooldown_s=5.0)
    with faults.inject("packed_sparse"):
        for _ in range(3):
            eng.score(pairs)
    (key,) = [k for k in eng.breakers if k[0] == "packed_sparse"]
    assert eng.breakers[key].state == OPEN
    eng.score(pairs)                   # open: serve fallback, no attempt
    assert eng.counters["breaker_rejected:packed_sparse"] == 1
    assert eng.last_plan.degraded_from == ("packed_sparse",)
    assert eng.last_plan.attempts == 1
    clk.t += 5.0                       # cool-down elapsed -> half-open probe
    out = eng.score(pairs)
    assert eng.breakers[key].state == CLOSED
    assert eng.last_plan.degraded_from == ()
    np.testing.assert_allclose(out, _ref_scores(pairs), rtol=0, atol=1e-6)


def test_acceptance_batch512_sparse_fault():
    """The §12 acceptance case: packed_sparse forced to fail on a batch-512
    sparse stream -> the call completes via packed_dense within 1e-6 of the
    healthy scores, the breaker opens after the threshold, and health()
    reports it."""
    pairs = search_pairs(11, 512, avg_degree=2.1)
    clk = _FakeClock()
    eng = _engine("auto", clock=clk, breaker_threshold=2)
    healthy = eng.score(pairs)
    assert eng.last_plan.path == "packed_sparse"   # the paper's workload
    with faults.inject("packed_sparse") as plan:
        degraded = eng.score(pairs)
        assert plan.triggered == 1
        assert eng.last_plan.degraded_from == ("packed_sparse",)
        np.testing.assert_allclose(degraded, healthy, rtol=0, atol=1e-6)
        eng.score(pairs)               # second consecutive failure -> open
    health = eng.health()
    (name,) = [k for k in health["breakers"] if "packed_sparse" in k]
    assert health["breakers"][name]["state"] == OPEN
    assert health["counters"]["errors:packed_sparse"] == 2
    out = eng.score(pairs)             # open breaker: packed_dense serves
    assert eng.last_plan.attempts == 1
    np.testing.assert_allclose(out, healthy, rtol=0, atol=1e-6)


# --------------------------------------------------------------- quarantine

def _valid_graph(n=6, seed=0):
    return random_graph(np.random.default_rng(seed), n, avg_degree=2.0)


@pytest.mark.parametrize("mutate,needle", [
    (lambda g: g["adj"].__setitem__((0, 1), np.nan), "non-finite"),
    (lambda g: g["adj"].__setitem__((0, 1), 1.0), "symmetric"),
    (lambda g: g["adj"].__setitem__((0, 0), 1.0), "self loops"),
    (lambda g: g["adj"].__setitem__((0, 1), 2.0), "binary"),
    (lambda g: g.__setitem__("labels", g["labels"][:-1]), "ragged"),
    (lambda g: g["labels"].__setitem__(0, CFG.n_node_labels), "out of range"),
    (lambda g: g.__setitem__("adj", g["adj"][:1]), "square"),
    (lambda g: g.__setitem__("adj", np.zeros((0, 0), np.float32)), "empty"),
])
def test_graph_problems_catches(mutate, needle):
    g = _valid_graph()
    g = {"adj": g["adj"].copy(), "labels": g["labels"].copy()}
    if needle == "symmetric":
        g["adj"][0, 1] = 1.0
        g["adj"][1, 0] = 0.0
        problems = graph_problems(g, n_labels=CFG.n_node_labels)
    else:
        mutate(g)
        problems = graph_problems(g, n_labels=CFG.n_node_labels)
    assert any(needle in p for p in problems), problems


def test_lenient_quarantine_scores_nan_keeps_rest():
    """One malformed request NaNs its own score only — the valid pairs of
    the same batch still score within the parity band (no poisoned batch)."""
    pairs = _pairs(7, 6)
    bad = {"adj": np.full((4, 4), np.nan, np.float32),
           "labels": np.zeros(4, np.int32)}
    mixed = [(bad, pairs[0][1])] + pairs[1:]
    eng = _engine("auto")
    out = eng.score(mixed)
    assert np.isnan(out[0])
    np.testing.assert_allclose(out[1:], _ref_scores(pairs[1:]),
                               rtol=0, atol=1e-6)
    (rec,) = eng.last_plan.quarantined
    assert rec.pair == 0 and rec.side == 0 and rec.reasons
    assert eng.counters["quarantined_graphs"] == 1


def test_strict_validation_raises_with_records():
    bad = {"adj": np.asarray([[0, 2], [2, 0]], np.float32),
           "labels": np.zeros(2, np.int32)}
    eng = _engine("auto", validation="strict")
    with pytest.raises(GraphValidationError) as ei:
        eng.score([(bad, _valid_graph())])
    assert ei.value.records[0].pair == 0


def test_validation_off_skips_quarantine():
    pairs = _pairs(8, 4)
    eng = _engine("packed_sparse", validation="off")
    out = eng.score(pairs)
    assert eng.last_plan.quarantined == ()
    np.testing.assert_allclose(out, _ref_scores(pairs), rtol=0, atol=1e-6)


def test_unknown_validation_mode_rejected():
    with pytest.raises(ValueError, match="validation"):
        _engine(validation="paranoid")


# ---------------------------------------------------------- guarded training

def test_train_ladder_degrades_with_grad_parity():
    pairs = _pairs(9, 12)
    tgt = np.linspace(0.1, 0.9, 12).astype(np.float32)
    eng = _engine("packed_sparse")
    l0, g0 = eng.loss_and_grad(pairs, tgt)
    with faults.inject("train:packed_sparse", mode="nan") as plan:
        l1, g1 = eng.loss_and_grad(pairs, tgt)
    assert plan.triggered == 1
    assert eng.last_plan.degraded_from == ("packed_sparse",)
    assert abs(float(l0) - float(l1)) < 1e-6
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(a, b, rtol=0, atol=1e-5)


def test_nonfinite_targets_dropped_not_poisoning():
    pairs = _pairs(10, 8)
    tgt = np.linspace(0.1, 0.9, 8).astype(np.float32)
    poisoned = tgt.copy()
    poisoned[3] = np.nan
    eng = _engine("reference")
    keep = [i for i in range(8) if i != 3]
    l_clean, g_clean = eng.loss_and_grad([pairs[i] for i in keep], tgt[keep])
    l_pois, g_pois = eng.loss_and_grad(pairs, poisoned)
    assert eng.counters["nonfinite_targets"] == 1
    assert abs(float(l_clean) - float(l_pois)) < 1e-6
    for a, b in zip(jax.tree.leaves(g_clean), jax.tree.leaves(g_pois)):
        np.testing.assert_allclose(a, b, rtol=0, atol=1e-6)


def test_step_skip_preserves_optimizer_state_bitwise():
    """A step whose loss/grads are non-finite after every engine-level
    recovery is SKIPPED: params and optimizer state come back bit-identical
    and the skip is counted; the next clean step proceeds normally."""
    from repro.train.optimizer import adamw_init
    from repro.train.step import build_simgnn_train_step

    pairs = _pairs(12, 6)
    batch = {"pairs": pairs,
             "target": np.linspace(0.2, 0.8, 6).astype(np.float32)}
    eng = _engine("reference")      # terminal rung: NaN serves, guard skips
    step = build_simgnn_train_step(eng)
    params, opt_state = PARAMS, adamw_init(PARAMS)
    with faults.inject("train:reference", mode="nan"):
        p1, o1, metrics = step(params, opt_state, batch)
    assert float(metrics["skipped"]) == 1.0
    assert eng.counters["train_skipped_steps"] == 1
    for a, b in zip(jax.tree.leaves((params, opt_state)),
                    jax.tree.leaves((p1, o1))):
        if hasattr(a, "dtype"):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            assert a == b
    p2, o2, metrics2 = step(p1, o1, batch)      # clean step advances
    assert "skipped" not in metrics2
    assert int(metrics2["step"]) == int(np.asarray(o1.step)) + 1
    assert not all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))


def test_tree_all_finite():
    assert tree_all_finite({"a": np.ones(3)}, np.float32(1.0))
    assert not tree_all_finite({"a": np.asarray([1.0, np.nan])})
    assert tree_all_finite(np.asarray([1, 2], np.int32))  # ints never NaN


def test_midstream_kill_resumes_bit_identical(tmp_path):
    """The §12 acceptance case for training: a run killed mid-stream and
    resumed from its checkpoint ends with BIT-IDENTICAL params + optimizer
    state vs an uninterrupted run (atomic checkpoints + deterministic
    per-step batch replay)."""
    from repro.train import loop
    from repro.train.optimizer import adamw_init
    from repro.train.step import build_simgnn_train_step

    rngs = [np.random.default_rng(100 + s) for s in range(6)]
    batches = [{"pairs": [(random_graph(r, 8, avg_degree=2.0),
                           random_graph(r, 8, avg_degree=2.0))
                          for _ in range(4)],
                "target": r.uniform(0.2, 0.9, 4).astype(np.float32)}
               for r in rngs]

    def run(ckpt_dir, n_steps):
        eng = _engine("reference")
        step = build_simgnn_train_step(eng)
        return loop.run(step, PARAMS, adamw_init(PARAMS),
                        lambda s: batches[s], n_steps=n_steps,
                        ckpt_dir=str(ckpt_dir), ckpt_every=2, log_every=100)

    p_full, o_full, _ = run(tmp_path / "full", 6)
    # "Killed" after 3 steps: drop the exit-time save so the only surviving
    # checkpoint is the mid-stream one at step 2 (ckpt_every=2), exactly
    # what a hard kill leaves behind.
    run(tmp_path / "killed", 3)
    shutil.rmtree(tmp_path / "killed" / "step_000000003")
    p_res, o_res, _ = run(tmp_path / "killed", 6)
    for a, b in zip(jax.tree.leaves((p_full, o_full)),
                    jax.tree.leaves((p_res, o_res))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------- serving resilience

def test_microbatcher_request_timeout():
    from repro.serve.batching import MicroBatcher, TimeoutResult

    clk = _FakeClock()
    mb = MicroBatcher(lambda reqs: [r * 2 for r in reqs], max_batch=10,
                      max_wait_s=1.0, clock=clk)
    assert mb.submit(1, timeout_s=0.05) is None
    assert mb.submit(2) is None
    assert abs(mb.deadline_in() - 0.05) < 1e-12   # per-request < group wait
    clk.t = 0.06
    out = mb.poll()                    # expired deadline triggers the flush
    assert isinstance(out[0], TimeoutResult)
    assert out[0].request == 1 and abs(out[0].waited_s - 0.06) < 1e-12
    assert out[1] == 4                 # live request still served, in place
    assert mb.stats.expired_flushes == 1
    assert mb.stats.expired_requests == 1
    assert mb.pending == []


def test_microbatcher_deadline_in_clamps_to_zero():
    from repro.serve.batching import MicroBatcher

    clk = _FakeClock()
    mb = MicroBatcher(lambda reqs: reqs, max_batch=10, max_wait_s=0.01,
                      clock=clk)
    mb.pending.append("r")             # stage without flushing
    mb._deadlines.append((None, clk.t))
    mb.oldest_ts = clk.t
    clk.t = 5.0                        # long overdue
    assert mb.deadline_in() == 0.0     # clamped, never negative


def test_microbatcher_retry_then_success():
    from repro.serve.batching import MicroBatcher

    calls, naps = [], []

    def flaky(reqs):
        calls.append(list(reqs))
        if len(calls) < 3:
            raise RuntimeError("transient")
        return [r + 1 for r in reqs]

    mb = MicroBatcher(flaky, max_batch=2, flush_retries=2,
                      retry_backoff_s=0.01, sleep=naps.append,
                      clock=_FakeClock())
    out = mb.submit(1)
    assert out is None
    out = mb.submit(2)                 # size flush -> fail, fail, succeed
    assert out == [2, 3]
    assert len(calls) == 3
    assert naps == [0.01, 0.02]        # exponential backoff
    assert mb.stats.retries == 2 and mb.stats.failed_flushes == 0


def test_microbatcher_retry_exhaustion_drains_queue():
    from repro.serve.batching import MicroBatcher

    def dead(reqs):
        raise RuntimeError("kernel down")

    mb = MicroBatcher(dead, max_batch=2, flush_retries=1,
                      sleep=lambda s: None, clock=_FakeClock())
    mb.submit(1)
    with pytest.raises(RuntimeError, match="kernel down"):
        mb.submit(2)
    assert mb.pending == []            # drained: later traffic unaffected
    assert mb.stats.failed_flushes == 1
    assert mb.stats.dropped_requests == 2
    assert mb.submit(3) is None        # queue works again


def test_search_server_survives_failed_corpus_shard():
    """A corpus bucket whose embed AND reference retry both fail is dropped
    (NaN rows, counted), the rest of the index serves, and NaN rows never
    reach the top-k."""
    from repro.serve.search import SimilaritySearchServer

    rng = np.random.default_rng(13)
    # Two size buckets: n<=8 and n in (8, 16].
    corpus = [random_graph(rng, n, avg_degree=2.0)
              for n in [6, 7, 8, 12, 13, 14, 15, 16]]
    srv = SimilaritySearchServer(PARAMS, CFG)
    with faults.inject("embed"), faults.inject("embed_fallback", times=1):
        emb = srv.index(corpus)
    dropped = int((~np.isfinite(emb).all(axis=-1)).sum())
    assert 0 < dropped < len(corpus)
    assert srv.stats.failed_embeddings == dropped
    assert srv.health()["failed_embeddings"] == dropped
    query = random_graph(rng, 9, avg_degree=2.0)
    k = len(corpus) - dropped
    idx, scores = srv.topk(query, k=k)
    assert np.isfinite(scores).all()   # NaN rows ranked out of the top-k
    assert len(idx) == k


def test_query_server_validation_passthrough():
    from repro.serve.batching import simgnn_query_server

    bad = {"adj": np.full((3, 3), np.inf, np.float32),
           "labels": np.zeros(3, np.int32)}
    score_fn = simgnn_query_server(PARAMS, CFG, use_kernels=True)
    pairs = _pairs(14, 3)
    out = score_fn([(bad, pairs[0][1])] + pairs[1:])
    assert np.isnan(out[0]) and np.isfinite(out[1:]).all()
    assert score_fn.last_plan.quarantined[0].pair == 0
    strict = simgnn_query_server(PARAMS, CFG, validation="strict")
    with pytest.raises(GraphValidationError):
        strict([(bad, pairs[0][1])])


# ------------------------------------------------------------- misc hooks

def test_reset_grow_warnings_hook():
    from repro.core import batching as cb
    from repro.data.graphs import random_graph as rg

    rng = np.random.default_rng(15)
    batch = cb.pad_graphs([rg(rng, 12, avg_degree=4.0)],
                          CFG.n_node_labels, 16)
    cb.reset_grow_warnings()
    with warnings.catch_warnings(record=True) as first:
        warnings.simplefilter("always")
        cb.to_edge_batch(batch, max_edges=4)
    assert any("growing the edge budget" in str(w.message) for w in first)
    with warnings.catch_warnings(record=True) as again:
        warnings.simplefilter("always")
        cb.to_edge_batch(batch, max_edges=4)
    assert not again                   # warn-once per process
    cb.reset_grow_warnings()           # the supported reset hook
    with warnings.catch_warnings(record=True) as after:
        warnings.simplefilter("always")
        cb.to_edge_batch(batch, max_edges=4)
    assert any("growing the edge budget" in str(w.message) for w in after)


def test_fault_hook_disarms_on_exit():
    from repro.core import engine as engine_mod

    with faults.inject("packed_dense"):
        assert engine_mod._FAULT_HOOK is not None
    assert engine_mod._FAULT_HOOK is None
    # and a healthy engine is unaffected afterwards
    eng = _engine("packed_dense")
    out = eng.score(_pairs(16, 4))
    assert eng.last_plan.degraded_from == ()
    assert np.isfinite(out).all()


# ------------------------------------------------ profile seam (§15 tracing)

def test_profile_record_fault_never_fails_scoring():
    """A crashing trace recorder must never fail the scoring call it is
    observing: the scores stay finite and healthy, the error is only
    counted (`profile_record_errors`), and the recorder keeps working once
    the fault clears (DESIGN.md §15 observability-is-free contract)."""
    from repro.core.profile import TraceRecorder

    rec = TraceRecorder(clock=_FakeClock())
    eng = _engine("packed_sparse", recorder=rec)
    pairs = _pairs(40, 6)
    with faults.inject("profile") as plan:
        out = eng.score(pairs)
    assert plan.triggered == 1
    assert np.isfinite(out).all()
    assert eng.last_plan.degraded_from == ()          # scoring untouched
    assert eng.counters["profile_record_errors"] == 1
    assert rec.total_records == 0                     # the record was lost
    eng.score(pairs)                                  # fault cleared
    assert rec.total_records == 1
    assert eng.counters["profile_record_errors"] == 1


def test_profile_record_fault_never_fails_training():
    """Same contract on the training side: loss_and_grad under an injected
    recorder fault still returns finite grads and counts the error."""
    from repro.core.profile import TraceRecorder

    eng = _engine("packed_dense", recorder=TraceRecorder(clock=_FakeClock()))
    batch = _pairs(41, 4)
    targets = np.linspace(0.1, 0.9, len(batch)).astype(np.float32)
    with faults.inject("profile", mode="raise") as plan:
        loss, grads = eng.loss_and_grad(batch, targets)
    assert plan.triggered >= 1
    assert tree_all_finite(loss, grads)
    assert eng.counters["profile_record_errors"] >= 1
