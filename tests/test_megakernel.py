"""Single-pass pair-score megakernel (kernels/fused_pair.py) parity sweeps.

Tolerance policy matches tests/test_kernels.py: fp32 sweeps at 1e-5-class
atol vs. the pure-jnp `core.simgnn.pair_score`; bf16 inputs at the 2e-2
bound from the ISSUE acceptance criteria.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.batching import DEFAULT_BUCKETS
from repro.core.simgnn import SimGNNConfig, init_simgnn_params, pair_score
from repro.data.graphs import bucketed_pair_batch as _pair_args
from repro.kernels import ops
from repro.kernels.fused_gcn import fused_gcn_att
from repro.kernels import ref


def _rel(a, b):
    return float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-9))


@pytest.mark.parametrize("bucket", DEFAULT_BUCKETS)
def test_megakernel_parity_all_buckets(bucket):
    cfg = SimGNNConfig(max_nodes=bucket)
    params = init_simgnn_params(jax.random.PRNGKey(0), cfg)
    args = _pair_args(bucket, bucket, 16)
    s_mega = ops.pair_score_megakernel(params, *args, block_pairs=8,
                                       interpret=True)
    s_core = pair_score(params, *args)
    np.testing.assert_allclose(np.asarray(s_mega), np.asarray(s_core),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("batch", [1, 5, 13])
def test_megakernel_non_block_multiple_batches(batch):
    """Pad/slice handling: any B works, pad pairs never leak into outputs."""
    cfg = SimGNNConfig(max_nodes=16)
    params = init_simgnn_params(jax.random.PRNGKey(1), cfg)
    args = _pair_args(7, 16, batch)
    s_mega = ops.pair_score_megakernel(params, *args, block_pairs=4,
                                       interpret=True)
    s_core = pair_score(params, *args)
    assert s_mega.shape == (batch,)
    np.testing.assert_allclose(np.asarray(s_mega), np.asarray(s_core),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("gcn_dims", [(64, 32), (64, 48, 32, 16)])
def test_megakernel_variadic_gcn_depth(gcn_dims):
    """2- and 4-layer stacks compile and match (no hardcoded w1/b1/w2/b2/w3)."""
    cfg = SimGNNConfig(gcn_dims=gcn_dims, max_nodes=16)
    params = init_simgnn_params(jax.random.PRNGKey(2), cfg)
    args = _pair_args(11, 16, 8)
    s_mega = ops.pair_score_megakernel(params, *args, block_pairs=4,
                                       interpret=True)
    s_core = pair_score(params, *args)
    np.testing.assert_allclose(np.asarray(s_mega), np.asarray(s_core),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("gcn_dims", [(64, 32), (64, 48, 32, 16)])
def test_fused_gcn_variadic_gcn_depth(gcn_dims):
    """The refactored two-kernel building block is also depth-variadic."""
    from repro.core.gcn import normalized_adjacency
    cfg = SimGNNConfig(gcn_dims=gcn_dims, max_nodes=16)
    params = init_simgnn_params(jax.random.PRNGKey(3), cfg)
    adj, feats, mask = _pair_args(13, 16, 8)[:3]
    a_norm = normalized_adjacency(adj, mask)
    out_k = fused_gcn_att(a_norm, feats, mask, params["gcn"],
                          params["att"]["w"], block_graphs=4, interpret=True)
    out_r = ref.fused_gcn_att_ref(a_norm, feats, mask, params["gcn"],
                                  params["att"]["w"])
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-4, atol=1e-5)


def test_megakernel_bf16_inputs():
    """bf16 in / fp32 accumulate: scores within the 2e-2 acceptance bound."""
    cfg = SimGNNConfig(max_nodes=32)
    params = init_simgnn_params(jax.random.PRNGKey(4), cfg)
    args = _pair_args(17, 32, 8)
    to16 = lambda t: jax.tree.map(lambda x: x.astype(jnp.bfloat16), t)
    s16 = ops.pair_score_megakernel(to16(params), *to16(tuple(args)),
                                    block_pairs=4, interpret=True)
    s_core = pair_score(params, *args)
    assert s16.dtype == jnp.bfloat16
    assert _rel(s16.astype(jnp.float32), s_core) < 2e-2


def test_megakernel_matches_two_kernel_path():
    cfg = SimGNNConfig()
    params = init_simgnn_params(jax.random.PRNGKey(5), cfg)
    args = _pair_args(19, 64, 12)
    s_mega = ops.pair_score_megakernel(params, *args, interpret=True)
    s_two = ops.simgnn_pair_score_kernel(params, *args, interpret=True)
    np.testing.assert_allclose(np.asarray(s_mega), np.asarray(s_two),
                               rtol=1e-5, atol=1e-6)


def test_server_routes_kernels_through_megakernel_with_bucket_cache():
    """With packing=False, use_kernels=True takes the bucketed megakernel
    fallback path: one cached executable per bucket (the packed default is
    covered by tests/test_packed.py)."""
    from repro.configs.simgnn_aids import CONFIG as SCFG
    from repro.data.graphs import query_pairs
    from repro.serve.batching import simgnn_query_server

    params = init_simgnn_params(jax.random.PRNGKey(6), SCFG)
    pairs = query_pairs(21, 16)
    score_ref = simgnn_query_server(params, SCFG)
    score_k = simgnn_query_server(params, SCFG, use_kernels=True,
                                  packing=False)
    out_ref = score_ref(pairs)
    out_k = score_k(pairs)
    np.testing.assert_allclose(out_k, out_ref, rtol=1e-4, atol=1e-5)
    # one cached executable per bucket actually used, reused across calls
    assert score_k.bucket_fns and set(score_k.bucket_fns) <= set(DEFAULT_BUCKETS)
    fns_before = dict(score_k.bucket_fns)
    score_k(pairs)
    assert score_k.bucket_fns == fns_before
