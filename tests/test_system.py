"""End-to-end behaviour tests for the paper's system (SimGNN on SPA-GCN).

These are the paper-level claims reduced to testable form:
  * training the SimGNN pipeline on GED-labelled pairs reduces the loss;
  * the fused kernel path and the jnp path agree end-to-end;
  * the query server (batching + size bucketing) returns order-correct
    scores and benefits from batching (Fig. 11 mechanism, smoke-level);
  * identical graphs score higher than heavily edited ones after training.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.simgnn_aids import CONFIG as SCFG
from repro.core.engine import ScoringEngine
from repro.core.simgnn import init_simgnn_params, pair_score
from repro.data.graphs import pair_stream, query_pairs
from repro.serve.batching import simgnn_query_server
from repro.train.optimizer import adamw_init
from repro.train.step import build_simgnn_train_step


def _train(n_steps=60, batch=32, seed=0, stream=None):
    params = init_simgnn_params(jax.random.PRNGKey(seed), SCFG)
    opt = adamw_init(params)
    # The engine routes the forward AND backward passes (DESIGN.md §11):
    # auto dispatch picks packed-sparse on this molecule-like stream.
    step = build_simgnn_train_step(ScoringEngine(params, SCFG),
                                   peak_lr=2e-3)
    stream = stream or pair_stream(seed, batch)
    losses = []
    for _ in range(n_steps):
        params, opt, m = step(params, opt, next(stream))
        losses.append(float(m["loss"]))
    return params, losses


def _binary_batch(seed, batch):
    """Pairs that are either identical (target 1.0) or unrelated (0.2) — a
    discrimination learnable in CI time (full GED regression needs thousands
    of steps; the paper trains offline and accelerates inference)."""
    from repro.data.graphs import random_graph
    rng = np.random.default_rng(seed)
    pairs, targets = [], []
    for _ in range(batch):
        g1 = random_graph(rng)
        if rng.random() < 0.5:
            g2, t = g1, 1.0
        else:
            g2, t = random_graph(rng), 0.2
        pairs.append((g1, g2))
        targets.append(t)
    return {"pairs": pairs, "target": np.asarray(targets, np.float32)}


def test_training_reduces_loss():
    _, losses = _train()
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first * 0.7, (first, last)


def test_trained_model_ranks_similarity():
    """End-to-end trainability: gradients flow through all four stages and
    the model can fit a fixed set of binary-similarity pairs, ranking
    identical above unrelated pairs. (Full GED generalization needs
    thousands of steps — the paper trains offline and accelerates
    inference, so CI asserts the memorization/ranking sanity level.)"""
    from repro.core.batching import pad_graphs

    batch = _binary_batch(0, 48)
    params = init_simgnn_params(jax.random.PRNGKey(0), SCFG)
    opt = adamw_init(params)
    engine = ScoringEngine(params, SCFG)
    step = build_simgnn_train_step(engine, peak_lr=5e-3)
    losses = []
    for _ in range(250):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.25, (losses[0], losses[-1])
    assert engine.last_plan.path in ("packed_sparse", "packed_dense")
    b1 = pad_graphs([p[0] for p in batch["pairs"]], 29, 64)
    b2 = pad_graphs([p[1] for p in batch["pairs"]], 29, 64)
    pred = np.asarray(pair_score(params, b1.adj, b1.feats, b1.mask,
                                 b2.adj, b2.feats, b2.mask))
    tgt = np.asarray(batch["target"])
    mean_id = pred[tgt > 0.5].mean()
    mean_far = pred[tgt < 0.5].mean()
    assert mean_id > mean_far + 0.15, (mean_id, mean_far)


def test_query_server_bucketing_and_order():
    params = init_simgnn_params(jax.random.PRNGKey(0), SCFG)
    pairs = query_pairs(3, 12)
    score = simgnn_query_server(params, SCFG)
    out = score(pairs)
    assert out.shape == (12,)
    assert ((out > 0) & (out < 1)).all()
    # kernel path produces the same scores in the same order
    score_k = simgnn_query_server(params, SCFG, use_kernels=True)
    out_k = score_k(pairs)
    np.testing.assert_allclose(out, out_k, rtol=1e-4, atol=1e-5)


def test_microbatcher_amortization():
    from repro.serve.batching import MicroBatcher
    calls = []

    def run_batch(reqs):
        calls.append(len(reqs))
        return [r * 2 for r in reqs]

    # generous deadline: this test asserts size-triggered flushes only, and
    # must not race the wall clock on a loaded CI runner
    mb = MicroBatcher(run_batch, max_batch=4, max_wait_s=60.0)
    outs = []
    for i in range(10):
        r = mb.submit(i)
        if r:
            outs += r
    outs += mb.flush() or []        # None contract: nothing ran -> no batch
    assert outs == [0, 2, 4, 6, 8, 10, 12, 14, 16, 18]
    assert calls == [4, 4, 2]       # batched, not 10 single calls
    assert mb.flush() is None       # drained queue: nothing ran, not []


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_microbatcher_deadline_flush_on_submit():
    """A submit arriving after the oldest request's deadline flushes even
    though max_batch is far away."""
    from repro.serve.batching import MicroBatcher
    clk = _FakeClock()
    mb = MicroBatcher(lambda reqs: [r * 2 for r in reqs], max_batch=100,
                      max_wait_s=0.01, clock=clk)
    assert mb.submit(1) is None
    clk.t += 0.005
    assert mb.submit(2) is None          # deadline measured from the OLDEST
    clk.t += 0.006                       # oldest has now waited 11ms > 10ms
    assert mb.submit(3) == [2, 4, 6]
    assert mb.pending == []


def test_microbatcher_deadline_poll():
    """The idle-loop pump: poll() flushes a stranded partial batch exactly
    when the deadline expires, and deadline_in() reports the time left."""
    from repro.serve.batching import MicroBatcher
    clk = _FakeClock()
    mb = MicroBatcher(lambda reqs: [r * 2 for r in reqs], max_batch=100,
                      max_wait_s=0.01, clock=clk)
    assert mb.poll() is None             # empty: nothing due
    assert mb.deadline_in() is None
    mb.submit(7)
    assert mb.poll() is None             # deadline not reached yet
    assert abs(mb.deadline_in() - 0.01) < 1e-12
    clk.t += 0.02
    assert mb.deadline_in() == 0.0
    assert mb.poll() == [14]
    assert mb.poll() is None             # drained
