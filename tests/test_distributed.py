"""Distribution substrate: sharding rules, compression, dry-run smoke.

The dry-run smoke runs in a subprocess with 8 host devices (2x2 / 2x2x2
meshes) so the main test process keeps its single-device view.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.distributed.compression import (compression_error_bound,
                                           int8_roundtrip)
from repro.distributed.sharding import param_spec

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_param_spec_rules():
    assert param_spec("embed/table", 2) == P("model", "data")
    assert param_spec("groups/0/attn/wq", 2) == P("data", "model")
    # stacked scan-group leading axis is replicated
    assert param_spec("groups/0/attn/wq", 3) == P(None, "data", "model")
    assert param_spec("groups/0/moe/w_in", 4) == P(None, None, "data", "model")
    assert param_spec("groups/0/mamba/out_proj", 3) == P(None, "model", "data")
    assert param_spec("groups/0/ln1/scale", 2) == P(None, None)
    assert param_spec("groups/0/rwkv/wr", 3) == P(None, "data", "model")
    assert param_spec("something/unknown", 1) == P(None)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_int8_compression_error_bound(seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32)
                    * rng.uniform(1e-3, 1e3))
    out = int8_roundtrip(g)
    bound = compression_error_bound(g)
    assert float(jnp.max(jnp.abs(out - g))) <= bound * 1.001


def test_int8_compression_preserves_direction():
    g = jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)),
                    jnp.float32)
    out = int8_roundtrip(g)
    cos = float(jnp.sum(g * out)
                / (jnp.linalg.norm(g) * jnp.linalg.norm(out)))
    assert cos > 0.999


@pytest.mark.slow
def test_dryrun_reduced_single_and_multi_mesh(tmp_path):
    """Full dry-run machinery end-to-end on an 8-device host: one train cell
    and one decode cell, on both the 2x2 single and 2x2x2 multi-pod mesh."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    for arch, shape in [("qwen1.5-4b", "train_4k"),
                        ("gemma2-9b", "decode_32k")]:
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--mesh", "both", "--reduced",
             "--out", str(tmp_path)],
            env=env, capture_output=True, text=True, timeout=900)
        assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    import json
    rec = json.load(open(tmp_path / "qwen1.5-4b__train_4k__multi.json"))
    assert rec["mesh_axes"] == ["pod", "data", "model"]
    assert rec["hlo_flops"] > 0
    assert rec["collectives"]["bytes_wire"] > 0


def test_constrain_divisibility_guard():
    """constrain() drops axes that don't divide the dim (long_500k batch=1)."""
    from repro.distributed.sharding import Runtime, constrain
    rt = Runtime(mesh=None)
    x = jnp.ones((1, 8, 4))
    # off-mesh: pure no-op
    assert constrain(rt, x, "dp", None, None) is x


@pytest.mark.slow
def test_gpipe_pipeline_parallelism(tmp_path):
    """GPipe over a 4-stage mesh == sequential model (fwd + grad), in a
    4-device subprocess."""
    script = r'''
import jax, jax.numpy as jnp
from repro.distributed.pipeline import gpipe, stack_stage_params
mesh = jax.make_mesh((4,), ("stage",))
d = 16
def stage_fn(p, x):
    return x + jnp.tanh(x @ p["w1"]) @ p["w2"]
stages = [{"w1": jax.random.normal(jax.random.PRNGKey(i), (d, 32)) * 0.3,
           "w2": jax.random.normal(jax.random.PRNGKey(100 + i), (32, d)) * 0.3}
          for i in range(4)]
stacked = stack_stage_params(stages)
x = jax.random.normal(jax.random.PRNGKey(7), (8, d))
seq = x
for p in stages:
    seq = stage_fn(p, seq)
piped = gpipe(stage_fn, mesh, n_microbatches=4)
y = jax.jit(piped)(stacked, x)
assert float(jnp.max(jnp.abs(y - seq))) < 1e-5
g = jax.grad(lambda ps, xx: jnp.sum(piped(ps, xx) ** 2))(stacked, x)
assert all(bool(jnp.all(jnp.isfinite(v))) for v in jax.tree.leaves(g))
print("PIPE_OK")
'''
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0 and "PIPE_OK" in r.stdout, r.stderr[-2000:]
