"""Edge-centric sparse path tests (DESIGN.md §9): reference edge-path parity
(incl. isolated nodes and all-pad rows), to_edge_batch auto-grow, packed-CSR
edge emission layout/round-trip, in-kernel aggregation bodies, and
packed-sparse megakernel parity sweeps.

Tolerance policy: the fp32 sparse path must match the pure-jnp reference at
the 1e-6 acceptance bound (scores, post-sigmoid); bf16 inputs at the 2e-2
bound from tests/test_megakernel.py.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.batching import (GraphBatch, bucket_pairs, edge_aggregate,
                                 next_pow2, pack_pairs, packed_pair_edges,
                                 to_edge_batch, unpack_pair_scores)
from repro.core.gcn import normalized_adjacency
from repro.core.simgnn import SimGNNConfig, init_simgnn_params, pair_score
from repro.data.graphs import random_graph
from repro.kernels import ops
from repro.kernels.common import (csr_aggregate_block, edge_aggregate_block,
                                  overflow_aggregate_block)

CFG = SimGNNConfig()
PARAMS = init_simgnn_params(jax.random.PRNGKey(0), CFG)


def _mixed_pairs(seed, n_pairs, max_n=64):
    rng = np.random.default_rng(seed)
    return [(random_graph(rng, int(rng.integers(5, max_n + 1))),
             random_graph(rng, int(rng.integers(5, max_n + 1))))
            for _ in range(n_pairs)]


def _reference_scores(params, pairs, n_labels=CFG.n_node_labels):
    out = np.zeros(len(pairs), np.float32)
    for b, (lhs, rhs, idxs) in bucket_pairs(pairs, n_labels,
                                            allow_oversize=True).items():
        s = pair_score(params, lhs.adj, lhs.feats, lhs.mask,
                       rhs.adj, rhs.feats, rhs.mask)
        out[idxs] = np.asarray(s)
    return out


def _rand_graph_batch(rng, b=4, n=16, p_edge=0.3):
    adj = (rng.random((b, n, n)) < p_edge).astype(np.float32)
    adj = np.triu(adj, 1)
    adj = adj + adj.transpose(0, 2, 1)
    n_nodes = rng.integers(2, n + 1, b)
    mask = (np.arange(n)[None] < n_nodes[:, None]).astype(np.float32)
    adj = adj * mask[:, :, None] * mask[:, None, :]
    return adj, mask


# ---------------------------------------------- reference edge path (dense
# normalized_adjacency @ HW  vs  edge_aggregate(to_edge_batch(...)))

def test_edge_path_matches_dense_on_random_batches():
    rng = np.random.default_rng(0)
    for seed in range(3):
        adj, mask = _rand_graph_batch(np.random.default_rng(seed), b=5, n=18)
        gb = GraphBatch(jnp.zeros(adj.shape[:2] + (0,)), jnp.asarray(adj),
                        jnp.asarray(mask),
                        jnp.asarray(mask.sum(-1), jnp.int32))
        eb = to_edge_batch(gb, max_edges=18 * 19)
        hw = jnp.asarray(rng.normal(size=(5, 18, 7)).astype(np.float32))
        dense = jnp.einsum("bnm,bmf->bnf",
                           normalized_adjacency(gb.adj, gb.mask), hw)
        np.testing.assert_allclose(np.asarray(edge_aggregate(eb, hw)),
                                   np.asarray(dense), rtol=1e-5, atol=1e-6)


def test_edge_path_isolated_nodes_and_all_pad_rows():
    """Isolated real nodes keep their self-loop message; an all-pad batch
    entry contributes exactly zero everywhere."""
    rng = np.random.default_rng(7)
    n = 8
    adj = np.zeros((2, n, n), np.float32)
    adj[0, 0, 1] = adj[0, 1, 0] = 1.0     # node 2 isolated but real
    mask = np.zeros((2, n), np.float32)
    mask[0, :3] = 1.0                     # batch entry 1: all-pad
    gb = GraphBatch(jnp.zeros((2, n, 0)), jnp.asarray(adj),
                    jnp.asarray(mask), jnp.asarray(mask.sum(-1), jnp.int32))
    eb = to_edge_batch(gb, max_edges=16)
    hw = jnp.asarray(rng.normal(size=(2, n, 4)).astype(np.float32))
    out = np.asarray(edge_aggregate(eb, hw))
    dense = np.asarray(jnp.einsum(
        "bnm,bmf->bnf", normalized_adjacency(gb.adj, gb.mask), hw))
    np.testing.assert_allclose(out, dense, rtol=1e-5, atol=1e-6)
    # isolated node: A'[2,2] == 1 -> message is its own hw row
    np.testing.assert_allclose(out[0, 2], np.asarray(hw)[0, 2], rtol=1e-6)
    assert (out[1] == 0).all()            # all-pad entry: exact zeros


# ------------------------------------------------- to_edge_batch auto-grow

def test_to_edge_batch_grows_instead_of_raising():
    adj, mask = _rand_graph_batch(np.random.default_rng(3), b=3, n=12,
                                  p_edge=0.6)
    gb = GraphBatch(jnp.zeros((3, 12, 0)), jnp.asarray(adj),
                    jnp.asarray(mask), jnp.asarray(mask.sum(-1), jnp.int32))
    nnz = int((np.asarray(normalized_adjacency(gb.adj, gb.mask)) != 0)
              .sum(axis=(1, 2)).max())
    small = max(8, nnz // 4)
    with pytest.warns(RuntimeWarning, match="growing the edge budget"):
        eb = to_edge_batch(gb, max_edges=small)
    assert eb.senders.shape[-1] == next_pow2(nnz, floor=small)
    # grown batch still aggregates exactly
    hw = jnp.asarray(np.random.default_rng(0).normal(
        size=(3, 12, 5)).astype(np.float32))
    dense = jnp.einsum("bnm,bmf->bnf",
                       normalized_adjacency(gb.adj, gb.mask), hw)
    np.testing.assert_allclose(np.asarray(edge_aggregate(eb, hw)),
                               np.asarray(dense), rtol=1e-5, atol=1e-6)


def test_to_edge_batch_grow_warns_once_per_stream():
    """Regression (PR 5 satellite): a stream that outruns `max_edges` on
    every batch must warn ONCE for a given growth, not per call, and the
    realized budget is surfaced on the result for callers to reuse."""
    adj, mask = _rand_graph_batch(np.random.default_rng(5), b=2, n=14,
                                  p_edge=0.7)
    gb = GraphBatch(jnp.zeros((2, 14, 0)), jnp.asarray(adj),
                    jnp.asarray(mask), jnp.asarray(mask.sum(-1), jnp.int32))
    small = 9       # a (requested, grown) key no other test uses
    with warnings.catch_warnings(record=True) as first:
        warnings.simplefilter("always")
        eb = to_edge_batch(gb, max_edges=small)
    assert sum("growing the edge budget" in str(w.message)
               for w in first) == 1
    assert eb.edge_budget == eb.senders.shape[-1] > small   # realized budget
    with warnings.catch_warnings(record=True) as again:
        warnings.simplefilter("always")
        eb2 = to_edge_batch(gb, max_edges=small)            # same stream
    assert not any("growing the edge budget" in str(w.message)
                   for w in again)
    assert eb2.edge_budget == eb.edge_budget
    # feeding the realized budget back means no growth at all
    with warnings.catch_warnings(record=True) as reused:
        warnings.simplefilter("always")
        to_edge_batch(gb, max_edges=eb.edge_budget)
    assert not reused


# --------------------------------------------------- edge-budget ladder

def test_packed_edge_budget_half_way_degrees():
    """Regression (PR 5 satellite): Python round() is banker's rounding, so
    degree 2.5 used to round DOWN to the D=4 rung of the 1.5-2.4 band while
    3.5 rounded up — half-way degrees must all round up (floor(d + 0.5))."""
    nb = 64
    ladder = lambda d: ops.packed_edge_budget(nb, d) // nb
    assert ladder(2.5) == 6        # floor(3.0)+2=5 -> rung 6 (was 4)
    assert ladder(3.5) == 6        # floor(4.0)+2=6 -> rung 6 (unchanged)
    assert ladder(4.5) == 8        # floor(5.0)+2=7 -> rung 8 (was 6)
    # the band below each half-way point keeps its old rung
    assert ladder(2.4) == 4 and ladder(1.5) == 4
    assert ladder(3.4) == 6 and ladder(4.4) == 6
    # monotone: a denser measured stream never gets a smaller budget
    degrees = [1.0 + 0.1 * i for i in range(120)]
    rungs = [ladder(d) for d in degrees]
    assert all(a <= b for a, b in zip(rungs, rungs[1:]))


def test_next_pow2():
    assert next_pow2(0) == 8 and next_pow2(8) == 8
    assert next_pow2(9) == 16 and next_pow2(200) == 256
    assert next_pow2(3, floor=2) == 4
    # a non-power-of-two floor must still yield a true power of two
    assert next_pow2(101, floor=100) == 128
    assert next_pow2(5, floor=6) == 8


# ------------------------------------------------ packed-CSR edge emission

def test_packed_pair_edges_round_trip():
    """CSR planes + overflow reconstruct the normalized block-diagonal
    adjacency exactly, and the ELLPACK layout invariant holds."""
    pairs = _mixed_pairs(1, 13)
    packed, stats = pack_pairs(pairs, 64, with_edges=True,
                               edge_budget=64 * 4)
    e = packed.edges
    nb = packed.node_budget
    d = e.edge_budget // nb
    assert e.edge_budget % nb == 0
    assert stats["edge_budget"] == e.edge_budget
    assert stats["nnz_lhs"] > 0 and 0 < stats["density_lhs"] < 1
    for side, (csr, ov) in enumerate(((e.edges1, e.overflow1),
                                      (e.edges2, e.overflow2))):
        adj = packed.adj1 if side == 0 else packed.adj2
        mask = packed.mask1 if side == 0 else packed.mask2
        a_norm = np.asarray(normalized_adjacency(adj, mask))
        t = a_norm.shape[0]
        # ELLPACK invariant: slot s belongs to node s % NB (plane s // NB)
        np.testing.assert_array_equal(
            np.asarray(csr.receivers),
            np.tile(np.tile(np.arange(nb, dtype=np.int32), d), (t, 1)))
        recon = np.zeros_like(a_norm)
        for eb_part in (csr, ov):
            s = np.asarray(eb_part.senders)
            r = np.asarray(eb_part.receivers)
            w = np.asarray(eb_part.weights)
            m = np.asarray(eb_part.edge_mask)
            for i in range(t):
                for j in np.flatnonzero(m[i]):
                    recon[i, r[i, j], s[i, j]] += w[i, j]
        np.testing.assert_allclose(recon, a_norm, rtol=0, atol=1e-7)


def test_packed_pair_edges_overflow_spill():
    """A deliberately tiny per-node budget spills the tail to the overflow
    list without losing any edge (round-trip above covers exactness; here:
    the spill is actually used and scores stay correct)."""
    pairs = _mixed_pairs(2, 10)
    packed, stats = pack_pairs(pairs, 64, with_edges=True,
                               edge_budget=64 * 2)   # D=2 << typical degree
    assert int(np.asarray(packed.edges.overflow1.edge_mask).sum()) > 0
    s = ops.pair_score_sparse(PARAMS, packed, interpret=True)
    out = unpack_pair_scores(s, packed, len(pairs))
    np.testing.assert_allclose(out, _reference_scores(PARAMS, pairs),
                               rtol=0, atol=1e-6)


def test_pack_pairs_edge_budget_validation():
    with pytest.raises(ValueError, match="multiple of node_budget"):
        packed, _ = pack_pairs(_mixed_pairs(3, 4), 64, with_edges=True,
                               edge_budget=100)


# ------------------------------------------------- in-kernel sparse bodies

def test_csr_and_segment_bodies_match_dense_aggregation():
    pairs = _mixed_pairs(4, 6)
    packed, _ = pack_pairs(pairs, 64, with_edges=True, edge_budget=64 * 4)
    e = packed.edges
    a_norm = normalized_adjacency(packed.adj1, packed.mask1)
    rng = np.random.default_rng(0)
    t, nb = np.asarray(packed.mask1).shape
    hw = jnp.asarray(rng.normal(size=(t, nb, 5)).astype(np.float32))
    dense = jnp.einsum("bnm,bmf->bnf", a_norm, hw)
    csr = csr_aggregate_block(e.edges1.senders, e.edges1.weights,
                              e.overflow1.senders, e.overflow1.receivers,
                              e.overflow1.weights, hw)
    np.testing.assert_allclose(np.asarray(csr), np.asarray(dense),
                               rtol=1e-5, atol=1e-6)
    # the generic flat segment-sum body agrees on the same edge arrays
    seg = (edge_aggregate_block(e.edges1.senders, e.edges1.receivers,
                                e.edges1.weights, hw)
           + overflow_aggregate_block(e.overflow1.senders,
                                      e.overflow1.receivers,
                                      e.overflow1.weights, hw))
    np.testing.assert_allclose(np.asarray(seg), np.asarray(csr),
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------- megakernel parity

@pytest.mark.parametrize("nbr_budget", [4, 6, 8])
def test_sparse_parity_across_edge_budgets(nbr_budget):
    pairs = _mixed_pairs(5, 20)
    packed, _ = pack_pairs(pairs, 64, with_edges=True,
                           edge_budget=64 * nbr_budget)
    s = ops.pair_score_sparse(PARAMS, packed, interpret=True)
    out = unpack_pair_scores(s, packed, len(pairs))
    np.testing.assert_allclose(out, _reference_scores(PARAMS, pairs),
                               rtol=0, atol=1e-6)


@pytest.mark.parametrize("batch", [1, 7, 13])
def test_sparse_parity_odd_batches(batch):
    pairs = _mixed_pairs(6 + batch, batch)
    packed, _ = pack_pairs(pairs, 64, with_edges=True)
    s = ops.pair_score_sparse(PARAMS, packed, interpret=True,
                              quantize_tiles=True)
    out = unpack_pair_scores(s, packed, len(pairs))
    assert out.shape == (batch,)
    np.testing.assert_allclose(out, _reference_scores(PARAMS, pairs),
                               rtol=0, atol=1e-6)


def test_sparse_auto_builds_edges():
    """pair_score_sparse on a batch packed WITHOUT edges extracts them at
    the default budget."""
    pairs = _mixed_pairs(8, 9)
    packed, _ = pack_pairs(pairs, 64)
    assert packed.edges is None
    s = ops.pair_score_sparse(PARAMS, packed, interpret=True)
    out = unpack_pair_scores(s, packed, len(pairs))
    np.testing.assert_allclose(out, _reference_scores(PARAMS, pairs),
                               rtol=0, atol=1e-6)


def test_sparse_bf16_inputs():
    pairs = _mixed_pairs(9, 10)
    packed, _ = pack_pairs(pairs, 64, with_edges=True)
    to16 = lambda t: jax.tree.map(
        lambda x: x.astype(jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, t)
    s16 = ops.pair_score_sparse(to16(PARAMS), to16(packed), interpret=True)
    assert s16.dtype == jnp.bfloat16
    out = unpack_pair_scores(s16.astype(jnp.float32), packed, len(pairs))
    ref = _reference_scores(PARAMS, pairs)
    rel = np.max(np.abs(out - ref)) / (np.max(np.abs(ref)) + 1e-9)
    assert rel < 2e-2


def test_sparse_variadic_gcn_depth():
    cfg = SimGNNConfig(gcn_dims=(64, 48, 32, 16))
    params = init_simgnn_params(jax.random.PRNGKey(2), cfg)
    pairs = _mixed_pairs(10, 8, max_n=32)
    packed, _ = pack_pairs(pairs, 64, with_edges=True)
    s = ops.pair_score_sparse(params, packed, interpret=True)
    out = unpack_pair_scores(s, packed, len(pairs))
    ref = np.zeros(len(pairs), np.float32)
    for b, (lhs, rhs, idxs) in bucket_pairs(pairs, cfg.n_node_labels).items():
        ref[idxs] = np.asarray(pair_score(params, lhs.adj, lhs.feats,
                                          lhs.mask, rhs.adj, rhs.feats,
                                          rhs.mask))
    np.testing.assert_allclose(out, ref, rtol=0, atol=1e-6)


# ---------------------------------------------------- data generator knob

def test_random_graph_avg_degree_knob_and_density_record():
    rng = np.random.default_rng(11)
    gs = [random_graph(rng, 40, avg_degree=6.0) for _ in range(20)]
    degrees = [g["avg_degree"] for g in gs]
    for g in gs:
        nnz = np.count_nonzero(g["adj"])
        assert g["avg_degree"] == pytest.approx(nnz / 40)
        assert g["density"] == pytest.approx(nnz / 1600)
    assert 4.0 < np.mean(degrees) <= 6.5    # collisions make 6.0 an upper bound
    sparse_gs = [random_graph(rng, 40) for _ in range(20)]
    assert np.mean([g["avg_degree"] for g in sparse_gs]) < 3.0
