"""Property-based invariants for the pair-packing planner (hypothesis):
over random graph sizes, degrees and budgets —

  * every pair lands in exactly one live tile slot (`pair_index` is a
    permutation of the input order under `pair_mask`);
  * segment IDs are contiguous per pair and sized exactly to each graph;
  * unpacking a packed `[T, P]` score tile recovers the input order;
  * `with_edges=True` CSR+COO round-trips the normalized adjacency's
    non-zeros exactly (count AND values).

Each property is a plain `_check_*` helper driven by a seeded generator so
the invariants are runnable without hypothesis too; the hypothesis wrappers
explore the (seed, n_pairs, budget) space in CI.
"""

import numpy as np
import pytest

from repro.core.batching import pack_pairs, unpack_pair_scores
from repro.core.gcn import normalized_adjacency
from repro.data.graphs import random_graph

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402


def _random_pairs(seed: int, n_pairs: int, node_budget: int,
                  max_degree: float):
    rng = np.random.default_rng(seed)
    deg = None if max_degree <= 0 else float(rng.uniform(1.0, max_degree))
    return [(random_graph(rng, int(rng.integers(2, node_budget + 1)),
                          avg_degree=deg),
             random_graph(rng, int(rng.integers(2, node_budget + 1)),
                          avg_degree=deg))
            for _ in range(n_pairs)]


def _check_slots_and_segments(seed, n_pairs, node_budget, max_degree):
    pairs = _random_pairs(seed, n_pairs, node_budget, max_degree)
    packed, stats = pack_pairs(pairs, node_budget)
    live = np.asarray(packed.pair_mask) > 0
    idxs = np.asarray(packed.pair_index)[live]
    # exactly one live slot per input pair, none invented
    assert sorted(idxs.tolist()) == list(range(n_pairs))
    assert stats["n_pairs"] == n_pairs
    for side, (seg_a, mask_a) in enumerate(
            ((packed.seg1, packed.mask1), (packed.seg2, packed.mask2))):
        seg = np.asarray(seg_a)
        mask = np.asarray(mask_a) > 0
        for t in range(seg.shape[0]):
            for p in np.flatnonzero(live[t]):
                nodes = np.flatnonzero((seg[t] == p) & mask[t])
                pair = pairs[int(np.asarray(packed.pair_index)[t, p])]
                # sized exactly to the packed graph, contiguous run
                assert len(nodes) == pair[side]["adj"].shape[0]
                assert np.array_equal(nodes,
                                      np.arange(nodes[0], nodes[-1] + 1))
            # no live node belongs to a dead slot
            assert set(np.unique(seg[t][mask[t]])) <= set(
                np.flatnonzero(live[t]))


def _check_unpack_roundtrip(seed, n_pairs, node_budget, max_degree):
    pairs = _random_pairs(seed, n_pairs, node_budget, max_degree)
    packed, _ = pack_pairs(pairs, node_budget)
    rng = np.random.default_rng(seed + 1)
    scores_tp = rng.normal(size=np.asarray(packed.pair_mask).shape).astype(
        np.float32)
    out = unpack_pair_scores(scores_tp, packed, n_pairs)
    live = np.asarray(packed.pair_mask) > 0
    pair_index = np.asarray(packed.pair_index)
    for t, p in zip(*np.nonzero(live)):
        assert out[pair_index[t, p]] == scores_tp[t, p]


def _check_edges_roundtrip(seed, n_pairs, node_budget, max_degree,
                           nbr_budget):
    pairs = _random_pairs(seed, n_pairs, node_budget, max_degree)
    edge_budget = None if nbr_budget is None else node_budget * nbr_budget
    packed, stats = pack_pairs(pairs, node_budget, with_edges=True,
                               edge_budget=edge_budget)
    nb = packed.node_budget
    for side, (adj, mask, csr, ov) in enumerate((
            (packed.adj1, packed.mask1, packed.edges.edges1,
             packed.edges.overflow1),
            (packed.adj2, packed.mask2, packed.edges.edges2,
             packed.edges.overflow2))):
        a_norm = np.asarray(normalized_adjacency(np.asarray(adj),
                                                 np.asarray(mask)))
        nnz = int(np.count_nonzero(a_norm))
        n_csr = int(np.asarray(csr.edge_mask).sum())
        n_ov = int(np.asarray(ov.edge_mask).sum())
        # nnz round-trips exactly: every A' non-zero is in CSR or COO,
        # no pad slot carries weight
        assert n_csr + n_ov == nnz
        key = "nnz_lhs" if side == 0 else "nnz_rhs"
        assert stats[key] == nnz
        # value-exact dense reconstruction (weights copied, never recomputed)
        dense = np.zeros_like(a_norm)
        for eb in (csr, ov):
            snd = np.asarray(eb.senders)
            rcv = np.asarray(eb.receivers)
            w = np.asarray(eb.weights) * np.asarray(eb.edge_mask)
            for t in range(dense.shape[0]):
                np.add.at(dense[t], (rcv[t], snd[t]), w[t])
        assert np.array_equal(dense, a_norm)
        # CSR plane layout: slot s holds an in-edge of node s % NB
        rcv = np.asarray(csr.receivers)
        assert np.array_equal(rcv % nb,
                              np.broadcast_to(np.arange(nb * (rcv.shape[-1]
                                                              // nb)) % nb,
                                              rcv.shape))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 12),
       st.sampled_from((16, 32, 64)), st.sampled_from((0.0, 3.0, 6.0)))
def test_every_pair_in_exactly_one_slot_with_contiguous_segments(
        seed, n_pairs, node_budget, max_degree):
    _check_slots_and_segments(seed, n_pairs, node_budget, max_degree)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 12),
       st.sampled_from((16, 32, 64)), st.sampled_from((0.0, 4.0)))
def test_unpack_recovers_packed_scores(seed, n_pairs, node_budget,
                                       max_degree):
    _check_unpack_roundtrip(seed, n_pairs, node_budget, max_degree)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 8),
       st.sampled_from((16, 32, 64)), st.sampled_from((0.0, 3.0, 8.0)),
       st.sampled_from((None, 4, 8)))
def test_packed_edges_roundtrip_adjacency_nnz(seed, n_pairs, node_budget,
                                              max_degree, nbr_budget):
    _check_edges_roundtrip(seed, n_pairs, node_budget, max_degree,
                           nbr_budget)
