"""MoE routing/dispatch invariants (unit + hypothesis property tests)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs import reduced_config
from repro.models.moe import _dispatch_indices, moe_capacity, moe_ffn, route


def _params(cfg, key=0):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    return {"router": jax.random.normal(ks[0], (d, e)) * 0.1,
            "w_in": jax.random.normal(ks[1], (e, d, 2 * f)) * 0.05,
            "w_out": jax.random.normal(ks[2], (e, f, d)) * 0.05}


def test_route_weights_normalized():
    cfg = reduced_config("phi3.5-moe-42b-a6.6b")
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    w, idx, aux = route(p["router"], x, cfg.top_k)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, atol=1e-5)
    # top-k experts are distinct per token
    assert (np.sort(np.asarray(idx), -1)[..., 1:] !=
            np.sort(np.asarray(idx), -1)[..., :-1]).all()
    assert np.isfinite(float(aux))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 8), st.integers(1, 4))
def test_dispatch_indices_invariants(seed, e, k):
    """Slots are unique per expert, in [0, cap), and keep-flags are exactly
    the first-cap assignments per expert."""
    rng = np.random.default_rng(seed)
    s = int(rng.integers(2, 33))
    k = min(k, e)
    cap = moe_capacity(s, e, k, 1.25)
    experts = jnp.asarray(rng.integers(0, e, (s, k)), jnp.int32)
    slot, keep = jax.jit(_dispatch_indices, static_argnums=(1, 2))(
        experts, e, cap)
    slot, keep = np.asarray(slot), np.asarray(keep)
    flat_e = np.asarray(experts).reshape(-1)
    for ee in range(e):
        kept_slots = slot[(flat_e == ee) & keep]
        assert len(np.unique(kept_slots)) == len(kept_slots)
        assert (kept_slots < cap).all()
        n_assigned = int((flat_e == ee).sum())
        assert int(((flat_e == ee) & keep).sum()) == min(n_assigned, cap)


def test_moe_no_drop_matches_dense():
    cfg = reduced_config("phi3.5-moe-42b-a6.6b").with_(capacity_factor=8.0)
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
    y, _ = moe_ffn(p, x, cfg)
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    w, idx = jax.lax.top_k(logits, cfg.top_k)
    w = jax.nn.softmax(w, -1)
    h = jnp.einsum("bsd,edf->bsef", x, p["w_in"])
    g, u = jnp.split(h, 2, -1)
    ye = jnp.einsum("bsef,efd->bsed", jax.nn.silu(g) * u, p["w_out"])
    ref = jnp.sum(jnp.take_along_axis(ye, idx[..., None], axis=2)
                  * w[..., None], axis=2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-4,
                               atol=1e-5)


def test_moe_capacity_drops_bounded():
    """With tight capacity, output differs from dense only on dropped tokens,
    and each token's output norm is bounded by the dense one's + 0."""
    cfg = reduced_config("granite-moe-3b-a800m").with_(capacity_factor=0.5)
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, cfg.d_model))
    y, _ = moe_ffn(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_decode_shape():
    """S=1 decode: capacity >= k guarantees no drops for a single token."""
    cfg = reduced_config("jamba-1.5-large-398b")
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 1, cfg.d_model))
    y, _ = moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    # must equal dense (no drops possible at S=1)
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    w, idx = jax.lax.top_k(logits, cfg.top_k)
    w = jax.nn.softmax(w, -1)
    h = jnp.einsum("bsd,edf->bsef", x, p["w_in"])
    g, u = jnp.split(h, 2, -1)
    ye = jnp.einsum("bsef,efd->bsed", jax.nn.silu(g) * u, p["w_out"])
    ref = jnp.sum(jnp.take_along_axis(ye, idx[..., None], axis=2)
                  * w[..., None], axis=2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-4,
                               atol=1e-5)


def test_moe_grads_flow_to_all_param_groups():
    cfg = reduced_config("granite-moe-3b-a800m").with_(capacity_factor=2.0)
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, cfg.d_model))
    g = jax.grad(lambda pp: jnp.sum(moe_ffn(pp, x, cfg)[0] ** 2))(p)
    for k, v in g.items():
        assert bool(jnp.all(jnp.isfinite(v))), k
        assert float(jnp.max(jnp.abs(v))) > 0, k
