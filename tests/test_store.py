"""Disk-chaos suite (DESIGN.md §13): durable-state integrity driven
deterministically through `repro.testing.faults` — the filesystem twin of
`tests/test_faults.py`.

Covers the full fault × surface matrix the acceptance criteria name:
each fault (torn write, bit flip, missing shard/file, stale manifest
version) against each surface (similarity-index load, train resume)
either fully recovers (selective re-embed / keep-k walk-back, counted in
`health()`) or raises a structured error — never silently-corrupt scores
or training state. Plus: ShardStore primitives (atomic writes, checksum
verification, mmap read-back), clean save/load bit-identity including
cache-eviction immunity, and the write-time fault seam itself.

CI runs this file as its own step so a durability regression is
distinguishable from a functional one at a glance.
"""

import json
import os

import jax
import numpy as np
import pytest

from repro.ckpt import manager as ckpt
from repro.core.simgnn import SimGNNConfig, init_simgnn_params
from repro.core.store import (MANIFEST_NAME, STORE_FORMAT_VERSION,
                              ManifestError, ShardStore, StoreError,
                              atomic_write_bytes, checksum, tree_digest)
from repro.data.graphs import zipf_corpus, zipf_query_stream
from repro.serve.search import SimilaritySearchServer
from repro.testing import faults

CFG = SimGNNConfig()
PARAMS = init_simgnn_params(jax.random.PRNGKey(0), CFG)

N_CORPUS = 12
SHARD_ROWS = 4                      # -> 3 shards over the test corpus


@pytest.fixture(scope="module")
def corpus():
    return zipf_corpus(3, N_CORPUS)


@pytest.fixture(scope="module")
def query():
    return next(zipf_query_stream(4, 8, n_corpus=N_CORPUS))["query"]


@pytest.fixture(scope="module")
def indexed(corpus):
    """One in-memory reference server shared by the read-only tests."""
    server = SimilaritySearchServer(PARAMS, CFG)
    server.index(corpus)
    return server


def _saved(tmp_path, indexed):
    d = str(tmp_path / "index")
    indexed.save(d, shard_rows=SHARD_ROWS)
    return d


# ------------------------------------------------------- store primitives

def test_store_roundtrip_bit_identical(tmp_path):
    m = np.arange(40, dtype=np.float32).reshape(10, 4)
    store = ShardStore(str(tmp_path))
    man = store.write(m, shard_rows=3, graph_keys=[f"{i:02x}"
                                                   for i in range(10)])
    assert man["format_version"] == STORE_FORMAT_VERSION
    assert [s["shape"][0] for s in man["shards"]] == [3, 3, 3, 1]
    assert store.verify() == {s["name"]: "ok" for s in man["shards"]}
    back = np.concatenate([store.read_shard(i) for i in store.shard_infos()])
    assert back.tobytes() == m.tobytes()
    # mmap read-back is a real memmap view of the shard file
    assert isinstance(store.read_shard(store.shard_infos()[0]), np.memmap)


def test_store_rewrite_sweeps_dead_shards(tmp_path):
    store = ShardStore(str(tmp_path))
    store.write(np.zeros((10, 2), np.float32), shard_rows=2)   # 5 shards
    store.write(np.ones((4, 2), np.float32), shard_rows=2)     # 2 shards
    names = sorted(n for n in os.listdir(tmp_path) if n.endswith(".bin"))
    assert names == ["shard_00000.bin", "shard_00001.bin"]
    assert all(s == "ok" for s in store.verify().values())


def test_atomic_write_leaves_no_tmp(tmp_path):
    path = str(tmp_path / "blob.bin")
    atomic_write_bytes(path, b"payload")
    assert os.listdir(tmp_path) == ["blob.bin"]
    assert open(path, "rb").read() == b"payload"


@pytest.mark.parametrize("mode,status", [
    ("torn", "corrupt"), ("bitflip", "corrupt"), ("missing", "missing")])
def test_at_rest_corruption_detected(tmp_path, mode, status):
    store = ShardStore(str(tmp_path))
    man = store.write(np.full((6, 3), 7.0, np.float32), shard_rows=6)
    faults.corrupt_file(str(tmp_path / man["shards"][0]["name"]), mode)
    assert store.verify() == {man["shards"][0]["name"]: status}
    with pytest.raises(StoreError):
        store.read_shard(store.shard_infos()[0])


def test_write_seam_torn_shard_detected(tmp_path):
    with faults.fs_inject("store:shard", "torn", times=1) as plan:
        store = ShardStore(str(tmp_path))
        store.write(np.arange(12, dtype=np.float32).reshape(6, 2),
                    shard_rows=3)
    assert plan.triggered == 1
    v = store.verify()
    assert v["shard_00000.bin"] == "corrupt" and v["shard_00001.bin"] == "ok"


def test_write_seam_missing_manifest(tmp_path):
    with faults.fs_inject("store:manifest", "missing"):
        ShardStore(str(tmp_path)).write(np.zeros((2, 2), np.float32))
    with pytest.raises(ManifestError, match="no manifest"):
        ShardStore(str(tmp_path)).manifest()


def test_stale_manifest_version_refused(tmp_path):
    with faults.fs_inject("store:manifest", "stale"):
        ShardStore(str(tmp_path)).write(np.zeros((2, 2), np.float32))
    with pytest.raises(ManifestError, match="format_version"):
        ShardStore(str(tmp_path)).manifest()


def test_garbled_manifest_refused(tmp_path):
    store = ShardStore(str(tmp_path))
    store.write(np.zeros((2, 2), np.float32))
    faults.corrupt_file(str(tmp_path / MANIFEST_NAME), "torn", at_byte=20)
    with pytest.raises(ManifestError, match="unreadable"):
        store.manifest()


def test_checksum_and_tree_digest_stable():
    assert checksum(b"abc") == checksum(b"abc")
    assert checksum(b"abc") != checksum(b"abd")
    assert tree_digest(PARAMS) == tree_digest(PARAMS)
    other = init_simgnn_params(jax.random.PRNGKey(1), CFG)
    assert tree_digest(PARAMS) != tree_digest(other)


# --------------------------------------------- surface 1: index save/load

def test_clean_save_load_bit_identical(tmp_path, indexed, corpus, query):
    """Satellite: restart parity — save -> load in a fresh server ->
    scores AND topk bit-identical to the original in-memory index."""
    d = _saved(tmp_path, indexed)
    fresh = SimilaritySearchServer(PARAMS, CFG)
    emb = fresh.load(d, corpus)
    assert emb.tobytes() == indexed.corpus_emb.tobytes()
    s0, s1 = indexed.scores(query), fresh.scores(query)
    assert s0.tobytes() == s1.tobytes()
    i0, v0 = indexed.topk(query, k=5)
    i1, v1 = fresh.topk(query, k=5)
    assert (i0 == i1).all() and v0.tobytes() == v1.tobytes()
    assert fresh.stats.shards_loaded == 3
    assert fresh.stats.shards_recovered == 0
    assert fresh.stats.rows_reembedded == 0


def test_loaded_index_immune_to_cache_eviction(tmp_path, indexed, corpus,
                                               query):
    """Satellite: after reload the resident matrix must survive LRU churn
    exactly like a built index does — eviction of every corpus entry
    cannot change served scores."""
    d = _saved(tmp_path, indexed)
    fresh = SimilaritySearchServer(PARAMS, CFG)
    fresh.load(d, corpus)
    before = fresh.scores(query)
    fresh.engine.cache.clear()                  # evict EVERYTHING
    after = fresh.scores(query)
    assert before.tobytes() == after.tobytes()


def test_load_populates_lru_like_index(tmp_path, indexed, corpus):
    d = _saved(tmp_path, indexed)
    fresh = SimilaritySearchServer(PARAMS, CFG)
    fresh.load(d, corpus)
    from repro.core.cache import graph_key
    assert all(graph_key(g) in fresh.engine.cache for g in corpus)


@pytest.mark.parametrize("mode", ["torn", "bitflip", "missing"])
def test_index_load_recovers_shard_fault(tmp_path, indexed, corpus, query,
                                         mode):
    """Chaos matrix, index-load surface: a damaged shard is detected by
    checksum/size/existence, ONLY its rows are re-embedded, counters land
    in health(), and the recovered index serves bit-identical scores."""
    d = _saved(tmp_path, indexed)
    faults.corrupt_file(os.path.join(d, "shard_00001.bin"), mode)
    fresh = SimilaritySearchServer(PARAMS, CFG)
    emb = fresh.load(d, corpus)
    assert emb.tobytes() == indexed.corpus_emb.tobytes()
    assert fresh.scores(query).tobytes() == indexed.scores(query).tobytes()
    assert fresh.stats.shards_loaded == 2
    assert fresh.stats.shards_recovered == 1
    assert fresh.stats.rows_reembedded == SHARD_ROWS
    h = fresh.health()
    assert h["shards_recovered"] == 1
    status = "missing" if mode == "missing" else "corrupt"
    assert h["counters"][f"store_shard_{status}"] == 1
    assert h["counters"]["store_rows_reembedded"] == SHARD_ROWS


def test_index_load_recovers_every_shard_lost(tmp_path, indexed, corpus,
                                              query):
    """All shards gone: load() still answers (it re-embeds everything) but
    the full rebuild is COUNTED, never silent."""
    d = _saved(tmp_path, indexed)
    for i in range(3):
        faults.corrupt_file(os.path.join(d, f"shard_{i:05d}.bin"), "missing")
    fresh = SimilaritySearchServer(PARAMS, CFG)
    emb = fresh.load(d, corpus)
    assert emb.tobytes() == indexed.corpus_emb.tobytes()
    assert fresh.stats.shards_recovered == 3
    assert fresh.stats.rows_reembedded == N_CORPUS


def test_index_load_stale_manifest_structured_error(tmp_path, indexed,
                                                    corpus):
    """Chaos matrix, index-load surface, stale manifest: the directory as
    a whole is untrustworthy -> structured ManifestError, and the server
    keeps its previous state (no partial adoption)."""
    d = _saved(tmp_path, indexed)
    faults.corrupt_file(os.path.join(d, MANIFEST_NAME), "stale")
    fresh = SimilaritySearchServer(PARAMS, CFG)
    with pytest.raises(ManifestError, match="format_version"):
        fresh.load(d, corpus)
    assert fresh.corpus_emb is None and fresh.corpus == []


def test_index_load_wrong_params_refused(tmp_path, indexed, corpus):
    """An index built by different model params must never serve: the
    embeddings would be finite, plausible, and wrong for every query."""
    d = _saved(tmp_path, indexed)
    other = init_simgnn_params(jax.random.PRNGKey(9), CFG)
    with pytest.raises(StoreError, match="different model"):
        SimilaritySearchServer(other, CFG).load(d, corpus)


def test_index_load_wrong_corpus_size_refused(tmp_path, indexed, corpus):
    d = _saved(tmp_path, indexed)
    with pytest.raises(StoreError, match="corpus"):
        SimilaritySearchServer(PARAMS, CFG).load(d, corpus[:-1])


def test_index_load_key_mismatch_reembeds(tmp_path, indexed, corpus):
    """A shard whose recorded graph_keys disagree with the corpus rows it
    claims (corpus drifted under the index) is re-embedded from the real
    graphs, not served stale."""
    d = _saved(tmp_path, indexed)
    swapped = list(corpus)
    swapped[0], swapped[1] = swapped[1], swapped[0]   # rows 0/1: shard 0
    fresh = SimilaritySearchServer(PARAMS, CFG)
    emb = fresh.load(d, swapped)
    assert fresh.stats.shards_recovered == 1
    assert fresh.engine.counters["store_shard_key_mismatch"] == 1
    # Recovered rows reflect the REAL corpus order, not the stale shard.
    ref = SimilaritySearchServer(PARAMS, CFG)
    ref.index(swapped)
    assert emb.tobytes() == ref.corpus_emb.tobytes()


def test_save_requires_index():
    with pytest.raises(ValueError, match="no corpus indexed"):
        SimilaritySearchServer(PARAMS, CFG).save("/nonexistent-unused")


# --------------------------------------------- surface 2: train resume

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (4, 8)),
            "step": jax.numpy.asarray(seed, jax.numpy.int32)}


def _ckpt_chain(tmp_path, steps=(10, 20, 30)):
    d = str(tmp_path / "ckpt")
    for s in steps:
        ckpt.save(d, s, _tree(s))
    return d


CKPT_FAULTS = [
    ("torn", "arrays.0.npz"), ("bitflip", "arrays.0.npz"),
    ("missing", "arrays.0.npz"), ("torn", "manifest.msgpack"),
    ("stale", "manifest.msgpack"), ("missing", "manifest.msgpack")]


@pytest.mark.parametrize("mode,victim", CKPT_FAULTS)
def test_resume_walks_back_past_corrupt_newest(tmp_path, mode, victim):
    """Chaos matrix, train-resume surface: every fault mode on the newest
    checkpoint makes latest_valid_step fall back to the previous complete-
    and-valid one, and verified restore() refuses the corrupt step."""
    d = _ckpt_chain(tmp_path)
    faults.corrupt_file(os.path.join(d, "step_000000030", victim), mode)
    best, skipped = ckpt.latest_valid_step(d)
    assert best == 20
    assert [s for s, _ in skipped] == [30]
    assert skipped[0][1]                       # structured problem strings
    if victim.startswith("arrays") or mode != "missing":
        with pytest.raises(ckpt.CheckpointCorrupt):
            ckpt.restore(d, 30, _tree(30))
    restored = ckpt.restore(d, best, _tree(20))
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(_tree(20)["w"]))


def test_resume_walks_back_two_rungs(tmp_path):
    d = _ckpt_chain(tmp_path)
    faults.corrupt_file(os.path.join(d, "step_000000030", "arrays.0.npz"),
                        "bitflip")
    faults.corrupt_file(os.path.join(d, "step_000000020",
                                     "manifest.msgpack"), "torn")
    best, skipped = ckpt.latest_valid_step(d)
    assert best == 10 and sorted(s for s, _ in skipped) == [20, 30]


def test_resume_all_corrupt_reports_none(tmp_path):
    d = _ckpt_chain(tmp_path, steps=(10,))
    faults.corrupt_file(os.path.join(d, "step_000000010", "arrays.0.npz"),
                        "torn")
    best, skipped = ckpt.latest_valid_step(d)
    assert best is None and [s for s, _ in skipped] == [10]


def test_write_seam_stale_ckpt_manifest(tmp_path):
    """The stale fault through the WRITE seam (a replica on newer code
    wrote the checkpoint): verification refuses it."""
    d = str(tmp_path)
    with faults.fs_inject("ckpt:manifest", "stale"):
        ckpt.save(d, 5, _tree())
    assert any("format_version" in p for p in ckpt.verify_step(d, 5))
    assert ckpt.latest_valid_step(d) == (None, [(5, ckpt.verify_step(d, 5))])


def test_write_seam_torn_ckpt_arrays(tmp_path):
    d = str(tmp_path)
    with faults.fs_inject("ckpt:arrays", "torn") as plan:
        ckpt.save(d, 5, _tree())
    assert plan.triggered == 1
    assert any("checksum mismatch" in p for p in ckpt.verify_step(d, 5))


def test_loop_resumes_through_walkback(tmp_path):
    """End to end: train/loop.run with resume="auto" restores the newest
    VALID checkpoint when the newest one is torn, reports the skip via
    on_resume, and continues training from there."""
    from repro.train import loop

    def step_fn(params, opt_state, batch):
        params = {"x": params["x"] + batch}
        return params, opt_state, {"loss": jax.numpy.asarray(0.0)}

    d = str(tmp_path / "run")
    p0 = {"x": jax.numpy.zeros(())}
    # 6 steps, checkpoint every 2 -> steps 2, 4, 6 on disk
    loop.run(step_fn, p0, {}, lambda s: jax.numpy.asarray(1.0), n_steps=6,
             ckpt_dir=d, ckpt_every=2, resume=None, log_every=100)
    faults.corrupt_file(os.path.join(d, "step_000000006", "arrays.0.npz"),
                        "bitflip")
    seen = {}

    def on_resume(step, skipped):
        seen["step"], seen["skipped"] = step, [s for s, _ in skipped]

    params, _, _ = loop.run(
        step_fn, p0, {}, lambda s: jax.numpy.asarray(1.0), n_steps=8,
        ckpt_dir=d, ckpt_every=2, resume="auto", log_every=100,
        on_resume=on_resume)
    assert seen == {"step": 4, "skipped": [6]}
    # resumed at 4, ran 4 more steps of +1
    assert float(np.asarray(params["x"])) == 8.0


def test_loop_fresh_start_when_everything_corrupt(tmp_path):
    from repro.train import loop

    def step_fn(params, opt_state, batch):
        return {"x": params["x"] + 1.0}, opt_state, {
            "loss": jax.numpy.asarray(0.0)}

    d = str(tmp_path / "run")
    loop.run(step_fn, {"x": jax.numpy.zeros(())}, {},
             lambda s: None, n_steps=2, ckpt_dir=d, ckpt_every=2,
             resume=None, log_every=100)
    faults.corrupt_file(os.path.join(d, "step_000000002", "arrays.0.npz"),
                        "torn")
    params, _, _ = loop.run(
        step_fn, {"x": jax.numpy.zeros(())}, {}, lambda s: None, n_steps=3,
        ckpt_dir=d, ckpt_every=50, resume="auto", log_every=100)
    assert float(np.asarray(params["x"])) == 3.0   # started from 0


# ------------------------------------- surface 3: trace profile (§15)

def _profile_recorder(tmp_path, n=4):
    """Recorder with `n` deterministic records staged for flush."""
    from repro.core.profile import TraceRecorder

    rec = TraceRecorder(path=str(tmp_path / "trace.jsonl"),
                        clock=lambda: 0.0)
    for i in range(n):
        rec.record(kind="score", path="packed_dense", n_pairs=4 + i,
                   max_nodes=16, mean_nodes=8.0, avg_degree=2.0,
                   density=0.25, wall_s=0.01)
    return rec


def test_profile_torn_flush_skipped_and_counted(tmp_path):
    """A torn profile flush must not raise, and the next read self-heals:
    the truncated tail is dropped-and-counted, every surviving record
    parses clean (§15 contract — losing samples is recoverable)."""
    from repro.core.profile import read_profile

    rec = _profile_recorder(tmp_path)
    with faults.fs_inject("profile", mode="torn") as plan:
        rec.flush()
    assert plan.triggered == 1
    records, dropped = read_profile(rec.path)
    assert len(records) < 4                # part of the flush was lost
    assert dropped <= 1                    # at most the one torn line
    assert all(r.path == "packed_dense" for r in records)


def test_profile_missing_flush_never_raises(tmp_path):
    """A dropped profile flush (writer believes it succeeded) degrades
    observability only: flush() returns quietly, the ring keeps every
    sample, and the reader reports the absence as a structured error."""
    from repro.core.profile import ProfileError, read_profile

    rec = _profile_recorder(tmp_path)
    with faults.fs_inject("profile", mode="missing") as plan:
        rec.flush()
    assert plan.triggered == 1
    assert not os.path.exists(rec.path)
    with pytest.raises(ProfileError, match="no profile"):
        read_profile(rec.path)
    assert rec.total_records == 4          # in-memory ring untouched


def test_profile_at_rest_bitflip_skipped_and_counted(tmp_path):
    """At-rest bit rot garbling one record line: that line (and only it)
    is skipped-and-counted by both readers, and `load()` resumes the seq
    counter past the survivors."""
    from repro.core.profile import TraceRecorder, read_profile

    rec = _profile_recorder(tmp_path)
    rec.flush()
    with open(rec.path, "rb") as f:
        header = f.readline()
    # flip the opening '{' of the first record line -> invalid JSON
    faults.corrupt_file(rec.path, "bitflip", at_byte=len(header))
    records, dropped = read_profile(rec.path)
    assert dropped == 1
    assert [r.seq for r in records] == [1, 2, 3]
    loaded = TraceRecorder.load(rec.path)
    assert loaded.counters["records_dropped"] == 1
    assert loaded._seq == 4                # past the surviving max seq


def test_profile_at_rest_torn_header_refused(tmp_path):
    """Damage inside the HEADER is whole-file distrust, not per-line skip:
    a schema we cannot verify must raise ProfileError, never guess."""
    from repro.core.profile import ProfileError, read_profile

    rec = _profile_recorder(tmp_path)
    rec.flush()
    faults.corrupt_file(rec.path, "torn", at_byte=10)
    with pytest.raises(ProfileError):
        read_profile(rec.path)


# ----------------------------------------------------------- seam hygiene

def test_fs_hook_disarms_on_exit(tmp_path):
    from repro.core import store as store_mod

    with faults.fs_inject("store:shard", "torn"):
        assert store_mod._FS_HOOK is not None
    assert store_mod._FS_HOOK is None
    # nested blocks: outer stays armed until the last exits
    with faults.fs_inject("store:shard", "torn"):
        with faults.fs_inject("store:manifest", "missing"):
            assert store_mod._FS_HOOK is not None
        assert store_mod._FS_HOOK is not None
    assert store_mod._FS_HOOK is None


def test_fs_inject_rejects_unknown_mode():
    with pytest.raises(ValueError, match="unknown filesystem fault"):
        with faults.fs_inject("store:shard", "gamma-ray"):
            pass


def test_stale_mode_only_for_manifests(tmp_path):
    with pytest.raises(ValueError, match="manifest sites"):
        with faults.fs_inject("store:shard", "stale"):
            ShardStore(str(tmp_path)).write(np.zeros((2, 2), np.float32))
