"""Differentiable engine scoring tests (DESIGN.md §11).

Gradient parity matrix: `ScoringEngine.loss_and_grad` on the custom-VJP
packed executors (packed_dense / packed_sparse) against the dense-reference
autodiff anchor `jax.value_and_grad(simgnn_loss)` — f32 at the 1e-5
acceptance bound (per-leaf max abs error), bf16 at the 2e-2 band — across
odd/even batches, isolated nodes and a COO-overflow-exercising high-degree
configuration. Plus: train-mode plan restriction (VJP-capable paths only,
reference fallback for oversize), pack-once accumulation equivalence, the
engine-routed train step, the no-path-branching contract for train/step.py,
and hypothesis properties pinning that the VJP of pad slots is exactly
zero.
"""

import ast
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.batching import pack_pairs, pad_graphs
from repro.core.engine import TRAIN_PATHS, ScoringEngine
from repro.core.simgnn import (SimGNNConfig, init_simgnn_params, simgnn_loss)
from repro.data.graphs import random_graph

CFG = SimGNNConfig()
PARAMS = init_simgnn_params(jax.random.PRNGKey(0), CFG)

#: f32 acceptance bound for engine grads vs dense-reference autodiff
#: (per-leaf max abs error; ISSUE/benchmarks/train.py use the same bound).
GRAD_ATOL_F32 = 1e-5
GRAD_ATOL_BF16 = 2e-2


def _mixed_pairs(seed, n_pairs, max_n=64, avg_degree=None):
    rng = np.random.default_rng(seed)
    return [(random_graph(rng, int(rng.integers(5, max_n + 1)),
                          avg_degree=avg_degree),
             random_graph(rng, int(rng.integers(5, max_n + 1)),
                          avg_degree=avg_degree))
            for _ in range(n_pairs)]


def _targets(seed, n):
    return np.random.default_rng(1000 + seed).uniform(0.0, 1.0, n).astype(
        np.float32)


def _ref_loss_and_grad(params, pairs, targets, max_nodes=64):
    """The independent autodiff anchor: `jax.value_and_grad(simgnn_loss)`
    on the one-hot dense-padded batch — no engine, no custom VJPs."""
    b1 = pad_graphs([p[0] for p in pairs], CFG.n_node_labels, max_nodes)
    b2 = pad_graphs([p[1] for p in pairs], CFG.n_node_labels, max_nodes)
    batch = {"adj1": b1.adj, "feats1": b1.feats, "mask1": b1.mask,
             "adj2": b2.adj, "feats2": b2.feats, "mask2": b2.mask,
             "target": jnp.asarray(targets)}
    return jax.value_and_grad(simgnn_loss)(params, batch)


def _assert_grad_close(got, ref, atol):
    leaves_got = jax.tree.leaves(got)
    leaves_ref = jax.tree.leaves(ref)
    assert len(leaves_got) == len(leaves_ref)
    worst = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(leaves_got, leaves_ref))
    assert worst <= atol, f"max grad err {worst:.2e} > {atol:.0e}"


def _cast(tree, dtype):
    if dtype == "float32":
        return tree
    return jax.tree.map(
        lambda x: x.astype(jnp.bfloat16)
        if x.dtype == jnp.float32 else x, tree)


# ----------------------------------------------------- gradient parity matrix

@pytest.mark.parametrize("batch", (7, 12))        # odd pads every policy
@pytest.mark.parametrize("dtype", ("float32", "bfloat16"))
@pytest.mark.parametrize("path", ("packed_dense", "packed_sparse"))
def test_grad_parity_matrix(path, dtype, batch):
    pairs = _mixed_pairs(batch, batch)
    targets = _targets(batch, batch)
    params = _cast(PARAMS, dtype)
    engine = ScoringEngine(params, CFG, path=path)
    loss, grads = engine.loss_and_grad(pairs, targets)
    ref_loss, ref_grads = _ref_loss_and_grad(_cast(PARAMS, dtype), pairs,
                                             targets)
    atol = GRAD_ATOL_F32 if dtype == "float32" else GRAD_ATOL_BF16
    assert abs(float(loss) - float(ref_loss)) <= atol
    _assert_grad_close(grads, ref_grads, atol)
    assert engine.last_plan.path == path
    assert engine.last_pack_stats is not None


def test_grad_parity_isolated_nodes():
    """Graphs with isolated (but real) nodes: the self-loop-only rows keep
    exact grad parity through both packed aggregations."""
    rng = np.random.default_rng(3)
    pairs = []
    for _ in range(6):
        g1 = random_graph(rng, 12)
        g2 = random_graph(rng, 9)
        for g in (g1, g2):          # sever one node completely
            g["adj"][0, :] = g["adj"][:, 0] = 0.0
        pairs.append((g1, g2))
    targets = _targets(3, 6)
    for path in ("packed_dense", "packed_sparse"):
        engine = ScoringEngine(PARAMS, CFG, path=path)
        loss, grads = engine.loss_and_grad(pairs, targets)
        ref_loss, ref_grads = _ref_loss_and_grad(PARAMS, pairs, targets)
        assert abs(float(loss) - float(ref_loss)) <= GRAD_ATOL_F32
        _assert_grad_close(grads, ref_grads, GRAD_ATOL_F32)


def test_grad_parity_through_coo_overflow():
    """A deliberately tiny per-node edge budget (D=2 << degree) forces the
    COO overflow aggregation — whose custom VJP is the sender/receiver swap
    — into the backward pass."""
    pairs = _mixed_pairs(4, 8, max_n=32, avg_degree=6.0)
    targets = _targets(4, 8)
    engine = ScoringEngine(PARAMS, CFG, path="packed_sparse",
                           edge_budget=64 * 2)
    loss, grads = engine.loss_and_grad(pairs, targets)
    assert engine.last_pack_stats["overflow_budget"] > 0
    ref_loss, ref_grads = _ref_loss_and_grad(PARAMS, pairs, targets)
    assert abs(float(loss) - float(ref_loss)) <= GRAD_ATOL_F32
    _assert_grad_close(grads, ref_grads, GRAD_ATOL_F32)


# ------------------------------------------------------- train-mode planning

def test_train_plan_restricted_to_vjp_capable_paths():
    engine = ScoringEngine(PARAMS, CFG)
    pairs = _mixed_pairs(5, 12)
    plan = engine.plan(pairs, train=True)
    assert plan.path in TRAIN_PATHS
    assert plan.fallback == "reference"
    # tiny batches degrade to the reference, not the bucketed megakernel
    tiny = engine.plan(_mixed_pairs(6, 2), train=True)
    assert tiny.path == "reference"


def test_train_rejects_non_vjp_paths():
    for path in ("bucketed_mega", "two_kernel", "embedding_cache"):
        engine = ScoringEngine(PARAMS, CFG, path=path)
        with pytest.raises(ValueError, match="VJP-capable"):
            engine.loss_and_grad(_mixed_pairs(7, 6), _targets(7, 6))


def test_train_oversize_pairs_fall_back_to_reference():
    rng = np.random.default_rng(8)
    pairs = _mixed_pairs(8, 6) + [(random_graph(rng, 90),
                                   random_graph(rng, 20))]
    targets = _targets(8, 7)
    engine = ScoringEngine(PARAMS, CFG, path="packed_sparse")
    loss, grads = engine.loss_and_grad(pairs, targets)
    plan = engine.last_plan
    assert len(plan.fit_idx) == 6 and list(plan.over_idx) == [6]
    assert plan.fallback == "reference"
    # parity against the forced-reference engine (itself anchored to
    # simgnn_loss autodiff by the matrix above), which buckets the same way
    ref_engine = ScoringEngine(PARAMS, CFG, path="reference")
    ref_loss, ref_grads = ref_engine.loss_and_grad(pairs, targets)
    assert abs(float(loss) - float(ref_loss)) <= GRAD_ATOL_F32
    _assert_grad_close(grads, ref_grads, GRAD_ATOL_F32)


def test_reference_executor_matches_simgnn_loss_autodiff():
    """The engine's reference train executor (label-gather variant) against
    the one-hot `simgnn_loss` anchor: same loss, same grads."""
    pairs = _mixed_pairs(9, 10)
    targets = _targets(9, 10)
    engine = ScoringEngine(PARAMS, CFG, path="reference")
    loss, grads = engine.loss_and_grad(pairs, targets)
    ref_loss, ref_grads = _ref_loss_and_grad(PARAMS, pairs, targets)
    assert abs(float(loss) - float(ref_loss)) <= GRAD_ATOL_F32
    _assert_grad_close(grads, ref_grads, GRAD_ATOL_F32)


def test_empty_batch_loss_and_grad():
    engine = ScoringEngine(PARAMS, CFG)
    loss, grads = engine.loss_and_grad([], [])
    assert float(loss) == 0.0
    assert all(float(jnp.max(jnp.abs(g))) == 0.0
               for g in jax.tree.leaves(grads))


def test_label_free_graphs_rejected_in_training():
    pairs = [({"adj": g1["adj"]}, g2) for g1, g2 in _mixed_pairs(10, 6)]
    engine = ScoringEngine(PARAMS, CFG)
    with pytest.raises(ValueError, match="int node labels"):
        engine.loss_and_grad(pairs, _targets(10, 6))


# ------------------------------------------- pack once, accumulate in chunks

def test_accumulation_microbatches_match_single_shot():
    pairs = _mixed_pairs(11, 16)
    targets = _targets(11, 16)
    engine = ScoringEngine(PARAMS, CFG, path="packed_sparse")
    loss1, grads1 = engine.loss_and_grad(pairs, targets, accum_steps=1)
    stats1 = dict(engine.last_pack_stats)
    loss4, grads4 = engine.loss_and_grad(pairs, targets, accum_steps=4)
    # same single packing (pack once per batch), same totals
    assert engine.last_pack_stats["n_tiles"] == stats1["n_tiles"]
    assert abs(float(loss1) - float(loss4)) <= 1e-6
    _assert_grad_close(grads4, grads1, 1e-6)


def test_accum_steps_must_be_power_of_two():
    engine = ScoringEngine(PARAMS, CFG)
    with pytest.raises(ValueError, match="power of two"):
        engine.loss_and_grad(_mixed_pairs(12, 8), _targets(12, 8),
                             accum_steps=3)


# ----------------------------------------------------- engine-routed training

def test_train_step_goes_through_engine():
    from repro.train.optimizer import adamw_init
    from repro.train.step import build_simgnn_train_step

    pairs = _mixed_pairs(13, 8)
    batch = {"pairs": pairs, "target": _targets(13, 8)}
    engine = ScoringEngine(PARAMS, CFG)
    step = build_simgnn_train_step(engine, peak_lr=1e-3)
    params, opt_state, metrics = step(PARAMS, adamw_init(PARAMS), batch)
    assert engine.last_plan.path in TRAIN_PATHS
    assert set(metrics) == {"loss", "grad_norm", "lr", "step"}
    assert float(metrics["loss"]) > 0
    # params actually moved
    moved = any(float(jnp.max(jnp.abs(a - b))) > 0
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(PARAMS)))
    assert moved


def test_train_step_no_direct_path_branching():
    """The refactor contract (mirror of the serve-side test): train/step.py
    must not name or branch on scoring paths, packing or kernels — that
    logic lives only in core/engine.py."""
    import repro.train.step as ts
    tree = ast.parse(inspect.getsource(ts))
    for node in ast.walk(tree):            # drop docstrings: code only
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Module)):
            if (node.body and isinstance(node.body[0], ast.Expr)
                    and isinstance(node.body[0].value, ast.Constant)):
                node.body = node.body[1:]
    src = ast.unparse(tree)
    for needle in ("pack_pairs", "bucket_pairs", "pair_score_packed",
                   "pair_score_sparse", "pair_score_megakernel",
                   "simgnn_loss", "packed_sparse", "packed_dense",
                   "oversize"):
        assert needle not in src, f"path selection leaked into train: {needle}"


# --------------------------------------------- pad-slot VJP-zero properties
# (plain seeded checks; tests/test_grad_properties.py drives the same
# helpers through hypothesis over the full (seed, n, d/p) space in CI)

def check_csr_vjp_of_pad_slots_is_exactly_zero(seed, n, d):
    """Pad ELLPACK slots (exact-zero weight, sender 0) must contribute
    EXACTLY zero cotangent: d_hw rows of nodes that send no real edge are
    bit-zero, and the pad slots' stored sender indices are irrelevant."""
    from repro.kernels.common import csr_aggregate_block

    rng = np.random.default_rng(seed)
    live = rng.random((1, n * d)) < 0.5
    nbr = rng.integers(0, n, (1, n * d)).astype(np.int32) * live
    w = (rng.uniform(0.5, 1.5, (1, n * d)).astype(np.float32) * live)
    e_ov = 4
    ovs = np.zeros((1, e_ov), np.int32)
    ovr = np.zeros((1, e_ov), np.int32)
    ovw = np.zeros((1, e_ov), np.float32)
    hw = rng.normal(size=(1, n, 3)).astype(np.float32)
    g = rng.normal(size=(1, n, 3)).astype(np.float32)

    def pullback(nbr_arr):
        f = lambda x: jnp.vdot(csr_aggregate_block(
            jnp.asarray(nbr_arr), jnp.asarray(w), jnp.asarray(ovs),
            jnp.asarray(ovr), jnp.asarray(ovw), x), jnp.asarray(g))
        return np.asarray(jax.grad(f)(jnp.asarray(hw)))

    d_hw = pullback(nbr)
    real_senders = set(nbr[0, live[0]].tolist())
    for node in range(n):
        if node not in real_senders:
            assert (d_hw[0, node] == 0).all(), node
    # pad slots' sender indices are dead: scrambling them changes nothing
    scrambled = nbr.copy()
    scrambled[~live] = rng.integers(0, n, int((~live).sum()))
    np.testing.assert_array_equal(d_hw, pullback(scrambled))


def check_segment_att_pool_vjp_of_pad_nodes_is_exactly_zero(seed, n, p):
    """Mask-0 node slots of a packed tile receive bit-zero `h` cotangents
    through the segment attention pooling VJP."""
    from repro.kernels.common import segment_att_pool_block

    rng = np.random.default_rng(seed)
    n_real = rng.integers(1, n + 1)
    mask = (np.arange(n) < n_real).astype(np.float32)[None]
    seg = (rng.integers(0, p, (1, n)).astype(np.int32) * mask).astype(
        np.int32)
    h = rng.normal(size=(1, n, 5)).astype(np.float32)
    att_w = rng.normal(size=(5, 5)).astype(np.float32) / np.sqrt(5)
    g = rng.normal(size=(1, p, 5)).astype(np.float32)

    f = lambda x: jnp.vdot(segment_att_pool_block(
        x, jnp.asarray(mask), jnp.asarray(seg), jnp.asarray(att_w), p),
        jnp.asarray(g))
    d_h = np.asarray(jax.grad(f)(jnp.asarray(h)))
    assert (d_h[0, n_real:] == 0).all()
    if n_real < n:   # pad rows of h are dead inputs too
        h2 = h.copy()
        h2[0, n_real:] = rng.normal(size=(n - n_real, 5))
        np.testing.assert_array_equal(d_h,
                                      np.asarray(jax.grad(f)(jnp.asarray(h2))))


@pytest.mark.parametrize("seed", range(4))
def test_csr_vjp_pad_slots_zero_seeded(seed):
    check_csr_vjp_of_pad_slots_is_exactly_zero(seed, n=4 + 2 * seed,
                                               d=1 + seed % 3)


@pytest.mark.parametrize("seed", range(4))
def test_segment_att_pool_vjp_pad_nodes_zero_seeded(seed):
    check_segment_att_pool_vjp_of_pad_nodes_is_exactly_zero(
        seed, n=4 + 2 * seed, p=1 + seed % 3)
