"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode).

Tolerance policy follows /opt/skills/resources/kernel_taxonomy.md Part E:
fp32 sweeps at 1e-5-class atol, bf16 at 2x measured bf16-vs-fp32 ref error.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gcn import normalized_adjacency
from repro.core.simgnn import SimGNNConfig, init_simgnn_params
from repro.data.graphs import pair_stream
from repro.kernels import ops, ref
from repro.kernels.flash_attn import flash_attention
from repro.kernels.fused_gcn import fused_gcn_att
from repro.kernels.wkv6 import wkv6


def _rel(a, b):
    return float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-9))


# --------------------------------------------------------------- fused_gcn

@pytest.mark.parametrize("n_nodes,block_graphs", [(8, 2), (16, 4), (32, 8),
                                                  (64, 4)])
def test_fused_gcn_shapes(n_nodes, block_graphs):
    cfg = SimGNNConfig(max_nodes=n_nodes)
    params = init_simgnn_params(jax.random.PRNGKey(0), cfg)
    if n_nodes >= 64:
        batch = next(pair_stream(1, 8, max_nodes=n_nodes))
        adj, feats, mask = (jnp.asarray(batch["adj1"]),
                            jnp.asarray(batch["feats1"]),
                            jnp.asarray(batch["mask1"]))
    else:                        # synthesize graphs that fit the bucket
        key = jax.random.PRNGKey(1)
        adj = (jax.random.uniform(key, (8, n_nodes, n_nodes)) > 0.5).astype(jnp.float32)
        adj = jnp.triu(adj, 1)
        adj = adj + adj.transpose(0, 2, 1)
        mask = jnp.ones((8, n_nodes))
        feats = jax.random.normal(key, (8, n_nodes, cfg.n_node_labels))
    a_norm = normalized_adjacency(adj, mask)
    out_k = fused_gcn_att(a_norm, feats, mask, params["gcn"],
                          params["att"]["w"], block_graphs=block_graphs,
                          interpret=True)
    out_r = ref.fused_gcn_att_ref(a_norm, feats, mask, params["gcn"],
                                  params["att"]["w"])
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-4, atol=1e-5)


def test_fused_gcn_bf16():
    cfg = SimGNNConfig()
    params = init_simgnn_params(jax.random.PRNGKey(0), cfg)
    batch = next(pair_stream(2, 8))
    to16 = lambda t: jax.tree.map(lambda x: x.astype(jnp.bfloat16), t)
    a_norm = normalized_adjacency(jnp.asarray(batch["adj1"]),
                                  jnp.asarray(batch["mask1"]))
    out_k = fused_gcn_att(a_norm.astype(jnp.bfloat16),
                          jnp.asarray(batch["feats1"], jnp.bfloat16),
                          jnp.asarray(batch["mask1"]),
                          to16(params["gcn"]), to16(params["att"]["w"]),
                          block_graphs=4, interpret=True)
    out_r = ref.fused_gcn_att_ref(a_norm, jnp.asarray(batch["feats1"]),
                                  jnp.asarray(batch["mask1"]),
                                  params["gcn"], params["att"]["w"])
    assert _rel(out_k.astype(jnp.float32), out_r) < 0.05


def test_full_simgnn_kernel_path_matches_core():
    from repro.core.simgnn import pair_score
    cfg = SimGNNConfig()
    params = init_simgnn_params(jax.random.PRNGKey(0), cfg)
    b = next(pair_stream(3, 12))
    args = [jnp.asarray(b[k]) for k in
            ("adj1", "feats1", "mask1", "adj2", "feats2", "mask2")]
    s_kernel = ops.simgnn_pair_score_kernel(params, *args, interpret=True)
    s_core = pair_score(params, *args)
    np.testing.assert_allclose(np.asarray(s_kernel), np.asarray(s_core),
                               rtol=1e-5, atol=1e-6)


# -------------------------------------------------------------- simgnn_head

@pytest.mark.parametrize("b,f,k", [(8, 16, 4), (128, 32, 16), (32, 64, 8)])
def test_simgnn_head_sweep(b, f, k):
    key = jax.random.PRNGKey(b + f)
    ntn = {"w": jax.random.normal(key, (k, f, f)) / f,
           "v": jax.random.normal(key, (k, 2 * f)) / f,
           "b": jnp.zeros((k,))}
    fcn = [{"w": jax.random.normal(key, (k, 4)) * 0.3, "b": jnp.zeros((4,))},
           {"w": jax.random.normal(key, (4, 1)) * 0.3, "b": jnp.zeros((1,))}]
    h1 = jax.random.normal(jax.random.PRNGKey(1), (b, f))
    h2 = jax.random.normal(jax.random.PRNGKey(2), (b, f))
    out_k = ops.pair_scores_fused({"ntn": ntn, "fcn": fcn}, h1, h2,
                                  block_pairs=min(8, b), interpret=True)
    out_r = ref.simgnn_head_ref(h1, h2, ntn, fcn)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-6)


# -------------------------------------------------------------- flash_attn

@pytest.mark.parametrize("causal,window,softcap", [
    (True, None, None), (False, None, None), (True, 64, None),
    (True, None, 30.0), (True, 32, 50.0)])
def test_flash_attention_masks(causal, window, softcap):
    b, t, h, kv, d = 2, 128, 4, 2, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (b, t, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, t, kv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, t, kv, d))
    out_k = flash_attention(q, k, v, causal=causal, window=window,
                            softcap=softcap, block_q=32, block_kv=32,
                            interpret=True)
    out_r = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                    softcap=softcap)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("t,s,h,kv,d", [(64, 64, 8, 8, 64), (128, 128, 8, 1, 16),
                                        (256, 256, 4, 4, 128)])
def test_flash_attention_shapes(t, s, h, kv, d):
    b = 2
    q = jax.random.normal(jax.random.PRNGKey(3), (b, t, h, d))
    k = jax.random.normal(jax.random.PRNGKey(4), (b, s, kv, d))
    v = jax.random.normal(jax.random.PRNGKey(5), (b, s, kv, d))
    out_k = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                            interpret=True)
    out_r = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-4, atol=2e-5)


def test_flash_attention_bf16():
    b, t, h, kv, d = 2, 128, 4, 2, 64
    mk = lambda i, kvh: jax.random.normal(jax.random.PRNGKey(i),
                                          (b, t, kvh, d)).astype(jnp.bfloat16)
    q, k, v = mk(0, h), mk(1, kv), mk(2, kv)
    out_k = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                            interpret=True)
    out_r = ref.flash_attention_ref(q, k, v, causal=True)
    assert _rel(out_k.astype(jnp.float32), out_r.astype(jnp.float32)) < 0.03


# -------------------------------------------------------------------- wkv6

@pytest.mark.parametrize("t,h,kd,vd,bt", [(64, 2, 16, 16, 32), (128, 4, 64, 64, 64),
                                          (32, 1, 8, 8, 32)])
def test_wkv6_sweep(t, h, kd, vd, bt):
    b = 2
    key = jax.random.PRNGKey(7)
    r = jax.random.normal(key, (b, t, h, kd)) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(8), (b, t, h, kd)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(9), (b, t, h, vd)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(10), (b, t, h, kd)))
    u = jax.random.normal(jax.random.PRNGKey(11), (h, kd)) * 0.1
    out_k = wkv6(r, k, v, w, u, block_t=bt, interpret=True)
    out_r = ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-4, atol=1e-5)


def test_wkv6_matches_model_scan():
    """Kernel recurrence == the model's XLA-path scan (rwkv6.wkv_scan)."""
    from repro.models.rwkv6 import wkv_scan
    b, t, h, kd = 2, 64, 2, 16
    r = jax.random.normal(jax.random.PRNGKey(0), (b, t, h, kd)) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(1), (b, t, h, kd)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(2), (b, t, h, kd)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(3), (b, t, h, kd)))
    u = jax.random.normal(jax.random.PRNGKey(4), (h, kd)) * 0.1
    out_scan, _ = wkv_scan(r, k, v, w, u)
    out_kernel = wkv6(r, k, v, w, u, block_t=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_scan),
                               rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------- moe_experts

@pytest.mark.parametrize("e,c,d,f,bc", [(4, 128, 64, 32, 64),
                                        (8, 256, 128, 64, 128),
                                        (2, 128, 256, 512, 128)])
def test_moe_expert_kernel_sweep(e, c, d, f, bc):
    from repro.kernels.moe_experts import moe_expert_ffn, moe_expert_ffn_ref
    x = jax.random.normal(jax.random.PRNGKey(0), (e, c, d))
    wi = jax.random.normal(jax.random.PRNGKey(1), (e, d, 2 * f)) * 0.05
    wo = jax.random.normal(jax.random.PRNGKey(2), (e, f, d)) * 0.05
    yk = moe_expert_ffn(x, wi, wo, block_c=bc, interpret=True)
    yr = moe_expert_ffn_ref(x, wi, wo)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-5,
                               atol=1e-6)


def test_moe_kernel_path_equals_xla_path():
    from repro.configs import reduced_config
    from repro.models.moe import moe_ffn
    cfg = reduced_config("granite-moe-3b-a800m")
    key = jax.random.PRNGKey(0)
    d, e, f2 = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    p = {"router": jax.random.normal(key, (d, e)) * 0.1,
         "w_in": jax.random.normal(jax.random.PRNGKey(1), (e, d, 2 * f2)) * 0.05,
         "w_out": jax.random.normal(jax.random.PRNGKey(2), (e, f2, d)) * 0.05}
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 33, d))
    y_xla, _ = moe_ffn(p, x, cfg)
    y_k, _ = moe_ffn(p, x, cfg.with_(moe_use_kernel=True))
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_xla), atol=1e-6)


# -------------------------------------------------------------- mamba_scan

@pytest.mark.parametrize("bsz,t,din,n,bt,bd", [(2, 64, 32, 4, 32, 16),
                                               (1, 128, 64, 16, 64, 64),
                                               (2, 32, 16, 8, 32, 16)])
def test_mamba_scan_kernel_sweep(bsz, t, din, n, bt, bd):
    from repro.kernels.mamba_scan import (mamba_selective_scan,
                                          mamba_selective_scan_ref)
    k = jax.random.PRNGKey(0)
    dt = jax.nn.softplus(jax.random.normal(k, (bsz, t, din))) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(1), (bsz, t, din))
    b = jax.random.normal(jax.random.PRNGKey(2), (bsz, t, n)) * 0.5
    c = jax.random.normal(jax.random.PRNGKey(3), (bsz, t, n)) * 0.5
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(4), (din, n)) * 0.3)
    d = jnp.ones((din,))
    yk = mamba_selective_scan(dt, x, b, c, a, d, block_t=bt, block_d=bd,
                              interpret=True)
    yr = mamba_selective_scan_ref(dt, x, b, c, a, d)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-4,
                               atol=1e-5)


def test_mamba_scan_kernel_matches_model_block():
    """Kernel == the exact recurrence inside models/mamba.py (no conv/gate)."""
    from repro.kernels.mamba_scan import (mamba_selective_scan,
                                          mamba_selective_scan_ref)
    bsz, t, din, n = 2, 48, 24, 4
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(9),
                                           (bsz, t, din))) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(10), (bsz, t, din))
    b = jax.random.normal(jax.random.PRNGKey(11), (bsz, t, n)) * 0.5
    c = jax.random.normal(jax.random.PRNGKey(12), (bsz, t, n)) * 0.5
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(13), (din, n)) * 0.3)
    d = jnp.zeros((din,))
    # sequential reference computed step by step in numpy
    h = np.zeros((bsz, din, n), np.float64)
    ys = np.zeros((bsz, t, din), np.float64)
    dtn, xn, bn, cn = map(np.asarray, (dt, x, b, c))
    an = np.asarray(a)
    for tt in range(t):
        a_bar = np.exp(dtn[:, tt][..., None] * an)
        h = a_bar * h + (dtn[:, tt] * xn[:, tt])[..., None] * bn[:, tt][:, None, :]
        ys[:, tt] = (h * cn[:, tt][:, None, :]).sum(-1)
    yk = mamba_selective_scan(dt, x, b, c, a, d, block_t=16, block_d=24,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(yk), ys, rtol=1e-4, atol=1e-5)
