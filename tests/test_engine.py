"""ScoringEngine (core/engine.py, DESIGN.md §9) tests: every path is
selectable and correct through the single dispatch point, auto dispatch
follows the measured workload statistics, oversized pairs split to the
bucketed fallback, and the serving wrapper keeps its public contract while
containing no path selection of its own.
"""

import jax
import numpy as np
import pytest

from repro.core.batching import bucket_pairs
from repro.core.engine import PATHS, ScoringEngine
from repro.core.simgnn import SimGNNConfig, init_simgnn_params, pair_score
from repro.data.graphs import random_graph, search_pairs
from repro.serve.batching import simgnn_query_server

CFG = SimGNNConfig()
PARAMS = init_simgnn_params(jax.random.PRNGKey(0), CFG)


def _mixed_pairs(seed, n_pairs, max_n=64, avg_degree=None):
    rng = np.random.default_rng(seed)
    return [(random_graph(rng, int(rng.integers(5, max_n + 1)),
                          avg_degree=avg_degree),
             random_graph(rng, int(rng.integers(5, max_n + 1)),
                          avg_degree=avg_degree))
            for _ in range(n_pairs)]


def _reference_scores(params, pairs):
    out = np.zeros(len(pairs), np.float32)
    for b, (lhs, rhs, idxs) in bucket_pairs(pairs, CFG.n_node_labels,
                                            allow_oversize=True).items():
        out[idxs] = np.asarray(pair_score(params, lhs.adj, lhs.feats,
                                          lhs.mask, rhs.adj, rhs.feats,
                                          rhs.mask))
    return out


# ------------------------------------------------------------ forced paths

@pytest.mark.parametrize("path,atol", [
    ("reference", 1e-6), ("two_kernel", 2e-5), ("bucketed_mega", 2e-5),
    ("packed_dense", 1e-6), ("packed_sparse", 1e-6)])
def test_every_path_scores_through_engine(path, atol):
    pairs = _mixed_pairs(0, 12)
    engine = ScoringEngine(PARAMS, CFG, path=path)
    out = engine.score(pairs)
    np.testing.assert_allclose(out, _reference_scores(PARAMS, pairs),
                               rtol=0, atol=atol)
    assert engine.last_plan.path == path
    assert engine.last_plan.reason.startswith("forced")


def test_unknown_path_rejected():
    with pytest.raises(ValueError, match="unknown path"):
        ScoringEngine(PARAMS, CFG, path="warp-drive")


# ------------------------------------------------------------ auto dispatch

def test_auto_picks_sparse_on_aids_like_stream():
    engine = ScoringEngine(PARAMS, CFG)
    pairs = _mixed_pairs(1, 16)              # molecule-like degree ~2.1
    plan = engine.plan(pairs)
    assert plan.path == "packed_sparse"
    assert plan.stats.avg_degree <= ScoringEngine.SPARSE_MAX_DEGREE
    out = engine.score(pairs)
    np.testing.assert_allclose(out, _reference_scores(PARAMS, pairs),
                               rtol=0, atol=1e-6)
    assert engine.last_pack_stats is not None
    assert engine.last_pack_stats["edge_budget"] > 0


def test_auto_picks_dense_on_dense_stream():
    engine = ScoringEngine(PARAMS, CFG)
    pairs = _mixed_pairs(2, 8, max_n=32, avg_degree=10.0)
    plan = engine.plan(pairs)
    assert plan.stats.avg_degree > ScoringEngine.SPARSE_MAX_DEGREE
    assert plan.path == "packed_dense"
    out = engine.score(pairs)
    np.testing.assert_allclose(out, _reference_scores(PARAMS, pairs),
                               rtol=0, atol=1e-6)


def test_auto_buckets_tiny_batches():
    engine = ScoringEngine(PARAMS, CFG)
    pairs = _mixed_pairs(3, ScoringEngine.MIN_PACK_PAIRS - 1)
    plan = engine.plan(pairs)
    assert plan.path == "bucketed_mega"
    assert len(plan.fit_idx) == 0 and len(plan.over_idx) == len(pairs)


def test_auto_buckets_label_free_graphs():
    engine = ScoringEngine(PARAMS, CFG)
    pairs = _mixed_pairs(4, 6)
    pairs = [({"adj": g1["adj"]}, g2) for g1, g2 in pairs]  # drop labels
    plan = engine.plan(pairs)
    assert not plan.stats.has_labels
    assert plan.path == "bucketed_mega"
    # execution requires labels today: a clear contract error, not a
    # KeyError deep inside padding
    with pytest.raises(ValueError, match="int node labels"):
        engine.score(pairs)


def test_last_pack_stats_reset_on_bucketed_call():
    """Stats must describe the latest call: a bucketed (tiny) call after a
    packed one clears the stale packed stats."""
    engine = ScoringEngine(PARAMS, CFG)
    engine.score(_mixed_pairs(8, 12))
    assert engine.last_pack_stats is not None
    engine.score(_mixed_pairs(9, 2))         # < MIN_PACK_PAIRS -> bucketed
    assert engine.last_plan.path == "bucketed_mega"
    assert engine.last_pack_stats is None


def test_forced_paths_skip_density_measurement():
    engine = ScoringEngine(PARAMS, CFG, path="reference")
    plan = engine.plan(_mixed_pairs(10, 4))
    assert plan.stats.avg_degree == 0.0      # scan skipped
    assert plan.stats.n_pairs == 4


def test_empty_call():
    engine = ScoringEngine(PARAMS, CFG)
    out = engine.score([])
    assert out.shape == (0,)


@pytest.mark.parametrize("path", ["auto"] + list(PATHS))
def test_empty_call_contract_every_path(path):
    """score([]) returns an empty float32 vector on EVERY path — no
    executor runs, no exception, and the plan is still published."""
    engine = ScoringEngine(PARAMS, CFG, path=path)
    out = engine.score([])
    assert out.shape == (0,) and out.dtype == np.float32
    assert engine.last_plan is not None
    assert engine.last_plan.stats.n_pairs == 0
    assert len(engine.last_plan.fit_idx) == len(engine.last_plan.over_idx) \
        == 0


def test_empty_call_contract_loss_and_grad():
    """loss_and_grad([], []) returns zero loss and an all-zero grad tree
    shaped like params — an empty stream batch is a no-op update, not a
    crash."""
    engine = ScoringEngine(PARAMS, CFG)
    loss, grads = engine.loss_and_grad([], [])
    assert float(loss) == 0.0
    assert jax.tree.structure(grads) == jax.tree.structure(PARAMS)
    assert all(float(np.abs(g).max(initial=0.0)) == 0.0
               for g in jax.tree.leaves(grads))


def test_workload_stats_measured():
    engine = ScoringEngine(PARAMS, CFG)
    pairs = _mixed_pairs(5, 10)
    st = engine.workload_stats(pairs)
    nnz = sum(np.count_nonzero(g["adj"]) for p in pairs for g in p)
    nodes = sum(g["adj"].shape[0] for p in pairs for g in p)
    assert st.n_pairs == 10
    assert st.avg_degree == pytest.approx(nnz / nodes)
    assert st.max_nodes == max(g["adj"].shape[0] for p in pairs for g in p)
    assert st.has_labels


# ------------------------------------------------------- oversize fallback

def test_packed_paths_split_oversized_pairs():
    rng = np.random.default_rng(13)
    pairs = _mixed_pairs(6, 6) + [(random_graph(rng, 90),
                                   random_graph(rng, 20))]
    for path in ("packed_sparse", "packed_dense"):
        engine = ScoringEngine(PARAMS, CFG, path=path)
        plan = engine.plan(pairs)
        assert len(plan.fit_idx) == 6 and list(plan.over_idx) == [6]
        assert plan.fallback == "bucketed_mega"
        out = engine.score(pairs)
        np.testing.assert_allclose(out, _reference_scores(PARAMS, pairs),
                                   rtol=1e-4, atol=2e-5)
        assert 128 in engine.bucket_fns     # oversize bucket compiled


# ------------------------------------------------------- serving wrapper

def test_server_is_thin_wrapper_with_contract():
    pairs = _mixed_pairs(7, 12)
    score = simgnn_query_server(PARAMS, CFG, use_kernels=True)
    assert score.engine.path == "auto"
    assert score.bucket_fns is score.engine.bucket_fns
    assert score.last_pack_stats is None and score.last_plan is None
    out = score(pairs)
    np.testing.assert_allclose(out, _reference_scores(PARAMS, pairs),
                               rtol=0, atol=1e-6)
    assert score.last_plan.path == "packed_sparse"
    assert score.last_pack_stats["n_pairs"] == 12
    assert score.node_budget == score.engine.node_budget


def test_server_flag_to_path_mapping():
    assert simgnn_query_server(PARAMS, CFG).engine.path == "reference"
    assert simgnn_query_server(PARAMS, CFG,
                               use_kernels=True).engine.path == "auto"
    assert simgnn_query_server(
        PARAMS, CFG, use_kernels=True,
        packing=False).engine.path == "bucketed_mega"
    assert simgnn_query_server(
        PARAMS, CFG, path="two_kernel").engine.path == "two_kernel"


def test_server_no_direct_path_branching():
    """The refactor contract: serve/batching.py must not name or branch on
    scoring paths — that logic lives only in core/engine.py."""
    import ast
    import inspect
    import repro.serve.batching as sb
    tree = ast.parse(inspect.getsource(sb.simgnn_query_server))
    for node in ast.walk(tree):            # drop docstrings: code only
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if (node.body and isinstance(node.body[0], ast.Expr)
                    and isinstance(node.body[0].value, ast.Constant)):
                node.body = node.body[1:]
    src = ast.unparse(tree)
    for needle in ("pack_pairs", "bucket_pairs", "pair_score_packed",
                   "pair_score_sparse", "pair_score_megakernel",
                   "fits", "oversize"):
        assert needle not in src, f"path selection leaked into serve: {needle}"


def test_engine_paths_registry():
    assert set(PATHS) == {"reference", "two_kernel", "bucketed_mega",
                          "packed_dense", "packed_sparse",
                          "embedding_cache"}


def test_search_pairs_degree_knob_changes_dispatch():
    engine = ScoringEngine(PARAMS, CFG)
    sparse_stream = search_pairs(1, 8, avg_degree=2.1)
    dense_stream = search_pairs(1, 8, avg_degree=12.0)
    assert engine.plan(sparse_stream).path == "packed_sparse"
    assert engine.plan(dense_stream).path == "packed_dense"
