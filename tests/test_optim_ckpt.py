"""Optimizer math, checkpoint/restart, elastic resharding, straggler policy."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import manager as ckpt
from repro.train import optimizer as opt
from repro.train.loop import StragglerMonitor, run


def test_adamw_matches_reference_math():
    """One AdamW step against a hand-computed numpy reference."""
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]]), "b": jnp.asarray([0.1])}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]]), "b": jnp.asarray([-0.5])}
    state = opt.adamw_init(p)
    lr, b1, b2, eps, wd = 0.1, 0.9, 0.95, 1e-8, 0.1
    new_p, new_state = opt.adamw_update(g, state, p, lr=lr, b1=b1, b2=b2,
                                        eps=eps, weight_decay=wd)
    for k in p:
        gn = np.asarray(g[k], np.float64)
        m = (1 - b1) * gn
        v = (1 - b2) * gn * gn
        mh = m / (1 - b1)
        vh = v / (1 - b2)
        delta = mh / (np.sqrt(vh) + eps)
        if gn.ndim >= 2:
            delta = delta + wd * np.asarray(p[k])
        ref = np.asarray(p[k]) - lr * delta
        np.testing.assert_allclose(np.asarray(new_p[k]), ref, rtol=1e-5)
    assert int(new_state.step) == 1


def test_adamw_bf16_state_dtype():
    p = {"w": jnp.ones((4, 4))}
    state = opt.adamw_init(p, "bfloat16")
    assert state.m["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((4, 4), 0.1)}
    new_p, new_state = opt.adamw_update(g, state, p, lr=0.01)
    assert new_state.v["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(new_p["w"])))


def test_cosine_schedule_shape():
    # first update (step counter 0) already has a nonzero lr
    s = opt.cosine_schedule(jnp.asarray(0), peak_lr=1.0, warmup=10, total=100)
    assert abs(float(s) - 0.1) < 1e-6
    s_peak = opt.cosine_schedule(jnp.asarray(9), peak_lr=1.0, warmup=10,
                                 total=100)
    assert abs(float(s_peak) - 1.0) < 1e-6
    s_end = opt.cosine_schedule(jnp.asarray(99), peak_lr=1.0, warmup=10,
                                total=100, floor=0.1)
    assert abs(float(s_end) - 0.1) < 1e-6


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - np.sqrt(250)) < 1e-4
    new_norm = opt.global_norm(clipped)
    assert abs(float(new_norm) - 1.0) < 1e-5


# ------------------------------------------------------------- checkpoints

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"layer": [{"w": jax.random.normal(k, (4, 8)),
                       "b": jnp.zeros(8)}],
            "step_count": jnp.asarray(7, jnp.int32)}


def test_ckpt_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 100, t)
    restored = ckpt.restore(str(tmp_path), 100, t)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, restored)


def test_ckpt_keep_k_and_latest(tmp_path):
    t = _tree()
    for s in (10, 20, 30, 40):
        ckpt.save(str(tmp_path), s, t, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 40
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_000000030", "step_000000040"]


def test_ckpt_crash_mid_save_ignored(tmp_path):
    """A .tmp directory left by a crash must not be picked up by restart."""
    t = _tree()
    ckpt.save(str(tmp_path), 10, t)
    os.makedirs(tmp_path / "step_000000020.tmp")   # simulated torn write
    assert ckpt.latest_step(str(tmp_path)) == 10


def test_ckpt_orphan_tmp_swept(tmp_path):
    """Orphaned step_<N>.tmp directories from crashed saves are reclaimed
    on the next save AND on the latest_step() scan (DESIGN.md §13
    satellite) — they used to accumulate forever."""
    t = _tree()
    orphan = tmp_path / "step_000000005.tmp"
    os.makedirs(orphan / "nested")
    (orphan / "nested" / "arrays.0.npz").write_bytes(b"torn")
    ckpt.save(str(tmp_path), 10, t)
    assert not orphan.exists()
    assert sorted(n for n in os.listdir(tmp_path)) == ["step_000000010"]

    os.makedirs(tmp_path / "step_000000099.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 10
    assert not (tmp_path / "step_000000099.tmp").exists()
    # An in-flight save of this process is exempt from the sweep.
    live = str(tmp_path / "step_000000042.tmp")
    os.makedirs(live)
    with ckpt._ACTIVE_LOCK:
        ckpt._ACTIVE_TMPS.add(live)
    try:
        assert ckpt.sweep_orphan_tmps(str(tmp_path)) == []
        assert os.path.isdir(live)
    finally:
        with ckpt._ACTIVE_LOCK:
            ckpt._ACTIVE_TMPS.discard(live)


def test_ckpt_structure_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    bad = {"other": jnp.zeros(3)}
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 1, bad)


def test_restart_resumes_bit_exact(tmp_path):
    """Train 6 steps straight vs 3 steps + simulated crash + resume: final
    params identical (deterministic data keyed by step)."""
    def step_fn(params, opt_state, batch):
        loss = jnp.sum((params["w"] - batch) ** 2)
        g = {"w": 2 * (params["w"] - batch)}
        new_p, new_o = opt.adamw_update(g, opt_state, params, lr=0.05)
        return new_p, new_o, {"loss": loss}

    def batch_fn(step):
        return jnp.full((3,), float(step))

    p0 = {"w": jnp.zeros(3)}
    # straight run, checkpointing every 2
    pa, oa, _ = run(step_fn, p0, opt.adamw_init(p0), batch_fn, n_steps=6,
                    ckpt_dir=str(tmp_path / "a"), ckpt_every=2, resume=None,
                    log_every=100)
    # crashy run: first 3 steps, then a fresh `run` resuming from ckpt
    pb, ob, _ = run(step_fn, p0, opt.adamw_init(p0), batch_fn, n_steps=3,
                    ckpt_dir=str(tmp_path / "b"), ckpt_every=2, resume=None,
                    log_every=100)
    pb2, ob2, _ = run(step_fn, p0, opt.adamw_init(p0), batch_fn, n_steps=6,
                      ckpt_dir=str(tmp_path / "b"), ckpt_every=2,
                      resume="auto", log_every=100)
    np.testing.assert_allclose(np.asarray(pa["w"]), np.asarray(pb2["w"]),
                               rtol=1e-6)
    assert int(oa.step) == int(ob2.step) == 6


def test_straggler_monitor_flags_slow_steps():
    m = StragglerMonitor(threshold=2.0)
    assert not m.observe(0, 1.0)
    for s in range(1, 5):
        assert not m.observe(s, 1.05)
    assert m.observe(5, 5.0)            # 5x slower -> straggler
    assert len(m.flagged) == 1
    assert not m.observe(6, 1.0)        # baseline not poisoned


def test_failure_recovery_in_loop(tmp_path):
    """A step_fn that throws once mid-run: the loop restores the last
    checkpoint and converges to the same final state as a clean run."""
    boom = {"armed": True}

    def make_step(crashes):
        def step_fn(params, opt_state, batch):
            if crashes and boom["armed"] and int(opt_state.step) == 4:
                boom["armed"] = False
                raise RuntimeError("injected failure")
            g = {"w": 2 * (params["w"] - batch)}
            new_p, new_o = opt.adamw_update(g, opt_state, params, lr=0.05)
            return new_p, new_o, {"loss": jnp.sum(params["w"])}
        return step_fn

    batch_fn = lambda s: jnp.full((2,), float(s))
    p0 = {"w": jnp.zeros(2)}
    pa, oa, _ = run(make_step(False), p0, opt.adamw_init(p0), batch_fn,
                    n_steps=8, ckpt_dir=str(tmp_path / "clean"),
                    ckpt_every=2, resume=None, log_every=100)
    pb, ob, _ = run(make_step(True), p0, opt.adamw_init(p0), batch_fn,
                    n_steps=8, ckpt_dir=str(tmp_path / "crashy"),
                    ckpt_every=2, resume=None, log_every=100)
    np.testing.assert_allclose(np.asarray(pa["w"]), np.asarray(pb["w"]),
                               rtol=1e-6)
