"""Embedding cache + 1-vs-N search correctness (core/cache.py,
engine embedding path, serve/search.py — DESIGN.md §10): canonical-hash
invariance, LRU mechanics, capacity-zero bypass, bit-identical mixed
hit/miss scoring, the plan's cached/to_embed split, auto dispatch flipping
on a warm cache, and the search server's top-k contract.
"""

import jax
import numpy as np
import pytest

from repro.core.cache import EmbeddingCache, graph_fingerprint, graph_key
from repro.core.engine import ScoringEngine
from repro.core.simgnn import SimGNNConfig, init_simgnn_params
from repro.data.graphs import edit_graph, random_graph, zipf_corpus
from repro.serve.search import SimilaritySearchServer

CFG = SimGNNConfig()
PARAMS = init_simgnn_params(jax.random.PRNGKey(0), CFG)


def _graphs(seed, n, max_n=40):
    rng = np.random.default_rng(seed)
    return [random_graph(rng, int(rng.integers(5, max_n))) for _ in range(n)]


def _strip(g):
    """A fresh dict without the memoized key (forces a real re-hash)."""
    return {"adj": g["adj"].copy(), "labels": g["labels"].copy()}


# ------------------------------------------------------------- canonical key

def test_graph_key_golden_hashes_pinned():
    """Fixed graphs -> fixed digests (DESIGN.md §13): persisted indexes key
    shards by this WL hash, so ANY change to the refinement (rounds, mixing
    constants, payload layout) silently invalidates every on-disk index.
    If this test fails, either revert the hash change or bump
    `core.store.STORE_FORMAT_VERSION` so old indexes are refused loudly —
    then re-pin these goldens."""
    fixed = [
        ([[0, 1, 0], [1, 0, 1], [0, 1, 0]], [0, 1, 2],
         "755be6bf1ea052fbbda850cc93286f88"),       # 3-path, distinct labels
        ([[0, 1, 1], [1, 0, 1], [1, 1, 0]], [5, 5, 5],
         "1aea8f559ddd5effbfb28b0be1e13fbb"),       # triangle, uniform
        ([[0]], [3],
         "4930b142e39aabe76578852e6b6f7606"),       # single node, no edges
        ([[0, 1, 1, 1], [1, 0, 0, 0], [1, 0, 0, 0], [1, 0, 0, 0]],
         [2, 0, 0, 1],
         "9f968a986bc050137c2b19fe86ce6c87"),       # 4-star, mixed labels
    ]
    for adj, labels, want in fixed:
        g = {"adj": np.asarray(adj, np.float32),
             "labels": np.asarray(labels, np.int32)}
        assert graph_key(g).hex() == want


def test_graph_key_node_permutation_hits():
    rng = np.random.default_rng(0)
    for g in _graphs(1, 10):
        perm = rng.permutation(g["adj"].shape[0])
        permuted = {"adj": g["adj"][perm][:, perm],
                    "labels": g["labels"][perm]}
        assert graph_key(g) == graph_key(permuted)


def test_graph_key_distinguishes_real_differences():
    gs = _graphs(2, 30)
    assert len({graph_key(g) for g in gs}) == len(gs)
    g = _strip(gs[0])
    relabeled = _strip(g)
    relabeled["labels"][0] = (relabeled["labels"][0] + 1) % CFG.n_node_labels
    assert graph_key(g) != graph_key(relabeled)
    deedged = _strip(g)
    r, c = np.nonzero(np.triu(deedged["adj"], 1))
    deedged["adj"][r[0], c[0]] = deedged["adj"][c[0], r[0]] = 0.0
    assert graph_key(g) != graph_key(deedged)


def test_graph_key_memoized_on_dict():
    g = _strip(_graphs(3, 1)[0])
    assert "_graph_key" not in g
    k = graph_key(g)
    assert g["_graph_key"] == k
    assert graph_key(g) == k
    # edit_graph builds fresh dicts: edits never inherit a stale memo
    edited = edit_graph(np.random.default_rng(0), g, 2)
    assert "_graph_key" not in edited


# ---------------------------------------------------------------- LRU policy

def test_lru_eviction_order():
    cache = EmbeddingCache(capacity=2)
    e = {k: np.full(2, i, np.float32) for i, k in enumerate("abc")}
    cache.put(b"a", e["a"])
    cache.put(b"b", e["b"])
    assert cache.get(b"a") is e["a"]         # promotes a over b
    cache.put(b"c", e["c"])                  # evicts b, the LRU entry
    assert b"b" not in cache and b"a" in cache and b"c" in cache
    assert cache.evictions == 1
    assert cache.get(b"b") is None           # miss counted
    assert cache.stats()["size"] == 2


def test_peek_is_recency_and_stats_neutral():
    cache = EmbeddingCache(capacity=2)
    cache.put(b"a", np.zeros(1))
    cache.put(b"b", np.zeros(1))
    cache.peek(b"a")                         # must NOT promote a
    cache.put(b"c", np.zeros(1))             # evicts a (still LRU)
    assert b"a" not in cache
    assert cache.hits == 0 and cache.misses == 0


def test_capacity_zero_bypasses_storage():
    cache = EmbeddingCache(capacity=0)
    cache.put(b"a", np.zeros(1))
    assert len(cache) == 0 and cache.get(b"a") is None
    assert cache.misses == 1 and cache.evictions == 0
    with pytest.raises(ValueError, match=">= 0"):
        EmbeddingCache(capacity=-1)


def test_engine_capacity_zero_still_scores():
    pairs = [(g, edit_graph(np.random.default_rng(7), g, 2))
             for g in _graphs(7, 5)]
    ref = ScoringEngine(PARAMS, CFG, path="reference").score(pairs)
    eng = ScoringEngine(PARAMS, CFG, path="embedding_cache", cache_size=0)
    out = eng.score(pairs)
    np.testing.assert_allclose(out, ref, rtol=0, atol=1e-6)
    assert len(eng.cache) == 0               # nothing was retained


# ----------------------------------------------------- engine cache behavior

def test_mixed_hit_miss_bit_identical_to_cold_run():
    shared = _graphs(10, 4)
    fresh = _graphs(11, 4)
    pairs = list(zip(shared, fresh)) + list(zip(fresh, shared))

    warm = ScoringEngine(PARAMS, CFG, path="embedding_cache")
    warm.embed_graphs(shared)                # half the batch becomes hits
    assert len(warm.cache) == len(shared)
    s_mixed = warm.score(pairs)

    cold = ScoringEngine(PARAMS, CFG, path="embedding_cache")
    s_cold = cold.score(pairs)
    np.testing.assert_array_equal(s_mixed, s_cold)   # bit-identical


def test_plan_reports_cached_to_embed_split():
    corpus = _graphs(12, 6)
    queries = _graphs(13, 6)
    eng = ScoringEngine(PARAMS, CFG, path="embedding_cache")
    eng.embed_graphs(corpus)
    plan = eng.plan(list(zip(queries, corpus)))
    assert plan.path == "embedding_cache"
    assert len(plan.graph_keys) == 12
    # rhs graphs (positions 6..11) are resident, lhs are unique misses
    assert sorted(plan.cached_idx) == list(range(6, 12))
    assert sorted(plan.to_embed_idx) == list(range(6))
    # duplicates of one miss embed once: pair the same query everywhere
    dup = [(queries[0], c) for c in corpus]
    plan = eng.plan(dup)
    assert len(plan.to_embed_idx) == 0 or len(plan.to_embed_idx) == 1


def test_embed_graphs_dedups_within_call_and_uses_cache():
    g = _graphs(14, 1)[0]
    eng = ScoringEngine(PARAMS, CFG, path="embedding_cache")
    out = eng.embed_graphs([g, g, g])
    assert eng.cache.misses == 1             # one unique graph, one embed
    np.testing.assert_array_equal(out[0], out[1])
    eng.embed_graphs([g])
    assert eng.cache.hits >= 1


def test_auto_dispatch_flips_on_warm_cache():
    rng = np.random.default_rng(15)
    corpus = _graphs(15, 8)
    pairs = [(random_graph(rng, 20), c) for c in corpus]
    eng = ScoringEngine(PARAMS, CFG)         # auto
    assert eng.plan(pairs).path == "packed_sparse"   # cold cache: unchanged
    eng.embed_graphs(corpus)                 # warm the corpus side
    plan = eng.plan(pairs)
    assert plan.path == "embedding_cache"
    assert "resident embeddings" in plan.reason
    ref = ScoringEngine(PARAMS, CFG, path="reference").score(pairs)
    np.testing.assert_allclose(eng.score(pairs), ref, rtol=0, atol=1e-6)


def test_cache_disabled_auto_never_flips():
    corpus = _graphs(16, 8)
    eng = ScoringEngine(PARAMS, CFG, cache_size=0)
    eng.embed_graphs(corpus)
    pairs = [(corpus[0], c) for c in corpus]
    assert eng.plan(pairs).path != "embedding_cache"


# ------------------------------------------------------------- search server

def test_search_server_topk_contract():
    corpus = zipf_corpus(21, 24)
    srv = SimilaritySearchServer(PARAMS, CFG)
    srv.index(corpus)
    query = random_graph(np.random.default_rng(22), 20)
    idx, scores = srv.topk(query, k=5)
    assert len(idx) == 5 and np.all(np.diff(scores) <= 0)
    full = srv.scores(query)
    np.testing.assert_array_equal(scores, full[idx])
    assert full.argmax() == idx[0]
    ref = ScoringEngine(PARAMS, CFG, path="reference").score(
        [(query, g) for g in corpus])
    np.testing.assert_allclose(full, ref, rtol=0, atol=1e-6)
    assert srv.stats.queries == 2 and srv.stats.index_size == 24
    # corpus scoring reads the resident index matrix, not the LRU; only
    # the repeated query-side embed goes through the cache — and hits.
    assert srv.stats.as_dict()["cache_hits"] >= 1


def test_search_server_requires_index():
    srv = SimilaritySearchServer(PARAMS, CFG)
    with pytest.raises(ValueError, match="no corpus indexed"):
        srv.topk(_graphs(23, 1)[0])


def test_search_server_index_survives_lru_eviction():
    corpus = zipf_corpus(24, 8)
    srv = SimilaritySearchServer(PARAMS, CFG, cache_size=2)
    emb = srv.index(corpus)
    assert len(srv.engine.cache) == 2        # LRU kept only its capacity
    assert emb.shape == (8, CFG.gcn_dims[-1])
    idx, _ = srv.topk(random_graph(np.random.default_rng(25), 16), k=3)
    assert len(idx) == 3                     # evictions never break serving


# ------------------------------------------------------ WL-collision guard

def test_graph_fingerprint_permutation_invariant_and_memoized():
    rng = np.random.default_rng(30)
    for g in _graphs(30, 6):
        perm = rng.permutation(g["adj"].shape[0])
        permuted = {"adj": g["adj"][perm][:, perm],
                    "labels": g["labels"][perm]}
        assert graph_fingerprint(g) == graph_fingerprint(permuted)
    g = _strip(_graphs(31, 1)[0])
    assert "_graph_fp" not in g
    fp = graph_fingerprint(g)
    assert g["_graph_fp"] == fp and graph_fingerprint(g) == fp
    n, edges, _ = fp
    assert n == g["adj"].shape[0]
    assert edges == int(np.count_nonzero(g["adj"])) // 2


def test_fingerprint_distinguishes_structural_differences():
    g = _strip(_graphs(32, 1)[0])
    relabeled = _strip(g)
    relabeled["labels"][0] = (relabeled["labels"][0] + 1) % CFG.n_node_labels
    assert graph_fingerprint(g) != graph_fingerprint(relabeled)
    deedged = _strip(g)
    r, c = np.nonzero(np.triu(deedged["adj"], 1))
    deedged["adj"][r[0], c[0]] = deedged["adj"][c[0], r[0]] = 0.0
    assert graph_fingerprint(g) != graph_fingerprint(deedged)


def test_collision_guard_evicts_and_misses_on_get():
    cache = EmbeddingCache(capacity=4)
    emb = np.zeros(3, np.float32)
    cache.put(b"k", emb, fingerprint=(5, 4, b"x"))
    assert cache.get(b"k", fingerprint=(5, 4, b"x")) is emb   # match: hit
    assert cache.key_collisions == 0
    # A DIFFERENT structure hashing to the same key must never be served
    # the stored row: evict + miss so the caller re-embeds.
    assert cache.get(b"k", fingerprint=(6, 7, b"y")) is None
    assert cache.key_collisions == 1
    assert b"k" not in cache                  # entry evicted, not kept
    assert cache.misses == 1 and cache.hits == 1
    other = np.ones(3, np.float32)
    cache.put(b"k", other, fingerprint=(6, 7, b"y"))
    assert cache.get(b"k", fingerprint=(6, 7, b"y")) is other
    assert cache.stats()["key_collisions"] == 1


def test_collision_guard_counts_on_put_overwrite():
    cache = EmbeddingCache(capacity=4)
    cache.put(b"k", np.zeros(2), fingerprint=(3, 2, b"a"))
    newer = np.ones(2)
    cache.put(b"k", newer, fingerprint=(9, 9, b"b"))   # colliding overwrite
    assert cache.key_collisions == 1
    # Last writer wins under ITS fingerprint (the overwrite is the fix).
    assert cache.get(b"k", fingerprint=(9, 9, b"b")) is newer


def test_fingerprintless_calls_stay_backward_compatible():
    cache = EmbeddingCache(capacity=4)
    emb = np.zeros(2)
    cache.put(b"k", emb)                      # no fingerprint recorded
    assert cache.get(b"k") is emb             # none presented: plain hit
    assert cache.get(b"k", fingerprint=(1, 1, b"z")) is emb   # stored None
    cache.put(b"k", emb, fingerprint=(1, 1, b"z"))            # upgrades fp
    assert cache.get(b"k") is emb             # none presented again: hit
    assert cache.key_collisions == 0


def test_engine_embeds_guarded_and_collisions_in_health():
    gs = _graphs(33, 3)
    eng = ScoringEngine(PARAMS, CFG, path="embedding_cache")
    eng.embed_graphs(gs)
    k = graph_key(gs[0])
    # The engine stored gs[0] under its real fingerprint; present a graph
    # forced to the SAME key but a different structure (simulated 64-bit
    # mixing collision) and the guard must evict rather than serve.
    impostor = _strip(gs[1])
    impostor["_graph_key"] = k
    out = eng.embed_graphs([impostor])
    ref = ScoringEngine(PARAMS, CFG, path="reference").embed_graphs(
        [_strip(gs[1])])
    np.testing.assert_allclose(out, ref, rtol=0, atol=1e-6)
    health = eng.health()
    assert health["cache"]["key_collisions"] >= 1
