"""input_specs + sharding specs for every (arch x shape x mesh) cell.

`input_specs(arch, shape)` returns ShapeDtypeStruct stand-ins for every model
input — weak-type-correct, shardable, no device allocation (assignment spec
§2). `cell_shardings` pairs them with NamedShardings for the mesh.

Sharding policy (DESIGN.md §6):
  tokens/frames/embeds  : batch over (pod?, data); seq unsharded at input
  attn KV caches        : batch over dp, cache-seq over model (SP decode);
                          for long_500k (batch=1) cache-seq over (data, model)
  mamba/rwkv states     : batch over dp, inner dim over model
  params / opt state    : name-based rules in distributed/sharding.py
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.distributed.sharding import Runtime, make_runtime, param_spec, _path_str
from repro.models import lm
from repro.models.config import ModelConfig

BF16 = jnp.bfloat16
I32 = jnp.int32


def dec_len(cfg: ModelConfig, seq_len: int) -> int:
    return max(128, seq_len // cfg.dec_seq_divisor) if cfg.is_enc_dec else seq_len


def input_specs(arch: str, shape: str) -> dict:
    """ShapeDtypeStructs for the step function's *data* arguments (params and
    caches have their own spec builders below)."""
    cfg = get_config(arch)
    sh = SHAPES[shape]
    b, s = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    d = cfg.d_model

    if kind in ("train", "prefill"):
        if cfg.is_enc_dec:
            return {"frames": jax.ShapeDtypeStruct((b, s, d), BF16),
                    "tokens": jax.ShapeDtypeStruct((b, dec_len(cfg, s)), I32)}
        if cfg.frontend == "vision":
            p = cfg.frontend_len
            return {"tokens": jax.ShapeDtypeStruct((b, s - p), I32),
                    "embeds": jax.ShapeDtypeStruct((b, p, d), BF16)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), I32)}

    assert kind == "decode"
    out = {"token": jax.ShapeDtypeStruct((b, 1), I32),
           "cache_pos": jax.ShapeDtypeStruct((b,), I32)}
    if cfg.is_enc_dec:
        out["enc_out"] = jax.ShapeDtypeStruct((b, s, d), BF16)
    return out


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int):
    return jax.eval_shape(lambda: lm.init_cache(cfg, batch, cache_len))


# ------------------------------------------------------------- shardings

def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


def data_shardings(rt: Runtime, specs: dict, *, kind: str):
    """NamedShardings for the input_specs dict."""
    mesh = rt.mesh
    dp = rt.batch_axes if len(rt.batch_axes) > 1 else rt.batch_axes[0]
    out = {}
    for k, v in specs.items():
        if k == "cache_pos":
            out[k] = _ns(mesh, dp)
        elif v.ndim == 3:                       # frames / embeds [B,S,D]
            out[k] = _ns(mesh, dp, None, None)
        else:                                    # tokens [B,S] / token [B,1]
            out[k] = _ns(mesh, dp, None)
        if v.shape[0] == 1:                      # long_500k: batch unshardable
            out[k] = _ns(mesh, *((None,) * v.ndim))
    return out


def cache_shardings(rt: Runtime, cfg: ModelConfig, caches, *, batch: int):
    """Sharding tree matching init_cache structure. Leaves [G, B, ...]."""
    mesh = rt.mesh
    dp = rt.batch_axes if len(rt.batch_axes) > 1 else rt.batch_axes[0]
    seq_ax = "model" if batch > 1 else ("data", "model")
    b_ax = dp if batch > 1 else None

    def leaf(path, x):
        name = _path_str(path)
        nd = x.ndim
        if name.endswith("/k") or name.endswith("/v"):
            return _ns(mesh, None, b_ax, seq_ax, None, None)
        if name.endswith("_scale"):                # int8 KV scales [G,B,W,KV]
            return _ns(mesh, None, b_ax, seq_ax, None)
        if name.endswith("/pos"):
            return _ns(mesh, None, b_ax, seq_ax)
        if name.endswith("ssm"):                 # [G,B,Din,N]
            return _ns(mesh, None, b_ax, "model", None)
        if name.endswith("conv"):                # [G,B,K-1,Din]
            return _ns(mesh, None, b_ax, None, "model")
        if name.endswith("wkv"):                 # [G,B,H,K,V]
            return _ns(mesh, None, b_ax, "model", None, None)
        if "shift" in name:                      # [G,B,1,D]
            return _ns(mesh, None, b_ax, None, "model")
        return _ns(mesh, *((None,) * nd))

    return jax.tree_util.tree_map_with_path(leaf, caches)


def param_shardings_abstract(rt: Runtime, params_abstract):
    def leaf(path, x):
        return NamedSharding(rt.mesh, param_spec(_path_str(path), x.ndim))
    return jax.tree_util.tree_map_with_path(leaf, params_abstract)


def opt_state_shardings(rt: Runtime, params_shardings, step_sharding=None):
    """m/v mirror the param shardings; step scalar replicated."""
    from repro.train.optimizer import AdamWState
    rep = NamedSharding(rt.mesh, P())
    return AdamWState(step=rep, m=params_shardings, v=params_shardings)
