from repro.distributed.sharding import force_host_device_count

force_host_device_count(512)
# ^ MUST precede every other import that touches devices: jax locks the count
#   on first backend init. The helper is a no-op when XLA_FLAGS already names
#   a count (the in-CI smoke test runs with 8 devices instead).

import argparse        # noqa: E402
import json            # noqa: E402
import os              # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

"""Multi-pod dry-run driver (assignment spec, MULTI-POD DRY-RUN §3).

For every (architecture x input-shape x mesh) cell:
  lower the step function with ShapeDtypeStruct inputs + explicit
  in/out shardings -> compile -> record memory_analysis / cost_analysis /
  HLO collective traffic into artifacts/dryrun/<cell>.json.

`--mesh single` = (data=16, model=16) v5e-256 pod;
`--mesh multi`  = (pod=2, data=16, model=16) 512 chips.
"""

from repro.configs import SHAPES, get_config, reduced_config, shape_applicable  # noqa: E402
from repro.distributed.sharding import make_runtime  # noqa: E402
from repro.launch import specs as S  # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402
from repro.launch.mesh import make_production_mesh, make_test_mesh  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.models.init import abstract_params  # noqa: E402
from repro.serve.step import build_decode_step, build_prefill_step  # noqa: E402
from repro.train.optimizer import adamw_init  # noqa: E402
from repro.train.step import build_train_step  # noqa: E402


def _rep(mesh):
    return NamedSharding(mesh, P())


def _logits_sh(rt, batch):
    dp = rt.batch_axes if len(rt.batch_axes) > 1 else rt.batch_axes[0]
    if batch == 1:
        return NamedSharding(rt.mesh, P(None, "model"))
    return NamedSharding(rt.mesh, P(dp, "model"))


def build_lowering(arch: str, shape: str, mesh, *, reduced: bool = False,
                   overrides: dict | None = None):
    """Returns (lowered, meta) for the cell."""
    cfg = reduced_config(arch) if reduced else get_config(arch)
    if overrides:
        cfg = cfg.with_(**overrides)
    sh = dict(SHAPES[shape])
    if reduced:       # tiny shapes for the in-CI smoke path
        sh.update(seq_len=max(256, sh["seq_len"] // 128),
                  global_batch=max(4, sh["global_batch"] // 64))
    b, s, kind = sh["global_batch"], sh["seq_len"], sh["kind"]
    rt = make_runtime(mesh)
    params_abs = abstract_params(cfg)
    p_sh = S.param_shardings_abstract(rt, params_abs)

    d = cfg.d_model
    if kind == "train":
        data = _train_inputs(cfg, b, s)
        data_sh = S.data_shardings(rt, data, kind=kind)
        opt_abs = jax.eval_shape(
            lambda p: adamw_init(p, cfg.opt_state_dtype), params_abs)
        opt_sh = S.opt_state_shardings(rt, p_sh)
        step = build_train_step(cfg, rt)
        metrics_sh = {"loss": _rep(mesh), "grad_norm": _rep(mesh),
                      "lr": _rep(mesh), "step": _rep(mesh)}
        jitted = jax.jit(step,
                         in_shardings=(p_sh, opt_sh, data_sh),
                         out_shardings=(p_sh, opt_sh, metrics_sh),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_abs, opt_abs, data)
    elif kind == "prefill":
        data = S.input_specs(arch, shape) if not reduced else _prefill_inputs(cfg, b, s)
        data_sh = S.data_shardings(rt, data, kind=kind)
        caches_abs = S.cache_specs(cfg, b, _prefill_cache_len(cfg, s))
        caches_sh = S.cache_shardings(rt, cfg, caches_abs, batch=b)
        dp = rt.batch_axes if len(rt.batch_axes) > 1 else rt.batch_axes[0]
        pos_sh = NamedSharding(mesh, P(dp if b > 1 else None))
        step = build_prefill_step(cfg, rt)
        if cfg.is_enc_dec:
            enc_sh = NamedSharding(mesh, P(dp if b > 1 else None, None, None))
            out_sh = (_logits_sh(rt, b), enc_sh, caches_sh, pos_sh)
            lowered = jax.jit(step, in_shardings=(p_sh, data_sh["frames"],
                                                  data_sh["tokens"]),
                              out_shardings=out_sh).lower(
                params_abs, data["frames"], data["tokens"])
        elif cfg.frontend == "vision":
            out_sh = (_logits_sh(rt, b), caches_sh, pos_sh)
            lowered = jax.jit(step, in_shardings=(p_sh, data_sh["tokens"],
                                                  data_sh["embeds"]),
                              out_shardings=out_sh).lower(
                params_abs, data["tokens"], data["embeds"])
        else:
            out_sh = (_logits_sh(rt, b), caches_sh, pos_sh)
            lowered = jax.jit(step, in_shardings=(p_sh, data_sh["tokens"]),
                              out_shardings=out_sh).lower(
                params_abs, data["tokens"])
    else:  # decode
        data = _decode_inputs(cfg, b, s)
        data_sh = S.data_shardings(rt, data, kind=kind)
        caches_abs = S.cache_specs(cfg, b, s)
        caches_sh = S.cache_shardings(rt, cfg, caches_abs, batch=b)
        step = build_decode_step(cfg, rt)
        out_sh = (_logits_sh(rt, b), caches_sh, data_sh["cache_pos"])
        if cfg.is_enc_dec:
            jitted = jax.jit(step,
                             in_shardings=(p_sh, data_sh["token"],
                                           data_sh["enc_out"], caches_sh,
                                           data_sh["cache_pos"]),
                             out_shardings=out_sh, donate_argnums=(3,))
            lowered = jitted.lower(params_abs, data["token"], data["enc_out"],
                                   caches_abs, data["cache_pos"])
        else:
            jitted = jax.jit(step,
                             in_shardings=(p_sh, data_sh["token"], caches_sh,
                                           data_sh["cache_pos"]),
                             out_shardings=out_sh, donate_argnums=(2,))
            lowered = jitted.lower(params_abs, data["token"], caches_abs,
                                   data["cache_pos"])

    meta = dict(arch=arch, shape=shape, kind=kind, global_batch=b, seq_len=s,
                n_devices=int(mesh.devices.size),
                mesh_shape=list(mesh.devices.shape),
                mesh_axes=list(mesh.axis_names),
                params_total=cfg.param_count(),
                params_active=cfg.active_param_count())
    return lowered, meta


def _train_inputs(cfg, b, s):
    d = cfg.d_model
    if cfg.is_enc_dec:
        return {"frames": jax.ShapeDtypeStruct((b, s, d), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((b, S.dec_len(cfg, s)), jnp.int32)}
    if cfg.frontend == "vision":
        p = cfg.frontend_len
        return {"tokens": jax.ShapeDtypeStruct((b, s - p), jnp.int32),
                "embeds": jax.ShapeDtypeStruct((b, p, d), jnp.bfloat16)}
    return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}


_prefill_inputs = _train_inputs


def _decode_inputs(cfg, b, s):
    out = {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
           "cache_pos": jax.ShapeDtypeStruct((b,), jnp.int32)}
    if cfg.is_enc_dec:
        out["enc_out"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    return out


def _prefill_cache_len(cfg, s):
    return S.dec_len(cfg, s) if cfg.is_enc_dec else s


def model_flops(meta) -> float:
    """Analytic useful-FLOPs: 6*N_active*tokens (train) / 2*N_active*tokens
    (inference). The spec's 6-N-D convention is the training number; we report
    the matching convention per step kind."""
    n = meta["params_active"]
    if meta["kind"] == "train":
        return 6.0 * n * meta["global_batch"] * meta["seq_len"]
    if meta["kind"] == "prefill":
        return 2.0 * n * meta["global_batch"] * meta["seq_len"]
    return 2.0 * n * meta["global_batch"]          # decode: one token per seq


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str, *,
             reduced: bool = False, save_hlo: bool = False,
             overrides: dict | None = None, tag: str = "") -> dict:
    cfg = reduced_config(arch) if reduced else get_config(arch)
    ok, note = shape_applicable(cfg, shape)
    cell_id = f"{arch}__{shape}__{mesh_kind}" + (f"__{tag}" if tag else "")
    if not ok:
        rec = dict(arch=arch, shape=shape, mesh=mesh_kind, skipped=True,
                   note=note)
        _write(out_dir, cell_id, rec)
        print(f"[dryrun] SKIP {cell_id}: {note}")
        return rec

    if reduced:
        mesh = make_test_mesh(2, 2, multi_pod=(mesh_kind == "multi"))
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    lowered, meta = build_lowering(arch, shape, mesh, reduced=reduced,
                                   overrides=overrides)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    mem_rec = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_rec[attr] = int(v)
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    cost_rec = {k: float(v) for k, v in (cost or {}).items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "transcendentals", "bytes accessed")
                    or k.startswith("bytes accessed"))}
    hlo = compiled.as_text()
    t3 = time.time()
    hlo_rec = analyze_hlo(hlo)   # loop-corrected FLOPs/bytes/collectives

    rec = dict(meta, mesh=mesh_kind, skipped=False,
               lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
               analyze_s=round(time.time() - t3, 2),
               memory=mem_rec, cost_analysis_raw=cost_rec,
               hlo_flops=hlo_rec["dot_flops"],
               hlo_mem_bytes=hlo_rec["mem_bytes_est"],
               collectives=hlo_rec["collectives"],
               model_flops=model_flops(meta), hlo_bytes_text=len(hlo))
    _write(out_dir, cell_id, rec)
    print(f"[dryrun] OK {cell_id}: compile={rec['compile_s']}s "
          f"hlo_flops={rec['hlo_flops']:.3e} "
          f"model_flops={rec['model_flops']:.3e} "
          f"coll_wire_GB={hlo_rec['collectives']['bytes_wire']/1e9:.2f}")
    if save_hlo:
        with open(os.path.join(out_dir, cell_id + ".hlo.txt"), "w") as f:
            f.write(hlo)
    return rec


def _write(out_dir: str, cell_id: str, rec: dict):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell_id + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[None, *SHAPES])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny configs + 8-device test mesh (CI smoke)")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override field=value (int/float/bool), e.g. "
                         "--set mamba_scan_unroll=8 (perf-iteration variants)")
    ap.add_argument("--tag", default="",
                    help="artifact suffix for variant runs")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            overrides[k] = int(v)
        except ValueError:
            try:
                overrides[k] = float(v)
            except ValueError:
                if v in ("true", "True", "false", "False"):
                    overrides[k] = v in ("true", "True")
                else:
                    overrides[k] = v          # plain string (e.g. int8)

    from repro.configs import ARCH_IDS
    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                cell = f"{arch}__{shape}__{mk}"
                path = os.path.join(args.out, cell + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[dryrun] cached {cell}")
                    continue
                try:
                    run_cell(arch, shape, mk, args.out, reduced=args.reduced,
                             save_hlo=args.save_hlo, overrides=overrides,
                             tag=args.tag)
                except Exception as e:  # record and continue the sweep
                    failures.append((cell, repr(e)))
                    _write(args.out, cell, dict(
                        arch=arch, shape=shape, mesh=mk, skipped=False,
                        error=repr(e), trace=traceback.format_exc()[-4000:]))
                    print(f"[dryrun] FAIL {cell}: {e}")
    if failures:
        print(f"[dryrun] {len(failures)} failures:")
        for c, e in failures:
            print("  ", c, e)
        raise SystemExit(1)
    print("[dryrun] all cells OK")


if __name__ == "__main__":
    main()
