"""End-to-end training launcher.

Two drivers:
  * `--model simgnn` (default): trains the paper's SimGNN on the synthetic
    AIDS-like pair stream — the (b) end-to-end example required by the
    assignment (100M-class model for a few hundred steps works on CPU).
  * `--model <arch-id>`: trains an assigned LM architecture (reduced config
    on CPU with --reduced; full config on a real fleet with --mesh).

Both paths share train/loop.py: checkpoint/restart, straggler monitoring,
failure retry. `--simulate-failure N` kills the process at step N to
exercise the restart path (tests do this in-process).
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np


def train_simgnn(args):
    from repro.configs.simgnn_aids import CONFIG as scfg
    from repro.core.engine import ScoringEngine
    from repro.core.simgnn import init_simgnn_params
    from repro.data.graphs import pair_stream
    from repro.distributed.sharding import (force_host_device_count,
                                            tile_runtime)
    from repro.train.optimizer import adamw_init
    from repro.train.step import build_simgnn_train_step
    from repro.train import loop

    runtime = None
    if args.devices > 1:
        # Data-parallel packed training (DESIGN.md §16): the engine shards
        # each batch's tile axis over a 1-D mesh and psums the chunk-scan
        # loss/grads. On CPU-only hosts the mesh is simulated (the opt-in
        # host-platform XLA flag, a no-op on real accelerators) so the
        # flag is exercisable anywhere. Must run before first backend use.
        force_host_device_count(args.devices)
        runtime = tile_runtime(args.devices)
    params = init_simgnn_params(jax.random.PRNGKey(args.seed), scfg)
    opt_state = adamw_init(params)
    # The engine dispatches the forward AND backward passes (DESIGN.md §11):
    # it measures each batch and picks the packed-sparse / packed-dense /
    # reference executor; the step itself contains no path selection.
    engine = ScoringEngine(params, scfg, runtime=runtime)
    step_fn = build_simgnn_train_step(engine, peak_lr=args.lr)
    stream = pair_stream(args.seed, args.batch, max_nodes=scfg.max_nodes)
    batches = {}

    def batch_fn(step):            # deterministic per step for restartability
        while step not in batches:
            batches[len(batches)] = next(stream)
        return batches[step]

    def on_metrics(step, rec):
        print(f"step {step:5d} loss {rec['loss']:.5f} "
              f"gnorm {rec['grad_norm']:.3f} {rec['sec_per_step']*1e3:.0f}ms")
        if args.simulate_failure and step == args.simulate_failure:
            print("[train] simulated failure!")
            os._exit(42)

    def on_resume(step, skipped):
        # Land the verified-restore outcome on the engine's counters so
        # `engine.health()` reports the resume story next to the breakers
        # (DESIGN.md §13): how many corrupt checkpoints the walk-back
        # skipped, and whether a resume happened at all.
        if step is not None:
            engine.counters["ckpt_resumes"] += 1
        engine.counters["ckpt_walkback_skipped"] += len(skipped)

    params, opt_state, hist = loop.run(
        step_fn, params, opt_state, batch_fn, n_steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        resume=args.resume, on_metrics=on_metrics, on_resume=on_resume)
    if engine.counters.get("train_skipped_steps"):
        print(f"[train] skipped {engine.counters['train_skipped_steps']} "
              "non-finite steps")
    if engine.counters.get("ckpt_walkback_skipped"):
        print(f"[train] resume walked back past "
              f"{engine.counters['ckpt_walkback_skipped']} corrupt "
              "checkpoint(s)")
    print(f"[train] final loss {hist[-1]['loss']:.5f}")
    return hist


def train_lm(args):
    from repro.configs import get_config, reduced_config
    from repro.data.tokens import batch_for_step
    from repro.distributed.sharding import make_runtime
    from repro.models.init import init_params
    from repro.train.optimizer import adamw_init
    from repro.train.step import build_train_step
    from repro.train import loop
    from repro.launch.mesh import make_production_mesh

    cfg = reduced_config(args.model) if args.reduced else get_config(args.model)
    mesh = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    rt = make_runtime(mesh)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    opt_state = adamw_init(params, cfg.opt_state_dtype)
    step_fn = jax.jit(build_train_step(cfg, rt, peak_lr=args.lr,
                                       compress_grads=args.compress_grads),
                      donate_argnums=(0, 1))

    def batch_fn(step):
        b = batch_for_step(cfg, step, global_batch=args.batch,
                           seq_len=args.seq_len)
        return {k: jnp.asarray(v) for k, v in b.items()}

    def on_metrics(step, rec):
        print(f"step {step:5d} loss {rec['loss']:.4f} "
              f"gnorm {rec['grad_norm']:.2f} lr {rec['lr']:.2e} "
              f"{rec['sec_per_step']*1e3:.0f}ms")

    params, opt_state, hist = loop.run(
        step_fn, params, opt_state, batch_fn, n_steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        resume=args.resume, on_metrics=on_metrics)
    print(f"[train] final loss {hist[-1]['loss']:.4f}")
    return hist


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="simgnn")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    # "auto" restores the latest complete checkpoint in --ckpt-dir and
    # replays the deterministic data stream from there (DESIGN.md §6/§12);
    # "none" always starts from step 0 (fresh run into a reused directory).
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="none", choices=["none", "single", "multi"])
    ap.add_argument("--compress-grads", action="store_true")
    # simgnn only: shard packed training over N mesh devices (§16). CPU
    # hosts simulate the mesh, so --devices 8 works on a laptop.
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--simulate-failure", type=int, default=0)
    args = ap.parse_args(argv)
    if args.model == "simgnn":
        return train_simgnn(args)
    return train_lm(args)


if __name__ == "__main__":
    main()
