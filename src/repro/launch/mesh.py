"""Production mesh construction (assignment spec, MULTI-POD DRY-RUN §1).

A function, not a module-level constant, so importing this module never
touches jax device state. Axis roles are documented in
distributed/sharding.py; hardware constants for the roofline live in
benchmarks/roofline.py.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2, *,
                   multi_pod: bool = False):
    """Small mesh for in-CI dry-run smoke tests (8 host devices)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
