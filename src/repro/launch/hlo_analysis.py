"""Post-compile HLO analysis: loop-corrected FLOPs, HBM traffic estimate, and
collective-traffic accounting.

Why this exists (EXPERIMENTS.md §Dry-run caveats):
  * `compiled.cost_analysis()` counts each `while` body ONCE — verified
    empirically (flops identical for 2/4/8-layer scanned models). Every layer
    stack here is a lax.scan, so raw cost_analysis under-counts by ~n_groups.
  * collective bytes are not in cost_analysis at all.

So we parse `compiled.as_text()` (optimized HLO):
  1. split into computations; build a per-computation symbol table
     (every op line declares its result type, so operand types resolve by
     name lookup);
  2. count dot FLOPs exactly (2 x result-elements x contracted-dims), and
     fusion-boundary bytes as an HBM-traffic estimate;
  3. build the call graph; `while` bodies multiply by the trip count parsed
     from the loop condition's compare constant; fusion-internal computations
     (calls= / reduce to_apply) are excluded from memory accounting;
  4. collectives: operand/result/wire bytes from result type + replica-group
     factor (ring algorithm estimates).
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")

def _dims(type_str: str) -> tuple[int, list[int]]:
    """(bytes_per_elem, dims) for the first array shape in a type string."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0, []
    dt, dims = m.group(1), m.group(2)
    d = [int(x) for x in dims.split(",")] if dims else []
    return _DTYPE_BYTES[dt], d


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return 2


_OP_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^()]*\)|[\w\[\],{}\/]+))\s+"
    r"([\w\-]+)\(([^\n]*)$")


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$",
                     stripped)
        if m and not stripped.startswith("ROOT") and "=" not in \
                stripped.split("(", 1)[0]:
            cur = m.group(1)
            comps[cur] = []
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is not None and stripped:
            comps[cur].append(stripped)
    return comps


def _entry_name(hlo: str) -> str | None:
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    return m.group(1) if m else None


_MATERIAL_OPS = ("fusion", "dot", "convolution", "copy", "concatenate",
                 "reduce", "reduce-window", "sort", "gather", "slice",
                 "dynamic-slice", "dynamic-update-slice", "scatter",
                 "select-and-scatter", "transpose", "pad", "cholesky",
                 "triangular-solve")


class _Comp:
    __slots__ = ("name", "symbols", "dot_flops", "mem_records", "coll",
                 "control_edges", "fusion_edges", "coll_counts")

    def __init__(self, name):
        self.name = name
        self.symbols: dict[str, str] = {}        # op name -> result type str
        self.dot_flops = 0
        self.mem_records: list[tuple[int, int, bool]] = []  # (bytes, lead, material)
        self.coll = [0, 0, 0]                     # operand, result, wire
        self.coll_counts: dict[str, int] = defaultdict(int)
        self.control_edges: list[tuple[str, int]] = []   # (callee, trip)
        self.fusion_edges: list[str] = []


def _parse_comp(name: str, lines: list[str]) -> _Comp:
    c = _Comp(name)
    # pass 1: symbol table
    parsed = []
    for ln in lines:
        m = _OP_RE.match(ln)
        if not m:
            continue
        op_name, rtype, opcode, rest = m.groups()
        c.symbols[op_name] = rtype
        parsed.append((op_name, rtype, opcode, rest, ln))
    # pass 2: semantics
    for op_name, rtype, opcode, rest, ln in parsed:
        operands = re.findall(r"%([\w\.\-]+)", rest.split(")", 1)[0])

        if opcode == "dot":
            _, rdims = _dims(rtype)
            lhs_t = c.symbols.get(operands[0], "") if operands else ""
            _, ldims = _dims(lhs_t)
            cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ln)
            contracted = 1
            if cm and ldims:
                for d in cm.group(1).split(","):
                    if d:
                        contracted *= ldims[int(d)]
            relems = 1
            for d in rdims:
                relems *= d
            c.dot_flops += 2 * relems * contracted
        elif opcode == "convolution":
            _, rdims = _dims(rtype)
            kern_t = c.symbols.get(operands[1], "") if len(operands) > 1 else ""
            _, kdims = _dims(kern_t)
            relems = 1
            for d in rdims:
                relems *= d
            kelems = 1
            for d in kdims:
                kelems *= d
            # 2 * out_elems * (kernel_elems / out_channels)
            out_ch = rdims[-1] if rdims else 1
            c.dot_flops += 2 * relems * max(1, kelems // max(1, out_ch))

        base = opcode
        for suf in ("-start", "-done"):
            if base.endswith(suf):
                base = base[: -len(suf)]
        if base in _COLLECTIVES and not opcode.endswith("-done"):
            rb = _shape_bytes(rtype)
            g = _group_size(ln)
            if base == "all-gather":
                ob, wire = rb // max(g, 1), rb * (g - 1) // max(g, 1)
            elif base == "reduce-scatter":
                ob = rb * g
                wire = ob * (g - 1) // max(g, 1)
            elif base == "all-reduce":
                ob, wire = rb, 2 * rb * (g - 1) // max(g, 1)
            else:
                ob, wire = rb, rb * (g - 1) // max(g, 1)
            c.coll[0] += ob
            c.coll[1] += rb
            c.coll[2] += wire
            c.coll_counts[base] += 1

        if opcode == "while":
            wm = re.search(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)", ln)
            if wm:
                c.control_edges.append(("COND:" + wm.group(1),
                                        "BODY:" + wm.group(2)))
        elif opcode == "conditional":
            for cm2 in re.finditer(r"(?:branch_computations=\{|true_computation=|false_computation=)%?([\w\.\-{}, %]+)", ln):
                for callee in re.findall(r"%?([\w\.\-]+)", cm2.group(1)):
                    c.control_edges.append((None, "CALL:" + callee))
        elif opcode == "call":
            cm2 = re.search(r"to_apply=%?([\w\.\-]+)", ln)
            if cm2:
                c.control_edges.append((None, "CALL:" + cm2.group(1)))
        elif opcode == "fusion":
            cm2 = re.search(r"calls=%?([\w\.\-]+)", ln)
            if cm2:
                c.fusion_edges.append(cm2.group(1))
        elif opcode in ("reduce", "reduce-window", "scatter", "sort", "map",
                        "select-and-scatter", "all-reduce", "reduce-scatter"):
            cm2 = re.search(r"to_apply=%?([\w\.\-]+)", ln)
            if cm2:
                c.fusion_edges.append(cm2.group(1))

        # HBM-traffic model: every materialized intermediate is written once
        # and read ~once -> 2 x result bytes per executed op. This avoids the
        # two failure modes measured on earlier estimators (EXPERIMENTS.md
        # §Dry-run caveats): (a) billing the full operand of a dynamic-slice
        # inside a T=4096 scan (~1000x inflation on Jamba's recurrence);
        # (b) multi-counting the same loop-carried buffer as an operand of
        # many fusions (~15x inflation on granite). Exceptions: in-place
        # update ops bill the update region, pure aliasing ops bill nothing.
        if opcode in ("dynamic-update-slice", "scatter", "select-and-scatter"):
            upd_idx = 2 if opcode == "scatter" else 1
            upd = (c.symbols.get(operands[upd_idx], "")
                   if len(operands) > upd_idx else "")
            c.mem_records.append(
                (2 * (_shape_bytes(upd) or _shape_bytes(rtype) // 4), 0, True))
        elif opcode not in ("parameter", "tuple", "get-tuple-element",
                            "bitcast", "constant", "while", "conditional",
                            "call", "iota", "after-all", "reshape",
                            "partition-id", "replica-id"):
            _, rdims = _dims(rtype)
            lead = rdims[0] if rdims else 0
            # standalone elementwise ops (convert/add/multiply/...) would
            # fuse into neighbours on TPU: they count toward the upper
            # bound but not the fusion-optimistic lower bound.
            c.mem_records.append((2 * _shape_bytes(rtype), lead,
                                  opcode in _MATERIAL_OPS))
    return c


def analyze_hlo(hlo: str) -> dict:
    comps_raw = _split_computations(hlo)
    comps = {n: _parse_comp(n, ls) for n, ls in comps_raw.items()}
    entry = _entry_name(hlo)

    # trip counts: constant compared in the condition computation
    def trip_of(cond_name: str) -> int:
        lines = comps_raw.get(cond_name, [])
        best = 1
        for ln in lines:
            for m in re.finditer(r"constant\((\d+)\)", ln):
                best = max(best, int(m.group(1)))
            # bound may be folded into a called compare fusion
            cm = re.search(r"calls=%?([\w\.\-]+)", ln)
            if cm:
                for ln2 in comps_raw.get(cm.group(1), []):
                    for m in re.finditer(r"constant\((\d+)\)", ln2):
                        best = max(best, int(m.group(1)))
        return best

    # propagate execution multipliers through control edges only; remember
    # each while body's own trip count for scan-accumulator detection
    mults: dict[str, int] = defaultdict(int)
    own_trip: dict[str, int] = {}
    fusion_reached: dict[str, int] = defaultdict(int)
    if entry:
        mults[entry] = 1
    work = [entry] if entry else []
    for _ in range(10_000):
        if not work:
            break
        cur = work.pop()
        comp = comps.get(cur)
        if comp is None:
            continue
        for cond, body in comp.control_edges:
            if body.startswith("BODY:"):
                tc = trip_of(cond[5:]) if cond else 1
                callee = body[5:]
                own_trip[callee] = max(own_trip.get(callee, 1), tc)
            else:
                tc = 1
                callee = body[5:]
            before = mults[callee]
            mults[callee] += mults[cur] * tc
            if mults[callee] != before:
                work.append(callee)
        for callee in comp.fusion_edges:
            fusion_reached[callee] += mults[cur]

    flops = 0
    mem = 0
    mem_lb = 0
    coll = [0, 0, 0]
    counts: dict[str, int] = defaultdict(int)
    static_counts: dict[str, int] = defaultdict(int)
    for name, comp in comps.items():
        mult = mults.get(name, 0)
        if mult == 0 and name not in fusion_reached and (
                comp.dot_flops or any(comp.coll)):
            mult = 1          # e.g. entry detection failure: count once
        if name in fusion_reached and mults.get(name, 0) == 0:
            # fusion-internal computation: dots still count (scaled by the
            # caller's multiplier), memory does not (inside the fusion)
            fmult = fusion_reached[name]
            flops += comp.dot_flops * fmult
            continue
        flops += comp.dot_flops * mult
        # scan-accumulator heuristic: a result whose leading dim equals the
        # enclosing loop's trip count is an in-place per-step update of a
        # [T, ...] buffer (the scan transpose/ys pattern) — bill the
        # per-step slice, not the whole buffer every iteration (measured
        # ~1000x inflation on the Jamba recurrence otherwise).
        trip = own_trip.get(name, 1)
        for bytes_, lead, material in comp.mem_records:
            eff = bytes_ // trip if (trip > 1 and lead == trip) else bytes_
            mem += mult * eff
            if material:
                mem_lb += mult * eff
        coll[0] += comp.coll[0] * mult
        coll[1] += comp.coll[1] * mult
        coll[2] += comp.coll[2] * mult
        for op, cnt in comp.coll_counts.items():
            counts[op] += cnt * mult
            static_counts[op] += cnt
    return {
        "dot_flops": int(flops),
        "mem_bytes_est": int(mem),
        "mem_bytes_fused_lb": int(mem_lb),
        "collectives": {
            "bytes_operand": int(coll[0]),
            "bytes_result": int(coll[1]),
            "bytes_wire": int(coll[2]),
            "counts": dict(counts),
            "static_counts": dict(static_counts),
        },
    }


def collective_stats(hlo: str) -> dict:
    """Back-compat wrapper returning just the collective block."""
    return analyze_hlo(hlo)["collectives"]


def while_trip_counts(hlo: str) -> dict[str, int]:
    comps_raw = _split_computations(hlo)
    out = {}
    for name, lines in comps_raw.items():
        for ln in lines:
            wm = re.search(r"while\(.*?condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)", ln)
            if wm:
                cond = wm.group(1)
                best = 1
                for ln2 in comps_raw.get(cond, []):
                    for m in re.finditer(r"constant\((\d+)\)", ln2):
                        best = max(best, int(m.group(1)))
                out[wm.group(2)] = best
    return out
