"""Elastic resharding: move a checkpoint from mesh A to mesh B.

Checkpoints store full (unsharded) leaves per host-shard file; restoring onto
a different mesh is therefore just `device_put` with the target mesh's
NamedShardings — the elastic-scaling path when the fleet grows/shrinks
between restarts (DESIGN.md §6). `reshard_live` re-lays-out an in-memory
tree without round-tripping disk (for in-job elasticity where the runtime
re-forms the mesh after losing a slice).
"""

from __future__ import annotations

import jax

from repro.ckpt import manager


def reshard_live(tree, shardings):
    """Re-lay-out an in-memory pytree onto new NamedShardings."""
    return jax.tree.map(
        lambda x, s: jax.device_put(jax.device_get(x), s)
        if s is not None else x, tree, shardings)


def restore_on_mesh(directory: str, step: int, like, shardings):
    """Restore a checkpoint saved on any mesh onto `shardings` (target mesh)."""
    return manager.restore(directory, step, like, shardings=shardings)
