"""Checkpointing: sharded, atomic, keep-k, async, integrity-verified — the
fault-tolerance substrate (DESIGN.md §6, §13).

Layout per step:
    <dir>/step_<N>.tmp/            (written first)
        arrays.npz                 flat leaves (per-host shards on a fleet)
        manifest.msgpack           tree structure + dtypes + shapes +
                                   format version + per-file checksums
                                   (written LAST: it is the commit record)
    <dir>/step_<N>/                (atomic rename when complete)

Restart contract: `latest_step()` ignores .tmp directories (and sweeps
orphaned ones left by crashed saves), so a job killed mid-save resumes from
the previous complete checkpoint. Since DESIGN.md §13 "complete" also means
*valid*: every durable write goes through `core.store.atomic_write_bytes`
(tmp+fsync+rename, and the fault seam for chaos tests), the manifest
records a format version and a blake2b checksum per arrays file, and
`restore()` verifies them before deserializing — `latest_valid_step()`
walks the keep-k chain newest-to-oldest past torn/bit-flipped/missing
checkpoints instead of crashing on (or worse, loading) garbage.

On a multi-host fleet each host writes its addressable shards
(`arrays.<process_index>.npz`) and process 0 writes the manifest; this
container is single-process so there is exactly one shard file, but the
layout and restore path are the multi-host ones. Elastic mesh changes are
handled at restore time by `ckpt/reshard.py` (arrays are saved unsharded
per-leaf here and re-laid-out onto the target mesh's NamedShardings).
"""

from __future__ import annotations

import io
import os
import re
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.core.store import StoreError, atomic_write_bytes, checksum

#: Bump when the manifest schema or arrays encoding changes; restore
#: refuses other versions (the §13 stale-manifest contract).
CKPT_FORMAT_VERSION = 1

#: tmp directories of saves currently in flight IN THIS PROCESS — the
#: orphan sweep skips them so `latest_step()` racing an async save never
#: deletes the save out from under its own writer thread. Crashed saves
#: (a fresh process) have no entry here and get swept.
_ACTIVE_TMPS: set[str] = set()
_ACTIVE_LOCK = threading.Lock()


class CheckpointCorrupt(StoreError):
    """A checkpoint failed integrity verification; `.step` and `.problems`
    carry the structured diagnosis (the §13 never-load-garbage contract)."""

    def __init__(self, step: int, problems: list[str]):
        super().__init__(f"checkpoint step {step} failed verification: "
                         + "; ".join(problems))
        self.step = step
        self.problems = list(problems)


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(_k(p) for p in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def _k(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def sweep_orphan_tmps(directory: str) -> list[str]:
    """Remove `step_<N>.tmp` directories left by crashed saves; returns the
    names removed. Called from `save()` and `latest_step()` so orphans
    never accumulate (DESIGN.md §13 satellite). In-flight saves of THIS
    process (`_ACTIVE_TMPS`) are exempt."""
    if not os.path.isdir(directory):
        return []
    removed = []
    with _ACTIVE_LOCK:
        active = set(_ACTIVE_TMPS)
    for name in os.listdir(directory):
        if not re.fullmatch(r"step_\d+\.tmp", name):
            continue
        path = os.path.join(directory, name)
        if path in active:
            continue
        shutil.rmtree(path, ignore_errors=True)
        removed.append(name)
    return removed


def save(directory: str, step: int, tree: Any, *, keep: int = 3,
         process_index: int = 0, blocking: bool = True) -> str:
    """Write checkpoint for `step`; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    sweep_orphan_tmps(directory)
    tmp = os.path.join(directory, f"step_{step:09d}.tmp")
    final = os.path.join(directory, f"step_{step:09d}")
    with _ACTIVE_LOCK:
        _ACTIVE_TMPS.add(tmp)
    try:
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        keys, vals, _ = _flatten_with_paths(tree)
        host_vals = [np.asarray(v) for v in vals]      # device -> host
        arrays_name = f"arrays.{process_index}.npz"
        buf = io.BytesIO()
        np.savez(buf, **{str(i): v for i, v in enumerate(host_vals)})
        arrays_bytes = buf.getvalue()
        manifest = {
            "format_version": CKPT_FORMAT_VERSION,
            "keys": keys,
            "dtypes": [str(v.dtype) for v in host_vals],
            "shapes": [list(v.shape) for v in host_vals],
            "step": step,
            "checksums": {arrays_name: checksum(arrays_bytes)},
        }
        # Arrays first, manifest LAST: the manifest is the commit record —
        # verification treats "manifest present but an arrays file torn"
        # as corruption, and a crash before the manifest leaves a tmp dir
        # the sweep reclaims.
        atomic_write_bytes(os.path.join(tmp, arrays_name), arrays_bytes,
                           site="ckpt:arrays")
        atomic_write_bytes(os.path.join(tmp, "manifest.msgpack"),
                           msgpack.packb(manifest), site="ckpt:manifest")
        if os.path.exists(final):                      # re-save of same step
            shutil.rmtree(final)
        os.rename(tmp, final)                          # atomic commit
    finally:
        with _ACTIVE_LOCK:
            _ACTIVE_TMPS.discard(tmp)
    _gc(directory, keep)
    return final


def save_async(directory: str, step: int, tree: Any, *, keep: int = 3):
    """Fire-and-forget save on a worker thread; the tree is snapshotted to
    host memory synchronously (cheap vs the write) so training can proceed."""
    keys, vals, _ = _flatten_with_paths(tree)
    host = [np.asarray(v) for v in vals]               # snapshot now

    def _work():
        snap = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(
                jax.tree.map(lambda _: 0, tree)), host)
        save(directory, step, snap, keep=keep)

    t = threading.Thread(target=_work, daemon=True)
    t.start()
    return t


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    sweep_orphan_tmps(directory)
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name,
                                             "manifest.msgpack")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def _read_manifest(path: str) -> dict:
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        return msgpack.unpackb(f.read())


def verify_step(directory: str, step: int) -> list[str]:
    """Integrity report for one checkpoint — empty list means valid.

    Checks, in order of how little can be trusted when they fail: manifest
    present and unpackable, format version supported, every checksummed
    arrays file present with matching size and blake2b. Content problems
    (wrong tree structure for a given `like`) are restore()'s job — they
    depend on the caller, not the bytes.
    """
    path = os.path.join(directory, f"step_{step:09d}")
    if not os.path.isdir(path):
        return [f"missing checkpoint directory {path}"]
    try:
        manifest = _read_manifest(path)
    except FileNotFoundError:
        return ["manifest missing"]
    except Exception as exc:                           # torn/garbled msgpack
        return [f"manifest unreadable: {exc!r}"]
    version = manifest.get("format_version")
    if version != CKPT_FORMAT_VERSION:
        return [f"unsupported format_version {version!r} "
                f"(expected {CKPT_FORMAT_VERSION})"]
    problems = []
    checksums = manifest.get("checksums", {})
    if not checksums:
        problems.append("manifest carries no checksums")
    for name, want in checksums.items():
        fpath = os.path.join(path, name)
        if not os.path.exists(fpath):
            problems.append(f"{name} missing")
            continue
        with open(fpath, "rb") as f:
            got = checksum(f.read())
        if got != want:
            problems.append(f"{name} checksum mismatch "
                            f"(manifest {want[:8]}.., file {got[:8]}..)")
    return problems


def valid_steps(directory: str) -> tuple[list[int], list[tuple[int, list]]]:
    """All complete steps split into (valid, [(step, problems), ...]),
    both newest-first."""
    steps = []
    if os.path.isdir(directory):
        sweep_orphan_tmps(directory)
        for name in os.listdir(directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                steps.append(int(m.group(1)))
    good, bad = [], []
    for s in sorted(steps, reverse=True):
        problems = verify_step(directory, s)
        (good.append(s) if not problems else bad.append((s, problems)))
    return good, bad


def latest_valid_step(directory: str
                      ) -> tuple[int | None, list[tuple[int, list]]]:
    """Newest checkpoint that passes verification, walking the keep-k
    chain back past corrupt ones (DESIGN.md §13 recovery ladder). Returns
    (step or None, skipped) where skipped lists every NEWER checkpoint
    that failed, with its problems — callers surface these as counters."""
    good, bad = valid_steps(directory)
    best = good[0] if good else None
    skipped = [(s, p) for s, p in bad if best is None or s > best]
    return best, skipped


def restore(directory: str, step: int, like: Any, *,
            shardings: Any = None, verify: bool = True) -> Any:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). If `shardings` is given (pytree of NamedSharding),
    leaves are placed onto devices with jax.device_put — this is also the
    elastic-resharding entry point (save on mesh A, restore on mesh B).

    `verify=True` (default) checks format version + checksums first and
    raises `CheckpointCorrupt` instead of deserializing damaged bytes —
    torn npz archives can otherwise yield shape errors deep inside numpy
    or, worse, silently truncated leaves.
    """
    if verify:
        problems = verify_step(directory, step)
        if problems:
            raise CheckpointCorrupt(step, problems)
    path = os.path.join(directory, f"step_{step:09d}")
    manifest = _read_manifest(path)
    arrays = {}
    for name in sorted(os.listdir(path)):
        if name.startswith("arrays.") and name.endswith(".npz"):
            with np.load(os.path.join(path, name)) as z:
                for k in z.files:
                    arrays[int(k)] = z[k]

    keys, _, treedef = _flatten_with_paths(like)
    if keys != manifest["keys"]:
        missing = set(manifest["keys"]) ^ set(keys)
        raise ValueError(f"checkpoint/model structure mismatch: {sorted(missing)[:5]} ...")
    leaves = [arrays[i] for i in range(len(keys))]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else jnp.asarray(x),
            tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree


def _gc(directory: str, keep: int):
    steps = sorted(
        int(m.group(1)) for name in os.listdir(directory)
        if (m := re.fullmatch(r"step_(\d+)", name)))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)
