"""Checkpointing: sharded, atomic, keep-k, async — the fault-tolerance
substrate (DESIGN.md §6).

Layout per step:
    <dir>/step_<N>.tmp/            (written first)
        manifest.msgpack           tree structure + dtypes + shapes + mesh
        arrays.npz                 flat leaves (per-host shards on a fleet)
    <dir>/step_<N>/                (atomic rename when complete)

Restart contract: `latest_step()` ignores .tmp directories, so a job killed
mid-save resumes from the previous complete checkpoint — tested in
tests/test_ckpt.py by simulating a crash between write and rename.

On a multi-host fleet each host writes its addressable shards
(`arrays.<process_index>.npz`) and process 0 writes the manifest; this
container is single-process so there is exactly one shard file, but the
layout and restore path are the multi-host ones. Elastic mesh changes are
handled at restore time by `ckpt/reshard.py` (arrays are saved unsharded
per-leaf here and re-laid-out onto the target mesh's NamedShardings).
"""

from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(_k(p) for p in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def _k(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(directory: str, step: int, tree: Any, *, keep: int = 3,
         process_index: int = 0, blocking: bool = True) -> str:
    """Write checkpoint for `step`; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"step_{step:09d}.tmp")
    final = os.path.join(directory, f"step_{step:09d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    keys, vals, _ = _flatten_with_paths(tree)
    host_vals = [np.asarray(v) for v in vals]          # device -> host
    manifest = {
        "keys": keys,
        "dtypes": [str(v.dtype) for v in host_vals],
        "shapes": [list(v.shape) for v in host_vals],
        "step": step,
    }
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    np.savez(os.path.join(tmp, f"arrays.{process_index}.npz"),
             **{str(i): v for i, v in enumerate(host_vals)})
    if os.path.exists(final):                          # re-save of same step
        shutil.rmtree(final)
    os.rename(tmp, final)                              # atomic commit
    _gc(directory, keep)
    return final


def save_async(directory: str, step: int, tree: Any, *, keep: int = 3):
    """Fire-and-forget save on a worker thread; the tree is snapshotted to
    host memory synchronously (cheap vs the write) so training can proceed."""
    keys, vals, _ = _flatten_with_paths(tree)
    host = [np.asarray(v) for v in vals]               # snapshot now

    def _work():
        snap = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(
                jax.tree.map(lambda _: 0, tree)), host)
        save(directory, step, snap, keep=keep)

    t = threading.Thread(target=_work, daemon=True)
    t.start()
    return t


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name,
                                             "manifest.msgpack")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(directory: str, step: int, like: Any, *,
            shardings: Any = None) -> Any:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). If `shardings` is given (pytree of NamedSharding),
    leaves are placed onto devices with jax.device_put — this is also the
    elastic-resharding entry point (save on mesh A, restore on mesh B)."""
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    arrays = {}
    for name in sorted(os.listdir(path)):
        if name.startswith("arrays.") and name.endswith(".npz"):
            with np.load(os.path.join(path, name)) as z:
                for k in z.files:
                    arrays[int(k)] = z[k]

    keys, _, treedef = _flatten_with_paths(like)
    if keys != manifest["keys"]:
        missing = set(manifest["keys"]) ^ set(keys)
        raise ValueError(f"checkpoint/model structure mismatch: {sorted(missing)[:5]} ...")
    leaves = [arrays[i] for i in range(len(keys))]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else jnp.asarray(x),
            tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree


def _gc(directory: str, keep: int):
    steps = sorted(
        int(m.group(1)) for name in os.listdir(directory)
        if (m := re.fullmatch(r"step_(\d+)", name)))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)
