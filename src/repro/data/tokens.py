"""Synthetic LM token pipeline: deterministic, host-sharded, restartable.

Real fleets stream from a distributed store; the contract this module
honours is the same one a production loader needs:
  * per-(process, step) determinism -> a restarted job re-reads the exact
    batch for the step it resumes at (checkpoint/restart bit-exactness);
  * host sharding: each process materializes only its addressable slice of
    the global batch (`process_index`/`process_count`);
  * shape/dtype match input_specs() exactly.

Token stream is a mixture of Zipf-distributed ids (vocabulary skew akin to
real corpora) so loss curves are non-degenerate.
"""

from __future__ import annotations

import numpy as np

from repro.models.config import ModelConfig


def batch_for_step(cfg: ModelConfig, step: int, *, global_batch: int,
                   seq_len: int, process_index: int = 0,
                   process_count: int = 1, seed: int = 17) -> dict:
    assert global_batch % process_count == 0
    local = global_batch // process_count
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, process_index]))
    a = 1.3                                   # Zipf exponent
    toks = rng.zipf(a, size=(local, seq_len)).astype(np.int64)
    toks = (toks - 1) % cfg.vocab_size
    batch = {"tokens": toks.astype(np.int32)}
    if cfg.is_enc_dec:
        s_dec = max(128, seq_len // cfg.dec_seq_divisor)
        batch = {
            "frames": rng.standard_normal(
                (local, seq_len, cfg.d_model)).astype(np.float32),
            "tokens": ((rng.zipf(a, size=(local, s_dec)) - 1)
                       % cfg.vocab_size).astype(np.int32),
        }
    elif cfg.frontend == "vision":
        p = cfg.frontend_len
        batch = {
            "tokens": toks[:, : seq_len - p].astype(np.int32),
            "embeds": rng.standard_normal(
                (local, p, cfg.d_model)).astype(np.float32),
        }
    return batch
