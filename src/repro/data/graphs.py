"""Synthetic AIDS-like graph-pair stream with GED-derived similarity labels.

The paper benchmarks on the AIDS antivirus screen dataset (42,687 chemical
compounds; 25.6 nodes / 27.6 edges on average; 29 node-label types) and forms
10,000 random query pairs. The dataset itself is not redistributable here, so
this module generates statistically matched surrogates:

  * sparse connected molecule-like graphs (random spanning tree + a few extra
    edges), node counts ~ N(25.6, 8) clipped to [5, 64], edge surplus ~ +2;
  * pairs are (G, edit(G, k)) with k uniform edit operations, giving a known
    GED *upper bound* k used as the training label via the SimGNN
    normalization  target = exp(-2k / (n1 + n2)).

Pure-numpy host pipeline (the FPGA host preprocessing role), deterministic in
the seed, stream-style API for the training loop.
"""

from __future__ import annotations

from typing import Iterator, Sequence

import numpy as np

N_NODE_LABELS = 29
AVG_NODES = 25.6


def _with_density(g: dict) -> dict:
    """Record the *realized* sparsity of a graph dict: `avg_degree` (2E/V,
    self loops excluded) and `density` (adjacency nnz fraction) — the
    measured quantities sparsity benchmarks and the scoring engine's
    dispatch read instead of trusting the generator's target."""
    n = g["adj"].shape[0]
    nnz = float(np.count_nonzero(g["adj"]))
    g["avg_degree"] = nnz / max(n, 1)
    g["density"] = nnz / max(n * n, 1)
    return g


def random_graph(rng: np.random.Generator, n_nodes: int | None = None, *,
                 avg_degree: float | None = None) -> dict:
    if n_nodes is None:
        n_nodes = int(np.clip(rng.normal(AVG_NODES, 8.0), 5, 64))
    # random spanning tree (connected, like chemical compounds)
    adj = np.zeros((n_nodes, n_nodes), np.float32)
    perm = rng.permutation(n_nodes)
    for i in range(1, n_nodes):
        j = perm[rng.integers(0, i)]
        adj[perm[i], j] = adj[j, perm[i]] = 1.0
    if avg_degree is None:
        # sprinkle extra edges: AIDS has ~2 more edges than a tree on average
        extra = rng.poisson(2.0)
    else:
        # Degree knob for sparsity benchmarks: aim for n*d/2 total edges on
        # top of the n-1 spanning-tree edges (a tree is already degree
        # ~2(n-1)/n, so AIDS-like d=2.1 adds only a couple). Collisions with
        # existing edges make the target an upper bound; the realized value
        # is recorded below.
        extra = max(0, int(round(n_nodes * avg_degree / 2.0)) - (n_nodes - 1))
    for _ in range(extra):
        a, b = rng.integers(0, n_nodes, 2)
        if a != b:
            adj[a, b] = adj[b, a] = 1.0
    labels = rng.integers(0, N_NODE_LABELS, n_nodes).astype(np.int32)
    return _with_density({"adj": adj, "labels": labels})


def edit_graph(rng: np.random.Generator, g: dict, n_edits: int) -> dict:
    """Apply n_edits random edit operations (edge add/del, label change).
    Node count is preserved so GED <= n_edits by construction."""
    adj = g["adj"].copy()
    labels = g["labels"].copy()
    n = adj.shape[0]
    for _ in range(n_edits):
        op = rng.integers(0, 3)
        if op == 0 and n > 1:                      # add a random edge (no-op
                                                   # if it already exists)
            a, b = rng.integers(0, n, 2)
            if a != b:
                adj[a, b] = adj[b, a] = 1.0
        elif op == 1:                              # delete a random edge
            rr, cc = np.nonzero(np.triu(adj, 1))
            if len(rr):
                i = rng.integers(0, len(rr))
                adj[rr[i], cc[i]] = adj[cc[i], rr[i]] = 0.0
        else:                                      # relabel a node
            labels[rng.integers(0, n)] = rng.integers(0, N_NODE_LABELS)
    return _with_density({"adj": adj, "labels": labels})


def ged_target(n_edits: int, n1: int, n2: int) -> float:
    """SimGNN label normalization: exp(-GED / ((n1+n2)/2))."""
    return float(np.exp(-2.0 * n_edits / (n1 + n2)))


def pair_stream(seed: int, batch: int, max_nodes: int = 64,
                max_edits: int = 8,
                avg_degree: float | None = None) -> Iterator[dict]:
    """Infinite stream of graph-pair batches for SimGNN training.

    Yields dicts carrying BOTH batch views: `pairs` (the raw graph-pair
    dicts + `target`, what the engine-routed train step consumes — it packs
    them itself, DESIGN.md §11) and the padded dense arrays
    adj1/feats1/mask1, adj2/feats2/mask2 (what the dense-reference loss
    consumes directly) — plus the batch's realized `density` / `avg_degree`
    (mean over both sides). `avg_degree` targets a degree other than the
    AIDS-like default (~2.1).
    """
    from repro.core.batching import pad_graphs

    rng = np.random.default_rng(seed)
    while True:
        g1s, g2s, targets = [], [], []
        for _ in range(batch):
            g1 = random_graph(rng, avg_degree=avg_degree)
            k = int(rng.integers(0, max_edits + 1))
            g2 = edit_graph(rng, g1, k)
            g1s.append(g1)
            g2s.append(g2)
            targets.append(ged_target(k, g1["adj"].shape[0], g2["adj"].shape[0]))
        b1 = pad_graphs(g1s, N_NODE_LABELS, max_nodes)
        b2 = pad_graphs(g2s, N_NODE_LABELS, max_nodes)
        gs = g1s + g2s
        yield {
            "pairs": list(zip(g1s, g2s)),
            "adj1": b1.adj, "feats1": b1.feats, "mask1": b1.mask,
            "adj2": b2.adj, "feats2": b2.feats, "mask2": b2.mask,
            "target": np.asarray(targets, np.float32),
            "density": float(np.mean([g["density"] for g in gs])),
            "avg_degree": float(np.mean([g["avg_degree"] for g in gs])),
        }


def bucketed_pair_batch(seed: int, bucket: int, batch: int,
                        n_labels: int = N_NODE_LABELS):
    """Batch of graph pairs whose graphs all fit `bucket` nodes, padded to
    it — the per-bucket workload for megakernel parity tests and benchmarks.
    Returns (adj1, feats1, mask1, adj2, feats2, mask2)."""
    from repro.core.batching import pad_graphs

    rng = np.random.default_rng(seed)
    g1s, g2s = [], []
    for _ in range(batch):
        n = int(rng.integers(max(2, bucket // 2), bucket + 1))
        g1 = random_graph(rng, n)
        g1s.append(g1)
        g2s.append(edit_graph(rng, g1, int(rng.integers(0, 4))))
    lhs = pad_graphs(g1s, n_labels, bucket)
    rhs = pad_graphs(g2s, n_labels, bucket)
    return (lhs.adj, lhs.feats, lhs.mask, rhs.adj, rhs.feats, rhs.mask)


def query_pairs(seed: int, n_pairs: int) -> list[tuple[dict, dict]]:
    """A fixed list of query pairs (the paper's 10,000-query benchmark)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_pairs):
        g1 = random_graph(rng)
        g2 = edit_graph(rng, g1, int(rng.integers(0, 9)))
        out.append((g1, g2))
    return out


def zipf_corpus(seed: int, n_corpus: int,
                avg_degree: float | None = None) -> list[dict]:
    """The fixed corpus behind `zipf_query_stream` — generated separately so
    a search service can `index()` exactly the graphs the stream will hit
    (same seed -> same corpus, independent of how many batches are drawn)."""
    rng = np.random.default_rng(seed)
    return [random_graph(rng, avg_degree=avg_degree) for _ in range(n_corpus)]


def zipf_query_stream(seed: int, batch: int, n_corpus: int = 256,
                      exponent: float = 1.1,
                      avg_degree: float | None = None) -> Iterator[dict]:
    """Infinite 1-vs-N search stream with Zipf-skewed corpus reuse.

    Real similarity-search traffic does not touch a corpus uniformly: a few
    popular compounds dominate (the regime where an LRU of per-graph
    embeddings earns its keep — DESIGN.md §10). Each batch pairs one fresh
    query graph against `batch` corpus graphs drawn by Zipf(`exponent`)
    over a seed-fixed popularity ranking, so a capacity-limited cache sees
    realistic skew: the hot head stays resident, the tail churns.

    Yields {"pairs": [(query, corpus[i]), ...], "corpus_idx": [batch] int64,
    "query": dict, "unique_frac": fraction of distinct corpus graphs in the
    batch}. Deterministic in `seed` (corpus via `zipf_corpus(seed, ...)`,
    picks from the continuing generator state); every graph dict carries
    its realized `density` / `avg_degree` like every other stream here.
    """
    rng = np.random.default_rng(seed)
    corpus = [random_graph(rng, avg_degree=avg_degree)
              for _ in range(n_corpus)]
    # Popularity rank decoupled from generation order (graph size must not
    # correlate with popularity), but fixed by the same seed.
    rank = rng.permutation(n_corpus)
    probs = 1.0 / (rank + 1.0) ** exponent
    probs /= probs.sum()
    while True:
        query = random_graph(rng, avg_degree=avg_degree)
        idx = rng.choice(n_corpus, size=batch, p=probs)
        yield {"pairs": [(query, corpus[i]) for i in idx],
               "corpus_idx": idx.astype(np.int64),
               "query": query,
               "unique_frac": len(np.unique(idx)) / max(batch, 1)}


def search_pairs(seed: int, n_pairs: int,
                 avg_degree: float | None = None) -> list[tuple[dict, dict]]:
    """Similarity-*search* pair stream: query and database graph sizes are
    independent draws (query_pairs' edit-pairs always share a node count,
    which understates the pair-max bucketing cost a real search workload
    pays — the paper pairs 10,000 *random* compounds). No GED labels.
    `avg_degree` targets a non-default degree (AIDS-like ~2.1 otherwise);
    each graph dict carries its realized `density` / `avg_degree`."""
    rng = np.random.default_rng(seed)
    return [(random_graph(rng, avg_degree=avg_degree),
             random_graph(rng, avg_degree=avg_degree))
            for _ in range(n_pairs)]
