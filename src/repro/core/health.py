"""Circuit breakers and health accounting for the scoring engine
(DESIGN.md §12).

The engine's path ladder gives every fast path a fallback; the breaker
decides when to stop *trying* the fast path. Without one, a persistently
broken kernel (bad Mosaic lowering after a toolchain bump, a shape class
that reliably exhausts VMEM) pays a failed attempt — compile time, an
exception, a retried batch — on every single call before degrading. The
breaker converts that into: fail `failure_threshold` consecutive times,
then serve straight from the fallback for a cool-down, then let ONE probe
through (half-open); success closes the breaker, failure re-opens it with
exponentially longer cool-downs (capped).

Breakers are keyed per (path, shape-class) by the engine: a kernel that
dies on 128-node overflow tiles keeps serving 64-node traffic normally.

The clock is injectable so tests drive open -> half-open -> closed
transitions deterministically (no sleeps), same pattern as
`serve.batching.MicroBatcher`.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


@dataclass
class CircuitBreaker:
    """Consecutive-failure breaker with exponential-backoff cool-downs.

    States: `closed` (normal; counting consecutive failures), `open`
    (rejecting — serve the fallback; entered after `failure_threshold`
    consecutive failures), `half_open` (cool-down elapsed; the next attempt
    is a probe — success closes, failure re-opens with the cool-down
    doubled, up to `max_cooldown_s`).
    """
    failure_threshold: int = 3
    cooldown_s: float = 30.0
    backoff: float = 2.0
    max_cooldown_s: float = 600.0
    clock: Callable[[], float] = time.monotonic

    state: str = field(default=CLOSED, init=False)
    consecutive_failures: int = field(default=0, init=False)
    failures: int = field(default=0, init=False)      # lifetime totals
    successes: int = field(default=0, init=False)
    rejections: int = field(default=0, init=False)    # calls turned away
    open_count: int = field(default=0, init=False)    # times opened (drives
                                                      # the backoff exponent)
    opened_at: float | None = field(default=None, init=False)

    def current_cooldown(self) -> float:
        exp = max(self.open_count - 1, 0)
        return min(self.cooldown_s * self.backoff ** exp, self.max_cooldown_s)

    def allow(self) -> bool:
        """May the protected path be attempted right now? Open breakers
        flip to half-open once the cool-down has elapsed (the probe)."""
        if self.state == OPEN:
            if (self.clock() - self.opened_at) >= self.current_cooldown():
                self.state = HALF_OPEN
            else:
                self.rejections += 1
                return False
        return True

    def record_success(self) -> None:
        self.successes += 1
        self.consecutive_failures = 0
        if self.state != CLOSED:
            self.state = CLOSED
            self.open_count = 0        # healthy again: backoff resets
            self.opened_at = None

    def record_failure(self) -> None:
        self.failures += 1
        self.consecutive_failures += 1
        if (self.state == HALF_OPEN
                or self.consecutive_failures >= self.failure_threshold):
            self.state = OPEN
            self.open_count += 1
            self.opened_at = self.clock()

    def snapshot(self) -> dict:
        """Serializable state for `engine.health()` / dashboards."""
        snap = {"state": self.state,
                "consecutive_failures": self.consecutive_failures,
                "failures": self.failures, "successes": self.successes,
                "rejections": self.rejections,
                "open_count": self.open_count}
        if self.state == OPEN:
            snap["cooldown_remaining_s"] = round(max(
                0.0, self.current_cooldown()
                - (self.clock() - self.opened_at)), 6)
        return snap
