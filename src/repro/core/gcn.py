"""Graph Convolutional Network core (Kipf & Welling) for batches of small graphs.

Paper mapping (SPA-GCN §2.1/§3.2): one GCN layer computes

    H^{l+1} = ReLU( A' · (H^l · W^l) + b^l )

with the multiplication order A'(HW) chosen over (A'H)W because both operands
of each product stay sparse-x-dense (fewer ops — same argument as the paper).
On TPU the graphs are processed as a *batch* of padded [N, F] tiles so every
matmul is a dense MXU-shaped batched GEMM; structural sparsity is removed by
size-bucketing (see core/batching.py) rather than by dynamic zero-skipping
(see DESIGN.md §2 for why that FPGA mechanism does not transfer).

All functions are natively batched: adjacency [B, N, N], features [B, N, F],
node mask [B, N]. They are pure and `jit`/`vmap`/`grad`-compatible.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


def normalized_adjacency(adj: Array, mask: Array) -> Array:
    """A' = D^-1/2 (A + I) D^-1/2 restricted to valid (masked) nodes.

    adj:  [B, N, N] 0/1 (or weighted) adjacency, padded with zeros.
    mask: [B, N] 1.0 for real nodes, 0.0 for padding.
    Padding rows/cols of the result are exactly zero, so padded nodes
    neither send nor receive messages.
    """
    m = mask[..., :, None] * mask[..., None, :]            # [B, N, N]
    eye = jnp.eye(adj.shape[-1], dtype=adj.dtype)
    a_tilde = (adj + eye) * m                              # self loops on real nodes only
    deg = jnp.sum(a_tilde, axis=-1)                        # [B, N]
    inv_sqrt = jnp.where(deg > 0, jax.lax.rsqrt(jnp.maximum(deg, 1e-12)), 0.0)
    return a_tilde * inv_sqrt[..., :, None] * inv_sqrt[..., None, :]


def init_gcn_params(key: Array, feature_dims: Sequence[int], dtype=jnp.float32):
    """Glorot-init a stack of GCN layers: dims (f0, f1, ..., fL)."""
    layers = []
    for i in range(len(feature_dims) - 1):
        key, sub = jax.random.split(key)
        fan_in, fan_out = feature_dims[i], feature_dims[i + 1]
        scale = jnp.sqrt(2.0 / (fan_in + fan_out)).astype(dtype)
        w = jax.random.normal(sub, (fan_in, fan_out), dtype) * scale
        b = jnp.zeros((fan_out,), dtype)
        layers.append({"w": w, "b": b})
    return layers


def gcn_layer(params, adj_norm: Array, h: Array, mask: Array, *,
              activation: bool = True) -> Array:
    """One GCN layer on a padded batch. A'(H·W) ordering (paper §3).

    adj_norm: [B, N, N], h: [B, N, Fin], mask: [B, N] -> [B, N, Fout].
    """
    hw = jnp.einsum("bnf,fg->bng", h, params["w"]) + params["b"]
    out = jnp.einsum("bnm,bmg->bng", adj_norm, hw)
    if activation:
        out = jax.nn.relu(out)
    return out * mask[..., None]


def gcn_stack(layers, adj_norm: Array, h: Array, mask: Array) -> Array:
    """Full GCN: ReLU between layers (incl. after the last one, as SimGNN does
    before attention pooling — matches the released SimGNN reference)."""
    for p in layers:
        h = gcn_layer(p, adj_norm, h, mask, activation=True)
    return h


def gcn_stack_from_labels(layers, adj_norm: Array, labels: Array,
                          mask: Array) -> Array:
    """GCN stack whose input is int32 node labels instead of one-hot features.

    The first layer's H·W becomes a W1 row gather: one_hot(labels) @ W1 ==
    W1[labels] *exactly* (each one-hot row sums a single non-zero product),
    so this is bit-identical to `gcn_stack` on one-hot feats while never
    materializing the [B, N, n_labels] block — the pure-jnp reference for
    the kernels' first-layer one-hot elimination (DESIGN.md §8).
    labels: [B, N] int32 (pad slots may hold any valid label; masked out).
    """
    hw = jnp.take(layers[0]["w"], labels, axis=0) + layers[0]["b"]
    h = jnp.einsum("bnm,bmg->bng", adj_norm, hw)
    h = jax.nn.relu(h) * mask[..., None]
    for p in layers[1:]:
        h = gcn_layer(p, adj_norm, h, mask, activation=True)
    return h


def gcn_stack_unfused_baseline(layers, adj_norm: Array, h: Array, mask: Array) -> Array:
    """Paper's *baseline* architecture analogue: each layer is its own jit
    region, so intermediates round-trip through HBM between layers (the
    FPGA baseline stored intermediates in global memory). Used only by
    benchmarks/table4.py to reproduce the paper's ablation structure."""
    step = jax.jit(lambda p, a, x, m: gcn_layer(p, a, x, m, activation=True))
    for p in layers:
        h = step(p, adj_norm, h, mask)
        h = jax.block_until_ready(h)
    return h


def activation_sparsity(h: Array, mask: Array) -> Array:
    """Fraction of exact zeros among real-node activations (paper §3.4 reports
    52%/47% for SimGNN layers 2/3; we measure rather than exploit — DESIGN §2)."""
    valid = mask[..., None] * jnp.ones_like(h)
    zeros = jnp.sum((h == 0) * valid)
    return zeros / jnp.maximum(jnp.sum(valid), 1.0)
