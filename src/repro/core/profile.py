"""Per-call trace recording + measured per-path latency cost model
(DESIGN.md §15).

SPA-GCN's central claim is that the right execution strategy for a
many-small-graph GCN workload is a function of *measurable* workload shape
(graph size, density, sparsity) — yet the engine's `plan()` historically
picked among six paths with hand-tuned folklore thresholds
(`SPARSE_MAX_DEGREE`, the >= 50% cache-residency flip), which Accel-GCN and
LW-GCN both show are workload-dependent crossovers, not constants. This
module turns those constants into data:

  * `TraceRecorder` — an in-memory ring of `TraceRecord`s (one per executed
    engine work item: path, shape stats, pack occupancy, degradation tail,
    wall seconds) plus an append-only JSONL profile persisted through
    `core.store.atomic_write_bytes` (site ``"profile"`` on the §13
    filesystem fault seam). The clock is injectable so timing-dependent
    tests run deterministic, mirroring `core.health.CircuitBreaker`.

  * `fit_cost_model` — a small ridge regression per path on shape features
    (pairs, total nodes, total edges, embeddings-to-compute), fitted from
    the recorded profile. `ScoringEngine.plan()` argmins the predicted
    cost when every candidate path has enough support, and falls back
    bit-identically to the threshold rules when cold.

Profile format (one JSON object per line):

    line 0:  {"profile_format_version": 2, "schema_digest": "<hex>"}
    line 1+: one record with EXACTLY the `TRACE_SCHEMA` fields

Version 1 profiles (no `n_devices` field) are still read — their records
are facts about single-device runs, so `n_devices=1` — and upgrade to v2
in place on the next flush.

`schema_digest()` pins the record schema the way the `graph_key` golden
hashes pin the WL hash (tests/test_cache.py): a reader either understands
a persisted profile or refuses it with a structured `ProfileError`
(`ManifestError`-style — never mis-parse), while individually garbled
record lines (torn appends, bit rot) are skipped-and-counted
(`records_dropped`), because losing one sample must not lose the profile.
"""

from __future__ import annotations

import hashlib
import json
import os
import time
from collections import Counter, deque
from dataclasses import asdict, astuple, dataclass
from typing import Callable, Iterable, Sequence

import numpy as np

from repro.core.store import StoreError, atomic_write_bytes

#: Bump when `TRACE_SCHEMA` changes shape or meaning. Readers refuse any
#: other version (ProfileError) instead of guessing — a mis-parsed latency
#: sample silently steers every later dispatch decision. v2 added
#: `n_devices` (DESIGN.md §16): v1 profiles are still read (every v1 record
#: ran single-device, so `n_devices=1` is a fact, not a guess) and are
#: upgraded to v2 in place on the next flush.
PROFILE_FORMAT_VERSION = 2

#: The versioned record schema: (field, json-type) in canonical order.
#: `schema_digest()` hashes this, so ANY rename / retype / reorder changes
#: the digest and old profiles are refused loudly rather than mis-read.
TRACE_SCHEMA = (
    ("kind", "str"),           # "score" | "train" | "step" (entry point)
    ("path", "str"),           # executed path; cost-model key
    ("n_pairs", "int"),        # pairs this work item scored
    ("max_nodes", "int"),      # ScorePlan shape stats, measured
    ("mean_nodes", "float"),
    ("avg_degree", "float"),
    ("density", "float"),
    ("occupancy", "float"),    # packed-tile occupancy (0 on unpacked paths)
    ("to_embed", "int"),       # cache misses actually embedded (cached path)
    ("degraded_from", "list"),  # rungs that failed before `path` served
    ("attempts", "int"),       # executor invocations tried
    ("wall_s", "float"),       # measured wall seconds (injectable clock)
    ("seq", "int"),            # recorder-assigned sequence number
    ("n_devices", "int"),      # mesh devices the call ran on (v2; v1 -> 1)
)

#: The v1 schema (everything before `n_devices`), kept so v1 profiles load.
_V1_SCHEMA = TRACE_SCHEMA[:-1]

_TYPE_CHECK = {
    "str": lambda v: isinstance(v, str),
    "int": lambda v: isinstance(v, int) and not isinstance(v, bool),
    "float": lambda v: isinstance(v, (int, float))
    and not isinstance(v, bool),
    "list": lambda v: isinstance(v, list),
}


def _digest_of(version: int, schema: tuple) -> str:
    h = hashlib.blake2b(digest_size=16)
    h.update(str(version).encode())
    for name, typ in schema:
        h.update(f"{name}:{typ};".encode())
    return h.hexdigest()


def schema_digest() -> str:
    """blake2b-128 hex of (format version, schema) — the golden-pinned
    format contract for persisted profiles."""
    return _digest_of(PROFILE_FORMAT_VERSION, TRACE_SCHEMA)


def v1_schema_digest() -> str:
    """Digest of the retired v1 schema — what a v1 header must carry for
    this reader to accept (and upgrade) it."""
    return _digest_of(1, _V1_SCHEMA)


class ProfileError(StoreError):
    """A persisted profile this reader cannot trust as a whole: missing /
    garbled header line, or a format version / schema digest it does not
    understand. Per-line damage is NOT this — garbled record lines are
    skipped and counted instead (losing a sample is recoverable; guessing
    a schema is not)."""


@dataclass(frozen=True)
class TraceRecord:
    """One executed engine work item, as the profile persists it."""
    kind: str
    path: str
    n_pairs: int
    max_nodes: int
    mean_nodes: float
    avg_degree: float
    density: float
    occupancy: float
    to_embed: int
    degraded_from: tuple
    attempts: int
    wall_s: float
    seq: int
    n_devices: int = 1        # v2 field; defaulted LAST so v1 loads fill it

    def to_json(self) -> str:
        d = asdict(self)
        d["degraded_from"] = list(self.degraded_from)
        return json.dumps(d, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "TraceRecord":
        """Strict schema validation: exactly the v2 schema fields — or
        exactly the v1 fields, in which case `n_devices=1` is filled in
        (every v1 record ran single-device). Anything else is a
        garbled/foreign line."""
        if not isinstance(d, dict):
            raise ValueError("record not an object")
        names = {n for n, _ in TRACE_SCHEMA}
        if set(d) == {n for n, _ in _V1_SCHEMA}:
            d = dict(d, n_devices=1)
        elif set(d) != names:
            raise ValueError(f"record fields {sorted(d)!r} != schema")
        for name, typ in TRACE_SCHEMA:
            if not _TYPE_CHECK[typ](d[name]):
                raise ValueError(f"field {name!r} is not {typ}")
        d = dict(d)
        d["degraded_from"] = tuple(str(x) for x in d["degraded_from"])
        for name, typ in TRACE_SCHEMA:
            if typ == "float":
                d[name] = float(d[name])
        return cls(**d)


def _header_line() -> str:
    return json.dumps({"profile_format_version": PROFILE_FORMAT_VERSION,
                       "schema_digest": schema_digest()}, sort_keys=True)


def _check_header(line: str, path: str) -> None:
    try:
        head = json.loads(line)
    except ValueError as exc:
        raise ProfileError(f"unreadable profile header at {path}: {exc}")
    if not isinstance(head, dict):
        raise ProfileError(f"profile header at {path} is not an object")
    version = head.get("profile_format_version")
    if version not in (1, PROFILE_FORMAT_VERSION):
        raise ProfileError(
            f"profile format version {version!r} != supported "
            f"{{1, {PROFILE_FORMAT_VERSION}}} at {path}: refusing to guess "
            "the record schema")
    digest = head.get("schema_digest")
    want = v1_schema_digest() if version == 1 else schema_digest()
    if digest != want:
        raise ProfileError(
            f"profile schema digest {digest!r} != {want!r} at "
            f"{path}: the record schema changed without a version bump — "
            "refusing to mis-parse")


class TraceRecorder:
    """In-memory ring + append-only JSONL persistence for trace records.

    `record()` NEVER raises (a broken recorder must never fail a scoring
    call — failures count on `counters["record_errors"]`); `flush()`
    appends the unpersisted tail to the JSONL profile at `path` through
    `atomic_write_bytes` (fault-seam site ``"profile"``), re-validating the
    existing file so a torn previous append self-heals: garbled lines are
    dropped-and-counted, never re-persisted. `clock` is the timestamp /
    timing source engines share so tests inject a fake one.
    """

    def __init__(self, capacity: int = 4096, path: str | None = None,
                 clock: Callable[[], float] = time.perf_counter,
                 flush_every: int = 0):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.path = path
        self.clock = clock
        #: auto-flush after this many unpersisted records (0 = manual only).
        self.flush_every = int(flush_every)
        self._ring: deque[TraceRecord] = deque(maxlen=self.capacity)
        self._pending: list[TraceRecord] = []
        #: monotonic count of records ever accepted (ring evictions and
        #: flushes never decrease it) — drives the engine's refit cadence.
        self.total_records = 0
        self._seq = 0
        self.counters: Counter = Counter()

    # ------------------------------------------------------------ recording

    def record(self, *, kind: str, path: str, n_pairs: int, max_nodes: int,
               mean_nodes: float, avg_degree: float, density: float,
               occupancy: float = 0.0, to_embed: int = 0,
               degraded_from: Sequence[str] = (), attempts: int = 1,
               wall_s: float = 0.0, n_devices: int = 1
               ) -> TraceRecord | None:
        """Append one record; returns it, or None if recording failed
        (counted, swallowed — observability must not take down serving)."""
        try:
            rec = TraceRecord(
                kind=str(kind), path=str(path), n_pairs=int(n_pairs),
                max_nodes=int(max_nodes), mean_nodes=float(mean_nodes),
                avg_degree=float(avg_degree), density=float(density),
                occupancy=float(occupancy), to_embed=int(to_embed),
                degraded_from=tuple(str(d) for d in degraded_from),
                attempts=int(attempts), wall_s=float(wall_s), seq=self._seq,
                n_devices=int(n_devices))
            self._seq += 1
            self._ring.append(rec)
            self._pending.append(rec)
            self.total_records += 1
            if (self.path and self.flush_every
                    and len(self._pending) >= self.flush_every):
                self.flush()
            return rec
        except Exception:
            self.counters["record_errors"] += 1
            return None

    def records(self) -> list[TraceRecord]:
        """Snapshot of the in-memory ring, oldest first."""
        return list(self._ring)

    def __len__(self) -> int:
        return len(self._ring)

    # ---------------------------------------------------------- persistence

    def _read_valid_lines(self, path: str) -> list[str]:
        """Existing profile's record lines that still parse + validate,
        re-serialized in the CURRENT schema (so a v1 profile upgrades to v2
        on the next flush — `n_devices=1` filled in); damaged lines (torn
        tail, bit rot) are dropped-and-counted. A bad HEADER raises
        ProfileError — appending to a profile of unknown schema would
        poison every future reader."""
        with open(path, "rb") as f:
            raw = f.read().decode("utf-8", errors="replace")
        lines = [ln for ln in raw.split("\n") if ln.strip()]
        if not lines:
            return []
        _check_header(lines[0], path)
        keep = []
        for ln in lines[1:]:
            try:
                keep.append(TraceRecord.from_dict(json.loads(ln)).to_json())
            except (ValueError, TypeError):
                self.counters["records_dropped"] += 1
        return keep

    def flush(self) -> int:
        """Persist the unpersisted tail; returns records now on disk.
        No-op without a configured `path`. Never raises — a full disk must
        degrade observability, not scoring (`counters["flush_errors"]`)."""
        if not self.path or not self._pending:
            return 0
        try:
            existing = (self._read_valid_lines(self.path)
                        if os.path.exists(self.path) else [])
            lines = ([_header_line()] + existing
                     + [r.to_json() for r in self._pending])
            atomic_write_bytes(self.path, ("\n".join(lines) + "\n").encode(),
                               site="profile")
            n = len(self._pending)
            self._pending = []
            self.counters["flushes"] += 1
            return n
        except Exception:
            self.counters["flush_errors"] += 1
            return 0

    @classmethod
    def load(cls, path: str, *, capacity: int | None = None,
             clock: Callable[[], float] = time.perf_counter
             ) -> "TraceRecorder":
        """Recorder seeded from a persisted profile. Header problems raise
        `ProfileError` (whole file untrusted); damaged record lines are
        skipped-and-counted on `counters["records_dropped"]`. Loaded
        records count as already persisted (a later `flush()` appends only
        new ones)."""
        if not os.path.exists(path):
            raise ProfileError(f"no profile at {path}")
        probe = cls(capacity=1)
        lines = probe._read_valid_lines(path)
        records = [TraceRecord.from_dict(json.loads(ln)) for ln in lines]
        rec = cls(capacity=capacity or max(len(records) * 2, 4096),
                  path=path, clock=clock)
        rec._ring.extend(records)
        rec.total_records = len(records)
        rec._seq = max((r.seq for r in records), default=-1) + 1
        rec.counters["records_dropped"] = probe.counters["records_dropped"]
        return rec


def read_profile(path: str) -> tuple[list[TraceRecord], int]:
    """(records, dropped-line count) of a persisted profile — the read-only
    flavor of `TraceRecorder.load` for analysis/benchmarks."""
    rec = TraceRecorder.load(path)
    return rec.records(), int(rec.counters["records_dropped"])


# ---------------------------------------------------------------- cost model

#: Shape features of one call for the per-path latency model. Deliberately
#: tiny: every term is a quantity the planner already measures host-side,
#: and per-path weights absorb the per-path constants (launch overhead,
#: per-pair head cost, per-node aggregation cost, per-edge gather cost,
#: per-miss embedding cost).
FEATURE_NAMES = ("bias", "pairs", "nodes", "edges", "to_embed")


def trace_features(n_pairs: float, mean_nodes: float, avg_degree: float,
                   to_embed: float = 0.0) -> np.ndarray:
    nodes = 2.0 * float(n_pairs) * float(mean_nodes)
    return np.array([1.0, float(n_pairs), nodes,
                     nodes * float(avg_degree), float(to_embed)], np.float64)


def _record_features(r: TraceRecord) -> np.ndarray:
    return trace_features(r.n_pairs, r.mean_nodes, r.avg_degree, r.to_embed)


def cost_key(path: str, n_devices: int = 1) -> str:
    """Cost-model group key: multi-device walls live under `path@Nd` so the
    planner never mixes single- and multi-device latency samples (a 2-device
    wall predicting a 1-device call would bias every dispatch). Single-device
    keys stay the bare path — v1 profiles keep fitting unchanged."""
    n = int(n_devices)
    return path if n <= 1 else f"{path}@{n}d"


@dataclass(frozen=True)
class CostModel:
    """Per-path ridge fit latency model: weights over `FEATURE_NAMES`,
    plus the support and training residual every fit exposes for
    `engine.health()["planner"]` and the replay gate."""
    weights: dict                   # path -> [len(FEATURE_NAMES)] float64
    support: dict                   # path -> clean records fitted from
    residual_medape: dict           # path -> median |pred-y|/y on train set
    n_records: int
    min_support: int

    def supports(self, paths: Iterable[str]) -> bool:
        return all(p in self.weights for p in paths)

    def predict(self, path: str, feats: np.ndarray) -> float:
        """Predicted wall seconds (clamped positive: a ridge fit can dip
        negative outside its support, and a negative latency would make
        argmin meaningless)."""
        return float(max(feats @ self.weights[path], 1e-9))

    def snapshot(self) -> dict:
        return {"paths": sorted(self.weights),
                "support": dict(self.support),
                "residual_medape": {k: round(v, 4)
                                    for k, v in self.residual_medape.items()},
                "n_records": self.n_records,
                "min_support": self.min_support}


def fit_cost_model(records: Sequence[TraceRecord], *, min_support: int = 8,
                   ridge: float = 1e-3) -> CostModel:
    """Fit one ridge regression per path from clean trace records.

    Clean = no degradation tail and a positive measured wall (a record
    whose timing includes failed attempts on other rungs would bill that
    rung's latency to the path that finally served). Rows are sorted by
    the full record tuple before any linear algebra, so the fit — and
    therefore every argmin the planner takes from it — is bit-identical
    under any record ordering (pinned by a property test). Paths with
    fewer than `min_support` clean records get no weights: the planner
    treats them as cold and keeps the threshold rules.
    """
    by_path: dict[str, list[TraceRecord]] = {}
    for r in records:
        if r.wall_s > 0.0 and not r.degraded_from:
            by_path.setdefault(cost_key(r.path, r.n_devices), []).append(r)
    weights: dict[str, np.ndarray] = {}
    support: dict[str, int] = {}
    residual: dict[str, float] = {}
    k = len(FEATURE_NAMES)
    for path, group in sorted(by_path.items()):
        if len(group) < min_support:
            continue
        group = sorted(group, key=astuple)
        x = np.stack([_record_features(r) for r in group])
        y = np.array([r.wall_s for r in group], np.float64)
        # Column scaling before the ridge penalty: the feature magnitudes
        # span ~5 orders (bias=1 vs edges~1e4), and an unscaled penalty
        # would regularize them incomparably.
        scale = np.maximum(np.abs(x).max(axis=0), 1e-12)
        xs = x / scale
        w = np.linalg.solve(xs.T @ xs + ridge * np.eye(k), xs.T @ y)
        w = w / scale
        pred = np.maximum(x @ w, 1e-9)
        weights[path] = w
        support[path] = len(group)
        residual[path] = float(np.median(
            np.abs(pred - y) / np.maximum(y, 1e-9)))
    return CostModel(weights=weights, support=support,
                     residual_medape=residual, n_records=len(records),
                     min_support=min_support)
