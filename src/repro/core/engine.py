"""ScoringEngine — the unified path-selection layer for SimGNN pair scoring
(DESIGN.md §9).

Five scoring paths coexist in this codebase, each fastest somewhere:

  reference      pure-jnp `core.simgnn.pair_score`, bucketed; the parity
                 anchor and the no-kernels fallback.
  two_kernel     fused GCN+Att then fused NTN+FCN head (embeddings
                 round-trip HBM); building blocks for embedding-only
                 callers, benchmark comparator.
  bucketed_mega  ONE pallas_call per size bucket (DESIGN.md §7); handles
                 any feature kind, serves as the oversize fallback.
  packed_dense   FFD node-packed segment-ID tiles, dense block-diagonal
                 adjacency matmul (DESIGN.md §8); wins on dense-adjacency
                 streams.
  packed_sparse  packed tiles aggregated from the A' non-zero edge list
                 (DESIGN.md §9); wins on sparse (AIDS-like) streams —
                 the paper's own workload.

Before this layer existed, the routing logic lived as ad-hoc branching
inside `serve.batching.simgnn_query_server`. The engine makes the decision
explicit and inspectable: `plan()` measures the workload (batch size, node
counts, *measured* edge density, label kind) and returns a `ScorePlan`
naming the chosen path, the pairs it covers, the oversize fallback split
and the reason — `score()` then executes it. The serving wrapper is a thin
shim that keeps its public `score_fn` contract.

All compiled-callable caches (one per size bucket, `bucket_fns`) and packing
statistics (`last_pack_stats`) live on the engine instance, so a serving
process holds exactly one engine per model and every executable is reused
across calls (the paper's 'customize per workload' principle, Table 2).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Sequence

import jax
import numpy as np

PATHS = ("reference", "two_kernel", "bucketed_mega", "packed_dense",
         "packed_sparse")
PACKED_PATHS = ("packed_dense", "packed_sparse")


@dataclass(frozen=True)
class WorkloadStats:
    """Measured properties of one score() call's pairs — the dispatch
    inputs. Densities are measured from the adjacency non-zeros, never
    assumed from the generator."""
    n_pairs: int
    max_nodes: int = 0
    mean_nodes: float = 0.0
    avg_degree: float = 0.0      # 2E/V over all graphs (self loops excluded)
    density: float = 0.0         # nnz / sum(n_i^2)
    has_labels: bool = True      # every graph carries int node labels


@dataclass(frozen=True)
class ScorePlan:
    """An explicit, inspectable dispatch decision for one batch of pairs.

    `path` scores the pairs at `fit_idx`; pairs at `over_idx` (too large for
    the packed node budget, or the whole batch on bucketed paths) run on
    `fallback` through power-of-two size buckets. `reason` is the
    human-readable dispatch rationale (surfaced by examples/simgnn_search).
    """
    path: str
    fallback: str
    fit_idx: np.ndarray
    over_idx: np.ndarray
    stats: WorkloadStats
    reason: str


class ScoringEngine:
    """Single dispatch point from graph-pair batches to scores.

    path="auto" selects per call from measured workload statistics; any
    explicit path name in `PATHS` forces that path (oversized pairs still
    fall back to bucketed scoring — nothing kills a call). Thresholds are
    class attributes so deployments can tune them.
    """

    #: densest stream the edge-centric kernel should take: beyond ~4
    #: neighbors/node the edge list stops being much smaller than the dense
    #: block and the MXU matmul wins (benchmarks/sparse.py measures the
    #: crossover; LW-GCN/Accel-GCN report the same degree-bound regime).
    SPARSE_MAX_DEGREE = 4.0
    #: below this many pairs, FFD packing cannot fill even one tile enough
    #: to beat a single bucketed launch.
    MIN_PACK_PAIRS = 4

    def __init__(self, params, cfg, *, path: str = "auto",
                 node_budget: int | None = None,
                 edge_budget: int | None = None):
        if path != "auto" and path not in PATHS:
            raise ValueError(f"unknown path {path!r}; expected 'auto' or one "
                             f"of {PATHS}")
        from repro.kernels.ops import packed_node_budget

        self.params = params
        self.cfg = cfg
        self.path = path
        self.node_budget = (packed_node_budget(cfg.max_nodes)
                            if node_budget is None else node_budget)
        self.edge_budget = edge_budget
        # Bucketed-path flavor this engine instance uses (forced reference /
        # two_kernel engines bucket through themselves; every other path
        # falls back to the §7 megakernel).
        self._bucket_flavor = (path if path in ("reference", "two_kernel")
                               else "bucketed_mega")
        self.bucket_fns: dict[int, Callable] = {}
        self.last_pack_stats: dict | None = None
        self.last_plan: ScorePlan | None = None
        self._ref_fn: Callable | None = None

    # ------------------------------------------------------------- planning

    def workload_stats(self, pairs: Sequence[tuple], *,
                       measure_density: bool = True) -> WorkloadStats:
        """Measure the dispatch inputs from the raw pair dicts (host numpy).

        Density measurement scans every adjacency (O(sum n_i^2)) — noise
        next to the packing planner, but pure waste on paths that never
        read it, so `plan()` disables it when the forced path ignores
        density (stats then report degree/density 0).
        """
        if not pairs:
            return WorkloadStats(0)
        sizes: list[int] = []
        nnz = 0.0
        cells = 0.0
        has_labels = True
        for g1, g2 in pairs:
            for g in (g1, g2):
                n = g["adj"].shape[0]
                sizes.append(n)
                if measure_density:
                    nnz += float(np.count_nonzero(g["adj"]))
                    cells += n * n
                has_labels = has_labels and "labels" in g
        nodes = sum(sizes)
        return WorkloadStats(
            n_pairs=len(pairs), max_nodes=max(sizes),
            mean_nodes=nodes / len(sizes),
            avg_degree=nnz / max(nodes, 1), density=nnz / max(cells, 1.0),
            has_labels=has_labels)

    def _select(self, stats: WorkloadStats) -> tuple[str, str]:
        if self.path != "auto":
            return self.path, f"forced path={self.path}"
        if stats.n_pairs == 0:
            return "reference", "empty call"
        if not stats.has_labels:
            # The packed kernels structurally require int labels (W1 row
            # gather); the bucketed megakernel is the dense-feats-capable
            # slot, though today's bucketed executor still builds one-hots
            # from labels (a dense-feats executor is ROADMAP backlog).
            return ("bucketed_mega",
                    "graphs without int labels cannot take a packed path")
        if stats.n_pairs < self.MIN_PACK_PAIRS:
            return ("bucketed_mega",
                    f"batch of {stats.n_pairs} too small to fill packed tiles"
                    f" (< {self.MIN_PACK_PAIRS})")
        if stats.avg_degree <= self.SPARSE_MAX_DEGREE:
            return ("packed_sparse",
                    f"measured avg degree {stats.avg_degree:.2f} <= "
                    f"{self.SPARSE_MAX_DEGREE:g}: edge list beats dense "
                    "adjacency")
        return ("packed_dense",
                f"measured avg degree {stats.avg_degree:.2f} > "
                f"{self.SPARSE_MAX_DEGREE:g}: dense MXU matmul wins")

    def plan(self, pairs: Sequence[tuple]) -> ScorePlan:
        """Measure the workload and decide — without running anything."""
        # Density only steers the auto sparse/dense split and the sparse
        # edge budget; forced paths that ignore it skip the O(sum n_i^2)
        # adjacency scan.
        stats = self.workload_stats(
            pairs, measure_density=self.path in ("auto", "packed_sparse"))
        path, reason = self._select(stats)
        if path in PACKED_PATHS:
            fits = np.asarray([max(g1["adj"].shape[0], g2["adj"].shape[0])
                               <= self.node_budget for g1, g2 in pairs], bool)
            fit_idx = np.flatnonzero(fits)
            over_idx = np.flatnonzero(~fits)
        else:
            fit_idx = np.empty(0, np.int64)
            over_idx = np.arange(len(pairs))
        return ScorePlan(path=path, fallback=self._bucket_flavor,
                         fit_idx=fit_idx, over_idx=over_idx, stats=stats,
                         reason=reason)

    # ------------------------------------------------------------ execution

    def _bucket_fn(self, bucket: int) -> Callable:
        """One cached callable per size bucket (built lazily, reused across
        calls; XLA caches one executable per padded batch shape inside)."""
        if bucket not in self.bucket_fns:
            from repro.core.simgnn import pair_score
            from repro.kernels import ops

            if self._bucket_flavor == "reference":
                if self._ref_fn is None:    # shared: jit caches per shape
                    self._ref_fn = jax.jit(pair_score)
                self.bucket_fns[bucket] = self._ref_fn
            elif self._bucket_flavor == "two_kernel":
                self.bucket_fns[bucket] = ops.simgnn_pair_score_kernel
            else:
                self.bucket_fns[bucket] = jax.jit(functools.partial(
                    ops.pair_score_megakernel,
                    block_pairs=ops.megakernel_block_pairs(bucket)))
        return self.bucket_fns[bucket]

    def _score_bucketed(self, pairs, idx: np.ndarray, out: np.ndarray):
        from repro.core.batching import bucket_pairs

        for bucket, (lhs, rhs, idxs) in bucket_pairs(
                pairs, self.cfg.n_node_labels, allow_oversize=True).items():
            s = self._bucket_fn(bucket)(
                self.params, lhs.adj, lhs.feats, lhs.mask,
                rhs.adj, rhs.feats, rhs.mask)
            out[idx[idxs]] = np.asarray(s)

    def _score_packed(self, pairs, idx: np.ndarray, out: np.ndarray,
                      sparse: bool, stats: WorkloadStats):
        from repro.core.batching import pack_pairs, unpack_pair_scores
        from repro.kernels import ops

        # Fixed slots_per_tile + power-of-two tile/edge quantization keep the
        # compiled-shape set small (O(log T) executables) under varying batch
        # sizes and FFD outcomes.
        slots = max(8, self.node_budget // 4)
        if sparse:
            edge_budget = self.edge_budget
            if edge_budget is None:
                edge_budget = ops.packed_edge_budget(self.node_budget,
                                                     stats.avg_degree)
            packed, pstats = pack_pairs(pairs, self.node_budget,
                                        slots_per_tile=slots,
                                        with_edges=True,
                                        edge_budget=edge_budget)
            s = ops.pair_score_sparse(self.params, packed,
                                      quantize_tiles=True)
        else:
            packed, pstats = pack_pairs(pairs, self.node_budget,
                                        slots_per_tile=slots)
            s = ops.pair_score_packed(self.params, packed,
                                      quantize_tiles=True)
        self.last_pack_stats = pstats
        out[idx] = unpack_pair_scores(s, packed, len(pairs))

    def score(self, pairs: Sequence[tuple]) -> np.ndarray:
        """Score a batch of graph-pair dicts in original order."""
        out = np.zeros(len(pairs), np.float32)
        plan = self.plan(pairs)
        self.last_plan = plan
        # Stats describe the *latest* call only: a bucketed call must not
        # leave a previous packed call's occupancy lying around.
        self.last_pack_stats = None
        if len(pairs) and not plan.stats.has_labels:
            # Every executor today builds features from int labels
            # (pad_graphs one-hots, packed kernels gather W1 rows); fail
            # with the contract instead of a KeyError deep inside padding.
            raise ValueError(
                "graphs must carry int node labels ('labels'); a dense-"
                "feats executor is not implemented yet (ROADMAP open item)")
        if len(plan.fit_idx):
            self._score_packed([pairs[i] for i in plan.fit_idx],
                               plan.fit_idx, out,
                               plan.path == "packed_sparse", plan.stats)
        if len(plan.over_idx):
            self._score_bucketed([pairs[i] for i in plan.over_idx],
                                 plan.over_idx, out)
        return out

    __call__ = score
