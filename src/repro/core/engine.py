"""ScoringEngine — the unified path-selection layer for SimGNN pair scoring
(DESIGN.md §9, §10).

Six scoring paths coexist in this codebase, each fastest somewhere:

  reference      pure-jnp `core.simgnn.pair_score`, bucketed; the parity
                 anchor and the no-kernels fallback.
  two_kernel     fused GCN+Att then fused NTN+FCN head (embeddings
                 round-trip HBM); building blocks for embedding-only
                 callers, benchmark comparator.
  bucketed_mega  ONE pallas_call per size bucket (DESIGN.md §7); handles
                 any feature kind, serves as the oversize fallback.
  packed_dense   FFD node-packed segment-ID tiles, dense block-diagonal
                 adjacency matmul (DESIGN.md §8); wins on dense-adjacency
                 streams.
  packed_sparse  packed tiles aggregated from the A' non-zero edge list
                 (DESIGN.md §9); wins on sparse (AIDS-like) streams —
                 the paper's own workload.
  embedding_cache  per-graph GCN+Att embeddings served from an LRU keyed
                 by a canonical graph hash, only the NTN+FCN head runs per
                 query (DESIGN.md §10); wins on 1-vs-N search where the
                 corpus side recurs across queries.

Before this layer existed, the routing logic lived as ad-hoc branching
inside `serve.batching.simgnn_query_server`. The engine makes the decision
explicit and inspectable: `plan()` measures the workload (batch size, node
counts, *measured* edge density, label kind) and returns a `ScorePlan`
naming the chosen path, the pairs it covers, the oversize fallback split
and the reason — `score()` then executes it. The serving wrapper is a thin
shim that keeps its public `score_fn` contract.

Since DESIGN.md §11 the engine dispatches BOTH directions of the model:
`loss_and_grad()` plans with the same machinery but restricts dispatch to
the VJP-capable paths (`TRAIN_PATHS`: reference | packed_dense |
packed_sparse — the packed executors are the custom-VJP jnp twins in
`kernels/grad.py`, since `pallas_call` has no autodiff rule), packs once
per batch, and reuses the packed layout across gradient-accumulation
microbatches. `train.step.build_simgnn_train_step` is the thin training
shim, exactly as the query server is the thin serving shim.

All compiled-callable caches (one per size bucket, `bucket_fns`) and packing
statistics (`last_pack_stats`) live on the engine instance, so a serving
process holds exactly one engine per model and every executable is reused
across calls (the paper's 'customize per workload' principle, Table 2).

Since DESIGN.md §12 the same ladder doubles as the fault-tolerance chain:
inputs are quarantined before planning (`core/validate.py` — invalid pairs
score NaN instead of poisoning the batch), a failing or NaN-producing
executor steps the call down the degradation ladder
(packed_sparse -> packed_dense -> bucketed_mega -> reference), and a
per-(path, shape-class) circuit breaker (`core/health.py`) stops retrying a
persistently broken path during a cool-down. `ScorePlan` records
`quarantined`/`degraded_from`/`attempts`; `health()` reports breaker states
and error counters. `repro.testing.faults` drives all of it
deterministically through the `_FAULT_HOOK` seam below.

Since DESIGN.md §15 the engine also measures itself: every executed work
item appends a `TraceRecord` (path, shape stats, pack occupancy, wall
seconds) to `self.recorder` (`core/profile.py` — ring + optional JSONL
profile), and `plan()` argmins a per-path latency model ridge-fitted from
that profile whenever every candidate path has `PLANNER_MIN_SUPPORT` clean
records — the hand-tuned `SPARSE_MAX_DEGREE` / `CACHE_MIN_HIT_FRAC`
thresholds below remain only as the bit-identical cold-profile fallback.
`benchmarks/replay.py` re-runs a captured mixed trace and gates
predicted-vs-measured error, so every future kernel's crossover point is
regression-tested data rather than folklore.
"""

from __future__ import annotations

import functools
import time
from collections import Counter, OrderedDict
from dataclasses import dataclass, field, replace
from typing import Callable, Sequence

import jax
import numpy as np

from repro.core.cache import EmbeddingCache, graph_fingerprint, graph_key
from repro.core.health import CircuitBreaker
from repro.core.profile import (TraceRecorder, cost_key, fit_cost_model,
                                trace_features)
from repro.core.validate import GraphValidationError, validate_pairs

PATHS = ("reference", "two_kernel", "bucketed_mega", "packed_dense",
         "packed_sparse", "embedding_cache")
PACKED_PATHS = ("packed_dense", "packed_sparse")
#: paths with a VJP-capable executor (DESIGN.md §11): the dense reference
#: is plain jnp, the packed paths have custom-VJP twins in kernels/grad.py.
#: The bucketed paths run inside pallas_call (no autodiff rule) and the
#: embedding cache serves stale non-differentiable activations.
TRAIN_PATHS = ("reference", "packed_dense", "packed_sparse")

#: Graceful-degradation ladder (DESIGN.md §12): when a path's executor
#: fails (raises, exhausts resources, or emits non-finite scores on
#: validated inputs) the call steps down to the next rung — specialized
#: paths degrade toward the dense jnp reference, which is the terminal
#: rung and never degrades further. This is SPA-GCN's flexibility argument
#: turned into a fault-tolerance chain: every rung computes the same
#: scores, only the execution strategy changes.
DEGRADE_LADDER = {
    "packed_sparse": ("packed_dense", "bucketed_mega", "reference"),
    "packed_dense": ("bucketed_mega", "reference"),
    "bucketed_mega": ("reference",),
    "two_kernel": ("bucketed_mega", "reference"),
    "embedding_cache": ("bucketed_mega", "reference"),
    "reference": (),
}
#: Training ladder: restricted to the VJP-capable executors (§11).
TRAIN_DEGRADE_LADDER = {
    "packed_sparse": ("packed_dense", "reference"),
    "packed_dense": ("reference",),
    "reference": (),
}


def _rung_name(path: str, devices: int) -> str:
    """Display/counter name of a ladder rung: the bare path single-device,
    `path@Nd` when the rung runs tile-sharded over N mesh devices
    (DESIGN.md §16) — matches `profile.cost_key` so counters, breaker
    snapshots and cost-model keys all read the same."""
    return path if devices <= 1 else f"{path}@{int(devices)}d"

#: Fault-injection seam (DESIGN.md §12): `repro.testing.faults.inject()`
#: arms this with a hook; production leaves it None (one attribute read per
#: executor call). The engine routes EVERY kernel/executor invocation
#: through `_call` so injected faults hit warm engines too — their jitted
#: callables are cached on the instance, out of monkeypatching's reach.
_FAULT_HOOK: Callable | None = None


def _call(site: str, thunk: Callable):
    hook = _FAULT_HOOK
    return hook(site, thunk) if hook is not None else thunk()


class NonFiniteOutput(RuntimeError):
    """An executor produced NaN/Inf scores (or grads) for inputs that
    passed validation — treated exactly like a crash by the degradation
    ladder: silently-corrupting kernels must not outrank loud ones."""


def tree_all_finite(*trees) -> bool:
    """True iff every floating leaf of the given pytrees is finite —
    the one-line guard `train.step` uses to skip poisoned update steps
    (DESIGN.md §12) without naming any dispatch path."""
    for leaf in jax.tree.leaves(trees):
        arr = np.asarray(leaf)
        if (np.issubdtype(arr.dtype, np.floating)
                and not np.isfinite(arr).all()):
            return False
    return True


def _empty_idx() -> np.ndarray:
    return np.empty(0, np.int64)


@dataclass(frozen=True)
class WorkloadStats:
    """Measured properties of one score() call's pairs — the dispatch
    inputs. Densities are measured from the adjacency non-zeros, never
    assumed from the generator."""
    n_pairs: int
    max_nodes: int = 0
    mean_nodes: float = 0.0
    avg_degree: float = 0.0      # 2E/V over all graphs (self loops excluded)
    density: float = 0.0         # nnz / sum(n_i^2)
    has_labels: bool = True      # every graph carries int node labels


@dataclass(frozen=True)
class ScorePlan:
    """An explicit, inspectable dispatch decision for one batch of pairs.

    `path` scores the pairs at `fit_idx`; pairs at `over_idx` (too large for
    the packed node budget, or the whole batch on bucketed paths) run on
    `fallback` through power-of-two size buckets. `reason` is the
    human-readable dispatch rationale (surfaced by examples/simgnn_search).

    On the embedding-cached path the plan additionally carries the hit/miss
    split (DESIGN.md §10): `graph_keys` holds the canonical key of every
    graph the plan covers (all lhs graphs, then all rhs graphs, quarantined
    pairs excluded), `cached_idx` the positions whose embedding is already
    resident, and `to_embed_idx` the positions that will actually be
    embedded — the *first* occurrence of each uncached key, so
    `len(to_embed_idx)` is the number of GCN+Att runs a `score()` will pay
    (later duplicates ride along for free).

    Fault-tolerance fields (DESIGN.md §12): `quarantined` holds the
    structured `InvalidGraph` records of inputs rejected by validation
    (lenient mode — those pairs score NaN and appear in neither `fit_idx`
    nor `over_idx`). After execution, the engine republishes the plan on
    `last_plan` with `degraded_from` (the rungs that failed or were
    breaker-rejected, in order) and `attempts` (executor invocations
    actually tried — 1 per work item on a healthy call).
    """
    path: str
    fallback: str
    fit_idx: np.ndarray
    over_idx: np.ndarray
    stats: WorkloadStats
    reason: str
    cached_idx: np.ndarray = field(default_factory=_empty_idx)
    to_embed_idx: np.ndarray = field(default_factory=_empty_idx)
    graph_keys: tuple = ()
    quarantined: tuple = ()
    degraded_from: tuple = ()
    attempts: int = 1
    #: two-stage retrieval (DESIGN.md §14): the top-M shortlist size the
    #: prefilter scan used before the exact rerank (0 = no prefilter ran).
    prefilter_m: int = 0
    #: device-sharded execution (DESIGN.md §16): mesh devices the planner
    #: assigned this call's packed tiles to (1 = unsharded; always 1 off-mesh
    #: and on unpacked paths). The §12 ladder's first degradation for a
    #: devices>1 plan is the same path collapsed to a single device.
    devices: int = 1
    #: measured-planner estimates (DESIGN.md §15): predicted wall seconds
    #: per candidate path when the fitted cost model drove this decision;
    #: empty when the threshold rules did (cold profile / forced path).
    cost_estimates: dict = field(default_factory=dict)


class ScoringEngine:
    """Single dispatch point from graph-pair batches to scores.

    path="auto" selects per call from measured workload statistics; any
    explicit path name in `PATHS` forces that path (oversized pairs still
    fall back to bucketed scoring — nothing kills a call). Thresholds are
    class attributes so deployments can tune them.
    """

    #: densest stream the edge-centric kernel should take: beyond ~4
    #: neighbors/node the edge list stops being much smaller than the dense
    #: block and the MXU matmul wins (benchmarks/sparse.py measures the
    #: crossover; LW-GCN/Accel-GCN report the same degree-bound regime).
    SPARSE_MAX_DEGREE = 4.0
    #: below this many pairs, FFD packing cannot fill even one tile enough
    #: to beat a single bucketed launch.
    MIN_PACK_PAIRS = 4
    #: auto flips to the embedding-cached path when at least this fraction
    #: of the call's unique graphs already have resident embeddings — below
    #: it the misses' GCN+Att recompute (now unbatched with the rest of the
    #: stream) erodes the head-only win (DESIGN.md §10 break-even).
    CACHE_MIN_HIT_FRAC = 0.5
    #: tiles per backward chunk on the packed training paths (DESIGN.md
    #: §11): the fwd+bwd of a chunk must fit cache — one monolithic
    #: backward over every tile thrashes (measured ~1.5x slower on the
    #: batch-256 stream), so the executor ALWAYS scans tile chunks,
    #: accumulating loss and grads; gradient accumulation then falls out
    #: for free (`accum_steps` just guarantees at least that many chunks).
    TRAIN_TILE_CHUNK = 16
    #: measured planner (DESIGN.md §15): a candidate path needs at least
    #: this many clean trace records before the cost model may steer it —
    #: below it, dispatch stays on the threshold rules above (the "cold"
    #: fallback, pinned bit-identical by test).
    PLANNER_MIN_SUPPORT = 8
    #: refit the cost model after this many new records (fitting is a
    #: handful of 5x5 solves — cheap, but not per-call cheap).
    PLANNER_REFIT_EVERY = 32

    def __init__(self, params, cfg, *, path: str = "auto",
                 node_budget: int | None = None,
                 edge_budget: int | None = None,
                 cache_size: int = 4096,
                 embed_with_kernels: bool = False,
                 validation: str = "lenient",
                 degrade: bool = True,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic,
                 recorder: TraceRecorder | None = None,
                 planner: str = "measured",
                 runtime=None,
                 grad_fn=None):
        if path != "auto" and path not in PATHS:
            raise ValueError(f"unknown path {path!r}; expected 'auto' or one "
                             f"of {PATHS}")
        if validation not in ("strict", "lenient", "off"):
            raise ValueError(f"unknown validation mode {validation!r}; "
                             "expected 'strict', 'lenient' or 'off'")
        if planner not in ("measured", "threshold"):
            raise ValueError(f"unknown planner mode {planner!r}; expected "
                             "'measured' or 'threshold'")
        from repro.kernels.ops import packed_node_budget

        self.params = params
        self.cfg = cfg
        self.path = path
        self.node_budget = (packed_node_budget(cfg.max_nodes)
                            if node_budget is None else node_budget)
        self.edge_budget = edge_budget
        # Bucketed-path flavor this engine instance uses (forced reference /
        # two_kernel engines bucket through themselves; every other path
        # falls back to the §7 megakernel).
        self._bucket_flavor = (path if path in ("reference", "two_kernel")
                               else "bucketed_mega")
        #: per-graph embedding LRU (DESIGN.md §10); capacity 0 disables it.
        self.cache = EmbeddingCache(cache_size)
        # Embedding executor flavor: the default pure-jnp jit keeps cached
        # scores within the 1e-6 parity band of the dense reference (the
        # embed stage is the amortized cold stage, so its speed is not the
        # point); `embed_with_kernels=True` opts indexing throughput into
        # the fused GCN+Att kernel (two-kernel stage 1, ~2e-5 parity).
        self._embed_kernels = embed_with_kernels
        self.bucket_fns: dict[int, Callable] = {}
        self.last_pack_stats: dict | None = None
        self.last_plan: ScorePlan | None = None
        self._ref_fn: Callable | None = None
        self._embed_ref_fn: Callable | None = None
        self._head_fn: Callable | None = None
        #: jitted value_and_grad executors, one per
        #: (train path, chunk, devices, grad-fn kind).
        self._train_fns: dict[tuple, Callable] = {}
        # ---- device-sharded execution (DESIGN.md §16) ----
        #: mesh + axis-role bundle (`distributed.sharding.Runtime`); None
        #: (or a mesh-less Runtime) keeps every path single-device — the
        #: engine then behaves bit-identically to its pre-mesh self.
        self.runtime = runtime
        self.n_devices = (int(runtime.n_devices)
                          if runtime is not None else 1)
        #: per-(path, device-count, tile_block) shard_map executables — the
        #: sharded twin of `bucket_fns` (jit caches per padded shape inside
        #: each entry).
        self._sharded_fns: dict[tuple[str, int, int], Callable] = {}
        #: sub-meshes over the first k mesh devices, built lazily (the §12
        #: collapse rung and the planner's pair-count clamp both shrink k).
        self._tile_meshes: dict[int, object] = {}
        #: swappable gradient-function object (train/sgf.py, paxml-style):
        #: wraps loss -> value_and_grad so clipped / DP variants slot into
        #: `loss_and_grad` without touching the executor cache logic.
        if grad_fn is None:
            from repro.train.sgf import StandardGradient
            grad_fn = StandardGradient()
        self.grad_fn = grad_fn
        #: realized COO overflow budget of past sparse packs — reused as the
        #: floor of later packs so one heavy batch doesn't make every
        #: subsequent batch re-derive (and re-compile) a different [T, E_ov]
        #: shape (the `to_edge_batch` realized-budget reuse, PR 5 satellite).
        self._overflow_floor: int = 8
        # ---- fault tolerance (DESIGN.md §12) ----
        #: "strict" raises GraphValidationError on any invalid input,
        #: "lenient" (default) quarantines per pair (NaN score), "off"
        #: skips validation (trusted in-process generators, benchmarks).
        self.validation = validation
        #: False pins every call to its planned path — failures propagate
        #: (debugging / parity harnesses); True (default) walks the ladder.
        self.degrade = degrade
        self.breaker_threshold = breaker_threshold
        self.breaker_cooldown_s = breaker_cooldown_s
        self._clock = clock
        #: per-(path, shape-class) circuit breakers, created lazily.
        self.breakers: dict[tuple, CircuitBreaker] = {}
        #: error/degradation/quarantine counters reported by `health()`.
        self.counters: Counter = Counter()
        #: bucketed callables for non-default flavors — only populated when
        #: degradation crosses flavors (e.g. bucketed_mega -> reference on a
        #: kernel-flavored engine). `bucket_fns` keeps its public int-keyed
        #: contract for the engine's own flavor.
        self._alt_bucket_fns: dict[tuple, Callable] = {}
        self._embed_fallback_fn: Callable | None = None
        self._head_fallback_fn: Callable | None = None
        # ---- measured planner (DESIGN.md §15) ----
        #: per-call trace ring (+ optional JSONL persistence) every executed
        #: work item appends to; pass a `TraceRecorder(path=...)` to persist
        #: a profile, or a shared recorder so replicas pool their samples.
        self.recorder = TraceRecorder(clock=clock) if recorder is None \
            else recorder
        #: "measured" (default): argmin the fitted per-path cost model when
        #: every candidate has `PLANNER_MIN_SUPPORT` clean records, else the
        #: threshold rules; "threshold": always the threshold rules (parity
        #: harnesses, the replay benchmark's measurement engines).
        self.planner = planner
        self._model = None
        self._model_fit_at = -1

    # ------------------------------------------------------------- planning

    def workload_stats(self, pairs: Sequence[tuple], *,
                       measure_density: bool = True) -> WorkloadStats:
        """Measure the dispatch inputs from the raw pair dicts (host numpy).

        Density measurement scans every adjacency (O(sum n_i^2)) — noise
        next to the packing planner, but pure waste on paths that never
        read it, so `plan()` disables it when the forced path ignores
        density (stats then report degree/density 0).
        """
        if not pairs:
            return WorkloadStats(0)
        sizes: list[int] = []
        nnz = 0.0
        cells = 0.0
        has_labels = True
        for g1, g2 in pairs:
            for g in (g1, g2):
                n = g["adj"].shape[0]
                sizes.append(n)
                if measure_density:
                    nnz += float(np.count_nonzero(g["adj"]))
                    cells += n * n
                has_labels = has_labels and "labels" in g
        nodes = sum(sizes)
        return WorkloadStats(
            n_pairs=len(pairs), max_nodes=max(sizes),
            mean_nodes=nodes / len(sizes),
            avg_degree=nnz / max(nodes, 1), density=nnz / max(cells, 1.0),
            has_labels=has_labels)

    def _select(self, stats: WorkloadStats, cache_hit_frac: float = 0.0, *,
                train: bool = False, n_to_embed: int = 0,
                keys_known: bool = False) -> tuple[str, str, dict]:
        """Dispatch decision: (path, reason, cost_estimates).

        Forced paths, empty calls and label-free batches are structural —
        no model can override them. Otherwise the measured planner
        (DESIGN.md §15) argmins the fitted per-path latency model when
        every candidate path has enough clean trace support; a cold or
        partially-supported profile falls back BIT-IDENTICALLY to the
        threshold rules in `_select_threshold` (pinned by test).
        """
        if self.path != "auto":
            if train and self.path not in TRAIN_PATHS:
                raise ValueError(
                    f"path {self.path!r} has no VJP-capable executor; "
                    f"training dispatch is restricted to {TRAIN_PATHS} "
                    "(DESIGN.md §11)")
            return self.path, f"forced path={self.path}", {}
        if stats.n_pairs == 0:
            return "reference", "empty call", {}
        if not stats.has_labels:
            # The packed kernels structurally require int labels (W1 row
            # gather); the bucketed megakernel is the dense-feats-capable
            # slot, though today's bucketed executor still builds one-hots
            # from labels (a dense-feats executor is ROADMAP backlog).
            # Training has no bucketed executor, so it degrades to the
            # reference (which will state the label contract on execution).
            return (("reference" if train else "bucketed_mega"),
                    "graphs without int labels cannot take a packed path",
                    {})
        est = self._planner_estimates(stats, train=train,
                                      n_to_embed=n_to_embed,
                                      keys_known=keys_known)
        if est is not None:
            # Deterministic tie-break: predicted cost, then PATHS order.
            path = min(est, key=lambda p: (est[p], PATHS.index(p)))
            ms = ", ".join(f"{p}={est[p] * 1e3:.2f}ms"
                           for p in sorted(est, key=est.get))
            return (path, f"measured cost model argmin ({ms})", est)
        path, reason = self._select_threshold(stats, cache_hit_frac,
                                              train=train)
        return path, reason, {}

    def _select_threshold(self, stats: WorkloadStats,
                          cache_hit_frac: float = 0.0, *,
                          train: bool = False) -> tuple[str, str]:
        """The hand-tuned threshold rules — the cold-profile fallback the
        measured planner must reproduce bit-identically when it lacks
        support (DESIGN.md §15; decision table pinned by
        tests/test_profile.py and the parity-matrix cold-planner test)."""
        if not train and cache_hit_frac >= self.CACHE_MIN_HIT_FRAC:
            return ("embedding_cache",
                    f"{cache_hit_frac:.0%} of unique graphs have resident "
                    f"embeddings (>= {self.CACHE_MIN_HIT_FRAC:.0%}): only "
                    "the NTN+FCN head runs")
        if stats.n_pairs < self.MIN_PACK_PAIRS:
            return (("reference" if train else "bucketed_mega"),
                    f"batch of {stats.n_pairs} too small to fill packed tiles"
                    f" (< {self.MIN_PACK_PAIRS})")
        if stats.avg_degree <= self.SPARSE_MAX_DEGREE:
            return ("packed_sparse",
                    f"measured avg degree {stats.avg_degree:.2f} <= "
                    f"{self.SPARSE_MAX_DEGREE:g}: edge list beats dense "
                    "adjacency")
        return ("packed_dense",
                f"measured avg degree {stats.avg_degree:.2f} > "
                f"{self.SPARSE_MAX_DEGREE:g}: dense MXU matmul wins")

    # ------------------------------------------ measured planner (§15)

    def _cost_model(self):
        """The fitted per-path latency model, refit lazily every
        `PLANNER_REFIT_EVERY` new records (None while the profile is too
        small for even one path)."""
        rec = self.recorder
        if rec is None or rec.total_records < self.PLANNER_MIN_SUPPORT:
            return self._model
        if (self._model_fit_at < 0
                or rec.total_records - self._model_fit_at
                >= self.PLANNER_REFIT_EVERY):
            self._model = fit_cost_model(
                rec.records(), min_support=self.PLANNER_MIN_SUPPORT)
            self._model_fit_at = rec.total_records
            self.counters["planner_refits"] += 1
        return self._model

    def _planner_estimates(self, stats: WorkloadStats, *, train: bool,
                           n_to_embed: int, keys_known: bool) -> dict | None:
        """Predicted wall seconds per candidate path, or None when the
        profile cannot steer this call (planner pinned to thresholds, no
        model yet, or any candidate below `PLANNER_MIN_SUPPORT` — partial
        support falls back whole, so the argmin never compares a measured
        path against an unmeasured one).

        Candidates are the auto-dispatchable executors: the three packed/
        bucketed scoring paths (plus the embedding-cached path whenever
        this call hashed keys — the >= 50% residency flip becomes a
        measured crossover), or `TRAIN_PATHS` under train. The dense
        reference stays out of the scoring candidate set exactly as it is
        under the threshold rules: it is the parity anchor and terminal
        degradation rung, not a latency contender.
        """
        if self.planner != "measured":
            return None
        model = self._cost_model()
        if model is None:
            return None
        # Candidate keys carry the device count the planner would actually
        # assign (profile.cost_key): an 8-device wall must never predict a
        # single-device call or vice versa (DESIGN.md §16).
        if train:
            cand = {p: cost_key(f"train:{p}", self._plan_devices(p, stats))
                    for p in TRAIN_PATHS}
        else:
            cand = {p: cost_key(p, self._plan_devices(p, stats))
                    for p in ("bucketed_mega", "packed_dense",
                              "packed_sparse")}
            if keys_known:
                cand["embedding_cache"] = "embedding_cache"
        if not model.supports(cand.values()):
            return None
        est = {}
        for path, key in cand.items():
            feats = trace_features(
                stats.n_pairs, stats.mean_nodes, stats.avg_degree,
                n_to_embed if path == "embedding_cache" else 0)
            est[path] = model.predict(key, feats)
        return est

    def _plan_devices(self, path: str, stats: WorkloadStats) -> int:
        """Mesh devices to assign a call's packed tiles to (DESIGN.md §16).

        Only the packed paths shard (their [T, ...] tile axis is the
        partition unit); the count halves until every device owns at least
        `MIN_PACK_PAIRS` pairs — a 3-pair call on an 8-device mesh runs
        single-device rather than shipping near-empty tiles to 7 chips.
        """
        nd = self.n_devices
        if nd <= 1 or path not in PACKED_PATHS:
            return 1
        while nd > 1 and stats.n_pairs < nd * self.MIN_PACK_PAIRS:
            nd //= 2
        return max(nd, 1)

    def _tile_mesh(self, devices: int):
        """1-D tile mesh over the first `devices` devices of the runtime
        mesh (cached: shard_map closures keep mesh identity stable)."""
        from jax.sharding import Mesh

        from repro.distributed.sharding import TILE_AXIS

        mesh = self._tile_meshes.get(devices)
        if mesh is None:
            devs = self.runtime.mesh.devices.reshape(-1)[:devices]
            mesh = Mesh(devs, (TILE_AXIS,))
            self._tile_meshes[devices] = mesh
        return mesh

    def _sharded_fn(self, path: str, devices: int,
                    tile_block: int) -> Callable:
        """Jitted shard_map executor for a packed path at a
        (device count, tile_block) — the per-shape-class executable cache
        the §16 refactor replaces the single global wrappers with.
        tile_block comes from `ops.sharded_tile_plan`, which
        balance-shrinks it so few tiles spread over many devices."""
        key = (path, devices, tile_block)
        fn = self._sharded_fns.get(key)
        if fn is None:
            from repro.kernels import ops

            build = (ops.build_pair_score_sparse_sharded
                     if path == "packed_sparse"
                     else ops.build_pair_score_packed_sharded)
            fn, _ = build(self._tile_mesh(devices), self.node_budget,
                          tile_block=tile_block)
            self._sharded_fns[key] = fn
        return fn

    def _record_trace(self, kind: str, path: str, n_pairs: int,
                      plan: ScorePlan, wall_s: float, *,
                      degraded: Sequence[str] = (), attempts: int = 1,
                      n_devices: int = 1):
        """Append one executed work item to the trace ring (DESIGN.md §15).
        Routed through the §12 fault seam (site "profile") and guarded:
        a failing recorder must never fail the scoring call it observes."""
        rec = self.recorder
        if rec is None:
            return
        pstats = self.last_pack_stats or {}
        occ = (float(pstats.get("occupancy_lhs", 0.0)
                     + pstats.get("occupancy_rhs", 0.0)) / 2.0
               if pstats else 0.0)
        try:
            _call("profile", lambda: rec.record(
                kind=kind, path=path, n_pairs=int(n_pairs),
                max_nodes=plan.stats.max_nodes,
                mean_nodes=plan.stats.mean_nodes,
                avg_degree=plan.stats.avg_degree,
                density=plan.stats.density, occupancy=occ,
                to_embed=len(plan.to_embed_idx),
                degraded_from=list(degraded), attempts=int(attempts),
                wall_s=float(wall_s), n_devices=int(n_devices)))
        except Exception:
            self.counters["profile_record_errors"] += 1

    def _graph_keys(self, pairs: Sequence[tuple]) -> tuple:
        """Canonical keys of every graph in the call: all lhs, then all rhs
        (the flattened order `ScorePlan.cached_idx`/`to_embed_idx` index).

        Hashes each distinct graph *object* once per call (1-vs-N batches
        repeat the query dict and hot corpus dicts many times — the id()
        memo turns 2·B WL hashes into one per unique object). The memo
        lives only for this call: id() values are not stable across GC.
        """
        memo: dict[int, bytes] = {}

        def key_of(g: dict) -> bytes:
            k = memo.get(id(g))
            if k is None:
                k = memo[id(g)] = graph_key(g)
            return k
        return tuple(key_of(p[side]) for side in (0, 1) for p in pairs)

    def plan(self, pairs: Sequence[tuple], *,
             train: bool = False) -> ScorePlan:
        """Measure the workload and decide — without running anything.

        With `train=True` the decision is restricted to the VJP-capable
        paths (`TRAIN_PATHS`, DESIGN.md §11): the cached path never steers
        (its embeddings carry no gradients), the small-batch / label-free
        degrades land on the dense reference instead of the bucketed
        megakernel, and the oversize fallback is the reference executor.

        Validation runs FIRST (DESIGN.md §12): invalid pairs are
        quarantined before any stats/packing code touches them (a malformed
        adjacency must fail as a structured record, not a shape error deep
        inside the planner). Quarantined pairs appear only in
        `plan.quarantined`; `fit_idx`/`over_idx` index the original batch
        but cover valid pairs only. Strict mode raises instead.
        """
        n = len(pairs)
        quarantined: tuple = ()
        valid_idx = np.arange(n, dtype=np.int64)
        if self.validation != "off" and n:
            valid_idx, quarantined = validate_pairs(
                pairs, n_labels=self.cfg.n_node_labels)
            if quarantined and self.validation == "strict":
                raise GraphValidationError(quarantined)
        valid = (pairs if len(valid_idx) == n
                 else [pairs[i] for i in valid_idx])
        # Density only steers the auto sparse/dense split and the sparse
        # edge budget; forced paths that ignore it skip the O(sum n_i^2)
        # adjacency scan.
        stats = self.workload_stats(
            valid, measure_density=self.path in ("auto", "packed_sparse"))
        # The cache steers dispatch only when it could hold answers: keys
        # are hashed (O(sum n_i), host-side) iff the path is forced to the
        # cached one, or auto sees a non-empty cache — a cold cache costs
        # auto streams nothing. Training never hashes: no path it may pick
        # reads the cache.
        keys: tuple = ()
        hit_frac = 0.0
        n_to_embed = 0
        if not train and len(valid) and stats.has_labels \
                and self.cache.capacity > 0 and (
                self.path == "embedding_cache"
                or (self.path == "auto" and len(self.cache))):
            keys = self._graph_keys(valid)
            unique = set(keys)
            hits = sum(1 for k in unique if k in self.cache)
            hit_frac = hits / len(unique)
            n_to_embed = len(unique) - hits
        path, reason, est = self._select(stats, hit_frac, train=train,
                                         n_to_embed=n_to_embed,
                                         keys_known=bool(keys))
        cached_idx = to_embed_idx = np.empty(0, np.int64)
        if path == "embedding_cache" and keys:
            hit = [k in self.cache for k in keys]
            cached_idx = np.flatnonzero(hit)
            first = {k: i for i, k in reversed(list(enumerate(keys)))}
            to_embed_idx = np.asarray(
                sorted(i for k, i in first.items() if not hit[i]), np.int64)
        if path in PACKED_PATHS:
            fits = np.asarray([max(g1["adj"].shape[0], g2["adj"].shape[0])
                               <= self.node_budget for g1, g2 in valid], bool)
            fit_idx = valid_idx[np.flatnonzero(fits)]
            over_idx = valid_idx[np.flatnonzero(~fits)]
        elif path == "embedding_cache":
            # The embed stage buckets internally with power-of-two overflow,
            # so nothing is oversized for this path.
            fit_idx = valid_idx
            over_idx = np.empty(0, np.int64)
        else:
            fit_idx = np.empty(0, np.int64)
            over_idx = valid_idx
        fallback = "reference" if train else self._bucket_flavor
        return ScorePlan(path=path, fallback=fallback,
                         fit_idx=fit_idx, over_idx=over_idx, stats=stats,
                         reason=reason, cached_idx=cached_idx,
                         to_embed_idx=to_embed_idx, graph_keys=keys,
                         quarantined=quarantined, cost_estimates=est,
                         devices=self._plan_devices(path, stats))

    # ------------------------------------------------------------ execution

    def _bucket_fn(self, bucket: int, flavor: str | None = None) -> Callable:
        """One cached callable per size bucket (built lazily, reused across
        calls; XLA caches one executable per padded batch shape inside).

        `flavor` overrides the engine's own bucketed flavor — used by the
        degradation ladder (e.g. a kernel-flavored engine stepping down to
        the jnp reference). The engine-flavor cache keeps its public
        int-keyed `bucket_fns` contract; other flavors live in a side cache.
        """
        from repro.core.simgnn import pair_score
        from repro.kernels import ops

        if flavor is None or flavor == self._bucket_flavor:
            flavor = self._bucket_flavor
            cache, key = self.bucket_fns, bucket
        else:
            cache, key = self._alt_bucket_fns, (flavor, bucket)
        if key not in cache:
            if flavor == "reference":
                if self._ref_fn is None:    # shared: jit caches per shape
                    self._ref_fn = jax.jit(pair_score)
                cache[key] = self._ref_fn
            elif flavor == "two_kernel":
                cache[key] = ops.simgnn_pair_score_kernel
            else:
                cache[key] = jax.jit(functools.partial(
                    ops.pair_score_megakernel,
                    block_pairs=ops.megakernel_block_pairs(bucket)))
        return cache[key]

    def _score_bucketed(self, pairs, idx: np.ndarray, out: np.ndarray,
                        flavor: str | None = None):
        from repro.core.batching import bucket_pairs

        site = flavor or self._bucket_flavor
        for bucket, (lhs, rhs, idxs) in bucket_pairs(
                pairs, self.cfg.n_node_labels, allow_oversize=True).items():
            fn = self._bucket_fn(bucket, flavor)
            s = _call(site, lambda fn=fn, lhs=lhs, rhs=rhs: fn(
                self.params, lhs.adj, lhs.feats, lhs.mask,
                rhs.adj, rhs.feats, rhs.mask))
            out[idx[idxs]] = np.asarray(s)

    def _score_packed(self, pairs, idx: np.ndarray, out: np.ndarray,
                      sparse: bool, stats: WorkloadStats):
        from repro.core.batching import pack_pairs, unpack_pair_scores
        from repro.kernels import ops

        # Fixed slots_per_tile + power-of-two tile/edge quantization keep the
        # compiled-shape set small (O(log T) executables) under varying batch
        # sizes and FFD outcomes.
        slots = max(8, self.node_budget // 4)
        if sparse:
            packed, pstats = self._pack_sparse(pairs, slots,
                                               stats.avg_degree)
            s = _call("packed_sparse",
                      lambda: ops.pair_score_sparse(self.params, packed,
                                                    quantize_tiles=True))
        else:
            packed, pstats = pack_pairs(pairs, self.node_budget,
                                        slots_per_tile=slots)
            s = _call("packed_dense",
                      lambda: ops.pair_score_packed(self.params, packed,
                                                    quantize_tiles=True))
        self.last_pack_stats = pstats
        out[idx] = unpack_pair_scores(s, packed, len(pairs))

    @staticmethod
    def _packed_score_arrays(packed, sparse: bool) -> tuple:
        """The positional array tuple a packed megakernel takes, in kernel
        order (shared by the sharded executors and `kernels.ops`)."""
        if sparse:
            e1, e2 = packed.edges.edges1, packed.edges.edges2
            o1, o2 = packed.edges.overflow1, packed.edges.overflow2
            return (e1.senders, e1.weights,
                    o1.senders, o1.receivers, o1.weights,
                    packed.labels1, packed.mask1, packed.seg1,
                    e2.senders, e2.weights,
                    o2.senders, o2.receivers, o2.weights,
                    packed.labels2, packed.mask2, packed.seg2,
                    packed.pair_mask)
        return (packed.adj1, packed.labels1, packed.mask1, packed.seg1,
                packed.adj2, packed.labels2, packed.mask2, packed.seg2,
                packed.pair_mask)

    def _score_packed_sharded(self, pairs, idx: np.ndarray, out: np.ndarray,
                              sparse: bool, stats: WorkloadStats,
                              devices: int):
        """Packed scoring with the tile axis sharded over `devices` mesh
        devices (DESIGN.md §16): pack host-side exactly as the unsharded
        executor, pad T to a power-of-two >= devices x tile_block, run the
        shard_map executor, gather [T, P] scores host-side. The fault site
        is `sharded:<path>` — a dead shard surfaces here and the §12 ladder
        collapses the call to the single-device rung."""
        from repro.core.batching import pack_pairs, unpack_pair_scores
        from repro.kernels import ops

        path = "packed_sparse" if sparse else "packed_dense"
        slots = max(8, self.node_budget // 4)
        if sparse:
            packed, pstats = self._pack_sparse(pairs, slots, stats.avg_degree)
        else:
            packed, pstats = pack_pairs(pairs, self.node_budget,
                                        slots_per_tile=slots)
        t = packed.mask1.shape[0]
        target, tile_block = ops.sharded_tile_plan(
            t, self.node_budget, devices, sparse=sparse)
        fn = self._sharded_fn(path, devices, tile_block)
        arrays = [ops._pad_batch(x, target)[0]
                  for x in self._packed_score_arrays(packed, sparse)]
        s = _call(f"sharded:{path}",
                  lambda: fn(self.params, *arrays))[:t]
        span = target // devices
        self.last_pack_stats = dict(
            pstats, devices=devices, tiles=t, tiles_padded=target,
            # live-tile fraction of each device's span (pad tiles append at
            # the end, so trailing devices absorb the padding waste).
            device_occupancy=[
                max(0, min(t - d * span, span)) / span
                for d in range(devices)])
        out[idx] = unpack_pair_scores(s, packed, len(pairs))

    def _pack_sparse(self, pairs, slots: int, avg_degree: float):
        """Shared sparse packing (scoring + training): ladder-sized edge
        budget, with the engine's realized overflow budget from earlier
        calls as the floor so one heavy batch doesn't flip the compiled
        [T, E_ov] shape back and forth across the stream."""
        from repro.core.batching import pack_pairs
        from repro.kernels import ops

        edge_budget = self.edge_budget
        if edge_budget is None:
            edge_budget = ops.packed_edge_budget(self.node_budget, avg_degree)
        packed, pstats = pack_pairs(pairs, self.node_budget,
                                    slots_per_tile=slots, with_edges=True,
                                    edge_budget=edge_budget,
                                    overflow_budget=self._overflow_floor)
        self._overflow_floor = max(self._overflow_floor,
                                   pstats["overflow_budget"])
        return packed, pstats

    # ------------------------------------- degradation + breakers (§12)

    def _shape_class(self, stats: WorkloadStats) -> tuple:
        """Power-of-two (batch, nodes) bucket a breaker is keyed on: a path
        that dies on 128-node overflow traffic keeps serving 64-node calls
        normally, and the key space stays O(log^2) like the executable set."""
        from repro.core.batching import next_pow2

        return (next_pow2(max(stats.n_pairs, 1), floor=1),
                next_pow2(max(stats.max_nodes, 1), floor=8))

    def _breaker(self, path: str, shape_class: tuple) -> CircuitBreaker:
        key = (path, shape_class)
        br = self.breakers.get(key)
        if br is None:
            br = self.breakers[key] = CircuitBreaker(
                failure_threshold=self.breaker_threshold,
                cooldown_s=self.breaker_cooldown_s, clock=self._clock)
        return br

    def _execute_rung(self, rung: str, devices: int, sub, idx: np.ndarray,
                      out: np.ndarray, plan: ScorePlan):
        if rung in PACKED_PATHS and devices > 1:
            self._score_packed_sharded(sub, idx, out,
                                       rung == "packed_sparse",
                                       plan.stats, devices)
        elif rung in PACKED_PATHS:
            self._score_packed(sub, idx, out, rung == "packed_sparse",
                               plan.stats)
        elif rung == "embedding_cache":
            self._score_cached(sub, idx, out, plan)
        else:
            self._score_bucketed(sub, idx, out, flavor=rung)

    def _ladder_rungs(self, start: str, devices: int,
                      ladder: dict) -> tuple:
        """(path, devices) rung sequence for one work item: the planned
        rung first; for a sharded start the SECOND rung is the same path
        collapsed to a single device (DESIGN.md §16 — a bad shard costs the
        mesh, never the batch), then the ordinary single-device ladder.
        degrade=False pins the call to its planned rung as before."""
        if not self.degrade:
            return ((start, devices),)
        rungs = [(start, devices)]
        if devices > 1:
            rungs.append((start, 1))
        rungs.extend((r, 1) for r in ladder.get(start, ()))
        return tuple(rungs)

    def _run_score_ladder(self, start: str, sub, idx: np.ndarray,
                          out: np.ndarray, plan: ScorePlan
                          ) -> tuple[int, list, str, int]:
        """Execute one work item (a pair subset) starting at `start`,
        stepping down `DEGRADE_LADDER` on failure (DESIGN.md §12).

        A rung fails by raising OR by producing non-finite scores for
        validated inputs (a silently-corrupting kernel). Each non-reference
        rung is guarded by its (rung-name, shape-class) breaker — sharded
        rungs breaker separately from their single-device twin, so a mesh
        with one persistently dead shard cools down while single-device
        keeps serving. While open, the rung is skipped outright and the
        next rung serves; once half-open, one probe runs. The terminal
        reference rung has no breaker and no finite check — by then NaN
        means the *model* is non-finite, which quarantine cannot rule out
        and retries cannot fix. Returns (attempts, degraded-rung names,
        the path that served, the device count it served at); re-raises
        only if every rung failed.
        """
        devices = plan.devices if start == plan.path else 1
        rungs = self._ladder_rungs(start, devices, DEGRADE_LADDER)
        sc = self._shape_class(plan.stats)
        degraded: list[str] = []
        attempts = 0
        last_err: Exception | None = None
        for rung, nd in rungs:
            name = _rung_name(rung, nd)
            terminal = rung == "reference"
            br = None if terminal else self._breaker(name, sc)
            if br is not None and not br.allow():
                self.counters[f"breaker_rejected:{name}"] += 1
                degraded.append(name)
                continue
            attempts += 1
            try:
                self._execute_rung(rung, nd, sub, idx, out, plan)
                if not terminal and not np.isfinite(out[idx]).all():
                    raise NonFiniteOutput(
                        f"{name} produced non-finite scores for validated "
                        "inputs")
                if br is not None:
                    br.record_success()
                return attempts, degraded, rung, nd
            except Exception as exc:
                if br is not None:
                    br.record_failure()
                self.counters[f"errors:{name}"] += 1
                degraded.append(name)
                last_err = exc
                if rung in PACKED_PATHS:
                    self.last_pack_stats = None   # stats of a failed attempt
        raise last_err if last_err is not None else RuntimeError(
            f"no executable rung for {start} (ladder exhausted)")

    def health(self) -> dict:
        """Inspectable fault-tolerance + planner state (DESIGN.md §12/§15):
        breaker snapshots keyed by path and shape class, error/degradation/
        quarantine counters, the embedding-LRU counters, and the measured
        planner (profile size, fitted model support + residuals)."""
        rec = self.recorder
        planner: dict = {"mode": self.planner,
                         "enabled": self._model is not None
                         and bool(self._model.weights)}
        if rec is not None:
            planner.update(records=rec.total_records,
                           records_dropped=int(
                               rec.counters["records_dropped"]),
                           record_errors=int(rec.counters["record_errors"]))
        if self._model is not None:
            planner["model"] = self._model.snapshot()
        return {
            "breakers": {
                f"{path}[pairs<={b},nodes<={n}]": br.snapshot()
                for (path, (b, n)), br in sorted(self.breakers.items())},
            "counters": dict(self.counters),
            "cache": self.cache.stats(),
            "planner": planner,
        }

    # -------------------------------------------------------- training path

    def _train_fn(self, path: str, chunk_tiles: int,
                  devices: int = 1) -> Callable:
        """One jitted value_and_grad executor per (train path, chunk size,
        device count, gradient-function kind) — cached on the engine like
        `bucket_fns`, so a training loop reuses one executable per padded
        shape. The function maps (params, targets, *arrays) -> (sum of
        squared errors, d/dparams), scanning `chunk_tiles`-tile chunks of
        the packed batch (cache blocking AND accumulation microbatching in
        one mechanism — the packed planes are packed once and only the scan
        slice moves).

        The raw loss -> value_and_grad transform is delegated to the
        engine's swappable `grad_fn` object (`train/sgf.py`, paxml-style),
        so clipped / DP variants change the executor without touching this
        cache logic. With `devices > 1` the whole chunk-scan runs under
        shard_map — each device scans only its tile span — and loss + grad
        tree are `psum`-reduced over the tile axis (DESIGN.md §16), OUTSIDE
        the grad object: per-microbatch transforms compose with the
        cross-device reduction unchanged.
        """
        key = (path, chunk_tiles, devices, self.grad_fn.cache_key)
        if key not in self._train_fns:
            import jax.numpy as jnp

            if path == "reference":
                from repro.core.simgnn import pair_score_from_labels

                def sse(params, tgt, *arrays):
                    return jnp.sum(
                        (pair_score_from_labels(params, *arrays) - tgt) ** 2)
            else:
                from repro.kernels import grad as kgrad

                score_fn = (kgrad.sparse_pair_score_grad
                            if path == "packed_sparse"
                            else kgrad.packed_pair_score_grad)

                def sse(params, tgt, *arrays):
                    # Pad pair slots score exact zero against target zero.
                    return jnp.sum((score_fn(params, *arrays) - tgt) ** 2)

            grad_fn = self.grad_fn.value_and_grad(sse)
            if path == "reference":
                fn = grad_fn
            else:
                def fn(params, tgt, *arrays):
                    t = tgt.shape[0]
                    n_chunks = t // chunk_tiles
                    if n_chunks <= 1:
                        return grad_fn(params, tgt, *arrays)

                    def chunk(x):
                        return x.reshape((n_chunks, chunk_tiles)
                                         + x.shape[1:])
                    xs = tuple(chunk(x) for x in (tgt,) + arrays)

                    def micro(acc, mb):
                        s, g = grad_fn(params, mb[0], *mb[1:])
                        return (acc[0] + s,
                                jax.tree.map(jnp.add, acc[1], g)), None
                    zero = (jnp.zeros((), jnp.float32),
                            jax.tree.map(
                                lambda p: jnp.zeros(p.shape, jnp.float32),
                                params))
                    (s, g), _ = jax.lax.scan(micro, zero, xs)
                    return s, g
                if devices > 1:
                    from jax.experimental.shard_map import shard_map
                    from jax.sharding import PartitionSpec as P

                    from repro.distributed.sharding import TILE_AXIS

                    n_arrays = 17 if path == "packed_sparse" else 9
                    scan = fn

                    def local(params, tgt, *arrays):
                        return jax.lax.psum(scan(params, tgt, *arrays),
                                            TILE_AXIS)
                    fn = shard_map(
                        local, mesh=self._tile_mesh(devices),
                        in_specs=(P(), P(TILE_AXIS))
                        + (P(TILE_AXIS),) * n_arrays,
                        out_specs=P(), check_rep=False)
            self._train_fns[key] = jax.jit(fn)
        return self._train_fns[key]

    def _packed_sse(self, params, fit_pairs, fit_targets: np.ndarray,
                    plan: ScorePlan, accum_steps: int,
                    path: str | None = None, devices: int = 1):
        """Sum-of-squared-errors + grads of the packed fit split: pack ONCE,
        scatter targets to [T, P] pair slots, pad the tile axis to a chunk
        multiple (pad tiles are all-zero: exact-zero scores, targets and
        grads), run the chunk-scanning custom-VJP executor. `path` defaults
        to the planned path; the train ladder passes the current rung.
        With `devices > 1` the tile axis pads to a devices x chunk multiple
        and runs the shard_map + psum executor (DESIGN.md §16) under the
        `sharded:train:<path>` fault site."""
        import jax.numpy as jnp

        from repro.core.batching import next_pow2, pack_pairs
        from repro.kernels import grad as kgrad

        path = plan.path if path is None else path
        sparse = path == "packed_sparse"
        slots = max(8, self.node_budget // 4)
        if sparse:
            packed, pstats = self._pack_sparse(fit_pairs, slots,
                                               plan.stats.avg_degree)
        else:
            packed, pstats = pack_pairs(fit_pairs, self.node_budget,
                                        slots_per_tile=slots)
        self.last_pack_stats = pstats

        pair_mask = np.asarray(packed.pair_mask)
        pair_index = np.asarray(packed.pair_index)
        tgt = np.zeros(pair_mask.shape, np.float32)
        live = pair_mask > 0
        tgt[live] = fit_targets[pair_index[live]]

        # Chunk small enough that accum_steps chunks exist and that padding
        # never exceeds the batch itself (all powers of two), then pad T to
        # a chunk multiple — bounded pad-tile waste (< one chunk) vs. up to
        # 2x for power-of-two T quantization. Sharded calls pad to a
        # devices x chunk multiple instead, so every device scans whole
        # chunks of its tile span.
        t = pair_mask.shape[0]
        chunk_tiles = min(self.TRAIN_TILE_CHUNK, next_pow2(t, floor=1))
        while chunk_tiles > 1 and (-(-t // chunk_tiles)) < accum_steps:
            chunk_tiles //= 2
        pad = (-t) % (chunk_tiles * max(devices, 1))

        def pad_tiles(x):
            if not pad:
                return x
            return jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], 0)

        arrays = tuple(pad_tiles(x)
                       for x in kgrad.packed_arrays(packed, sparse=sparse))
        if devices > 1:
            self.last_pack_stats = dict(pstats, devices=devices, tiles=t,
                                        tiles_padded=t + pad)
        fn = self._train_fn(path, chunk_tiles, devices)
        site = (f"sharded:train:{path}" if devices > 1
                else f"train:{path}")
        return _call(site,
                     lambda: fn(params, pad_tiles(jnp.asarray(tgt)),
                                *arrays))

    def _reference_sse(self, params, pairs, targets: np.ndarray):
        """SSE + grads of the dense-reference executor (the train-mode
        fallback for oversized pairs and tiny batches), bucketed like
        `_score_bucketed` with power-of-two overflow buckets."""
        import jax.numpy as jnp

        from repro.core.batching import bucket_pairs

        fn = self._train_fn("reference", 1)
        sse = jnp.zeros((), jnp.float32)
        grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        for _, (lhs, rhs, idxs) in bucket_pairs(
                pairs, self.cfg.n_node_labels, allow_oversize=True).items():
            s, g = _call("train:reference",
                         lambda lhs=lhs, rhs=rhs, idxs=idxs: fn(
                             params, jnp.asarray(targets[idxs]),
                             lhs.adj, lhs.labels, lhs.mask,
                             rhs.adj, rhs.labels, rhs.mask))
            sse = sse + s
            grads = jax.tree.map(jnp.add, grads, g)
        return sse, grads

    def _run_train_ladder(self, start: str, params, sub,
                          tgt: np.ndarray, plan: ScorePlan,
                          accum_steps: int) -> tuple:
        """Training twin of `_run_score_ladder`: walk the VJP-capable
        `TRAIN_DEGRADE_LADDER`, breaker-gated per (train:path, shape-class)
        — train breakers are separate from score breakers because the
        executors are (custom-VJP twins vs. pallas kernels). Non-terminal
        rungs that emit non-finite loss/grads for finite targets fail like
        crashes; the reference rung serves whatever it computes (a NaN
        there is the model's, and `train.step` skips the update).
        Like the score ladder, a sharded start collapses to its
        single-device twin before crossing paths (DESIGN.md §16). Returns
        (sse, grads, attempts, degraded, the path that served, the device
        count it served at)."""
        devices = plan.devices if start == plan.path else 1
        rungs = self._ladder_rungs(start, devices, TRAIN_DEGRADE_LADDER)
        sc = self._shape_class(plan.stats)
        degraded: list[str] = []
        attempts = 0
        last_err: Exception | None = None
        for rung, nd in rungs:
            name = _rung_name(rung, nd)
            terminal = rung == "reference"
            br = (None if terminal
                  else self._breaker(f"train:{name}", sc))
            if br is not None and not br.allow():
                self.counters[f"breaker_rejected:train:{name}"] += 1
                degraded.append(name)
                continue
            attempts += 1
            try:
                if rung in PACKED_PATHS:
                    s, g = self._packed_sse(params, sub, tgt, plan,
                                            accum_steps, path=rung,
                                            devices=nd)
                else:
                    s, g = self._reference_sse(params, sub, tgt)
                if not terminal and not tree_all_finite(s, g):
                    raise NonFiniteOutput(
                        f"train:{name} produced non-finite loss/grads for "
                        "finite targets")
                if br is not None:
                    br.record_success()
                return s, g, attempts, degraded, rung, nd
            except Exception as exc:
                if br is not None:
                    br.record_failure()
                self.counters[f"errors:train:{name}"] += 1
                degraded.append(name)
                last_err = exc
                if rung in PACKED_PATHS:
                    self.last_pack_stats = None
        raise last_err if last_err is not None else RuntimeError(
            f"no executable train rung for {start} (ladder exhausted)")

    def loss_and_grad(self, pairs: Sequence[tuple], targets, *,
                      params=None, accum_steps: int = 1):
        """MSE loss and parameter gradients for one batch of graph pairs —
        the differentiable twin of `score()` (DESIGN.md §11).

        Plans with the same `ScorePlan` machinery but restricted to the
        VJP-capable paths (`TRAIN_PATHS`); the oversize-fallback split is
        preserved with the dense reference as the fallback executor. Packed
        paths pack ONCE per call and ALWAYS scan the tiles in
        `TRAIN_TILE_CHUNK`-sized chunks (cache blocking); `accum_steps`
        (a power of two) guarantees at least that many chunks — gradient
        accumulation without re-packing, since only the scan slice moves.

        Fault tolerance (DESIGN.md §12): non-finite targets are dropped
        before planning (a poisoned label would NaN the whole SSE), invalid
        graphs are quarantined by `plan()`, and each work item walks
        `TRAIN_DEGRADE_LADDER` on executor failure. The loss is normalized
        by the number of pairs actually scored, so dropped/quarantined
        pairs do not deflate the gradient signal.

        `params` defaults to the engine's own (serving) params; a training
        loop passes its evolving copy. Returns `(loss, grads)` with
        loss = mean_i (pred_i - target_i)^2 over the scored pairs and grads
        a pytree like `params` (fp32 accumulation).
        """
        import jax.numpy as jnp

        if accum_steps < 1 or accum_steps & (accum_steps - 1):
            raise ValueError(f"accum_steps must be a power of two, got "
                             f"{accum_steps}")
        params = self.params if params is None else params
        targets = np.asarray(targets, np.float32).reshape(-1)
        if targets.shape[0] != len(pairs):
            raise ValueError(f"{len(pairs)} pairs but {targets.shape[0]} "
                             "targets")
        finite_t = np.isfinite(targets)
        if not finite_t.all():
            self.counters["nonfinite_targets"] += int((~finite_t).sum())
            keep = np.flatnonzero(finite_t)
            pairs = [pairs[i] for i in keep]
            targets = targets[keep]
        plan = self.plan(pairs, train=True)
        self.last_plan = plan
        self.last_pack_stats = None
        if plan.quarantined:
            self.counters["quarantined_graphs"] += len(plan.quarantined)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if not len(pairs):
            return jnp.zeros((), jnp.float32), zero
        if not plan.stats.has_labels:
            raise ValueError(
                "graphs must carry int node labels ('labels'); a dense-"
                "feats executor is not implemented yet (ROADMAP open item)")
        sse = jnp.zeros((), jnp.float32)
        grads = zero
        degraded: list[str] = []
        attempts = 0
        n_live = 0
        for start, idx in ((plan.path, plan.fit_idx),
                           ("reference", plan.over_idx)):
            if not len(idx):
                continue
            t0 = self._clock()
            s, g, a, d, rung, nd = self._run_train_ladder(
                start, params, [pairs[i] for i in idx], targets[idx],
                plan, accum_steps)
            jax.block_until_ready(g)
            self._record_trace("train", f"train:{rung}", len(idx), plan,
                               self._clock() - t0, degraded=d, attempts=a,
                               n_devices=nd)
            sse = sse + s
            grads = jax.tree.map(jnp.add, grads, g)
            attempts += a
            degraded.extend(d)
            n_live += len(idx)
        self.last_plan = replace(plan, degraded_from=tuple(degraded),
                                 attempts=max(attempts, 1))
        if not n_live:
            return jnp.zeros((), jnp.float32), zero
        n = float(n_live)
        return sse / n, jax.tree.map(lambda x: x / n, grads)

    # ------------------------------------------------- embedding-cached path

    def _embed_fn(self) -> Callable:
        """(params, adj, feats, mask) -> [B, F] graph embeddings, jit-cached.

        Pure-jnp `graph_embedding` by default (the parity anchor — per-graph
        results are bit-identical across batch compositions and pad widths,
        which the cache correctness tests rely on); the fused GCN+Att kernel
        when the engine was built with `embed_with_kernels=True`.
        """
        if self._embed_ref_fn is None:
            if self._embed_kernels:
                from repro.core.gcn import normalized_adjacency
                from repro.kernels import ops

                def fused(params, adj, feats, mask):
                    a_norm = normalized_adjacency(adj, mask)
                    return ops.graph_embeddings_fused(params, a_norm, feats,
                                                      mask)
                self._embed_ref_fn = fused
            else:
                from repro.core.simgnn import graph_embedding
                self._embed_ref_fn = jax.jit(graph_embedding)
        return self._embed_ref_fn

    def embed_graphs(self, graphs: Sequence[dict], *,
                     keys: Sequence[bytes] | None = None) -> np.ndarray:
        """Per-graph `[F]` GCN+Att embeddings through the cache.

        Hits are served from the LRU; unique misses are bucketed by size
        (power-of-two overflow for oversized graphs), embedded in batched
        calls, and inserted. Returns `[len(graphs), F]` float32 in input
        order — duplicates within one call are embedded once.
        """
        from repro.core.batching import bucket_for, pad_graphs

        f = self.cfg.gcn_dims[-1]
        out = np.zeros((len(graphs), f), np.float32)
        if not graphs:
            return out
        if keys is None:
            keys = [graph_key(g) for g in graphs]
        # One LRU access per *unique* key: duplicates within a call are one
        # logical lookup (hit/miss counters stay per-graph, not per-slot).
        # Lookups carry the structural fingerprint so a WL-key collision
        # evicts-and-misses instead of serving another graph's row.
        seen: dict[bytes, np.ndarray | None] = {}
        misses: "OrderedDict[bytes, list[int]]" = OrderedDict()
        for i, k in enumerate(keys):
            if k not in seen:
                seen[k] = self.cache.get(k, graph_fingerprint(graphs[i]))
            emb = seen[k]
            if emb is not None:
                out[i] = emb
            else:
                misses.setdefault(k, []).append(i)
        if not misses:
            return out
        buckets: dict[int, list[tuple[bytes, dict]]] = {}
        for k, idxs in misses.items():
            g = graphs[idxs[0]]
            b = bucket_for(g["adj"].shape[0], allow_oversize=True)
            buckets.setdefault(b, []).append((k, g))
        embed = self._embed_fn()
        for b, items in sorted(buckets.items()):
            batch = pad_graphs([g for _, g in items],
                               self.cfg.n_node_labels, b)

            def run(site, fn):
                h = np.asarray(_call(site, lambda: fn(
                    self.params, batch.adj, batch.feats, batch.mask)),
                    np.float32)
                if not np.isfinite(h).all():
                    raise NonFiniteOutput(
                        f"{site} produced non-finite embeddings")
                return h

            # Per-bucket degradation (DESIGN.md §12): a failing embed batch
            # retries once on the pure-jnp reference embedder; if that also
            # fails, ONLY this bucket's graphs are dropped (NaN rows, never
            # cached) — the other buckets and every cache hit still serve.
            try:
                hg = run("embed", embed)
            except Exception:
                self.counters["errors:embed"] += 1
                try:
                    hg = run("embed_fallback", self._embed_fallback())
                    self.counters["embed_fallbacks"] += 1
                except Exception:
                    self.counters["errors:embed_fallback"] += 1
                    self.counters["embed_dropped_graphs"] += len(items)
                    for k, _ in items:
                        out[misses[k]] = np.nan
                    continue
            for (k, g), emb in zip(items, hg):
                emb = emb.copy()
                emb.setflags(write=False)
                self.cache.put(k, emb, graph_fingerprint(g))
                out[misses[k]] = emb
        return out

    def prefilter_topm(self, qv, corpus_emb, m: int, *,
                       block_cols: int | None = None,
                       ntn_operands: tuple | None = None
                       ) -> tuple[np.ndarray, np.ndarray]:
        """Blocked streaming top-M prefilter scan (DESIGN.md §14).

        The first stage of a two-stage query: shortlist `m` corpus rows per
        query without ever materializing the [Q, N] score matrix. With
        `ntn_operands=(uq, dq)` (from `kernels.retrieval.collapse_query_ntn`)
        the scan runs the exact streamed NTN+FCN proxy; otherwise `qv` is
        dotted against the corpus directly (raw or calibrated vectors).
        Routed through the §12 fault seam (site "prefilter") so chaos tests
        and real kernel failures surface here — callers degrade to the
        exact full scan and the counters record it. Raises on any failure;
        corrupt output (non-finite scores from finite inputs, out-of-range
        indices) is promoted to `NonFiniteOutput` rather than served.
        """
        from repro.kernels import retrieval

        self.counters["prefilter_calls"] += 1
        try:
            if ntn_operands is not None:
                uq, dq = ntn_operands
                s, i = _call("prefilter", lambda: retrieval.blocked_topm_ntn(
                    uq, dq, corpus_emb, self.params["fcn"], m,
                    block_cols=block_cols))
            else:
                s, i = _call("prefilter", lambda: retrieval.blocked_topm(
                    qv, corpus_emb, m, block_cols=block_cols))
            s, i = np.asarray(s, np.float32), np.asarray(i)
            n = np.asarray(corpus_emb).shape[0]
            if i.size and not ((i >= 0) & (i < n)).all():
                raise NonFiniteOutput(
                    "prefilter returned out-of-range candidate indices")
            if s.size and np.isnan(s).any():
                raise NonFiniteOutput("prefilter returned NaN scores")
        except Exception:
            self.counters["errors:prefilter"] += 1
            raise
        self.counters["prefilter_queries"] += len(np.asarray(qv))
        return s, i

    def _embed_fallback(self) -> Callable:
        """Pure-jnp reference embedder used as the per-bucket retry when the
        configured embed executor fails — always available, kernel-free."""
        if self._embed_fallback_fn is None:
            from repro.core.simgnn import graph_embedding
            self._embed_fallback_fn = jax.jit(graph_embedding)
        return self._embed_fallback_fn

    def _head(self) -> Callable:
        if self._head_fn is None:
            if self._bucket_flavor == "reference":
                self._head_fn = self._head_fallback()
            else:
                from repro.kernels import ops

                def head(params, h1, h2):
                    bp = max(8, min(128, -(-h1.shape[0] // 8) * 8))
                    return ops.pair_scores_fused(params, h1, h2,
                                                 block_pairs=bp)
                self._head_fn = head
        return self._head_fn

    def _head_fallback(self) -> Callable:
        if self._head_fallback_fn is None:
            from repro.core.simgnn import fcn_head, ntn_scores

            self._head_fallback_fn = jax.jit(
                lambda params, h1, h2: fcn_head(
                    params["fcn"], ntn_scores(params["ntn"], h1, h2)))
        return self._head_fallback_fn

    def pair_scores_from_embeddings(self, hg1, hg2) -> np.ndarray:
        """Batched NTN+FCN head on precomputed `[B, F]` graph embeddings —
        the entire per-query cost of a warm 1-vs-N search (DESIGN.md §10).
        Runs the fused head kernel (`kernels/simgnn_head.py`) except on
        forced-reference engines, which stay kernel-free. A failing or
        NaN-emitting head retries once on the jnp reference head; pairs
        whose *embeddings* are already NaN (dropped embed buckets) score
        NaN without tripping the retry."""
        import jax.numpy as jnp

        hg1 = np.asarray(hg1, np.float32)
        hg2 = np.asarray(hg2, np.float32)
        row_ok = (np.isfinite(hg1).all(axis=-1)
                  & np.isfinite(hg2).all(axis=-1))
        h1 = jnp.asarray(hg1)
        h2 = jnp.asarray(hg2)

        def run(site, fn):
            s = np.asarray(_call(site, lambda: fn(self.params, h1, h2)),
                           np.float32)
            if not np.isfinite(s[row_ok]).all():
                raise NonFiniteOutput(
                    f"{site} produced non-finite scores for finite "
                    "embeddings")
            return s

        try:
            return run("head", self._head())
        except Exception:
            self.counters["errors:head"] += 1
            return run("head_fallback", self._head_fallback())

    def _score_cached(self, pairs, idx: np.ndarray, out: np.ndarray,
                      plan: ScorePlan):
        n = len(pairs)
        keys = plan.graph_keys if len(plan.graph_keys) == 2 * n else None
        hg1 = self.embed_graphs([p[0] for p in pairs],
                                keys=keys[:n] if keys else None)
        hg2 = self.embed_graphs([p[1] for p in pairs],
                                keys=keys[n:] if keys else None)
        out[idx] = self.pair_scores_from_embeddings(hg1, hg2)

    def score(self, pairs: Sequence[tuple]) -> np.ndarray:
        """Score a batch of graph-pair dicts in original order.

        Fault tolerance (DESIGN.md §12): quarantined pairs score NaN;
        each work item (the planned path's fit split, the fallback's
        oversize split) walks the degradation ladder on executor failure.
        The executed plan — including `degraded_from` and `attempts` — is
        republished on `last_plan`.
        """
        out = np.zeros(len(pairs), np.float32)
        plan = self.plan(pairs)
        self.last_plan = plan
        # Stats describe the *latest* call only: a bucketed call must not
        # leave a previous packed call's occupancy lying around.
        self.last_pack_stats = None
        if plan.quarantined:
            self.counters["quarantined_graphs"] += len(plan.quarantined)
            out[sorted({rec.pair for rec in plan.quarantined})] = np.nan
        if len(plan.fit_idx) or len(plan.over_idx):
            if not plan.stats.has_labels:
                # Every executor today builds features from int labels
                # (pad_graphs one-hots, packed kernels gather W1 rows); fail
                # with the contract instead of a KeyError deep inside
                # padding.
                raise ValueError(
                    "graphs must carry int node labels ('labels'); a dense-"
                    "feats executor is not implemented yet (ROADMAP open "
                    "item)")
            degraded: list[str] = []
            attempts = 0
            for start, idx in ((plan.path, plan.fit_idx),
                               (plan.fallback, plan.over_idx)):
                if not len(idx):
                    continue
                t0 = self._clock()
                a, d, rung, nd = self._run_score_ladder(
                    start, [pairs[i] for i in idx], idx, out, plan)
                self._record_trace("score", rung, len(idx), plan,
                                   self._clock() - t0, degraded=d,
                                   attempts=a, n_devices=nd)
                attempts += a
                degraded.extend(d)
            self.last_plan = replace(plan, degraded_from=tuple(degraded),
                                     attempts=max(attempts, 1))
        return out

    __call__ = score
