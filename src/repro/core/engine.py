"""ScoringEngine — the unified path-selection layer for SimGNN pair scoring
(DESIGN.md §9, §10).

Six scoring paths coexist in this codebase, each fastest somewhere:

  reference      pure-jnp `core.simgnn.pair_score`, bucketed; the parity
                 anchor and the no-kernels fallback.
  two_kernel     fused GCN+Att then fused NTN+FCN head (embeddings
                 round-trip HBM); building blocks for embedding-only
                 callers, benchmark comparator.
  bucketed_mega  ONE pallas_call per size bucket (DESIGN.md §7); handles
                 any feature kind, serves as the oversize fallback.
  packed_dense   FFD node-packed segment-ID tiles, dense block-diagonal
                 adjacency matmul (DESIGN.md §8); wins on dense-adjacency
                 streams.
  packed_sparse  packed tiles aggregated from the A' non-zero edge list
                 (DESIGN.md §9); wins on sparse (AIDS-like) streams —
                 the paper's own workload.
  embedding_cache  per-graph GCN+Att embeddings served from an LRU keyed
                 by a canonical graph hash, only the NTN+FCN head runs per
                 query (DESIGN.md §10); wins on 1-vs-N search where the
                 corpus side recurs across queries.

Before this layer existed, the routing logic lived as ad-hoc branching
inside `serve.batching.simgnn_query_server`. The engine makes the decision
explicit and inspectable: `plan()` measures the workload (batch size, node
counts, *measured* edge density, label kind) and returns a `ScorePlan`
naming the chosen path, the pairs it covers, the oversize fallback split
and the reason — `score()` then executes it. The serving wrapper is a thin
shim that keeps its public `score_fn` contract.

Since DESIGN.md §11 the engine dispatches BOTH directions of the model:
`loss_and_grad()` plans with the same machinery but restricts dispatch to
the VJP-capable paths (`TRAIN_PATHS`: reference | packed_dense |
packed_sparse — the packed executors are the custom-VJP jnp twins in
`kernels/grad.py`, since `pallas_call` has no autodiff rule), packs once
per batch, and reuses the packed layout across gradient-accumulation
microbatches. `train.step.build_simgnn_train_step` is the thin training
shim, exactly as the query server is the thin serving shim.

All compiled-callable caches (one per size bucket, `bucket_fns`) and packing
statistics (`last_pack_stats`) live on the engine instance, so a serving
process holds exactly one engine per model and every executable is reused
across calls (the paper's 'customize per workload' principle, Table 2).
"""

from __future__ import annotations

import functools
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import numpy as np

from repro.core.cache import EmbeddingCache, graph_key

PATHS = ("reference", "two_kernel", "bucketed_mega", "packed_dense",
         "packed_sparse", "embedding_cache")
PACKED_PATHS = ("packed_dense", "packed_sparse")
#: paths with a VJP-capable executor (DESIGN.md §11): the dense reference
#: is plain jnp, the packed paths have custom-VJP twins in kernels/grad.py.
#: The bucketed paths run inside pallas_call (no autodiff rule) and the
#: embedding cache serves stale non-differentiable activations.
TRAIN_PATHS = ("reference", "packed_dense", "packed_sparse")


def _empty_idx() -> np.ndarray:
    return np.empty(0, np.int64)


@dataclass(frozen=True)
class WorkloadStats:
    """Measured properties of one score() call's pairs — the dispatch
    inputs. Densities are measured from the adjacency non-zeros, never
    assumed from the generator."""
    n_pairs: int
    max_nodes: int = 0
    mean_nodes: float = 0.0
    avg_degree: float = 0.0      # 2E/V over all graphs (self loops excluded)
    density: float = 0.0         # nnz / sum(n_i^2)
    has_labels: bool = True      # every graph carries int node labels


@dataclass(frozen=True)
class ScorePlan:
    """An explicit, inspectable dispatch decision for one batch of pairs.

    `path` scores the pairs at `fit_idx`; pairs at `over_idx` (too large for
    the packed node budget, or the whole batch on bucketed paths) run on
    `fallback` through power-of-two size buckets. `reason` is the
    human-readable dispatch rationale (surfaced by examples/simgnn_search).

    On the embedding-cached path the plan additionally carries the hit/miss
    split (DESIGN.md §10): `graph_keys` holds the canonical key of every
    graph in the call (all lhs graphs, then all rhs graphs), `cached_idx`
    the positions whose embedding is already resident, and `to_embed_idx`
    the positions that will actually be embedded — the *first* occurrence
    of each uncached key, so `len(to_embed_idx)` is the number of GCN+Att
    runs a `score()` will pay (later duplicates ride along for free).
    """
    path: str
    fallback: str
    fit_idx: np.ndarray
    over_idx: np.ndarray
    stats: WorkloadStats
    reason: str
    cached_idx: np.ndarray = field(default_factory=_empty_idx)
    to_embed_idx: np.ndarray = field(default_factory=_empty_idx)
    graph_keys: tuple = ()


class ScoringEngine:
    """Single dispatch point from graph-pair batches to scores.

    path="auto" selects per call from measured workload statistics; any
    explicit path name in `PATHS` forces that path (oversized pairs still
    fall back to bucketed scoring — nothing kills a call). Thresholds are
    class attributes so deployments can tune them.
    """

    #: densest stream the edge-centric kernel should take: beyond ~4
    #: neighbors/node the edge list stops being much smaller than the dense
    #: block and the MXU matmul wins (benchmarks/sparse.py measures the
    #: crossover; LW-GCN/Accel-GCN report the same degree-bound regime).
    SPARSE_MAX_DEGREE = 4.0
    #: below this many pairs, FFD packing cannot fill even one tile enough
    #: to beat a single bucketed launch.
    MIN_PACK_PAIRS = 4
    #: auto flips to the embedding-cached path when at least this fraction
    #: of the call's unique graphs already have resident embeddings — below
    #: it the misses' GCN+Att recompute (now unbatched with the rest of the
    #: stream) erodes the head-only win (DESIGN.md §10 break-even).
    CACHE_MIN_HIT_FRAC = 0.5
    #: tiles per backward chunk on the packed training paths (DESIGN.md
    #: §11): the fwd+bwd of a chunk must fit cache — one monolithic
    #: backward over every tile thrashes (measured ~1.5x slower on the
    #: batch-256 stream), so the executor ALWAYS scans tile chunks,
    #: accumulating loss and grads; gradient accumulation then falls out
    #: for free (`accum_steps` just guarantees at least that many chunks).
    TRAIN_TILE_CHUNK = 16

    def __init__(self, params, cfg, *, path: str = "auto",
                 node_budget: int | None = None,
                 edge_budget: int | None = None,
                 cache_size: int = 4096,
                 embed_with_kernels: bool = False):
        if path != "auto" and path not in PATHS:
            raise ValueError(f"unknown path {path!r}; expected 'auto' or one "
                             f"of {PATHS}")
        from repro.kernels.ops import packed_node_budget

        self.params = params
        self.cfg = cfg
        self.path = path
        self.node_budget = (packed_node_budget(cfg.max_nodes)
                            if node_budget is None else node_budget)
        self.edge_budget = edge_budget
        # Bucketed-path flavor this engine instance uses (forced reference /
        # two_kernel engines bucket through themselves; every other path
        # falls back to the §7 megakernel).
        self._bucket_flavor = (path if path in ("reference", "two_kernel")
                               else "bucketed_mega")
        #: per-graph embedding LRU (DESIGN.md §10); capacity 0 disables it.
        self.cache = EmbeddingCache(cache_size)
        # Embedding executor flavor: the default pure-jnp jit keeps cached
        # scores within the 1e-6 parity band of the dense reference (the
        # embed stage is the amortized cold stage, so its speed is not the
        # point); `embed_with_kernels=True` opts indexing throughput into
        # the fused GCN+Att kernel (two-kernel stage 1, ~2e-5 parity).
        self._embed_kernels = embed_with_kernels
        self.bucket_fns: dict[int, Callable] = {}
        self.last_pack_stats: dict | None = None
        self.last_plan: ScorePlan | None = None
        self._ref_fn: Callable | None = None
        self._embed_ref_fn: Callable | None = None
        self._head_fn: Callable | None = None
        #: jitted value_and_grad executors, one per (train path, accum).
        self._train_fns: dict[tuple[str, int], Callable] = {}
        #: realized COO overflow budget of past sparse packs — reused as the
        #: floor of later packs so one heavy batch doesn't make every
        #: subsequent batch re-derive (and re-compile) a different [T, E_ov]
        #: shape (the `to_edge_batch` realized-budget reuse, PR 5 satellite).
        self._overflow_floor: int = 8

    # ------------------------------------------------------------- planning

    def workload_stats(self, pairs: Sequence[tuple], *,
                       measure_density: bool = True) -> WorkloadStats:
        """Measure the dispatch inputs from the raw pair dicts (host numpy).

        Density measurement scans every adjacency (O(sum n_i^2)) — noise
        next to the packing planner, but pure waste on paths that never
        read it, so `plan()` disables it when the forced path ignores
        density (stats then report degree/density 0).
        """
        if not pairs:
            return WorkloadStats(0)
        sizes: list[int] = []
        nnz = 0.0
        cells = 0.0
        has_labels = True
        for g1, g2 in pairs:
            for g in (g1, g2):
                n = g["adj"].shape[0]
                sizes.append(n)
                if measure_density:
                    nnz += float(np.count_nonzero(g["adj"]))
                    cells += n * n
                has_labels = has_labels and "labels" in g
        nodes = sum(sizes)
        return WorkloadStats(
            n_pairs=len(pairs), max_nodes=max(sizes),
            mean_nodes=nodes / len(sizes),
            avg_degree=nnz / max(nodes, 1), density=nnz / max(cells, 1.0),
            has_labels=has_labels)

    def _select(self, stats: WorkloadStats, cache_hit_frac: float = 0.0, *,
                train: bool = False) -> tuple[str, str]:
        if self.path != "auto":
            if train and self.path not in TRAIN_PATHS:
                raise ValueError(
                    f"path {self.path!r} has no VJP-capable executor; "
                    f"training dispatch is restricted to {TRAIN_PATHS} "
                    "(DESIGN.md §11)")
            return self.path, f"forced path={self.path}"
        if stats.n_pairs == 0:
            return "reference", "empty call"
        if not stats.has_labels:
            # The packed kernels structurally require int labels (W1 row
            # gather); the bucketed megakernel is the dense-feats-capable
            # slot, though today's bucketed executor still builds one-hots
            # from labels (a dense-feats executor is ROADMAP backlog).
            # Training has no bucketed executor, so it degrades to the
            # reference (which will state the label contract on execution).
            return (("reference" if train else "bucketed_mega"),
                    "graphs without int labels cannot take a packed path")
        if not train and cache_hit_frac >= self.CACHE_MIN_HIT_FRAC:
            return ("embedding_cache",
                    f"{cache_hit_frac:.0%} of unique graphs have resident "
                    f"embeddings (>= {self.CACHE_MIN_HIT_FRAC:.0%}): only "
                    "the NTN+FCN head runs")
        if stats.n_pairs < self.MIN_PACK_PAIRS:
            return (("reference" if train else "bucketed_mega"),
                    f"batch of {stats.n_pairs} too small to fill packed tiles"
                    f" (< {self.MIN_PACK_PAIRS})")
        if stats.avg_degree <= self.SPARSE_MAX_DEGREE:
            return ("packed_sparse",
                    f"measured avg degree {stats.avg_degree:.2f} <= "
                    f"{self.SPARSE_MAX_DEGREE:g}: edge list beats dense "
                    "adjacency")
        return ("packed_dense",
                f"measured avg degree {stats.avg_degree:.2f} > "
                f"{self.SPARSE_MAX_DEGREE:g}: dense MXU matmul wins")

    def _graph_keys(self, pairs: Sequence[tuple]) -> tuple:
        """Canonical keys of every graph in the call: all lhs, then all rhs
        (the flattened order `ScorePlan.cached_idx`/`to_embed_idx` index).

        Hashes each distinct graph *object* once per call (1-vs-N batches
        repeat the query dict and hot corpus dicts many times — the id()
        memo turns 2·B WL hashes into one per unique object). The memo
        lives only for this call: id() values are not stable across GC.
        """
        memo: dict[int, bytes] = {}

        def key_of(g: dict) -> bytes:
            k = memo.get(id(g))
            if k is None:
                k = memo[id(g)] = graph_key(g)
            return k
        return tuple(key_of(p[side]) for side in (0, 1) for p in pairs)

    def plan(self, pairs: Sequence[tuple], *,
             train: bool = False) -> ScorePlan:
        """Measure the workload and decide — without running anything.

        With `train=True` the decision is restricted to the VJP-capable
        paths (`TRAIN_PATHS`, DESIGN.md §11): the cached path never steers
        (its embeddings carry no gradients), the small-batch / label-free
        degrades land on the dense reference instead of the bucketed
        megakernel, and the oversize fallback is the reference executor.
        """
        # Density only steers the auto sparse/dense split and the sparse
        # edge budget; forced paths that ignore it skip the O(sum n_i^2)
        # adjacency scan.
        stats = self.workload_stats(
            pairs, measure_density=self.path in ("auto", "packed_sparse"))
        # The cache steers dispatch only when it could hold answers: keys
        # are hashed (O(sum n_i), host-side) iff the path is forced to the
        # cached one, or auto sees a non-empty cache — a cold cache costs
        # auto streams nothing. Training never hashes: no path it may pick
        # reads the cache.
        keys: tuple = ()
        hit_frac = 0.0
        if not train and len(pairs) and stats.has_labels \
                and self.cache.capacity > 0 and (
                self.path == "embedding_cache"
                or (self.path == "auto" and len(self.cache))):
            keys = self._graph_keys(pairs)
            unique = set(keys)
            hit_frac = (sum(1 for k in unique if k in self.cache)
                        / len(unique))
        path, reason = self._select(stats, hit_frac, train=train)
        cached_idx = to_embed_idx = np.empty(0, np.int64)
        if path == "embedding_cache" and keys:
            hit = [k in self.cache for k in keys]
            cached_idx = np.flatnonzero(hit)
            first = {k: i for i, k in reversed(list(enumerate(keys)))}
            to_embed_idx = np.asarray(
                sorted(i for k, i in first.items() if not hit[i]), np.int64)
        if path in PACKED_PATHS:
            fits = np.asarray([max(g1["adj"].shape[0], g2["adj"].shape[0])
                               <= self.node_budget for g1, g2 in pairs], bool)
            fit_idx = np.flatnonzero(fits)
            over_idx = np.flatnonzero(~fits)
        elif path == "embedding_cache":
            # The embed stage buckets internally with power-of-two overflow,
            # so nothing is oversized for this path.
            fit_idx = np.arange(len(pairs))
            over_idx = np.empty(0, np.int64)
        else:
            fit_idx = np.empty(0, np.int64)
            over_idx = np.arange(len(pairs))
        fallback = "reference" if train else self._bucket_flavor
        return ScorePlan(path=path, fallback=fallback,
                         fit_idx=fit_idx, over_idx=over_idx, stats=stats,
                         reason=reason, cached_idx=cached_idx,
                         to_embed_idx=to_embed_idx, graph_keys=keys)

    # ------------------------------------------------------------ execution

    def _bucket_fn(self, bucket: int) -> Callable:
        """One cached callable per size bucket (built lazily, reused across
        calls; XLA caches one executable per padded batch shape inside)."""
        if bucket not in self.bucket_fns:
            from repro.core.simgnn import pair_score
            from repro.kernels import ops

            if self._bucket_flavor == "reference":
                if self._ref_fn is None:    # shared: jit caches per shape
                    self._ref_fn = jax.jit(pair_score)
                self.bucket_fns[bucket] = self._ref_fn
            elif self._bucket_flavor == "two_kernel":
                self.bucket_fns[bucket] = ops.simgnn_pair_score_kernel
            else:
                self.bucket_fns[bucket] = jax.jit(functools.partial(
                    ops.pair_score_megakernel,
                    block_pairs=ops.megakernel_block_pairs(bucket)))
        return self.bucket_fns[bucket]

    def _score_bucketed(self, pairs, idx: np.ndarray, out: np.ndarray):
        from repro.core.batching import bucket_pairs

        for bucket, (lhs, rhs, idxs) in bucket_pairs(
                pairs, self.cfg.n_node_labels, allow_oversize=True).items():
            s = self._bucket_fn(bucket)(
                self.params, lhs.adj, lhs.feats, lhs.mask,
                rhs.adj, rhs.feats, rhs.mask)
            out[idx[idxs]] = np.asarray(s)

    def _score_packed(self, pairs, idx: np.ndarray, out: np.ndarray,
                      sparse: bool, stats: WorkloadStats):
        from repro.core.batching import pack_pairs, unpack_pair_scores
        from repro.kernels import ops

        # Fixed slots_per_tile + power-of-two tile/edge quantization keep the
        # compiled-shape set small (O(log T) executables) under varying batch
        # sizes and FFD outcomes.
        slots = max(8, self.node_budget // 4)
        if sparse:
            packed, pstats = self._pack_sparse(pairs, slots,
                                               stats.avg_degree)
            s = ops.pair_score_sparse(self.params, packed,
                                      quantize_tiles=True)
        else:
            packed, pstats = pack_pairs(pairs, self.node_budget,
                                        slots_per_tile=slots)
            s = ops.pair_score_packed(self.params, packed,
                                      quantize_tiles=True)
        self.last_pack_stats = pstats
        out[idx] = unpack_pair_scores(s, packed, len(pairs))

    def _pack_sparse(self, pairs, slots: int, avg_degree: float):
        """Shared sparse packing (scoring + training): ladder-sized edge
        budget, with the engine's realized overflow budget from earlier
        calls as the floor so one heavy batch doesn't flip the compiled
        [T, E_ov] shape back and forth across the stream."""
        from repro.core.batching import pack_pairs
        from repro.kernels import ops

        edge_budget = self.edge_budget
        if edge_budget is None:
            edge_budget = ops.packed_edge_budget(self.node_budget, avg_degree)
        packed, pstats = pack_pairs(pairs, self.node_budget,
                                    slots_per_tile=slots, with_edges=True,
                                    edge_budget=edge_budget,
                                    overflow_budget=self._overflow_floor)
        self._overflow_floor = max(self._overflow_floor,
                                   pstats["overflow_budget"])
        return packed, pstats

    # -------------------------------------------------------- training path

    def _train_fn(self, path: str, chunk_tiles: int) -> Callable:
        """One jitted value_and_grad executor per (train path, chunk size) —
        cached on the engine like `bucket_fns`, so a training loop reuses
        one executable per padded shape. The function maps
        (params, targets, *arrays) -> (sum of squared errors, d/dparams),
        scanning `chunk_tiles`-tile chunks of the packed batch (cache
        blocking AND accumulation microbatching in one mechanism — the
        packed planes are packed once and only the scan slice moves)."""
        key = (path, chunk_tiles)
        if key not in self._train_fns:
            import jax.numpy as jnp

            if path == "reference":
                from repro.core.simgnn import pair_score_from_labels

                def sse(params, tgt, *arrays):
                    return jnp.sum(
                        (pair_score_from_labels(params, *arrays) - tgt) ** 2)
            else:
                from repro.kernels import grad as kgrad

                score_fn = (kgrad.sparse_pair_score_grad
                            if path == "packed_sparse"
                            else kgrad.packed_pair_score_grad)

                def sse(params, tgt, *arrays):
                    # Pad pair slots score exact zero against target zero.
                    return jnp.sum((score_fn(params, *arrays) - tgt) ** 2)

            grad_fn = jax.value_and_grad(sse)
            if path == "reference":
                fn = grad_fn
            else:
                def fn(params, tgt, *arrays):
                    t = tgt.shape[0]
                    n_chunks = t // chunk_tiles
                    if n_chunks <= 1:
                        return grad_fn(params, tgt, *arrays)

                    def chunk(x):
                        return x.reshape((n_chunks, chunk_tiles)
                                         + x.shape[1:])
                    xs = tuple(chunk(x) for x in (tgt,) + arrays)

                    def micro(acc, mb):
                        s, g = grad_fn(params, mb[0], *mb[1:])
                        return (acc[0] + s,
                                jax.tree.map(jnp.add, acc[1], g)), None
                    zero = (jnp.zeros((), jnp.float32),
                            jax.tree.map(
                                lambda p: jnp.zeros(p.shape, jnp.float32),
                                params))
                    (s, g), _ = jax.lax.scan(micro, zero, xs)
                    return s, g
            self._train_fns[key] = jax.jit(fn)
        return self._train_fns[key]

    def _packed_sse(self, params, fit_pairs, fit_targets: np.ndarray,
                    plan: ScorePlan, accum_steps: int):
        """Sum-of-squared-errors + grads of the packed fit split: pack ONCE,
        scatter targets to [T, P] pair slots, pad the tile axis to a chunk
        multiple (pad tiles are all-zero: exact-zero scores, targets and
        grads), run the chunk-scanning custom-VJP executor."""
        import jax.numpy as jnp

        from repro.core.batching import next_pow2, pack_pairs
        from repro.kernels import grad as kgrad

        sparse = plan.path == "packed_sparse"
        slots = max(8, self.node_budget // 4)
        if sparse:
            packed, pstats = self._pack_sparse(fit_pairs, slots,
                                               plan.stats.avg_degree)
        else:
            packed, pstats = pack_pairs(fit_pairs, self.node_budget,
                                        slots_per_tile=slots)
        self.last_pack_stats = pstats

        pair_mask = np.asarray(packed.pair_mask)
        pair_index = np.asarray(packed.pair_index)
        tgt = np.zeros(pair_mask.shape, np.float32)
        live = pair_mask > 0
        tgt[live] = fit_targets[pair_index[live]]

        # Chunk small enough that accum_steps chunks exist and that padding
        # never exceeds the batch itself (all powers of two), then pad T to
        # a chunk multiple — bounded pad-tile waste (< one chunk) vs. up to
        # 2x for power-of-two T quantization.
        t = pair_mask.shape[0]
        chunk_tiles = min(self.TRAIN_TILE_CHUNK, next_pow2(t, floor=1))
        while chunk_tiles > 1 and (-(-t // chunk_tiles)) < accum_steps:
            chunk_tiles //= 2
        pad = (-t) % chunk_tiles

        def pad_tiles(x):
            if not pad:
                return x
            return jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], 0)

        arrays = tuple(pad_tiles(x)
                       for x in kgrad.packed_arrays(packed, sparse=sparse))
        fn = self._train_fn(plan.path, chunk_tiles)
        return fn(params, pad_tiles(jnp.asarray(tgt)), *arrays)

    def _reference_sse(self, params, pairs, targets: np.ndarray):
        """SSE + grads of the dense-reference executor (the train-mode
        fallback for oversized pairs and tiny batches), bucketed like
        `_score_bucketed` with power-of-two overflow buckets."""
        import jax.numpy as jnp

        from repro.core.batching import bucket_pairs

        fn = self._train_fn("reference", 1)
        sse = jnp.zeros((), jnp.float32)
        grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        for _, (lhs, rhs, idxs) in bucket_pairs(
                pairs, self.cfg.n_node_labels, allow_oversize=True).items():
            s, g = fn(params, jnp.asarray(targets[idxs]),
                      lhs.adj, lhs.labels, lhs.mask,
                      rhs.adj, rhs.labels, rhs.mask)
            sse = sse + s
            grads = jax.tree.map(jnp.add, grads, g)
        return sse, grads

    def loss_and_grad(self, pairs: Sequence[tuple], targets, *,
                      params=None, accum_steps: int = 1):
        """MSE loss and parameter gradients for one batch of graph pairs —
        the differentiable twin of `score()` (DESIGN.md §11).

        Plans with the same `ScorePlan` machinery but restricted to the
        VJP-capable paths (`TRAIN_PATHS`); the oversize-fallback split is
        preserved with the dense reference as the fallback executor. Packed
        paths pack ONCE per call and ALWAYS scan the tiles in
        `TRAIN_TILE_CHUNK`-sized chunks (cache blocking); `accum_steps`
        (a power of two) guarantees at least that many chunks — gradient
        accumulation without re-packing, since only the scan slice moves.

        `params` defaults to the engine's own (serving) params; a training
        loop passes its evolving copy. Returns `(loss, grads)` with
        loss = mean_i (pred_i - target_i)^2 over the whole batch and grads
        a pytree like `params` (fp32 accumulation).
        """
        import jax.numpy as jnp

        if accum_steps < 1 or accum_steps & (accum_steps - 1):
            raise ValueError(f"accum_steps must be a power of two, got "
                             f"{accum_steps}")
        params = self.params if params is None else params
        plan = self.plan(pairs, train=True)
        self.last_plan = plan
        self.last_pack_stats = None
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if not len(pairs):
            return jnp.zeros((), jnp.float32), zero
        if not plan.stats.has_labels:
            raise ValueError(
                "graphs must carry int node labels ('labels'); a dense-"
                "feats executor is not implemented yet (ROADMAP open item)")
        targets = np.asarray(targets, np.float32).reshape(-1)
        if targets.shape[0] != len(pairs):
            raise ValueError(f"{len(pairs)} pairs but {targets.shape[0]} "
                             "targets")
        sse = jnp.zeros((), jnp.float32)
        grads = zero
        if len(plan.fit_idx):
            s, g = self._packed_sse(params, [pairs[i] for i in plan.fit_idx],
                                    targets[plan.fit_idx], plan, accum_steps)
            sse = sse + s
            grads = jax.tree.map(jnp.add, grads, g)
        if len(plan.over_idx):
            s, g = self._reference_sse(params,
                                       [pairs[i] for i in plan.over_idx],
                                       targets[plan.over_idx])
            sse = sse + s
            grads = jax.tree.map(jnp.add, grads, g)
        n = float(len(pairs))
        return sse / n, jax.tree.map(lambda x: x / n, grads)

    # ------------------------------------------------- embedding-cached path

    def _embed_fn(self) -> Callable:
        """(params, adj, feats, mask) -> [B, F] graph embeddings, jit-cached.

        Pure-jnp `graph_embedding` by default (the parity anchor — per-graph
        results are bit-identical across batch compositions and pad widths,
        which the cache correctness tests rely on); the fused GCN+Att kernel
        when the engine was built with `embed_with_kernels=True`.
        """
        if self._embed_ref_fn is None:
            if self._embed_kernels:
                from repro.core.gcn import normalized_adjacency
                from repro.kernels import ops

                def fused(params, adj, feats, mask):
                    a_norm = normalized_adjacency(adj, mask)
                    return ops.graph_embeddings_fused(params, a_norm, feats,
                                                      mask)
                self._embed_ref_fn = fused
            else:
                from repro.core.simgnn import graph_embedding
                self._embed_ref_fn = jax.jit(graph_embedding)
        return self._embed_ref_fn

    def embed_graphs(self, graphs: Sequence[dict], *,
                     keys: Sequence[bytes] | None = None) -> np.ndarray:
        """Per-graph `[F]` GCN+Att embeddings through the cache.

        Hits are served from the LRU; unique misses are bucketed by size
        (power-of-two overflow for oversized graphs), embedded in batched
        calls, and inserted. Returns `[len(graphs), F]` float32 in input
        order — duplicates within one call are embedded once.
        """
        from repro.core.batching import bucket_for, pad_graphs

        f = self.cfg.gcn_dims[-1]
        out = np.zeros((len(graphs), f), np.float32)
        if not graphs:
            return out
        if keys is None:
            keys = [graph_key(g) for g in graphs]
        # One LRU access per *unique* key: duplicates within a call are one
        # logical lookup (hit/miss counters stay per-graph, not per-slot).
        seen: dict[bytes, np.ndarray | None] = {}
        misses: "OrderedDict[bytes, list[int]]" = OrderedDict()
        for i, k in enumerate(keys):
            emb = seen[k] if k in seen else seen.setdefault(
                k, self.cache.get(k))
            if emb is not None:
                out[i] = emb
            else:
                misses.setdefault(k, []).append(i)
        if not misses:
            return out
        buckets: dict[int, list[tuple[bytes, dict]]] = {}
        for k, idxs in misses.items():
            g = graphs[idxs[0]]
            b = bucket_for(g["adj"].shape[0], allow_oversize=True)
            buckets.setdefault(b, []).append((k, g))
        embed = self._embed_fn()
        for b, items in sorted(buckets.items()):
            batch = pad_graphs([g for _, g in items],
                               self.cfg.n_node_labels, b)
            hg = np.asarray(embed(self.params, batch.adj, batch.feats,
                                  batch.mask), np.float32)
            for (k, _), emb in zip(items, hg):
                emb = emb.copy()
                emb.setflags(write=False)
                self.cache.put(k, emb)
                out[misses[k]] = emb
        return out

    def pair_scores_from_embeddings(self, hg1, hg2) -> np.ndarray:
        """Batched NTN+FCN head on precomputed `[B, F]` graph embeddings —
        the entire per-query cost of a warm 1-vs-N search (DESIGN.md §10).
        Runs the fused head kernel (`kernels/simgnn_head.py`) except on
        forced-reference engines, which stay kernel-free."""
        import jax.numpy as jnp

        if self._head_fn is None:
            if self._bucket_flavor == "reference":
                from repro.core.simgnn import fcn_head, ntn_scores

                self._head_fn = jax.jit(lambda params, h1, h2: fcn_head(
                    params["fcn"], ntn_scores(params["ntn"], h1, h2)))
            else:
                from repro.kernels import ops

                def head(params, h1, h2):
                    bp = max(8, min(128, -(-h1.shape[0] // 8) * 8))
                    return ops.pair_scores_fused(params, h1, h2,
                                                 block_pairs=bp)
                self._head_fn = head
        hg1 = jnp.asarray(np.asarray(hg1, np.float32))
        hg2 = jnp.asarray(np.asarray(hg2, np.float32))
        return np.asarray(self._head_fn(self.params, hg1, hg2), np.float32)

    def _score_cached(self, pairs, out: np.ndarray, plan: ScorePlan):
        n = len(pairs)
        keys = plan.graph_keys if len(plan.graph_keys) == 2 * n else None
        hg1 = self.embed_graphs([p[0] for p in pairs],
                                keys=keys[:n] if keys else None)
        hg2 = self.embed_graphs([p[1] for p in pairs],
                                keys=keys[n:] if keys else None)
        out[:] = self.pair_scores_from_embeddings(hg1, hg2)

    def score(self, pairs: Sequence[tuple]) -> np.ndarray:
        """Score a batch of graph-pair dicts in original order."""
        out = np.zeros(len(pairs), np.float32)
        plan = self.plan(pairs)
        self.last_plan = plan
        # Stats describe the *latest* call only: a bucketed call must not
        # leave a previous packed call's occupancy lying around.
        self.last_pack_stats = None
        if len(pairs) and not plan.stats.has_labels:
            # Every executor today builds features from int labels
            # (pad_graphs one-hots, packed kernels gather W1 rows); fail
            # with the contract instead of a KeyError deep inside padding.
            raise ValueError(
                "graphs must carry int node labels ('labels'); a dense-"
                "feats executor is not implemented yet (ROADMAP open item)")
        if plan.path == "embedding_cache":
            if len(pairs):
                self._score_cached(pairs, out, plan)
            return out
        if len(plan.fit_idx):
            self._score_packed([pairs[i] for i in plan.fit_idx],
                               plan.fit_idx, out,
                               plan.path == "packed_sparse", plan.stats)
        if len(plan.over_idx):
            self._score_bucketed([pairs[i] for i in plan.over_idx],
                                 plan.over_idx, out)
        return out

    __call__ = score
