"""Padded batching + size-bucketing for many small graphs.

This is the TPU-native replacement for SPA-GCN's dynamic zero-skipping
(DESIGN.md §2): instead of skipping zero MACs at runtime, we remove the two
dominant *structural* zero populations up front:

  * pad zeros  — graphs are padded to the smallest bucket (8/16/32/64 nodes)
                 that fits them instead of a global max, so a 10-node AIDS
                 graph costs 16^2 adjacency work, not 64^2;
  * adjacency zeros — aggregation can run from the edge list
                 (`edge_aggregate`) touching only real edges, the analogue of
                 the paper streaming only non-zero A' entries to the FPGA.

Buckets also give XLA a small, fixed set of shapes to compile (one executable
per bucket), mirroring the paper's per-layer parameter customization.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

DEFAULT_BUCKETS = (8, 16, 32, 64)


class GraphBatch(NamedTuple):
    """A batch of padded graphs. All arrays are device-ready."""
    feats: Array          # [B, N, F]  one-hot node labels (or embeddings)
    adj: Array            # [B, N, N]  raw 0/1 adjacency (no self loops)
    mask: Array           # [B, N]     1.0 for real nodes
    n_nodes: Array        # [B]        int32

    @property
    def max_nodes(self) -> int:
        return self.adj.shape[-1]


class EdgeBatch(NamedTuple):
    """Edge-list view of the same batch (for edge-level aggregation)."""
    senders: Array        # [B, E] int32, padded with 0
    receivers: Array      # [B, E] int32
    weights: Array        # [B, E] normalized A' entries (0 for pad edges)
    edge_mask: Array      # [B, E]


def pad_graphs(graphs: Sequence[dict], n_labels: int, max_nodes: int) -> GraphBatch:
    """graphs: list of {"adj": np [n,n], "labels": np [n] int}. Pads to max_nodes."""
    b = len(graphs)
    feats = np.zeros((b, max_nodes, n_labels), np.float32)
    adj = np.zeros((b, max_nodes, max_nodes), np.float32)
    mask = np.zeros((b, max_nodes), np.float32)
    n_nodes = np.zeros((b,), np.int32)
    for i, g in enumerate(graphs):
        n = g["adj"].shape[0]
        if n > max_nodes:
            raise ValueError(f"graph with {n} nodes exceeds bucket {max_nodes}")
        adj[i, :n, :n] = g["adj"]
        feats[i, np.arange(n), g["labels"]] = 1.0
        mask[i, :n] = 1.0
        n_nodes[i] = n
    return GraphBatch(jnp.asarray(feats), jnp.asarray(adj),
                      jnp.asarray(mask), jnp.asarray(n_nodes))


def bucket_for(n: int, buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"graph with {n} nodes exceeds largest bucket {buckets[-1]}")


def bucket_pairs(pairs: Sequence[tuple], n_labels: int,
                 buckets: Sequence[int] = DEFAULT_BUCKETS):
    """Group graph *pairs* by the bucket of the larger graph.

    Returns {bucket_size: (GraphBatch_lhs, GraphBatch_rhs, indices)} where
    `indices` restores the original pair order. One compiled executable per
    bucket (the 'customize per workload' principle, paper Table 2).
    """
    groups: dict[int, list] = {}
    for idx, (g1, g2) in enumerate(pairs):
        b = bucket_for(max(g1["adj"].shape[0], g2["adj"].shape[0]), buckets)
        groups.setdefault(b, []).append((idx, g1, g2))
    out = {}
    for b, items in sorted(groups.items()):
        idxs = np.asarray([i for i, _, _ in items], np.int32)
        lhs = pad_graphs([g for _, g, _ in items], n_labels, b)
        rhs = pad_graphs([g for _, _, g in items], n_labels, b)
        out[b] = (lhs, rhs, idxs)
    return out


def to_edge_batch(batch: GraphBatch, max_edges: int) -> EdgeBatch:
    """Extract the normalized-adjacency non-zeros as a padded edge list.

    Includes self loops (A+I) with symmetric normalization weights — i.e. the
    exact non-zero structure of A' that the paper streams to the FPGA.
    Host-side (numpy); small graphs make this negligible (paper §3.2.2).
    """
    from repro.core.gcn import normalized_adjacency  # late import, no cycle

    a_norm = np.asarray(normalized_adjacency(batch.adj, batch.mask))
    bsz, n, _ = a_norm.shape
    senders = np.zeros((bsz, max_edges), np.int32)
    receivers = np.zeros((bsz, max_edges), np.int32)
    weights = np.zeros((bsz, max_edges), np.float32)
    emask = np.zeros((bsz, max_edges), np.float32)
    for i in range(bsz):
        r, c = np.nonzero(a_norm[i])
        e = len(r)
        if e > max_edges:
            raise ValueError(f"{e} edges exceed max_edges={max_edges}")
        receivers[i, :e], senders[i, :e] = r, c
        weights[i, :e] = a_norm[i, r, c]
        emask[i, :e] = 1.0
    return EdgeBatch(jnp.asarray(senders), jnp.asarray(receivers),
                     jnp.asarray(weights), jnp.asarray(emask))


def edge_aggregate(edges: EdgeBatch, hw: Array) -> Array:
    """Aggregation step from the edge list: out[b, r] += w * hw[b, s].

    Touches only real edges (plus pad slots that contribute exact zeros) —
    the paper's 'read only the non-zero A' elements' (§3.2.2), expressed as a
    batched gather + segment-sum so XLA lowers it to vectorized dynamic ops.
    hw: [B, N, F] (the H·W product) -> [B, N, F].
    """
    gathered = jnp.take_along_axis(hw, edges.senders[..., None], axis=1)   # [B, E, F]
    msgs = gathered * (edges.weights * edges.edge_mask)[..., None]
    n = hw.shape[1]
    seg = jax.vmap(lambda m, r: jax.ops.segment_sum(m, r, num_segments=n))
    return seg(msgs, edges.receivers)
