"""Padded batching, size-bucketing and node-packing for many small graphs.

This is the TPU-native replacement for SPA-GCN's dynamic zero-skipping
(DESIGN.md §2): instead of skipping zero MACs at runtime, we remove the
dominant *structural* zero populations up front:

  * pad zeros  — graphs are padded to the smallest bucket (8/16/32/64 nodes)
                 that fits them instead of a global max, so a 10-node AIDS
                 graph costs 16^2 adjacency work, not 64^2;
  * packing    — `pack_pairs` goes further (DESIGN.md §8): multiple
                 variable-size graphs share one fixed `[node_budget]` tile
                 (first-fit-decreasing), with per-node segment IDs marking
                 graph membership, so a 17-node graph costs ~17 rows instead
                 of a 32-row bucket;
  * adjacency zeros — aggregation can run from the edge list
                 (`edge_aggregate`) touching only real edges, the analogue of
                 the paper streaming only non-zero A' entries to the FPGA;
                 `pack_pairs(with_edges=True)` emits the packed-CSR tile
                 form of the same non-zeros (`PackedEdges`, DESIGN.md §9)
                 that the packed-sparse megakernel aggregates from.

Buckets/tiles also give XLA a small, fixed set of shapes to compile (one
executable per bucket), mirroring the paper's per-layer customization.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

DEFAULT_BUCKETS = (8, 16, 32, 64)


class GraphBatch(NamedTuple):
    """A batch of padded graphs. All arrays are device-ready."""
    feats: Array          # [B, N, F]  one-hot node labels (or embeddings)
    adj: Array            # [B, N, N]  raw 0/1 adjacency (no self loops)
    mask: Array           # [B, N]     1.0 for real nodes
    n_nodes: Array        # [B]        int32
    labels: Array | None = None   # [B, N] int32 node labels (pad slots 0) —
                                  # the compact form of one-hot `feats`; lets
                                  # kernels gather W1 rows instead of
                                  # multiplying [N, n_labels] one-hots.

    @property
    def max_nodes(self) -> int:
        return self.adj.shape[-1]


class EdgeBatch(NamedTuple):
    """Edge-list view of the same batch (for edge-level aggregation)."""
    senders: Array        # [B, E] int32, padded with 0
    receivers: Array      # [B, E] int32
    weights: Array        # [B, E] normalized A' entries (0 for pad edges)
    edge_mask: Array      # [B, E]

    @property
    def edge_budget(self) -> int:
        """The *realized* per-graph edge budget E — after any auto-grow in
        `to_edge_batch` — so callers can carry it into the next batch of a
        stream instead of re-deriving (and re-warning) every call."""
        return self.senders.shape[-1]


def pad_graphs(graphs: Sequence[dict], n_labels: int, max_nodes: int) -> GraphBatch:
    """graphs: list of {"adj": np [n,n], "labels": np [n] int}. Pads to max_nodes."""
    b = len(graphs)
    feats = np.zeros((b, max_nodes, n_labels), np.float32)
    adj = np.zeros((b, max_nodes, max_nodes), np.float32)
    mask = np.zeros((b, max_nodes), np.float32)
    n_nodes = np.zeros((b,), np.int32)
    labels = np.zeros((b, max_nodes), np.int32)
    for i, g in enumerate(graphs):
        n = g["adj"].shape[0]
        if n > max_nodes:
            raise ValueError(f"graph with {n} nodes exceeds bucket {max_nodes}")
        adj[i, :n, :n] = g["adj"]
        feats[i, np.arange(n), g["labels"]] = 1.0
        mask[i, :n] = 1.0
        n_nodes[i] = n
        labels[i, :n] = g["labels"]
    return GraphBatch(jnp.asarray(feats), jnp.asarray(adj),
                      jnp.asarray(mask), jnp.asarray(n_nodes),
                      jnp.asarray(labels))


def bucket_for(n: int, buckets: Sequence[int] = DEFAULT_BUCKETS, *,
               allow_oversize: bool = False) -> int:
    for b in buckets:
        if n <= b:
            return b
    if allow_oversize:
        # Oversized queries get a power-of-two bucket of their own instead of
        # killing the call; doubling bounds the executable count at
        # O(log max_n) while capping pad waste at 2x.
        b = buckets[-1]
        while b < n:
            b *= 2
        return b
    raise ValueError(f"graph with {n} nodes exceeds largest bucket {buckets[-1]}")


def bucket_pairs(pairs: Sequence[tuple], n_labels: int,
                 buckets: Sequence[int] = DEFAULT_BUCKETS, *,
                 allow_oversize: bool = False):
    """Group graph *pairs* by the bucket of the larger graph.

    Returns {bucket_size: (GraphBatch_lhs, GraphBatch_rhs, indices)} where
    `indices` restores the original pair order. One compiled executable per
    bucket (the 'customize per workload' principle, paper Table 2). With
    `allow_oversize`, graphs beyond the largest bucket fall into power-of-two
    overflow buckets instead of raising.
    """
    groups: dict[int, list] = {}
    for idx, (g1, g2) in enumerate(pairs):
        b = bucket_for(max(g1["adj"].shape[0], g2["adj"].shape[0]), buckets,
                       allow_oversize=allow_oversize)
        groups.setdefault(b, []).append((idx, g1, g2))
    out = {}
    for b, items in sorted(groups.items()):
        idxs = np.asarray([i for i, _, _ in items], np.int32)
        lhs = pad_graphs([g for _, g, _ in items], n_labels, b)
        rhs = pad_graphs([g for _, _, g in items], n_labels, b)
        out[b] = (lhs, rhs, idxs)
    return out


# --------------------------------------------------------- pair packing (§8)

class PackedPairBatch(NamedTuple):
    """Graph *pairs* packed into fixed node-budget tiles (DESIGN.md §8).

    Tile t holds up to P pairs; pair slot p of tile t owns one contiguous
    node range in the lhs tile (its G1) and one in the rhs tile (its G2).
    Adjacency is block-diagonal by construction — no pair's edges cross
    another's range — so in-kernel masked normalization factors per graph.
    `seg*` maps every node slot to its pair slot (pad slots: segment 0 with
    mask 0, contributing exact zeros to every segment reduction).
    """
    adj1: Array           # [T, NB, NB] block-diagonal raw adjacency (lhs)
    labels1: Array        # [T, NB] int32 node labels (pad 0)
    mask1: Array          # [T, NB] 1.0 for real nodes
    seg1: Array           # [T, NB] int32 pair-slot id in [0, P)
    adj2: Array           # [T, NB, NB] (rhs)
    labels2: Array        # [T, NB]
    mask2: Array          # [T, NB]
    seg2: Array           # [T, NB]
    pair_mask: Array      # [T, P] 1.0 for real pair slots
    pair_index: Array     # [T, P] int32 original pair position (0 where pad)
    edges: "PackedEdges | None" = None   # tile-local A' edge lists (§9) —
                                         # present when packed with
                                         # `with_edges=True`; the packed-sparse
                                         # megakernel's input view.

    @property
    def node_budget(self) -> int:
        return self.adj1.shape[-1]

    @property
    def slots_per_tile(self) -> int:
        return self.pair_mask.shape[-1]


class PackedEdges(NamedTuple):
    """Packed-CSR view of a packed tile batch's *normalized* adjacency
    (DESIGN.md §9): per tile and side, the non-zeros of A' as
    (sender, receiver, weight) triples padded to a shared power-of-two
    `edge_budget`, laid out in D = edge_budget / node_budget neighbor
    *planes* (ELLPACK column-major): slot `s` of a tile holds the
    (s // NB)-th in-edge of node `s % NB`, so summing a node's neighbors
    is the sum of D contiguous [NB, F] planes — fully vectorizable, no
    scatter (receivers are stored explicitly too, so the arrays are also a
    valid plain edge list for `edge_aggregate`). Nodes with in-degree > D
    spill their excess edges to a small COO `overflow` list — LW-GCN's
    compressed-row format plus Accel-GCN's degree-aware split: the regular
    rows ride the vector path, the heavy tail a tiny one-hot contraction.
    Block-diagonality is inherited from the node packing — no edge crosses
    graphs, so segment reductions stay exact. Pad slots carry exact-zero
    weight/mask (neutral in aggregation).
    """
    edges1: EdgeBatch     # lhs CSR rows, arrays [T, NB*D]
    edges2: EdgeBatch     # rhs CSR rows
    overflow1: EdgeBatch  # lhs COO spill (in-degree > D), arrays [T, E_ov]
    overflow2: EdgeBatch  # rhs COO spill

    @property
    def edge_budget(self) -> int:
        return self.edges1.senders.shape[-1]

    @property
    def overflow_budget(self) -> int:
        return self.overflow1.senders.shape[-1]


def pack_pairs(pairs: Sequence[tuple], node_budget: int = 64, *,
               slots_per_tile: int | None = None,
               with_edges: bool = False, edge_budget: int | None = None,
               overflow_budget: int = 8):
    """First-fit-decreasing packing of graph pairs into `[T, node_budget]`
    tiles. Returns (PackedPairBatch, stats).

    Both sides of a pair land in the *same* tile at the same pair slot (the
    packed NTN stage scores tile-aligned slot pairs), so a pair is placed in
    the first tile where its G1 fits the remaining lhs budget AND its G2 the
    rhs budget. Decreasing order by total pair size keeps FFD occupancy high
    (~0.9 on AIDS-like streams vs ~0.55 for max-side bucketing).

    With `with_edges=True` the result additionally carries `edges`: the
    tile-local padded edge list of the normalized adjacency (`PackedEdges`,
    DESIGN.md §9) that the packed-sparse megakernel aggregates from,
    extracted by `packed_pair_edges` at a quantized `edge_budget`
    (node_budget rows x a small neighbor-budget ladder, auto-grown to fit;
    `kernels.ops.packed_edge_budget` is the sizing policy) and an
    `overflow_budget` floor for the COO spill — callers that stream many
    batches pass the previous batch's realized `stats["overflow_budget"]`
    back in so the compiled [T, E_ov] shape stays put. stats then gains
    the measured nnz / adjacency density per side.

    stats: occupancy / pad-fraction per side plus tile shape — the measured
    quantities benchmarks/packed.py and benchmarks/sparse.py report per
    policy.
    """
    sizes = [(g1["adj"].shape[0], g2["adj"].shape[0]) for g1, g2 in pairs]
    for n1, n2 in sizes:
        if max(n1, n2) > node_budget:
            raise ValueError(
                f"graph with {max(n1, n2)} nodes exceeds node_budget "
                f"{node_budget}; route oversized pairs to the padded fallback")
    cap = slots_per_tile if slots_per_tile else len(pairs) or 1
    order = sorted(range(len(pairs)), key=lambda i: -(sizes[i][0] + sizes[i][1]))
    tiles: list[dict] = []          # {"used1", "used2", "items": [pair idx]}
    for i in order:
        n1, n2 = sizes[i]
        for t in tiles:
            if (t["used1"] + n1 <= node_budget
                    and t["used2"] + n2 <= node_budget
                    and len(t["items"]) < cap):
                t["used1"] += n1
                t["used2"] += n2
                t["items"].append(i)
                break
        else:
            tiles.append({"used1": n1, "used2": n2, "items": [i]})

    n_tiles = len(tiles) or 1
    if slots_per_tile is None:
        most = max((len(t["items"]) for t in tiles), default=1)
        slots_per_tile = max(8, -(-most // 8) * 8)    # sublane-aligned P
    adj = [np.zeros((n_tiles, node_budget, node_budget), np.float32)
           for _ in range(2)]
    labels = [np.zeros((n_tiles, node_budget), np.int32) for _ in range(2)]
    mask = [np.zeros((n_tiles, node_budget), np.float32) for _ in range(2)]
    seg = [np.zeros((n_tiles, node_budget), np.int32) for _ in range(2)]
    pair_mask = np.zeros((n_tiles, slots_per_tile), np.float32)
    pair_index = np.zeros((n_tiles, slots_per_tile), np.int32)
    for t, tile in enumerate(tiles):
        offs = [0, 0]
        for p, idx in enumerate(tile["items"]):
            pair_mask[t, p] = 1.0
            pair_index[t, p] = idx
            for side, g in enumerate(pairs[idx]):
                n = g["adj"].shape[0]
                o = offs[side]
                adj[side][t, o:o + n, o:o + n] = g["adj"]
                labels[side][t, o:o + n] = g["labels"]
                mask[side][t, o:o + n] = 1.0
                seg[side][t, o:o + n] = p
                offs[side] += n

    real = [sum(s[0] for s in sizes), sum(s[1] for s in sizes)]
    cells = max(n_tiles * node_budget, 1)
    stats = {
        "n_pairs": len(pairs), "n_tiles": n_tiles,
        "node_budget": node_budget, "slots_per_tile": slots_per_tile,
        "occupancy_lhs": real[0] / cells, "occupancy_rhs": real[1] / cells,
        "pad_fraction_lhs": 1.0 - real[0] / cells,
        "pad_fraction_rhs": 1.0 - real[1] / cells,
        "mean_pairs_per_tile": len(pairs) / n_tiles,
    }
    packed = PackedPairBatch(
        jnp.asarray(adj[0]), jnp.asarray(labels[0]), jnp.asarray(mask[0]),
        jnp.asarray(seg[0]),
        jnp.asarray(adj[1]), jnp.asarray(labels[1]), jnp.asarray(mask[1]),
        jnp.asarray(seg[1]),
        jnp.asarray(pair_mask), jnp.asarray(pair_index))
    if with_edges:
        edges = packed_pair_edges(packed, edge_budget,
                                  overflow_budget=overflow_budget)
        packed = packed._replace(edges=edges)
        nnz = [int(np.asarray(e.edge_mask).sum()) + int(np.asarray(o.edge_mask).sum())
               for e, o in ((edges.edges1, edges.overflow1),
                            (edges.edges2, edges.overflow2))]
        adj_cells = n_tiles * node_budget * node_budget
        stats.update(
            edge_budget=edges.edge_budget,
            overflow_budget=edges.overflow_budget,
            nnz_lhs=nnz[0], nnz_rhs=nnz[1],
            density_lhs=nnz[0] / adj_cells, density_rhs=nnz[1] / adj_cells,
            edge_occupancy=(nnz[0] + nnz[1])
            / max(2 * n_tiles * edges.edge_budget, 1))
    return packed, stats


def packed_pair_edges(packed: PackedPairBatch,
                      edge_budget: int | None = None,
                      overflow_budget: int = 8) -> PackedEdges:
    """Extract per-tile packed-CSR A' edge lists from a packed tile batch
    (DESIGN.md §9).

    Extracts the same A' non-zeros as `to_edge_batch` (one vectorized
    nonzero scan per side — this sits on the §11 training hot path) — the
    packed adjacency is block-diagonal and the masked normalization factors
    per graph, so each tile's A' non-zeros ARE the union of its graphs' A'
    non-zeros — then lays the (receiver-sorted) list out in
    D = edge_budget/node_budget ELLPACK neighbor planes (plane d, slot n =
    node n's d-th in-edge); edges beyond a node's D slots spill to the COO
    overflow list. Budgets are powers of two and
    auto-grow to fit (`edge_budget=None` sizes D to the realized max
    in-degree, leaving the overflow empty). Both sides share one budget.
    """
    from repro.core.gcn import normalized_adjacency  # late import, no cycle

    nb = packed.node_budget
    if edge_budget is not None and edge_budget % nb:
        raise ValueError(f"edge_budget {edge_budget} must be a multiple of "
                         f"node_budget {nb} (CSR rows)")
    d_budget = (edge_budget // nb) if edge_budget else 1
    # Fully vectorized extraction (no per-tile Python loop — the host pack
    # sits on the training hot path since DESIGN.md §11): one nonzero scan
    # per side; np.nonzero returns row-major order, so edges arrive sorted
    # by (tile, receiver) and the in-row rank is a searchsorted subtraction.
    sides = []
    for adj, mask in ((packed.adj1, packed.mask1), (packed.adj2, packed.mask2)):
        a_norm = np.asarray(normalized_adjacency(adj, mask))
        t = a_norm.shape[0]
        tiles, rows, cols = np.nonzero(a_norm)
        w = a_norm[tiles, rows, cols].astype(np.float32)
        key = tiles.astype(np.int64) * nb + rows
        rank = np.arange(key.size) - np.searchsorted(key, key, side="left")
        max_rank = int(rank.max()) + 1 if key.size else 0
        sides.append((t, tiles, rows, cols, w, rank, max_rank))

    d = max(d_budget, 1)
    if edge_budget is None:
        d = next_pow2(max(s[6] for s in sides), floor=2)
    ov_need = 0
    for t, tiles, rows, cols, w, rank, _ in sides:
        spill = rank >= d
        if spill.any():
            ov_need = max(ov_need, int(np.bincount(tiles[spill]).max()))
    e_ov = next_pow2(ov_need, floor=max(8, overflow_budget))

    # Narrow index planes (DESIGN.md §16 satellite): within-tile node
    # indices fit int16 whenever the node budget does, halving the four
    # index planes' host->device bytes; the kernels' gathers and compares
    # promote against int32 iotas/offsets, so scores are bit-identical
    # (pinned by the int16 row of the sharded parity matrix).
    idx_dtype = np.int16 if nb < 2 ** 15 else np.int32
    out = []
    for t, tiles, rows, cols, w, rank, _ in sides:
        cs = np.zeros((t, nb * d), idx_dtype)
        cr = np.tile(np.tile(np.arange(nb, dtype=idx_dtype), d), (t, 1))
        cw = np.zeros((t, nb * d), np.float32)
        cm = np.zeros((t, nb * d), np.float32)
        os_ = np.zeros((t, e_ov), idx_dtype)
        or_ = np.zeros((t, e_ov), idx_dtype)
        ow = np.zeros((t, e_ov), np.float32)
        om = np.zeros((t, e_ov), np.float32)
        fit = rank < d
        # Plane-major (ELLPACK) flat slot: tile * NB·D + rank * NB + row.
        slot = tiles[fit] * (nb * d) + rank[fit] * nb + rows[fit]
        cs.reshape(-1)[slot] = cols[fit]
        cw.reshape(-1)[slot] = w[fit]
        cm.reshape(-1)[slot] = 1.0
        if (~fit).any():
            t_ov = tiles[~fit]            # sorted: position within tile is
            pos = (np.arange(t_ov.size)   # offset from the tile's first
                   - np.searchsorted(t_ov, t_ov, side="left"))
            oslot = t_ov * e_ov + pos
            os_.reshape(-1)[oslot] = cols[~fit]
            or_.reshape(-1)[oslot] = rows[~fit]
            ow.reshape(-1)[oslot] = w[~fit]
            om.reshape(-1)[oslot] = 1.0
        out.append((EdgeBatch(jnp.asarray(cs), jnp.asarray(cr),
                              jnp.asarray(cw), jnp.asarray(cm)),
                    EdgeBatch(jnp.asarray(os_), jnp.asarray(or_),
                              jnp.asarray(ow), jnp.asarray(om))))
    return PackedEdges(out[0][0], out[1][0], out[0][1], out[1][1])


def unpack_pair_scores(scores_tp, packed: PackedPairBatch,
                       n_pairs: int) -> np.ndarray:
    """Scatter kernel output [T, P] back to original pair order (host-side)."""
    s = np.asarray(scores_tp, np.float32)
    live = np.asarray(packed.pair_mask) > 0
    out = np.zeros(n_pairs, np.float32)
    out[np.asarray(packed.pair_index)[live]] = s[live]
    return out


def next_pow2(n: int, floor: int = 8) -> int:
    """Smallest power of two >= max(n, floor) — the shape-quantization helper
    shared by overflow buckets, tile counts and edge budgets (a small, fixed
    executable set under varying workloads). Always a true power of two,
    even when `floor` itself is not."""
    target = max(n, floor)
    p = 1
    while p < target:
        p *= 2
    return p


#: (requested, grown) budget pairs already warned about — a stream that
#: outruns its `max_edges` on every batch re-derives the same grown budget
#: each call; warning once per distinct growth (per process) keeps the log
#: readable while `EdgeBatch.edge_budget` gives callers the realized value
#: to feed back in (at which point growth — and the warning — stop).
_GROW_WARNED: set[tuple[int, int]] = set()


def reset_grow_warnings() -> None:
    """Clear the warn-once registry so the next budget growth warns again.

    The registry is process-global (one warning per distinct growth per
    process), which is right for servers but wrong for test isolation and
    for long-lived processes that deliberately re-tune budgets — both were
    reaching in and mutating `_GROW_WARNED` directly. This is the supported
    hook."""
    _GROW_WARNED.clear()


def to_edge_batch(batch: GraphBatch, max_edges: int) -> EdgeBatch:
    """Extract the normalized-adjacency non-zeros as a padded edge list.

    Includes self loops (A+I) with symmetric normalization weights — i.e. the
    exact non-zero structure of A' that the paper streams to the FPGA.
    Host-side (numpy); small graphs make this negligible (paper §3.2.2).

    If any graph's non-zero count exceeds `max_edges`, the whole batch's edge
    budget auto-grows to the next power of two that fits instead of killing
    the stream — the same degrade-to-padding policy as the power-of-two
    overflow buckets of `bucket_for`. The warning fires ONCE per distinct
    (requested, grown) pair per process, not per batch; the realized budget
    is surfaced as `EdgeBatch.edge_budget` (and in `pack_pairs` stats) so
    stream callers reuse it on the next batch. Pad edge slots carry
    sender/receiver 0 and exact-zero weight/mask, so they are neutral in
    every aggregation.
    """
    from repro.core.gcn import normalized_adjacency  # late import, no cycle

    a_norm = np.asarray(normalized_adjacency(batch.adj, batch.mask))
    bsz, n, _ = a_norm.shape
    nonzeros = [np.nonzero(a_norm[i]) for i in range(bsz)]
    peak = max((len(r) for r, _ in nonzeros), default=0)
    if peak > max_edges:
        grown = next_pow2(peak, floor=max(8, max_edges))
        if (max_edges, grown) not in _GROW_WARNED:
            _GROW_WARNED.add((max_edges, grown))
            import warnings
            warnings.warn(
                f"{peak} non-zeros exceed max_edges={max_edges}; growing the "
                f"edge budget to {grown} (power-of-two) instead of raising "
                "(warned once per stream: reuse EdgeBatch.edge_budget to "
                "stop re-growing)",
                RuntimeWarning, stacklevel=2)
        max_edges = grown
    senders = np.zeros((bsz, max_edges), np.int32)
    receivers = np.zeros((bsz, max_edges), np.int32)
    weights = np.zeros((bsz, max_edges), np.float32)
    emask = np.zeros((bsz, max_edges), np.float32)
    for i, (r, c) in enumerate(nonzeros):
        e = len(r)
        receivers[i, :e], senders[i, :e] = r, c
        weights[i, :e] = a_norm[i, r, c]
        emask[i, :e] = 1.0
    return EdgeBatch(jnp.asarray(senders), jnp.asarray(receivers),
                     jnp.asarray(weights), jnp.asarray(emask))


def edge_aggregate(edges: EdgeBatch, hw: Array) -> Array:
    """Aggregation step from the edge list: out[b, r] += w * hw[b, s].

    Touches only real edges (plus pad slots that contribute exact zeros) —
    the paper's 'read only the non-zero A' elements' (§3.2.2), expressed as a
    batched gather + segment-sum so XLA lowers it to vectorized dynamic ops.
    hw: [B, N, F] (the H·W product) -> [B, N, F].
    """
    gathered = jnp.take_along_axis(hw, edges.senders[..., None], axis=1)   # [B, E, F]
    msgs = gathered * (edges.weights * edges.edge_mask)[..., None]
    n = hw.shape[1]
    seg = jax.vmap(lambda m, r: jax.ops.segment_sum(m, r, num_segments=n))
    return seg(msgs, edges.receivers)
