"""SimGNN (Bai et al., WSDM'19) — the end-to-end application accelerated by
SPA-GCN. Pipeline (paper §4.1):

  1. GCN x3            -> node embeddings H in R^{|V| x F}
  2. Att pooling        -> graph embedding h_G = sum_n sigmoid(h_n^T c) h_n,
                           c = tanh(W_att * mean_n h_n)
  3. Neural Tensor Net  -> K similarity scores
                           s = ReLU(h1^T W[k] h2 + V [h1;h2] + b)
  4. FCN                -> single similarity score in (0, 1)

The whole pair-score is one fused jit region (the paper's cross-stage dataflow
pipeline — DESIGN.md §2); `kernels/fused_gcn.py` provides the Pallas TPU
realization of stages 1-2 and `kernels/simgnn_head.py` of stages 3-4.

Everything is batched over pairs: inputs are two `GraphBatch`es of equal batch.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.gcn import (gcn_stack, gcn_stack_from_labels, init_gcn_params,
                            normalized_adjacency)

Array = jax.Array


class SimGNNConfig(NamedTuple):
    """Defaults follow the released SimGNN reference used as the paper's
    CPU/GPU baseline [45]: GCN filters 128/64/32, NTN K=16, FCN 16->8->4->1."""
    n_node_labels: int = 29           # AIDS one-hot node types
    gcn_dims: tuple = (128, 64, 32)
    ntn_k: int = 16
    fcn_dims: tuple = (8, 4)          # hidden dims; final scalar layer appended
    max_nodes: int = 64
    dtype: str = "float32"

    @property
    def feature_dims(self):
        return (self.n_node_labels,) + tuple(self.gcn_dims)


def init_simgnn_params(key: Array, cfg: SimGNNConfig):
    dtype = jnp.dtype(cfg.dtype)
    k_gcn, k_att, k_ntn_w, k_ntn_v, k_fcn = jax.random.split(key, 5)
    f = cfg.gcn_dims[-1]
    params = {
        "gcn": init_gcn_params(k_gcn, cfg.feature_dims, dtype),
        "att": {"w": jax.random.normal(k_att, (f, f), dtype) / jnp.sqrt(f)},
        "ntn": {
            "w": jax.random.normal(k_ntn_w, (cfg.ntn_k, f, f), dtype) / f,
            "v": jax.random.normal(k_ntn_v, (cfg.ntn_k, 2 * f), dtype) / jnp.sqrt(2.0 * f),
            "b": jnp.zeros((cfg.ntn_k,), dtype),
        },
        "fcn": [],
    }
    dims = (cfg.ntn_k,) + tuple(cfg.fcn_dims) + (1,)
    for i in range(len(dims) - 1):
        k_fcn, sub = jax.random.split(k_fcn)
        scale = jnp.sqrt(2.0 / (dims[i] + dims[i + 1])).astype(dtype)
        params["fcn"].append({
            "w": jax.random.normal(sub, (dims[i], dims[i + 1]), dtype) * scale,
            "b": jnp.zeros((dims[i + 1],), dtype),
        })
    return params


def attention_pooling(att_params, h: Array, mask: Array) -> Array:
    """Global context-aware attention (paper Eq. 3). h: [B, N, F] -> [B, F]."""
    n_valid = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1.0)   # [B, 1]
    mean_h = jnp.sum(h * mask[..., None], axis=-2) / n_valid             # [B, F]
    # c = tanh(W_att mean(h))  — Eq. 5 rewrite sum(W h_n) = W sum(h_n) applies
    # automatically here because we matmul the mean once (adder reuse).
    c = jnp.tanh(jnp.einsum("bf,fg->bg", mean_h, att_params["w"]))       # [B, F]
    a = jax.nn.sigmoid(jnp.einsum("bnf,bf->bn", h, c))                   # [B, N]
    a = a * mask
    return jnp.einsum("bn,bnf->bf", a, h)                                # [B, F]


def ntn_scores(ntn_params, hg1: Array, hg2: Array) -> Array:
    """Neural Tensor Network (paper Eq. 4). hg*: [B, F] -> [B, K]."""
    bilinear = jnp.einsum("bf,kfg,bg->bk", hg1, ntn_params["w"], hg2)
    cat = jnp.concatenate([hg1, hg2], axis=-1)                           # [B, 2F]
    linear = jnp.einsum("bf,kf->bk", cat, ntn_params["v"])
    return jax.nn.relu(bilinear + linear + ntn_params["b"])


def fcn_head(fcn_params, s: Array) -> Array:
    """FCN reducing [B, K] -> [B] similarity in (0,1)."""
    for i, p in enumerate(fcn_params):
        s = jnp.einsum("bi,ij->bj", s, p["w"]) + p["b"]
        if i + 1 < len(fcn_params):
            s = jax.nn.relu(s)
    return jax.nn.sigmoid(s[..., 0])


def node_embeddings(params, adj: Array, feats: Array, mask: Array) -> Array:
    """Stage 1: [B, N, n_labels] -> [B, N, F]. `adj` is the *raw* adjacency;
    normalization happens here (the paper precomputes A' on the host — a
    one-time O(N^2) cost folded into the same jit region on TPU)."""
    a_norm = normalized_adjacency(adj, mask)
    return gcn_stack(params["gcn"], a_norm, feats, mask)


def graph_embedding(params, adj: Array, feats: Array, mask: Array) -> Array:
    h = node_embeddings(params, adj, feats, mask)
    return attention_pooling(params["att"], h, mask)


def pair_score(params, adj1, feats1, mask1, adj2, feats2, mask2) -> Array:
    """Full SimGNN pipeline for a batch of graph pairs -> [B] scores.

    The paper runs the two graphs *serially* through one GCN engine to save
    FPGA area (§4.2); on TPU area-reuse is free (same weights), so we fold the
    two graphs into one batched GCN call of size 2B — identical math, better
    MXU occupancy. This is a documented hardware adaptation (DESIGN.md §2).
    """
    adj = jnp.concatenate([adj1, adj2], axis=0)
    feats = jnp.concatenate([feats1, feats2], axis=0)
    mask = jnp.concatenate([mask1, mask2], axis=0)
    hg = graph_embedding(params, adj, feats, mask)          # [2B, F]
    hg1, hg2 = jnp.split(hg, 2, axis=0)
    s = ntn_scores(params["ntn"], hg1, hg2)
    return fcn_head(params["fcn"], s)


def pair_score_from_labels(params, adj1, labels1, mask1,
                           adj2, labels2, mask2) -> Array:
    """`pair_score` taking int32 node labels instead of one-hot features —
    bit-identical scores (gather == one-hot matmul, see
    `gcn_stack_from_labels`) at 1/n_labels the feature-input footprint. The
    pure-jnp reference for the packed megakernel's label path."""
    adj = jnp.concatenate([adj1, adj2], axis=0)
    labels = jnp.concatenate([labels1, labels2], axis=0)
    mask = jnp.concatenate([mask1, mask2], axis=0)
    a_norm = normalized_adjacency(adj, mask)
    h = gcn_stack_from_labels(params["gcn"], a_norm, labels, mask)
    hg = attention_pooling(params["att"], h, mask)
    hg1, hg2 = jnp.split(hg, 2, axis=0)
    s = ntn_scores(params["ntn"], hg1, hg2)
    return fcn_head(params["fcn"], s)


def pair_score_serial_baseline(params, adj1, feats1, mask1, adj2, feats2, mask2) -> Array:
    """Paper-faithful serial variant (GCN engine reused for G1 then G2) —
    kept as the faithful baseline for benchmarks; numerically identical."""
    hg1 = graph_embedding(params, adj1, feats1, mask1)
    hg2 = graph_embedding(params, adj2, feats2, mask2)
    s = ntn_scores(params["ntn"], hg1, hg2)
    return fcn_head(params["fcn"], s)


def simgnn_loss(params, batch) -> Array:
    """MSE against exp(-normalized GED) targets (SimGNN training objective).
    batch: dict with adj1, feats1, mask1, adj2, feats2, mask2, target [B]."""
    pred = pair_score(params, batch["adj1"], batch["feats1"], batch["mask1"],
                      batch["adj2"], batch["feats2"], batch["mask2"])
    return jnp.mean((pred - batch["target"]) ** 2)
