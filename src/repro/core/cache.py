"""Engine-level embedding cache for 1-vs-N similarity search (DESIGN.md §10).

SPA-GCN's target workload scores ONE query graph against MANY corpus graphs,
yet every scoring path recomputes the corpus-side GCN+Att embedding on every
query even though it is query-independent. GraphACT (arXiv:2001.02498) makes
the general point: precomputing redundant aggregation pays off exactly when
the same subgraphs recur. Here the recurring unit is the whole (small) corpus
graph, so the cacheable object is its final `[F]` graph embedding and the
per-query cost collapses to the NTN+FCN head stage.

Two pieces live here:

  * `graph_key` — a canonical, node-order-invariant hash of a graph dict
    (node count, int labels, edge list), built by Weisfeiler-Lehman color
    refinement. Any permutation of the same labeled graph maps to the same
    key, so a re-submitted corpus graph hits regardless of how the client
    ordered its nodes. WL can collide on 1-WL-equivalent non-isomorphic
    graphs — but a GCN is itself bounded by 1-WL expressiveness (its
    message passing refines exactly the WL colors, with degrees — which the
    symmetric normalization reads — fixed by the first refinement), so any
    two graphs the key conflates get identical embeddings from this model
    family anyway: a collision returns the right answer.

  * `EmbeddingCache` — a plain LRU over those keys with hit/miss/eviction
    counters. Capacity 0 disables storage entirely (every lookup is a miss,
    `put` is a no-op) so the uncached behavior is one config value away.

Host-side and pure-numpy on purpose: keys are computed where the graphs are
born (the FPGA host-preprocessing role), never on device.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict

import numpy as np

#: WL refinement rounds for `graph_key`. Three rounds stabilize colors on
#: molecule-sized graphs (diameter-limited information has propagated); more
#: rounds refine nothing a 3-layer GCN could tell apart either.
WL_ITERS = 3


def _digest(payload: bytes) -> bytes:
    return hashlib.blake2b(payload, digest_size=16).digest()


_MIX1 = np.uint64(0xBF58476D1CE4E5B9)       # splitmix64 finalizer constants
_MIX2 = np.uint64(0x94D049BB133111EB)
_SELF = np.uint64(0x9E3779B97F4A7C15)       # golden-ratio odd multipliers
_NBR = np.uint64(0xD6E8FEB86659FD93)
_LBL = np.uint64(0xA24BAED4963EE407)


def _mix(x: np.ndarray) -> np.ndarray:
    """splitmix64 avalanche, vectorized on uint64 (wrapping arithmetic)."""
    x = (x ^ (x >> np.uint64(30))) * _MIX1
    x = (x ^ (x >> np.uint64(27))) * _MIX2
    return x ^ (x >> np.uint64(31))


def graph_key(g: dict, *, wl_iters: int = WL_ITERS) -> bytes:
    """Canonical cache key for a graph dict {"adj": [n,n], "labels": [n]}.

    Node-order invariant: per-node WL colors are combined only through
    commutative multiset reductions (neighbor sums during refinement, a
    sorted color array and an endpoint-symmetric edge sum at the end), so
    `graph_key(g) == graph_key(permute(g))` for any node permutation
    applied consistently to adjacency and labels. Distinct labeled graphs
    differing in node count, label multiset, edge count or any WL-visible
    structure get distinct keys (up to 64-bit mixing collisions — the
    multiset sums are splitmix64-avalanched first, so colliding them is a
    birthday problem on 2^64, far below the blake2b payload's own floor).

    Fully vectorized numpy (one matrix-vector round per WL iteration,
    ~150µs per molecule-sized graph), and memoized on the dict itself under
    `"_graph_key"` — the same idiom as the generator's `avg_degree` /
    `density` annotations — so recurring corpus dicts are hashed once per
    process, not once per call. The memo assumes graphs are immutable once
    scored (the contract every cache needs anyway); `edit_graph` builds new
    dicts, so edits never inherit a stale key.
    """
    k = g.get("_graph_key")
    if k is not None:
        return k
    adj = np.asarray(g["adj"]) != 0
    labels = np.asarray(g["labels"], np.uint64)
    # Round 0: colors are the mixed raw node labels.
    colors = _mix(labels * _LBL + _SELF)
    for _ in range(wl_iters):
        # Multiset of neighbor colors as a wrapping sum of mixed values —
        # commutative, hence permutation invariant.
        nbr = (adj * _mix(colors * _NBR)[None, :]).sum(axis=1,
                                                       dtype=np.uint64)
        colors = _mix(colors * _SELF + nbr)
    r, c = np.nonzero(np.triu(adj))
    edge_sig = (_mix(colors[r] + colors[c]).sum(dtype=np.uint64)
                if len(r) else np.uint64(0))
    payload = (np.uint64(adj.shape[0]).tobytes()
               + np.uint64(int(adj.sum())).tobytes()
               + edge_sig.tobytes()
               + np.sort(colors).tobytes()
               + np.sort(labels).tobytes())
    k = _digest(payload)
    try:
        g["_graph_key"] = k
    except TypeError:            # immutable mapping: just skip the memo
        pass
    return k


def graph_fingerprint(g: dict) -> tuple:
    """Cheap structural fingerprint guarding `graph_key` collisions.

    `(n_nodes, n_edges, labels-digest)` — computable without WL refinement,
    memoized on the dict as `"_graph_fp"` (same immutability contract as
    the key memo). Two 1-WL-equivalent graphs get identical *embeddings*
    from this model family, so a WL collision is harmless by construction;
    this fingerprint exists for the failure mode the WL argument does NOT
    cover — a 64-bit mixing collision between structurally different
    graphs, where serving the cached row would be silently wrong.
    """
    fp = g.get("_graph_fp")
    if fp is not None:
        return fp
    adj = np.asarray(g["adj"])
    labels = np.asarray(g["labels"], np.int64)
    fp = (int(adj.shape[0]), int(np.count_nonzero(adj)) // 2,
          _digest(np.sort(labels).tobytes()))
    try:
        g["_graph_fp"] = fp
    except TypeError:            # immutable mapping: just skip the memo
        pass
    return fp


class EmbeddingCache:
    """LRU of per-graph `[F]` embeddings keyed by `graph_key`.

    `get` promotes on hit; `put` evicts the least-recently-used entry past
    `capacity`. `peek`/`__contains__` never touch recency — planning code
    uses them so inspecting a plan cannot reorder the cache. Stored arrays
    are returned as-is (callers must not mutate them; the engine stores
    read-only numpy copies).

    Collision guard: `put`/`get` accept an optional `graph_fingerprint`.
    When both the stored and the presented fingerprint exist and disagree,
    the key has COLLIDED across structurally different graphs — the entry
    is evicted and the lookup misses (`key_collisions` counts it, surfaced
    through `stats()` and `engine.health()`); a wrong embedding is never
    served. Fingerprint-less calls behave exactly as before.
    """

    def __init__(self, capacity: int = 4096):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = capacity
        self._store: OrderedDict[bytes, tuple[np.ndarray,
                                              tuple | None]] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.key_collisions = 0

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: bytes) -> bool:
        return key in self._store

    def peek(self, key: bytes) -> np.ndarray | None:
        """Recency- and stats-neutral lookup (the planner's view)."""
        entry = self._store.get(key)
        return entry[0] if entry is not None else None

    def get(self, key: bytes,
            fingerprint: tuple | None = None) -> np.ndarray | None:
        entry = self._store.get(key)
        if entry is None:
            self.misses += 1
            return None
        emb, fp = entry
        if (fingerprint is not None and fp is not None
                and fp != fingerprint):
            # WL-key collision between different structures: never serve
            # the wrong row — evict and report a miss so the caller
            # re-embeds (and re-puts under its own fingerprint).
            self.key_collisions += 1
            del self._store[key]
            self.misses += 1
            return None
        self._store.move_to_end(key)
        self.hits += 1
        return emb

    def put(self, key: bytes, emb: np.ndarray,
            fingerprint: tuple | None = None) -> None:
        if self.capacity == 0:
            return
        prev = self._store.get(key)
        if prev is not None:
            if (fingerprint is not None and prev[1] is not None
                    and prev[1] != fingerprint):
                self.key_collisions += 1
            self._store.move_to_end(key)
            self._store[key] = (emb, fingerprint)
            return
        self._store[key] = (emb, fingerprint)
        while len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._store.clear()

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"capacity": self.capacity, "size": len(self._store),
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "key_collisions": self.key_collisions,
                "hit_rate": round(self.hit_rate, 4)}
