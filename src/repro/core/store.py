"""Durable shard store: crash-safe persistence for precomputed state
(DESIGN.md §13).

SPA-GCN's many-small-graphs setting makes the per-graph embedding corpus
the expensive precomputed artifact (the same precompute-once-reuse-
everywhere move GraphACT makes for repeated aggregations) — so it must
survive restarts, be shareable across serving replicas, and NEVER be
trusted blindly: a torn write that goes unnoticed corrupts every
similarity score served afterward. This module is the one place durable
bytes are produced and verified:

  * `atomic_write_bytes` — tmp + flush + fsync + rename, then fsync on the
    containing directory so the rename itself is durable. Every durable
    write in the repo (store shards, store manifest, checkpoint arrays,
    checkpoint manifest) funnels through it, which is also the filesystem
    fault seam: `repro.testing.faults.fs_inject` arms `_FS_HOOK` to
    deterministically tear, bit-flip, or drop exactly the bytes a chaos
    test wants (mirroring the §12 executor seam `engine._FAULT_HOOK`).

  * `ShardStore` — a directory of raw row-shard files described by ONE
    versioned JSON manifest (written last, atomically: a reader sees either
    the previous complete index or the new complete index, never a torn
    mix). The manifest records the format version, per-shard shape / dtype
    / blake2b checksum, and the WL `graph_key`s each shard covers, so a
    loader can verify every shard and selectively rebuild only the bad
    ones. Shards read back as `np.memmap` views (checksummed first).

Layout:

    <dir>/manifest.json              versioned manifest (atomic, last)
    <dir>/shard_00000.bin            raw C-order rows (atomic, checksummed)
    <dir>/shard_00001.bin            ...

Error taxonomy: `ManifestError` (missing / unreadable / wrong format
version — the directory as a whole cannot be trusted; callers rebuild) vs
per-shard statuses from `verify()` ("ok" | "missing" | "corrupt") which
support *selective* recovery. `StoreError` is the common base so callers
can catch the whole family.
"""

from __future__ import annotations

import hashlib
import json
import os
from dataclasses import dataclass
from typing import Callable, Sequence

import numpy as np

#: Bump when the manifest schema or shard byte layout changes. A reader
#: that sees any other version MUST refuse (ManifestError) rather than
#: guess: shard descriptions it misparses would deserialize garbage that
#: passes no further check.
STORE_FORMAT_VERSION = 1
MANIFEST_NAME = "manifest.json"

#: Rows per shard for the similarity-search index (DESIGN.md §13/§14).
#: Doubles as the retrieval prefilter's column-block size so the streaming
#: top-M scan's sequential block loop walks the corpus in 1:1
#: correspondence with the persisted shards — the partition unit a later
#: multi-process sharded server distributes. Keep it a power of two no
#: larger than `kernels.retrieval.RETRIEVAL_MAX_BLOCK_COLS`.
DEFAULT_SHARD_ROWS = 256


class StoreError(RuntimeError):
    """Base class for durable-state failures (structured, never silent)."""


class ManifestError(StoreError):
    """The manifest is missing, unreadable, or a format version this
    reader does not understand — nothing in the directory can be trusted,
    so recovery is rebuild-from-source, not selective repair."""


#: Filesystem fault seam (DESIGN.md §13): `repro.testing.faults.fs_inject`
#: arms this with a hook mapping (site, path, data) -> data | None;
#: production leaves it None (one attribute read per durable write).
#: Returning None simulates a write the caller believes succeeded but
#: never reached disk ("missing"); returning mutated bytes simulates torn
#: writes / bit rot that survived the fsync path.
_FS_HOOK: Callable | None = None


def _fs(site: str, path: str, data: bytes) -> bytes | None:
    hook = _FS_HOOK
    return hook(site, path, data) if hook is not None else data


def checksum(data: bytes) -> str:
    """Content checksum used by both the shard store and the checkpoint
    manager — blake2b-128 hex (collision floor far below disk-error rates,
    ~an order of magnitude faster than sha256 on large arrays)."""
    return hashlib.blake2b(data, digest_size=16).hexdigest()


def atomic_write_bytes(path: str, data: bytes, *, site: str = "store:blob"
                       ) -> None:
    """Durably write `data` to `path`: tmp file + flush + fsync + atomic
    rename + directory fsync. A crash at ANY point leaves either the old
    complete file or no file — never a prefix. `site` names this write for
    the fault seam."""
    data = _fs(site, path, data)
    if data is None:                 # injected lost write
        return
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    dfd = os.open(os.path.dirname(os.path.abspath(path)) or ".", os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


def tree_digest(tree) -> str:
    """Checksum of a parameter pytree (structure keys + leaf bytes): the
    store stamps it into index manifests so an index built by one model
    can never silently serve under another's params."""
    import jax

    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    h = hashlib.blake2b(digest_size=16)
    for path, leaf in flat:
        arr = np.asarray(leaf)
        h.update(repr(path).encode())
        h.update(str(arr.dtype).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


@dataclass(frozen=True)
class ShardInfo:
    """One shard as the manifest describes it (the trusted side of every
    integrity comparison)."""
    name: str                        # file name inside the store directory
    shape: tuple                     # row-shard shape, C order
    dtype: str
    checksum: str                    # blake2b-128 hex of the file bytes
    graph_keys: tuple = ()           # hex WL key per row (optional)

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)
                   * np.dtype(self.dtype).itemsize)


class ShardStore:
    """Integrity-verified row-sharded array persistence in one directory.

    `write()` replaces the store's contents atomically-enough for readers:
    shards land first (each individually atomic), the manifest last — a
    reader concurrent with a writer (or after a mid-write crash) sees a
    complete manifest whose shards either verify or are individually
    reported bad. `verify()`/`read_shard()` never return bytes that fail
    their manifest checksum.
    """

    def __init__(self, directory: str):
        self.directory = str(directory)

    # -------------------------------------------------------------- writing

    def write(self, matrix: np.ndarray, *, shard_rows: int = 1024,
              graph_keys: Sequence[str] | None = None,
              meta: dict | None = None) -> dict:
        """Persist `matrix` as row shards + manifest; returns the manifest.

        `graph_keys` (hex strings, one per row) record which WL-keyed
        graphs each shard covers so a loader can re-embed exactly the rows
        a bad shard loses. `meta` is caller context stored verbatim
        (model digest, dims, flags).
        """
        matrix = np.ascontiguousarray(matrix)
        if graph_keys is not None and len(graph_keys) != matrix.shape[0]:
            raise ValueError(f"{len(graph_keys)} graph_keys for "
                             f"{matrix.shape[0]} rows")
        if shard_rows < 1:
            raise ValueError(f"shard_rows must be >= 1, got {shard_rows}")
        os.makedirs(self.directory, exist_ok=True)
        shards = []
        for i, row0 in enumerate(range(0, max(matrix.shape[0], 1),
                                       shard_rows)):
            part = matrix[row0:row0 + shard_rows]
            name = f"shard_{i:05d}.bin"
            data = part.tobytes()
            atomic_write_bytes(os.path.join(self.directory, name), data,
                               site="store:shard")
            shards.append({
                "name": name, "shape": list(part.shape),
                "dtype": str(part.dtype), "checksum": checksum(data),
                "graph_keys": (list(graph_keys[row0:row0 + part.shape[0]])
                               if graph_keys is not None else []),
            })
        manifest = {"format_version": STORE_FORMAT_VERSION,
                    "shape": list(matrix.shape), "dtype": str(matrix.dtype),
                    "shards": shards, "meta": dict(meta or {})}
        # Manifest LAST: its atomic rename is the commit point of the whole
        # write — a crash before it leaves the previous index intact.
        atomic_write_bytes(os.path.join(self.directory, MANIFEST_NAME),
                           json.dumps(manifest, indent=1).encode(),
                           site="store:manifest")
        # Shards beyond this manifest's coverage (a previous, larger index)
        # are dead bytes a future writer would half-overwrite: sweep them.
        live = {s["name"] for s in shards}
        for fname in os.listdir(self.directory):
            if (fname.startswith("shard_") and fname.endswith(".bin")
                    and fname not in live):
                os.remove(os.path.join(self.directory, fname))
        return manifest

    # -------------------------------------------------------------- reading

    def manifest(self) -> dict:
        """Load + validate the manifest; raises ManifestError when the
        directory as a whole cannot be trusted (missing / unparseable /
        unknown format version / missing required fields)."""
        path = os.path.join(self.directory, MANIFEST_NAME)
        if not os.path.exists(path):
            raise ManifestError(f"no manifest at {path}")
        try:
            with open(path, "rb") as f:
                man = json.loads(f.read().decode())
        except (ValueError, UnicodeDecodeError) as exc:
            raise ManifestError(f"unreadable manifest at {path}: {exc}")
        version = man.get("format_version")
        if version != STORE_FORMAT_VERSION:
            raise ManifestError(
                f"manifest format_version {version!r} != supported "
                f"{STORE_FORMAT_VERSION} at {path}: refusing to guess the "
                "shard layout")
        for field in ("shape", "dtype", "shards"):
            if field not in man:
                raise ManifestError(f"manifest at {path} missing {field!r}")
        return man

    def shard_infos(self, man: dict | None = None) -> list[ShardInfo]:
        man = self.manifest() if man is None else man
        return [ShardInfo(name=s["name"], shape=tuple(s["shape"]),
                          dtype=s["dtype"], checksum=s["checksum"],
                          graph_keys=tuple(s.get("graph_keys", ())))
                for s in man["shards"]]

    def verify_shard(self, info: ShardInfo) -> str:
        """"ok" | "missing" | "corrupt" — corrupt covers size mismatch
        (torn write) and checksum mismatch (bit rot) alike: either way the
        bytes are not the bytes the manifest committed."""
        path = os.path.join(self.directory, info.name)
        if not os.path.exists(path):
            return "missing"
        if os.path.getsize(path) != info.nbytes:
            return "corrupt"
        with open(path, "rb") as f:
            if checksum(f.read()) != info.checksum:
                return "corrupt"
        return "ok"

    def read_shard(self, info: ShardInfo, *, mmap: bool = True,
                   verify: bool = True) -> np.ndarray:
        """Checksummed shard read-back; `mmap=True` returns a read-only
        memmap view (zero-copy until touched). Raises StoreError rather
        than returning bytes that fail verification."""
        if verify:
            status = self.verify_shard(info)
            if status != "ok":
                raise StoreError(f"shard {info.name} is {status}")
        path = os.path.join(self.directory, info.name)
        if mmap:
            return np.memmap(path, dtype=np.dtype(info.dtype), mode="r",
                             shape=info.shape)
        with open(path, "rb") as f:
            return np.frombuffer(f.read(), dtype=np.dtype(info.dtype)
                                 ).reshape(info.shape)

    def verify(self) -> dict:
        """Whole-store integrity report: {shard name: status}. Manifest
        problems raise ManifestError (there is no per-shard story without
        a trusted manifest)."""
        return {info.name: self.verify_shard(info)
                for info in self.shard_infos()}
