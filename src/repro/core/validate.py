"""Input quarantine — graph sanitation before planning (DESIGN.md §12).

Every scoring path downstream of `ScoringEngine.plan()` assumes clean
inputs: square binary symmetric adjacency, int labels in range, no
non-finite values. A production stream violates each of those eventually,
and one malformed graph used to poison the whole micro-batch (a shape error
deep inside packing, or NaNs silently spreading through a packed tile that
also holds 30 healthy pairs).

This module turns that into a per-request outcome: `validate_pairs` scans a
batch host-side and splits it into valid pairs (scored normally) and
quarantined pairs, each with a structured `InvalidGraph` record naming the
pair, side and every reason. The engine (lenient mode, the default) scores
quarantined pairs as NaN — the standard "no answer" marker that survives
serialization — and surfaces the records on the `ScorePlan`; strict mode
raises `GraphValidationError` with the same records attached.

The checks are single-pass numpy reductions per graph (isfinite / binary /
symmetry), so validation costs about as much as the density measurement the
auto planner already performs. Engines built with `validation="off"` skip
it entirely (trusted in-process generators, benchmarks).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np


@dataclass(frozen=True)
class InvalidGraph:
    """One quarantined graph: which pair and side of the call it came from,
    and every validation failure found (not just the first — a client fixing
    its producer wants the full list)."""
    pair: int                 # pair position within the call
    side: int                 # 0 = lhs, 1 = rhs
    reasons: tuple            # tuple[str, ...], human-readable

    def __str__(self) -> str:
        return (f"pair {self.pair} side {self.side}: "
                + "; ".join(self.reasons))


class GraphValidationError(ValueError):
    """Strict-mode rejection; `.records` carries the InvalidGraph list."""

    def __init__(self, records: Sequence[InvalidGraph]):
        self.records = tuple(records)
        lines = ", ".join(str(r) for r in self.records[:4])
        more = (f" (+{len(self.records) - 4} more)"
                if len(self.records) > 4 else "")
        super().__init__(
            f"{len(self.records)} invalid graph(s) in batch: {lines}{more}")


def graph_problems(g, *, n_labels: int | None = None) -> list[str]:
    """Every validation failure of one graph dict (empty list == valid).

    Checks, in dependency order (later checks assume earlier ones hold):
      * structure — a dict with an "adj" key; adjacency array-like, 2-D,
        square, at least one node;
      * dtype — numeric adjacency (object/str arrays are rejected before
        any arithmetic touches them);
      * values — finite (no NaN/Inf), binary {0, 1} (covers negative
        entries), zero diagonal (raw adjacency carries no self loops —
        normalization adds A+I itself), symmetric (undirected contract;
        the symmetric-A' training VJP exploits it);
      * labels, when present — 1-D of length n, integer dtype (float labels
        can smuggle NaN and break the W1 row gather), in [0, n_labels).
    Missing labels are NOT invalid here: the engine's label-free contract
    error stays in charge of that case.
    """
    if not isinstance(g, dict) or "adj" not in g:
        return ["missing adjacency ('adj')"]
    problems: list[str] = []
    try:
        adj = np.asarray(g["adj"])
    except Exception:
        return ["adjacency is not array-like"]
    if adj.dtype == object or adj.dtype.kind in "USV":
        return [f"non-numeric adjacency dtype {adj.dtype}"]
    if adj.ndim != 2 or adj.shape[0] != adj.shape[1]:
        return [f"adjacency not square (shape {adj.shape})"]
    n = adj.shape[0]
    if n == 0:
        return ["empty graph (0 nodes)"]
    if not np.isfinite(adj).all():
        problems.append("non-finite adjacency entries (NaN/Inf)")
    else:
        if not ((adj == 0) | (adj == 1)).all():
            problems.append("non-binary adjacency entries")
        if np.asarray(adj.diagonal()).any():
            problems.append("self loops on the diagonal (raw adjacency "
                            "must be hollow; normalization adds A+I)")
        if not (adj == adj.T).all():
            problems.append("asymmetric adjacency (graphs are undirected)")
    if "labels" in g:
        try:
            labels = np.asarray(g["labels"])
        except Exception:
            problems.append("labels are not array-like")
            return problems
        if labels.ndim != 1 or labels.shape[0] != n:
            problems.append(f"ragged labels (shape {labels.shape} for "
                            f"{n} nodes)")
        elif labels.dtype.kind not in "iu":
            problems.append(f"non-integer label dtype {labels.dtype}")
        else:
            if labels.size and int(labels.min()) < 0:
                problems.append("negative node labels")
            if (n_labels is not None and labels.size
                    and int(labels.max()) >= n_labels):
                problems.append(f"node label {int(labels.max())} out of "
                                f"range [0, {n_labels})")
    return problems


def validate_pairs(pairs: Sequence[tuple], *, n_labels: int | None = None
                   ) -> tuple[np.ndarray, tuple]:
    """Split a batch of graph pairs into valid and quarantined.

    Returns `(valid_idx, records)`: `valid_idx` the int64 positions of pairs
    where BOTH sides pass, `records` a tuple of `InvalidGraph` (one per bad
    graph — a pair with two bad sides yields two records). Distinct graph
    *objects* are validated once per call (1-vs-N batches repeat the query
    and hot corpus dicts; the memo is per-call only, like the engine's
    graph-key memo, because id() values are not stable across GC).
    """
    memo: dict[int, list[str]] = {}
    records: list[InvalidGraph] = []
    valid: list[int] = []
    for i, pair in enumerate(pairs):
        if not isinstance(pair, (tuple, list)) or len(pair) != 2:
            records.append(InvalidGraph(i, 0, ("not a (g1, g2) pair",)))
            continue
        ok = True
        for side, g in enumerate(pair):
            key = id(g)
            problems = memo.get(key)
            if problems is None:
                problems = memo[key] = graph_problems(g, n_labels=n_labels)
            if problems:
                ok = False
                records.append(InvalidGraph(i, side, tuple(problems)))
        if ok:
            valid.append(i)
    return np.asarray(valid, np.int64), tuple(records)
