"""AI21 Jamba-1.5-large 398B: Mamba+attention 7:1 interleave, 16-expert top-2
MoE every other layer. [arXiv:2403.19887]
Training note: optimizer moments are kept in bf16 (opt_state_dtype) so the
fully-sharded state fits 16 GB/chip on a single v5e-256 pod (DESIGN.md §6)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    layer_pattern=("mamba", "mamba", "mamba", "attn",
                   "mamba", "mamba", "mamba", "mamba"),
    moe_period=2, n_experts=16, top_k=2, d_ff_expert=24576,
    mamba_d_state=16, mamba_expand=2, mamba_d_conv=4,
    rope_theta=None, tie_embeddings=False, subquadratic=True,
    opt_state_dtype="bfloat16",
)
