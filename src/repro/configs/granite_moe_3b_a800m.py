"""IBM Granite-3.0 3B-A800M: 40-expert top-8 fine-grained MoE.
[hf:ibm-granite/granite-3.0-3b-a800m-base; hf-verified family]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    moe_period=1, n_experts=40, top_k=8, d_ff_expert=512,
    rope_theta=10_000.0, tie_embeddings=True,
)
