"""Microsoft Phi-3-mini 3.8B: MHA (kv=32), RoPE, SwiGLU.
[arXiv:2404.14219]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064,
    rope_theta=10_000.0, tie_embeddings=False,
)
