"""RWKV-6 (Finch) 7B: attention-free, data-dependent per-channel decay.
[arXiv:2404.05892; hf-verified family] O(1) decode state -> long_500k runs."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=14336, vocab_size=65536,
    layer_pattern=("rwkv",), rwkv_head_dim=64,
    rope_theta=None, tie_embeddings=False, subquadratic=True,
)
