"""H2O Danube-3 4B: llama+mistral mix with sliding-window attention.
[arXiv:2401.16818] SWA(4096) on all layers -> ring KV cache makes the
long_500k decode cell constant-memory per layer."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, head_dim=120,
    d_ff=10240, vocab_size=32000,
    layer_pattern=("attn_local",), sliding_window=4096,
    rope_theta=10_000.0, tie_embeddings=True, subquadratic=True,
)
