"""SeamlessM4T-large-v2 backbone: 24L encoder + 24L decoder, d=1024.
[arXiv:2308.11596] Audio frontend is a stub: input_specs() provides
precomputed fbank-frame embeddings (assignment spec)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab_size=256206,
    is_enc_dec=True, n_enc_layers=24, frontend="audio", dec_seq_divisor=8,
    rope_theta=10_000.0, tie_embeddings=True,
)
