"""Microsoft Phi-3.5-MoE: 16-expert top-2, 42B total / 6.6B active.
[hf:microsoft/Phi-3.5-MoE-instruct; hf-verified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=6400, vocab_size=32064,
    moe_period=1, n_experts=16, top_k=2, d_ff_expert=6400,
    rope_theta=10_000.0, tie_embeddings=False,
)
