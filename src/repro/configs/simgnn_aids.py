"""The paper's own model: SimGNN on AIDS (DESIGN.md §4)."""
from repro.core.simgnn import SimGNNConfig

CONFIG = SimGNNConfig(n_node_labels=29, gcn_dims=(128, 64, 32), ntn_k=16,
                      fcn_dims=(8, 4), max_nodes=64)
