"""Architecture registry: the 10 assigned configs + the paper's own SimGNN.

`get_config(name)` accepts the dashed public ids (e.g. "gemma2-9b").
`SHAPES` defines the four assigned input-shape cells; `cells()` enumerates the
runnable (arch x shape) grid with the skip rules from DESIGN.md §5.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_IDS = [
    "granite-moe-3b-a800m",
    "phi3.5-moe-42b-a6.6b",
    "gemma2-9b",
    "phi3-mini-3.8b",
    "h2o-danube-3-4b",
    "qwen1.5-4b",
    "seamless-m4t-large-v2",
    "rwkv6-7b",
    "jamba-1.5-large-398b",
    "internvl2-2b",
]

_MODULES = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b_a66b",
    "gemma2-9b": "gemma2_9b",
    "phi3-mini-3.8b": "phi3_mini_38b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "qwen1.5-4b": "qwen15_4b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "rwkv6-7b": "rwkv6_7b",
    "jamba-1.5-large-398b": "jamba_15_large_398b",
    "internvl2-2b": "internvl2_2b",
}

SHAPES = {
    "train_4k":    dict(kind="train",   seq_len=4_096,   global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32_768,  global_batch=32),
    "decode_32k":  dict(kind="decode",  seq_len=32_768,  global_batch=128),
    "long_500k":   dict(kind="decode",  seq_len=524_288, global_batch=1),
}


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def reduced_config(name: str) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
    cfg = get_config(name)
    kw = dict(
        n_layers=cfg.group_size * min(2, cfg.n_groups),
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256, param_dtype="float32", dtype="float32",
        sliding_window=8 if cfg.sliding_window else None,
        rwkv_head_dim=16, mamba_dt_rank=8, mamba_d_state=4,
    )
    if cfg.n_kv_heads == cfg.n_heads:
        kw["n_kv_heads"] = 4                     # keep MHA archs MHA
    if cfg.moe_period:
        kw.update(n_experts=4, top_k=min(2, cfg.top_k), d_ff_expert=32)
    if cfg.is_enc_dec:
        kw.update(n_enc_layers=2)
    if cfg.frontend == "vision":
        kw.update(frontend_len=4)
    return cfg.with_(**kw)


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) per the DESIGN.md §5 skip rules."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k decode cache/compute is quadratic-class; skipped per spec (DESIGN.md §5)"
    return True, ""


def cells():
    """All (arch, shape, runnable, note) cells — the 40-cell grid."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, note = shape_applicable(cfg, shape)
            out.append((arch, shape, ok, note))
    return out
