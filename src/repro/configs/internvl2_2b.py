"""InternVL2-2B: InternViT frontend (stub) + InternLM2-1.8B backbone.
[arXiv:2404.16821; hf-verified family] input_specs() provides 256 precomputed
patch embeddings per image (pixel-shuffled InternViT output)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=92553,
    frontend="vision", frontend_len=256,
    rope_theta=10_000.0, tie_embeddings=True,
)
