"""Gemma-2 9B: local(4096)+global alternating attention, logit softcaps,
sandwich norms. [arXiv:2408.00118; hf-verified]
Marked subquadratic-eligible for long_500k: half the layers are
sliding-window (ring cache); global layers decode against the full (sharded)
cache -- O(S) per token. See DESIGN.md §5."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000,
    layer_pattern=("attn_local", "attn"), sliding_window=4096,
    attn_softcap=50.0, final_softcap=30.0, post_block_norm=True,
    rope_theta=10_000.0, tie_embeddings=True, subquadratic=True,
)
