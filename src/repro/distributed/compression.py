"""Gradient compression for the cross-pod (DCN) all-reduce.

Block-wise int8 quantization with a shared absmax scale per tensor:
  q = round(g / s * 127),  s = absmax(g)
Under SPMD the quantize/dequantize runs fully sharded; the all-reduce that
XLA inserts for data-parallel gradients then moves int8 (+ one f32 scale) —
a 4x wire reduction on the slowest (DCN) hops. Exactness: unbiased up to
0.5/127 absmax rounding per element; the error bound is tested in
tests/test_distributed.py.

This transform is applied to *gradients before the optimizer*, so with
compression ON the all-reduce itself still runs in the compressed dtype only
if XLA schedules it after quantize — we force that by quantizing inside the
loss-grad function boundary (see train/step.py) and summing quantized values.
For the dry-run accounting, the visible effect is the gradient tree entering
the optimizer in int8-roundtripped form.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_roundtrip(g: jax.Array) -> jax.Array:
    """Quantize-dequantize one tensor (absmax/127 scale)."""
    if g.dtype == jnp.int32 or g.ndim == 0:
        return g
    g32 = g.astype(jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / s), -127, 127).astype(jnp.int8)
    return (q.astype(jnp.float32) * s).astype(g.dtype)


def int8_compress_tree(grads):
    return jax.tree.map(int8_roundtrip, grads)


def compression_error_bound(g: jax.Array) -> float:
    """Max elementwise error bound: absmax/254 (half a quant step)."""
    return float(jnp.max(jnp.abs(g)) / 254.0)
