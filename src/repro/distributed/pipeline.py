"""GPipe-style pipeline parallelism over a 'stage' mesh axis.

Exact, autodiff-compatible microbatch pipelining expressed with
shard_map + lax.ppermute (the jax-native mapping of the paper-era
"dataflow pipeline between stages" onto a TPU mesh — DESIGN.md §6):

  * stage s owns a contiguous slice of layers (params stacked on a leading
    [S, ...] axis sharded over 'stage');
  * at tick t, stage 0 injects microbatch t, every stage applies its slice
    to its current activation, results rotate s -> s+1 via ppermute;
  * after S + M - 1 ticks the last stage has emitted all M microbatches;
    outputs are recovered with a masked psum (only the last stage's buffer
    is nonzero).

Backward through ppermute is the reverse permute, so jax.grad of a
pipelined loss *is* the backward pipeline — no custom scheduling code.
This is bubble-optimal GPipe (bubble fraction (S-1)/(S+M-1)); 1F1B-style
re-ordering is a scheduling refinement on the same primitive.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def gpipe(stage_fn, mesh: Mesh, *, axis: str = "stage",
          n_microbatches: int | None = None):
    """Build a pipelined apply: (params_stacked [S,...], x [B,...]) -> y.

    stage_fn(stage_params, x_mb) -> y_mb must preserve the activation shape
    (homogeneous d_model across stages, as in all our transformer stacks).
    """
    s = mesh.shape[axis]

    def apply(params_stacked, x):
        m = n_microbatches or s
        assert x.shape[0] % m == 0, (x.shape, m)
        micro = x.reshape(m, x.shape[0] // m, *x.shape[1:])

        pspecs = jax.tree.map(lambda _: P(axis), params_stacked)

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(pspecs, P()),            # params sharded, data replicated
            out_specs=P(),
            check_rep=False)
        def pipelined(params_local, micro_all):
            sidx = jax.lax.axis_index(axis)
            mb = micro_all.shape[1]
            buf = jnp.zeros_like(micro_all[0])
            out = jnp.zeros_like(micro_all)
            perm = [(i, (i + 1) % s) for i in range(s)]
            for t in range(m + s - 1):
                inject = micro_all[min(t, m - 1)]
                is_first = (sidx == 0) & (t < m)
                cur = jnp.where(is_first, inject, buf)
                y = stage_fn(jax.tree.map(lambda p: p[0], params_local), cur)
                w = t - (s - 1)                 # microbatch finished this tick
                if w >= 0:
                    write = (sidx == s - 1)
                    out = out.at[w].set(jnp.where(write, y, out[w]))
                buf = jax.lax.ppermute(y, axis, perm)
            # only the last stage holds real outputs; sum-off the zeros
            out = jnp.where(sidx == s - 1, out, jnp.zeros_like(out))
            return jax.lax.psum(out, axis)

        y = pipelined(params_stacked, micro)
        return y.reshape(x.shape[0], *y.shape[2:])

    return apply


def stack_stage_params(per_stage_params: list):
    """[stage0_tree, stage1_tree, ...] -> single tree with leading S axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_stage_params)
