"""Sharding rules: parameter/activation PartitionSpecs for the production mesh.

Axis roles (DESIGN.md §6):
  pod    — pure data parallelism across pods (gradient all-reduce over DCN)
  data   — data parallelism for the batch *and* FSDP for parameters
           (params/optimizer state sharded over `data`, all-gathered on use —
           XLA SPMD inserts the collectives from the NamedSharding specs)
  model  — tensor parallelism: attention heads, FFN hidden, vocab, experts'
           hidden dim; also the KV-cache sequence shards for decode (SP).

Rules are name-based over the param-tree paths, then right-aligned to the
leaf's rank so the stacked scan-group leading axis is automatically
replicated. `None` mesh (single-CPU tests) makes every helper a no-op.
"""

from __future__ import annotations

import os
import re
from dataclasses import dataclass, field

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Axis name for the packed-tile data-parallel mesh (DESIGN.md §16): the
# [T, ...] leading dim of packed pair tiles, train chunk-scans, and the
# §14 prefilter's corpus spans are all sharded over this one axis.
TILE_AXIS = "tile"

_HOST_COUNT_FLAG = "--xla_force_host_platform_device_count"


def force_host_device_count(n: int) -> int:
    """Opt in to `n` simulated host (CPU) devices, before first backend use.

    Appends `--xla_force_host_platform_device_count=n` to XLA_FLAGS unless a
    count is already present (so CI / callers that pre-set the env win), then
    returns the realized `jax.local_device_count()`. Must run before JAX
    initializes its backends — the count locks on first device query. If the
    backend initialized earlier with a different count, the realized count is
    returned as-is; callers that need exactly `n` should check the return.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if _HOST_COUNT_FLAG not in flags:
        os.environ["XLA_FLAGS"] = (
            (flags + " " if flags else "") + f"{_HOST_COUNT_FLAG}={int(n)}")
    return jax.local_device_count()


def tile_mesh(n_devices: int | None = None) -> Mesh:
    """1-D mesh over TILE_AXIS spanning the first `n_devices` local devices
    (all of them when None). A subset mesh is legal — this is what lets one
    8-device pytest process exercise device_count ∈ {1, 2, 8}."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"tile_mesh: requested {n} devices, have {len(devs)} "
            f"(use force_host_device_count() before first JAX use)")
    return Mesh(np.asarray(devs[:n]), (TILE_AXIS,))


def tile_runtime(n_devices: int | None = None) -> Runtime:
    """Runtime whose mesh is a 1-D tile mesh — the object threaded into
    `ScoringEngine(runtime=...)` and the search server."""
    return Runtime(mesh=tile_mesh(n_devices))

# (regex over '/'-joined path, base spec for the *unstacked* param)
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed/table$",        ("model", "data")),   # [V, D] vocab-TP, D-FSDP
    (r"lm_head/w$",          ("data", "model")),   # [D, V]
    (r"(wq|wk|wv)$",         ("data", "model")),   # [D, H*hd]
    (r"(wq_b|wk_b|wv_b)$",   ("model",)),          # qkv bias [H*hd]
    (r"wo$",                 ("model", "data")),   # [H*hd, D]
    (r"mlp/w_in$",           ("data", "model")),   # [D, 2F] (fused gate+up)
    (r"mlp/w_out$",          ("model", "data")),   # [F, D]
    (r"moe/router$",         (None, None)),        # [D, E] small, replicated
    (r"moe/w_in$",           (None, "data", "model")),   # [E, D, 2F]
    (r"moe/w_out$",          (None, "model", "data")),   # [E, F, D]
    (r"mamba/in_proj$",      ("data", "model")),   # [D, 2*Din]
    (r"mamba/conv_w$",       ("model", None)),     # [Din, k]
    (r"mamba/conv_b$",       ("model",)),
    (r"mamba/x_proj$",       ("model", None)),     # [Din, R+2N]
    (r"mamba/dt_proj$",      (None, "model")),     # [R, Din]
    (r"mamba/dt_bias$",      ("model",)),
    (r"mamba/a_log$",        ("model", None)),     # [Din, N]
    (r"mamba/d$",            ("model",)),
    (r"mamba/out_proj$",     ("model", "data")),   # [Din, D]
    (r"rwkv/(wr|wk|wv|wg)$", ("data", "model")),
    (r"rwkv/wo$",            ("model", "data")),
    (r"rwkv/(w0|u)$",        ("model", None)),     # [H, K]
    (r"rwkv/(lora_a\w*)$",   (None, None)),        # tiny LoRAs, replicated
    (r"rwkv/(lora_b\w*)$",   (None, None)),
    (r"rwkv/(mix_\w+)$",     (None,)),
    (r"cmix/w_in$",          ("data", "model")),
    (r"cmix/w_out$",         ("model", "data")),
    (r"cmix/wr$",            ("data", "model")),
    (r"norm|scale|ln",       (None,)),             # norms replicated
]


@dataclass
class Runtime:
    """Mesh + axis-role bundle threaded through step builders."""
    mesh: Mesh | None = None
    batch_axes: tuple = ("data",)            # ('pod','data') when multi-pod
    tp_axis: str = "model"
    fsdp_axis: str = "data"
    remat: bool = True
    opt_state_dtype: str = "float32"         # bf16 for the 398B config

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.mesh.devices.shape)) if self.mesh else 1


def make_runtime(mesh: Mesh | None, **kw) -> Runtime:
    if mesh is not None and "pod" in mesh.axis_names:
        kw.setdefault("batch_axes", ("pod", "data"))
    return Runtime(mesh=mesh, **kw)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(path: str, ndim: int) -> P:
    """Resolve the PartitionSpec for a param path, right-aligned to rank."""
    for pat, base in _PARAM_RULES:
        if re.search(pat, path):
            spec = tuple(base)
            if len(spec) > ndim:                # e.g. bias folded smaller
                spec = spec[-ndim:]
            return P(*((None,) * (ndim - len(spec)) + spec))
    return P(*((None,) * ndim))                 # default: replicated


def param_shardings(rt: Runtime, params):
    """Tree of NamedShardings (or None off-mesh) matching the param tree."""
    if rt.mesh is None:
        return jax.tree.map(lambda _: None, params)

    def leaf(path, x):
        return NamedSharding(rt.mesh, param_spec(_path_str(path), x.ndim))

    return jax.tree_util.tree_map_with_path(leaf, params)


def constrain(rt: Runtime, x, *spec):
    """with_sharding_constraint that is a no-op off-mesh. `spec` entries may
    be 'dp' (expands to the batch axes), an axis name, or None. Any entry
    whose mesh size does not divide the corresponding dim is dropped — this
    is what lets the same model code serve batch=256 training and the
    batch=1 long_500k cell."""
    if rt.mesh is None:
        return x
    resolved = []
    for dim, s in zip(x.shape, spec):
        axes = rt.batch_axes if s == "dp" else s
        if axes is None:
            resolved.append(None)
            continue
        ax_tuple = axes if isinstance(axes, tuple) else (axes,)
        size = 1
        for a in ax_tuple:
            size *= rt.mesh.shape[a]
        resolved.append(axes if (dim % size == 0 and dim >= size) else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rt.mesh, P(*resolved)))


def batch_sharding(rt: Runtime, ndim: int, *, seq_axis: int | None = None):
    """Input batch sharding: batch over dp axes; optionally seq over model."""
    if rt.mesh is None:
        return None
    spec = [None] * ndim
    spec[0] = rt.batch_axes
    if seq_axis is not None:
        spec[seq_axis] = rt.tp_axis
    return NamedSharding(rt.mesh, P(*spec))


def replicated(rt: Runtime):
    if rt.mesh is None:
        return None
    return NamedSharding(rt.mesh, P())
