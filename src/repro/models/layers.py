"""Shared LM layers: norms, RoPE, attention (train/prefill/decode), MLP.

Attention has two exact paths:
  * dense — score matrix materialized; used for short sequences and for
    single-token decode against a KV cache (scores are [B,H,1,S] — tiny);
  * chunked — lax.scan over KV blocks with online softmax (FlashAttention
    recurrence expressed in XLA); used for long prefill/train so the [T,S]
    score matrix never materializes. `kernels/flash_attn.py` is the Pallas
    realization of the same recurrence for real-TPU runs.

Everything is mask-exact w.r.t. causal, sliding-window and softcap semantics
shared with kernels/ref.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -1e30
CHUNK_THRESHOLD = 2048
KV_CHUNK = 1024


def rmsnorm(x: Array, w: Array, eps: float = 1e-6) -> Array:
    x32 = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * rms * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x [B, T, H, hd], positions [B, T] -> rotated x."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs     # [B,T,half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def swiglu_mlp(p, x: Array) -> Array:
    gate_up = jnp.einsum("btd,df->btf", x, p["w_in"])
    gate, up = jnp.split(gate_up, 2, axis=-1)
    return jnp.einsum("btf,fd->btd", jax.nn.silu(gate) * up, p["w_out"])


# ---------------------------------------------------------------- attention

def _qkv(p, x: Array, cfg, positions: Array):
    b, t, _ = x.shape
    q = jnp.einsum("btd,dh->bth", x, p["wq"])
    k = jnp.einsum("btd,dh->bth", x, p["wk"])
    v = jnp.einsum("btd,dh->bth", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["wq_b"], k + p["wk_b"], v + p["wv_b"]
    q = q.reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    if cfg.rope_theta is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _scores(q: Array, k: Array, cfg) -> Array:
    """[B,T,H,hd] x [B,S,KV,hd] -> [B,H,T,S] with GQA via reshape."""
    b, t, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, t, kv, g, hd)
    sc = jnp.einsum("btkgd,bskd->bkgts", qg.astype(jnp.float32),
                    k.astype(jnp.float32)) * (hd ** -0.5)
    sc = sc.reshape(b, kv * g, t, s)
    if cfg.attn_softcap is not None:
        sc = cfg.attn_softcap * jnp.tanh(sc / cfg.attn_softcap)
    return sc


def _apply_probs(p: Array, v: Array) -> Array:
    """[B,H,T,S] x [B,S,KV,hd] -> [B,T,H,hd]."""
    b, h, t, s = p.shape
    kv = v.shape[2]
    g = h // kv
    pg = p.reshape(b, kv, g, t, s)
    out = jnp.einsum("bkgts,bskd->btkgd", pg, v.astype(jnp.float32))
    return out.reshape(b, t, h, v.shape[-1])


def _mask(q_pos: Array, kv_pos: Array, *, causal: bool, window: int | None,
          kv_len_mask: Array | None = None) -> Array:
    """q_pos [B,T], kv_pos [B,S] -> bool [B,1,T,S]."""
    qp = q_pos[:, :, None]
    kp = kv_pos[:, None, :]
    m = jnp.ones(qp.shape[:1] + (qp.shape[1], kp.shape[2]), bool)
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= (qp - kp) < window
    if kv_len_mask is not None:
        m &= kv_len_mask[:, None, :]
    return m[:, None, :, :]


def attention_core(q, k, v, cfg, mask) -> Array:
    """Exact masked attention, dense scores. mask [B,1,T,S] bool."""
    sc = _scores(q, k, cfg)
    sc = jnp.where(mask, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    p = jnp.where(mask, p, 0.0)
    return _apply_probs(p, v).astype(q.dtype)


def chunked_attention_core(q, k, v, cfg, *, q_pos, kv_pos, causal, window) -> Array:
    """Online-softmax over KV chunks (flash recurrence in XLA)."""
    b, t, h, hd = q.shape
    s = k.shape[1]
    n_chunks = -(-s // KV_CHUNK)
    pad = n_chunks * KV_CHUNK - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=2**30)
    kc = k.reshape(b, n_chunks, KV_CHUNK, *k.shape[2:]).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, KV_CHUNK, *v.shape[2:]).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(b, n_chunks, KV_CHUNK).transpose(1, 0, 2)

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        k_i, v_i, p_i = xs
        sc = _scores(q, k_i, cfg)                                   # [B,H,T,C]
        msk = _mask(q_pos, p_i, causal=causal, window=window)
        sc = jnp.where(msk, sc, NEG_INF)
        m_cur = jnp.max(sc, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        pr = jnp.exp(sc - m_new[..., None])
        pr = jnp.where(msk, pr, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(pr, axis=-1)
        acc = acc * alpha[..., None] + _apply_probs(pr, v_i).transpose(0, 2, 1, 3)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, t), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, t), jnp.float32)
    a0 = jnp.zeros((b, h, t, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)                # [B,T,H,hd]


def self_attention(p, x: Array, cfg, *, positions: Array, local: bool,
                   cache=None, cache_pos=None):
    """Self-attention. Train/prefill (cache=None): returns (y, (k, v)) so the
    caller can build a KV cache. Decode (cache given): x is [B,1,D]; the cache
    is a *ring buffer* {"k","v" [B,W,KV,hd], "pos" [B,W] int32 (-1 = empty)} —
    for sliding-window layers W == window, so a 500k-context Danube/Gemma-2
    local layer holds a constant-size cache (DESIGN.md §6, SP/serving)."""
    b, t, _ = x.shape
    window = cfg.sliding_window if local else None
    q, k, v = _qkv(p, x, cfg, positions)

    if cache is None:
        if t >= CHUNK_THRESHOLD:
            out = chunked_attention_core(q, k, v, cfg, q_pos=positions,
                                         kv_pos=positions, causal=True,
                                         window=window)
        else:
            mask = _mask(positions, positions, causal=True, window=window)
            out = attention_core(q, k, v, cfg, mask)
        y = jnp.einsum("bthd,hdD->btD", out,
                       p["wo"].reshape(cfg.n_heads, cfg.head_dim, -1))
        return y, (k, v)

    # decode: ring-buffer write at cache_pos % W, attend over stored positions
    k_cache, v_cache, pos_buf = cache["k"], cache["v"], cache["pos"]
    w_alloc = k_cache.shape[1]
    slot = cache_pos % w_alloc                                      # [B]
    onehot = (jnp.arange(w_alloc)[None, :] == slot[:, None])        # [B, W]
    quant = "k_scale" in cache
    if quant:     # int8 KV (per token x head absmax scale) — §Perf cell C
        k_q, k_s = quantize_kv(k)
        v_q, v_s = quantize_kv(v)
        k_cache = jnp.where(onehot[:, :, None, None], k_q, k_cache)
        v_cache = jnp.where(onehot[:, :, None, None], v_q, v_cache)
        k_scale = jnp.where(onehot[:, :, None], k_s, cache["k_scale"])
        v_scale = jnp.where(onehot[:, :, None], v_s, cache["v_scale"])
        k_use = k_cache.astype(jnp.float32) * k_scale[..., None]
        v_use = v_cache.astype(jnp.float32) * v_scale[..., None]
    else:
        k_cache = jnp.where(onehot[:, :, None, None], k.astype(k_cache.dtype),
                            k_cache)
        v_cache = jnp.where(onehot[:, :, None, None], v.astype(v_cache.dtype),
                            v_cache)
        k_use, v_use = k_cache, v_cache
    pos_buf = jnp.where(onehot, cache_pos[:, None], pos_buf)
    valid = (pos_buf >= 0) & (pos_buf <= cache_pos[:, None])
    mask = _mask(positions, pos_buf, causal=False, window=window,
                 kv_len_mask=valid)
    out = attention_core(q, k_use, v_use, cfg, mask)
    y = jnp.einsum("bthd,hdD->btD", out,
                   p["wo"].reshape(cfg.n_heads, cfg.head_dim, -1))
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos_buf}
    if quant:
        new_cache["k_scale"] = k_scale
        new_cache["v_scale"] = v_scale
    return y, new_cache


def quantize_kv(x: Array):
    """[..., hd] -> (int8 values, per-row absmax/127 scale [...])."""
    s = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1), 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, s


def cross_attention(p, x: Array, enc_out: Array, cfg, enc_mask: Array | None = None):
    """Decoder cross-attention (seamless). x [B,T,D], enc_out [B,S,D]."""
    b, t, _ = x.shape
    s = enc_out.shape[1]
    q = jnp.einsum("btd,dh->bth", x, p["wq"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = jnp.einsum("bsd,dh->bsh", enc_out, p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = jnp.einsum("bsd,dh->bsh", enc_out, p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    ones_q = jnp.zeros((b, t), jnp.int32)
    kv_pos = jnp.zeros((b, s), jnp.int32)
    mask = _mask(ones_q, kv_pos, causal=False, window=None, kv_len_mask=enc_mask)
    out = attention_core(q, k, v, cfg, mask)
    return jnp.einsum("bthd,hdD->btD", out,
                      p["wo"].reshape(cfg.n_heads, cfg.head_dim, -1))
