"""LM assembly: block dispatch, scan-over-groups, forward / prefill / decode.

The layer stack is applied with `lax.scan` over repeating groups (HLO stays
compact: Jamba-72L lowers as 9 steps of an 8-layer group). Training wraps the
group body in `jax.checkpoint` so only per-group carries are saved — the
standard remat-over-scan memory policy at 1000-node scale.

Caches are pytrees mirroring the group structure, leaves stacked [G, ...]:
  attn  -> {"k","v" [G,B,W,KV,hd], "pos" [G,B,W]}   (W = window for local)
  mamba -> {"conv" [G,B,K-1,Din], "ssm" [G,B,Din,N]}
  rwkv  -> {"shift_t","shift_c" [G,B,1,D], "wkv" [G,B,H,K,V]}
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import Runtime, constrain
from repro.models import layers, rwkv6
from repro.models.config import ModelConfig
from repro.models.mamba import mamba_block
from repro.models.moe import moe_ffn

Array = jax.Array


# ------------------------------------------------------------------ blocks

def _ffn_part(p, h: Array, cfg, is_moe: bool, kind: str, cmix_state=None,
              rt=None):
    """Returns (y, aux_loss, cmix_shift_out)."""
    if kind == "rwkv":
        y, last = rwkv6.channel_mix(p["cmix"], h, shift_state=cmix_state)
        return y, 0.0, last
    if is_moe:
        y, aux = moe_ffn(p["moe"], h, cfg, rt)
        return y, aux, None
    return layers.swiglu_mlp(p["mlp"], h), 0.0, None


def apply_block(p, x: Array, cfg, kind: str, is_moe: bool, *,
                positions: Array, cache=None, cache_pos=None, enc_out=None,
                causal: bool = True, rt=None):
    """One layer: (mixer + residual) then (ffn + residual). Returns
    (x, new_cache, aux_loss)."""
    new_cache: dict[str, Any] = {}
    h = layers.rmsnorm(x, p["ln1"]["scale"], cfg.norm_eps)
    if kind in ("attn", "attn_local"):
        if causal:
            y, c = layers.self_attention(
                p["attn"], h, cfg, positions=positions,
                local=(kind == "attn_local"),
                cache=None if cache is None else cache["attn"],
                cache_pos=cache_pos)
            if cache is not None:
                new_cache["attn"] = c
            else:
                new_cache["attn_kv"] = c       # (k, v) for prefill cache build
        else:                                   # encoder: bidirectional
            mask = layers._mask(positions, positions, causal=False, window=None)
            q, k, v = layers._qkv(p["attn"], h, cfg, positions)
            out = layers.attention_core(q, k, v, cfg, mask)
            y = jnp.einsum("bthd,hdD->btD", out,
                           p["attn"]["wo"].reshape(cfg.n_heads, cfg.head_dim, -1))
    elif kind == "mamba":
        y, c = mamba_block(p["mamba"], h, cfg,
                           state=None if cache is None else cache["mamba"])
        new_cache["mamba"] = c
    elif kind == "rwkv":
        st = cache["rwkv"] if cache is not None else None
        y, shift_t, wkv = rwkv6.time_mix(
            p["rwkv"], h, cfg,
            shift_state=None if st is None else st["shift_t"],
            wkv_state=None if st is None else st["wkv"])
        new_cache["rwkv"] = {"shift_t": shift_t, "wkv": wkv}
    else:
        raise ValueError(kind)
    if cfg.post_block_norm:
        y = layers.rmsnorm(y, p["post_ln1"]["scale"], cfg.norm_eps)
    x = x + y

    if enc_out is not None:                     # decoder cross-attention
        h = layers.rmsnorm(x, p["ln_x"]["scale"], cfg.norm_eps)
        x = x + layers.cross_attention(p["xattn"], h, enc_out, cfg)

    h = layers.rmsnorm(x, p["ln2"]["scale"], cfg.norm_eps)
    cm_st = (cache["rwkv"]["shift_c"] if (kind == "rwkv" and cache is not None)
             else None)
    y, aux, cm_last = _ffn_part(p, h, cfg, is_moe, kind, cmix_state=cm_st,
                                rt=rt)
    if kind == "rwkv":
        new_cache["rwkv"]["shift_c"] = cm_last
    if cfg.post_block_norm:
        y = layers.rmsnorm(y, p["post_ln2"]["scale"], cfg.norm_eps)
    return x + y, new_cache, aux


# ------------------------------------------------------------- group scan

def _scan_groups(params, cfg, rt: Runtime, x: Array, *, positions,
                 caches=None, cache_pos=None, enc_out=None, causal=True,
                 remat=False, groups_key="groups", kinds=None, moes=None):
    kinds = kinds or cfg.layer_kinds()
    moes = moes if moes is not None else cfg.layer_is_moe()
    # Megatron-style sequence sharding between layers pays off only for pure
    # attention stacks; MoE dispatch and SSM/RWKV time-scans index the whole
    # sequence locally, and a seq-sharded residual forces the partitioner
    # into masked-gather all-reduces of the [E,C,D] dispatch buffers
    # (observed: 2.5 TB/device/step on granite before this policy;
    # EXPERIMENTS.md §Perf).
    seq_shard = (x.shape[1] >= rt.n_devices
                 and not cfg.no_seq_shard
                 and not cfg.moe_period
                 and all(k in ("attn", "attn_local") for k in kinds))

    def body(carry, xs):
        x = carry
        grp, cache_grp = xs
        new_caches = []
        aux_total = 0.0
        for j, kind in enumerate(kinds):
            x, nc, aux = apply_block(
                grp[j], x, cfg, kind, moes[j], positions=positions,
                cache=None if cache_grp is None else cache_grp[j],
                cache_pos=cache_pos, enc_out=enc_out, causal=causal, rt=rt)
            new_caches.append(nc)
            aux_total = aux_total + aux
        if seq_shard:
            x = constrain(rt, x, "dp", rt.tp_axis, None)
        else:
            x = constrain(rt, x, "dp", None, None)
        return x, (new_caches, aux_total)

    if remat:
        body = jax.checkpoint(body)
    if caches is None:
        x, (stacks, auxes) = jax.lax.scan(
            lambda c, g: body(c, (g, None)), x, params[groups_key])
    else:
        x, (stacks, auxes) = jax.lax.scan(body, x, (params[groups_key], caches))
    return x, stacks, jnp.sum(auxes)


# ---------------------------------------------------------------- forward

def embed_tokens(params, cfg, tokens: Array) -> Array:
    return jnp.take(params["embed"]["table"], tokens, axis=0)


def logits_from_hidden(params, cfg, x: Array) -> Array:
    x = layers.rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("btd,vd->btv", x, params["embed"]["table"])
    else:
        logits = jnp.einsum("btd,dv->btv", x, params["lm_head"]["w"])
    logits = logits.astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    if cfg.vocab_padded != cfg.vocab_size:   # mask Megatron-style pad ids
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e9, logits)
    return logits


def forward(params, cfg: ModelConfig, rt: Runtime, tokens: Array, *,
            embeds: Array | None = None, remat: bool = False):
    """Training/scoring forward. tokens [B,S_tok]; embeds [B,P,D] prepended
    (VLM patches / audio frames). Returns (logits [B,S,V], aux_loss)."""
    x = embed_tokens(params, cfg, tokens)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = constrain(rt, x, "dp", None, None)
    if cfg.is_enc_dec:
        raise ValueError("use encdec.forward_encdec for enc-dec models")
    x, _, aux = _scan_groups(params, cfg, rt, x, positions=positions,
                             remat=remat)
    return logits_from_hidden(params, cfg, x), aux


# ------------------------------------------------------------------ serve

def _attn_alloc(cfg, kind: str, cache_len: int) -> int:
    if kind == "attn_local" and cfg.sliding_window:
        return min(cache_len, cfg.sliding_window)
    return cache_len


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=None) -> list:
    """Zero/empty decode cache (list over group positions, leaves [G,...])."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    g = cfg.n_groups
    quant = cfg.kv_cache_dtype == "int8"
    kv_dtype = jnp.int8 if quant else dtype
    caches = []
    for kind in cfg.layer_kinds():
        if kind in ("attn", "attn_local"):
            w = _attn_alloc(cfg, kind, cache_len)
            c = {
                "k": jnp.zeros((g, batch, w, cfg.n_kv_heads, cfg.head_dim),
                               kv_dtype),
                "v": jnp.zeros((g, batch, w, cfg.n_kv_heads, cfg.head_dim),
                               kv_dtype),
                "pos": jnp.full((g, batch, w), -1, jnp.int32)}
            if quant:
                c["k_scale"] = jnp.zeros((g, batch, w, cfg.n_kv_heads),
                                         jnp.float32)
                c["v_scale"] = jnp.zeros((g, batch, w, cfg.n_kv_heads),
                                         jnp.float32)
            caches.append({"attn": c})
        elif kind == "mamba":
            caches.append({"mamba": {
                "conv": jnp.zeros((g, batch, cfg.mamba_d_conv - 1,
                                   cfg.mamba_d_inner), dtype),
                "ssm": jnp.zeros((g, batch, cfg.mamba_d_inner,
                                  cfg.mamba_d_state), jnp.float32)}})
        elif kind == "rwkv":
            h, hk = cfg.n_rwkv_heads, cfg.rwkv_head_dim
            caches.append({"rwkv": {
                "shift_t": jnp.zeros((g, batch, 1, cfg.d_model), dtype),
                "shift_c": jnp.zeros((g, batch, 1, cfg.d_model), dtype),
                "wkv": jnp.zeros((g, batch, h, hk, hk), jnp.float32)}})
    return caches


def prefill(params, cfg: ModelConfig, rt: Runtime, tokens: Array, *,
            embeds: Array | None = None, cache_len: int | None = None):
    """Process the prompt; return (last_logits [B,V], cache, cache_pos [B])."""
    x = embed_tokens(params, cfg, tokens)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    cache_len = cache_len or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = constrain(rt, x, "dp", None, None)
    x, kv_stacks, _ = _scan_groups(params, cfg, rt, x, positions=positions)

    # Build the decode cache from the per-layer (k, v) stacks.
    caches = init_cache(cfg, b, cache_len)
    quant = cfg.kv_cache_dtype == "int8"
    for j, kind in enumerate(cfg.layer_kinds()):
        if kind in ("attn", "attn_local"):
            k_all, v_all = kv_stacks[j]["attn_kv"]       # [G,B,S,KV,hd]
            w = caches[j]["attn"]["k"].shape[2]
            tail = jnp.arange(s - min(s, w), s)          # last W positions
            slots = tail % w
            k_tail, v_tail = k_all[:, :, tail], v_all[:, :, tail]
            if quant:
                k_tail, k_s = layers.quantize_kv(k_tail)
                v_tail, v_s = layers.quantize_kv(v_tail)
                caches[j]["attn"]["k_scale"] = \
                    caches[j]["attn"]["k_scale"].at[:, :, slots].set(k_s)
                caches[j]["attn"]["v_scale"] = \
                    caches[j]["attn"]["v_scale"].at[:, :, slots].set(v_s)
            caches[j]["attn"]["k"] = caches[j]["attn"]["k"].at[:, :, slots].set(
                k_tail.astype(caches[j]["attn"]["k"].dtype))
            caches[j]["attn"]["v"] = caches[j]["attn"]["v"].at[:, :, slots].set(
                v_tail.astype(caches[j]["attn"]["v"].dtype))
            caches[j]["attn"]["pos"] = caches[j]["attn"]["pos"].at[:, :, slots].set(
                jnp.broadcast_to(tail, caches[j]["attn"]["pos"][:, :, slots].shape))
        elif kind == "mamba":
            caches[j]["mamba"] = kv_stacks[j]["mamba"]
        elif kind == "rwkv":
            caches[j]["rwkv"] = kv_stacks[j]["rwkv"]
    last = logits_from_hidden(params, cfg, x[:, -1:])[:, 0]
    cache_pos = jnp.full((b,), s, jnp.int32)
    return last, caches, cache_pos


def decode_step(params, cfg: ModelConfig, rt: Runtime, token: Array,
                caches, cache_pos: Array):
    """One decode step. token [B,1] int32, cache_pos [B] = current length.
    Returns (logits [B,V], new_caches, cache_pos+1)."""
    x = embed_tokens(params, cfg, token)
    b = x.shape[0]
    positions = cache_pos[:, None]
    x = constrain(rt, x, "dp", None, None)
    x, new_caches, _ = _scan_groups(params, cfg, rt, x, positions=positions,
                                    caches=caches, cache_pos=cache_pos)
    logits = logits_from_hidden(params, cfg, x)[:, 0]
    return logits, new_caches, cache_pos + 1


# ------------------------------------------------------------------- loss

def lm_loss(params, cfg: ModelConfig, rt: Runtime, batch, *,
            remat: bool = True, aux_weight: float = 0.01):
    """Next-token cross-entropy (+ MoE aux). batch: {"tokens" [B,S],
    optional "embeds" [B,P,D]} — targets are tokens shifted by one."""
    tokens = batch["tokens"]
    embeds = batch.get("embeds")
    logits, aux = forward(params, cfg, rt, tokens, embeds=embeds, remat=remat)
    p = 0 if embeds is None else embeds.shape[1]
    pred = logits[:, p:-1]                      # positions predicting tokens
    tgt = tokens[:, 1:]
    logz = jax.nn.logsumexp(pred, axis=-1)
    gold = jnp.take_along_axis(pred, tgt[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    return nll + aux_weight * aux
