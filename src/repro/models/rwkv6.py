"""RWKV-6 (Finch) block: time-mix with data-dependent per-channel decay +
squared-ReLU channel-mix.

The defining v6 feature — the decay w_t produced from the shifted input via a
small LoRA — is implemented faithfully; the five static token-shift mixing
vectors follow the v6 structure. The WKV recurrence has two exact backends:
the jnp scan below (XLA path, used for lowering/dry-run and CPU tests) and
`kernels/wkv6.py` (Pallas TPU path, same math — see tests/test_kernels.py).

Decode carries {"shift_t", "shift_c", "wkv"} — O(1) state per token, making
rwkv6-7b eligible for the long_500k cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def _token_shift(x: Array, last: Array | None) -> Array:
    """Returns x_{t-1} (zeros / carried state at t=0). x [B,T,D]."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def wkv_scan(r, k, v, w, u):
    """Exact recurrence; r/k/w [B,T,H,K], v [B,T,H,V], u [H,K] -> [B,T,H,V].
    o_t = r_t^T S_{t-1} + (r_t . (u*k_t)) v_t ;  S_t = diag(w_t) S + k_t v_t^T"""
    def step(s, inp):
        rt, kt, vt, wt = inp                                # [B,H,K]...[B,H,V]
        o = jnp.einsum("bhk,bhkv->bhv", rt, s) \
            + jnp.sum(rt * u * kt, -1, keepdims=True) * vt
        s = wt[..., None] * s + kt[..., None] * vt[..., None, :]
        return s, o

    b, t, h, kd = r.shape
    vd = v.shape[-1]
    s0 = jnp.zeros((b, h, kd, vd), jnp.float32)
    f32 = lambda a: a.astype(jnp.float32).transpose(1, 0, 2, 3)
    s_final, o = jax.lax.scan(step, s0, (f32(r), f32(k), f32(v), f32(w)))
    return o.transpose(1, 0, 2, 3), s_final


def time_mix(p, x: Array, cfg, *, shift_state=None, wkv_state=None):
    """Returns (y [B,T,D], new_shift [B,1,D], new_wkv [B,H,K,V])."""
    b, t, d = x.shape
    h, kd = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    prev = _token_shift(x, shift_state)
    delta = prev - x

    def mixed(name):
        return x + delta * p[f"mix_{name}"]

    r = jnp.einsum("btd,dk->btk", mixed("r"), p["wr"]).reshape(b, t, h, kd)
    k = jnp.einsum("btd,dk->btk", mixed("k"), p["wk"]).reshape(b, t, h, kd)
    v = jnp.einsum("btd,dk->btk", mixed("v"), p["wv"]).reshape(b, t, h, kd)
    g = jax.nn.silu(jnp.einsum("btd,dk->btk", mixed("g"), p["wg"]))
    # data-dependent decay (the Finch signature): w = exp(-exp(w0 + lora(xw)))
    xw = mixed("w")
    w_lora = jnp.einsum("btr,rk->btk", jnp.tanh(
        jnp.einsum("btd,dr->btr", xw, p["lora_a_w"])), p["lora_b_w"])
    w = jnp.exp(-jnp.exp(p["w0"].reshape(h * kd).astype(jnp.float32)
                         + w_lora.astype(jnp.float32)))
    w = w.reshape(b, t, h, kd)

    if t == 1 and wkv_state is not None:                    # decode fast path
        rt, kt, vt, wt = (a[:, 0].astype(jnp.float32) for a in (r, k, v, w))
        o = jnp.einsum("bhk,bhkv->bhv", rt, wkv_state) \
            + jnp.sum(rt * p["u"] * kt, -1, keepdims=True) * vt
        new_wkv = wt[..., None] * wkv_state + kt[..., None] * vt[..., None, :]
        o = o[:, None]                                      # [B,1,H,V]
    else:
        o, new_wkv = wkv_scan(r, k, v, w, p["u"])
        if wkv_state is not None:                           # prefill w/ state
            pass                                            # state was zero-init
    # per-head groupnorm then gate
    o32 = o.astype(jnp.float32)
    mean = jnp.mean(o32, -1, keepdims=True)
    var = jnp.var(o32, -1, keepdims=True)
    o = ((o32 - mean) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype)
    o = (o.reshape(b, t, d) * g).astype(x.dtype)
    y = jnp.einsum("btk,kd->btd", o, p["wo"])
    return y, x[:, -1:], new_wkv


def channel_mix(p, x: Array, *, shift_state=None):
    """RWKV channel-mix: squared-ReLU FFN with receptance gate."""
    prev = _token_shift(x, shift_state)
    delta = prev - x
    xk = x + delta * p["mix_ck"]
    xr = x + delta * p["mix_cr"]
    kk = jnp.einsum("btd,df->btf", xk, p["w_in"])
    kk = jnp.square(jax.nn.relu(kk))
    out = jnp.einsum("btf,fd->btd", kk, p["w_out"])
    rr = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["wr"]))
    return rr * out, x[:, -1:]
