"""Parameter initialization for every block kind.

Init is truncated-normal(0.02) with depth-scaled output projections. Stacked
over scan groups on axis 0 (init is vmapped over group keys), so a leaf for a
32-layer homogeneous model has shape [32, ...]; a 72-layer Jamba with
group_size 8 has [9, ...] leaves for each of the 8 group positions.

For the dry-run nothing is ever materialized: `abstract_params` wraps this in
`jax.eval_shape`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def _dense(key, shape, dtype, scale=0.02):
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * scale).astype(dtype)


def _norm(d, dtype):
    return {"scale": jnp.zeros((d,), dtype)}


def init_attn(key, cfg: ModelConfig, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense(ks[0], (d, h * hd), dtype),
        "wk": _dense(ks[1], (d, kv * hd), dtype),
        "wv": _dense(ks[2], (d, kv * hd), dtype),
        "wo": _dense(ks[3], (h * hd, d), dtype,
                     scale=0.02 / max(1, cfg.n_layers) ** 0.5),
    }
    if cfg.qkv_bias:
        p["wq_b"] = jnp.zeros((h * hd,), dtype)
        p["wk_b"] = jnp.zeros((kv * hd,), dtype)
        p["wv_b"] = jnp.zeros((kv * hd,), dtype)
    return p


def init_mlp(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {"w_in": _dense(k1, (cfg.d_model, 2 * cfg.d_ff), dtype),
            "w_out": _dense(k2, (cfg.d_ff, cfg.d_model), dtype,
                            scale=0.02 / max(1, cfg.n_layers) ** 0.5)}


def init_moe(key, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    e, f = cfg.n_experts, cfg.d_ff_expert
    return {"router": _dense(k1, (cfg.d_model, e), jnp.float32),
            "w_in": _dense(k2, (e, cfg.d_model, 2 * f), dtype),
            "w_out": _dense(k3, (e, f, cfg.d_model), dtype,
                            scale=0.02 / max(1, cfg.n_layers) ** 0.5)}


def init_mamba(key, cfg: ModelConfig, dtype):
    d, din, n, r = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state, cfg.dt_rank
    ks = jax.random.split(key, 6)
    # S4D-real A init: A[:, j] = -(j+1) -> a_log = log(j+1)
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (din, 1))
    return {
        "in_proj": _dense(ks[0], (d, 2 * din), dtype),
        "conv_w": _dense(ks[1], (din, cfg.mamba_d_conv), dtype, scale=0.3),
        "conv_b": jnp.zeros((din,), dtype),
        "x_proj": _dense(ks[2], (din, r + 2 * n), dtype),
        "dt_proj": _dense(ks[3], (r, din), dtype, scale=r ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(  # softplus^-1 of uniform [1e-3, 1e-1]
            jax.random.uniform(ks[4], (din,), jnp.float32, 1e-3, 1e-1))
        ).astype(jnp.float32),
        "a_log": jnp.log(a),
        "d": jnp.ones((din,), jnp.float32),
        "out_proj": _dense(ks[5], (din, d), dtype,
                           scale=0.02 / max(1, cfg.n_layers) ** 0.5),
    }


def init_rwkv(key, cfg: ModelConfig, dtype, lora_rank: int = 32):
    d = cfg.d_model
    h, hk = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wr": _dense(ks[0], (d, d), dtype),
        "wk": _dense(ks[1], (d, d), dtype),
        "wv": _dense(ks[2], (d, d), dtype),
        "wg": _dense(ks[3], (d, d), dtype),
        "wo": _dense(ks[4], (d, d), dtype,
                     scale=0.02 / max(1, cfg.n_layers) ** 0.5),
        "lora_a_w": _dense(ks[5], (d, lora_rank), dtype),
        "lora_b_w": _dense(ks[6], (lora_rank, d), dtype, scale=0.01),
        "w0": jnp.full((h, hk), -6.0, jnp.float32),   # slow decay at init
        "u": _dense(ks[7], (h, hk), jnp.float32, scale=0.5),
    }
    for name in ("r", "k", "v", "g", "w"):
        p[f"mix_{name}"] = jnp.full((d,), 0.5, dtype)
    return p


def init_cmix(key, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_in": _dense(k1, (cfg.d_model, cfg.d_ff), dtype),
            "w_out": _dense(k2, (cfg.d_ff, cfg.d_model), dtype,
                            scale=0.02 / max(1, cfg.n_layers) ** 0.5),
            "wr": _dense(k3, (cfg.d_model, cfg.d_model), dtype),
            "mix_ck": jnp.full((cfg.d_model,), 0.5, dtype),
            "mix_cr": jnp.full((cfg.d_model,), 0.5, dtype)}


def init_block(key, cfg: ModelConfig, kind: str, is_moe: bool, dtype,
               cross_attn: bool = False):
    """One layer's params for the given kind."""
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {"ln1": _norm(d, dtype)}
    if kind in ("attn", "attn_local"):
        p["attn"] = init_attn(ks[0], cfg, dtype)
    elif kind == "mamba":
        p["mamba"] = init_mamba(ks[0], cfg, dtype)
    elif kind == "rwkv":
        p["rwkv"] = init_rwkv(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if cfg.post_block_norm:
        p["post_ln1"] = _norm(d, dtype)
    if cross_attn:
        p["ln_x"] = _norm(d, dtype)
        p["xattn"] = init_attn(ks[3], cfg, dtype)
    p["ln2"] = _norm(d, dtype)
    if kind == "rwkv":
        p["cmix"] = init_cmix(ks[1], cfg, dtype)
    elif is_moe:
        p["moe"] = init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg, dtype)
    if cfg.post_block_norm:
        p["post_ln2"] = _norm(d, dtype)
    return p


def init_params(key, cfg: ModelConfig):
    """Full parameter tree. Group-stacked leaves on axis 0."""
    dtype = jnp.dtype(cfg.param_dtype)
    kinds, moes = cfg.layer_kinds(), cfg.layer_is_moe()
    k_embed, k_head, k_groups, k_enc = jax.random.split(key, 4)

    params = {
        "embed": {"table": _dense(k_embed, (cfg.vocab_padded, cfg.d_model),
                                  dtype)},
        "final_norm": _norm(cfg.d_model, dtype),
        "groups": [],
    }
    pos_keys = jax.random.split(k_groups, cfg.group_size)
    for j, (kind, moe) in enumerate(zip(kinds, moes)):
        gkeys = jax.random.split(pos_keys[j], cfg.n_groups)
        stacked = jax.vmap(
            lambda kk: init_block(kk, cfg, kind, moe, dtype,
                                  cross_attn=cfg.is_enc_dec))(gkeys)
        params["groups"].append(stacked)
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": _dense(k_head, (cfg.d_model, cfg.vocab_padded),
                                         dtype)}
    if cfg.is_enc_dec:
        n_enc_groups = cfg.n_enc_layers
        ekeys = jax.random.split(k_enc, n_enc_groups)
        params["enc_groups"] = [jax.vmap(
            lambda kk: init_block(kk, cfg, "attn", False, dtype))(ekeys)]
        params["enc_final_norm"] = _norm(cfg.d_model, dtype)
    return params


def abstract_params(cfg: ModelConfig, seed: int = 0):
    """ShapeDtypeStruct tree — no allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(seed), cfg))
