"""Encoder-decoder assembly (seamless-m4t backbone).

Per the assignment spec, the audio frontend is a stub: `input_specs()` feeds
precomputed fbank-frame *embeddings* [B, S_enc, D] straight into the encoder.
The encoder is a bidirectional transformer scan; the decoder is the standard
lm.py stack with cross-attention injected into every block (ln_x/xattn params
exist because cfg.is_enc_dec=True).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import Runtime, constrain
from repro.models import layers, lm
from repro.models.config import ModelConfig

Array = jax.Array


def encode(params, cfg: ModelConfig, rt: Runtime, frames: Array, *,
           remat: bool = False) -> Array:
    """frames [B, S_enc, D] (precomputed frame embeddings) -> enc_out."""
    b, s, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = constrain(rt, frames.astype(jnp.dtype(cfg.dtype)), "dp", None, None)
    x, _, _ = lm._scan_groups(
        params, cfg, rt, x, positions=positions, causal=False, remat=remat,
        groups_key="enc_groups", kinds=["attn"], moes=[False])
    return layers.rmsnorm(x, params["enc_final_norm"]["scale"], cfg.norm_eps)


def forward_encdec(params, cfg: ModelConfig, rt: Runtime, frames: Array,
                   tokens: Array, *, remat: bool = False):
    """Training forward: encoder over frames, decoder over target tokens with
    cross-attention. Returns (logits [B,S_dec,V], aux)."""
    enc_out = encode(params, cfg, rt, frames, remat=remat)
    x = lm.embed_tokens(params, cfg, tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = constrain(rt, x, "dp", None, None)
    x, _, aux = lm._scan_groups(params, cfg, rt, x, positions=positions,
                                enc_out=enc_out, remat=remat)
    return lm.logits_from_hidden(params, cfg, x), aux


def encdec_loss(params, cfg: ModelConfig, rt: Runtime, batch, *,
                remat: bool = True):
    logits, aux = forward_encdec(params, cfg, rt, batch["frames"],
                                 batch["tokens"], remat=remat)
    pred = logits[:, :-1]
    tgt = batch["tokens"][:, 1:]
    logz = jax.nn.logsumexp(pred, axis=-1)
    gold = jnp.take_along_axis(pred, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold) + 0.01 * aux


def prefill_encdec(params, cfg: ModelConfig, rt: Runtime, frames: Array,
                   tokens: Array, *, cache_len: int | None = None):
    """Encoder pass + decoder prompt prefill. Returns
    (last_logits, enc_out, caches, cache_pos)."""
    enc_out = encode(params, cfg, rt, frames)
    x = lm.embed_tokens(params, cfg, tokens)
    b, s, _ = x.shape
    cache_len = cache_len or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, kv_stacks, _ = lm._scan_groups(params, cfg, rt, x, positions=positions,
                                      enc_out=enc_out)
    caches = lm.init_cache(cfg, b, cache_len)
    for j, kind in enumerate(cfg.layer_kinds()):
        k_all, v_all = kv_stacks[j]["attn_kv"]
        w = caches[j]["attn"]["k"].shape[2]
        tail = jnp.arange(s - min(s, w), s)
        slots = tail % w
        caches[j]["attn"]["k"] = caches[j]["attn"]["k"].at[:, :, slots].set(
            k_all[:, :, tail].astype(caches[j]["attn"]["k"].dtype))
        caches[j]["attn"]["v"] = caches[j]["attn"]["v"].at[:, :, slots].set(
            v_all[:, :, tail].astype(caches[j]["attn"]["v"].dtype))
        caches[j]["attn"]["pos"] = caches[j]["attn"]["pos"].at[:, :, slots].set(
            jnp.broadcast_to(tail, caches[j]["attn"]["pos"][:, :, slots].shape))
    last = lm.logits_from_hidden(params, cfg, x[:, -1:])[:, 0]
    return last, enc_out, caches, jnp.full((b,), s, jnp.int32)


def decode_step_encdec(params, cfg: ModelConfig, rt: Runtime, token: Array,
                       enc_out: Array, caches, cache_pos: Array):
    x = lm.embed_tokens(params, cfg, token)
    positions = cache_pos[:, None]
    x, new_caches, _ = lm._scan_groups(params, cfg, rt, x, positions=positions,
                                       caches=caches, cache_pos=cache_pos,
                                       enc_out=enc_out)
    logits = lm.logits_from_hidden(params, cfg, x)[:, 0]
    return logits, new_caches, cache_pos + 1
