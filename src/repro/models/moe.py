"""Mixture-of-Experts FFN: top-k routing with per-sequence capacity and
scatter-based dispatch into dense [E, C, D] expert tiles.

Design rationale (DESIGN.md §5 — this is where the paper's discipline meets
the LM substrate): each expert's workload is a *small dense problem*; rather
than launching per-expert ragged work, tokens are packed into fixed-capacity
dense tiles so expert compute is one batched MXU einsum. Routing/dispatch is
computed **per sequence** (the batch dim is the GShard 'group' dim): every op
is batched over B, so sharding B over the data axes makes routing entirely
local to each data shard — no cross-shard sorts or global cumsums, which is
what makes this formulation scale to 1000+ nodes.

Tokens over capacity are dropped (contribute zero; the residual passes them
through) — standard GShard/Switch semantics with capacity_factor slack.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def moe_capacity(seq_len: int, n_experts: int, top_k: int,
                 capacity_factor: float) -> int:
    c = int(seq_len * top_k * capacity_factor / n_experts) + 1
    return max(top_k, min(c, seq_len))


def route(router_w: Array, x: Array, top_k: int):
    """x [B,S,D] -> (weights [B,S,k], experts [B,S,k] int32, aux_loss)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    weights, experts = jax.lax.top_k(logits, top_k)
    weights = jax.nn.softmax(weights, axis=-1)            # renorm over top-k
    # Switch-style load-balancing aux loss (fraction routed x mean prob)
    n_e = router_w.shape[-1]
    probs = jax.nn.softmax(logits, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(experts[..., 0], n_e, dtype=jnp.float32),
                    axis=(0, 1))
    aux = n_e * jnp.sum(frac * jnp.mean(probs, axis=(0, 1)))
    return weights, experts, aux


def _dispatch_indices(experts: Array, n_experts: int, capacity: int):
    """Per sequence: experts [S, k] -> (slot [S*k], keep [S*k]) where slot is
    the position inside the destination expert's capacity buffer."""
    s, k = experts.shape
    flat = experts.reshape(s * k)                          # token-major order
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)   # [S*k, E]
    pos = jnp.cumsum(onehot, axis=0) - 1                   # position per expert
    slot = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    keep = slot < capacity
    return jnp.clip(slot, 0, capacity - 1), keep


def moe_ffn(p, x: Array, cfg, rt=None) -> tuple[Array, Array]:
    """p: {router [D,E], w_in [E,D,2F], w_out [E,F,D]}; x [B,S,D].
    Returns (y [B,S,D], aux_loss).

    The vmap over sequences carries `spmd_axis_name` so the partitioner pins
    every dispatch intermediate's batch dim to the data axes — without it,
    XLA replicates the [E,C,D] buffers over data and pays giant all-gathers
    (observed in the granite dry-run before this fix; EXPERIMENTS.md §Perf)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = moe_capacity(s, e, k, cfg.capacity_factor)
    # Decide whether the batch dim can be pinned to the data axes; if so,
    # every vmapped intermediate gets an explicit sharding via
    # spmd_axis_name + the inner constraints below. Without the pins the
    # partitioner aligns the [B,E,C,D] dispatch buffers to the FSDP weight
    # layout — replicating the batch dim and paying ~2.5 TB/device of
    # masked-gather all-reduces (measured; EXPERIMENTS.md §Perf).
    spmd = None
    if rt is not None and rt.mesh is not None:
        from repro.distributed.sharding import constrain
        x = constrain(rt, x, "dp", None, None)   # seq must be shard-local
        n_dp = 1
        for a in rt.batch_axes:
            n_dp *= rt.mesh.shape[a]
        if b % n_dp == 0 and b >= n_dp:
            spmd = rt.batch_axes if len(rt.batch_axes) > 1 else rt.batch_axes[0]

    def cst(v, *spec):
        if spmd is None:
            return v
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.lax.with_sharding_constraint(
            v, NamedSharding(rt.mesh, P(*spec)))

    weights, experts, aux = route(p["router"], x, k)

    def one_seq(x_s, w_s, e_s):
        """x_s [S,D], w_s [S,k], e_s [S,k] -> [S,D].

        Gather-based dispatch: the only scatter builds a tiny int32
        slot->token map [E,C]; every wide tensor then moves through gathers,
        which the SPMD partitioner handles with the batch dim sharded
        (scatter-based dispatch forced XLA to replicate the [B,E,C,D]
        buffers over the data axis — see EXPERIMENTS.md §Perf, MoE fix)."""
        slot, keep = _dispatch_indices(e_s, e, cap)        # [S*k]
        flat_e = e_s.reshape(s * k)
        sentinel = s * k
        assign = jnp.where(keep, jnp.arange(s * k, dtype=jnp.int32), sentinel)
        tok_for_slot = jnp.full((e, cap), sentinel, jnp.int32)
        tok_for_slot = tok_for_slot.at[flat_e, slot].min(assign)  # [E,C] small
        # gather tokens into dense expert tiles (sentinel -> zero row)
        x_pad = jnp.concatenate([x_s, jnp.zeros((1, d), x_s.dtype)], axis=0)
        src_tok = jnp.minimum(tok_for_slot // k, s)        # [E,C] token ids
        buf = cst(x_pad[src_tok], None, None, None)        # [E,C,D] gather
        if cfg.moe_use_kernel:
            # Fused expert FFN (kernels/moe_experts.py): hidden activations
            # stay in VMEM — the SPA-GCN fusion discipline applied to the
            # MoE HBM bottleneck (EXPERIMENTS.md §Perf, granite iteration 6).
            from repro.kernels.moe_experts import moe_expert_ffn
            bc = min(128, cap)
            pad_c = (-cap) % bc
            buf_p = jnp.pad(buf, ((0, 0), (0, pad_c), (0, 0)))
            y_p = moe_expert_ffn(buf_p, p["w_in"], p["w_out"], block_c=bc)
            y_buf = cst(y_p[:, :cap], None, None, None)
        else:
            h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"])  # fused gate+up
            h = cst(h, None, None, rt.tp_axis if rt else None)
            gate, up = jnp.split(h, 2, axis=-1)
            h = jax.nn.silu(gate) * up
            # NOTE (§Perf iteration 5, refuted): pinning y_buf's D to the
            # model axis to force a reduce-scatter here measured *worse*
            # (1356 vs 1223 GB wire) — XLA does not sink the reduction
            # through the slot gather and pays an extra reshard.
            y_buf = cst(jnp.einsum("ecf,efd->ecd", h, p["w_out"]),
                        None, None, None)
        y_tok = y_buf[flat_e, slot]                        # gather back [S*k,D]
        y_tok = y_tok * (w_s.reshape(s * k)[:, None] * keep[:, None])
        return jnp.sum(y_tok.reshape(s, k, d), axis=1)

    y = jax.vmap(one_seq, spmd_axis_name=spmd)(
        x, weights.astype(x.dtype), experts)
    return y.astype(x.dtype), aux
