"""Model configuration for the LM substrate (all 10 assigned architectures).

One frozen dataclass describes every family: dense / MoE / SSM / hybrid /
enc-dec / VLM. The per-layer structure is a repeating `layer_pattern` of block
kinds ("attn", "attn_local", "mamba", "rwkv"); MoE replaces the dense FFN on
every `moe_period`-th layer. Layers are *stacked by repeating group* so the
model applies them under `lax.scan` (compact HLO — a 72-layer Jamba lowers as
9 scan steps of an 8-layer group).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention
    rope_theta: float | None = 10_000.0     # None -> no RoPE (Jamba attn)
    qkv_bias: bool = False
    attn_softcap: float | None = None       # Gemma-2 attention logit softcap
    final_softcap: float | None = None      # Gemma-2 final logit softcap
    sliding_window: int | None = None       # SWA window for "attn_local"
    post_block_norm: bool = False           # Gemma-2 sandwich norms
    # block structure
    layer_pattern: tuple = ("attn",)        # repeating unit of block kinds
    moe_period: int = 0                     # 0: never; k: every k-th layer
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    moe_use_kernel: bool = False     # fused expert kernel (TPU runtime path)
    # mamba
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_d_conv: int = 4
    mamba_dt_rank: int = 0                  # 0 -> ceil(d_model / 16)
    mamba_scan_unroll: int = 1              # steps fused per while iteration
    mamba_naive_disc: bool = False          # §Perf B-it0: materialize a_bar/bx
    # rwkv
    rwkv_head_dim: int = 64
    # enc-dec / frontends
    is_enc_dec: bool = False
    n_enc_layers: int = 0
    frontend: str | None = None             # None | "audio" | "vision"
    frontend_len: int = 0                   # prepended embed positions (vlm)
    dec_seq_divisor: int = 1                # enc-dec: S_dec = S // divisor
    # numerics
    norm_eps: float = 1e-6
    kv_cache_dtype: str = "bfloat16"        # "int8": quantized KV cache
    no_seq_shard: bool = False              # disable Megatron-SP residual
    tie_embeddings: bool = True
    dtype: str = "bfloat16"                 # activations
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"        # bf16 for the 398B config
    # long-context applicability (which shapes run; see DESIGN.md §5)
    subquadratic: bool = False              # eligible for long_500k

    # ---- derived ----
    @property
    def vocab_padded(self) -> int:
        """Embedding rows padded to 512 (Megatron-style) so the vocab dim
        shards evenly over any mesh axis; logits at padded ids are masked."""
        return -(-self.vocab_size // 512) * 512

    @property
    def group_size(self) -> int:
        return _lcm(len(self.layer_pattern), self.moe_period or 1)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.group_size == 0, (self.name, self.n_layers,
                                                      self.group_size)
        return self.n_layers // self.group_size

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or -(-self.d_model // 16)

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def layer_kinds(self) -> list[str]:
        """Block kind for each position inside one scan group."""
        return [self.layer_pattern[i % len(self.layer_pattern)]
                for i in range(self.group_size)]

    def layer_is_moe(self) -> list[bool]:
        if not self.moe_period:
            return [False] * self.group_size
        return [(i % self.moe_period) == self.moe_period - 1
                for i in range(self.group_size)]

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- analytic parameter / FLOP model (for roofline MODEL_FLOPS) ----
    def param_count(self) -> int:
        d, hd = self.d_model, self.head_dim
        n = 0
        per_kind = {}
        per_kind["attn"] = per_kind["attn_local"] = (
            d * (self.n_heads + 2 * self.n_kv_heads) * hd
            + self.n_heads * hd * d)
        din, st, rk = self.mamba_d_inner, self.mamba_d_state, self.dt_rank
        per_kind["mamba"] = (d * 2 * din + din * self.mamba_d_conv
                             + din * (rk + 2 * st) + rk * din + 2 * din
                             + din * d)
        hk = self.rwkv_head_dim
        per_kind["rwkv"] = (4 * d * d + d * d            # r,k,v,g,o
                            + 2 * (d * 32 + 32 * d)      # w/x loras (approx)
                            + 2 * self.n_rwkv_heads * hk  # w0, u
                            + d * self.d_ff + self.d_ff * d + d * d)  # chan mix
        dense_ffn = 3 * d * self.d_ff
        moe_ffn = self.n_experts * 3 * d * self.d_ff_expert + d * self.n_experts
        kinds, moes = self.layer_kinds(), self.layer_is_moe()
        for k, m in zip(kinds, moes):
            n += per_kind[k]
            if k in ("attn", "attn_local") or k in ("mamba", "rwkv"):
                if k == "rwkv":
                    pass                                  # rwkv has its own ffn
                else:
                    n += moe_ffn if m else dense_ffn
        n *= self.n_groups
        if self.is_enc_dec:  # encoder layers: attn + ffn; decoder adds cross
            enc = per_kind["attn"] + dense_ffn
            n += self.n_enc_layers * enc + self.n_layers * per_kind["attn"]
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return int(n)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.moe_period:
            return self.param_count()
        full_moe = self.n_experts * 3 * self.d_model * self.d_ff_expert
        active_moe = self.top_k * 3 * self.d_model * self.d_ff_expert
        n_moe_layers = sum(self.layer_is_moe()) * self.n_groups
        return int(self.param_count() - n_moe_layers * (full_moe - active_moe))


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)
