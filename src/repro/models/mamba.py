"""Mamba (selective SSM) block — the Jamba hybrid's recurrent layer.

Recurrence per channel c with state dim N:
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t        (ZOH discretization)
    y_t = C_t . h_t + D * x_t
with data-dependent (selective) B_t, C_t, dt_t. Train/prefill scans over time
with `lax.scan` (compact HLO under the layer-group scan); decode carries
(conv_state, ssm_state) — O(1) per token, which is what makes the
`long_500k` cell runnable for the hybrid arch (DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def _causal_conv(x: Array, w: Array, b: Array, conv_state: Array | None):
    """Depthwise causal conv over time. x [B,T,Din], w [Din,K], b [Din].
    conv_state [B, K-1, Din] for decode. Returns (y, new_state)."""
    k = w.shape[-1]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                  # [B, T+K-1, Din]
    y = sum(xp[:, i:i + x.shape[1], :] * w[:, i] for i in range(k)) + b
    new_state = xp[:, -(k - 1):, :]
    return y, new_state


def mamba_block(p, x: Array, cfg, *, state=None):
    """x [B,T,D]. state None (train/prefill) or
    {"conv": [B,K-1,Din], "ssm": [B,Din,N]} (decode). Returns (y, new_state)."""
    b, t, d = x.shape
    din, n = cfg.mamba_d_inner, cfg.mamba_d_state
    xz = jnp.einsum("btd,de->bte", x, p["in_proj"])         # [B,T,2*Din]
    xin, z = jnp.split(xz, 2, axis=-1)

    conv_state = state["conv"] if state is not None else None
    xin, new_conv = _causal_conv(xin, p["conv_w"], p["conv_b"], conv_state)
    xin = jax.nn.silu(xin)

    proj = jnp.einsum("bte,er->btr", xin, p["x_proj"])      # [B,T,R+2N]
    dt_low, b_mat, c_mat = jnp.split(
        proj, [cfg.dt_rank, cfg.dt_rank + n], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("btr,re->bte", dt_low, p["dt_proj"])
                         + p["dt_bias"])                    # [B,T,Din]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))            # [Din,N]
    dt32 = dt.astype(jnp.float32)
    x32 = xin.astype(jnp.float32)

    h0 = (state["ssm"].astype(jnp.float32) if state is not None
          else jnp.zeros((b, din, n), jnp.float32))

    # Discretize *inside* the step: materializing a_bar/bx as [B,T,Din,N]
    # arrays cost 2 x 4.3 GB/device/layer of HBM traffic on the Jamba
    # train_4k cell (N=16x blowup); computed per step they live in
    # registers. unroll fuses consecutive steps, amortizing the
    # state's fusion-boundary round trips (EXPERIMENTS.md §Perf, Jamba
    # iterations 1-2).
    if cfg.mamba_naive_disc:
        # §Perf B-iteration-0 baseline: precompute a_bar/bx as [B,T,Din,N]
        # arrays (the reference selective-scan formulation) — a 16x (N)
        # blowup of the scan inputs, kept behind a flag for the A/B.
        a_bar_all = jnp.exp(dt32[..., None] * a)            # [B,T,Din,N]
        bx_all = (dt32 * x32)[..., None] * b_mat.astype(jnp.float32)[:, :, None, :]

        def step0(h, inp):
            a_t, bx_t, c_t = inp
            h = a_t * h + bx_t
            return h, jnp.einsum("bdn,bn->bd", h, c_t)

        xs0 = (a_bar_all.transpose(1, 0, 2, 3), bx_all.transpose(1, 0, 2, 3),
               c_mat.astype(jnp.float32).transpose(1, 0, 2))
        h_final, ys = jax.lax.scan(step0, h0, xs0)
    else:
        def step(h, inp):
            dt_t, b_t, c_t, x_t = inp       # [B,Din],[B,N],[B,N],[B,Din]
            a_bar = jnp.exp(dt_t[..., None] * a)            # [B,Din,N]
            bx = (dt_t * x_t)[..., None] * b_t[:, None, :]
            h = a_bar * h + bx
            y = jnp.einsum("bdn,bn->bd", h, c_t)
            return h, y

        xs = (dt32.transpose(1, 0, 2),
              b_mat.astype(jnp.float32).transpose(1, 0, 2),
              c_mat.astype(jnp.float32).transpose(1, 0, 2),
              x32.transpose(1, 0, 2))
        h_final, ys = jax.lax.scan(step, h0, xs,
                                   unroll=cfg.mamba_scan_unroll)  # ys [T,B,Din]
    y = ys.transpose(1, 0, 2) + p["d"] * x32                # skip via D
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"])
    new_state = {"conv": new_conv.astype(x.dtype), "ssm": h_final}
    return out, new_state
