"""1-vs-N graph similarity search service (DESIGN.md §10).

The paper's end use is similarity *search*: one query compound scored
against a corpus of molecules, top results returned. The corpus side of
every pair is query-independent, so this server indexes the corpus ONCE
(GCN+Att embeddings through the engine's cache) and serves each query with
one query-side embedding plus a batched NTN+FCN head over the whole corpus
— the head kernel (`kernels/simgnn_head.py`) is the entire per-query device
cost. `benchmarks/search.py` measures the resulting warm-corpus speedup vs
rescoring every pair through the packed-sparse path.

The server is a thin orchestration layer: all scoring goes through
`core.engine.ScoringEngine` (`embed_graphs` / `pair_scores_from_embeddings`),
so path policy, caching, and parity anchoring stay in one place.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.core.cache import graph_key
from repro.core.engine import ScorePlan, ScoringEngine, WorkloadStats
from repro.core.store import (DEFAULT_SHARD_ROWS, ShardStore, StoreError,
                              tree_digest)
from repro.kernels.retrieval import (collapse_query_ntn,
                                     fit_prefilter_calibration,
                                     prefilter_query_vectors,
                                     retrieval_block_cols, topm_reference)


@dataclass
class SearchStats:
    """Measured server behavior: stage seconds are cumulative wall-clock so
    callers can report per-stage shares; cache counters come straight from
    the engine's LRU."""
    queries: int = 0
    pairs_scored: int = 0
    index_size: int = 0
    failed_embeddings: int = 0     # corpus rows that are NaN after indexing
                                   # (their embed bucket AND its reference
                                   # retry failed — DESIGN.md §12)
    shards_loaded: int = 0         # shards restored verified from disk (§13)
    shards_recovered: int = 0      # shards that failed verification and
                                   # were selectively re-embedded
    rows_reembedded: int = 0       # corpus rows recomputed during load()
    prefilter_queries: int = 0     # queries served through the two-stage
                                   # blocked top-M scan (DESIGN.md §14)
    prefilter_degraded: int = 0    # two-stage queries that fell back to the
                                   # exact full scan on prefilter failure
    recall_samples: int = 0        # two-stage queries also run exact for
                                   # online recall measurement
    recall_sum: float = 0.0        # summed sampled recall@k (mean = sum/n)
    embed_seconds: float = 0.0     # query-side embedding (+ any corpus misses)
    head_seconds: float = 0.0      # NTN+FCN over the corpus (exact scans)
    prefilter_seconds: float = 0.0  # blocked top-M scan (+ proxy collapse)
    gather_seconds: float = 0.0    # host-side survivor row gather
    rerank_seconds: float = 0.0    # exact NTN+FCN head over the M survivors
    calibrate_seconds: float = 0.0  # one-off proxy calibration per index
    topk_seconds: float = 0.0      # host-side partial sort
    cache: dict = field(default_factory=dict)

    @property
    def recall_mean(self) -> float:
        return (self.recall_sum / self.recall_samples
                if self.recall_samples else float("nan"))

    def as_dict(self) -> dict:
        return {"queries": self.queries, "pairs_scored": self.pairs_scored,
                "index_size": self.index_size,
                "failed_embeddings": self.failed_embeddings,
                "shards_loaded": self.shards_loaded,
                "shards_recovered": self.shards_recovered,
                "rows_reembedded": self.rows_reembedded,
                "prefilter_queries": self.prefilter_queries,
                "prefilter_degraded": self.prefilter_degraded,
                "recall_samples": self.recall_samples,
                "recall_mean": round(self.recall_mean, 4)
                if self.recall_samples else None,
                "embed_seconds": round(self.embed_seconds, 6),
                "head_seconds": round(self.head_seconds, 6),
                "prefilter_seconds": round(self.prefilter_seconds, 6),
                "gather_seconds": round(self.gather_seconds, 6),
                "rerank_seconds": round(self.rerank_seconds, 6),
                "calibrate_seconds": round(self.calibrate_seconds, 6),
                "topk_seconds": round(self.topk_seconds, 6),
                **{f"cache_{k}": v for k, v in self.cache.items()}}


class SimilaritySearchServer:
    """Index a graph corpus once, then serve top-k similarity queries.

    `index()` embeds every corpus graph through the engine's embedding
    cache and keeps the resulting `[N, F]` matrix resident — evictions from
    the LRU (which also serves ad-hoc `score()` traffic) never invalidate
    the index. `topk()` embeds the query (a cache hit if the client repeats
    it), broadcasts it against the corpus matrix through the fused head,
    and partial-sorts the scores host-side.
    """

    #: sampled two-stage recall below this at calibration time escalates
    #: the proxy from the collapsed linear fit to the exact streamed
    #: NTN+FCN scan (DESIGN.md §14).
    PREFILTER_TARGET_RECALL = 0.99

    def __init__(self, params, cfg, *, cache_size: int = 4096,
                 embed_with_kernels: bool = False,
                 shard_rows: int = DEFAULT_SHARD_ROWS,
                 recall_sample_every: int = 0,
                 clock: Callable[[], float] = time.perf_counter,
                 recorder=None, runtime=None):
        #: injectable timing source for every per-stage SearchStats timer
        #: (mirrors `CircuitBreaker`/`MicroBatcher`): tests drive
        #: deterministic stage seconds with a fake clock, no sleeps. The
        #: same clock feeds the engine (breaker cool-downs, trace records).
        self._clock = clock
        #: a multi-device `distributed.sharding.Runtime` splits the
        #: prefilter scan into per-device corpus spans (DESIGN.md §16).
        self.engine = ScoringEngine(params, cfg, path="embedding_cache",
                                    cache_size=cache_size,
                                    embed_with_kernels=embed_with_kernels,
                                    clock=clock, recorder=recorder,
                                    runtime=runtime)
        self.corpus: list[dict] = []
        self.corpus_emb: np.ndarray | None = None
        self.stats = SearchStats()
        #: persisted-shard size; also the prefilter's column-block unit so
        #: the streaming scan walks the corpus shard-by-shard (§14).
        self.shard_rows = int(shard_rows)
        #: 0 disables online recall sampling; N>0 runs every Nth two-stage
        #: query through the exact path too and records recall@k on stats.
        self.recall_sample_every = int(recall_sample_every)
        self._calib: dict | None = None
        self._two_stage_queries = 0

    # -------------------------------------------------------------- indexing

    def index(self, corpus: list[dict]) -> np.ndarray:
        """Embed and retain the corpus; returns the `[N, F]` matrix.

        Re-indexing replaces the corpus. Embeddings also land in the
        engine's LRU, so mixed flows (`engine.score` on pairs touching
        corpus graphs) hit without recomputing.
        """
        t0 = self._clock()
        self.corpus = list(corpus)
        self.corpus_emb = self.engine.embed_graphs(self.corpus)
        self._calib = None             # proxy must recalibrate per index
        self.stats.embed_seconds += self._clock() - t0
        self.stats.index_size = len(self.corpus)
        # Survive a failed corpus shard (DESIGN.md §12): the engine already
        # retried each failing embed bucket on the reference embedder and
        # NaN'd only the graphs whose retry ALSO failed — those rows stay in
        # the index (scores NaN, ranked last by topk) and are counted here
        # instead of killing the whole index() call.
        self.stats.failed_embeddings = int(
            (~np.isfinite(self.corpus_emb).all(axis=-1)).sum())
        self.stats.cache = self.engine.cache.stats()
        return self.corpus_emb

    # ------------------------------------------------------------ durability

    def save(self, directory: str, *, shard_rows: int | None = None) -> dict:
        """Persist the resident index (DESIGN.md §13): the `[N, F]` matrix
        in checksummed row shards plus a versioned manifest recording the
        WL `graph_key` of every row and a digest of the model params —
        restarts and other replicas `load()` it instead of re-embedding
        the corpus. Returns the manifest."""
        if self.corpus_emb is None:
            raise ValueError("no corpus indexed; call index(corpus) first")
        keys = [graph_key(g).hex() for g in self.corpus]
        return ShardStore(directory).write(
            np.ascontiguousarray(self.corpus_emb, np.float32),
            shard_rows=shard_rows or self.shard_rows, graph_keys=keys,
            meta={"kind": "similarity_index",
                  "params_digest": tree_digest(self.engine.params),
                  "n_graphs": len(self.corpus),
                  "feat_dim": int(self.corpus_emb.shape[1])})

    def load(self, directory: str, corpus: list[dict]) -> np.ndarray:
        """Adopt a persisted index for `corpus` (DESIGN.md §13 recovery
        ladder). Every shard is checksum-verified and its recorded
        `graph_key`s compared to the corpus rows it claims to cover; shards
        that verify are mmap-read, shards that are missing / torn /
        bit-flipped / mismatched are SELECTIVELY re-embedded from the
        corpus graphs — counted on `stats`/`health()`, never a silent full
        rebuild. Manifest-level problems (missing, unreadable, stale
        format version, wrong model params, wrong corpus size) raise a
        structured `StoreError`: with an untrustworthy manifest there is
        no per-shard story, and serving scores from it would violate the
        never-serve-corrupt-state contract. Bit-identical to `index()` on
        a clean store (embeddings round-trip as raw float32 bytes)."""
        store = ShardStore(directory)
        man = store.manifest()                 # ManifestError on stale/bad
        meta = man.get("meta", {})
        if meta.get("params_digest") != tree_digest(self.engine.params):
            raise StoreError(
                f"index at {directory} was built by a different model "
                f"(params digest {meta.get('params_digest')!r}): scores "
                "from it would be silently wrong — rebuild with index()")
        if meta.get("n_graphs") != len(corpus):
            raise StoreError(
                f"index at {directory} covers {meta.get('n_graphs')} "
                f"graphs but the corpus has {len(corpus)}")
        n, f = int(man["shape"][0]), int(man["shape"][1])
        counters = self.engine.counters        # surfaces via health()
        out = np.zeros((n, f), np.float32)
        corpus = list(corpus)
        row = 0
        loaded = recovered = reembedded = 0
        first_shard_rows = None
        for info in store.shard_infos(man):
            rows = info.shape[0]
            if first_shard_rows is None:
                first_shard_rows = rows
            status = store.verify_shard(info)
            if status == "ok" and info.graph_keys:
                actual = [graph_key(corpus[i]).hex()
                          for i in range(row, row + rows)]
                if list(info.graph_keys) != actual:
                    status = "key_mismatch"
            if status == "ok":
                out[row:row + rows] = store.read_shard(info)
                loaded += 1
            else:
                counters[f"store_shard_{status}"] += 1
                # Selective recovery: re-embed ONLY this shard's rows (the
                # engine's embed path — identical bytes to index()'s).
                out[row:row + rows] = self.engine.embed_graphs(
                    corpus[row:row + rows])
                recovered += 1
                reembedded += rows
            row += rows
        if row != n:
            raise StoreError(f"manifest shards cover {row} rows but claim "
                             f"shape[0]={n}")
        self.corpus = corpus
        self.corpus_emb = out
        self._calib = None
        if first_shard_rows:
            # Adopt the persisted shard size as the prefilter block unit so
            # the streaming scan stays 1:1 with the on-disk shards (§14).
            self.shard_rows = first_shard_rows
        self.stats.index_size = n
        self.stats.shards_loaded += loaded
        self.stats.shards_recovered += recovered
        self.stats.rows_reembedded += reembedded
        counters["store_shards_loaded"] += loaded
        counters["store_shards_recovered"] += recovered
        counters["store_rows_reembedded"] += reembedded
        self.stats.failed_embeddings = int(
            (~np.isfinite(out).all(axis=-1)).sum())
        # Re-populate the LRU exactly as index() would have, so mixed
        # flows (`engine.score` on pairs touching corpus graphs) hit — and
        # eviction stays irrelevant to the resident matrix either way.
        for g, emb in zip(corpus, out):
            if np.isfinite(emb).all():
                emb = np.array(emb, np.float32)
                emb.setflags(write=False)
                self.engine.cache.put(graph_key(g), emb)
        self.stats.cache = self.engine.cache.stats()
        return out

    # -------------------------------------------------------------- querying

    def topk(self, query: dict, k: int = 10, *, mode: str = "exact",
             prefilter_m: int = 64) -> tuple[np.ndarray, np.ndarray]:
        """Score `query` against the corpus; returns (indices, scores) of
        the k most similar corpus graphs, scores descending.

        mode="exact" runs the full NTN+FCN head over all N corpus rows;
        mode="two_stage" shortlists `prefilter_m` candidates with the
        blocked streaming top-M proxy scan first, then reranks only the
        survivors through the exact head (DESIGN.md §14) — identical
        ranking whenever the shortlist contains the true top-k, and
        bit-identical to exact when `prefilter_m >= N`. k is clamped to
        the corpus size (k >= N returns all N ranked); `prefilter_m` is
        raised to k when k is larger, so the shortlist always covers the
        requested depth."""
        return self.search([query], k, mode=mode,
                           prefilter_m=prefilter_m)[0]

    def search(self, queries: list[dict], k: int = 10, *,
               mode: str = "exact", prefilter_m: int = 64) -> list[tuple]:
        """Batched search: [(indices, scores), ...] per query. In
        two_stage mode the prefilter scans ALL queries in one blocked
        kernel launch and the rerank batches every survivor into one head
        call — the per-query cost amortizes with the batch."""
        if mode not in ("exact", "two_stage"):
            raise ValueError(f"mode must be 'exact' or 'two_stage', "
                             f"got {mode!r}")
        if not queries:
            return []
        if mode == "exact":
            return [self._exact_topk(q, k) for q in queries]
        return self._two_stage_search(queries, k, prefilter_m)

    def _exact_topk(self, query: dict, k: int) -> tuple:
        scores = self.scores(query)
        t0 = self._clock()
        top, s = self._rank(scores, k)
        self.stats.topk_seconds += self._clock() - t0
        return top, s

    @staticmethod
    def _rank(scores: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
        """Top-k of a score vector, NaN-safe and k-clamped.

        Ranks on a NaN->-inf copy: argpartition on `-scores` would float
        NaN entries (failed corpus embeddings) INTO the top-k, silently
        displacing real results. Returned scores keep their NaN so a
        caller that does see one knows it is a failure, not a similarity.
        k is clamped to [0, N]; k >= N returns the full stable descending
        order (an all-NaN vector ranks in ascending index order), so
        oversized k never crashes the partial sort."""
        n = len(scores)
        k = max(0, min(int(k), n))
        if k == 0:
            return np.empty(0, np.int64), scores[:0]
        rank = np.where(np.isfinite(scores), scores, -np.inf)
        if k >= n:
            top = np.argsort(-rank, kind="stable")
        else:
            top = np.argpartition(-rank, k - 1)[:k]
            top = top[np.argsort(-rank[top], kind="stable")]
        return top.astype(np.int64), scores[top]

    def scores(self, query: dict) -> np.ndarray:
        """Full `[N]` similarity vector of `query` vs the indexed corpus."""
        if self.corpus_emb is None:
            raise ValueError("no corpus indexed; call index(corpus) first")
        t0 = self._clock()
        hq = self.engine.embed_graphs([query])
        t1 = self._clock()
        hq = np.broadcast_to(hq[0], self.corpus_emb.shape)
        out = self.engine.pair_scores_from_embeddings(hq, self.corpus_emb)
        t2 = self._clock()
        self.stats.queries += 1
        self.stats.pairs_scored += len(self.corpus)
        self.stats.embed_seconds += t1 - t0
        self.stats.head_seconds += t2 - t1
        self.stats.cache = self.engine.cache.stats()
        return out

    # ------------------------------------------------- two-stage retrieval

    def _two_stage_search(self, queries: list[dict], k: int,
                          prefilter_m: int) -> list[tuple]:
        """Blocked top-M prefilter over all queries at once, then one
        batched exact rerank of the survivors (DESIGN.md §14)."""
        if self.corpus_emb is None:
            raise ValueError("no corpus indexed; call index(corpus) first")
        n = len(self.corpus)
        # The shortlist must cover the requested k (a top-99 query through
        # a 4-wide shortlist could never return 99 rows), clamped to N.
        m = max(1, min(max(int(prefilter_m), min(int(k), n)), n))
        nq = len(queries)
        t0 = self._clock()
        hq = self.engine.embed_graphs(queries)
        t1 = self._clock()
        self.stats.embed_seconds += t1 - t0
        calib = self._calibration()
        block = retrieval_block_cols(n, shard_rows=self.shard_rows)
        spans = self._prefilter_spans(n, block)
        try:
            if calib["proxy"] == "linear":
                qv = prefilter_query_vectors(
                    self.engine.params["ntn"]["w"], hq, calib)
                ntn_ops = None
            else:                                  # exact streamed NTN+FCN
                qv = hq
                ntn_ops = collapse_query_ntn(self.engine.params["ntn"], hq)
            _, pidx = self._span_topm(qv, ntn_ops, m, block, spans)
        except Exception:
            # Degradation rung (§12/§14/§16): a failing prefilter kernel —
            # including a single dead span of the sharded scan — must not
            # fail the query: serve it through the exact full scan (query
            # embeds are already cached, so only the head re-runs) and
            # count the degradation for health()/dashboards.
            self.engine.counters["prefilter_degraded"] += nq
            self.stats.prefilter_degraded += nq
            return [self._exact_topk(q, k) for q in queries]
        t2 = self._clock()
        self.stats.prefilter_seconds += t2 - t1
        # Ascending survivor order: sequential row gather AND the same tie
        # order as the exact path's stable sort — with m == N this makes
        # the rerank input literally the corpus matrix, so scores and
        # ranking come out bit-identical to mode="exact".
        pidx = np.sort(pidx, axis=1)
        h2 = self.corpus_emb[pidx.reshape(-1)]
        h1 = np.repeat(hq, m, axis=0)
        t3 = self._clock()
        self.stats.gather_seconds += t3 - t2
        s = self.engine.pair_scores_from_embeddings(h1, h2).reshape(nq, m)
        t4 = self._clock()
        self.stats.rerank_seconds += t4 - t3
        results = []
        for qi in range(nq):
            loc, sc = self._rank(s[qi], k)
            results.append((pidx[qi][loc].astype(np.int64), sc))
        self.stats.topk_seconds += self._clock() - t4
        self.stats.queries += nq
        self.stats.pairs_scored += nq * m
        self.stats.prefilter_queries += nq
        self.stats.cache = self.engine.cache.stats()
        self.engine.last_plan = ScorePlan(
            path="embedding_cache", fallback="embedding_cache",
            fit_idx=np.arange(nq), over_idx=np.empty(0, np.int64),
            stats=WorkloadStats(n_pairs=nq * m),
            reason=f"two-stage retrieval: {calib['proxy']} prefilter "
                   f"top-{m} of {n} ({len(spans)} span(s), block {block}), "
                   "exact rerank",
            prefilter_m=m, devices=len(spans))
        self._sample_recall(queries, k, results)
        return results

    def _prefilter_spans(self, n: int, block: int) -> list[tuple[int, int]]:
        """Contiguous corpus spans for the prefilter scan — one per device
        of the engine's mesh (DESIGN.md §16), each a whole number of
        `block` columns so every span's block tiles coincide with the
        unsharded scan's. Fewer blocks than devices collapses to fewer
        spans; a single-device engine scans the corpus as one span
        (bit-identical to the pre-§16 behavior by construction)."""
        n_blocks = -(-n // block)
        n_spans = max(1, min(int(self.engine.n_devices), n_blocks))
        per = -(-n_blocks // n_spans) * block
        return [(lo, min(lo + per, n)) for lo in range(0, n, per)]

    def _span_topm(self, qv, ntn_ops, m: int, block: int,
                   spans: list[tuple[int, int]]) -> tuple:
        """Per-shard prefilter: run the blocked top-M scan over each corpus
        span, then merge the per-span shortlists host-side (§16).

        The merge is associative — each span's top-min(m, span_n) is a
        superset of its contribution to the global top-m — and selects by
        (-score, ascending global index), exactly the tie order of the
        kernel's running block merge (`top_k` keeps the earliest position,
        blocks arrive in ascending order). Span scores are bitwise equal to
        the unsharded scan's (same block tiles, same dot products), so the
        merged survivor set — and therefore the reranked top-k — is
        bit-identical to the single-span scan."""
        parts = []
        for lo, hi in spans:
            s, i = self.engine.prefilter_topm(
                qv, self.corpus_emb[lo:hi], min(m, hi - lo),
                block_cols=block, ntn_operands=ntn_ops)
            parts.append((s, i.astype(np.int64) + lo))
        if len(parts) == 1:
            return parts[0]
        self.engine.counters["prefilter_span_scans"] += len(parts)
        s = np.concatenate([p[0] for p in parts], axis=1)
        i = np.concatenate([p[1] for p in parts], axis=1)
        out_s = np.empty((s.shape[0], m), np.float32)
        out_i = np.empty((s.shape[0], m), np.int64)
        for q in range(s.shape[0]):
            order = np.lexsort((i[q], -s[q]))[:m]
            out_s[q], out_i[q] = s[q][order], i[q][order]
        return out_s, out_i

    def _sample_recall(self, queries: list[dict], k: int,
                       results: list[tuple]) -> None:
        """Online recall measurement: every `recall_sample_every`-th
        two-stage query is ALSO served exactly and the overlap of the two
        top-k sets recorded on `stats` (§14 observability). Sampling cost
        shows up in the exact-path stage timers like any exact query."""
        every = self.recall_sample_every
        for qi, query in enumerate(queries):
            self._two_stage_queries += 1
            if not every or (self._two_stage_queries % every):
                continue
            exact_idx, _ = self._exact_topk(query, k)
            got, want = set(results[qi][0].tolist()), exact_idx.tolist()
            recall = (sum(t in got for t in want) / len(want)
                      if want else 1.0)
            self.stats.recall_samples += 1
            self.stats.recall_sum += recall
            self.engine.counters["prefilter_recall_samples"] += 1

    def _calibration(self) -> dict:
        """Fit + validate the prefilter proxy for the current index (once
        per `index()`/`load()`; DESIGN.md §14).

        Fits the collapsed linear proxy against exact head scores on a
        sampled corpus sub-matrix, measures its recall@10 there, and keeps
        it only if it meets `PREFILTER_TARGET_RECALL`; otherwise escalates
        to the exact streamed NTN+FCN scan (recall 1.0 by construction, at
        K matmul slices per block instead of one). The chosen proxy, fit
        quality and measured recalls are recorded for `health()`."""
        if self._calib is not None:
            return self._calib
        t0 = self._clock()
        emb = self.corpus_emb
        finite = np.flatnonzero(np.isfinite(emb).all(axis=1))
        ntn = self.engine.params["ntn"]
        calib: dict = {"proxy": "ntn_exact", "r2": None,
                       "recall_linear": None,
                       "target_recall": self.PREFILTER_TARGET_RECALL}
        # Validation slice: exact scores for a few pseudo-queries against a
        # bounded corpus sample — index-time cost stays O(1) in N.
        nq = min(8, len(finite))
        nv = min(2048, len(finite))
        if nq >= 2:
            rng = np.random.default_rng(0x5EED ^ len(emb))
            qi = rng.choice(finite, nq, replace=False)
            vi = (finite if nv == len(finite)
                  else rng.choice(finite, nv, replace=False))
            h1 = np.repeat(emb[qi], nv, axis=0)
            h2 = np.tile(emb[vi], (nq, 1))
            y = self.engine.pair_scores_from_embeddings(h1, h2)
            exact = y.reshape(nq, nv)
            kk = min(10, nv)
            true_k = np.argsort(-np.where(np.isfinite(exact), exact,
                                          -np.inf),
                                axis=1, kind="stable")[:, :kk]
            try:
                fit = fit_prefilter_calibration(ntn["w"], h1, h2, y)
                qv = prefilter_query_vectors(ntn["w"], emb[qi], fit)
                mm = min(64, nv)
                _, cand = topm_reference(qv, emb[vi], mm)
                rec = sum(t in set(row.tolist())
                          for row, tk in zip(cand, true_k)
                          for t in tk) / (nq * kk)
                calib.update(fit, recall_linear=round(rec, 4))
                if rec >= self.PREFILTER_TARGET_RECALL:
                    calib["proxy"] = "linear"
            except (np.linalg.LinAlgError, ValueError):
                pass                       # degenerate sample: stay exact
        self._calib = calib
        self.stats.calibrate_seconds += self._clock() - t0
        self.engine.counters["prefilter_calibrations"] += 1
        self.engine.counters[f"prefilter_proxy:{calib['proxy']}"] += 1
        return calib

    def health(self) -> dict:
        """Engine fault-tolerance state plus the server's own view of the
        index (DESIGN.md §12/§13) — one call for dashboards/tests. The
        durable-state counters (`store_*`, `ckpt_*`) ride inside the
        engine's counter dict."""
        calib = self._calib or {}
        return {**self.engine.health(),
                "index_size": self.stats.index_size,
                "failed_embeddings": self.stats.failed_embeddings,
                "shards_loaded": self.stats.shards_loaded,
                "shards_recovered": self.stats.shards_recovered,
                "rows_reembedded": self.stats.rows_reembedded,
                "prefilter": {
                    "proxy": calib.get("proxy"),
                    "r2": calib.get("r2"),
                    "recall_linear": calib.get("recall_linear"),
                    "target_recall": calib.get("target_recall"),
                    "queries": self.stats.prefilter_queries,
                    "degraded": self.stats.prefilter_degraded,
                    "recall_samples": self.stats.recall_samples,
                    "recall_mean": (round(self.stats.recall_mean, 4)
                                    if self.stats.recall_samples else None),
                    "block_cols": (retrieval_block_cols(
                        len(self.corpus), shard_rows=self.shard_rows)
                        if self.corpus else None),
                    "spans": (len(self._prefilter_spans(
                        len(self.corpus), retrieval_block_cols(
                            len(self.corpus),
                            shard_rows=self.shard_rows)))
                        if self.corpus else None)}}

    @property
    def hit_rate(self) -> float:
        return self.engine.cache.hit_rate
