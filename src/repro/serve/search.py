"""1-vs-N graph similarity search service (DESIGN.md §10).

The paper's end use is similarity *search*: one query compound scored
against a corpus of molecules, top results returned. The corpus side of
every pair is query-independent, so this server indexes the corpus ONCE
(GCN+Att embeddings through the engine's cache) and serves each query with
one query-side embedding plus a batched NTN+FCN head over the whole corpus
— the head kernel (`kernels/simgnn_head.py`) is the entire per-query device
cost. `benchmarks/search.py` measures the resulting warm-corpus speedup vs
rescoring every pair through the packed-sparse path.

The server is a thin orchestration layer: all scoring goes through
`core.engine.ScoringEngine` (`embed_graphs` / `pair_scores_from_embeddings`),
so path policy, caching, and parity anchoring stay in one place.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.cache import graph_key
from repro.core.engine import ScoringEngine
from repro.core.store import ShardStore, StoreError, tree_digest


@dataclass
class SearchStats:
    """Measured server behavior: stage seconds are cumulative wall-clock so
    callers can report per-stage shares; cache counters come straight from
    the engine's LRU."""
    queries: int = 0
    pairs_scored: int = 0
    index_size: int = 0
    failed_embeddings: int = 0     # corpus rows that are NaN after indexing
                                   # (their embed bucket AND its reference
                                   # retry failed — DESIGN.md §12)
    shards_loaded: int = 0         # shards restored verified from disk (§13)
    shards_recovered: int = 0      # shards that failed verification and
                                   # were selectively re-embedded
    rows_reembedded: int = 0       # corpus rows recomputed during load()
    embed_seconds: float = 0.0     # query-side embedding (+ any corpus misses)
    head_seconds: float = 0.0      # NTN+FCN over the corpus
    topk_seconds: float = 0.0      # host-side partial sort
    cache: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {"queries": self.queries, "pairs_scored": self.pairs_scored,
                "index_size": self.index_size,
                "failed_embeddings": self.failed_embeddings,
                "shards_loaded": self.shards_loaded,
                "shards_recovered": self.shards_recovered,
                "rows_reembedded": self.rows_reembedded,
                "embed_seconds": round(self.embed_seconds, 6),
                "head_seconds": round(self.head_seconds, 6),
                "topk_seconds": round(self.topk_seconds, 6),
                **{f"cache_{k}": v for k, v in self.cache.items()}}


class SimilaritySearchServer:
    """Index a graph corpus once, then serve top-k similarity queries.

    `index()` embeds every corpus graph through the engine's embedding
    cache and keeps the resulting `[N, F]` matrix resident — evictions from
    the LRU (which also serves ad-hoc `score()` traffic) never invalidate
    the index. `topk()` embeds the query (a cache hit if the client repeats
    it), broadcasts it against the corpus matrix through the fused head,
    and partial-sorts the scores host-side.
    """

    def __init__(self, params, cfg, *, cache_size: int = 4096,
                 embed_with_kernels: bool = False):
        self.engine = ScoringEngine(params, cfg, path="embedding_cache",
                                    cache_size=cache_size,
                                    embed_with_kernels=embed_with_kernels)
        self.corpus: list[dict] = []
        self.corpus_emb: np.ndarray | None = None
        self.stats = SearchStats()

    # -------------------------------------------------------------- indexing

    def index(self, corpus: list[dict]) -> np.ndarray:
        """Embed and retain the corpus; returns the `[N, F]` matrix.

        Re-indexing replaces the corpus. Embeddings also land in the
        engine's LRU, so mixed flows (`engine.score` on pairs touching
        corpus graphs) hit without recomputing.
        """
        t0 = time.perf_counter()
        self.corpus = list(corpus)
        self.corpus_emb = self.engine.embed_graphs(self.corpus)
        self.stats.embed_seconds += time.perf_counter() - t0
        self.stats.index_size = len(self.corpus)
        # Survive a failed corpus shard (DESIGN.md §12): the engine already
        # retried each failing embed bucket on the reference embedder and
        # NaN'd only the graphs whose retry ALSO failed — those rows stay in
        # the index (scores NaN, ranked last by topk) and are counted here
        # instead of killing the whole index() call.
        self.stats.failed_embeddings = int(
            (~np.isfinite(self.corpus_emb).all(axis=-1)).sum())
        self.stats.cache = self.engine.cache.stats()
        return self.corpus_emb

    # ------------------------------------------------------------ durability

    def save(self, directory: str, *, shard_rows: int = 256) -> dict:
        """Persist the resident index (DESIGN.md §13): the `[N, F]` matrix
        in checksummed row shards plus a versioned manifest recording the
        WL `graph_key` of every row and a digest of the model params —
        restarts and other replicas `load()` it instead of re-embedding
        the corpus. Returns the manifest."""
        if self.corpus_emb is None:
            raise ValueError("no corpus indexed; call index(corpus) first")
        keys = [graph_key(g).hex() for g in self.corpus]
        return ShardStore(directory).write(
            np.ascontiguousarray(self.corpus_emb, np.float32),
            shard_rows=shard_rows, graph_keys=keys,
            meta={"kind": "similarity_index",
                  "params_digest": tree_digest(self.engine.params),
                  "n_graphs": len(self.corpus),
                  "feat_dim": int(self.corpus_emb.shape[1])})

    def load(self, directory: str, corpus: list[dict]) -> np.ndarray:
        """Adopt a persisted index for `corpus` (DESIGN.md §13 recovery
        ladder). Every shard is checksum-verified and its recorded
        `graph_key`s compared to the corpus rows it claims to cover; shards
        that verify are mmap-read, shards that are missing / torn /
        bit-flipped / mismatched are SELECTIVELY re-embedded from the
        corpus graphs — counted on `stats`/`health()`, never a silent full
        rebuild. Manifest-level problems (missing, unreadable, stale
        format version, wrong model params, wrong corpus size) raise a
        structured `StoreError`: with an untrustworthy manifest there is
        no per-shard story, and serving scores from it would violate the
        never-serve-corrupt-state contract. Bit-identical to `index()` on
        a clean store (embeddings round-trip as raw float32 bytes)."""
        store = ShardStore(directory)
        man = store.manifest()                 # ManifestError on stale/bad
        meta = man.get("meta", {})
        if meta.get("params_digest") != tree_digest(self.engine.params):
            raise StoreError(
                f"index at {directory} was built by a different model "
                f"(params digest {meta.get('params_digest')!r}): scores "
                "from it would be silently wrong — rebuild with index()")
        if meta.get("n_graphs") != len(corpus):
            raise StoreError(
                f"index at {directory} covers {meta.get('n_graphs')} "
                f"graphs but the corpus has {len(corpus)}")
        n, f = int(man["shape"][0]), int(man["shape"][1])
        counters = self.engine.counters        # surfaces via health()
        out = np.zeros((n, f), np.float32)
        corpus = list(corpus)
        row = 0
        loaded = recovered = reembedded = 0
        for info in store.shard_infos(man):
            rows = info.shape[0]
            status = store.verify_shard(info)
            if status == "ok" and info.graph_keys:
                actual = [graph_key(corpus[i]).hex()
                          for i in range(row, row + rows)]
                if list(info.graph_keys) != actual:
                    status = "key_mismatch"
            if status == "ok":
                out[row:row + rows] = store.read_shard(info)
                loaded += 1
            else:
                counters[f"store_shard_{status}"] += 1
                # Selective recovery: re-embed ONLY this shard's rows (the
                # engine's embed path — identical bytes to index()'s).
                out[row:row + rows] = self.engine.embed_graphs(
                    corpus[row:row + rows])
                recovered += 1
                reembedded += rows
            row += rows
        if row != n:
            raise StoreError(f"manifest shards cover {row} rows but claim "
                             f"shape[0]={n}")
        self.corpus = corpus
        self.corpus_emb = out
        self.stats.index_size = n
        self.stats.shards_loaded += loaded
        self.stats.shards_recovered += recovered
        self.stats.rows_reembedded += reembedded
        counters["store_shards_loaded"] += loaded
        counters["store_shards_recovered"] += recovered
        counters["store_rows_reembedded"] += reembedded
        self.stats.failed_embeddings = int(
            (~np.isfinite(out).all(axis=-1)).sum())
        # Re-populate the LRU exactly as index() would have, so mixed
        # flows (`engine.score` on pairs touching corpus graphs) hit — and
        # eviction stays irrelevant to the resident matrix either way.
        for g, emb in zip(corpus, out):
            if np.isfinite(emb).all():
                emb = np.array(emb, np.float32)
                emb.setflags(write=False)
                self.engine.cache.put(graph_key(g), emb)
        self.stats.cache = self.engine.cache.stats()
        return out

    # -------------------------------------------------------------- querying

    def topk(self, query: dict, k: int = 10) -> tuple[np.ndarray, np.ndarray]:
        """Score `query` against the whole corpus; returns (indices, scores)
        of the k most similar corpus graphs, scores descending."""
        scores = self.scores(query)
        t0 = time.perf_counter()
        k = min(k, len(scores))
        # Rank on a NaN->-inf copy: argpartition on `-scores` would float
        # NaN entries (failed corpus embeddings) INTO the top-k, silently
        # displacing real results. Returned scores keep their NaN so a
        # caller that does see one knows it is a failure, not a similarity.
        rank = np.where(np.isfinite(scores), scores, -np.inf)
        top = np.argpartition(-rank, k - 1)[:k]
        top = top[np.argsort(-rank[top], kind="stable")]
        self.stats.topk_seconds += time.perf_counter() - t0
        return top, scores[top]

    def scores(self, query: dict) -> np.ndarray:
        """Full `[N]` similarity vector of `query` vs the indexed corpus."""
        if self.corpus_emb is None:
            raise ValueError("no corpus indexed; call index(corpus) first")
        t0 = time.perf_counter()
        hq = self.engine.embed_graphs([query])
        t1 = time.perf_counter()
        hq = np.broadcast_to(hq[0], self.corpus_emb.shape)
        out = self.engine.pair_scores_from_embeddings(hq, self.corpus_emb)
        t2 = time.perf_counter()
        self.stats.queries += 1
        self.stats.pairs_scored += len(self.corpus)
        self.stats.embed_seconds += t1 - t0
        self.stats.head_seconds += t2 - t1
        self.stats.cache = self.engine.cache.stats()
        return out

    def search(self, queries: list[dict], k: int = 10) -> list[tuple]:
        """Batched convenience wrapper: [(indices, scores), ...] per query."""
        return [self.topk(q, k) for q in queries]

    def health(self) -> dict:
        """Engine fault-tolerance state plus the server's own view of the
        index (DESIGN.md §12/§13) — one call for dashboards/tests. The
        durable-state counters (`store_*`, `ckpt_*`) ride inside the
        engine's counter dict."""
        return {**self.engine.health(),
                "index_size": self.stats.index_size,
                "failed_embeddings": self.stats.failed_embeddings,
                "shards_loaded": self.stats.shards_loaded,
                "shards_recovered": self.stats.shards_recovered,
                "rows_reembedded": self.stats.rows_reembedded}

    @property
    def hit_rate(self) -> float:
        return self.engine.cache.hit_rate
