"""Serving steps: prefill and decode, for all families incl. enc-dec/VLM.

`decode_32k` / `long_500k` cells lower `serve_step` (one new token against a
seq_len KV cache), `prefill_32k` lowers the prompt pass returning last-token
logits plus the populated cache — per the assignment's shape semantics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import Runtime
from repro.models import encdec, lm
from repro.models.config import ModelConfig


def build_prefill_step(cfg: ModelConfig, rt: Runtime):
    if cfg.is_enc_dec:
        def step(params, frames, tokens):
            last, enc_out, caches, pos = encdec.prefill_encdec(
                params, cfg, rt, frames, tokens)
            return last, enc_out, caches, pos
        return step

    def step(params, tokens, embeds=None):
        return lm.prefill(params, cfg, rt, tokens, embeds=embeds)
    return step


def build_decode_step(cfg: ModelConfig, rt: Runtime):
    if cfg.is_enc_dec:
        def step(params, token, enc_out, caches, cache_pos):
            return encdec.decode_step_encdec(params, cfg, rt, token, enc_out,
                                             caches, cache_pos)
        return step

    def step(params, token, caches, cache_pos):
        return lm.decode_step(params, cfg, rt, token, caches, cache_pos)
    return step


def greedy_generate(params, cfg: ModelConfig, rt: Runtime, prompt, *,
                    max_new: int = 16, embeds=None):
    """Host-loop greedy decoding (examples/tests; production uses the jitted
    steps directly with continuous batching — serve/batching.py)."""
    decode = jax.jit(build_decode_step(cfg, rt))
    if cfg.is_enc_dec:
        raise NotImplementedError("use encdec steps directly")
    last, caches, pos = jax.jit(build_prefill_step(cfg, rt))(
        params, prompt, embeds)
    toks = [jnp.argmax(last, -1)]
    for _ in range(max_new - 1):
        logits, caches, pos = decode(params, toks[-1][:, None], caches, pos)
        toks.append(jnp.argmax(logits, -1))
    return jnp.stack(toks, axis=1)
