"""Query batching for serving — the paper's Fig. 11 mechanism, generalized.

SPA-GCN batches ~300 graph-matching queries per kernel launch to amortize
OpenCL/PCIe setup (2.8x E2E there). The TPU analogues implemented here:

  * `MicroBatcher` — accumulate requests until `max_batch` or `max_wait_s`,
    then run one jitted call for the whole group (dispatch amortization);
  * `simgnn_query_server` — the paper's exact workload: a stream of graph
    pairs, bucketed by size (core/batching.py) and scored in fused batches.

benchmarks/fig11.py sweeps `max_batch` to reproduce the paper's batching
curve on this implementation.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class MicroBatcher:
    run_batch: Callable            # list[request] -> list[result]
    max_batch: int = 256
    max_wait_s: float = 0.005
    pending: list = field(default_factory=list)

    def submit(self, request):
        self.pending.append(request)
        if len(self.pending) >= self.max_batch:
            return self.flush()
        return None

    def flush(self):
        if not self.pending:
            return []
        batch, self.pending = self.pending, []
        return self.run_batch(batch)


def simgnn_query_server(params, cfg, *, use_kernels: bool = False):
    """Returns score_fn(list[(g1, g2)]) -> np.ndarray of similarity scores.
    Buckets pairs by size, one compiled executable per bucket."""
    from repro.core.batching import bucket_pairs
    from repro.core.simgnn import pair_score
    from repro.kernels.ops import simgnn_pair_score_kernel

    fn = simgnn_pair_score_kernel if use_kernels else pair_score
    jitted = jax.jit(fn)

    def score(pairs):
        out = np.zeros(len(pairs), np.float32)
        for bucket, (lhs, rhs, idxs) in bucket_pairs(
                pairs, cfg.n_node_labels).items():
            s = jitted(params, lhs.adj, lhs.feats, lhs.mask,
                       rhs.adj, rhs.feats, rhs.mask)
            out[idxs] = np.asarray(s)
        return out

    return score
