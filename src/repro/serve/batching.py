"""Query batching for serving — the paper's Fig. 11 mechanism, generalized.

SPA-GCN batches ~300 graph-matching queries per kernel launch to amortize
OpenCL/PCIe setup (2.8x E2E there). The TPU analogues implemented here:

  * `MicroBatcher` — accumulate requests until `max_batch` or until the
    oldest pending request has waited `max_wait_s`, then run one jitted call
    for the whole group (dispatch amortization with a latency bound);
  * `simgnn_query_server` — the paper's exact workload: a stream of graph
    pairs, bucketed by size (core/batching.py) and scored in fused batches,
    with one compiled executable cached per bucket. `use_kernels=True`
    routes every bucket through the single-pass megakernel
    (kernels/fused_pair.py, DESIGN.md §7) with a VMEM-sized block-pairs
    choice per bucket.

benchmarks/fig11.py sweeps `max_batch` to reproduce the paper's batching
curve on this implementation; benchmarks/megakernel.py compares the three
pair-scoring paths per bucket.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import numpy as np


@dataclass
class MicroBatcher:
    """Size- and deadline-bounded request accumulator.

    `submit` flushes when the pending group reaches `max_batch` OR when the
    oldest pending request has already waited `max_wait_s`. Between arrivals
    the serving loop calls `poll()` (or checks `deadline_in()`) so a lull in
    traffic cannot strand a partial batch. `clock` is injectable for tests.
    """
    run_batch: Callable            # list[request] -> list[result]
    max_batch: int = 256
    max_wait_s: float = 0.005
    clock: Callable[[], float] = time.monotonic
    pending: list = field(default_factory=list)
    oldest_ts: float | None = field(default=None, repr=False)

    def submit(self, request):
        if not self.pending:
            self.oldest_ts = self.clock()
        self.pending.append(request)
        if len(self.pending) >= self.max_batch or self._deadline_expired():
            return self.flush()
        return None

    def _deadline_expired(self) -> bool:
        return (bool(self.pending)
                and self.clock() - self.oldest_ts >= self.max_wait_s)

    def deadline_in(self) -> float | None:
        """Seconds until the pending group must flush (None if empty)."""
        if not self.pending:
            return None
        return max(0.0, self.max_wait_s - (self.clock() - self.oldest_ts))

    def poll(self):
        """Flush iff the deadline has expired; the serving loop's idle tick.
        Returns the batch results, or None if nothing was due."""
        if self._deadline_expired():
            return self.flush()
        return None

    def flush(self):
        if not self.pending:
            return []
        batch, self.pending = self.pending, []
        self.oldest_ts = None
        return self.run_batch(batch)


def simgnn_query_server(params, cfg, *, use_kernels: bool = False):
    """Returns score_fn(list[(g1, g2)]) -> np.ndarray of similarity scores.

    Buckets pairs by size and keeps one jitted callable per bucket in
    `score_fn.bucket_fns` (built lazily on first use, reused across calls —
    the paper's 'customize per workload' principle, Table 2; XLA then caches
    one executable per padded batch shape inside each callable). With
    `use_kernels=True` every bucket runs the single-pass megakernel — the
    whole wrapper (padding, kernel, slice) under one jit so serving pays a
    single dispatch — with a per-bucket `block_pairs` sized to keep the pair
    block's working set in VMEM.
    """
    from repro.core.batching import bucket_pairs
    from repro.core.simgnn import pair_score
    from repro.kernels.ops import megakernel_block_pairs, pair_score_megakernel

    bucket_fns: dict[int, Callable] = {}
    ref_fn = None if use_kernels else jax.jit(pair_score)

    def fn_for(bucket: int) -> Callable:
        if bucket not in bucket_fns:
            if use_kernels:
                bucket_fns[bucket] = jax.jit(functools.partial(
                    pair_score_megakernel,
                    block_pairs=megakernel_block_pairs(bucket)))
            else:
                bucket_fns[bucket] = ref_fn     # shared: jit caches per shape
        return bucket_fns[bucket]

    def score(pairs):
        out = np.zeros(len(pairs), np.float32)
        for bucket, (lhs, rhs, idxs) in bucket_pairs(
                pairs, cfg.n_node_labels).items():
            s = fn_for(bucket)(params, lhs.adj, lhs.feats, lhs.mask,
                               rhs.adj, rhs.feats, rhs.mask)
            out[idxs] = np.asarray(s)
        return out

    score.bucket_fns = bucket_fns
    return score
