"""Query batching for serving — the paper's Fig. 11 mechanism, generalized.

SPA-GCN batches ~300 graph-matching queries per kernel launch to amortize
OpenCL/PCIe setup (2.8x E2E there). The TPU analogues implemented here:

  * `MicroBatcher` — accumulate requests until `max_batch` or until the
    oldest pending request has waited `max_wait_s`, then run one jitted call
    for the whole group (dispatch amortization with a latency bound); its
    `stats` record every flush (size- vs deadline-triggered, occupancy) so
    benchmarks report *measured* batch occupancy;
  * `simgnn_query_server` — the paper's exact workload: a stream of graph
    pairs scored in fused batches. Since DESIGN.md §9, this is a thin
    wrapper over `core.engine.ScoringEngine`: ALL path selection
    (reference / two-kernel / bucketed-mega / packed-dense / packed-sparse,
    plus the oversize fallback split) lives in the engine's `plan()`; the
    wrapper only maps the legacy `use_kernels`/`packing` flags onto an
    engine path and keeps the public score_fn attribute contract.

benchmarks/fig11.py sweeps `max_batch` to reproduce the paper's batching
curve on this implementation; benchmarks/packed.py and benchmarks/sparse.py
compare the scoring paths head-to-head.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable


@dataclass
class FlushStats:
    """Measured MicroBatcher behavior (benchmarks/fig11.py reads these
    instead of inferring occupancy from the request count)."""
    batches: int = 0               # total flushes that ran a batch
    requests: int = 0              # total requests flushed
    size_flushes: int = 0          # flushes triggered by reaching max_batch
    deadline_flushes: int = 0      # flushes triggered by max_wait_s
    manual_flushes: int = 0        # explicit flush() calls that ran a batch
                                   # (empty manual flushes are no-ops)
    occupancy_sum: float = 0.0     # sum of len(batch)/max_batch per flush

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / self.batches if self.batches else 0.0


@dataclass
class MicroBatcher:
    """Size- and deadline-bounded request accumulator.

    `submit` flushes when the pending group reaches `max_batch` OR when the
    oldest pending request has already waited `max_wait_s`. Between arrivals
    the serving loop calls `poll()` (or checks `deadline_in()`) so a lull in
    traffic cannot strand a partial batch. `clock` is injectable for tests.

    Return contract (uniform across submit/poll/flush): `None` means
    NOTHING RAN — no batch was dispatched. A list (possibly empty, if
    `run_batch` returned no results) means a batch ran. An empty `flush()`
    is therefore `None`, not `[]`, and does not count in `FlushStats`.
    """
    run_batch: Callable            # list[request] -> list[result]
    max_batch: int = 256
    max_wait_s: float = 0.005
    clock: Callable[[], float] = time.monotonic
    pending: list = field(default_factory=list)
    oldest_ts: float | None = field(default=None, repr=False)
    stats: FlushStats = field(default_factory=FlushStats)

    def submit(self, request):
        if not self.pending:
            self.oldest_ts = self.clock()
        self.pending.append(request)
        if len(self.pending) >= self.max_batch:
            return self.flush(reason="size")
        if self._deadline_expired():
            return self.flush(reason="deadline")
        return None

    def _deadline_expired(self) -> bool:
        return (bool(self.pending)
                and self.clock() - self.oldest_ts >= self.max_wait_s)

    def deadline_in(self) -> float | None:
        """Seconds until the pending group must flush (None if empty)."""
        if not self.pending:
            return None
        return max(0.0, self.max_wait_s - (self.clock() - self.oldest_ts))

    def poll(self):
        """Flush iff the deadline has expired; the serving loop's idle tick.
        Returns the batch results, or None if nothing was due."""
        if self._deadline_expired():
            return self.flush(reason="deadline")
        return None

    def flush(self, reason: str = "manual"):
        """Run the pending group now. Returns the batch results, or None if
        the queue was empty (nothing ran — indistinguishable from a real
        zero-result batch otherwise); empty flushes leave `stats` untouched.
        """
        if not self.pending:
            return None
        batch, self.pending = self.pending, []
        self.oldest_ts = None
        st = self.stats
        st.batches += 1
        st.requests += len(batch)
        st.occupancy_sum += len(batch) / self.max_batch
        if reason == "size":
            st.size_flushes += 1
        elif reason == "deadline":
            st.deadline_flushes += 1
        else:
            st.manual_flushes += 1
        return self.run_batch(batch)


def simgnn_query_server(params, cfg, *, use_kernels: bool = False,
                        packing: bool = True, node_budget: int | None = None,
                        path: str | None = None, cache_size: int = 4096):
    """Returns score_fn(list[(g1, g2)]) -> np.ndarray of similarity scores.

    A thin wrapper over `core.engine.ScoringEngine` (DESIGN.md §9) — no path
    selection happens here. The legacy flags map onto an engine path:
    `use_kernels=False` -> "reference"; `use_kernels=True, packing=False` ->
    "bucketed_mega"; `use_kernels=True, packing=True` -> "auto" (the engine
    measures each call's density and picks packed-sparse or packed-dense,
    with the bucketed fallback for oversized pairs). An explicit `path`
    overrides the flags. `cache_size` bounds the engine's per-graph
    embedding LRU (DESIGN.md §10; 0 disables it). The LRU is populated by
    the embedding path itself — force `path="embedding_cache"`, or warm it
    out of band via `score_fn.engine.embed_graphs` (what
    `serve.search.SimilaritySearchServer.index` does) — after which auto
    dispatch serves recurring graphs embedding-free; plain `score()` calls
    on the non-cached paths never write it.

    Public contract kept from the pre-engine server: the returned score_fn
    exposes `bucket_fns` (the engine's per-bucket callable cache),
    `last_pack_stats` (measured packing occupancy of the latest call),
    `node_budget`, and — new — `last_plan` and `engine`.
    """
    from repro.core.engine import ScoringEngine

    if path is None:
        path = (("auto" if packing else "bucketed_mega") if use_kernels
                else "reference")
    engine = ScoringEngine(params, cfg, path=path, node_budget=node_budget,
                           cache_size=cache_size)

    def score(pairs):
        out = engine.score(pairs)
        score.last_pack_stats = engine.last_pack_stats
        score.last_plan = engine.last_plan
        return out

    score.engine = engine
    score.bucket_fns = engine.bucket_fns       # same dict object: live view
    score.last_pack_stats = None
    score.last_plan = None
    score.node_budget = engine.node_budget
    return score
