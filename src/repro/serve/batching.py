"""Query batching for serving — the paper's Fig. 11 mechanism, generalized.

SPA-GCN batches ~300 graph-matching queries per kernel launch to amortize
OpenCL/PCIe setup (2.8x E2E there). The TPU analogues implemented here:

  * `MicroBatcher` — accumulate requests until `max_batch` or until the
    oldest pending request has waited `max_wait_s`, then run one jitted call
    for the whole group (dispatch amortization with a latency bound); its
    `stats` record every flush (size- vs deadline-triggered, occupancy) so
    benchmarks report *measured* batch occupancy;
  * `simgnn_query_server` — the paper's exact workload: a stream of graph
    pairs scored in fused batches. Since DESIGN.md §9, this is a thin
    wrapper over `core.engine.ScoringEngine`: ALL path selection
    (reference / two-kernel / bucketed-mega / packed-dense / packed-sparse,
    plus the oversize fallback split) lives in the engine's `plan()`; the
    wrapper only maps the legacy `use_kernels`/`packing` flags onto an
    engine path and keeps the public score_fn attribute contract.

benchmarks/fig11.py sweeps `max_batch` to reproduce the paper's batching
curve on this implementation; benchmarks/packed.py and benchmarks/sparse.py
compare the scoring paths head-to-head.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable


@dataclass
class FlushStats:
    """Measured MicroBatcher behavior (benchmarks/fig11.py reads these
    instead of inferring occupancy from the request count)."""
    batches: int = 0               # total flushes that ran a batch
    requests: int = 0              # total requests flushed
    size_flushes: int = 0          # flushes triggered by reaching max_batch
    deadline_flushes: int = 0      # flushes triggered by max_wait_s
    expired_flushes: int = 0       # flushes triggered by a per-request
                                   # deadline (timeout_s), counted distinctly
                                   # from the group max_wait_s deadline
    manual_flushes: int = 0        # explicit flush() calls that ran a batch
                                   # (empty manual flushes are no-ops)
    occupancy_sum: float = 0.0     # sum of len(batch)/max_batch per flush
    expired_requests: int = 0      # requests answered with TimeoutResult
    retries: int = 0               # run_batch retry attempts after a failure
    failed_flushes: int = 0        # flushes whose run_batch exhausted retries
    dropped_requests: int = 0      # requests lost to a failed flush

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / self.batches if self.batches else 0.0


@dataclass(frozen=True)
class TimeoutResult:
    """Positional stand-in for a request whose per-request deadline had
    already passed when its batch flushed (DESIGN.md §12): the client gets
    a typed timeout instead of a stale score, and the expired request never
    consumes batch compute."""
    request: object
    waited_s: float


@dataclass
class MicroBatcher:
    """Size- and deadline-bounded request accumulator.

    `submit` flushes when the pending group reaches `max_batch` OR when the
    oldest pending request has already waited `max_wait_s`. Between arrivals
    the serving loop calls `poll()` (or checks `deadline_in()`) so a lull in
    traffic cannot strand a partial batch. `clock` is injectable for tests.

    Return contract (uniform across submit/poll/flush): `None` means
    NOTHING RAN — no batch was dispatched. A list (possibly empty, if
    `run_batch` returned no results) means a batch ran. An empty `flush()`
    is therefore `None`, not `[]`, and does not count in `FlushStats`.

    Resilience (DESIGN.md §12): `submit(req, timeout_s=...)` attaches a
    per-request deadline — an expired request is answered positionally with
    a `TimeoutResult` at flush instead of consuming batch compute, and
    `deadline_in()`/`poll()` honor the earliest per-request deadline so the
    serving loop wakes up in time. A `run_batch` that raises is retried up
    to `flush_retries` times with exponential backoff (`sleep` injectable);
    exhausting retries counts `failed_flushes`/`dropped_requests` and
    re-raises — the queue is already drained, so one poisoned batch cannot
    wedge every later request behind it.
    """
    run_batch: Callable            # list[request] -> list[result]
    max_batch: int = 256
    max_wait_s: float = 0.005
    clock: Callable[[], float] = time.monotonic
    flush_retries: int = 2         # run_batch attempts = 1 + flush_retries
    retry_backoff_s: float = 0.05  # sleep 1x, 2x, 4x... between attempts
    sleep: Callable[[float], None] = time.sleep
    pending: list = field(default_factory=list)
    oldest_ts: float | None = field(default=None, repr=False)
    stats: FlushStats = field(default_factory=FlushStats)
    #: (absolute deadline | None, enqueue ts) per pending request, aligned
    #: with `pending` (which stays a plain request list — public contract).
    _deadlines: list = field(default_factory=list, repr=False)

    def submit(self, request, *, timeout_s: float | None = None):
        now = self.clock()
        if not self.pending:
            self.oldest_ts = now
        self.pending.append(request)
        self._deadlines.append(
            (None if timeout_s is None else now + timeout_s, now))
        if len(self.pending) >= self.max_batch:
            return self.flush(reason="size")
        return self.poll()

    def _request_expired(self) -> bool:
        now = self.clock()
        return any(d is not None and now >= d for d, _ in self._deadlines)

    def _deadline_expired(self) -> bool:
        return (bool(self.pending)
                and self.clock() - self.oldest_ts >= self.max_wait_s)

    def deadline_in(self) -> float | None:
        """Seconds until the pending group must flush (None if empty) —
        the sooner of the group max_wait_s and the earliest per-request
        deadline, clamped to 0.0 once overdue (never negative: the serving
        loop can pass it straight to a wait/select call)."""
        if not self.pending:
            return None
        due = self.oldest_ts + self.max_wait_s
        for d, _ in self._deadlines:
            if d is not None:
                due = min(due, d)
        return max(0.0, due - self.clock())

    def poll(self):
        """Flush iff a deadline has expired; the serving loop's idle tick.
        Returns the batch results, or None if nothing was due."""
        if self._request_expired():
            return self.flush(reason="expired")
        if self._deadline_expired():
            return self.flush(reason="deadline")
        return None

    def flush(self, reason: str = "manual"):
        """Run the pending group now. Returns the batch results, or None if
        the queue was empty (nothing ran — indistinguishable from a real
        zero-result batch otherwise); empty flushes leave `stats` untouched.

        Requests whose per-request deadline has already passed are answered
        with `TimeoutResult` at their original positions (requires
        `run_batch` to return one result per request, which every scoring
        backend here does); the live remainder runs as one batch.
        """
        if not self.pending:
            return None
        batch, self.pending = self.pending, []
        deadlines, self._deadlines = self._deadlines, []
        self.oldest_ts = None
        now = self.clock()
        st = self.stats
        st.batches += 1
        st.requests += len(batch)
        st.occupancy_sum += len(batch) / self.max_batch
        if reason == "size":
            st.size_flushes += 1
        elif reason == "deadline":
            st.deadline_flushes += 1
        elif reason == "expired":
            st.expired_flushes += 1
        else:
            st.manual_flushes += 1
        expired = {i for i, (d, _) in enumerate(deadlines)
                   if d is not None and now >= d}
        live = [r for i, r in enumerate(batch) if i not in expired]
        st.expired_requests += len(expired)
        res = live and self._run_with_retries(live)
        if not expired:
            return res
        out: list = []
        it = iter(res or ())
        for i, r in enumerate(batch):
            out.append(TimeoutResult(r, now - deadlines[i][1])
                       if i in expired else next(it, None))
        return out

    def _run_with_retries(self, live: list):
        last_err = None
        for attempt in range(1 + self.flush_retries):
            if attempt:
                self.stats.retries += 1
                self.sleep(self.retry_backoff_s * 2 ** (attempt - 1))
            try:
                return self.run_batch(live)
            except Exception as exc:
                last_err = exc
        self.stats.failed_flushes += 1
        self.stats.dropped_requests += len(live)
        raise last_err


def simgnn_query_server(params, cfg, *, use_kernels: bool = False,
                        packing: bool = True, node_budget: int | None = None,
                        path: str | None = None, cache_size: int = 4096,
                        validation: str = "lenient",
                        clock: Callable[[], float] = time.perf_counter,
                        recorder=None, runtime=None):
    """Returns score_fn(list[(g1, g2)]) -> np.ndarray of similarity scores.

    A thin wrapper over `core.engine.ScoringEngine` (DESIGN.md §9) — no path
    selection happens here. The legacy flags map onto an engine path:
    `use_kernels=False` -> "reference"; `use_kernels=True, packing=False` ->
    "bucketed_mega"; `use_kernels=True, packing=True` -> "auto" (the engine
    measures each call's density and picks packed-sparse or packed-dense,
    with the bucketed fallback for oversized pairs). An explicit `path`
    overrides the flags. `cache_size` bounds the engine's per-graph
    embedding LRU (DESIGN.md §10; 0 disables it). The LRU is populated by
    the embedding path itself — force `path="embedding_cache"`, or warm it
    out of band via `score_fn.engine.embed_graphs` (what
    `serve.search.SimilaritySearchServer.index` does) — after which auto
    dispatch serves recurring graphs embedding-free; plain `score()` calls
    on the non-cached paths never write it.

    `clock`/`recorder` are forwarded to the engine: the injectable clock
    stamps its trace records and breaker cool-downs deterministically under
    test, and an external `core.profile.TraceRecorder` lets a caller share
    one persisted profile across servers (DESIGN.md §15).

    `runtime` is forwarded to the engine (DESIGN.md §16): a multi-device
    `distributed.sharding.Runtime` lets the planner shard packed-path tile
    batches over the mesh; None keeps every path single-device.

    `validation` is forwarded to the engine (DESIGN.md §12): the default
    "lenient" quarantines malformed request graphs per pair (NaN score in
    the response, structured records on `last_plan.quarantined`) — one bad
    client cannot poison a shared micro-batch; "strict" raises, "off"
    trusts the caller.

    Public contract kept from the pre-engine server: the returned score_fn
    exposes `bucket_fns` (the engine's per-bucket callable cache),
    `last_pack_stats` (measured packing occupancy of the latest call),
    `node_budget`, and — new — `last_plan` and `engine`.
    """
    from repro.core.engine import ScoringEngine

    if path is None:
        path = (("auto" if packing else "bucketed_mega") if use_kernels
                else "reference")
    engine = ScoringEngine(params, cfg, path=path, node_budget=node_budget,
                           cache_size=cache_size, validation=validation,
                           clock=clock, recorder=recorder, runtime=runtime)

    def score(pairs):
        out = engine.score(pairs)
        score.last_pack_stats = engine.last_pack_stats
        score.last_plan = engine.last_plan
        return out

    score.engine = engine
    score.bucket_fns = engine.bucket_fns       # same dict object: live view
    score.last_pack_stats = None
    score.last_plan = None
    score.node_budget = engine.node_budget
    return score
