"""Query batching for serving — the paper's Fig. 11 mechanism, generalized.

SPA-GCN batches ~300 graph-matching queries per kernel launch to amortize
OpenCL/PCIe setup (2.8x E2E there). The TPU analogues implemented here:

  * `MicroBatcher` — accumulate requests until `max_batch` or until the
    oldest pending request has waited `max_wait_s`, then run one jitted call
    for the whole group (dispatch amortization with a latency bound); its
    `stats` record every flush (size- vs deadline-triggered, occupancy) so
    benchmarks report *measured* batch occupancy;
  * `simgnn_query_server` — the paper's exact workload: a stream of graph
    pairs scored in fused batches. `use_kernels=True` routes by default
    through the packed-pair megakernel (kernels/packed_pair.py, DESIGN.md
    §8): pairs are FFD-packed into node-budget tiles with segment IDs and
    first-layer label gather. Size-bucketing (core/batching.py, one cached
    executable per bucket through kernels/fused_pair.py) remains the
    reference path and the fallback for pairs beyond the node budget;
    oversized queries get power-of-two overflow buckets instead of killing
    the call.

benchmarks/fig11.py sweeps `max_batch` to reproduce the paper's batching
curve on this implementation; benchmarks/packed.py compares the packed,
bucketed-megakernel and two-kernel scoring policies.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import numpy as np


@dataclass
class FlushStats:
    """Measured MicroBatcher behavior (benchmarks/fig11.py reads these
    instead of inferring occupancy from the request count)."""
    batches: int = 0               # total flushes that ran a batch
    requests: int = 0              # total requests flushed
    size_flushes: int = 0          # flushes triggered by reaching max_batch
    deadline_flushes: int = 0      # flushes triggered by max_wait_s
    manual_flushes: int = 0        # explicit flush() calls
    occupancy_sum: float = 0.0     # sum of len(batch)/max_batch per flush

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / self.batches if self.batches else 0.0


@dataclass
class MicroBatcher:
    """Size- and deadline-bounded request accumulator.

    `submit` flushes when the pending group reaches `max_batch` OR when the
    oldest pending request has already waited `max_wait_s`. Between arrivals
    the serving loop calls `poll()` (or checks `deadline_in()`) so a lull in
    traffic cannot strand a partial batch. `clock` is injectable for tests.
    """
    run_batch: Callable            # list[request] -> list[result]
    max_batch: int = 256
    max_wait_s: float = 0.005
    clock: Callable[[], float] = time.monotonic
    pending: list = field(default_factory=list)
    oldest_ts: float | None = field(default=None, repr=False)
    stats: FlushStats = field(default_factory=FlushStats)

    def submit(self, request):
        if not self.pending:
            self.oldest_ts = self.clock()
        self.pending.append(request)
        if len(self.pending) >= self.max_batch:
            return self.flush(reason="size")
        if self._deadline_expired():
            return self.flush(reason="deadline")
        return None

    def _deadline_expired(self) -> bool:
        return (bool(self.pending)
                and self.clock() - self.oldest_ts >= self.max_wait_s)

    def deadline_in(self) -> float | None:
        """Seconds until the pending group must flush (None if empty)."""
        if not self.pending:
            return None
        return max(0.0, self.max_wait_s - (self.clock() - self.oldest_ts))

    def poll(self):
        """Flush iff the deadline has expired; the serving loop's idle tick.
        Returns the batch results, or None if nothing was due."""
        if self._deadline_expired():
            return self.flush(reason="deadline")
        return None

    def flush(self, reason: str = "manual"):
        if not self.pending:
            return []
        batch, self.pending = self.pending, []
        self.oldest_ts = None
        st = self.stats
        st.batches += 1
        st.requests += len(batch)
        st.occupancy_sum += len(batch) / self.max_batch
        if reason == "size":
            st.size_flushes += 1
        elif reason == "deadline":
            st.deadline_flushes += 1
        else:
            st.manual_flushes += 1
        return self.run_batch(batch)


def simgnn_query_server(params, cfg, *, use_kernels: bool = False,
                        packing: bool = True, node_budget: int | None = None):
    """Returns score_fn(list[(g1, g2)]) -> np.ndarray of similarity scores.

    `use_kernels=True` routes by default through the packed-pair megakernel
    (DESIGN.md §8): each call's pairs are FFD-packed into `[T, node_budget]`
    segment-ID tiles (host-side, O(B log B)) and scored in ONE pallas_call
    with first-layer label gather; `score_fn.last_pack_stats` exposes the
    measured occupancy. Pairs with a graph beyond the node budget — and the
    whole stream when `packing=False` or `use_kernels=False` — take the
    bucketed path: one jitted callable per size bucket in
    `score_fn.bucket_fns` (built lazily, reused across calls — the paper's
    'customize per workload' principle, Table 2; XLA caches one executable
    per padded batch shape inside each callable), with power-of-two overflow
    buckets for queries beyond the largest standard bucket, so an oversized
    graph degrades to extra padding instead of a ValueError.
    """
    from repro.core.batching import (bucket_pairs, pack_pairs,
                                     unpack_pair_scores)
    from repro.core.simgnn import pair_score
    from repro.kernels.ops import (megakernel_block_pairs, packed_node_budget,
                                   pair_score_megakernel, pair_score_packed)

    if node_budget is None:
        node_budget = packed_node_budget(cfg.max_nodes)
    bucket_fns: dict[int, Callable] = {}
    ref_fn = None if use_kernels else jax.jit(pair_score)

    def fn_for(bucket: int) -> Callable:
        if bucket not in bucket_fns:
            if use_kernels:
                bucket_fns[bucket] = jax.jit(functools.partial(
                    pair_score_megakernel,
                    block_pairs=megakernel_block_pairs(bucket)))
            else:
                bucket_fns[bucket] = ref_fn     # shared: jit caches per shape
        return bucket_fns[bucket]

    def score_bucketed(pairs, idx, out):
        for bucket, (lhs, rhs, idxs) in bucket_pairs(
                pairs, cfg.n_node_labels, allow_oversize=True).items():
            s = fn_for(bucket)(params, lhs.adj, lhs.feats, lhs.mask,
                               rhs.adj, rhs.feats, rhs.mask)
            out[idx[idxs]] = np.asarray(s)

    def score(pairs):
        out = np.zeros(len(pairs), np.float32)
        if not (use_kernels and packing):
            score_bucketed(pairs, np.arange(len(pairs)), out)
            return out
        fits = np.asarray([max(g1["adj"].shape[0], g2["adj"].shape[0])
                           <= node_budget for g1, g2 in pairs], bool)
        fit_idx = np.flatnonzero(fits)
        if len(fit_idx):
            # Fixed slots_per_tile + power-of-two tile quantization keep the
            # compiled-shape set small (O(log T) executables) under varying
            # batch sizes and FFD outcomes.
            packed, stats = pack_pairs([pairs[i] for i in fit_idx],
                                       node_budget,
                                       slots_per_tile=max(8, node_budget // 4))
            score.last_pack_stats = stats
            s = pair_score_packed(params, packed, quantize_tiles=True)
            out[fit_idx] = unpack_pair_scores(s, packed, len(fit_idx))
        over_idx = np.flatnonzero(~fits)
        if len(over_idx):
            # Oversized pairs: padded bucket fallback (power-of-two buckets).
            score_bucketed([pairs[i] for i in over_idx], over_idx, out)
        return out

    score.bucket_fns = bucket_fns
    score.last_pack_stats = None
    score.node_budget = node_budget
    return score
