"""Train-step builders: value_and_grad -> clip -> AdamW, for both the LM
substrate and the paper's SimGNN model.

Under jit with NamedSharding'd params, XLA SPMD derives the FSDP collectives
(all-gather params on use, reduce-scatter grads) automatically; the optimizer
update then runs fully sharded (ZeRO-3 equivalent). Optional int8 gradient
compression (distributed/compression.py) targets the cross-pod DCN
all-reduce. Gradient accumulation microbatches via lax.scan when
`accum_steps > 1`.

The SimGNN step delegates its entire forward/backward to
`ScoringEngine.loss_and_grad` (DESIGN.md §11): path selection between the
dense reference and the custom-VJP packed executors lives in the engine,
never here.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.distributed.sharding import Runtime
from repro.models import encdec, lm
from repro.models.config import ModelConfig
from repro.train import optimizer as opt

Array = jax.Array


def loss_for(cfg: ModelConfig) -> Callable:
    if cfg.is_enc_dec:
        return encdec.encdec_loss
    return lm.lm_loss


def build_train_step(cfg: ModelConfig, rt: Runtime, *,
                     peak_lr: float = 3e-4, max_grad_norm: float = 1.0,
                     accum_steps: int = 1, compress_grads: bool = False,
                     constrain_grads: bool = True):
    """Returns step_fn(params, opt_state, batch) -> (params, opt_state, metrics).
    batch leaves may carry a leading accum dim when accum_steps > 1.

    constrain_grads pins each gradient to its parameter's sharding at the
    autodiff output. Measured neutral on the gemma2 cell (the partitioner
    already lands grads in param sharding there — §Perf appendix D,
    iteration D2 refuted); kept as a zero-cost guard against partitioner
    drift on other architectures."""
    loss_fn = loss_for(cfg)

    def fwd_bwd(params, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, rt, batch))(params)
        if constrain_grads and rt.mesh is not None:
            from repro.distributed.sharding import param_shardings
            shardings = param_shardings(rt, grads)
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s)
                if s is not None else g, grads, shardings)
        return loss, grads

    def step_fn(params, opt_state, batch):
        if accum_steps > 1:
            def micro(acc, mb):
                loss, grads = fwd_bwd(params, mb)
                return (acc[0] + loss,
                        jax.tree.map(jnp.add, acc[1], grads)), None
            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss, grads), _ = jax.lax.scan(micro, zero, batch)
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
        else:
            loss, grads = fwd_bwd(params, batch)

        if compress_grads:
            from repro.distributed.compression import int8_compress_tree
            grads = int8_compress_tree(grads)

        grads, grad_norm = opt.clip_by_global_norm(grads, max_grad_norm)
        lr = opt.cosine_schedule(opt_state.step, peak_lr=peak_lr)
        params, opt_state = opt.adamw_update(grads, opt_state, params, lr=lr)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": grad_norm,
                   "lr": lr, "step": opt_state.step}
        return params, opt_state, metrics

    return step_fn


def build_simgnn_apply(*, peak_lr: float = 1e-3,
                       max_grad_norm: float = 1.0):
    """The jitted SimGNN optimizer half-step (clip -> cosine schedule ->
    AdamW), shared by `build_simgnn_train_step` and any baseline that must
    pair a different loss with the SAME update (benchmarks/train.py's
    dense-reference policy) — one source for the schedule constants."""
    @jax.jit
    def apply(params, opt_state, loss, grads):
        grads, grad_norm = opt.clip_by_global_norm(grads, max_grad_norm)
        lr = opt.cosine_schedule(opt_state.step, peak_lr=peak_lr, warmup=50,
                                 total=2_000)
        params, opt_state = opt.adamw_update(grads, opt_state, params, lr=lr,
                                             weight_decay=1e-4)
        return params, opt_state, {"loss": loss, "grad_norm": grad_norm,
                                   "lr": lr, "step": opt_state.step}

    return apply


def build_simgnn_train_step(engine, *, peak_lr: float = 1e-3,
                            max_grad_norm: float = 1.0,
                            accum_steps: int = 1,
                            clock: Callable[[], float] | None = None):
    """Train step for the paper's model (MSE on exp(-nGED) targets), routed
    through a `core.engine.ScoringEngine` (DESIGN.md §11) — the engine is
    the single dispatch point for BOTH directions of the model, so no path
    selection (packing, bucketing, kernel choice) happens here.

    batch: {"pairs": [(g1, g2), ...], "target": [B]} — raw graph-pair dicts
    (e.g. `data.graphs.pair_stream` batches). The engine packs once per
    batch and reuses the packed layout across `accum_steps` accumulation
    microbatches; the optimizer update runs in one jitted region.

    Non-finite guard (DESIGN.md §12): if the loss or any gradient leaf is
    NaN/Inf after the engine has exhausted its own degradation options, the
    update is SKIPPED — params and optimizer state pass through unchanged
    (no momentum poisoning, no step-count advance), the skip is counted on
    `engine.counters["train_skipped_steps"]`, and the metrics carry
    `skipped=1` so loops and dashboards can see the gap.

    Tracing (DESIGN.md §15): each full step also lands one `kind="train"`
    / `path="train_step"` record on `engine.recorder` — the end-to-end
    step latency next to the engine's own per-rung `train:<path>` records,
    so the replay harness can compare optimizer overhead against forward/
    backward time. `clock` defaults to the engine's injectable clock.
    """
    from repro.core.engine import tree_all_finite

    apply = build_simgnn_apply(peak_lr=peak_lr, max_grad_norm=max_grad_norm)
    clk = clock if clock is not None else engine._clock

    def _trace(n_pairs: int, wall_s: float) -> None:
        rec = getattr(engine, "recorder", None)
        if rec is None:
            return
        stats = getattr(engine.last_plan, "stats", None)
        rec.record(kind="train", path="train_step", n_pairs=n_pairs,
                   max_nodes=getattr(stats, "max_nodes", 0),
                   mean_nodes=getattr(stats, "mean_nodes", 0.0),
                   avg_degree=getattr(stats, "avg_degree", 0.0),
                   density=getattr(stats, "density", 0.0),
                   wall_s=wall_s)

    def step_fn(params, opt_state, batch):
        t0 = clk()
        loss, grads = engine.loss_and_grad(batch["pairs"], batch["target"],
                                           params=params,
                                           accum_steps=accum_steps)
        if not tree_all_finite(loss, grads):
            engine.counters["train_skipped_steps"] += 1
            metrics = {"loss": loss.astype(jnp.float32),
                       "grad_norm": jnp.zeros((), jnp.float32),
                       "lr": jnp.zeros((), jnp.float32),
                       "step": opt_state.step,
                       "skipped": jnp.ones((), jnp.float32)}
            _trace(len(batch["pairs"]), clk() - t0)
            return params, opt_state, metrics
        params, opt_state, metrics = apply(params, opt_state, loss, grads)
        jax.block_until_ready(metrics["loss"])
        _trace(len(batch["pairs"]), clk() - t0)
        return params, opt_state, metrics

    return step_fn
