"""Training loop: checkpoint/restart, straggler detection, failure recovery.

Fault-tolerance contract (DESIGN.md §6):
  * checkpoint every `ckpt_every` steps (atomic, keep-k — ckpt/manager.py);
  * `resume="auto"` restores the latest complete checkpoint and *replays the
    data stream deterministically* (data/tokens.py keys batches by step);
  * StragglerMonitor keeps an EWMA of step wall-time; a step slower than
    `threshold x` EWMA is flagged — on a real fleet the runner would evict
    the slow host and restart from the last checkpoint (here: logged +
    counted, and the policy is unit-tested);
  * any exception inside the step triggers a restore-and-retry
    (`max_retries`), the standard preemption/XLA-crash path.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.ckpt import manager as ckpt


@dataclass
class StragglerMonitor:
    threshold: float = 3.0
    alpha: float = 0.2            # EWMA weight
    ewma: float | None = None
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.threshold * self.ewma
        if slow:
            self.flagged.append((step, dt, self.ewma))
        # straggler steps don't poison the baseline
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * min(
            dt, self.threshold * self.ewma)
        return slow


def run(step_fn, params, opt_state, batch_fn, *, n_steps: int,
        ckpt_dir: str | None = None, ckpt_every: int = 50,
        resume: str | None = "auto", max_retries: int = 2,
        log_every: int = 10, monitor: StragglerMonitor | None = None,
        on_metrics=None, on_resume=None):
    """Generic driver used by launch/train.py and the failure-recovery test.
    batch_fn(step) -> batch pytree. Returns (params, opt_state, history).

    Resume goes through VERIFIED restore (DESIGN.md §13): the newest
    checkpoint that passes format-version + checksum verification wins, and
    torn/bit-flipped/missing newer ones are walked past (reported via
    `on_resume(step, skipped)` so callers can surface counters) — the loop
    never deserializes a checkpoint it cannot verify.
    """
    monitor = monitor or StragglerMonitor()
    start = 0
    if ckpt_dir and resume == "auto":
        last, skipped = ckpt.latest_valid_step(ckpt_dir)
        for s, problems in skipped:
            print(f"[loop] skipping corrupt checkpoint step {s}: "
                  f"{problems[0]}")
        if on_resume is not None:
            on_resume(last, skipped)
        if last is not None:
            params, opt_state = ckpt.restore(ckpt_dir, last,
                                             (params, opt_state))
            start = last
            print(f"[loop] resumed from step {last}"
                  + (f" (walked back past {len(skipped)} corrupt)"
                     if skipped else ""))

    history = []
    step = start
    retries = 0
    while step < n_steps:
        try:
            t0 = time.time()
            batch = batch_fn(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            slow = monitor.observe(step, dt)
            if slow:
                print(f"[loop] straggler at step {step}: {dt:.3f}s "
                      f"(ewma {monitor.ewma:.3f}s) — would evict+restart on fleet")
            if step % log_every == 0 or step == n_steps - 1:
                rec = {k: float(np.asarray(v)) for k, v in metrics.items()}
                rec["sec_per_step"] = dt
                history.append(rec)
                if on_metrics:
                    on_metrics(step, rec)
            step += 1
            if ckpt_dir and step % ckpt_every == 0:
                ckpt.save(ckpt_dir, step, (params, opt_state))
            retries = 0
        except Exception:
            retries += 1
            if not ckpt_dir or retries > max_retries:
                raise
            last, _ = ckpt.latest_valid_step(ckpt_dir)
            print(f"[loop] step {step} failed; restoring step {last} "
                  f"(retry {retries}/{max_retries})")
            if last is not None:
                params, opt_state = ckpt.restore(ckpt_dir, last,
                                                 (params, opt_state))
                step = last
    if ckpt_dir:
        ckpt.save(ckpt_dir, step, (params, opt_state))
    return params, opt_state, history
