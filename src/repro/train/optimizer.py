"""AdamW + cosine schedule in pure JAX (no optax in this environment).

Moments are stored in a configurable dtype: fp32 by default, bf16 for the
398B Jamba config so the fully-sharded (ZeRO-3-equivalent) state fits
16 GB/chip on one v5e-256 pod (DESIGN.md §6). All arithmetic is fp32
regardless of storage dtype; params update in their own dtype.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # scalar int32
    m: object                # pytree like params
    v: object                # pytree like params


def adamw_init(params, state_dtype: str = "float32") -> AdamWState:
    dt = jnp.dtype(state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def cosine_schedule(step, *, peak_lr: float = 3e-4, warmup: int = 200,
                    total: int = 10_000, floor: float = 0.1):
    """lr for the step *being taken* (1-indexed: the first update uses
    lr = peak/warmup, not 0 — a silent-no-op bug the smoke tests caught)."""
    s = jnp.maximum(step.astype(jnp.float32), 0.0) + 1.0
    warm = s / max(1, warmup)
    frac = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return peak_lr * jnp.where(s < warmup, warm, cos)


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw_update(grads, state: AdamWState, params, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    """One AdamW step; fp32 math, storage dtypes preserved."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
