"""Stochastic gradient functions — the swappable loss -> (value, grad)
transform the ScoringEngine's training executors are built from
(DESIGN.md §16; the composable-SGF pattern of paxml's `sgf.py`).

`ScoringEngine._train_fn` historically hard-coded `jax.value_and_grad`
inside its jitted chunk-scan executors, which made any gradient transform
(clipping, per-microbatch noise for DP-SGD, ghost-norm estimation) a fork
of the executor-cache logic. Instead the engine now holds ONE gradient
function object (`engine.grad_fn`) and asks it for the transform:

    grad_fn = engine.grad_fn.value_and_grad(sse)       # inside _train_fn
    key     = (..., engine.grad_fn.cache_key)          # executor cache key

The object is pure configuration — it owns no params and no state — so it
is safe to close over inside jitted functions, and `cache_key` keys the
executor cache (two engines sharing a transform share executables; swapping
the transform retraces instead of serving a stale one).

Composition contract with device sharding (DESIGN.md §16): the transform is
applied at the MICROBATCH level — inside the tile-chunk scan, before the
cross-chunk accumulation and before the cross-device `psum`. Standard
gradients are reduction-transparent so nothing changes; clipping variants
therefore clip per microbatch chunk (the usual accumulation-compatible
approximation — a single global clip would need the full-batch norm, which
the streamed chunk-scan never materializes).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["GradientFunction", "StandardGradient", "ClippedGradient",
           "global_norm"]


def global_norm(tree) -> jax.Array:
    """L2 norm over every leaf of a gradient pytree."""
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in leaves))


@dataclass(frozen=True)
class GradientFunction:
    """Base transform: how a scalar loss function becomes a
    (value, grads) function. Subclasses override `value_and_grad` and
    extend `cache_key`; instances must stay frozen/stateless (they are
    closed over by jitted executors and hashed into cache keys)."""

    @property
    def cache_key(self) -> str:
        return "standard"

    def value_and_grad(self, loss_fn):
        """loss_fn(params, *args) -> scalar   becomes
        fn(params, *args) -> (scalar, grads-like-params)."""
        return jax.value_and_grad(loss_fn)


@dataclass(frozen=True)
class StandardGradient(GradientFunction):
    """Plain `jax.value_and_grad` — the default, bit-identical to the
    pre-SGF executors."""


@dataclass(frozen=True)
class ClippedGradient(GradientFunction):
    """Per-microbatch global-norm clipping: grads whose L2 norm exceeds
    `clip_norm` are rescaled onto the ball. The first slot-in variant the
    SGF seam exists for (ghost-norm / DP-SGD follow the same shape: wrap
    the transform, extend the key)."""
    clip_norm: float = 1.0

    @property
    def cache_key(self) -> str:
        return f"clip:{self.clip_norm:g}"

    def value_and_grad(self, loss_fn):
        vg = jax.value_and_grad(loss_fn)

        def fn(params, *args):
            v, g = vg(params, *args)
            norm = global_norm(g)
            scale = jnp.minimum(1.0, self.clip_norm
                                / jnp.maximum(norm, 1e-12))
            return v, jax.tree.map(lambda x: x * scale, g)
        return fn
