"""Deterministic fault injection for the scoring engine (DESIGN.md §12).

Every degradation transition in the engine — ladder step-down, breaker
open/half-open/close, NaN-guarded training, per-bucket embed fallback — is
only trustworthy if it can be *driven* on demand. This harness does that
without monkeypatching kernels (whose jitted callables the engine caches,
so attribute patching would silently miss warm engines): the engine routes
every executor invocation through a module-level hook seam
(`core.engine._FAULT_HOOK`, `None` in production — a single attribute read
per kernel call), and `inject()` arms that seam for the duration of a
`with` block.

Sites are the engine's execution points:

    "packed_sparse" | "packed_dense" | "bucketed_mega" | "two_kernel"
    | "reference"          — score-path kernel calls (one per bucket/pack)
    "embed"                — the per-bucket embedding call (cache misses)
    "embed_fallback"       — the reference retry of a failed embed bucket
    "head"                 — the fused NTN+FCN head
    "head_fallback"        — the reference retry of a failed head call
    "train:packed_sparse" | "train:packed_dense" | "train:reference"
                           — loss_and_grad executor calls

Modes:

    "raise"  — raise `FaultError` (a generic kernel crash);
    "oom"    — raise `ResourceExhausted` (simulated RESOURCE_EXHAUSTED /
               VMEM exhaustion on the chosen path);
    "nan"    — let the call run, then replace every floating leaf of the
               result with NaN (a silently-corrupting kernel — the hardest
               failure class, caught by the engine's finite checks).

`after` skips the first N matching calls before firing; `times` bounds how
many calls fire (None = every one while armed). Multiple `inject()` blocks
nest; each returns its `FaultPlan` whose `calls`/`triggered` counters let
tests assert exactly which executions were hit.

    with faults.inject("packed_sparse", mode="raise") as plan:
        out = engine.score(pairs)          # completes via packed_dense
    assert plan.triggered >= 1
"""

from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp


class FaultError(RuntimeError):
    """An injected kernel failure (generic crash)."""


class ResourceExhausted(FaultError):
    """An injected allocation failure — stands in for the XLA
    RESOURCE_EXHAUSTED family (VMEM/HBM OOM on a specific path)."""


@dataclass
class FaultPlan:
    """One armed fault: where, how, and when it fires (plus observed
    counters for assertions)."""
    site: str
    mode: str = "raise"            # raise | oom | nan
    after: int = 0                 # skip the first `after` matching calls
    times: int | None = None       # fire at most this many times
    calls: int = field(default=0, init=False)       # matching calls seen
    triggered: int = field(default=0, init=False)   # calls actually failed

    def _fires(self) -> bool:
        i = self.calls
        self.calls += 1
        if i < self.after or (self.times is not None
                              and self.triggered >= self.times):
            return False
        self.triggered += 1
        return True


_ACTIVE: list[FaultPlan] = []


def _nan_like(x):
    x = jnp.asarray(x) if not hasattr(x, "dtype") else x
    if jnp.issubdtype(x.dtype, jnp.inexact):
        return jnp.full_like(x, jnp.nan)
    return x


def _hook(site: str, thunk):
    """The seam the engine calls around every executor invocation."""
    corrupt = False
    for plan in list(_ACTIVE):
        if plan.site != site:
            continue
        if plan._fires():
            if plan.mode == "oom":
                raise ResourceExhausted(
                    f"injected RESOURCE_EXHAUSTED at {site} "
                    f"(call {plan.calls - 1})")
            if plan.mode == "raise":
                raise FaultError(
                    f"injected fault at {site} (call {plan.calls - 1})")
            corrupt = True                          # mode == "nan"
    out = thunk()
    if corrupt:
        out = jax.tree.map(_nan_like, out)
    return out


@contextmanager
def inject(site: str, mode: str = "raise", *, after: int = 0,
           times: int | None = None):
    """Arm one fault for the duration of the block; yields its FaultPlan."""
    if mode not in ("raise", "oom", "nan"):
        raise ValueError(f"unknown fault mode {mode!r}")
    from repro.core import engine as engine_mod

    plan = FaultPlan(site, mode, after, times)
    _ACTIVE.append(plan)
    engine_mod._FAULT_HOOK = _hook
    try:
        yield plan
    finally:
        _ACTIVE.remove(plan)
        if not _ACTIVE:
            engine_mod._FAULT_HOOK = None
