"""Deterministic fault injection for the scoring engine (DESIGN.md §12).

Every degradation transition in the engine — ladder step-down, breaker
open/half-open/close, NaN-guarded training, per-bucket embed fallback — is
only trustworthy if it can be *driven* on demand. This harness does that
without monkeypatching kernels (whose jitted callables the engine caches,
so attribute patching would silently miss warm engines): the engine routes
every executor invocation through a module-level hook seam
(`core.engine._FAULT_HOOK`, `None` in production — a single attribute read
per kernel call), and `inject()` arms that seam for the duration of a
`with` block.

Sites are the engine's execution points:

    "packed_sparse" | "packed_dense" | "bucketed_mega" | "two_kernel"
    | "reference"          — score-path kernel calls (one per bucket/pack)
    "embed"                — the per-bucket embedding call (cache misses)
    "embed_fallback"       — the reference retry of a failed embed bucket
    "head"                 — the fused NTN+FCN head
    "head_fallback"        — the reference retry of a failed head call
    "prefilter"            — the blocked top-M retrieval scan (two-stage
                             search degrades to the exact full scan, §14)
    "train:packed_sparse" | "train:packed_dense" | "train:reference"
                           — loss_and_grad executor calls
    "sharded:packed_sparse" | "sharded:packed_dense"
                           — the multi-device shard_map score executors
                             (§16): a dead shard surfaces here and the
                             ladder collapses the call to single-device
    "sharded:train:packed_sparse" | "sharded:train:packed_dense"
                           — the multi-device psum train executors (§16)
    "profile"              — the engine's trace-record append (§15): a
                             failing recorder must never fail the scoring
                             call, only count `profile_record_errors`

Modes:

    "raise"  — raise `FaultError` (a generic kernel crash);
    "oom"    — raise `ResourceExhausted` (simulated RESOURCE_EXHAUSTED /
               VMEM exhaustion on the chosen path);
    "nan"    — let the call run, then replace every floating leaf of the
               result with NaN (a silently-corrupting kernel — the hardest
               failure class, caught by the engine's finite checks).

`after` skips the first N matching calls before firing; `times` bounds how
many calls fire (None = every one while armed). Multiple `inject()` blocks
nest; each returns its `FaultPlan` whose `calls`/`triggered` counters let
tests assert exactly which executions were hit.

    with faults.inject("packed_sparse", mode="raise") as plan:
        out = engine.score(pairs)          # completes via packed_dense
    assert plan.triggered >= 1
"""

from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp


class FaultError(RuntimeError):
    """An injected kernel failure (generic crash)."""


class ResourceExhausted(FaultError):
    """An injected allocation failure — stands in for the XLA
    RESOURCE_EXHAUSTED family (VMEM/HBM OOM on a specific path)."""


@dataclass
class FaultPlan:
    """One armed fault: where, how, and when it fires (plus observed
    counters for assertions)."""
    site: str
    mode: str = "raise"            # raise | oom | nan
    after: int = 0                 # skip the first `after` matching calls
    times: int | None = None       # fire at most this many times
    calls: int = field(default=0, init=False)       # matching calls seen
    triggered: int = field(default=0, init=False)   # calls actually failed

    def _fires(self) -> bool:
        i = self.calls
        self.calls += 1
        if i < self.after or (self.times is not None
                              and self.triggered >= self.times):
            return False
        self.triggered += 1
        return True


_ACTIVE: list[FaultPlan] = []


def _nan_like(x):
    x = jnp.asarray(x) if not hasattr(x, "dtype") else x
    if jnp.issubdtype(x.dtype, jnp.inexact):
        return jnp.full_like(x, jnp.nan)
    return x


def _hook(site: str, thunk):
    """The seam the engine calls around every executor invocation."""
    corrupt = False
    for plan in list(_ACTIVE):
        if plan.site != site:
            continue
        if plan._fires():
            if plan.mode == "oom":
                raise ResourceExhausted(
                    f"injected RESOURCE_EXHAUSTED at {site} "
                    f"(call {plan.calls - 1})")
            if plan.mode == "raise":
                raise FaultError(
                    f"injected fault at {site} (call {plan.calls - 1})")
            corrupt = True                          # mode == "nan"
    out = thunk()
    if corrupt:
        out = jax.tree.map(_nan_like, out)
    return out


@contextmanager
def inject(site: str, mode: str = "raise", *, after: int = 0,
           times: int | None = None):
    """Arm one fault for the duration of the block; yields its FaultPlan."""
    if mode not in ("raise", "oom", "nan"):
        raise ValueError(f"unknown fault mode {mode!r}")
    from repro.core import engine as engine_mod

    plan = FaultPlan(site, mode, after, times)
    _ACTIVE.append(plan)
    engine_mod._FAULT_HOOK = _hook
    try:
        yield plan
    finally:
        _ACTIVE.remove(plan)
        if not _ACTIVE:
            engine_mod._FAULT_HOOK = None


# --------------------------------------------------------------------------
# Filesystem faults (DESIGN.md §13): the durable-state twin of the executor
# seam above. Every durable write in the repo funnels through
# `core.store.atomic_write_bytes(path, data, site=...)`; `fs_inject()` arms
# its `_FS_HOOK` so a chaos test can corrupt EXACTLY the bytes of one named
# write — no sleeping on race windows, no real disk errors required. Sites:
#
#     "store:shard"    — one ShardStore row-shard file
#     "store:manifest" — the ShardStore JSON manifest
#     "ckpt:arrays"    — a checkpoint's arrays.<proc>.npz payload
#     "ckpt:manifest"  — a checkpoint's msgpack manifest
#     "profile"        — a TraceRecorder JSONL flush (§15): torn/garbled
#                        record lines are skipped-and-counted on the next
#                        read (`records_dropped`), never fail a flush
#
# Write-time modes (what reaches the disk despite the writer's fsync path):
#
#     "torn"    — the write is truncated at byte `at_byte` (default: half);
#     "bitflip" — one bit of byte `at_byte` is flipped (silent bit rot);
#     "missing" — the write is dropped entirely, the writer believes it
#                 succeeded (lost write / dropped flush);
#     "stale"   — manifest sites only: the manifest is written with a
#                 format version this reader does not support (a replica
#                 running newer code wrote the index).
#
# `corrupt_file()` applies the same damage to a file already on disk — the
# at-rest corruption story (the write was fine; the disk rotted later).


@dataclass
class FsFaultPlan:
    """One armed filesystem fault, with observed counters for assertions."""
    site: str
    mode: str = "torn"             # torn | bitflip | missing | stale
    at_byte: int | None = None     # position for torn/bitflip (default mid)
    after: int = 0
    times: int | None = None
    calls: int = field(default=0, init=False)
    triggered: int = field(default=0, init=False)

    _fires = FaultPlan._fires


_FS_ACTIVE: list[FsFaultPlan] = []


def _damage_bytes(data: bytes, mode: str, at_byte: int | None,
                  site: str) -> bytes | None:
    if mode == "missing":
        return None
    if mode == "stale":
        if not site.endswith("manifest"):
            raise ValueError(f"mode 'stale' only applies to manifest sites, "
                             f"got {site!r}")
        if site.startswith("store:"):
            import json

            man = json.loads(data.decode())
            man["format_version"] = man.get("format_version", 0) + 1000
            return json.dumps(man).encode()
        import msgpack

        man = msgpack.unpackb(data)
        man["format_version"] = man.get("format_version", 0) + 1000
        return msgpack.packb(man)
    at = len(data) // 2 if at_byte is None else min(at_byte, len(data) - 1)
    if mode == "torn":
        return data[:at]
    buf = bytearray(data)          # mode == "bitflip"
    buf[at] ^= 0x01
    return bytes(buf)


def _fs_hook(site: str, path: str, data: bytes) -> bytes | None:
    for plan in list(_FS_ACTIVE):
        if plan.site != site:
            continue
        if plan._fires():
            data = _damage_bytes(data, plan.mode, plan.at_byte, site)
            if data is None:
                return None
    return data


@contextmanager
def fs_inject(site: str, mode: str = "torn", *, at_byte: int | None = None,
              after: int = 0, times: int | None = None):
    """Arm one filesystem fault for the block; yields its FsFaultPlan."""
    if mode not in ("torn", "bitflip", "missing", "stale"):
        raise ValueError(f"unknown filesystem fault mode {mode!r}")
    from repro.core import store as store_mod

    plan = FsFaultPlan(site, mode, at_byte, after, times)
    _FS_ACTIVE.append(plan)
    store_mod._FS_HOOK = _fs_hook
    try:
        yield plan
    finally:
        _FS_ACTIVE.remove(plan)
        if not _FS_ACTIVE:
            store_mod._FS_HOOK = None


def corrupt_file(path: str, mode: str = "bitflip", *,
                 at_byte: int | None = None) -> None:
    """Deterministically damage a file already on disk (at-rest bit rot /
    truncation / loss), bypassing the atomic-write seam on purpose: the
    write succeeded, the DISK failed later."""
    import os

    if mode == "missing":
        os.remove(path)
        return
    # Map the file back to its manifest dialect so mode="stale" works at
    # rest too (store manifests are JSON, checkpoint manifests msgpack).
    site = ("store:manifest" if path.endswith(".json")
            else "ckpt:manifest" if path.endswith(".msgpack") else path)
    with open(path, "rb") as f:
        data = f.read()
    data = _damage_bytes(data, mode, at_byte, site=site)
    with open(path, "wb") as f:
        f.write(data)
