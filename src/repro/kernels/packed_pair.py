"""Node-packed sparsity-aware pair-score megakernel (DESIGN.md §8).

Same single-pass dataflow as `fused_pair.py` — normalization -> GCN stack ->
Att -> NTN -> FCN, nothing but final scores touching HBM — but the program's
unit of work is a *packed tile*, not a padded pair: `core.batching.pack_pairs`
first-fit-decreasing-packs many variable-size graph pairs into fixed
`[node_budget]` node tiles with per-node segment IDs, so

  * pad zeros shrink from per-graph bucket padding (up to ~2x of every row)
    to the tile's FFD slack (~10%), and
  * the first GCN layer's one-hot feature multiply disappears entirely: the
    kernel carries int32 node labels and gathers W1 rows
    (`gcn_layers_block(labels=...)`), never materializing the
    [N, n_labels] one-hot block (~n_labels-fold feature HBM traffic cut).

Per-graph stages become segment-ID forms of the same MXU-shaped ops:
adjacency normalization needs no change (the packed adjacency is
block-diagonal and the masked normalization factors per graph), Att pooling
contracts against the segment one-hot (`segment_att_pool_block`), and the
NTN/FCN head scores every pair slot of the tile in one [TB*P, F] block.
Pad node slots carry mask 0 / segment 0 and contribute exact zeros; pad pair
slots are zeroed by `pair_mask` on the way out.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (compiler_params, flatten_layer_params,
                                  gcn_layers_block, leading_block_spec,
                                  normalize_adjacency_block, ntn_fcn_block,
                                  read_layer_refs, replicated_spec,
                                  segment_att_pool_block, should_interpret)


def _kernel(n_gcn_layers,
            adj1_ref, lab1_ref, mask1_ref, seg1_ref,
            adj2_ref, lab2_ref, mask2_ref, seg2_ref, pmask_ref, *refs):
    out_ref, refs = refs[-1], refs[:-1]
    gcn_refs, refs = refs[:2 * n_gcn_layers], refs[2 * n_gcn_layers:]
    watt_ref, wt_ref, vt_ref, ntn_b_ref = refs[:4]
    fcn_refs = refs[4:]
    tb = adj1_ref.shape[0]
    p = pmask_ref.shape[-1]

    # Stack lhs/rhs tiles into one [2*TB, ...] block (engine reuse ->
    # batching, DESIGN.md §2): one normalization, GCN stack and Att stage
    # serve both sides of every pair.
    adj = jnp.concatenate([adj1_ref[...], adj2_ref[...]], 0).astype(jnp.float32)
    labels = jnp.concatenate([lab1_ref[...], lab2_ref[...]], 0)
    mask = jnp.concatenate([mask1_ref[...], mask2_ref[...]], 0).astype(jnp.float32)
    seg = jnp.concatenate([seg1_ref[...], seg2_ref[...]], 0)

    # Block-diagonal A': masked normalization factors per packed graph.
    a_norm = normalize_adjacency_block(adj, mask)
    h = gcn_layers_block(a_norm, None, mask, read_layer_refs(gcn_refs),
                         labels=labels)                    # [2*TB, NB, F]
    hg = segment_att_pool_block(h, mask, seg, watt_ref[...], p)  # [2*TB, P, F]
    f = hg.shape[-1]
    scores = ntn_fcn_block(hg[:tb].reshape(tb * p, f),
                           hg[tb:].reshape(tb * p, f),
                           wt_ref[...], vt_ref[...], ntn_b_ref[...],
                           read_layer_refs(fcn_refs))      # [TB*P, 1]
    out_ref[...] = (scores.reshape(tb, p)
                    * pmask_ref[...].astype(jnp.float32)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_block", "interpret"))
def packed_pair_score(adj1: jax.Array, labels1: jax.Array, mask1: jax.Array,
                      seg1: jax.Array, adj2: jax.Array, labels2: jax.Array,
                      mask2: jax.Array, seg2: jax.Array, pair_mask: jax.Array,
                      gcn_params, att_w: jax.Array, ntn_params, fcn_params, *,
                      tile_block: int = 4,
                      interpret: bool | None = None) -> jax.Array:
    """Packed tiles (pack_pairs layout) -> [T, P] pair-slot scores in one
    pallas_call. T must be a multiple of tile_block (ops.py pads; pad tiles
    have all-zero masks and pair_mask zeroes their slots)."""
    if interpret is None:
        interpret = should_interpret()
    t, nb, _ = adj1.shape
    assert t % tile_block == 0, (t, tile_block)
    p = pair_mask.shape[-1]
    f = gcn_params[-1]["w"].shape[1]
    k = ntn_params["b"].shape[0]
    # Host-side pre-transposes (same layouts as fused_pair.py): W [K,F,F]
    # -> [F, K*F], V [K,2F] -> [2F, K] so the kernel sees pure matmuls.
    wt = jnp.transpose(ntn_params["w"], (1, 0, 2)).reshape(f, k * f)
    vt = ntn_params["v"].T
    weights = (flatten_layer_params(gcn_params)
               + [att_w, wt, vt, ntn_params["b"]]
               + flatten_layer_params(fcn_params))

    def blk(shape):
        return leading_block_spec((tile_block,) + shape)

    out = pl.pallas_call(
        functools.partial(_kernel, len(gcn_params)),
        grid=(t // tile_block,),
        in_specs=[blk((nb, nb)), blk((nb,)), blk((nb,)), blk((nb,)),
                  blk((nb, nb)), blk((nb,)), blk((nb,)), blk((nb,)),
                  blk((p,))]
                 + [replicated_spec(a) for a in weights],
        out_specs=blk((p,)),
        out_shape=jax.ShapeDtypeStruct((t, p), mask1.dtype),
        compiler_params=compiler_params(("parallel",)),
        interpret=interpret,
    )(adj1, labels1, mask1, seg1, adj2, labels2, mask2, seg2, pair_mask,
      *weights)
    return out
