"""Fused NTN + FCN head Pallas kernel (SimGNN stages 3-4, paper §4.3 Fig. 9).

One program scores a block of graph pairs: the K bilinear similarity slices,
the linear term, and the whole fully-connected reduction run back-to-back in
VMEM. The bilinear tensor contraction  h1^T W[k] h2  is reshaped into a single
MXU matmul  (GB, F) @ (F, K*F)  followed by an elementwise reduce against h2 —
the TPU version of the paper's observation that NTN is "a series of fixed-size
MVMs" best served by one small dense engine. The compute body lives in
`common.ntn_fcn_block`, shared with the end-to-end megakernel
(`fused_pair.py`), and is variadic over FCN depth.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (compiler_params, flatten_layer_params,
                                  leading_block_spec, ntn_fcn_block,
                                  read_layer_refs, replicated_spec,
                                  should_interpret)


def _kernel(h1_ref, h2_ref, wt_ref, vt_ref, b_ref, *fcn_refs):
    out_ref = fcn_refs[-1]
    scores = ntn_fcn_block(h1_ref[...].astype(jnp.float32),
                           h2_ref[...].astype(jnp.float32),
                           wt_ref[...], vt_ref[...], b_ref[...],
                           read_layer_refs(fcn_refs[:-1]))
    out_ref[...] = scores.astype(out_ref.dtype)                     # [GB, 1]


@functools.partial(jax.jit, static_argnames=("block_pairs", "interpret"))
def simgnn_head(hg1: jax.Array, hg2: jax.Array, ntn_params, fcn_params, *,
                block_pairs: int = 128,
                interpret: bool | None = None) -> jax.Array:
    """hg1/hg2 [B, F] graph embeddings -> [B] similarity scores in (0,1)."""
    if interpret is None:
        interpret = should_interpret()
    b, f = hg1.shape
    assert b % block_pairs == 0, (b, block_pairs)
    k = ntn_params["b"].shape[0]
    # Pre-transpose on the host side of the call: W [K,F,F] -> [F, K*F],
    # V [K,2F] -> [2F, K] so the kernel sees pure matmul layouts.
    wt = jnp.transpose(ntn_params["w"], (1, 0, 2)).reshape(f, k * f)
    vt = ntn_params["v"].T
    fcn_flat = flatten_layer_params(fcn_params)

    def blk(shape):
        return leading_block_spec((block_pairs,) + shape)

    out = pl.pallas_call(
        _kernel,
        grid=(b // block_pairs,),
        in_specs=[blk((f,)), blk((f,)), replicated_spec(wt),
                  replicated_spec(vt), replicated_spec(ntn_params["b"])]
                 + [replicated_spec(a) for a in fcn_flat],
        out_specs=blk((1,)),
        out_shape=jax.ShapeDtypeStruct((b, 1), hg1.dtype),
        compiler_params=compiler_params(("parallel",)),
        interpret=interpret,
    )(hg1, hg2, wt, vt, ntn_params["b"], *fcn_flat)
    return out[:, 0]
