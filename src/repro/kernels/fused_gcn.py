"""Fused N-layer GCN + attention-pooling Pallas TPU kernel.

This is the TPU realization of SPA-GCN's central mechanism: the FPGA dataflow
pipeline that runs *all* GCN layers plus the Att stage with no off-chip
traffic for intermediates (paper §3.3, Fig. 4). Here one grid program
processes a block of `GB` graphs: adjacency, features, and every layer's
intermediate live in VMEM for the program's whole lifetime; layer weights
(tiny: <=128x128) are broadcast to all programs and read from HBM once per
block — the paper's "read each element only once" principle.

Parallelism mapping (paper Table 2 -> TPU):
  SIMD_FT / SIMD_Agg  -> MXU lanes (feature dim, 128-wide)
  DF (node duplication)-> sublanes (graphs x nodes rows of the matmul)
  inter-layer pipeline -> in-VMEM loop over layers (no HBM spill at all,
                          strictly stronger than FIFO pipelining)
  query replication    -> grid over graph blocks x chips over the mesh

The kernel is variadic over GCN depth — any `SimGNNConfig.gcn_dims` length
compiles (the layer loop lives in `common.gcn_att_block`, shared with the
end-to-end megakernel in `fused_pair.py`). The grid dimension is 'parallel':
graph blocks are independent (the paper's replicated pipelines).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (compiler_params, flatten_layer_params,
                                  gcn_att_block, leading_block_spec,
                                  read_layer_refs, replicated_spec,
                                  should_interpret)


def _kernel(adj_ref, feats_ref, mask_ref, *refs):
    out_ref, watt_ref, layer_refs = refs[-1], refs[-2], refs[:-2]
    adj = adj_ref[...].astype(jnp.float32)          # [GB, N, N]
    h = feats_ref[...].astype(jnp.float32)          # [GB, N, F0]
    mask = mask_ref[...].astype(jnp.float32)        # [GB, N]
    hg = gcn_att_block(adj, h, mask, read_layer_refs(layer_refs),
                       watt_ref[...])
    out_ref[...] = hg.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_graphs", "interpret"))
def fused_gcn_att(adj_norm: jax.Array, feats: jax.Array, mask: jax.Array,
                  gcn_params, att_w: jax.Array, *,
                  block_graphs: int = 8,
                  interpret: bool | None = None) -> jax.Array:
    """adj_norm [B,N,N] (pre-normalized A'), feats [B,N,F0], mask [B,N]
    -> graph embeddings [B, F_last]. B must be a multiple of block_graphs
    (ops.py pads). `gcn_params` may hold any number of layers."""
    if interpret is None:
        interpret = should_interpret()
    b, n, _ = adj_norm.shape
    assert b % block_graphs == 0, (b, block_graphs)
    flat = flatten_layer_params(gcn_params)
    f_out = gcn_params[-1]["w"].shape[1]
    grid = (b // block_graphs,)

    def blk(shape):   # per-graph-block operand
        return leading_block_spec((block_graphs,) + shape)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[blk((n, n)), blk((n, feats.shape[-1])), blk((n,))]
                 + [replicated_spec(a) for a in flat + [att_w]],
        out_specs=blk((f_out,)),
        out_shape=jax.ShapeDtypeStruct((b, f_out), feats.dtype),
        compiler_params=compiler_params(("parallel",)),
        interpret=interpret,
    )(adj_norm, feats, mask, *flat, att_w)
