"""Fused 3-layer GCN + attention-pooling Pallas TPU kernel.

This is the TPU realization of SPA-GCN's central mechanism: the FPGA dataflow
pipeline that runs *all* GCN layers plus the Att stage with no off-chip
traffic for intermediates (paper §3.3, Fig. 4). Here one grid program
processes a block of `GB` graphs: adjacency, features, and every layer's
intermediate live in VMEM for the program's whole lifetime; layer weights
(tiny: <=128x128) are broadcast to all programs and read from HBM once per
block — the paper's "read each element only once" principle.

Parallelism mapping (paper Table 2 -> TPU):
  SIMD_FT / SIMD_Agg  -> MXU lanes (feature dim, 128-wide)
  DF (node duplication)-> sublanes (graphs x nodes rows of the matmul)
  inter-layer pipeline -> in-VMEM loop over layers (no HBM spill at all,
                          strictly stronger than FIFO pipelining)
  query replication    -> grid over graph blocks x chips over the mesh

The grid dimension is 'parallel': graph blocks are independent (the paper's
replicated pipelines).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import compiler_params, should_interpret


def _kernel(adj_ref, feats_ref, mask_ref,
            w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, watt_ref,
            out_ref):
    adj = adj_ref[...]                       # [GB, N, N]
    h = feats_ref[...].astype(jnp.float32)   # [GB, N, F0]
    mask = mask_ref[...]                     # [GB, N]
    gb, n, _ = h.shape

    for w_ref, b_ref in ((w1_ref, b1_ref), (w2_ref, b2_ref), (w3_ref, b3_ref)):
        w = w_ref[...].astype(jnp.float32)
        # Feature Transformation (paper MULT+ACC): one 2D MXU matmul for the
        # whole graph block — (GB*N, Fin) @ (Fin, Fout).
        hw = jnp.dot(h.reshape(gb * n, -1), w,
                     preferred_element_type=jnp.float32) + b_ref[...]
        hw = hw.reshape(gb, n, -1)
        # Aggregation (paper ACG): per-graph small matmul A' @ (HW); the
        # graph-block loop is unrolled (GB is a static, small tile factor).
        h = jnp.stack([
            jnp.dot(adj[g], hw[g], preferred_element_type=jnp.float32)
            for g in range(gb)
        ])
        # ReLU + mask: the paper's max(0,.) unit at the ACG output.
        h = jnp.maximum(h, 0.0) * mask[..., None]

    # Att stage (paper §4.2, Eq. 3) fused in the same program.
    n_valid = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)   # [GB,1]
    mean_h = jnp.sum(h * mask[..., None], axis=1) / n_valid            # [GB,F]
    c = jnp.tanh(jnp.dot(mean_h, watt_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32))          # [GB,F]
    att = jax.nn.sigmoid(jnp.sum(h * c[:, None, :], axis=-1)) * mask   # [GB,N]
    out_ref[...] = jnp.sum(att[..., None] * h, axis=1).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_graphs", "interpret"))
def fused_gcn_att(adj_norm: jax.Array, feats: jax.Array, mask: jax.Array,
                  gcn_params, att_w: jax.Array, *,
                  block_graphs: int = 8,
                  interpret: bool | None = None) -> jax.Array:
    """adj_norm [B,N,N] (pre-normalized A'), feats [B,N,F0], mask [B,N]
    -> graph embeddings [B, F_last]. B must be a multiple of block_graphs
    (ops.py pads)."""
    if interpret is None:
        interpret = should_interpret()
    b, n, _ = adj_norm.shape
    assert b % block_graphs == 0, (b, block_graphs)
    (w1, b1), (w2, b2), (w3, b3) = [(p["w"], p["b"]) for p in gcn_params]
    f_out = w3.shape[1]
    grid = (b // block_graphs,)

    def blk(shape):   # per-graph-block operand
        return pl.BlockSpec((block_graphs,) + shape, lambda i: (i,) + (0,) * len(shape))

    def rep(a):       # replicated (weights): full array to every program
        return pl.BlockSpec(a.shape, lambda i: (0,) * a.ndim)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[blk((n, n)), blk((n, feats.shape[-1])), blk((n,)),
                  rep(w1), rep(b1), rep(w2), rep(b2), rep(w3), rep(b3), rep(att_w)],
        out_specs=blk((f_out,)),
        out_shape=jax.ShapeDtypeStruct((b, f_out), feats.dtype),
        compiler_params=compiler_params(("parallel",)),
        interpret=interpret,
    )(adj_norm, feats, mask, w1, b1, w2, b2, w3, b3, att_w)
