"""Jit'd public wrappers around the Pallas kernels.

These are the entry points the rest of the framework uses: they handle
padding to block multiples, parameter plumbing from the core/ model param
trees, and the interpret-mode fallback (DESIGN.md §2 — kernels compile with
Mosaic on TPU, run emulated elsewhere).

For SimGNN pair scoring there are four kernel paths (path selection lives in
`core.engine.ScoringEngine`, DESIGN.md §9):

  * `pair_score_sparse` — the edge-centric packed-sparse megakernel
    (DESIGN.md §9): packed tiles aggregated from the A' non-zero edge list
    (in-kernel segment sum) instead of the dense adjacency matmul; the
    engine's choice for sparse (AIDS-like) streams.
  * `pair_score_packed` — the packed-pair megakernel (DESIGN.md §8): many
    variable-size pairs share fixed node-budget tiles (segment IDs), the
    first layer gathers W1 rows from int32 labels instead of multiplying
    one-hots; the engine's choice for dense-adjacency streams.
  * `pair_score_megakernel` — ONE pallas_call per bucket-padded pair batch
    (DESIGN.md §7); the dense-feats path, kept for non-one-hot inputs and
    as the bucketed fallback for oversized pairs.
  * `simgnn_pair_score_kernel` — the two-kernel composition (fused GCN+Att,
    then fused NTN+FCN head) kept as building blocks for embedding-only /
    head-only callers and as the benchmark comparison point.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.flash_attn import flash_attention
from repro.kernels.fused_gcn import fused_gcn_att
from repro.kernels.fused_pair import fused_pair_score
from repro.kernels.packed_pair import packed_pair_score
from repro.kernels.retrieval import (blocked_topm, blocked_topm_ntn,
                                     collapse_query_ntn,
                                     retrieval_block_cols)
from repro.kernels.simgnn_head import simgnn_head
from repro.kernels.sparse_pair import sparse_pair_score
from repro.kernels.wkv6 import wkv6

__all__ = ["flash_attention", "wkv6", "graph_embeddings_fused",
           "pair_scores_fused", "simgnn_pair_score_kernel",
           "pair_score_megakernel", "megakernel_block_pairs",
           "pair_score_packed", "packed_node_budget", "packed_tile_block",
           "pair_score_sparse", "packed_edge_budget", "sparse_tile_block",
           "blocked_topm", "blocked_topm_ntn", "collapse_query_ntn",
           "retrieval_block_cols", "sharded_tile_block",
           "sharded_tile_plan",
           "sharded_tile_target", "build_pair_score_packed_sharded",
           "build_pair_score_sparse_sharded", "pair_score_packed_sharded",
           "pair_score_sparse_sharded"]


def _pad_batch(x: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    b = x.shape[0]
    pad = (-b) % multiple
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], 0)
    return x, b


def graph_embeddings_fused(params, adj_norm, feats, mask, *,
                           block_graphs: int = 8,
                           interpret: bool | None = None) -> jax.Array:
    """SimGNN stages 1-2 via the fused Pallas kernel. Pads B to a block
    multiple (pad graphs have all-zero masks -> zero embeddings)."""
    adj_norm, b = _pad_batch(adj_norm, block_graphs)
    feats, _ = _pad_batch(feats, block_graphs)
    mask, _ = _pad_batch(mask, block_graphs)
    out = fused_gcn_att(adj_norm, feats, mask, params["gcn"],
                        params["att"]["w"], block_graphs=block_graphs,
                        interpret=interpret)
    return out[:b]


def pair_scores_fused(params, hg1, hg2, *, block_pairs: int = 128,
                      interpret: bool | None = None) -> jax.Array:
    """SimGNN stages 3-4 via the fused head kernel."""
    hg1, b = _pad_batch(hg1, block_pairs)
    hg2, _ = _pad_batch(hg2, block_pairs)
    out = simgnn_head(hg1, hg2, params["ntn"], params["fcn"],
                      block_pairs=block_pairs, interpret=interpret)
    return out[:b]


def simgnn_pair_score_kernel(params, adj1, feats1, mask1, adj2, feats2, mask2,
                             *, block_graphs: int = 8,
                             interpret: bool | None = None) -> jax.Array:
    """Full SimGNN pipeline on the two-kernel path: both graphs share one
    fused GCN+Att invocation (batch 2B), then the fused NTN+FCN head — the
    graph embeddings round-trip through HBM between the two launches (the
    megakernel below removes that). Expects *raw* adjacency; normalization
    happens here (parity with core.simgnn)."""
    from repro.core.gcn import normalized_adjacency

    adj = jnp.concatenate([adj1, adj2], axis=0)
    feats = jnp.concatenate([feats1, feats2], axis=0)
    mask = jnp.concatenate([mask1, mask2], axis=0)
    a_norm = normalized_adjacency(adj, mask)
    hg = graph_embeddings_fused(params, a_norm, feats, mask,
                                block_graphs=block_graphs, interpret=interpret)
    hg1, hg2 = jnp.split(hg, 2, axis=0)
    bp = max(8, min(128, hg1.shape[0]))
    return pair_scores_fused(params, hg1, hg2, block_pairs=bp,
                             interpret=interpret)


def megakernel_block_pairs(n_nodes: int) -> int:
    """Pairs-per-program policy for the megakernel, by graph bucket size.

    Sized so one program's working set (two graphs' adjacency + every layer
    activation at the widest feature dim) stays a small fraction of the
    ~16 MB VMEM: 64 pairs at N=8 down to 8 pairs at N=64."""
    return max(8, min(64, 512 // max(n_nodes, 1)))


def pair_score_megakernel(params, adj1, feats1, mask1, adj2, feats2, mask2,
                          *, block_pairs: int | None = None,
                          interpret: bool | None = None) -> jax.Array:
    """Full SimGNN pipeline in ONE pallas_call (DESIGN.md §7): raw adjacency
    in, [B] scores out; normalization, the whole GCN stack, Att pooling, NTN
    and FCN never leave VMEM. Pads B to a block multiple (pad pairs have
    all-zero masks; their scores are sliced off)."""
    if block_pairs is None:
        block_pairs = megakernel_block_pairs(adj1.shape[-1])
    b = adj1.shape[0]
    # Never pad beyond one block: a batch smaller than block_pairs shrinks
    # the block to B rounded up to the 8-sublane tile instead.
    block_pairs = min(block_pairs, max(8, -(-b // 8) * 8))
    padded = [_pad_batch(x, block_pairs)[0]
              for x in (adj1, feats1, mask1, adj2, feats2, mask2)]
    out = fused_pair_score(*padded, params["gcn"], params["att"]["w"],
                           params["ntn"], params["fcn"],
                           block_pairs=block_pairs, interpret=interpret)
    return out[:b]


def packed_node_budget(max_nodes: int) -> int:
    """Node budget for packed tiles: at least one whole graph must fit, and a
    64-node floor keeps the tile's last dims near the 128-lane MXU tile while
    a single tile's working set (two sides' adjacency + A' + widest-layer
    activations, ~200 KB fp32 at NB=64) stays a small fraction of the ~16 MB
    VMEM even at `packed_tile_block` tiles per program."""
    return max(64, -(-max_nodes // 8) * 8)


def packed_tile_block(node_budget: int) -> int:
    """Tiles-per-program policy for the packed megakernel: scale down with
    the node budget so a program's working set (two sides' adjacency + A' +
    widest activations, ~130 KB fp32 per NB=64 tile) stays ~2 MB — a small
    fraction of the ~16 MB VMEM (16 tiles at NB=64, 8 at NB=128)."""
    return max(1, min(16, 1024 // max(node_budget, 1)))


def _tile_pad_plan(t: int, tile_block: int,
                   quantize_tiles: bool) -> tuple[int, int]:
    """Shared tile-count padding policy for the packed megakernels: returns
    (target_tiles, tile_block) with target a tile_block multiple >= t.

    `quantize_tiles` rounds T up to the next power of two so a serving loop
    with varying batch sizes compiles O(log T) executables instead of one
    per tile count (the 'small, fixed set of shapes' principle)."""
    target = t
    if quantize_tiles:
        target = 1
        while target < t:
            target *= 2
    tile_block = min(tile_block, target)
    # Pad-tile waste is real kernel work: halve tile_block until the rounding
    # waste is <= t/8 (always true once tile_block divides target).
    while (tile_block > 1
           and (-(-target // tile_block) * tile_block - target) * 8 > target):
        tile_block //= 2
    # target is a tile_block multiple >= t, so padding to `target` lands on it.
    return -(-target // tile_block) * tile_block, tile_block


def pair_score_packed(params, packed, *, tile_block: int | None = None,
                      quantize_tiles: bool = False,
                      interpret: bool | None = None) -> jax.Array:
    """Score a `core.batching.PackedPairBatch` in ONE pallas_call
    (DESIGN.md §8): [T, P] pair-slot scores, zero at pad slots. Pads T to a
    tile_block multiple (pad tiles carry all-zero masks; `pair_mask` zeroes
    their slots). Use `core.batching.unpack_pair_scores` to restore the
    original pair order. See `_tile_pad_plan` for `quantize_tiles`."""
    if tile_block is None:
        tile_block = packed_tile_block(packed.node_budget)
    t = packed.adj1.shape[0]
    target, tile_block = _tile_pad_plan(t, tile_block, quantize_tiles)
    arrays = [_pad_batch(x, target)[0]
              for x in (packed.adj1, packed.labels1, packed.mask1, packed.seg1,
                        packed.adj2, packed.labels2, packed.mask2, packed.seg2,
                        packed.pair_mask)]
    out = packed_pair_score(*arrays, params["gcn"], params["att"]["w"],
                            params["ntn"], params["fcn"],
                            tile_block=tile_block, interpret=interpret)
    return out[:t]


def sparse_tile_block(node_budget: int) -> int:
    """Tiles-per-program policy for the packed-sparse megakernel. The sparse
    tile's VMEM working set drops the [NB, NB] adjacency and A' blocks
    entirely (edge lists are ~3·E words, ~3 KB at E=256, vs 16 KB+16 KB of
    fp32 adjacency at NB=64), leaving activations as the footprint
    (~35 KB/tile side) — so about twice as many tiles fit the same ~2 MB
    program budget as `packed_tile_block` allows the dense kernel."""
    return max(1, min(32, 2048 // max(node_budget, 1)))


def packed_edge_budget(node_budget: int, avg_degree: float | None = None) -> int:
    """Packed-CSR edge budget per tile side: node_budget receiver rows times
    a per-node neighbor budget D from a small quantized ladder (4/6/8/12/16
    ... — O(log) distinct compiled shapes, like the power-of-two tile
    counts) sized to cover ~p75 of the in-degree distribution (self loop
    included) — D=4 at AIDS-like degree ~2.1, so NB·D = 256 slots vs the
    4096-entry dense block at NB=64. The tail beyond D spills to the small
    COO overflow list (degree-aware split), so a modest D never loses
    edges; `packed_pair_edges` also auto-grows if a whole stream outruns
    the budget. Half-way degrees round UP (floor(d + 0.5), not Python's
    banker's round(): round(2.5) == 2 made degree 2.5 share D=4 with the
    1.5–2.4 band while 3.5 rounded up — an inconsistent ladder step)."""
    d = 2.5 if avg_degree is None else avg_degree
    need = math.floor(d + 0.5) + 2         # ~p75 of molecule-like streams;
    for per_node in (4, 6, 8, 12, 16, 24, 32, 48, 64):   # tail -> overflow
        if per_node >= need:
            return node_budget * per_node
    return node_budget * node_budget       # degenerate: fully dense rows


def pair_score_sparse(params, packed, *, tile_block: int | None = None,
                      quantize_tiles: bool = False,
                      interpret: bool | None = None) -> jax.Array:
    """Score a `core.batching.PackedPairBatch` through the edge-centric
    packed-sparse megakernel (DESIGN.md §9): aggregation runs from the
    tile-local A' edge list (in-kernel segment sum) instead of the dense
    block-diagonal adjacency matmul. Same [T, P] output contract, tile
    padding and `quantize_tiles` policy as `pair_score_packed`.

    Expects `packed.edges` (pack with `with_edges=True`); when absent, the
    edge lists are extracted here at the default `packed_edge_budget`."""
    from repro.core.batching import packed_pair_edges

    edges = packed.edges
    if edges is None:
        edges = packed_pair_edges(packed,
                                  packed_edge_budget(packed.node_budget))
    if tile_block is None:
        tile_block = sparse_tile_block(packed.node_budget)
    t = packed.mask1.shape[0]
    target, tile_block = _tile_pad_plan(t, tile_block, quantize_tiles)
    e1, e2 = edges.edges1, edges.edges2
    o1, o2 = edges.overflow1, edges.overflow2
    arrays = [_pad_batch(x, target)[0]
              for x in (e1.senders, e1.weights,
                        o1.senders, o1.receivers, o1.weights,
                        packed.labels1, packed.mask1, packed.seg1,
                        e2.senders, e2.weights,
                        o2.senders, o2.receivers, o2.weights,
                        packed.labels2, packed.mask2, packed.seg2,
                        packed.pair_mask)]
    out = sparse_pair_score(*arrays, params["gcn"], params["att"]["w"],
                            params["ntn"], params["fcn"],
                            tile_block=tile_block, interpret=interpret)
    return out[:t]


# ---------------------------------------------------------------------------
# Device-sharded packed scoring (DESIGN.md §16): the [T, ...] tile axis is
# the data-parallel unit — shard it over a 1-D `tile` mesh, run the SAME
# packed megakernel per device on its tile span, gather scores host-side.
# Params ride in replicated (P()); the kernel body is unchanged, so per-tile
# results are bitwise products of the same program as the unsharded call.
#
# All sharded shape policy is pure powers of two (tile_block, padded tile
# count, device count), so every device count's per-device span is a whole
# number of identical tile_block programs, and the per-tile results stay
# bitwise-reproducible across device counts: the kernels are
# tile_block-invariant (each tile's reductions are within-tile; pinned by
# tests/test_sharded.py), so balance-shrinking tile_block to spread few
# tiles over many devices changes only the launch grid, never the scores.


def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def _pow2_ceil(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def sharded_tile_block(node_budget: int, *, sparse: bool = False) -> int:
    """Tiles-per-program ceiling for the sharded wrappers: the
    single-device VMEM policy rounded down to a power of two (see block
    comment above)."""
    tb = (sparse_tile_block if sparse else packed_tile_block)(node_budget)
    return _pow2_floor(tb)


def sharded_tile_plan(t: int, node_budget: int, n_devices: int, *,
                      sparse: bool = False) -> tuple[int, int]:
    """(padded tile count, tile_block) for a sharded call over `t` live
    tiles: T pads to a power-of-two >= t with at least one program per
    device, and tile_block shrinks below the VMEM policy when the mesh has
    more parallelism than tiles — the tile -> device balance assignment
    (20 tiles on 8 devices run as 5 devices x one 4-tile program, not one
    device x a 32-tile program plus 7 idle)."""
    tb = sharded_tile_block(node_budget, sparse=sparse)
    target = _pow2_ceil(max(t, 1))
    tb = min(tb, max(1, target // int(n_devices)))
    return max(target, int(n_devices) * tb), tb


def sharded_tile_target(t: int, tile_block: int, n_devices: int) -> int:
    """Padded tile count for a sharded call: power-of-two >= t, and at least
    one tile_block program per device."""
    return max(_pow2_ceil(max(t, 1)), int(n_devices) * tile_block)


def build_pair_score_packed_sharded(mesh: Mesh, node_budget: int, *,
                                    tile_block: int | None = None,
                                    interpret: bool | None = None):
    """Returns (fn, tile_block): `fn(params, adj1, labels1, mask1, seg1,
    adj2, labels2, mask2, seg2, pair_mask)` scoring tiles sharded over the
    mesh's `tile` axis. Inputs must be padded to a `sharded_tile_target`
    multiple; output is the full padded [T, P] score block (caller slices).
    `tile_block` defaults to the VMEM policy ceiling; callers pass the
    `sharded_tile_plan` block to balance few tiles over many devices.

    check_rep=False: pallas_call carries no replication rule, and every
    output element is tile-local anyway."""
    from repro.distributed.sharding import TILE_AXIS

    if tile_block is None:
        tile_block = sharded_tile_block(node_budget)

    def local(params, adj1, labels1, mask1, seg1,
              adj2, labels2, mask2, seg2, pair_mask):
        return packed_pair_score(adj1, labels1, mask1, seg1,
                                 adj2, labels2, mask2, seg2, pair_mask,
                                 params["gcn"], params["att"]["w"],
                                 params["ntn"], params["fcn"],
                                 tile_block=tile_block, interpret=interpret)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(),) + (P(TILE_AXIS),) * 9,
                   out_specs=P(TILE_AXIS), check_rep=False)
    return jax.jit(fn), tile_block


def build_pair_score_sparse_sharded(mesh: Mesh, node_budget: int, *,
                                    tile_block: int | None = None,
                                    interpret: bool | None = None):
    """Sparse twin of `build_pair_score_packed_sharded`: `fn(params, <17
    packed-CSR arrays in `pair_score_sparse` order>)`, tile axis sharded."""
    from repro.distributed.sharding import TILE_AXIS

    if tile_block is None:
        tile_block = sharded_tile_block(node_budget, sparse=True)

    def local(params, *arrays):
        return sparse_pair_score(*arrays, params["gcn"], params["att"]["w"],
                                 params["ntn"], params["fcn"],
                                 tile_block=tile_block, interpret=interpret)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(),) + (P(TILE_AXIS),) * 17,
                   out_specs=P(TILE_AXIS), check_rep=False)
    return jax.jit(fn), tile_block


@functools.lru_cache(maxsize=32)
def _sharded_builder_cached(mesh: Mesh, node_budget: int, sparse: bool,
                            tile_block: int, interpret: bool | None):
    build = (build_pair_score_sparse_sharded if sparse
             else build_pair_score_packed_sharded)
    return build(mesh, node_budget, tile_block=tile_block,
                 interpret=interpret)


def pair_score_packed_sharded(params, packed, *, mesh: Mesh,
                              interpret: bool | None = None) -> jax.Array:
    """Standalone sharded equivalent of `pair_score_packed` (the engine
    holds its own per-(path, device-count, tile_block) executable cache;
    this module cache serves tests/benchmarks). Same [T, P] output
    contract."""
    t = packed.adj1.shape[0]
    target, tile_block = sharded_tile_plan(t, packed.node_budget,
                                           mesh.devices.size)
    fn, _ = _sharded_builder_cached(mesh, packed.node_budget,
                                    False, tile_block, interpret)
    arrays = [_pad_batch(x, target)[0]
              for x in (packed.adj1, packed.labels1, packed.mask1, packed.seg1,
                        packed.adj2, packed.labels2, packed.mask2, packed.seg2,
                        packed.pair_mask)]
    return fn(params, *arrays)[:t]


def pair_score_sparse_sharded(params, packed, *, mesh: Mesh,
                              interpret: bool | None = None) -> jax.Array:
    """Standalone sharded equivalent of `pair_score_sparse`."""
    from repro.core.batching import packed_pair_edges

    edges = packed.edges
    if edges is None:
        edges = packed_pair_edges(packed,
                                  packed_edge_budget(packed.node_budget))
    t = packed.mask1.shape[0]
    target, tile_block = sharded_tile_plan(t, packed.node_budget,
                                           mesh.devices.size, sparse=True)
    fn, _ = _sharded_builder_cached(mesh, packed.node_budget,
                                    True, tile_block, interpret)
    e1, e2 = edges.edges1, edges.edges2
    o1, o2 = edges.overflow1, edges.overflow2
    arrays = [_pad_batch(x, target)[0]
              for x in (e1.senders, e1.weights,
                        o1.senders, o1.receivers, o1.weights,
                        packed.labels1, packed.mask1, packed.seg1,
                        e2.senders, e2.weights,
                        o2.senders, o2.receivers, o2.weights,
                        packed.labels2, packed.mask2, packed.seg2,
                        packed.pair_mask)]
    return fn(params, *arrays)[:t]
