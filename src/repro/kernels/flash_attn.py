"""FlashAttention Pallas TPU kernel — GQA, causal, sliding-window, softcap.

The LM-substrate compute hot-spot. Online-softmax accumulation keeps the
(bq x bkv) score tile, running max/denominator, and the output accumulator in
VMEM across the sequential kv-block grid dimension — the same "intermediates
never spill" discipline the paper applies to GCN stages (DESIGN.md §2).

Grid: (batch, q_heads, q_blocks, kv_blocks); the kv dimension is 'arbitrary'
(sequential) so scratch carries across it; the rest are 'parallel'. GQA is
expressed in the K/V BlockSpec index maps (q-head -> kv-head), so no repeated
KV materialization ever happens.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import compiler_params, should_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int | None,
            softcap: float | None, bq: int, bkv: int, kv_blocks: int):
    ikv = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kv_pos = ikv * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)

    def _block_needed():
        if not causal and window is None:
            return True
        ok = True
        if causal:  # any q >= first kv of the block
            ok = jnp.logical_and(ok, (iq + 1) * bq - 1 >= ikv * bkv)
        if window is not None:  # any kv within window of the last q row
            ok = jnp.logical_and(ok, (ikv + 1) * bkv - 1 > iq * bq - window)
        return ok

    @pl.when(_block_needed())
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # [bq, d]
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # [bkv, d]
        v = v_ref[0, :, 0, :].astype(jnp.float32)          # [bkv, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kv_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, q_pos - kv_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)                         # kill -inf rows
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ikv == kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)                  # fully-masked rows -> 0
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_kv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    softcap: float | None = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q [B,T,H,D], k/v [B,S,KV,D] with H % KV == 0 -> [B,T,H,D]."""
    if interpret is None:
        interpret = should_interpret()
    b, t, h, d = q.shape
    _, s, kv, _ = k.shape
    assert h % kv == 0, (h, kv)
    group = h // kv
    bq, bkv = min(block_q, t), min(block_kv, s)
    assert t % bq == 0 and s % bkv == 0, (t, bq, s, bkv)
    grid = (b, h, t // bq, s // bkv)
    scale = d ** -0.5

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        bq=bq, bkv=bkv, kv_blocks=s // bkv)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, d), lambda b_, h_, iq, ikv: (b_, iq, h_, 0)),
            pl.BlockSpec((1, bkv, 1, d),
                         lambda b_, h_, iq, ikv: (b_, ikv, h_ // group, 0)),
            pl.BlockSpec((1, bkv, 1, d),
                         lambda b_, h_, iq, ikv: (b_, ikv, h_ // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, d),
                               lambda b_, h_, iq, ikv: (b_, iq, h_, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max m
            pltpu.VMEM((bq,), jnp.float32),      # running denom l
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        compiler_params=compiler_params(
            ("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
