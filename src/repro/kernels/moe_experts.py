"""Fused MoE expert-FFN Pallas TPU kernel: both GEMMs + SwiGLU per capacity
tile, hidden activations never leave VMEM.

Motivation (EXPERIMENTS.md §Perf, granite hillclimb): the XLA lowering of the
expert computation materializes ~6 dispatch-sized [E,C,D] buffers per layer
in HBM (gather result, gate/up halves, hidden, y_buf, + backward mirrors) —
at top-8/cf1.25 that is ~10.25x the token bytes each. This kernel is the
SPA-GCN fusion discipline applied to MoE: one grid program handles one
(expert, capacity-block) tile, reads x once, streams W_in/W_out tiles, and
writes y once — HBM traffic drops from ~6 to ~2 dispatch-buffers per layer.

Grid: (E, C/BC). Weights for expert e are indexed by the grid, so each
program sees only its expert's [D, 2F] / [F, D] — VMEM per program:
BC*D + D*2F_tile + BC*2F + F_tile*D + BC*D; with BC=128, D<=2048, F tiled to
512 that is ~6 MB, comfortably inside the ~128 MB VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import compiler_params, should_interpret


def _kernel(x_ref, win_ref, wout_ref, y_ref):
    x = x_ref[0].astype(jnp.float32)                  # [BC, D]
    win = win_ref[0].astype(jnp.float32)              # [D, 2F]
    wout = wout_ref[0].astype(jnp.float32)            # [F, D]
    h = jnp.dot(x, win, preferred_element_type=jnp.float32)   # [BC, 2F] VMEM
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up                        # [BC, F] VMEM only
    y = jnp.dot(h, wout, preferred_element_type=jnp.float32)  # [BC, D]
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def moe_expert_ffn(x_dispatch: jax.Array, w_in: jax.Array, w_out: jax.Array,
                   *, block_c: int = 128,
                   interpret: bool | None = None) -> jax.Array:
    """x_dispatch [E, C, D], w_in [E, D, 2F], w_out [E, F, D] -> [E, C, D].
    C must be a multiple of block_c (ops-side padding)."""
    if interpret is None:
        interpret = should_interpret()
    e, c, d = x_dispatch.shape
    f = w_out.shape[1]
    assert c % block_c == 0, (c, block_c)
    grid = (e, c // block_c)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, d), lambda ei, ci: (ei, ci, 0)),
            pl.BlockSpec((1, d, 2 * f), lambda ei, ci: (ei, 0, 0)),
            pl.BlockSpec((1, f, d), lambda ei, ci: (ei, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, d), lambda ei, ci: (ei, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, d), x_dispatch.dtype),
        compiler_params=compiler_params(("parallel", "parallel")),
        interpret=interpret,
    )(x_dispatch, w_in, w_out)


def moe_expert_ffn_ref(x_dispatch: jax.Array, w_in: jax.Array,
                       w_out: jax.Array) -> jax.Array:
    """Pure-jnp oracle."""
    h = jnp.einsum("ecd,edf->ecf", x_dispatch.astype(jnp.float32),
                   w_in.astype(jnp.float32))
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    y = jnp.einsum("ecf,efd->ecd", h, w_out.astype(jnp.float32))
    return y.astype(x_dispatch.dtype)
