"""Edge-centric packed-sparse (packed-CSR) pair-score megakernel
(DESIGN.md §9).

Same single-pass dataflow and tile format as `packed_pair.py` — FFD-packed
segment-ID tiles, segment Att pooling, NTN/FCN on tile-aligned pair slots,
nothing but final scores touching HBM — but the GCN aggregation is
*edge-centric*: instead of multiplying the dense `[NB, NB]` block-diagonal
adjacency through the MXU (>95% structural zeros at AIDS-like degree ~2),
the kernel streams the tile's A' non-zeros in the packed-CSR layout of
`core.batching.packed_pair_edges` and accumulates messages in-kernel
(`gcn_layers_edge_block`). This is the TPU realization of the paper's
central sparsity claim (§3.2.2: "read only the non-zero A' elements"),
LW-GCN's compressed row format, and Accel-GCN's degree-aware workload
split:

  * per layer, aggregation costs O(NB·D·F) gathered messages (D = per-node
    neighbor budget from the `ops.packed_edge_budget` ladder — 4 at
    AIDS-like degree ~2.1) instead of O(NB²·F) MACs — ~14x fewer
    aggregation FLOPs at the default budgets (benchmarks/sparse.py reports
    the measured ratio); the regular ELLPACK planes reduce with
    statically-unrolled contiguous adds (no scatter), only the heavy-tail
    overflow edges take a small one-hot contraction;
  * the first layer keeps PR 2's one-hot elimination: int32 labels ride
    into the kernel and the widest H·W becomes a W1 row gather
    (`gcn_layers_edge_block(labels=...)`), so no [N, n_labels] one-hot is
    ever materialized;
  * the adjacency block and the in-kernel normalization disappear
    entirely: edge weights are the host-precomputed normalized A' entries
    (block-diagonal by construction, exact-zero pad slots), the FPGA
    host-preprocessing role; HBM traffic per tile side drops from NB²
    adjacency floats to ~2·(NB·D + E_ov) edge words (~8x at the default
    budgets).

Pad edge slots point at node 0 with zero weight and are neutral without any
branch; pad node slots carry mask 0 / segment 0; pad pair slots are zeroed
by `pair_mask` on the way out — the same exact-zero discipline as §8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (compiler_params, flatten_layer_params,
                                  gcn_layers_edge_block, leading_block_spec,
                                  ntn_fcn_block, read_layer_refs,
                                  replicated_spec, segment_att_pool_block,
                                  should_interpret)


def _kernel(n_gcn_layers,
            nbr1_ref, nw1_ref, ovs1_ref, ovr1_ref, ovw1_ref,
            lab1_ref, mask1_ref, seg1_ref,
            nbr2_ref, nw2_ref, ovs2_ref, ovr2_ref, ovw2_ref,
            lab2_ref, mask2_ref, seg2_ref,
            pmask_ref, *refs):
    out_ref, refs = refs[-1], refs[:-1]
    gcn_refs, refs = refs[:2 * n_gcn_layers], refs[2 * n_gcn_layers:]
    watt_ref, wt_ref, vt_ref, ntn_b_ref = refs[:4]
    fcn_refs = refs[4:]
    tb = mask1_ref.shape[0]
    p = pmask_ref.shape[-1]

    # Stack lhs/rhs tiles into one [2*TB, ...] block (engine reuse ->
    # batching, DESIGN.md §2): one GCN stack and Att stage serve both sides.
    cat = lambda a, b: jnp.concatenate([a[...], b[...]], 0)
    nbr = cat(nbr1_ref, nbr2_ref)
    nw = cat(nw1_ref, nw2_ref).astype(jnp.float32)
    ovs = cat(ovs1_ref, ovs2_ref)
    ovr = cat(ovr1_ref, ovr2_ref)
    ovw = cat(ovw1_ref, ovw2_ref).astype(jnp.float32)
    labels = cat(lab1_ref, lab2_ref)
    mask = cat(mask1_ref, mask2_ref).astype(jnp.float32)
    seg = cat(seg1_ref, seg2_ref)

    # No normalization stage: the edge weights already hold A' non-zeros.
    h = gcn_layers_edge_block(nbr, nw, ovs, ovr, ovw, None, mask,
                              read_layer_refs(gcn_refs),
                              labels=labels)                 # [2*TB, NB, F]
    hg = segment_att_pool_block(h, mask, seg, watt_ref[...], p)  # [2*TB, P, F]
    f = hg.shape[-1]
    scores = ntn_fcn_block(hg[:tb].reshape(tb * p, f),
                           hg[tb:].reshape(tb * p, f),
                           wt_ref[...], vt_ref[...], ntn_b_ref[...],
                           read_layer_refs(fcn_refs))        # [TB*P, 1]
    out_ref[...] = (scores.reshape(tb, p)
                    * pmask_ref[...].astype(jnp.float32)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_block", "interpret"))
def sparse_pair_score(nbr1: jax.Array, nbr_w1: jax.Array,
                      ov_snd1: jax.Array, ov_rcv1: jax.Array,
                      ov_w1: jax.Array, labels1: jax.Array,
                      mask1: jax.Array, seg1: jax.Array,
                      nbr2: jax.Array, nbr_w2: jax.Array,
                      ov_snd2: jax.Array, ov_rcv2: jax.Array,
                      ov_w2: jax.Array, labels2: jax.Array,
                      mask2: jax.Array, seg2: jax.Array,
                      pair_mask: jax.Array,
                      gcn_params, att_w: jax.Array, ntn_params, fcn_params, *,
                      tile_block: int = 4,
                      interpret: bool | None = None) -> jax.Array:
    """Packed tiles in packed-CSR edge form (pack_pairs(with_edges=True)
    layout) -> [T, P] pair-slot scores in one pallas_call. T must be a
    multiple of tile_block (ops.py pads; pad tiles have all-zero
    masks/weights and pair_mask zeroes their slots)."""
    if interpret is None:
        interpret = should_interpret()
    t, nb = mask1.shape
    assert t % tile_block == 0, (t, tile_block)
    e = nbr1.shape[-1]
    e_ov = ov_snd1.shape[-1]
    p = pair_mask.shape[-1]
    f = gcn_params[-1]["w"].shape[1]
    k = ntn_params["b"].shape[0]
    # Host-side pre-transposes (same layouts as packed_pair.py): W [K,F,F]
    # -> [F, K*F], V [K,2F] -> [2F, K] so the kernel sees pure matmuls.
    wt = jnp.transpose(ntn_params["w"], (1, 0, 2)).reshape(f, k * f)
    vt = ntn_params["v"].T
    weights = (flatten_layer_params(gcn_params)
               + [att_w, wt, vt, ntn_params["b"]]
               + flatten_layer_params(fcn_params))

    def blk(shape):
        return leading_block_spec((tile_block,) + shape)

    side = [blk((e,)), blk((e,)), blk((e_ov,)), blk((e_ov,)), blk((e_ov,)),
            blk((nb,)), blk((nb,)), blk((nb,))]
    out = pl.pallas_call(
        functools.partial(_kernel, len(gcn_params)),
        grid=(t // tile_block,),
        in_specs=side + side + [blk((p,))]
                 + [replicated_spec(a) for a in weights],
        out_specs=blk((p,)),
        out_shape=jax.ShapeDtypeStruct((t, p), mask1.dtype),
        compiler_params=compiler_params(("parallel",)),
        interpret=interpret,
    )(nbr1, nbr_w1, ov_snd1, ov_rcv1, ov_w1, labels1, mask1, seg1,
      nbr2, nbr_w2, ov_snd2, ov_rcv2, ov_w2, labels2, mask2, seg2, pair_mask,
      *weights)
    return out
