"""Pure-jnp oracles for every Pallas kernel in this package.

Each `*_ref` mirrors its kernel's semantics exactly (same masking, same
normalization) using only jax.numpy — these are the ground truth for the
per-kernel allclose sweeps in tests/test_kernels.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -1e30


def fused_gcn_att_ref(adj_norm: Array, feats: Array, mask: Array,
                      gcn_params, att_w: Array) -> Array:
    """Oracle for kernels/fused_gcn.py: 3 GCN layers + att pooling.
    Takes the *normalized* adjacency (kernel parity)."""
    h = feats.astype(jnp.float32)
    for p in gcn_params:
        hw = jnp.einsum("bnf,fg->bng", h, p["w"].astype(jnp.float32)) + p["b"]
        h = jnp.einsum("bnm,bmg->bng", adj_norm.astype(jnp.float32), hw)
        h = jax.nn.relu(h) * mask[..., None]
    n_valid = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1.0)
    mean_h = jnp.sum(h * mask[..., None], axis=-2) / n_valid
    c = jnp.tanh(mean_h @ att_w.astype(jnp.float32))
    a = jax.nn.sigmoid(jnp.einsum("bnf,bf->bn", h, c)) * mask
    return jnp.einsum("bn,bnf->bf", a, h).astype(feats.dtype)


def simgnn_head_ref(hg1: Array, hg2: Array, ntn_params, fcn_params) -> Array:
    """Oracle for kernels/simgnn_head.py: NTN + FCN -> [B] scores."""
    h1 = hg1.astype(jnp.float32)
    h2 = hg2.astype(jnp.float32)
    bilinear = jnp.einsum("bf,kfg,bg->bk", h1,
                          ntn_params["w"].astype(jnp.float32), h2)
    cat = jnp.concatenate([h1, h2], axis=-1)
    linear = jnp.einsum("bf,kf->bk", cat, ntn_params["v"].astype(jnp.float32))
    s = jax.nn.relu(bilinear + linear + ntn_params["b"])
    for i, p in enumerate(fcn_params):
        s = s @ p["w"].astype(jnp.float32) + p["b"]
        if i + 1 < len(fcn_params):
            s = jax.nn.relu(s)
    return jax.nn.sigmoid(s[..., 0]).astype(hg1.dtype)


def flash_attention_ref(q: Array, k: Array, v: Array, *,
                        causal: bool = True, window: int | None = None,
                        softcap: float | None = None) -> Array:
    """Oracle for kernels/flash_attn.py. q [B,T,H,D], k/v [B,S,KV,D]."""
    b, t, h, d = q.shape
    _, s_len, kv, _ = k.shape
    group = h // kv
    k = jnp.repeat(k, group, axis=2)
    v = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(t)[:, None]
    kv_pos = jnp.arange(s_len)[None, :]
    mask = jnp.ones((t, s_len), bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window is not None:
        mask &= (q_pos - kv_pos) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    return jnp.einsum("bhts,bshd->bthd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def wkv6_ref(r: Array, k: Array, v: Array, w: Array, u: Array) -> Array:
    """Oracle for kernels/wkv6.py — direct sequential recurrence."""
    b, t, h, kd = r.shape
    vd = v.shape[-1]

    def head_scan(r_h, k_h, v_h, w_h, u_h):     # [T,K],[T,K],[T,V],[T,K],[K]
        def step(s, inp):
            rt, kt, vt, wt = inp
            o = rt @ s + jnp.sum(rt * u_h * kt) * vt
            s = wt[:, None] * s + kt[:, None] * vt[None, :]
            return s, o
        s0 = jnp.zeros((kd, vd), jnp.float32)
        _, o = jax.lax.scan(step, s0, (r_h, k_h, v_h, w_h))
        return o

    f32 = lambda x: x.astype(jnp.float32)
    over_heads = jax.vmap(head_scan, in_axes=(1, 1, 1, 1, 0), out_axes=1)
    over_batch = jax.vmap(over_heads, in_axes=(0, 0, 0, 0, None))
    out = over_batch(f32(r), f32(k), f32(v), f32(w), f32(u))
    return out.astype(r.dtype)
