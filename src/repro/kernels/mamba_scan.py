"""Selective-SSM (Mamba) recurrence Pallas TPU kernel.

The §Perf cell-B conclusion made concrete: the XLA lowering of the selective
scan pays per-timestep HBM round trips for the [BD, N] state and the
discretized inputs; this kernel keeps the state in VMEM scratch across the
sequential time-block grid dimension and computes the ZOH discretization
in-register per step — HBM traffic collapses to one read of (dt, x, B, C)
and one write of y.

Per (batch, channel-block) program, state h [BD, N]:
    a_bar_t = exp(dt_t * A)            (per-channel, in-register)
    h       = a_bar_t * h + (dt_t * x_t) * B_t
    y_t     = h . C_t + D * x_t

Grid: (B, Din/BD, T/BT); time is 'arbitrary' (sequential), the rest
parallel — the same structure as kernels/wkv6.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import compiler_params, should_interpret


def _kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, d_ref, y_ref, state_ref, *,
            bt: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    dt = dt_ref[0].astype(jnp.float32)          # [BT, BD]
    x = x_ref[0].astype(jnp.float32)            # [BT, BD]
    bm = b_ref[0].astype(jnp.float32)           # [BT, N]
    cm = c_ref[0].astype(jnp.float32)           # [BT, N]
    a = a_ref[...].astype(jnp.float32)          # [BD, N]
    d = d_ref[...].astype(jnp.float32)          # [BD]

    def step(t, carry):
        h, ys = carry                            # h [BD, N]
        a_bar = jnp.exp(dt[t][:, None] * a)      # in-register discretization
        h = a_bar * h + (dt[t] * x[t])[:, None] * bm[t][None, :]
        y = jnp.sum(h * cm[t][None, :], axis=-1) + d * x[t]
        return h, ys.at[t].set(y)

    h0 = state_ref[...]
    ys0 = jnp.zeros((bt, dt.shape[-1]), jnp.float32)
    h_final, ys = jax.lax.fori_loop(0, bt, step, (h0, ys0))
    state_ref[...] = h_final
    y_ref[0] = ys.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_d", "interpret"))
def mamba_selective_scan(dt: jax.Array, x: jax.Array, b: jax.Array,
                         c: jax.Array, a: jax.Array, d: jax.Array, *,
                         block_t: int = 128, block_d: int = 512,
                         interpret: bool | None = None) -> jax.Array:
    """dt/x [B,T,Din], b/c [B,T,N], a [Din,N] (negative), d [Din]
    -> y [B,T,Din]."""
    if interpret is None:
        interpret = should_interpret()
    bsz, t, din = x.shape
    n = a.shape[-1]
    bt = min(block_t, t)
    bd = min(block_d, din)
    assert t % bt == 0 and din % bd == 0, (t, bt, din, bd)
    grid = (bsz, din // bd, t // bt)

    return pl.pallas_call(
        functools.partial(_kernel, bt=bt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda b_, di, it: (b_, it, di)),  # dt
            pl.BlockSpec((1, bt, bd), lambda b_, di, it: (b_, it, di)),  # x
            pl.BlockSpec((1, bt, n), lambda b_, di, it: (b_, it, 0)),    # B
            pl.BlockSpec((1, bt, n), lambda b_, di, it: (b_, it, 0)),    # C
            pl.BlockSpec((bd, n), lambda b_, di, it: (di, 0)),           # A
            pl.BlockSpec((bd,), lambda b_, di, it: (di,)),               # D
        ],
        out_specs=pl.BlockSpec((1, bt, bd), lambda b_, di, it: (b_, it, di)),
        out_shape=jax.ShapeDtypeStruct((bsz, t, din), x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        compiler_params=compiler_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(dt, x, b, c, a, d)


def mamba_selective_scan_ref(dt, x, b, c, a, d):
    """Pure-jnp oracle — same math as models/mamba.py's scan."""
    def step(h, inp):
        dt_t, x_t, b_t, c_t = inp               # [B,Din],[B,Din],[B,N],[B,N]
        a_bar = jnp.exp(dt_t[..., None] * a)
        h = a_bar * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t) + d * x_t
        return h, y

    f32 = lambda z: z.astype(jnp.float32)
    bsz, t, din = x.shape
    h0 = jnp.zeros((bsz, din, a.shape[-1]), jnp.float32)
    xs = (f32(dt).transpose(1, 0, 2), f32(x).transpose(1, 0, 2),
          f32(b).transpose(1, 0, 2), f32(c).transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2).astype(x.dtype)
