"""Shared Pallas utilities: interpret-mode policy, compiler params, and the
SimGNN layer-loop / block-spec helpers used by all three SimGNN kernels.

All kernels in this package target TPU (pl.pallas_call + BlockSpec VMEM
tiling). On this CPU-only container they are *validated* with interpret=True,
which executes the kernel body with jnp semantics. `should_interpret()`
selects interpret mode automatically off-TPU so the same ops.py wrappers run
everywhere; on a real TPU fleet the flag resolves to False and Mosaic compiles
the kernels.

The `*_block` functions below are the in-VMEM compute bodies shared by
`fused_gcn.py`, `simgnn_head.py`, and the end-to-end megakernel
`fused_pair.py` (DESIGN.md §7): they take *values* already read from refs,
are variadic over layer count, and accumulate in fp32 regardless of the
input dtype (bf16 in / fp32 accumulate / out-dtype store).

The gather/segment aggregation bodies additionally carry `jax.custom_vjp`
rules (DESIGN.md §11): the backward pass of an edge aggregation is the SAME
aggregation with the sender and receiver planes swapped (A' is symmetric in
structure; its transpose-multiply is another edge sweep), so the packed-CSR
/ COO layouts built for the forward pass serve the backward pass unchanged
— no transposed layout is ever materialized. Integer index planes get
`float0` cotangents (indices have no tangent space), which also keeps
autodiff from tracing through the gathers. These rules are what makes the
packed scoring paths differentiable end-to-end (`kernels/grad.py`,
`core.engine.ScoringEngine.loss_and_grad`).
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # renamed across jax versions
    from jax.experimental.pallas import tpu as pltpu
    CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None)
except ImportError:  # pragma: no cover
    pltpu = None
    CompilerParams = None


def should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def compiler_params(dimension_semantics: tuple[str, ...]):
    """Grid dimension semantics for Mosaic ('parallel' dims may be reordered;
    'arbitrary' dims run sequentially so VMEM scratch carries across steps).
    Returns None in interpret mode (ignored there)."""
    if should_interpret() or CompilerParams is None:
        return None
    return CompilerParams(dimension_semantics=dimension_semantics)


# ---------------------------------------------------------------- block specs

def leading_block_spec(block_shape: tuple[int, ...]) -> pl.BlockSpec:
    """BlockSpec tiling only the leading (grid) dimension: program i sees
    rows [i*block, (i+1)*block) and the full extent of every other axis."""
    nd = len(block_shape)
    return pl.BlockSpec(block_shape, lambda i: (i,) + (0,) * (nd - 1))


def replicated_spec(a: jax.Array) -> pl.BlockSpec:
    """BlockSpec broadcasting a whole (small) array to every program — used
    for weights, which are read from HBM once per block (the paper's 'read
    each element only once' principle)."""
    return pl.BlockSpec(a.shape, lambda i: (0,) * a.ndim)


# ----------------------------------------------------- variadic layer plumbing

def flatten_layer_params(layers) -> list[jax.Array]:
    """[{'w','b'}, ...] -> [w1, b1, w2, b2, ...] for variadic kernel args."""
    flat = []
    for p in layers:
        flat += [p["w"], p["b"]]
    return flat


def read_layer_refs(refs) -> list[tuple[jax.Array, jax.Array]]:
    """Inverse of `flatten_layer_params` inside a kernel: a flat tuple of
    (w, b) refs -> list of (w, b) *values*."""
    assert len(refs) % 2 == 0, len(refs)
    return [(refs[2 * i][...], refs[2 * i + 1][...])
            for i in range(len(refs) // 2)]


# ------------------------------------------------------------- VJP plumbing

def _int_zeros(x: jax.Array) -> np.ndarray:
    """float0 cotangent for an integer index plane: indices have no tangent
    space, and returning float0 (rather than float zeros) is what custom_vjp
    requires for int-dtype primals."""
    return np.zeros(np.shape(x), jax.dtypes.float0)


@jax.custom_vjp
def label_gather(w: jax.Array, labels: jax.Array) -> jax.Array:
    """First-layer one-hot elimination as a differentiable gather:
    `one_hot(labels) @ W == W[labels]` exactly, so the forward pass is a row
    gather (no [M, n_labels] one-hot ever exists). The custom backward keeps
    the same discipline: dW = one_hot(labels)^T @ g is ONE MXU-shaped
    [n_labels, M] x [M, F] contraction instead of autodiff's per-row
    scatter-add. w [L, F], labels [M] int32 -> [M, F] fp32."""
    return jnp.take(w.astype(jnp.float32), labels, axis=0)


def _label_gather_fwd(w, labels):
    return label_gather(w, labels), (w, labels)


def _label_gather_bwd(res, g):
    w, labels = res
    m = labels.shape[0]
    l_ids = jax.lax.broadcasted_iota(jnp.int32, (w.shape[0], m), 0)
    onehot_t = (labels[None, :] == l_ids).astype(jnp.float32)   # [L, M]
    dw = jnp.dot(onehot_t, g.astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    return dw.astype(w.dtype), _int_zeros(labels)


label_gather.defvjp(_label_gather_fwd, _label_gather_bwd)


# ------------------------------------------------------------ in-VMEM bodies

def normalize_adjacency_block(adj: jax.Array, mask: jax.Array) -> jax.Array:
    """In-kernel A' = D^-1/2 (A + I) D^-1/2 on a [GB, N, N] block.

    Same math as core.gcn.normalized_adjacency (parity-tested); the identity
    is built from broadcasted_iota so Mosaic can lower it. fp32 in/out.
    """
    _, n, _ = adj.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    eye = (rows == cols).astype(adj.dtype)
    m = mask[:, :, None] * mask[:, None, :]
    a_tilde = (adj + eye[None]) * m
    deg = jnp.sum(a_tilde, axis=-1)
    inv_sqrt = jnp.where(deg > 0, jax.lax.rsqrt(jnp.maximum(deg, 1e-12)), 0.0)
    return a_tilde * inv_sqrt[:, :, None] * inv_sqrt[:, None, :]


def gcn_layers_block(adj_norm: jax.Array, h: jax.Array | None,
                     mask: jax.Array, layer_wb, *,
                     labels: jax.Array | None = None) -> jax.Array:
    """Variadic GCN stack on one graph block, all in VMEM.

    adj_norm [GB, N, N], h [GB, N, F0], mask [GB, N] (fp32) -> [GB, N, F_last].
    layer_wb: list of (w, b) values, any length (SimGNNConfig.gcn_dims).

    With `labels` [GB, N] int32, the first layer's H·W is replaced by a row
    gather of W1 (one_hot(labels) @ W1 == W1[labels] exactly, since one-hot
    matmul rows sum a single non-zero product): no [N, n_labels] one-hot is
    ever materialized or multiplied, cutting the first layer's feature HBM
    traffic ~n_labels-fold and skipping its MXU pass. `h` may be None then.
    """
    gb, n, _ = adj_norm.shape
    for li, (w, b) in enumerate(layer_wb):
        if li == 0 and labels is not None:
            # Structural feature sparsity: one-hot first layer as a gather
            # (custom VJP: dW1 is one one-hot contraction, no scatter).
            hw = label_gather(w, labels.reshape(gb * n))
        else:
            # Feature Transformation (paper MULT+ACC): one 2D MXU matmul for
            # the whole graph block — (GB*N, Fin) @ (Fin, Fout).
            hw = jnp.dot(h.reshape(gb * n, -1), w.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        hw = (hw + b.astype(jnp.float32)).reshape(gb, n, -1)
        # Aggregation (paper ACG): one batched contraction [GB,N,N]@[GB,N,F]
        # — a single MXU-shaped op instead of a per-graph unrolled dot loop.
        h = jax.lax.dot_general(adj_norm, hw, (((2,), (1,)), ((0,), (0,))),
                                preferred_element_type=jnp.float32)
        # ReLU + mask: the paper's max(0,.) unit at the ACG output.
        h = jnp.maximum(h, 0.0) * mask[..., None]
    return h


def _edge_aggregate(senders, receivers, weights, hw):
    """Raw segment-sum edge aggregation body (no VJP rule — see the public
    `edge_aggregate_block` wrapper)."""
    gb, n, f = hw.shape
    e = senders.shape[-1]
    gathered = jnp.take_along_axis(hw, senders[..., None], axis=1)  # [GB,E,F]
    msgs = (gathered * weights[..., None].astype(jnp.float32)).reshape(gb * e, f)
    offs = jnp.arange(gb, dtype=jnp.int32)[:, None] * n              # [GB,1]
    flat = jax.ops.segment_sum(msgs, (receivers + offs).reshape(gb * e),
                               num_segments=gb * n)
    return flat.reshape(gb, n, f)


def _edge_weight_cotangent(senders, receivers, hw, g):
    """dL/dw for one edge list: per edge, <g[receiver], hw[sender]> — the
    same two gathers as the forward pass, reduced over F. [GB, E] fp32."""
    g_r = jnp.take_along_axis(g.astype(jnp.float32), receivers[..., None],
                              axis=1)
    h_s = jnp.take_along_axis(hw.astype(jnp.float32), senders[..., None],
                              axis=1)
    return jnp.sum(g_r * h_s, axis=-1)


@jax.custom_vjp
def edge_aggregate_block(senders: jax.Array, receivers: jax.Array,
                         weights: jax.Array, hw: jax.Array) -> jax.Array:
    """In-kernel segment-sum aggregation from a tile-local edge list:
    out[g, r, :] = sum over edges e with receivers[g, e] == r of
    weights[g, e] * hw[g, senders[g, e], :].

    senders/receivers [GB, E] int32, weights [GB, E] (A' non-zeros, pad
    slots exact zero), hw [GB, N, F] -> [GB, N, F]. This is the edge-centric
    replacement for the dense `adj_norm @ hw` contraction: O(E·F) messages
    instead of O(N²·F) MACs, the paper's 'read only the non-zero A'
    elements' (§3.2.2) inside the kernel. Pad edges gather row `senders=0`,
    multiply by an exact-zero weight and land on receiver 0 — neutral by
    construction, no masking branch needed. Same gather + segment-sum idiom
    as `core.batching.edge_aggregate` (parity-tested), but flattened to ONE
    segment reduction over [GB*E] with per-block receiver offsets — one
    large scatter schedules better than GB small ones on every backend.

    Custom VJP (DESIGN.md §11): the cotangent of `hw` is the SAME edge sweep
    with sender/receiver planes swapped (transpose-aggregation), so the
    backward pass reuses the forward layout; pad edges stay exactly neutral
    in both directions (their weight is an exact zero factor of every
    product).
    """
    return _edge_aggregate(senders, receivers, weights, hw)


def _edge_aggregate_fwd(senders, receivers, weights, hw):
    return _edge_aggregate(senders, receivers, weights, hw), (
        senders, receivers, weights, hw)


def _edge_aggregate_bwd(res, g):
    senders, receivers, weights, hw = res
    d_hw = _edge_aggregate(receivers, senders, weights, g)    # swapped planes
    d_w = _edge_weight_cotangent(senders, receivers, hw, g)
    return (_int_zeros(senders), _int_zeros(receivers),
            d_w.astype(weights.dtype), d_hw.astype(hw.dtype))


edge_aggregate_block.defvjp(_edge_aggregate_fwd, _edge_aggregate_bwd)


def _overflow_aggregate(ov_snd, ov_rcv, ov_w, hw):
    """Raw COO one-hot contraction body (no VJP rule — see the public
    `overflow_aggregate_block` wrapper)."""
    gb, n, f = hw.shape
    e_ov = ov_snd.shape[-1]
    gathered = jnp.take_along_axis(hw, ov_snd[..., None], axis=1)  # [GB,Eo,F]
    msgs = gathered * ov_w[..., None].astype(jnp.float32)
    node_ids = jax.lax.broadcasted_iota(jnp.int32, (gb, n, e_ov), 1)
    scat = (ov_rcv[:, None, :] == node_ids).astype(jnp.float32)    # [GB,N,Eo]
    return jax.lax.dot_general(scat, msgs, (((2,), (1,)), ((0,), (0,))),
                               preferred_element_type=jnp.float32)


@jax.custom_vjp
def overflow_aggregate_block(ov_snd: jax.Array, ov_rcv: jax.Array,
                             ov_w: jax.Array, hw: jax.Array) -> jax.Array:
    """Aggregate the small COO overflow list (in-degree > D spill) as a
    one-hot contraction: out = onehot(receivers)^T @ (w * hw[senders]).
    With E_ov <= ~32 the [N, E_ov] @ [E_ov, F] matmul is a few percent of a
    dense layer and stays MXU-shaped — no scatter anywhere in the kernel.

    Custom VJP (DESIGN.md §11): dL/d(hw) is the same one-hot contraction
    with the sender/receiver roles swapped — a literal argument swap of the
    forward body — so the backward pass stays MXU-shaped too."""
    return _overflow_aggregate(ov_snd, ov_rcv, ov_w, hw)


def _overflow_aggregate_fwd(ov_snd, ov_rcv, ov_w, hw):
    return _overflow_aggregate(ov_snd, ov_rcv, ov_w, hw), (
        ov_snd, ov_rcv, ov_w, hw)


def _overflow_aggregate_bwd(res, g):
    ov_snd, ov_rcv, ov_w, hw = res
    d_hw = _overflow_aggregate(ov_rcv, ov_snd, ov_w, g)       # swapped planes
    d_w = _edge_weight_cotangent(ov_snd, ov_rcv, hw, g)
    return (_int_zeros(ov_snd), _int_zeros(ov_rcv),
            d_w.astype(ov_w.dtype), d_hw.astype(hw.dtype))


overflow_aggregate_block.defvjp(_overflow_aggregate_fwd,
                                _overflow_aggregate_bwd)


def _csr_aggregate(nbr, nbr_w, ov_snd, ov_rcv, ov_w, hw):
    """Raw packed-CSR aggregation body (no VJP rule — see the public
    `csr_aggregate_block` wrapper)."""
    gb, n, f = hw.shape
    d = nbr.shape[-1] // n
    gathered = jnp.take_along_axis(hw, nbr[..., None], axis=1)   # [GB,N*D,F]
    msgs = (gathered * nbr_w[..., None].astype(jnp.float32)).reshape(gb, d,
                                                                     n * f)
    # Plane reduction as D-1 statically-unrolled adds of contiguous
    # [GB, N*F] planes: keeps the reduction a pure vector add chain (a
    # strided axis-reduce defeats vectorization on the interpret path).
    out = msgs[:, 0]
    for k in range(1, d):
        out = out + msgs[:, k]
    return (out.reshape(gb, n, f)
            + _overflow_aggregate(ov_snd, ov_rcv, ov_w, hw))


@jax.custom_vjp
def csr_aggregate_block(nbr: jax.Array, nbr_w: jax.Array,
                        ov_snd: jax.Array, ov_rcv: jax.Array,
                        ov_w: jax.Array, hw: jax.Array) -> jax.Array:
    """Degree-aware packed-CSR aggregation (DESIGN.md §9) — scatter-free.

    nbr/nbr_w [GB, N*D] are ELLPACK neighbor *planes* (slot s holds the
    (s // N)-th in-edge of node s % N), so accumulating a node's neighbors
    is a gather + a sum of D contiguous [N, F] planes — fully vectorizable,
    no scatter at all. The heavy tail (nodes with in-degree > D) arrives as
    the small COO overflow list and takes a one-hot contraction
    (`overflow_aggregate_block`) — Accel-GCN's degree-aware workload split:
    regular rows on the vector path, outlier rows on the matrix path. Pad
    slots carry exact-zero weights.

    Custom VJP (DESIGN.md §11): the backward pass runs over the SAME
    ELLPACK/COO planes. The receiver of ELL slot s is the implicit s % N,
    so gathering the output cotangent by receiver is a free plane tiling;
    the message cotangents then scatter to the *senders* with one flat
    segment-sum (the `edge_aggregate_block` idiom) while the COO tail is
    again a literal sender/receiver swap of the one-hot contraction. No
    transposed edge layout is ever built.
    """
    return _csr_aggregate(nbr, nbr_w, ov_snd, ov_rcv, ov_w, hw)


def _csr_aggregate_fwd(nbr, nbr_w, ov_snd, ov_rcv, ov_w, hw):
    return _csr_aggregate(nbr, nbr_w, ov_snd, ov_rcv, ov_w, hw), (
        nbr, nbr_w, ov_snd, ov_rcv, ov_w, hw)


def _csr_bwd_outputs(res, g32, d_hw):
    """Shared tail of both CSR backward rules: the per-slot weight
    cotangents (orientation-exact regardless of A' symmetry — XLA DCEs
    them when only param grads are requested) and the output tuple.
    Receiver gather is free: ELL slot d*N + r reads g[r] — D plane tiles.
    """
    nbr, nbr_w, ov_snd, ov_rcv, ov_w, hw = res
    d = nbr.shape[-1] // hw.shape[1]
    g_r = jnp.tile(g32, (1, d, 1))                               # [GB,N*D,F]
    h_s = jnp.take_along_axis(hw.astype(jnp.float32), nbr[..., None], axis=1)
    d_nbr_w = jnp.sum(g_r * h_s, axis=-1)                        # [GB, N*D]
    d_ov_w = _edge_weight_cotangent(ov_snd, ov_rcv, hw, g32)
    return (_int_zeros(nbr), d_nbr_w.astype(nbr_w.dtype),
            _int_zeros(ov_snd), _int_zeros(ov_rcv),
            d_ov_w.astype(ov_w.dtype), d_hw.astype(hw.dtype))


def _csr_aggregate_bwd(res, g):
    nbr, nbr_w, ov_snd, ov_rcv, ov_w, hw = res
    gb, n, f = hw.shape
    d = nbr.shape[-1] // n
    g32 = g.astype(jnp.float32)
    # Generic transpose-aggregation: gather the cotangent by the implicit
    # receivers (plane tiling), scatter to senders with one flat
    # segment-sum (the edge_aggregate_block idiom).
    msgs = (jnp.tile(g32, (1, d, 1))
            * nbr_w[..., None].astype(jnp.float32)).reshape(gb * n * d, f)
    offs = jnp.arange(gb, dtype=jnp.int32)[:, None] * n
    d_hw = jax.ops.segment_sum(msgs, (nbr + offs).reshape(gb * n * d),
                               num_segments=gb * n).reshape(gb, n, f)
    d_hw = d_hw + _overflow_aggregate(ov_rcv, ov_snd, ov_w, g32)
    return _csr_bwd_outputs(res, g32, d_hw)


csr_aggregate_block.defvjp(_csr_aggregate_fwd, _csr_aggregate_bwd)


@jax.custom_vjp
def csr_aggregate_block_sym(nbr: jax.Array, nbr_w: jax.Array,
                            ov_snd: jax.Array, ov_rcv: jax.Array,
                            ov_w: jax.Array, hw: jax.Array) -> jax.Array:
    """`csr_aggregate_block` for a structurally SYMMETRIC A' — which every
    normalized adjacency in this codebase is (undirected graphs + self
    loops, and symmetry survives the block-diagonal packing). Identical
    forward; the backward exploits A'^T == A': the `hw` cotangent
    d_hw = A'^T g = A' g is the SAME scatter-free forward aggregation
    applied to the output cotangent — plane adds + the small one-hot
    contraction, zero scatters in the backward pass (the generic rule's
    scatter-by-sender segment-sum disappears). Note the symmetry argument
    only holds for the COMBINED ELL+COO split: a single edge may sit in the
    ELL planes while its mirror spilled to the overflow list, so neither
    part is symmetric alone — the backward therefore re-runs the whole
    combined aggregation, never the parts separately. Per-slot weight
    cotangents keep the generic (orientation-exact) rule.

    `gcn_layers_edge_block` (the GCN stack, where A' is symmetric by
    construction) uses this variant; callers with directed/asymmetric edge
    lists must use `csr_aggregate_block`.
    """
    return _csr_aggregate(nbr, nbr_w, ov_snd, ov_rcv, ov_w, hw)


def _csr_aggregate_sym_bwd(res, g):
    nbr, nbr_w, ov_snd, ov_rcv, ov_w, hw = res
    g32 = g.astype(jnp.float32)
    # A' symmetric: transpose-aggregation IS the forward aggregation on g.
    d_hw = _csr_aggregate(nbr, nbr_w, ov_snd, ov_rcv, ov_w, g32)
    return _csr_bwd_outputs(res, g32, d_hw)


csr_aggregate_block_sym.defvjp(_csr_aggregate_fwd, _csr_aggregate_sym_bwd)


def gcn_layers_edge_block(nbr: jax.Array, nbr_w: jax.Array,
                          ov_snd: jax.Array, ov_rcv: jax.Array,
                          ov_w: jax.Array, h: jax.Array | None,
                          mask: jax.Array, layer_wb, *,
                          labels: jax.Array | None = None) -> jax.Array:
    """Variadic GCN stack whose aggregation runs from the packed-CSR edge
    lists (DESIGN.md §9) — the sparse twin of `gcn_layers_block`.

    The dense path's per-layer `adj_norm @ (H·W)` batched contraction is
    replaced by `csr_aggregate_block`; the feature transform (H·W matmul,
    or PR 2's first-layer W1 label gather when int `labels` are given) and
    the ReLU∘mask epilogue are identical. No adjacency or in-kernel
    normalization at all: the edge weights are the host-precomputed A'
    non-zeros (the FPGA host-preprocessing role, paper §3.2.2), so the
    [GB, N, N] block never exists on-chip.
    """
    gb, n = mask.shape
    for li, (w, b) in enumerate(layer_wb):
        if li == 0 and labels is not None:
            # Structural feature sparsity: one-hot first layer as a gather
            # (custom VJP: dW1 is one one-hot contraction, no scatter).
            hw = label_gather(w, labels.reshape(gb * n))
        else:
            hw = jnp.dot(h.reshape(gb * n, -1), w.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        hw = (hw + b.astype(jnp.float32)).reshape(gb, n, -1)
        # A' is symmetric here by construction (undirected + self loops),
        # so the sym variant's scatter-free transpose-aggregate backward
        # applies (DESIGN.md §11).
        h = csr_aggregate_block_sym(nbr, nbr_w, ov_snd, ov_rcv, ov_w, hw)
        h = jnp.maximum(h, 0.0) * mask[..., None]
    return h


def att_pool_block(h: jax.Array, mask: jax.Array,
                   att_w: jax.Array) -> jax.Array:
    """Att stage (paper §4.2, Eq. 3): h [GB, N, F], mask [GB, N] -> [GB, F]."""
    n_valid = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)   # [GB,1]
    mean_h = jnp.sum(h * mask[..., None], axis=1) / n_valid            # [GB,F]
    c = jnp.tanh(jnp.dot(mean_h, att_w.astype(jnp.float32),
                         preferred_element_type=jnp.float32))          # [GB,F]
    att = jax.nn.sigmoid(jnp.sum(h * c[:, None, :], axis=-1)) * mask   # [GB,N]
    return jnp.sum(att[..., None] * h, axis=1)                         # [GB,F]


def gcn_att_block(adj_norm: jax.Array, h: jax.Array, mask: jax.Array,
                  layer_wb, att_w: jax.Array, *,
                  labels: jax.Array | None = None) -> jax.Array:
    """GCN stack + per-graph Att pooling: [GB, N, F0] -> [GB, F_last]."""
    h = gcn_layers_block(adj_norm, h, mask, layer_wb, labels=labels)
    return att_pool_block(h, mask, att_w)


def segment_onehot(seg: jax.Array, mask: jax.Array,
                   n_segments: int) -> jax.Array:
    """Segment-membership matrix S [GB, P, N] from per-node segment IDs.

    S[g, p, n] = 1 iff node slot n belongs to segment p AND is a real node.
    Built from broadcasted_iota so Mosaic can lower it; pad slots (mask 0)
    are zero in every segment row, so S-contractions give them exact-zero
    contributions without any branch.
    """
    gb, n = seg.shape
    p_ids = jax.lax.broadcasted_iota(jnp.int32, (gb, n_segments, n), 1)
    return (seg[:, None, :] == p_ids).astype(jnp.float32) * mask[:, None, :]


def _seg_att_pool_from_onehot(h, mask, s, att_w):
    """Segment Att pooling given a precomputed segment one-hot S [GB, P, N]
    (the shared body of `segment_att_pool_block`'s forward AND backward)."""
    counts = jnp.maximum(jnp.sum(s, axis=-1, keepdims=True), 1.0)      # [GB,P,1]
    batched = (((2,), (1,)), ((0,), (0,)))
    mean_h = jax.lax.dot_general(s, h, batched,
                                 preferred_element_type=jnp.float32) / counts
    gb, p, f = mean_h.shape
    c = jnp.tanh(jnp.dot(mean_h.reshape(gb * p, f), att_w.astype(jnp.float32),
                         preferred_element_type=jnp.float32)).reshape(gb, p, f)
    # Per-node context = its own segment's c, fetched by one S^T contraction.
    c_node = jax.lax.dot_general(s, c, (((1,), (1,)), ((0,), (0,))),
                                 preferred_element_type=jnp.float32)   # [GB,N,F]
    att = jax.nn.sigmoid(jnp.sum(h * c_node, axis=-1)) * mask          # [GB,N]
    return jax.lax.dot_general(s, att[..., None] * h, batched,
                               preferred_element_type=jnp.float32)     # [GB,P,F]


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def segment_att_pool_block(h: jax.Array, mask: jax.Array, seg: jax.Array,
                           att_w: jax.Array, n_segments: int) -> jax.Array:
    """Att pooling per *segment* of a packed tile (DESIGN.md §8).

    h [GB, N, F], seg [GB, N] int32 in [0, P) -> [GB, P, F] — the per-graph
    leading dim of `att_pool_block` becomes a segment axis: the per-graph
    mean/softmax-sigmoid/sum reductions turn into contractions against the
    segment one-hot S, so all three stay MXU-shaped batched matmuls. Empty
    segments (pad pair slots) yield all-zero embeddings.

    Custom VJP (DESIGN.md §11): the segment one-hot S is built once in the
    forward pass and saved as a residual — the backward differentiates the
    pure-matmul body against the SAME S (matmul transposes are matmuls), so
    the int32 `seg` plane never enters autodiff (float0 cotangent) and the
    iota-compare that builds S is never re-traced. Pad node slots are zero
    rows of S, so their cotangents are exact zeros in both directions.
    """
    s = segment_onehot(seg, mask, n_segments)                          # [GB,P,N]
    return _seg_att_pool_from_onehot(h, mask, s, att_w)


def _segment_att_pool_fwd(h, mask, seg, att_w, n_segments):
    s = segment_onehot(seg, mask, n_segments)
    return _seg_att_pool_from_onehot(h, mask, s, att_w), (
        h, mask, seg, s, att_w)


def _segment_att_pool_bwd(n_segments, res, g):
    h, mask, seg, s, att_w = res
    _, pull = jax.vjp(_seg_att_pool_from_onehot, h, mask, s, att_w)
    d_h, d_mask, d_s, d_att_w = pull(g.astype(jnp.float32))
    # S = onehot(seg) * mask[:, None, :] also carries mask sensitivity:
    # dS/dmask[g, n] is 1 only at row seg[g, n], fetched by one gather.
    d_mask = d_mask + jnp.take_along_axis(d_s, seg[:, None, :],
                                          axis=1)[:, 0, :]
    return (d_h.astype(h.dtype), d_mask.astype(mask.dtype),
            _int_zeros(seg), d_att_w.astype(att_w.dtype))


segment_att_pool_block.defvjp(_segment_att_pool_fwd, _segment_att_pool_bwd)


def ntn_fcn_block(h1: jax.Array, h2: jax.Array, wt: jax.Array, vt: jax.Array,
                  bias: jax.Array, fcn_wb) -> jax.Array:
    """NTN + FCN on one pair block, all in VMEM: h1/h2 [GB, F] -> [GB, 1]
    sigmoid scores. `wt` is W [K,F,F] pre-reshaped to [F, K*F] and `vt` is
    V [K,2F] transposed, so both contractions are pure matmuls."""
    gb, f = h1.shape
    k = bias.shape[0]
    t = jnp.dot(h1, wt.astype(jnp.float32),
                preferred_element_type=jnp.float32)                    # [GB,K*F]
    bilinear = jnp.sum(t.reshape(gb, k, f) * h2[:, None, :], axis=-1)  # [GB,K]
    cat = jnp.concatenate([h1, h2], axis=-1)                           # [GB,2F]
    linear = jnp.dot(cat, vt.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    s = jnp.maximum(bilinear + linear + bias.astype(jnp.float32), 0.0)
    n_fc = len(fcn_wb)
    for i, (w, b) in enumerate(fcn_wb):
        s = jnp.dot(s, w.astype(jnp.float32),
                    preferred_element_type=jnp.float32) + b.astype(jnp.float32)
        if i + 1 < n_fc:
            s = jnp.maximum(s, 0.0)
    return jax.nn.sigmoid(s)                                           # [GB,1]
