"""Shared Pallas utilities: interpret-mode policy, compiler params, and the
SimGNN layer-loop / block-spec helpers used by all three SimGNN kernels.

All kernels in this package target TPU (pl.pallas_call + BlockSpec VMEM
tiling). On this CPU-only container they are *validated* with interpret=True,
which executes the kernel body with jnp semantics. `should_interpret()`
selects interpret mode automatically off-TPU so the same ops.py wrappers run
everywhere; on a real TPU fleet the flag resolves to False and Mosaic compiles
the kernels.

The `*_block` functions below are the in-VMEM compute bodies shared by
`fused_gcn.py`, `simgnn_head.py`, and the end-to-end megakernel
`fused_pair.py` (DESIGN.md §7): they take *values* already read from refs,
are variadic over layer count, and accumulate in fp32 regardless of the
input dtype (bf16 in / fp32 accumulate / out-dtype store).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # renamed across jax versions
    from jax.experimental.pallas import tpu as pltpu
    CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None)
except ImportError:  # pragma: no cover
    pltpu = None
    CompilerParams = None


def should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def compiler_params(dimension_semantics: tuple[str, ...]):
    """Grid dimension semantics for Mosaic ('parallel' dims may be reordered;
    'arbitrary' dims run sequentially so VMEM scratch carries across steps).
    Returns None in interpret mode (ignored there)."""
    if should_interpret() or CompilerParams is None:
        return None
    return CompilerParams(dimension_semantics=dimension_semantics)


# ---------------------------------------------------------------- block specs

def leading_block_spec(block_shape: tuple[int, ...]) -> pl.BlockSpec:
    """BlockSpec tiling only the leading (grid) dimension: program i sees
    rows [i*block, (i+1)*block) and the full extent of every other axis."""
    nd = len(block_shape)
    return pl.BlockSpec(block_shape, lambda i: (i,) + (0,) * (nd - 1))


def replicated_spec(a: jax.Array) -> pl.BlockSpec:
    """BlockSpec broadcasting a whole (small) array to every program — used
    for weights, which are read from HBM once per block (the paper's 'read
    each element only once' principle)."""
    return pl.BlockSpec(a.shape, lambda i: (0,) * a.ndim)


# ----------------------------------------------------- variadic layer plumbing

def flatten_layer_params(layers) -> list[jax.Array]:
    """[{'w','b'}, ...] -> [w1, b1, w2, b2, ...] for variadic kernel args."""
    flat = []
    for p in layers:
        flat += [p["w"], p["b"]]
    return flat


def read_layer_refs(refs) -> list[tuple[jax.Array, jax.Array]]:
    """Inverse of `flatten_layer_params` inside a kernel: a flat tuple of
    (w, b) refs -> list of (w, b) *values*."""
    assert len(refs) % 2 == 0, len(refs)
    return [(refs[2 * i][...], refs[2 * i + 1][...])
            for i in range(len(refs) // 2)]


# ------------------------------------------------------------ in-VMEM bodies

def normalize_adjacency_block(adj: jax.Array, mask: jax.Array) -> jax.Array:
    """In-kernel A' = D^-1/2 (A + I) D^-1/2 on a [GB, N, N] block.

    Same math as core.gcn.normalized_adjacency (parity-tested); the identity
    is built from broadcasted_iota so Mosaic can lower it. fp32 in/out.
    """
    _, n, _ = adj.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    eye = (rows == cols).astype(adj.dtype)
    m = mask[:, :, None] * mask[:, None, :]
    a_tilde = (adj + eye[None]) * m
    deg = jnp.sum(a_tilde, axis=-1)
    inv_sqrt = jnp.where(deg > 0, jax.lax.rsqrt(jnp.maximum(deg, 1e-12)), 0.0)
    return a_tilde * inv_sqrt[:, :, None] * inv_sqrt[:, None, :]


def gcn_layers_block(adj_norm: jax.Array, h: jax.Array | None,
                     mask: jax.Array, layer_wb, *,
                     labels: jax.Array | None = None) -> jax.Array:
    """Variadic GCN stack on one graph block, all in VMEM.

    adj_norm [GB, N, N], h [GB, N, F0], mask [GB, N] (fp32) -> [GB, N, F_last].
    layer_wb: list of (w, b) values, any length (SimGNNConfig.gcn_dims).

    With `labels` [GB, N] int32, the first layer's H·W is replaced by a row
    gather of W1 (one_hot(labels) @ W1 == W1[labels] exactly, since one-hot
    matmul rows sum a single non-zero product): no [N, n_labels] one-hot is
    ever materialized or multiplied, cutting the first layer's feature HBM
    traffic ~n_labels-fold and skipping its MXU pass. `h` may be None then.
    """
    gb, n, _ = adj_norm.shape
    for li, (w, b) in enumerate(layer_wb):
        if li == 0 and labels is not None:
            # Structural feature sparsity: one-hot first layer as a gather.
            hw = jnp.take(w.astype(jnp.float32), labels.reshape(gb * n),
                          axis=0)
        else:
            # Feature Transformation (paper MULT+ACC): one 2D MXU matmul for
            # the whole graph block — (GB*N, Fin) @ (Fin, Fout).
            hw = jnp.dot(h.reshape(gb * n, -1), w.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        hw = (hw + b.astype(jnp.float32)).reshape(gb, n, -1)
        # Aggregation (paper ACG): one batched contraction [GB,N,N]@[GB,N,F]
        # — a single MXU-shaped op instead of a per-graph unrolled dot loop.
        h = jax.lax.dot_general(adj_norm, hw, (((2,), (1,)), ((0,), (0,))),
                                preferred_element_type=jnp.float32)
        # ReLU + mask: the paper's max(0,.) unit at the ACG output.
        h = jnp.maximum(h, 0.0) * mask[..., None]
    return h


def edge_aggregate_block(senders: jax.Array, receivers: jax.Array,
                         weights: jax.Array, hw: jax.Array) -> jax.Array:
    """In-kernel segment-sum aggregation from a tile-local edge list:
    out[g, r, :] = sum over edges e with receivers[g, e] == r of
    weights[g, e] * hw[g, senders[g, e], :].

    senders/receivers [GB, E] int32, weights [GB, E] (A' non-zeros, pad
    slots exact zero), hw [GB, N, F] -> [GB, N, F]. This is the edge-centric
    replacement for the dense `adj_norm @ hw` contraction: O(E·F) messages
    instead of O(N²·F) MACs, the paper's 'read only the non-zero A'
    elements' (§3.2.2) inside the kernel. Pad edges gather row `senders=0`,
    multiply by an exact-zero weight and land on receiver 0 — neutral by
    construction, no masking branch needed. Same gather + segment-sum idiom
    as `core.batching.edge_aggregate` (parity-tested), but flattened to ONE
    segment reduction over [GB*E] with per-block receiver offsets — one
    large scatter schedules better than GB small ones on every backend.
    """
    gb, n, f = hw.shape
    e = senders.shape[-1]
    gathered = jnp.take_along_axis(hw, senders[..., None], axis=1)  # [GB,E,F]
    msgs = (gathered * weights[..., None].astype(jnp.float32)).reshape(gb * e, f)
    offs = jnp.arange(gb, dtype=jnp.int32)[:, None] * n              # [GB,1]
    flat = jax.ops.segment_sum(msgs, (receivers + offs).reshape(gb * e),
                               num_segments=gb * n)
    return flat.reshape(gb, n, f)


def overflow_aggregate_block(ov_snd: jax.Array, ov_rcv: jax.Array,
                             ov_w: jax.Array, hw: jax.Array) -> jax.Array:
    """Aggregate the small COO overflow list (in-degree > D spill) as a
    one-hot contraction: out = onehot(receivers)^T @ (w * hw[senders]).
    With E_ov <= ~32 the [N, E_ov] @ [E_ov, F] matmul is a few percent of a
    dense layer and stays MXU-shaped — no scatter anywhere in the kernel."""
    gb, n, f = hw.shape
    e_ov = ov_snd.shape[-1]
    gathered = jnp.take_along_axis(hw, ov_snd[..., None], axis=1)  # [GB,Eo,F]
    msgs = gathered * ov_w[..., None].astype(jnp.float32)
    node_ids = jax.lax.broadcasted_iota(jnp.int32, (gb, n, e_ov), 1)
    scat = (ov_rcv[:, None, :] == node_ids).astype(jnp.float32)    # [GB,N,Eo]
    return jax.lax.dot_general(scat, msgs, (((2,), (1,)), ((0,), (0,))),
                               preferred_element_type=jnp.float32)


def csr_aggregate_block(nbr: jax.Array, nbr_w: jax.Array,
                        ov_snd: jax.Array, ov_rcv: jax.Array,
                        ov_w: jax.Array, hw: jax.Array) -> jax.Array:
    """Degree-aware packed-CSR aggregation (DESIGN.md §9) — scatter-free.

    nbr/nbr_w [GB, N*D] are ELLPACK neighbor *planes* (slot s holds the
    (s // N)-th in-edge of node s % N), so accumulating a node's neighbors
    is a gather + a sum of D contiguous [N, F] planes — fully vectorizable,
    no scatter at all. The heavy tail (nodes with in-degree > D) arrives as
    the small COO overflow list and takes a one-hot contraction
    (`overflow_aggregate_block`) — Accel-GCN's degree-aware workload split:
    regular rows on the vector path, outlier rows on the matrix path. Pad
    slots carry exact-zero weights.
    """
    gb, n, f = hw.shape
    d = nbr.shape[-1] // n
    gathered = jnp.take_along_axis(hw, nbr[..., None], axis=1)   # [GB,N*D,F]
    msgs = (gathered * nbr_w[..., None].astype(jnp.float32)).reshape(gb, d,
                                                                     n * f)
    # Plane reduction as D-1 statically-unrolled adds of contiguous
    # [GB, N*F] planes: keeps the reduction a pure vector add chain (a
    # strided axis-reduce defeats vectorization on the interpret path).
    out = msgs[:, 0]
    for k in range(1, d):
        out = out + msgs[:, k]
    return (out.reshape(gb, n, f)
            + overflow_aggregate_block(ov_snd, ov_rcv, ov_w, hw))


def gcn_layers_edge_block(nbr: jax.Array, nbr_w: jax.Array,
                          ov_snd: jax.Array, ov_rcv: jax.Array,
                          ov_w: jax.Array, h: jax.Array | None,
                          mask: jax.Array, layer_wb, *,
                          labels: jax.Array | None = None) -> jax.Array:
    """Variadic GCN stack whose aggregation runs from the packed-CSR edge
    lists (DESIGN.md §9) — the sparse twin of `gcn_layers_block`.

    The dense path's per-layer `adj_norm @ (H·W)` batched contraction is
    replaced by `csr_aggregate_block`; the feature transform (H·W matmul,
    or PR 2's first-layer W1 label gather when int `labels` are given) and
    the ReLU∘mask epilogue are identical. No adjacency or in-kernel
    normalization at all: the edge weights are the host-precomputed A'
    non-zeros (the FPGA host-preprocessing role, paper §3.2.2), so the
    [GB, N, N] block never exists on-chip.
    """
    gb, n = mask.shape
    for li, (w, b) in enumerate(layer_wb):
        if li == 0 and labels is not None:
            # Structural feature sparsity: one-hot first layer as a gather.
            hw = jnp.take(w.astype(jnp.float32), labels.reshape(gb * n),
                          axis=0)
        else:
            hw = jnp.dot(h.reshape(gb * n, -1), w.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        hw = (hw + b.astype(jnp.float32)).reshape(gb, n, -1)
        h = csr_aggregate_block(nbr, nbr_w, ov_snd, ov_rcv, ov_w, hw)
        h = jnp.maximum(h, 0.0) * mask[..., None]
    return h


def att_pool_block(h: jax.Array, mask: jax.Array,
                   att_w: jax.Array) -> jax.Array:
    """Att stage (paper §4.2, Eq. 3): h [GB, N, F], mask [GB, N] -> [GB, F]."""
    n_valid = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)   # [GB,1]
    mean_h = jnp.sum(h * mask[..., None], axis=1) / n_valid            # [GB,F]
    c = jnp.tanh(jnp.dot(mean_h, att_w.astype(jnp.float32),
                         preferred_element_type=jnp.float32))          # [GB,F]
    att = jax.nn.sigmoid(jnp.sum(h * c[:, None, :], axis=-1)) * mask   # [GB,N]
    return jnp.sum(att[..., None] * h, axis=1)                         # [GB,F]


def gcn_att_block(adj_norm: jax.Array, h: jax.Array, mask: jax.Array,
                  layer_wb, att_w: jax.Array, *,
                  labels: jax.Array | None = None) -> jax.Array:
    """GCN stack + per-graph Att pooling: [GB, N, F0] -> [GB, F_last]."""
    h = gcn_layers_block(adj_norm, h, mask, layer_wb, labels=labels)
    return att_pool_block(h, mask, att_w)


def segment_onehot(seg: jax.Array, mask: jax.Array,
                   n_segments: int) -> jax.Array:
    """Segment-membership matrix S [GB, P, N] from per-node segment IDs.

    S[g, p, n] = 1 iff node slot n belongs to segment p AND is a real node.
    Built from broadcasted_iota so Mosaic can lower it; pad slots (mask 0)
    are zero in every segment row, so S-contractions give them exact-zero
    contributions without any branch.
    """
    gb, n = seg.shape
    p_ids = jax.lax.broadcasted_iota(jnp.int32, (gb, n_segments, n), 1)
    return (seg[:, None, :] == p_ids).astype(jnp.float32) * mask[:, None, :]


def segment_att_pool_block(h: jax.Array, mask: jax.Array, seg: jax.Array,
                           att_w: jax.Array, n_segments: int) -> jax.Array:
    """Att pooling per *segment* of a packed tile (DESIGN.md §8).

    h [GB, N, F], seg [GB, N] int32 in [0, P) -> [GB, P, F] — the per-graph
    leading dim of `att_pool_block` becomes a segment axis: the per-graph
    mean/softmax-sigmoid/sum reductions turn into contractions against the
    segment one-hot S, so all three stay MXU-shaped batched matmuls. Empty
    segments (pad pair slots) yield all-zero embeddings.
    """
    s = segment_onehot(seg, mask, n_segments)                          # [GB,P,N]
    counts = jnp.maximum(jnp.sum(s, axis=-1, keepdims=True), 1.0)      # [GB,P,1]
    batched = (((2,), (1,)), ((0,), (0,)))
    mean_h = jax.lax.dot_general(s, h, batched,
                                 preferred_element_type=jnp.float32) / counts
    gb, p, f = mean_h.shape
    c = jnp.tanh(jnp.dot(mean_h.reshape(gb * p, f), att_w.astype(jnp.float32),
                         preferred_element_type=jnp.float32)).reshape(gb, p, f)
    # Per-node context = its own segment's c, fetched by one S^T contraction.
    c_node = jax.lax.dot_general(s, c, (((1,), (1,)), ((0,), (0,))),
                                 preferred_element_type=jnp.float32)   # [GB,N,F]
    att = jax.nn.sigmoid(jnp.sum(h * c_node, axis=-1)) * mask          # [GB,N]
    return jax.lax.dot_general(s, att[..., None] * h, batched,
                               preferred_element_type=jnp.float32)     # [GB,P,F]


def ntn_fcn_block(h1: jax.Array, h2: jax.Array, wt: jax.Array, vt: jax.Array,
                  bias: jax.Array, fcn_wb) -> jax.Array:
    """NTN + FCN on one pair block, all in VMEM: h1/h2 [GB, F] -> [GB, 1]
    sigmoid scores. `wt` is W [K,F,F] pre-reshaped to [F, K*F] and `vt` is
    V [K,2F] transposed, so both contractions are pure matmuls."""
    gb, f = h1.shape
    k = bias.shape[0]
    t = jnp.dot(h1, wt.astype(jnp.float32),
                preferred_element_type=jnp.float32)                    # [GB,K*F]
    bilinear = jnp.sum(t.reshape(gb, k, f) * h2[:, None, :], axis=-1)  # [GB,K]
    cat = jnp.concatenate([h1, h2], axis=-1)                           # [GB,2F]
    linear = jnp.dot(cat, vt.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    s = jnp.maximum(bilinear + linear + bias.astype(jnp.float32), 0.0)
    n_fc = len(fcn_wb)
    for i, (w, b) in enumerate(fcn_wb):
        s = jnp.dot(s, w.astype(jnp.float32),
                    preferred_element_type=jnp.float32) + b.astype(jnp.float32)
        if i + 1 < n_fc:
            s = jnp.maximum(s, 0.0)
    return jax.nn.sigmoid(s)                                           # [GB,1]
