"""Shared Pallas utilities: interpret-mode policy and compiler params.

All kernels in this package target TPU (pl.pallas_call + BlockSpec VMEM
tiling). On this CPU-only container they are *validated* with interpret=True,
which executes the kernel body with jnp semantics. `should_interpret()`
selects interpret mode automatically off-TPU so the same ops.py wrappers run
everywhere; on a real TPU fleet the flag resolves to False and Mosaic compiles
the kernels.
"""

from __future__ import annotations

import jax

try:  # renamed across jax versions
    from jax.experimental.pallas import tpu as pltpu
    CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None)
except ImportError:  # pragma: no cover
    pltpu = None
    CompilerParams = None


def should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def compiler_params(dimension_semantics: tuple[str, ...]):
    """Grid dimension semantics for Mosaic ('parallel' dims may be reordered;
    'arbitrary' dims run sequentially so VMEM scratch carries across steps).
    Returns None in interpret mode (ignored there)."""
    if should_interpret() or CompilerParams is None:
        return None
    return CompilerParams(dimension_semantics=dimension_semantics)
