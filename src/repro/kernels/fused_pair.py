"""Single-pass SimGNN pair-score megakernel (DESIGN.md §7).

This is the full realization of SPA-GCN's cross-stage dataflow pipeline
(paper §3.3, Fig. 4): ONE `pallas_call` whose program takes a block of graph
*pairs* — G1 and G2 tiles co-resident in VMEM — and runs

    adjacency normalization -> N-layer GCN -> Att pooling -> NTN -> FCN
    -> sigmoid

entirely in-register/VMEM, writing only the final [B] similarity scores back
to HBM. Nothing else touches off-chip memory: raw adjacency, features and
masks are read once per block, weights are broadcast to every program, and
every intermediate (A', all layer activations, graph embeddings, NTN slices)
lives and dies inside the program. This subsumes the two-kernel path
(`fused_gcn.py` + `simgnn_head.py`), which round-trips the graph embeddings
through HBM between stages 2 and 3.

The two graphs of each pair are stacked into one [2*GB, ...] block before the
GCN stack, so every matmul sees twice the rows (same trick as
`core.simgnn.pair_score`: on TPU, engine reuse is free and batching the two
sides doubles MXU occupancy). The layer loops are variadic — any
`SimGNNConfig.gcn_dims` / `fcn_dims` compiles — and accumulate in fp32 with
bf16 inputs supported (bf16 in / fp32 accumulate / out-dtype store).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (compiler_params, flatten_layer_params,
                                  gcn_att_block, leading_block_spec,
                                  normalize_adjacency_block, ntn_fcn_block,
                                  read_layer_refs, replicated_spec,
                                  should_interpret)


def _kernel(n_gcn_layers,
            adj1_ref, feats1_ref, mask1_ref, adj2_ref, feats2_ref, mask2_ref,
            *refs):
    out_ref, refs = refs[-1], refs[:-1]
    gcn_refs, refs = refs[:2 * n_gcn_layers], refs[2 * n_gcn_layers:]
    watt_ref, wt_ref, vt_ref, ntn_b_ref = refs[:4]
    fcn_refs = refs[4:]
    gb = adj1_ref.shape[0]

    # Stack the pair into one [2*GB, ...] block: one normalization, one GCN
    # stack, one Att stage for both sides (double MXU occupancy).
    adj = jnp.concatenate([adj1_ref[...], adj2_ref[...]], 0).astype(jnp.float32)
    h0 = jnp.concatenate([feats1_ref[...], feats2_ref[...]], 0).astype(jnp.float32)
    mask = jnp.concatenate([mask1_ref[...], mask2_ref[...]], 0).astype(jnp.float32)

    a_norm = normalize_adjacency_block(adj, mask)          # stage 0 (host prep
                                                           # in the paper)
    hg = gcn_att_block(a_norm, h0, mask, read_layer_refs(gcn_refs),
                       watt_ref[...])                      # stages 1-2
    scores = ntn_fcn_block(hg[:gb], hg[gb:], wt_ref[...], vt_ref[...],
                           ntn_b_ref[...],
                           read_layer_refs(fcn_refs))      # stages 3-4
    out_ref[...] = scores.astype(out_ref.dtype)            # [GB, 1]


@functools.partial(jax.jit, static_argnames=("block_pairs", "interpret"))
def fused_pair_score(adj1: jax.Array, feats1: jax.Array, mask1: jax.Array,
                     adj2: jax.Array, feats2: jax.Array, mask2: jax.Array,
                     gcn_params, att_w: jax.Array, ntn_params, fcn_params, *,
                     block_pairs: int = 8,
                     interpret: bool | None = None) -> jax.Array:
    """Raw adjacency/features/masks for both sides of B graph pairs ->
    [B] similarity scores, in one pallas_call. B must be a multiple of
    block_pairs (ops.py pads; pad pairs have all-zero masks and their scores
    are sliced off)."""
    if interpret is None:
        interpret = should_interpret()
    b, n, _ = adj1.shape
    assert b % block_pairs == 0, (b, block_pairs)
    f = gcn_params[-1]["w"].shape[1]
    k = ntn_params["b"].shape[0]
    # Host-side pre-transposes (same layouts as simgnn_head.py): W [K,F,F]
    # -> [F, K*F], V [K,2F] -> [2F, K] so the kernel sees pure matmuls.
    wt = jnp.transpose(ntn_params["w"], (1, 0, 2)).reshape(f, k * f)
    vt = ntn_params["v"].T
    weights = (flatten_layer_params(gcn_params)
               + [att_w, wt, vt, ntn_params["b"]]
               + flatten_layer_params(fcn_params))

    def blk(shape):
        return leading_block_spec((block_pairs,) + shape)

    f0 = feats1.shape[-1]
    out = pl.pallas_call(
        functools.partial(_kernel, len(gcn_params)),
        grid=(b // block_pairs,),
        in_specs=[blk((n, n)), blk((n, f0)), blk((n,)),
                  blk((n, n)), blk((n, f0)), blk((n,))]
                 + [replicated_spec(a) for a in weights],
        out_specs=blk((1,)),
        out_shape=jax.ShapeDtypeStruct((b, 1), feats1.dtype),
        compiler_params=compiler_params(("parallel",)),
        interpret=interpret,
    )(adj1, feats1, mask1, adj2, feats2, mask2, *weights)
    return out[:, 0]
