"""Differentiable twins of the packed pair-score megakernels (DESIGN.md §11).

`pl.pallas_call` has no autodiff rule, so the packed inference kernels
(`packed_pair.py`, `sparse_pair.py`) cannot be fed to `jax.grad` directly.
But their compute BODIES live in `kernels/common.py` as pure-jnp functions
of values — and since those bodies now carry `jax.custom_vjp` rules whose
backward passes reuse the forward edge planes (transpose-aggregation), the
same single-pass dataflow becomes differentiable simply by composing the
bodies under `jit` instead of under `pallas_call`:

  * `packed_pair_score_grad`  — the §8 dense block-diagonal tile path;
  * `sparse_pair_score_grad`  — the §9 packed-CSR edge-centric path.

Both consume the exact `core.batching.pack_pairs` layouts the inference
kernels consume and return the same `[T, P]` pair-slot scores (zero at pad
slots), so one packing pass per training batch serves the forward AND
backward passes of every accumulation microbatch. On TPU the bodies lower
to the same MXU-shaped contractions XLA would fuse anyway; what the Pallas
wrapper adds for inference (explicit VMEM residency across stages) is
redundant under autodiff, which must spill residuals to HBM regardless.

`core.engine.ScoringEngine.loss_and_grad` is the dispatch point; nothing
else should import these directly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import (gcn_layers_block, gcn_layers_edge_block,
                                  normalize_adjacency_block, ntn_fcn_block,
                                  segment_att_pool_block)


def _layer_values(layers) -> list[tuple[jax.Array, jax.Array]]:
    """[{'w','b'}, ...] param dicts -> [(w, b), ...] values (the form the
    `*_block` bodies take; `read_layer_refs` is the in-kernel analogue)."""
    return [(p["w"], p["b"]) for p in layers]


def _ntn_transposes(params):
    """Host-side NTN pre-transposes shared with the Pallas wrappers:
    W [K,F,F] -> [F, K*F] and V [K,2F] -> [2F, K] so both contractions in
    `ntn_fcn_block` are pure matmuls."""
    f = params["gcn"][-1]["w"].shape[1]
    k = params["ntn"]["b"].shape[0]
    wt = jnp.transpose(params["ntn"]["w"], (1, 0, 2)).reshape(f, k * f)
    vt = params["ntn"]["v"].T
    return wt, vt


def _head_scores(params, hg, t, p, pair_mask):
    """Segment embeddings [2T, P, F] -> masked [T, P] pair-slot scores."""
    f = hg.shape[-1]
    wt, vt = _ntn_transposes(params)
    scores = ntn_fcn_block(hg[:t].reshape(t * p, f), hg[t:].reshape(t * p, f),
                           wt, vt, params["ntn"]["b"],
                           _layer_values(params["fcn"]))          # [T*P, 1]
    return scores.reshape(t, p) * pair_mask.astype(jnp.float32)


def packed_pair_score_grad(params, adj1, labels1, mask1, seg1,
                           adj2, labels2, mask2, seg2,
                           pair_mask) -> jax.Array:
    """Differentiable §8 packed-dense scorer: the same stage sequence as
    `packed_pair._kernel` (stack sides -> in-graph normalization -> GCN
    stack with W1 label gather -> segment Att pool -> NTN/FCN) on values.
    pack_pairs layout in, [T, P] pair-slot scores out (zero at pad slots)."""
    t = adj1.shape[0]
    p = pair_mask.shape[-1]
    cat = lambda a, b: jnp.concatenate([a, b], 0)
    adj = cat(adj1, adj2).astype(jnp.float32)
    labels = cat(labels1, labels2)
    mask = cat(mask1, mask2).astype(jnp.float32)
    seg = cat(seg1, seg2)

    a_norm = normalize_adjacency_block(adj, mask)
    h = gcn_layers_block(a_norm, None, mask, _layer_values(params["gcn"]),
                         labels=labels)                           # [2T, NB, F]
    hg = segment_att_pool_block(h, mask, seg, params["att"]["w"], p)
    return _head_scores(params, hg, t, p, pair_mask)


def sparse_pair_score_grad(params,
                           nbr1, nbr_w1, ov_snd1, ov_rcv1, ov_w1,
                           labels1, mask1, seg1,
                           nbr2, nbr_w2, ov_snd2, ov_rcv2, ov_w2,
                           labels2, mask2, seg2,
                           pair_mask) -> jax.Array:
    """Differentiable §9 packed-sparse scorer: aggregation runs from the
    packed-CSR edge planes (`csr_aggregate_block`, whose custom VJP swaps
    sender/receiver planes in the backward pass) — mirror of
    `sparse_pair._kernel`. pack_pairs(with_edges=True) layout in, [T, P]
    pair-slot scores out."""
    t = mask1.shape[0]
    p = pair_mask.shape[-1]
    cat = lambda a, b: jnp.concatenate([a, b], 0)
    nbr = cat(nbr1, nbr2)
    nw = cat(nbr_w1, nbr_w2).astype(jnp.float32)
    ovs = cat(ov_snd1, ov_snd2)
    ovr = cat(ov_rcv1, ov_rcv2)
    ovw = cat(ov_w1, ov_w2).astype(jnp.float32)
    labels = cat(labels1, labels2)
    mask = cat(mask1, mask2).astype(jnp.float32)
    seg = cat(seg1, seg2)

    # No normalization stage: the edge weights already hold A' non-zeros.
    h = gcn_layers_edge_block(nbr, nw, ovs, ovr, ovw, None, mask,
                              _layer_values(params["gcn"]),
                              labels=labels)                      # [2T, NB, F]
    hg = segment_att_pool_block(h, mask, seg, params["att"]["w"], p)
    return _head_scores(params, hg, t, p, pair_mask)


def packed_arrays(packed, *, sparse: bool) -> tuple:
    """Flatten a PackedPairBatch into the positional array tuple the
    matching `*_score_grad` function takes (after `pair_mask`-last ordering
    the jitted loss closures rely on)."""
    if sparse:
        e = packed.edges
        return (e.edges1.senders, e.edges1.weights,
                e.overflow1.senders, e.overflow1.receivers,
                e.overflow1.weights,
                packed.labels1, packed.mask1, packed.seg1,
                e.edges2.senders, e.edges2.weights,
                e.overflow2.senders, e.overflow2.receivers,
                e.overflow2.weights,
                packed.labels2, packed.mask2, packed.seg2,
                packed.pair_mask)
    return (packed.adj1, packed.labels1, packed.mask1, packed.seg1,
            packed.adj2, packed.labels2, packed.mask2, packed.seg2,
            packed.pair_mask)
