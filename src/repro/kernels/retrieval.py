"""Blocked streaming top-M retrieval prefilter (DESIGN.md §14).

The two-stage query path (serve/search.py) shortlists M candidates per
query with a cheap embedding-space proxy before the exact NTN+FCN rerank.
The proxy scan is this kernel: a [Q, F] query-vector block against the
resident [N, F] corpus matrix, streamed in VMEM-sized *column* blocks of
`block_cols` corpus rows. Each sequential grid step computes one
[BQ, block_cols] score tile, merges it into a running per-query top-M
(score + corpus index) held in the revisited output refs, and moves on —
the full [Q, N] score matrix is NEVER materialized, which is the whole
point at corpus scale (a million rows is a 4 GB float32 score matrix per
128-query batch; the running state is [Q, M]). Same row-blocked streaming
discipline Accel-GCN applies to aggregation, pointed at the retrieval scan.

The proxy itself is a plain dot product in embedding space. For ranking
fidelity against the real NTN+FCN head, `fit_prefilter_calibration`
ridge-fits the head's logit as a linear function of the NTN bilinear
features and collapses the fit into ONE F-vector per query
(`prefilter_query_vectors`), so calibration changes nothing about the
kernel — only what is fed to it.

Shard alignment: `retrieval_block_cols` sizes the column block to the
persisted shard rows of `core/store.py` (DESIGN.md §13), so the kernel's
sequential block loop walks the corpus in 1:1 correspondence with the
on-disk shards — the unit a later multi-process sharded server distributes.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (compiler_params, flatten_layer_params,
                                  read_layer_refs, replicated_spec,
                                  should_interpret)

__all__ = ["RETRIEVAL_MAX_BLOCK_COLS", "NEG_FILL", "retrieval_block_cols",
           "blocked_topm", "blocked_topm_ntn", "collapse_query_ntn",
           "topm_reference", "ntn_logit_reference",
           "fit_prefilter_calibration", "prefilter_query_vectors"]

#: Hard ceiling on corpus rows per streamed block — the block-shape guard
#: that enforces "never materialize [Q, N]": a [128, 1024] f32 score tile
#: is 512 KB of VMEM; one block spanning a million-row corpus would not be.
RETRIEVAL_MAX_BLOCK_COLS = 1024

#: Finite sentinel for non-finite proxy scores (NaN corpus/query embedding
#: rows from dropped embed buckets, DESIGN.md §12). Finite on purpose: it
#: still outranks the -inf slots used for top-M init placeholders and
#: padded corpus columns, so NaN rows rank LAST among real rows but padding
#: and placeholders can never surface as results.
NEG_FILL = float(np.float32(-3.0e38))


def retrieval_block_cols(n_corpus: int, *,
                         shard_rows: int | None = None) -> int:
    """Corpus-column block size for `blocked_topm`.

    With `shard_rows` (the persisted shard size of the serving index), the
    block is the shard itself when it fits the VMEM ceiling — sequential
    grid step j then scans exactly shard j. Oversized shards are halved
    until they fit, so blocks still nest evenly inside shard boundaries.
    Without a store, the block is the corpus rounded up to a power of two,
    capped at `RETRIEVAL_MAX_BLOCK_COLS`.
    """
    if n_corpus < 1:
        raise ValueError(f"n_corpus must be >= 1, got {n_corpus}")
    if shard_rows is not None and shard_rows >= 1:
        b = int(shard_rows)
        while b > RETRIEVAL_MAX_BLOCK_COLS and b % 2 == 0:
            b //= 2
        return min(b, RETRIEVAL_MAX_BLOCK_COLS)
    b = 8
    while b < n_corpus and b < RETRIEVAL_MAX_BLOCK_COLS:
        b *= 2
    return b


def _merge_topm(out_s_ref, out_i_ref, s, *, m: int, block_cols: int,
                n_valid: int):
    """Fold one [BQ, block_cols] score tile into the running per-query
    top-M held in the revisited output refs (sequential grid dim 1)."""
    j = pl.program_id(1)
    # The guard the tests assert: one program only ever sees a
    # [BQ, block_cols] score tile, never [Q, N].
    assert s.shape[1] == block_cols, s.shape
    col = j * block_cols + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(jnp.isfinite(s), s, NEG_FILL)           # NaN rows rank last
    s = jnp.where(col < n_valid, s, -jnp.inf)             # padding never wins

    @pl.when(j == 0)
    def _init():                                          # noqa: ANN202
        out_s_ref[...] = jnp.full(out_s_ref.shape, -jnp.inf, out_s_ref.dtype)
        out_i_ref[...] = jnp.zeros(out_i_ref.shape, out_i_ref.dtype)

    # Merge running top-M with this block's scores. `top_k` keeps the
    # EARLIEST position on ties, and running entries come from earlier
    # (lower-index) blocks, so ties resolve to the ascending corpus index —
    # the same order the exact path's stable sort produces.
    merged_s = jnp.concatenate([out_s_ref[...], s], axis=1)
    merged_i = jnp.concatenate([out_i_ref[...], col], axis=1)
    top_s, pos = jax.lax.top_k(merged_s, m)
    out_s_ref[...] = top_s
    out_i_ref[...] = jnp.take_along_axis(merged_i, pos, axis=1)


def _topm_kernel(qv_ref, c_ref, out_s_ref, out_i_ref, *, m: int,
                 block_cols: int, n_valid: int):
    qb = qv_ref[...].astype(jnp.float32)                  # [BQ, F]
    cb = c_ref[...].astype(jnp.float32)                   # [BN, F]
    s = jax.lax.dot_general(qb, cb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    _merge_topm(out_s_ref, out_i_ref, s, m=m, block_cols=block_cols,
                n_valid=n_valid)


def _topm_ntn_kernel(uq_ref, dq_ref, c_ref, *refs, m: int, block_cols: int,
                     n_valid: int, ntn_k: int, feat: int):
    out_s_ref, out_i_ref = refs[-2], refs[-1]
    layers = read_layer_refs(refs[:-2])
    uq = uq_ref[...].astype(jnp.float32)                  # [BQ, K*F]
    dq = dq_ref[...].astype(jnp.float32)                  # [BQ, K]
    cb = c_ref[...].astype(jnp.float32)                   # [BN, F]
    # Exact NTN activations, query side pre-collapsed: slice k of the
    # bilinear+linear form is one [BQ, F] x [F, BN] matmul against the
    # corpus block (K matmuls per tile vs the pairwise head's K*F-wide
    # contraction PER PAIR — the 1-vs-N structure is the whole saving).
    acts = []
    for k in range(ntn_k):
        a = jax.lax.dot_general(uq[:, k * feat:(k + 1) * feat], cb,
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        acts.append(a + dq[:, k][:, None])
    x = jnp.maximum(jnp.stack(acts, axis=-1), 0.0)        # [BQ, BN, K]
    # Exact FCN on the activation tile; the pre-sigmoid logit is the
    # proxy (sigmoid is monotone, so top-M is unchanged by skipping it).
    for li, (wl, bl) in enumerate(layers):
        x = jax.lax.dot_general(x, wl.astype(jnp.float32),
                                (((2,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        x = x + bl.astype(jnp.float32)
        if li + 1 < len(layers):
            x = jnp.maximum(x, 0.0)
    _merge_topm(out_s_ref, out_i_ref, x[..., 0], m=m, block_cols=block_cols,
                n_valid=n_valid)


def _rep2(a) -> pl.BlockSpec:
    """2D-grid replicated spec: every program sees the whole (small) array."""
    return pl.BlockSpec(a.shape, lambda i, j: (0,) * a.ndim)


def _pad_pow2(q: int, cap: int) -> tuple[int, int]:
    """(padded rows, query-block rows): queries pad to a power of two so the
    jit cache holds one executable per shape *class*, not per batch size."""
    qp = 8
    while qp < q:
        qp *= 2
    return qp, min(cap, qp)


@functools.partial(jax.jit,
                   static_argnames=("m", "block_cols", "interpret"))
def _blocked_topm(qv, corpus, *, m: int, block_cols: int, interpret: bool):
    q, f = qv.shape
    n = corpus.shape[0]
    qp, block_q = _pad_pow2(q, 128)
    npad = -(-n // block_cols) * block_cols
    qv = jnp.pad(qv.astype(jnp.float32), ((0, qp - q), (0, 0)))
    cp = jnp.pad(corpus.astype(jnp.float32), ((0, npad - n), (0, 0)))
    kern = functools.partial(_topm_kernel, m=m, block_cols=block_cols,
                             n_valid=n)
    out_s, out_i = pl.pallas_call(
        kern,
        grid=(qp // block_q, npad // block_cols),
        in_specs=[pl.BlockSpec((block_q, f), lambda i, j: (i, 0)),
                  pl.BlockSpec((block_cols, f), lambda i, j: (j, 0))],
        # Constant index along j: the per-query running top-M lives in the
        # revisited output block across the sequential column scan.
        out_specs=[pl.BlockSpec((block_q, m), lambda i, j: (i, 0)),
                   pl.BlockSpec((block_q, m), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((qp, m), jnp.float32),
                   jax.ShapeDtypeStruct((qp, m), jnp.int32)],
        compiler_params=compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(qv, cp)
    return out_s[:q], out_i[:q]


@functools.partial(jax.jit,
                   static_argnames=("m", "block_cols", "interpret"))
def _blocked_topm_ntn(uq, dq, corpus, fcn_flat, *, m: int, block_cols: int,
                      interpret: bool):
    q, kf = uq.shape
    k = dq.shape[1]
    n, f = corpus.shape
    # Smaller query block than the dot kernel: the activation tile is
    # [BQ, block_cols, K] f32 (8 * 1024 * 16 * 4B = 512 KB at the cap).
    qp, block_q = _pad_pow2(q, 8)
    npad = -(-n // block_cols) * block_cols
    uq = jnp.pad(uq.astype(jnp.float32), ((0, qp - q), (0, 0)))
    dq = jnp.pad(dq.astype(jnp.float32), ((0, qp - q), (0, 0)))
    cp = jnp.pad(corpus.astype(jnp.float32), ((0, npad - n), (0, 0)))
    kern = functools.partial(_topm_ntn_kernel, m=m, block_cols=block_cols,
                             n_valid=n, ntn_k=k, feat=f)
    out_s, out_i = pl.pallas_call(
        kern,
        grid=(qp // block_q, npad // block_cols),
        in_specs=[pl.BlockSpec((block_q, kf), lambda i, j: (i, 0)),
                  pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
                  pl.BlockSpec((block_cols, f), lambda i, j: (j, 0))]
                 + [_rep2(a) for a in fcn_flat],
        out_specs=[pl.BlockSpec((block_q, m), lambda i, j: (i, 0)),
                   pl.BlockSpec((block_q, m), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((qp, m), jnp.float32),
                   jax.ShapeDtypeStruct((qp, m), jnp.int32)],
        compiler_params=compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(uq, dq, cp, *fcn_flat)
    return out_s[:q], out_i[:q]


def blocked_topm(qv, corpus, m: int, *, block_cols: int | None = None,
                 interpret: bool | None = None):
    """Streaming top-M proxy scan: `(scores [Q, M], indices [Q, M])`.

    `qv` is [Q, F] query vectors (raw embeddings for a dot proxy, or
    `prefilter_query_vectors` output for the calibrated proxy), `corpus`
    the resident [N, F] matrix. Scores within each row are descending;
    indices are corpus row numbers. M is clamped to N. Raises `ValueError`
    if `block_cols` exceeds `RETRIEVAL_MAX_BLOCK_COLS` — the caller-visible
    half of the never-materialize-[Q, N] contract.
    """
    qv = jnp.asarray(qv, jnp.float32)
    corpus = jnp.asarray(corpus, jnp.float32)
    if qv.ndim != 2 or corpus.ndim != 2 or qv.shape[1] != corpus.shape[1]:
        raise ValueError(f"shape mismatch: qv {qv.shape} vs corpus "
                         f"{corpus.shape}")
    args = _scan_args(qv.shape[0], corpus.shape[0], m, block_cols, interpret)
    if args is None:
        return (np.zeros((qv.shape[0], 0), np.float32),
                np.zeros((qv.shape[0], 0), np.int32))
    s, i = _blocked_topm(qv, corpus, **args)
    return np.asarray(s), np.asarray(i)


def blocked_topm_ntn(uq, dq, corpus, fcn_params, m: int, *,
                     block_cols: int | None = None,
                     interpret: bool | None = None):
    """Exact streamed NTN+FCN top-M scan — the escalated proxy rung.

    `(uq, dq)` come from `collapse_query_ntn`: the query side of the NTN is
    folded into one [K, F] matrix + [K] offset per query (paid once), so
    each corpus block costs K matmul slices + the tiny FCN instead of the
    pairwise head's per-pair K*F-wide contraction — exact ranking at a
    fraction of the full-head scan's work, still never materializing
    [Q, N]. Returns `(logits [Q, M], indices [Q, M])`; logits are
    pre-sigmoid exact head scores (monotone in the served similarity).
    """
    uq = jnp.asarray(uq, jnp.float32)
    dq = jnp.asarray(dq, jnp.float32)
    corpus = jnp.asarray(corpus, jnp.float32)
    if uq.shape[1] != dq.shape[1] * corpus.shape[1]:
        raise ValueError(f"uq {uq.shape} is not [Q, K*F] for dq {dq.shape} "
                         f"and corpus {corpus.shape}")
    args = _scan_args(uq.shape[0], corpus.shape[0], m, block_cols, interpret)
    if args is None:
        return (np.zeros((uq.shape[0], 0), np.float32),
                np.zeros((uq.shape[0], 0), np.int32))
    flat = tuple(jnp.asarray(a, jnp.float32)
                 for a in flatten_layer_params(fcn_params))
    s, i = _blocked_topm_ntn(uq, dq, corpus, flat, **args)
    return np.asarray(s), np.asarray(i)


def _scan_args(q: int, n: int, m: int, block_cols: int | None,
               interpret: bool | None) -> dict | None:
    """Shared clamp/guard policy of both scan wrappers (None: empty scan)."""
    if q == 0 or n == 0:
        return None
    if block_cols is None:
        block_cols = retrieval_block_cols(n)
    if block_cols > RETRIEVAL_MAX_BLOCK_COLS:
        raise ValueError(
            f"block_cols={block_cols} exceeds RETRIEVAL_MAX_BLOCK_COLS="
            f"{RETRIEVAL_MAX_BLOCK_COLS}: a block that wide materializes "
            "the score matrix the streaming scan exists to avoid")
    return {"m": int(max(1, min(m, n))), "block_cols": int(block_cols),
            "interpret": should_interpret() if interpret is None
            else interpret}


def topm_reference(qv, corpus, m: int):
    """Dense numpy reference for `blocked_topm` (same sentinel and tie
    order): materializes [Q, N] — tests only."""
    s = np.asarray(qv, np.float32) @ np.asarray(corpus, np.float32).T
    return _rank_reference(s, m)


def collapse_query_ntn(ntn_params, hq) -> tuple[np.ndarray, np.ndarray]:
    """Fold the NTN's query side into per-query scan operands.

    Slice k of the NTN pre-activation is
    `h_q W_k h_c + v_k[:F]·h_q + v_k[F:]·h_c + b_k`; grouping by the
    candidate gives `(h_q W_k + v_k[F:])·h_c + (v_k[:F]·h_q + b_k)`.
    Returns `(uq [Q, K*F], dq [Q, K])` — the candidate-facing matrices and
    the per-query constants. One K·F² contraction per query, amortized
    over the whole corpus scan."""
    w = np.asarray(ntn_params["w"], np.float32)             # [K, F, F]
    v = np.asarray(ntn_params["v"], np.float32)             # [K, 2F]
    b = np.asarray(ntn_params["b"], np.float32)             # [K]
    hq = np.asarray(hq, np.float32)
    f = w.shape[1]
    uq = np.einsum("qf,kfg->qkg", hq, w) + v[None, :, f:]
    dq = hq @ v[:, :f].T + b[None, :]
    return (uq.reshape(hq.shape[0], -1).astype(np.float32),
            dq.astype(np.float32))


def ntn_logit_reference(uq, dq, corpus, fcn_params, m: int):
    """Dense numpy reference for `blocked_topm_ntn`: materializes [Q, N]
    — tests only."""
    corpus = np.asarray(corpus, np.float32)
    q, (n, f) = np.asarray(uq).shape[0], corpus.shape
    k = np.asarray(dq).shape[1]
    a = np.einsum("qkf,nf->qnk", np.asarray(uq, np.float32).reshape(q, k, f),
                  corpus) + np.asarray(dq, np.float32)[:, None, :]
    x = np.maximum(a, 0.0)
    for li, p in enumerate(fcn_params):
        x = x @ np.asarray(p["w"], np.float32) + np.asarray(p["b"],
                                                            np.float32)
        if li + 1 < len(fcn_params):
            x = np.maximum(x, 0.0)
    return _rank_reference(x[..., 0], m)


def _rank_reference(s: np.ndarray, m: int):
    s = np.where(np.isfinite(s), s, np.float32(NEG_FILL)).astype(np.float32)
    m = int(max(1, min(m, s.shape[1])))
    order = np.argsort(-s, axis=1, kind="stable")[:, :m]
    return (np.take_along_axis(s, order, axis=1),
            order.astype(np.int32))


# ------------------------------------------------------------- calibration

def fit_prefilter_calibration(ntn_w, hq, hc, exact_scores, *,
                              ridge: float = 1e-4) -> dict:
    """Fit the proxy so dot-product ranking tracks the exact head.

    The head's pre-sigmoid score is (through the FCN) a nonlinear function
    of the K NTN activations  relu(h_q W_k h_c + v_k·[h_q; h_c] + b_k).
    Ridge-regressing the exact score's logit on the bilinear features
    phi_k = h_q W_k h_c  plus h_c (the candidate half of the linear term)
    and h_q captures the head's dominant linear structure; everything
    query-only is rank-constant per query and irrelevant to top-M. The fit
    collapses into coefficients (alpha [K], beta [F]) such that

        proxy(q, c) = (sum_k alpha_k (h_q @ W_k) + beta) · h_c

    — i.e. one calibrated F-vector per query (`prefilter_query_vectors`)
    and the scan stays a pure blocked dot product. Returns
    {"alpha", "beta", "r2", "n_samples"}; `r2` is the in-sample fit quality
    on logits (diagnostic — recall@k is the metric that gates).
    """
    w = np.asarray(ntn_w, np.float32)                       # [K, F, F]
    hq = np.asarray(hq, np.float32)
    hc = np.asarray(hc, np.float32)
    y = np.asarray(exact_scores, np.float64)
    ok = (np.isfinite(hq).all(axis=-1) & np.isfinite(hc).all(axis=-1)
          & np.isfinite(y))
    hq, hc, y = hq[ok], hc[ok], y[ok]
    if len(y) < w.shape[0]:
        raise ValueError(f"need >= {w.shape[0]} finite calibration pairs, "
                         f"got {len(y)}")
    y = np.log(np.clip(y, 1e-6, 1 - 1e-6)) - np.log1p(
        -np.clip(y, 1e-6, 1 - 1e-6))
    t = np.einsum("qf,kfg->qkg", hq, w)                     # [S, K, F]
    phi = np.einsum("qkg,qg->qk", t, hc)                    # [S, K]
    x = np.concatenate([phi, hc, hq, np.ones((len(y), 1))],
                       axis=1).astype(np.float64)
    k, f = w.shape[0], w.shape[1]
    # Ridge in the normal equations; scale-aware lambda so wildly different
    # feature magnitudes (bilinear vs raw embedding) are penalized evenly.
    g = x.T @ x
    lam = ridge * np.trace(g) / g.shape[0]
    coef = np.linalg.solve(g + lam * np.eye(g.shape[0]), x.T @ y)
    pred = x @ coef
    ss_res = float(((y - pred) ** 2).sum())
    ss_tot = float(((y - y.mean()) ** 2).sum()) or 1.0
    return {"alpha": coef[:k].astype(np.float32),
            "beta": coef[k:k + f].astype(np.float32),
            "r2": round(1.0 - ss_res / ss_tot, 6),
            "n_samples": int(len(y))}


def prefilter_query_vectors(ntn_w, hq, calib: dict) -> np.ndarray:
    """Collapse calibrated coefficients into per-query scan vectors:
    `[Q, F]` such that `qv @ corpus.T` is the calibrated proxy score.
    Costs one K·F² contraction per query — paid once, amortized over the
    whole N-row scan."""
    w = np.asarray(ntn_w, np.float32)
    hq = np.asarray(hq, np.float32)
    t = np.einsum("qf,kfg->qkg", hq, w)                     # [Q, K, F]
    return (np.einsum("k,qkg->qg", calib["alpha"], t)
            + calib["beta"][None, :]).astype(np.float32)
