"""RWKV-6 (Finch) WKV linear-recurrence Pallas TPU kernel.

Per head with key dim K and value dim V, data-dependent per-channel decay:

    o_t = r_t^T S_{t-1}  +  (r_t . (u * k_t)) v_t
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

The [K, V] state matrix stays in VMEM scratch across the sequential time-block
grid dimension — one HBM read per input element, one write per output element
(the SPA-GCN "read once" rule applied to a recurrence). Grid:
(batch, heads, time_blocks) with time 'arbitrary' (sequential).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import compiler_params, should_interpret


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref, *, bt: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0, :, 0, :].astype(jnp.float32)     # [bt, K]
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)     # [bt, V]
    w = w_ref[0, :, 0, :].astype(jnp.float32)     # [bt, K] decay in (0,1)
    u = u_ref[0, :].astype(jnp.float32)           # [K] bonus for current token

    def step(t, carry):
        s, ys = carry
        rt, kt, vt, wt = r[t], k[t], v[t], w[t]
        o = rt @ s + jnp.sum(rt * u * kt) * vt     # [V]
        s = wt[:, None] * s + kt[:, None] * vt[None, :]
        return s, ys.at[t].set(o)

    s0 = state_ref[...]
    ys0 = jnp.zeros((bt, v.shape[-1]), jnp.float32)
    s_final, ys = jax.lax.fori_loop(0, bt, step, (s0, ys0))
    state_ref[...] = s_final
    o_ref[0, :, 0, :] = ys.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, *, block_t: int = 128,
         interpret: bool | None = None) -> jax.Array:
    """r/k/w [B,T,H,K], v [B,T,H,V], u [H,K] -> [B,T,H,V]."""
    if interpret is None:
        interpret = should_interpret()
    b, t, h, kd = r.shape
    vd = v.shape[-1]
    bt = min(block_t, t)
    assert t % bt == 0, (t, bt)
    grid = (b, h, t // bt)

    def seq(d):
        return pl.BlockSpec((1, bt, 1, d), lambda b_, h_, it: (b_, it, h_, 0))

    return pl.pallas_call(
        functools.partial(_kernel, bt=bt),
        grid=grid,
        in_specs=[seq(kd), seq(kd), seq(vd), seq(kd),
                  pl.BlockSpec((1, kd), lambda b_, h_, it: (h_, 0))],
        out_specs=seq(vd),
        out_shape=jax.ShapeDtypeStruct((b, t, h, vd), r.dtype),
        scratch_shapes=[pltpu.VMEM((kd, vd), jnp.float32)],
        compiler_params=compiler_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u)
