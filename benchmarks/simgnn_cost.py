"""Analytic FLOP/byte model of one SimGNN query (pair of N-node graphs).

Used by table5/table6 to put the pipeline on the TPU roofline (this container
has no TPU, so modeled time = max(compute, memory) term — same method as the
assignment's §Roofline, applied at SimGNN scale).
"""

from __future__ import annotations

from repro.configs.simgnn_aids import CONFIG as CFG


def per_query_flops(n_nodes: int, avg_edges: float = 27.6) -> float:
    """Both graphs through GCNx3 + Att + NTN + FCN."""
    dims = (CFG.n_node_labels,) + tuple(CFG.gcn_dims)
    f = CFG.gcn_dims[-1]
    k = CFG.ntn_k
    flops = 0.0
    for fi, fo in zip(dims[:-1], dims[1:]):
        ft = 2 * n_nodes * fi * fo                   # feature transform (HW)
        agg = 2 * (2 * avg_edges + n_nodes) * fo     # edge-list aggregation
        flops += ft + agg
    flops += 2 * n_nodes * f + 2 * f * f + 2 * n_nodes * f  # Att stage
    flops *= 2                                        # two graphs
    flops += 2 * f * f * k + 2 * 2 * f * k            # NTN
    flops += 2 * (k * 8 + 8 * 4 + 4)                  # FCN
    return flops


def per_query_flops_mxu(n_nodes: int, batch: int) -> float:
    """Effective FLOPs on the 128x128 MXU: contraction/output dims pad to
    the systolic tile, rows ride the (batch x nodes) dimension. This is the
    *structural* utilization model — the honest denominator for a modeled
    v5e number (raw per_query_flops assumes perfect utilization on 29-wide
    matrices, which the MXU cannot deliver)."""
    def pad(x, m):
        return -(-x // m) * m

    dims = (CFG.n_node_labels,) + tuple(CFG.gcn_dims)
    f = CFG.gcn_dims[-1]
    k = CFG.ntn_k
    rows = batch * n_nodes                  # FT rows across the fused batch
    flops = 0.0
    for fi, fo in zip(dims[:-1], dims[1:]):
        flops += 2 * pad(rows, 8) * pad(fi, 128) * pad(fo, 128) / batch
        flops += 2 * pad(batch * n_nodes, 8) * pad(n_nodes, 128) * pad(fo, 128) / batch
    flops += 2 * pad(batch, 8) * pad(f, 128) * pad(f, 128) / batch      # Att
    flops += 2 * pad(batch, 8) * pad(f, 128) * pad(k * f, 128) / batch  # NTN
    return flops


DISPATCH_FLOOR_S = 5e-6      # per-executable launch overhead, amortized


def per_query_bytes(n_nodes: int, batch: int) -> float:
    """HBM traffic per query with the fused pipeline: inputs read once,
    weights amortized over the batch (paper's 'read each element only once')."""
    dims = (CFG.n_node_labels,) + tuple(CFG.gcn_dims)
    in_bytes = 2 * (n_nodes * CFG.n_node_labels + n_nodes * n_nodes) * 2
    w_elems = sum(fi * fo for fi, fo in zip(dims[:-1], dims[1:]))
    f = CFG.gcn_dims[-1]
    w_elems += f * f + CFG.ntn_k * f * f + CFG.ntn_k * 2 * f + 200
    out_bytes = 4
    return in_bytes + out_bytes + (w_elems * 2) / max(batch, 1)
