"""Recorded-trace replay: the measured-planner regression gate
(DESIGN.md §15).

SPA-GCN's crossover points between execution strategies are workload
properties, not constants — so the only durable regression test over all
six engine paths at once is MEASURED: capture a mixed traffic trace
(score paths, the embedding-cached search flow, the train paths), persist
it as a versioned JSONL profile (`core/profile.py`), then REPLAY the same
deterministic workloads against a live engine whose planner runs on the
cost model fitted from that profile.

Phases (one process, so every path is jit-warm before anything is timed):

  capture — forced-path engines share one `TraceRecorder`; each workload
            is run unrecorded first (compile warm-up must not pollute the
            profile), then recorded `reps` times per path. Workloads are
            regenerated from pinned seeds (`data/graphs.py` streams:
            independent search pairs at several sizes x degrees, Zipf
            query batches for the cached path, GED pair batches for the
            train paths), so replay needs no graph serialization — the
            profile stores only shapes and timings.
  replay  — the profile is loaded back through `TraceRecorder.load`
            (garbled lines dropped-and-counted), an auto engine plans
            every score workload with `planner="measured"`, and each
            candidate path's REAL latency is measured on the same warm
            forced engines.

`--check` (CI gate, acceptance criteria of ISSUE 9):
  * the planner is actually warm: every replayed plan carries
    `cost_estimates` (a cold fallback here means capture under-supported
    a candidate path);
  * per-path predicted-vs-measured latency error <= 35% median across
    replayed calls (and the fit's own in-sample residual medape <= 35%
    for every fitted path, train paths included);
  * the planner's chosen path is measured-best, or within 10% of the
    best, on >= 80% of replayed calls;
  * cold-planner fallback: with an empty profile, `planner="measured"`
    plans bit-identically (path AND reason) to `planner="threshold"` on
    every replayed workload, score and train.

Usage:  PYTHONPATH=src python benchmarks/replay.py [--tiny] [--check]
            [--trace replay_profile.jsonl] [--out replay_bench.json]

On this CPU-only container kernels run in interpret mode — absolute times
are the trajectory baseline, not TPU times; the gates compare paths
against each other and the model against its own measurements, so they
hold on any substrate.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

import numpy as np

if __package__ in (None, ""):   # `python benchmarks/replay.py` support
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import finish_check, time_call
from repro.configs.simgnn_aids import CONFIG as CFG
from repro.core.engine import TRAIN_PATHS, ScoringEngine
from repro.core.profile import TraceRecorder, fit_cost_model, read_profile
from repro.core.simgnn import init_simgnn_params
from repro.data.graphs import (pair_stream, random_graph, search_pairs,
                               zipf_corpus, zipf_query_stream)

#: the auto-dispatchable scoring candidates the replay gate measures —
#: exactly the candidate set `ScoringEngine._planner_estimates` prices
#: when no cache keys are hashed.
SCORE_CANDIDATES = ("bucketed_mega", "packed_dense", "packed_sparse")

MAX_MEDIAN_ERR = 0.35       # per-path |pred - measured| / measured, median
MIN_CHOSEN_OK = 0.80        # fraction of calls where chosen is near-best
CHOSEN_MARGIN = 1.10        # "near-best": within 10% of the measured best
TINY_CHOSEN_MARGIN = 1.25   # tiny workloads run ~5 ms, where 10% is inside
                            # scheduler noise between near-tied candidates
TINY_MAX_MEDIAN_ERR = 0.50  # same reason for the error gates: tiny walls
                            # (~3 ms cached-path calls) are timer-noise
                            # dominated; CI's default grid keeps 0.35


def score_workloads(tiny: bool) -> list[dict]:
    """Deterministic scoring workloads, regenerated identically by capture
    and replay: independent-pair batches across sizes x degrees so the
    fitted model sees both the pairs term and the edges term move."""
    sizes = (6, 12, 24) if tiny else (8, 16, 32)
    degrees = (None, 6.0)
    out = []
    for i, n in enumerate(sizes):
        for j, deg in enumerate(degrees):
            out.append({
                "name": f"score_n{n}_deg{deg if deg else 'aids'}",
                "pairs": search_pairs(seed=100 + 10 * i + j, n_pairs=n,
                                      avg_degree=deg)})
    return out


def train_workloads(tiny: bool) -> list[dict]:
    sizes = (4, 8) if tiny else (6, 12, 24)
    out = []
    for i, n in enumerate(sizes):
        batch = next(pair_stream(seed=300 + i, batch=n))
        out.append({"name": f"train_b{n}", "pairs": batch["pairs"],
                    "target": batch["target"]})
    return out


def _detached(engines: dict) -> None:
    for eng in engines.values():
        eng.recorder = None


def _attached(engines: dict, rec: TraceRecorder) -> None:
    for eng in engines.values():
        eng.recorder = rec


def build_measure_engines(params) -> dict:
    """One forced-path engine per scoring candidate. `degrade=False` so a
    measurement can never silently time a different rung than its label;
    `planner="threshold"` so nothing here ever consults the model it is
    generating data for."""
    return {p: ScoringEngine(params, CFG, path=p, validation="off",
                             degrade=False, planner="threshold")
            for p in SCORE_CANDIDATES}


def capture(params, trace_path: str, *, tiny: bool,
            score_engines: dict) -> dict:
    """Record the mixed profile and flush it to `trace_path`; returns
    capture stats for the BENCH record."""
    reps = 3 if tiny else 4
    recorder = TraceRecorder(path=trace_path)
    sws, tws = score_workloads(tiny), train_workloads(tiny)

    # --- score paths. The warm-up call runs UNRECORDED immediately before
    # each workload's recorded reps: it absorbs compilation AND pins the
    # exact compiled shapes the reps will hit (the sparse pack's realized
    # overflow budget ratchets across workloads, so warming everything
    # first would leave later recompiles inside recorded calls — exactly
    # the timing pollution the clean-record rule exists to keep out).
    for eng in score_engines.values():
        for w in sws:
            eng.recorder = None
            eng.score(w["pairs"])
            eng.recorder = recorder
            for _ in range(reps):
                eng.score(w["pairs"])
    _detached(score_engines)

    # --- embedding-cached path: Zipf query batches over a fixed corpus,
    # captured in the path's steady state — the regime the planner prices.
    # Everything shape- or state-cold runs UNRECORDED first: the whole
    # corpus is pre-embedded (so no recorded call pays a corpus miss), the
    # four single-miss embed shapes `(1, bucket)` are pre-compiled (each
    # recorded call embeds exactly its one fresh query; miss batches pad
    # to the miss count, so an unseen count means an XLA compile mid-
    # record), and each stream's first batch warms its head shape.
    cached = ScoringEngine(params, CFG, path="embedding_cache",
                           validation="off", planner="threshold")
    n_corpus = 24 if tiny else 48
    batch_sizes = (8, 16) if tiny else (12, 24)
    rng = np.random.default_rng(0xCAFE)
    cached.recorder = None
    for n in (6, 12, 24, 48):
        cached.embed_graphs([random_graph(rng, n)])
    for si, batch in enumerate(batch_sizes):
        stream = zipf_query_stream(seed=500 + si, batch=batch,
                                   n_corpus=n_corpus)
        cached.recorder = None
        cached.embed_graphs(zipf_corpus(500 + si, n_corpus))
        cached.score(next(stream)["pairs"])
        cached.recorder = recorder
        # 6 tiny batches: 2 streams x 4 would leave exactly min-support
        # records, where one noisy ~3 ms wall swings the in-sample medape
        # past the gate under machine load.
        for _ in range(6 if tiny else 5):
            cached.score(next(stream)["pairs"])

    # --- train paths: forced VJP-capable engines through loss_and_grad,
    # same warm-then-record-per-workload discipline as the score paths.
    t_reps = 4 if tiny else 3
    for path in TRAIN_PATHS:
        eng = ScoringEngine(params, CFG, path=path, validation="off",
                            degrade=False, planner="threshold")
        for w in tws:
            eng.recorder = None
            eng.loss_and_grad(w["pairs"], w["target"])
            eng.recorder = recorder
            for _ in range(t_reps):
                eng.loss_and_grad(w["pairs"], w["target"])

    flushed = recorder.flush()
    return {"records": recorder.total_records, "flushed": flushed,
            "flush_errors": int(recorder.counters["flush_errors"])}


def replay(params, trace_path: str, *, tiny: bool, score_engines: dict,
           records: list, failures: list) -> None:
    """Re-run the captured workloads against the profile-warmed planner
    and append one BENCH record per workload + the model summary."""
    profile, dropped = read_profile(trace_path)
    recorder = TraceRecorder.load(trace_path)
    auto = ScoringEngine(params, CFG, validation="off",
                         planner="measured", recorder=recorder)
    model = fit_cost_model(profile,
                           min_support=ScoringEngine.PLANNER_MIN_SUPPORT)
    snap = model.snapshot()
    records.append({"bench": "replay", "policy": "model",
                    "trace_records": len(profile),
                    "records_dropped": dropped, **snap})
    print("BENCH " + json.dumps(records[-1]))
    max_err = TINY_MAX_MEDIAN_ERR if tiny else MAX_MEDIAN_ERR
    for path, medape in snap["residual_medape"].items():
        if medape > max_err:
            failures.append(f"in-sample residual medape {medape:.2f} > "
                            f"{max_err} on {path}")

    _detached(score_engines)
    per_path_err: dict[str, list] = {p: [] for p in SCORE_CANDIDATES}
    chosen_ok = 0
    sws = score_workloads(tiny)
    for w in sws:
        plan = auto.plan(w["pairs"])
        est = plan.cost_estimates
        if not est:
            failures.append(f"planner cold on replay of {w['name']}: "
                            f"{plan.reason}")
            continue
        measured = {p: time_call(
            lambda p=p: score_engines[p].score(w["pairs"]),
            repeats=5 if tiny else 3, reduce="median")
            for p in est}
        best = min(measured.values())
        margin = TINY_CHOSEN_MARGIN if tiny else CHOSEN_MARGIN
        ok = measured[plan.path] <= margin * best
        chosen_ok += ok
        for p in est:
            per_path_err[p].append(abs(est[p] - measured[p]) / measured[p])
        rec = {"bench": "replay", "workload": w["name"],
               "n_pairs": len(w["pairs"]), "chosen": plan.path,
               "chosen_ok": bool(ok),
               "predicted_s": {p: round(v, 6) for p, v in est.items()},
               "measured_s": {p: round(v, 6)
                              for p, v in measured.items()}}
        records.append(rec)
        print("BENCH " + json.dumps(rec))

    for p, errs in per_path_err.items():
        if not errs:
            continue
        med = float(np.median(errs))
        records.append({"bench": "replay", "policy": "path_error",
                        "path": p, "median_err": round(med, 4),
                        "calls": len(errs)})
        print("BENCH " + json.dumps(records[-1]))
        if med > max_err:
            failures.append(f"median predicted-vs-measured error "
                            f"{med:.2f} > {max_err} on {p}")
    n_planned = sum(1 for r in records
                    if r.get("bench") == "replay" and "chosen" in r)
    if n_planned:
        frac = chosen_ok / n_planned
        records.append({"bench": "replay", "policy": "chosen",
                        "ok_frac": round(frac, 4), "calls": n_planned})
        print("BENCH " + json.dumps(records[-1]))
        if frac < MIN_CHOSEN_OK:
            failures.append(f"planner chose a near-best path on only "
                            f"{frac:.0%} of calls (< {MIN_CHOSEN_OK:.0%})")

    # --- cold fallback: an empty profile must leave the measured planner
    # bit-identical to the threshold rules on every replayed workload.
    cold_m = ScoringEngine(params, CFG, validation="off",
                           planner="measured")
    cold_t = ScoringEngine(params, CFG, validation="off",
                           planner="threshold")
    mismatches = []
    for w in sws:
        pm, pt = cold_m.plan(w["pairs"]), cold_t.plan(w["pairs"])
        if (pm.path, pm.reason) != (pt.path, pt.reason):
            mismatches.append(f"{w['name']}: {pm.path} != {pt.path}")
    for w in train_workloads(tiny):
        pm = cold_m.plan(w["pairs"], train=True)
        pt = cold_t.plan(w["pairs"], train=True)
        if (pm.path, pm.reason) != (pt.path, pt.reason):
            mismatches.append(f"{w['name']}: {pm.path} != {pt.path}")
    records.append({"bench": "replay", "policy": "cold_fallback",
                    "mismatches": mismatches})
    print("BENCH " + json.dumps(records[-1]))
    if mismatches:
        failures.append("cold planner diverged from threshold rules: "
                        + "; ".join(mismatches))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--tiny", action="store_true",
                    help="smaller workloads (CI smoke / laptops)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when a replay gate fails")
    ap.add_argument("--trace", default=None,
                    help="profile JSONL path (default: a temp file; pass "
                         "a path to keep the captured profile)")
    ap.add_argument("--out", default=None, help="JSON artifact path")
    args = ap.parse_args(argv)

    import jax
    params = init_simgnn_params(jax.random.PRNGKey(0), CFG)
    tmp = None
    trace = args.trace
    if trace is None:
        tmp = tempfile.mkdtemp(prefix="replay_profile_")
        trace = os.path.join(tmp, "profile.jsonl")

    records: list = []
    failures: list = []
    score_engines = build_measure_engines(params)
    cap = capture(params, trace, tiny=args.tiny,
                  score_engines=score_engines)
    records.append({"bench": "replay", "policy": "capture", **cap})
    print("BENCH " + json.dumps(records[-1]))
    if cap["flush_errors"]:
        failures.append(f"profile flush failed {cap['flush_errors']}x")
    replay(params, trace, tiny=args.tiny, score_engines=score_engines,
           records=records, failures=failures)
    finish_check(records, failures, bench="replay", out=args.out,
                 check=args.check)


if __name__ == "__main__":
    main()
