"""Three-way SimGNN pair-scoring policy comparison on a mixed-size stream.

Policies (all scoring the SAME batch of variable-size graph pairs):

  packed        — `ops.pair_score_packed`: pairs FFD-packed into node-budget
                  tiles with segment IDs, first-layer label gather, ONE
                  pallas_call (DESIGN.md §8);
  bucketed_mega — `ops.pair_score_megakernel` per size bucket (pair-max
                  bucketing, one launch per bucket; DESIGN.md §7);
  two_kernel    — `ops.simgnn_pair_score_kernel` per bucket (fused GCN+Att,
                  embeddings round-trip HBM, fused NTN+FCN head).

Unlike benchmarks/megakernel.py (uniform per-bucket batches), the stream
here is the serving shape: AIDS-like sizes, query and database graph drawn
independently (`data.graphs.search_pairs`), so the bucketed policies pay the
pair-max padding a real search workload pays and the packed policy's
measured pad fraction shows what FFD packing removes. On this CPU-only
container kernels run in interpret mode — numbers are the trajectory
baseline, not TPU times. Emits one `BENCH {json}` line per policy including
measured pad-fraction/occupancy.

Usage:  PYTHONPATH=src python benchmarks/packed.py [--tiny] [--check]
            [--out packed_bench.json]

`--check` (CI gate): non-zero exit if any kernel policy's parity vs the
reference jit drifts above 1e-6 or the packed policy is slower than the
bucketed megakernel.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

if __package__ in (None, ""):   # `python benchmarks/packed.py` support
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import finish_check, time_fn
from repro.configs.simgnn_aids import CONFIG as CFG
from repro.core.batching import bucket_pairs, pack_pairs, unpack_pair_scores
from repro.core.simgnn import init_simgnn_params, pair_score
from repro.data.graphs import search_pairs
from repro.kernels import ops

PARITY_BOUND = 1e-6


def run(batch: int = 512, node_budget: int = 64, iters: int = 3,
        seed: int = 47):
    params = init_simgnn_params(jax.random.PRNGKey(0), CFG)
    pairs = search_pairs(seed, batch)
    sizes = np.asarray([[g1["adj"].shape[0], g2["adj"].shape[0]]
                        for g1, g2 in pairs])

    # Host-side prep for every policy happens once, outside the timed region
    # (the serving loop reuses device buffers the same way); planner cost is
    # reported separately below.
    t0 = time.perf_counter()
    packed, pstats = pack_pairs(pairs, node_budget)
    planner_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    buckets = bucket_pairs(pairs, CFG.n_node_labels, allow_oversize=True)
    bucketer_s = time.perf_counter() - t0

    ref_fn = jax.jit(pair_score)

    def run_packed():
        return unpack_pair_scores(ops.pair_score_packed(params, packed),
                                  packed, batch)

    def run_bucketed(pair_fn):
        out = np.zeros(batch, np.float32)
        for b, (lhs, rhs, idxs) in buckets.items():
            out[idxs] = np.asarray(pair_fn(params, lhs.adj, lhs.feats,
                                           lhs.mask, rhs.adj, rhs.feats,
                                           rhs.mask))
        return out

    policies = {
        "packed": run_packed,
        "bucketed_mega": lambda: run_bucketed(ops.pair_score_megakernel),
        "two_kernel": lambda: run_bucketed(ops.simgnn_pair_score_kernel),
    }

    # Pad accounting: bucketed pads BOTH sides to the pair-max bucket.
    bucket_of = {int(i): b for b, (_, _, idxs) in buckets.items()
                 for i in idxs}
    padded_rows = sum(2 * bucket_of[i] for i in range(batch))
    real_rows = int(sizes.sum())
    bucketed_pad = 1.0 - real_rows / padded_rows
    packed_pad = (pstats["pad_fraction_lhs"] + pstats["pad_fraction_rhs"]) / 2

    s_ref = run_bucketed(ref_fn)
    records, seconds, parity = [], {}, {}
    for name, fn in policies.items():
        parity[name] = float(np.max(np.abs(fn() - s_ref)))   # also warms
        seconds[name] = time_fn(fn, warmup=1, iters=iters)
        rec = {"bench": "packed", "stream": "search", "batch": batch,
               "policy": name,
               "seconds_per_call": round(seconds[name], 6),
               "us_per_pair": round(1e6 * seconds[name] / batch, 3),
               "pairs_per_s": round(batch / seconds[name], 1),
               "max_abs_err_vs_ref": parity[name],
               "pad_fraction": round(bucketed_pad if name != "packed"
                                     else packed_pad, 4)}
        if name == "packed":
            rec.update(node_budget=node_budget,
                       n_tiles=pstats["n_tiles"],
                       slots_per_tile=pstats["slots_per_tile"],
                       occupancy=round(1.0 - packed_pad, 4),
                       mean_pairs_per_tile=round(
                           pstats["mean_pairs_per_tile"], 2),
                       planner_seconds=round(planner_s, 6))
        else:
            rec.update(n_buckets=len(buckets),
                       occupancy=round(1.0 - bucketed_pad, 4),
                       bucketer_seconds=round(bucketer_s, 6))
        records.append(rec)
        print("BENCH " + json.dumps(rec))

    summary = {"bench": "packed", "stream": "search", "batch": batch,
               "policy": "summary",
               "packed_speedup_vs_bucketed_mega":
                   round(seconds["bucketed_mega"] / seconds["packed"], 3),
               "packed_speedup_vs_two_kernel":
                   round(seconds["two_kernel"] / seconds["packed"], 3),
               "worst_kernel_parity": max(parity.values())}
    records.append(summary)
    print("BENCH " + json.dumps(summary))
    return records, summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small batch, few iters")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero on parity drift or packed slowdown")
    ap.add_argument("--out", type=str, default=None,
                    help="write BENCH records to this JSON file")
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--node-budget", type=int, default=64)
    ap.add_argument("--iters", type=int, default=3)
    a = ap.parse_args()
    if a.tiny:
        records, summary = run(batch=48, iters=2)
    else:
        records, summary = run(batch=a.batch, node_budget=a.node_budget,
                               iters=a.iters)
    failures = []
    if summary["worst_kernel_parity"] > PARITY_BOUND:
        failures.append(f"kernel-vs-reference parity "
                        f"{summary['worst_kernel_parity']:.2e} > "
                        f"{PARITY_BOUND:.0e}")
    if summary["packed_speedup_vs_bucketed_mega"] < 1.0:
        failures.append(
            "packed slower than bucketed megakernel "
            f"({summary['packed_speedup_vs_bucketed_mega']}x)")
    finish_check(records, failures, bench="packed", out=a.out, check=a.check)


if __name__ == "__main__":
    main()
