"""Roofline analysis from the dry-run artifacts (assignment §ROOFLINE).

Per (arch x shape x mesh) cell, derive the three terms (seconds/step):

    compute    = HLO_FLOPs_per_device / peak_FLOP/s         (197 TF bf16)
    memory     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
    collective = wire_bytes_per_device / link_bw            (50 GB/s/link)

Sources: `hlo_flops` / `hlo_mem_bytes` / `collectives.bytes_wire` are the
loop-corrected per-device numbers from launch/hlo_analysis.py (the raw
cost_analysis() is also recorded but under-counts scan bodies — see that
module's docstring). Dominant term = the bottleneck; the useful-compute
ratio MODEL_FLOPS / (HLO_FLOPs x devices) flags remat/capacity waste.

Caveats (recorded in EXPERIMENTS.md):
  * HLO comes from the CPU-backend SPMD partition — bf16 compute is
    legalized to f32 on CPU, so byte-sized terms are ~2x a TPU lowering;
  * memory term is a fusion-boundary estimate, an upper bound vs real TPU
    fusion.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

ART_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "artifacts", "dryrun")


def load_cells(art_dir: str = ART_DIR) -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        d = json.load(open(f))
        d["_file"] = os.path.basename(f)
        cells.append(d)
    return cells


def terms(cell: dict) -> dict | None:
    if cell.get("skipped") or "error" in cell:
        return None
    n = cell["n_devices"]
    t_compute = cell["hlo_flops"] / PEAK_FLOPS_BF16
    t_memory = cell["hlo_mem_bytes"] / HBM_BW
    t_coll = cell["collectives"]["bytes_wire"] / ICI_BW
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_coll), key=lambda kv: kv[1])
    useful = cell["model_flops"] / max(1, cell["hlo_flops"] * n)
    bound = max(t_compute, t_memory, t_coll)
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "t_compute": t_compute, "t_memory": t_memory, "t_collective": t_coll,
        "dominant": dom[0], "step_time_lb": bound,
        "useful_ratio": useful,
        "roofline_fraction": t_compute / bound if bound else 0.0,
        "model_flops": cell["model_flops"],
        "hlo_flops_dev": cell["hlo_flops"],
    }


_FIX_HINT = {
    "compute": "at the compute roof: raise useful-ratio (less remat/capacity slack)",
    "memory": "fuse/shrink fusion-boundary buffers (chunked loss, flash-attn kernel) to cut HBM traffic",
    "collective": "re-shard the dominant collective (MoE dispatch / FSDP gathers) or overlap with compute",
}


def render_markdown(art_dir: str = ART_DIR) -> str:
    rows = []
    skips = []
    for cell in load_cells(art_dir):
        t = terms(cell)
        if t is None:
            skips.append(f"| {cell['arch']} | {cell['shape']} | {cell.get('mesh','-')} | "
                         f"{cell.get('note', cell.get('error', ''))[:90]} |")
            continue
        rows.append(t)
    out = ["| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | roofline frac | useful ratio | what moves it |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for t in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        out.append(
            f"| {t['arch']} | {t['shape']} | {t['mesh']} | "
            f"{t['t_compute']:.3f} | {t['t_memory']:.3f} | "
            f"{t['t_collective']:.3f} | **{t['dominant']}** | "
            f"{t['roofline_fraction']:.2f} | {t['useful_ratio']:.2f} | "
            f"{_FIX_HINT[t['dominant']]} |")
    if skips:
        out += ["", "Skipped cells (DESIGN.md §5 rules):",
                "| arch | shape | mesh | reason |", "|---|---|---|---|"] + skips
    return "\n".join(out)


def run():
    from benchmarks.common import emit
    cells = [t for t in (terms(c) for c in load_cells()) if t]
    if not cells:
        emit("roofline.no_artifacts", 0.0, "run_launch.dryrun_first")
        return
    n_ok = len(cells)
    worst = min(cells, key=lambda t: t["roofline_fraction"])
    coll = max(cells, key=lambda t: t["t_collective"] / max(t["step_time_lb"], 1e-12))
    for t in cells:
        emit(f"roofline.{t['arch']}.{t['shape']}.{t['mesh']}",
             t["step_time_lb"] * 1e6,
             f"dom={t['dominant']}_frac={t['roofline_fraction']:.2f}"
             f"_useful={t['useful_ratio']:.2f}")
    emit("roofline.summary", 0.0,
         f"cells={n_ok}_worst={worst['arch']}/{worst['shape']}"
         f"_most_collective={coll['arch']}/{coll['shape']}")


if __name__ == "__main__":
    print(render_markdown())
