"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run

Prints ``name,us_per_call,derived`` CSV lines. Tables 4/5/6 and Fig. 11
reproduce the paper's experiment structure (see each module's docstring);
`roofline` renders the LM-substrate dry-run cells (§Roofline).
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    failures = []
    for name in ("table4", "table5", "table6", "fig11", "roofline"):
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run()
        except Exception as e:  # keep the suite going; record the failure
            failures.append(name)
            print(f"{name}.ERROR,0.0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
