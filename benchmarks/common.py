"""Shared benchmark utilities: timing, CSV emission, TPU roofline model."""

from __future__ import annotations

import time

import jax

# TPU v5e roofline constants (assignment spec)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link


def time_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-clock seconds per call (blocking on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def time_call(fn, *, repeats: int = 2, reduce: str = "min") -> float:
    """Wall-clock seconds over `repeats` blocking calls of a zero-arg
    thunk — the replay harness's measurement primitive (DESIGN.md §15).
    reduce="min" measures *capability* (scheduler noise only ever adds
    time); reduce="median" matches the central tendency of per-call trace
    records, which is what a cost model fitted on them predicts."""
    if reduce not in ("min", "median"):
        raise ValueError(f"unknown reduce {reduce!r}")
    times = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[0] if reduce == "min" else times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def tpu_model_time(flops: float, bytes_hbm: float) -> float:
    """Single-chip roofline time: max of compute and memory terms."""
    return max(flops / PEAK_FLOPS_BF16, bytes_hbm / HBM_BW)


def finish_check(records: list, failures: list, *, bench: str,
                 out: str | None, check: bool):
    """Uniform benchmark epilogue shared by every gated main.

    Appends a `policy: "check"` record carrying the gate verdict, writes
    the `--out` JSON artifact BEFORE exiting — so CI gets the measurements
    and the exact failure strings even when the gate fails (the workflow
    uploads artifacts with `if: always()`) — then applies the `--check`
    exit-code contract. Gate conditions are evaluated by the caller;
    `failures` is its (possibly empty) list of human-readable reasons.
    """
    import json
    import sys

    rec = {"bench": bench, "policy": "check", "checked": bool(check),
           "ok": not failures, "failures": list(failures)}
    records.append(rec)
    print("BENCH " + json.dumps(rec))
    if out:
        with open(out, "w") as f:
            json.dump(records, f, indent=1)
    if check and failures:
        print("CHECK FAILED: " + "; ".join(failures))
        sys.exit(1)
    if check:
        print("CHECK OK")
