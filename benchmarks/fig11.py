"""Paper Fig. 11 — effect of batching queries.

The paper amortizes OpenCL/PCIe setup over ~300 queries for a 2.8x E2E gain.
Here the per-call overhead is Python+jit dispatch; sweeping queries-per-call
reproduces the same amortization curve shape on this stack. The MicroBatcher
section reports *measured* flush behavior (batch occupancy, size- vs
deadline-triggered flushes) from `MicroBatcher.stats` rather than inferring
occupancy from request counts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs.simgnn_aids import CONFIG as CFG
from repro.core.batching import pad_graphs
from repro.core.simgnn import init_simgnn_params, pair_score
from repro.data.graphs import query_pairs

SWEEP = (1, 8, 32, 128, 256, 512)


def run():
    params = init_simgnn_params(jax.random.PRNGKey(0), CFG)
    pairs = query_pairs(41, max(SWEEP))
    lhs = pad_graphs([p[0] for p in pairs], CFG.n_node_labels, 64)
    rhs = pad_graphs([p[1] for p in pairs], CFG.n_node_labels, 64)
    fn = jax.jit(pair_score)

    qps_at = {}
    for b in SWEEP:
        args = (lhs.adj[:b], lhs.feats[:b], lhs.mask[:b],
                rhs.adj[:b], rhs.feats[:b], rhs.mask[:b])
        jax.block_until_ready(fn(params, *args))          # per-shape warm
        n_calls = max(1, 512 // b)

        def run_all():
            out = None
            for _ in range(n_calls):
                out = fn(params, *args)
            return out

        t = time_fn(run_all, warmup=1, iters=3)
        qps_at[b] = n_calls * b / t
    base = qps_at[SWEEP[0]]
    for b in SWEEP:
        emit(f"fig11.batch_{b}", 1e6 / qps_at[b],
             f"qps={qps_at[b]:,.0f}_speedup={qps_at[b] / base:.2f}x_paper_2.8x_at_300")
    return qps_at


def run_microbatcher(max_batch: int = 64, n_queries: int = 300,
                     max_wait_s: float = 0.02):
    """Drive a MicroBatcher over the paper's ~300-query stream and report its
    measured flush stats (real occupancy, not request-count inference)."""
    from repro.serve.batching import MicroBatcher

    params = init_simgnn_params(jax.random.PRNGKey(0), CFG)
    fn = jax.jit(pair_score)

    def run_batch(reqs):
        # Pad the flush to max_batch so every flush reuses one executable;
        # slice back so results align 1:1 with the submitted requests.
        k = len(reqs)
        reqs = reqs + [reqs[0]] * (max_batch - k)
        lhs = pad_graphs([p[0] for p in reqs], CFG.n_node_labels, 64)
        rhs = pad_graphs([p[1] for p in reqs], CFG.n_node_labels, 64)
        out = fn(params, lhs.adj, lhs.feats, lhs.mask,
                 rhs.adj, rhs.feats, rhs.mask)
        return list(jax.block_until_ready(out))[:k]

    mb = MicroBatcher(run_batch, max_batch=max_batch, max_wait_s=max_wait_s)
    for pair in query_pairs(43, n_queries):
        mb.submit(pair)
        mb.poll()
    mb.flush()
    st = mb.stats
    emit(f"fig11.microbatch_{max_batch}", 0.0,
         f"batches={st.batches}_mean_occupancy={st.mean_occupancy:.3f}"
         f"_size_flushes={st.size_flushes}"
         f"_deadline_flushes={st.deadline_flushes}"
         f"_manual_flushes={st.manual_flushes}")
    return st


if __name__ == "__main__":
    run()
    run_microbatcher()
