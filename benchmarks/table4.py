"""Paper Table 4 — impact of GCN architecture optimizations.

Paper rows (FPGA)                  -> TPU-adaptation rows here
  Baseline (shared engine,            `baseline`: per-layer jit boundaries,
  per-layer, dense 64-pad)            serial graph processing, global 64-pad
  +Inter-Layer Pipeline             -> `fused`: whole GCN+Att+NTN+FCN in one
                                       jit region, both graphs batched
  +Extended Sparsity                -> `bucketed`: + size buckets (8/16/32/64)
                                       removing structural zeros (DESIGN.md §2)

Metric: wall-clock per query batch on CPU (relative speedups are the
reproduction target: paper got 1.56x then 2.27x cumulative) plus the
activation-sparsity measurement the paper exploits (52%/47%).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs.simgnn_aids import CONFIG as CFG
from repro.core.batching import bucket_pairs, pad_graphs
from repro.core.gcn import (activation_sparsity, gcn_layer, gcn_stack,
                            normalized_adjacency)
from repro.core.simgnn import (attention_pooling, fcn_head, init_simgnn_params,
                               ntn_scores, pair_score)
from repro.data.graphs import query_pairs

N_QUERIES = 512


def _pad_all(pairs, n):
    lhs = pad_graphs([p[0] for p in pairs], CFG.n_node_labels, n)
    rhs = pad_graphs([p[1] for p in pairs], CFG.n_node_labels, n)
    return lhs, rhs


def baseline_scores(params, lhs, rhs):
    """Paper-baseline analogue: each GCN layer its own jit region (off-chip
    round trips between layers), graphs processed serially, global max pad."""
    layer = jax.jit(lambda p, a, h, m: gcn_layer(p, a, h, m))
    pool = jax.jit(lambda p, h, m: attention_pooling(p, h, m))
    head = jax.jit(lambda p, s1, s2: fcn_head(p["fcn"],
                                              ntn_scores(p["ntn"], s1, s2)))
    hgs = []
    for gb in (lhs, rhs):
        a = jax.jit(normalized_adjacency)(gb.adj, gb.mask)
        h = gb.feats
        for lp in params["gcn"]:
            h = layer(lp, a, h, gb.mask)
            h.block_until_ready()
        hgs.append(pool(params["att"], h, gb.mask))
    return head(params, hgs[0], hgs[1])


def run():
    params = init_simgnn_params(jax.random.PRNGKey(0), CFG)
    pairs = query_pairs(11, N_QUERIES)
    lhs64, rhs64 = _pad_all(pairs, 64)

    fused = jax.jit(pair_score)

    t_base = time_fn(lambda: baseline_scores(params, lhs64, rhs64),
                     warmup=1, iters=5)
    t_fused = time_fn(lambda: fused(params, lhs64.adj, lhs64.feats, lhs64.mask,
                                    rhs64.adj, rhs64.feats, rhs64.mask),
                      warmup=1, iters=5)

    buckets = bucket_pairs(pairs, CFG.n_node_labels)
    compiled = {b: jax.jit(pair_score) for b in buckets}
    for b, (lh, rh, _) in buckets.items():   # warm
        jax.block_until_ready(compiled[b](params, lh.adj, lh.feats, lh.mask,
                                          rh.adj, rh.feats, rh.mask))

    def bucketed():
        outs = []
        for b, (lh, rh, _) in buckets.items():
            outs.append(compiled[b](params, lh.adj, lh.feats, lh.mask,
                                    rh.adj, rh.feats, rh.mask))
        return outs

    t_bucket = time_fn(bucketed, warmup=1, iters=5)

    per_q = 1e6 / N_QUERIES
    emit("table4.baseline_per_layer_globalpad", t_base * per_q, "speedup=1.00x")
    emit("table4.fused_pipeline", t_fused * per_q,
         f"speedup={t_base / t_fused:.2f}x_paper_1.56x")
    emit("table4.fused_plus_bucketing", t_bucket * per_q,
         f"speedup={t_base / t_bucket:.2f}x_paper_2.27x")

    # activation sparsity the paper exploits (52% / 47% on layers 2/3)
    a = normalized_adjacency(lhs64.adj, lhs64.mask)
    h = lhs64.feats
    sp = []
    for lp in params["gcn"]:
        h = gcn_layer(lp, a, h, lhs64.mask)
        sp.append(float(activation_sparsity(h, lhs64.mask)))
    emit("table4.relu_sparsity_l2_l3", 0.0,
         f"measured={sp[1]:.2f}/{sp[2]:.2f}_paper_0.52/0.47")
    return {"t_base": t_base, "t_fused": t_fused, "t_bucket": t_bucket,
            "sparsity": sp}


if __name__ == "__main__":
    run()
