"""Four-way SimGNN pair-scoring policy comparison on a degree-controlled
sparse stream (DESIGN.md §9).

Policies (all scoring the SAME batch of variable-size graph pairs):

  sparse        — `ops.pair_score_sparse`: packed tiles aggregated from the
                  A' non-zero edge list (in-kernel segment sum) — the
                  edge-centric path, paper §3.2.2;
  packed_dense  — `ops.pair_score_packed`: same packed tiles, dense
                  block-diagonal adjacency matmul (DESIGN.md §8);
  bucketed_mega — `ops.pair_score_megakernel` per size bucket (§7);
  two_kernel    — `ops.simgnn_pair_score_kernel` per bucket.

The stream is `data.graphs.search_pairs` with the `avg_degree` knob —
AIDS-like ~2.1 by default — and every record carries the *measured* nnz /
density plus the aggregation-FLOPs each policy spends, so `flops_avoided`
is accounting, not marketing. On this CPU-only container kernels run in
interpret mode — numbers are the trajectory baseline, not TPU times. Emits
one `BENCH {json}` line per policy.

Usage:  PYTHONPATH=src python benchmarks/sparse.py [--tiny] [--check]
            [--avg-degree 2.1] [--out sparse_bench.json]

`--check` (CI gate): non-zero exit if the sparse policy's parity vs the
reference jit drifts above 1e-6, or if — at measured avg degree <= 4 —
the sparse policy is slower than packed-dense.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

if __package__ in (None, ""):   # `python benchmarks/sparse.py` support
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import finish_check, time_fn
from repro.configs.simgnn_aids import CONFIG as CFG
from repro.core.batching import bucket_pairs, pack_pairs, unpack_pair_scores
from repro.core.engine import ScoringEngine
from repro.core.simgnn import init_simgnn_params, pair_score
from repro.data.graphs import search_pairs
from repro.kernels import ops

PARITY_BOUND = 1e-6


def run(batch: int = 512, node_budget: int = 64, iters: int = 5,
        seed: int = 53, avg_degree: float = 2.1):
    params = init_simgnn_params(jax.random.PRNGKey(0), CFG)
    pairs = search_pairs(seed, batch, avg_degree=avg_degree)
    measured_degree = float(np.mean([g["avg_degree"]
                                     for p in pairs for g in p]))
    measured_density = float(np.mean([g["density"]
                                      for p in pairs for g in p]))

    # Host-side prep happens once, outside the timed region (the serving
    # loop reuses device buffers the same way); planner cost reported below.
    t0 = time.perf_counter()
    edge_budget = ops.packed_edge_budget(node_budget, measured_degree)
    packed_sp, sstats = pack_pairs(pairs, node_budget, with_edges=True,
                                   edge_budget=edge_budget)
    sparse_planner_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    packed_dn, dstats = pack_pairs(pairs, node_budget)
    dense_planner_s = time.perf_counter() - t0
    buckets = bucket_pairs(pairs, CFG.n_node_labels, allow_oversize=True)

    ref_fn = jax.jit(pair_score)

    def run_sparse():
        return unpack_pair_scores(ops.pair_score_sparse(params, packed_sp),
                                  packed_sp, batch)

    def run_packed_dense():
        return unpack_pair_scores(ops.pair_score_packed(params, packed_dn),
                                  packed_dn, batch)

    def run_bucketed(pair_fn):
        out = np.zeros(batch, np.float32)
        for b, (lhs, rhs, idxs) in buckets.items():
            out[idxs] = np.asarray(pair_fn(params, lhs.adj, lhs.feats,
                                           lhs.mask, rhs.adj, rhs.feats,
                                           rhs.mask))
        return out

    policies = {
        "sparse": run_sparse,
        "packed_dense": run_packed_dense,
        "bucketed_mega": lambda: run_bucketed(ops.pair_score_megakernel),
        "two_kernel": lambda: run_bucketed(ops.simgnn_pair_score_kernel),
    }

    # Aggregation-FLOPs accounting (MACs over all GCN layers; the feature
    # transform H·W is identical across policies and excluded). Dense pays
    # the full padded adjacency block per layer; sparse pays one MAC per
    # padded CSR slot (NB·D) plus the overflow contraction (E_ov·NB per
    # layer as a one-hot matmul) — padding counted honestly.
    sum_f = sum(CFG.gcn_dims)
    t_tiles = int(np.asarray(packed_sp.mask1).shape[0])
    nnz = sstats["nnz_lhs"] + sstats["nnz_rhs"]
    ov_budget = sstats["overflow_budget"]
    agg_macs = {
        "sparse": 2 * t_tiles * (edge_budget + ov_budget * node_budget)
                  * sum_f,
        "packed_dense": 2 * t_tiles * node_budget ** 2 * sum_f,
        "bucketed_mega": sum(2 * b * b * sum_f * len(idxs)
                             for b, (_, _, idxs) in buckets.items()),
    }
    agg_macs["two_kernel"] = agg_macs["bucketed_mega"]

    # The engine's own decision for this stream (DESIGN.md §9 dispatch).
    plan = ScoringEngine(params, CFG, node_budget=node_budget).plan(pairs)

    s_ref = run_bucketed(ref_fn)
    records, seconds, parity = [], {}, {}
    for name, fn in policies.items():
        parity[name] = float(np.max(np.abs(fn() - s_ref)))   # also warms
        seconds[name] = time_fn(fn, warmup=1, iters=iters)
        rec = {"bench": "sparse", "stream": "search", "batch": batch,
               "policy": name,
               "target_avg_degree": avg_degree,
               "measured_avg_degree": round(measured_degree, 3),
               "measured_density": round(measured_density, 5),
               "seconds_per_call": round(seconds[name], 6),
               "us_per_pair": round(1e6 * seconds[name] / batch, 3),
               "pairs_per_s": round(batch / seconds[name], 1),
               "max_abs_err_vs_ref": parity[name],
               "agg_macs": agg_macs[name],
               "flops_avoided_vs_packed_dense": round(
                   1.0 - agg_macs[name] / agg_macs["packed_dense"], 4)}
        if name == "sparse":
            rec.update(node_budget=node_budget, edge_budget=edge_budget,
                       nbr_budget=edge_budget // node_budget,
                       overflow_budget=ov_budget,
                       n_tiles=t_tiles,
                       nnz_lhs=sstats["nnz_lhs"], nnz_rhs=sstats["nnz_rhs"],
                       adj_density_lhs=round(sstats["density_lhs"], 5),
                       adj_density_rhs=round(sstats["density_rhs"], 5),
                       edge_occupancy=round(sstats["edge_occupancy"], 4),
                       nnz_macs=nnz * sum_f,
                       planner_seconds=round(sparse_planner_s, 6))
        elif name == "packed_dense":
            rec.update(node_budget=node_budget, n_tiles=t_tiles,
                       occupancy=round(dstats["occupancy_lhs"], 4),
                       planner_seconds=round(dense_planner_s, 6))
        else:
            rec.update(n_buckets=len(buckets))
        records.append(rec)
        print("BENCH " + json.dumps(rec))

    summary = {"bench": "sparse", "stream": "search", "batch": batch,
               "policy": "summary",
               "measured_avg_degree": round(measured_degree, 3),
               "engine_auto_path": plan.path,
               "engine_reason": plan.reason,
               "sparse_speedup_vs_packed_dense":
                   round(seconds["packed_dense"] / seconds["sparse"], 3),
               "sparse_speedup_vs_bucketed_mega":
                   round(seconds["bucketed_mega"] / seconds["sparse"], 3),
               "sparse_speedup_vs_two_kernel":
                   round(seconds["two_kernel"] / seconds["sparse"], 3),
               "sparse_parity": parity["sparse"],
               "worst_kernel_parity": max(parity.values())}
    records.append(summary)
    print("BENCH " + json.dumps(summary))
    return records, summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small batch, few iters")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero on sparse parity drift or sparse "
                         "slower than packed-dense at avg degree <= 4")
    ap.add_argument("--out", type=str, default=None,
                    help="write BENCH records to this JSON file")
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--node-budget", type=int, default=64)
    ap.add_argument("--avg-degree", type=float, default=2.1,
                    help="target stream degree (AIDS-like 2.1 default)")
    ap.add_argument("--iters", type=int, default=5)
    a = ap.parse_args()
    if a.tiny:
        records, summary = run(batch=48, iters=2, avg_degree=a.avg_degree)
    else:
        records, summary = run(batch=a.batch, node_budget=a.node_budget,
                               iters=a.iters, avg_degree=a.avg_degree)
    failures = []
    if summary["sparse_parity"] > PARITY_BOUND:
        failures.append(f"sparse-vs-reference parity "
                        f"{summary['sparse_parity']:.2e} > "
                        f"{PARITY_BOUND:.0e}")
    if (summary["measured_avg_degree"] <= 4.0
            and summary["sparse_speedup_vs_packed_dense"] < 1.0):
        failures.append(
            "sparse slower than packed-dense on a sparse stream "
            f"({summary['sparse_speedup_vs_packed_dense']}x at degree "
            f"{summary['measured_avg_degree']})")
    finish_check(records, failures, bench="sparse", out=a.out, check=a.check)


if __name__ == "__main__":
    main()
