"""Three-way SimGNN pair-scoring comparison, per size bucket.

Paths compared (all scoring the same batch of graph pairs):

  reference_jit — `core.simgnn.pair_score` under one jax.jit (XLA fusion);
  two_kernel    — `ops.simgnn_pair_score_kernel`: fused GCN+Att pallas_call,
                  graph embeddings round-trip HBM, fused NTN+FCN pallas_call;
  megakernel    — `ops.pair_score_megakernel`: ONE pallas_call, nothing but
                  the final scores touches HBM (DESIGN.md §7).

On this CPU-only container the kernels run in interpret mode, so the numbers
are the *trajectory baseline* (relative structure, dispatch counts, graph
sizes), not TPU times. Emits one `BENCH {json}` line per (bucket, path) so
the perf trajectory is machine-readable across PRs.

Usage:  PYTHONPATH=src python benchmarks/megakernel.py [--tiny]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import numpy as np

if __package__ in (None, ""):   # `python benchmarks/megakernel.py` support
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import time_fn
from repro.configs.simgnn_aids import CONFIG as CFG
from repro.core.simgnn import init_simgnn_params, pair_score
from repro.data.graphs import bucketed_pair_batch
from repro.kernels import ops


def run(batch: int = 512, buckets=(8, 16, 32, 64), iters: int = 5):
    params = init_simgnn_params(jax.random.PRNGKey(0), CFG)
    ref_fn = jax.jit(pair_score)
    records = []
    for bucket in buckets:
        args = bucketed_pair_batch(bucket, bucket, batch, CFG.n_node_labels)
        bp = ops.megakernel_block_pairs(bucket)
        paths = {
            "reference_jit": lambda: ref_fn(params, *args),
            "two_kernel": lambda: ops.simgnn_pair_score_kernel(params, *args),
            "megakernel": lambda: ops.pair_score_megakernel(
                params, *args, block_pairs=bp),
        }
        s_ref = np.asarray(ref_fn(params, *args))
        seconds = {}
        for name, fn in paths.items():
            err = float(np.max(np.abs(np.asarray(fn()) - s_ref)))  # also warms
            seconds[name] = time_fn(fn, warmup=1, iters=iters)
            rec = {"bench": "megakernel", "bucket": bucket, "batch": batch,
                   "path": name,
                   "seconds_per_call": round(seconds[name], 6),
                   "us_per_pair": round(1e6 * seconds[name] / batch, 3),
                   "pairs_per_s": round(batch / seconds[name], 1),
                   "max_abs_err_vs_ref": err}
            records.append(rec)
            print("BENCH " + json.dumps(rec))
        rec = {"bench": "megakernel", "bucket": bucket, "batch": batch,
               "path": "summary",
               "mega_speedup_vs_two_kernel":
                   round(seconds["two_kernel"] / seconds["megakernel"], 3),
               "mega_speedup_vs_reference":
                   round(seconds["reference_jit"] / seconds["megakernel"], 3)}
        records.append(rec)
        print("BENCH " + json.dumps(rec))
    return records


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small batch, two buckets, few iters")
    ap.add_argument("--batch", type=int, default=512)
    a = ap.parse_args()
    if a.tiny:
        run(batch=32, buckets=(8, 16), iters=2)
    else:
        run(batch=a.batch)
