"""Paper Table 5 — flexibility across platforms.

The paper maps SPA-GCN to three FPGAs (KU15P / U50 / U280) that differ in
resources and memory bandwidth. The TPU-framework analogue: the same
pipeline on platforms differing in compute/bandwidth — measured host CPU,
one modeled v5e chip (roofline), and a v5e-8 slice (query replication =
the paper's 6-pipeline scale-out), plus the compiled mesh cells from the
dry-run artifacts as the "platform" axis at LM scale.
"""

from __future__ import annotations

import jax

from benchmarks.common import HBM_BW, PEAK_FLOPS_BF16, emit, time_fn
from benchmarks.simgnn_cost import per_query_bytes, per_query_flops
from repro.configs.simgnn_aids import CONFIG as CFG
from repro.core.simgnn import init_simgnn_params, pair_score
from repro.data.graphs import query_pairs
from repro.serve.batching import simgnn_query_server

BATCH = 256


def run():
    params = init_simgnn_params(jax.random.PRNGKey(0), CFG)
    pairs = query_pairs(21, BATCH)
    score = simgnn_query_server(params, CFG)
    score(pairs)    # warm
    t_cpu = time_fn(lambda: score(pairs), warmup=1, iters=5)
    cpu_qps = BATCH / t_cpu

    from benchmarks.simgnn_cost import DISPATCH_FLOOR_S, per_query_flops_mxu
    flops = per_query_flops(26)
    flops_mxu = per_query_flops_mxu(26, BATCH)
    bts = per_query_bytes(26, BATCH)
    # modeled chip time: MXU-padded compute vs HBM stream vs the amortized
    # dispatch floor (the overhead class the paper's Fig. 11 amortizes)
    t_chip = max(flops_mxu / PEAK_FLOPS_BF16, bts / HBM_BW,
                 DISPATCH_FLOOR_S / BATCH)
    terms = {"compute": flops_mxu / PEAK_FLOPS_BF16, "memory": bts / HBM_BW,
             "dispatch": DISPATCH_FLOOR_S / BATCH}
    bound = max(terms, key=terms.get)
    v5e_qps = 1.0 / t_chip
    emit("table5.host_cpu", 1e6 * t_cpu / BATCH, f"qps={cpu_qps:,.0f}")
    emit("table5.v5e_1chip_modeled", 1e6 * t_chip,
         f"qps={v5e_qps:,.0f}_bound={bound}_upper_bound")
    emit("table5.v5e_8chip_modeled", 1e6 * t_chip / 8,
         f"qps={8 * v5e_qps:,.0f}_paper_scaleout_6x")
    emit("table5.flops_per_query", 0.0,
         f"raw={flops:.3e}_mxu_padded={flops_mxu:.3e}")
    emit("table5.bytes_per_query", 0.0,
         f"{bts:.3e}_ai={flops / bts:.1f}_flops_per_byte")
    return {"cpu_qps": cpu_qps, "v5e_qps": v5e_qps, "bound": bound}


if __name__ == "__main__":
    run()
