"""1-vs-N similarity search: embedding-cached vs full-rescoring policies
(DESIGN.md §10).

The workload is the paper's end use: queries scored against a recurring
corpus (`data.graphs.zipf_query_stream` — Zipf-skewed picks over a fixed
corpus, fresh query graph per batch). Policies (see `_rotate` for which
batches each cycles):

  cached_warm    — `ScoringEngine` embedding-cached path with the corpus
                   pre-indexed (`serve.search.SimilaritySearchServer`): per
                   call, one query-side embedding miss plus the fused
                   NTN+FCN head; corpus embeddings never recompute.
  cached_cold    — same path with the cache cleared before every call: the
                   worst case (pays hashing AND every embedding) bounding
                   the cache's downside.
  uncached_sparse— packed-CSR sparse path (the engine's best full-rescoring
                   policy on this AIDS-like stream) recomputing both sides'
                   GCN+Att every call.
  two_kernel     — per-bucket fused GCN+Att then fused head (the §7-era
                   baseline).

Emits one `BENCH {json}` line per policy with measured cache hit rate and
the warm path's per-stage seconds (query embed / head / hashing overhead).
On this CPU-only container kernels run in interpret mode — numbers are the
trajectory baseline, not TPU times.

Usage:  PYTHONPATH=src python benchmarks/search.py [--tiny] [--check]
            [--batch 512] [--corpus 256] [--out search_bench.json]

A fifth `persist` record times the durable-index restart story
(DESIGN.md §13): `server.save()` -> cold-process `load()` of the verified
shards vs cold-process `index()` rebuild, with the loaded matrix's parity
vs the built one.

`--check` (CI gate): non-zero exit if the fused head drifts >1e-6 from the
reference NTN+FCN on identical embeddings, if warm cached end-to-end scores
drift >1e-6 from the reference scorer, if the loaded index drifts >1e-6
from the built one (it round-trips raw float32, so anything non-zero is a
store bug), if the warm cached policy is not >= 5x faster than uncached
packed-sparse, or if loading the persisted index is slower than rebuilding.

`--retrieval` switches to the two-stage retrieval benchmark (DESIGN.md
§14): blocked streaming top-M prefilter + exact NTN/FCN rerank vs the
exact full-head scan, on a corpus sized for the scan term to matter
(default 4096). Its `--check` gates: two_stage recall@10 >= 0.99 at
M=64, M=N ranking bit-identical to exact, and (corpus >= 4096 only)
two_stage strictly faster than the exact scan.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

import jax
import numpy as np

if __package__ in (None, ""):   # `python benchmarks/search.py` support
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import finish_check, time_fn
from repro.configs.simgnn_aids import CONFIG as CFG
from repro.core.engine import ScoringEngine
from repro.core.simgnn import fcn_head, init_simgnn_params, ntn_scores
from repro.data.graphs import random_graph, zipf_corpus, zipf_query_stream
from repro.kernels.retrieval import retrieval_block_cols
from repro.serve.search import SimilaritySearchServer

PARITY_BOUND = 1e-6
SPEEDUP_BOUND = 5.0

# Two-stage retrieval gates (DESIGN.md §14 / ISSUE 8 acceptance).
RECALL_BOUND = 0.99               # recall@10 floor at M = RETRIEVAL_M
RETRIEVAL_M = 64                  # the gated shortlist size
RETRIEVAL_GATED_CORPUS = 4096     # speedup is only a contract at scale


def _rotate(batches, fn):
    """Step fn through pre-built batches, wrapping around.

    The warm policy gets warmup+iters distinct batches so every timed call
    sees a query the cache has never held (a repeated query would hit and
    flatter the warm numbers to head-only). The cache-less policies cycle
    the warmup batches instead: repeats cannot flatter a policy with no
    cache, and recurring shapes let trace/compile amortize the way a
    steady-state deployment would — fresh batches there would bill jit
    retracing (per novel bucket/miss-count composition) as per-call cost."""
    state = {"i": 0}

    def call():
        b = batches[state["i"] % len(batches)]
        state["i"] += 1
        return fn(b)
    return call


def run(batch: int = 512, n_corpus: int = 256, n_query_batches: int = 4,
        iters: int = 8, seed: int = 71, cache_size: int = 4096):
    params = init_simgnn_params(jax.random.PRNGKey(0), CFG)
    stream = zipf_query_stream(seed, batch, n_corpus=n_corpus)
    # warmup runs n_query_batches calls, timing runs iters more: one
    # distinct batch per call so no timed query is ever resident already.
    batches = [next(stream) for _ in range(n_query_batches + iters)]
    corpus = zipf_corpus(seed, n_corpus)
    mean_unique = float(np.mean([b["unique_frac"] for b in batches]))

    # ---------------------------------------------------------- the policies
    server = SimilaritySearchServer(params, CFG, cache_size=cache_size)
    t0 = time.perf_counter()
    server.index(corpus)
    index_seconds = time.perf_counter() - t0
    warm = server.engine

    # Persisted-index restart costs (DESIGN.md §13): save the resident
    # index, then time two cold restarts — one rebuilding from the corpus
    # (fresh engine: pays embed jit + every GCN+Att), one adopting the
    # verified on-disk shards. Both are one-shot costs, timed once, and
    # parity is checked bitwise-ish (raw float32 round-trip -> 0.0).
    idx_dir = tempfile.mkdtemp(prefix="simgnn_index_")
    try:
        t0 = time.perf_counter()
        server.save(idx_dir)
        save_seconds = time.perf_counter() - t0
        rebuild_srv = SimilaritySearchServer(params, CFG,
                                             cache_size=cache_size)
        t0 = time.perf_counter()
        rebuild_srv.index(corpus)
        rebuild_seconds = time.perf_counter() - t0
        load_srv = SimilaritySearchServer(params, CFG,
                                          cache_size=cache_size)
        t0 = time.perf_counter()
        load_srv.load(idx_dir, corpus)
        load_seconds = time.perf_counter() - t0
        persist_parity = float(np.max(np.abs(
            load_srv.corpus_emb - server.corpus_emb)))
        persist_recovered = load_srv.stats.shards_recovered
    finally:
        shutil.rmtree(idx_dir, ignore_errors=True)

    # validation="off" on the timed comparators: trusted generator stream,
    # and the per-call adjacency scan would tax every policy's timings.
    cold = ScoringEngine(params, CFG, path="embedding_cache",
                         cache_size=cache_size, validation="off")
    sparse = ScoringEngine(params, CFG, path="packed_sparse",
                           validation="off")
    twok = ScoringEngine(params, CFG, path="two_kernel", validation="off")

    def run_cold(b):
        # Genuinely cold: drop the LRU AND the per-dict `graph_key` memos,
        # so every call re-pays WL hashing like a client with fresh dicts.
        cold.cache.clear()
        for g1, g2 in b["pairs"]:
            g1.pop("_graph_key", None)
            g2.pop("_graph_key", None)
        return cold.score(b["pairs"])

    rep_batches = batches[:n_query_batches]   # cycled by cache-less policies
    policies = {
        "cached_warm": _rotate(batches, lambda b: warm.score(b["pairs"])),
        "cached_cold": _rotate(rep_batches, run_cold),
        "uncached_sparse": _rotate(rep_batches,
                                   lambda b: sparse.score(b["pairs"])),
        "two_kernel": _rotate(rep_batches, lambda b: twok.score(b["pairs"])),
    }

    # Pre-warm the query-side embed executables for every size bucket so no
    # timed call pays compilation (fresh queries land in arbitrary buckets).
    rng = np.random.default_rng(seed + 1)
    for n in (6, 12, 24, 48):
        warm.embed_graphs([random_graph(rng, n)])
        cold.embed_graphs([random_graph(rng, n)])

    # --------------------------------------------------------- timed sweep
    warm.cache.hits = warm.cache.misses = 0     # count the timed phase only
    records, seconds = [], {}
    warmup = n_query_batches
    for name, fn in policies.items():
        seconds[name] = time_fn(fn, warmup=warmup, iters=iters)
    hit_stats = warm.cache.stats()

    # ------------------------------------------------------------- parity
    # After the sweep on purpose: the parity embeds would otherwise make the
    # timed batches' queries resident and turn the warm timing head-only.
    ref = ScoringEngine(params, CFG, path="reference")
    s_ref = ref.score(batches[0]["pairs"])
    s_warm = warm.score(batches[0]["pairs"])
    e2e_parity = float(np.max(np.abs(s_warm - s_ref)))
    # Head-stage parity: fused head kernel vs reference NTN+FCN on the SAME
    # embeddings (isolates the per-query hot stage from the embed flavor).
    emb_q = warm.embed_graphs([b["pairs"][0][0] for b in batches])
    emb_c = warm.embed_graphs(corpus)
    h1 = np.repeat(emb_q, -(-len(emb_c) // len(emb_q)), 0)[: len(emb_c)]
    h2 = emb_c
    head_kernel = warm.pair_scores_from_embeddings(h1, h2)
    head_ref = np.asarray(fcn_head(params["fcn"], ntn_scores(
        params["ntn"], h1.astype(np.float32), h2.astype(np.float32))))
    head_parity = float(np.max(np.abs(head_kernel - head_ref)))

    # Per-stage split of the warm service call (embed query / head / sort),
    # measured through the server on queries the cache has never seen (the
    # timed batches' queries are resident by now and would flatter embed).
    server.stats.embed_seconds = server.stats.head_seconds = 0.0
    server.stats.topk_seconds = 0.0
    server.stats.queries = 0
    for _ in range(n_query_batches):
        server.topk(next(stream)["query"], k=10)
    q = max(server.stats.queries, 1)
    stage = {"embed_s_per_query": server.stats.embed_seconds / q,
             "head_s_per_query": server.stats.head_seconds / q,
             "topk_s_per_query": server.stats.topk_seconds / q}

    for name in policies:
        rec = {"bench": "search", "stream": "zipf", "batch": batch,
               "n_corpus": n_corpus, "policy": name,
               "mean_unique_frac": round(mean_unique, 4),
               "seconds_per_call": round(seconds[name], 6),
               "us_per_pair": round(1e6 * seconds[name] / batch, 3),
               "pairs_per_s": round(batch / seconds[name], 1)}
        if name == "cached_warm":
            rec.update(cache=hit_stats, hit_rate=hit_stats["hit_rate"],
                       index_seconds=round(index_seconds, 6),
                       head_parity=head_parity, e2e_parity=e2e_parity,
                       **{k: round(v, 6) for k, v in stage.items()})
        records.append(rec)
        print("BENCH " + json.dumps(rec))

    # Restart-cost policies (one-shot timings, not per-call medians).
    persist = {"bench": "search", "stream": "zipf", "batch": batch,
               "n_corpus": n_corpus, "policy": "persist",
               "save_seconds": round(save_seconds, 6),
               "load_seconds": round(load_seconds, 6),
               "rebuild_seconds": round(rebuild_seconds, 6),
               "load_vs_rebuild_speedup":
                   round(rebuild_seconds / max(load_seconds, 1e-9), 3),
               "persist_parity": persist_parity,
               "shards_recovered": persist_recovered}
    records.append(persist)
    print("BENCH " + json.dumps(persist))

    summary = {"bench": "search", "stream": "zipf", "batch": batch,
               "policy": "summary", "n_corpus": n_corpus,
               "hit_rate": hit_stats["hit_rate"],
               "head_parity": head_parity, "e2e_parity": e2e_parity,
               "persist_parity": persist_parity,
               "load_seconds": round(load_seconds, 6),
               "rebuild_seconds": round(rebuild_seconds, 6),
               "load_vs_rebuild_speedup":
                   round(rebuild_seconds / max(load_seconds, 1e-9), 3),
               "warm_speedup_vs_uncached_sparse":
                   round(seconds["uncached_sparse"] / seconds["cached_warm"], 3),
               "warm_speedup_vs_two_kernel":
                   round(seconds["two_kernel"] / seconds["cached_warm"], 3),
               "warm_speedup_vs_cold":
                   round(seconds["cached_cold"] / seconds["cached_warm"], 3),
               "index_seconds": round(index_seconds, 6),
               **{k: round(v, 6) for k, v in stage.items()}}
    records.append(summary)
    print("BENCH " + json.dumps(summary))
    return records, summary


def run_retrieval(n_corpus: int = 4096, n_queries: int = 8,
                  n_query_batches: int = 4, iters: int = 6, seed: int = 73,
                  k: int = 10, prefilter_m: int = RETRIEVAL_M):
    """Two-stage retrieval vs the exact full scan (DESIGN.md §14).

    Policies (each call serves a batch of `n_queries` resident queries):

      exact_scan — mode="exact": per query, one fused NTN+FCN head call
                   over all N corpus rows (N pairs per query).
      two_stage  — mode="two_stage": ONE blocked streaming top-M
                   prefilter launch over the whole batch, then ONE
                   batched exact rerank head call over the Q*M survivors.

    Query batches are prebuilt and cycled so embeddings are cache-warm
    in BOTH policies (and the one-time prefilter calibration lands in
    warmup): the timed difference is the scan itself — the term that
    scales with the corpus. Recall@k, the recall-vs-M curve, and the
    M=N bit-parity check run on fresh queries after the sweep.
    """
    params = init_simgnn_params(jax.random.PRNGKey(0), CFG)
    corpus = zipf_corpus(seed, n_corpus)
    server = SimilaritySearchServer(
        params, CFG, cache_size=n_corpus + 8 * n_queries * n_query_batches)
    t0 = time.perf_counter()
    server.index(corpus)
    index_seconds = time.perf_counter() - t0

    # Fresh query graphs (the stream's corpus only feeds its pair lists,
    # which this benchmark never scores — batch=2 keeps it cheap).
    stream = zipf_query_stream(seed + 1, 2, n_corpus=32)
    qbatches = [[next(stream)["query"] for _ in range(n_queries)]
                for _ in range(n_query_batches)]

    m = min(prefilter_m, n_corpus)
    policies = {
        "exact_scan": _rotate(qbatches,
                              lambda b: server.search(b, k=k, mode="exact")),
        "two_stage": _rotate(qbatches,
                             lambda b: server.search(b, k=k,
                                                     mode="two_stage",
                                                     prefilter_m=m)),
    }
    seconds = {}
    for name, fn in policies.items():
        seconds[name] = time_fn(fn, warmup=n_query_batches, iters=iters)
    calib = server._calibration()

    # Per-stage split of the two_stage call, re-measured after the sweep
    # on the same (cache-warm) batches so the split reflects steady state.
    st = server.stats
    st.embed_seconds = st.prefilter_seconds = st.gather_seconds = 0.0
    st.rerank_seconds = st.topk_seconds = 0.0
    nq0 = st.queries
    for b in qbatches:
        server.search(b, k=k, mode="two_stage", prefilter_m=m)
    nstage = max(st.queries - nq0, 1)
    stage = {"embed_s_per_query": st.embed_seconds / nstage,
             "prefilter_s_per_query": st.prefilter_seconds / nstage,
             "gather_s_per_query": st.gather_seconds / nstage,
             "rerank_s_per_query": st.rerank_seconds / nstage,
             "topk_s_per_query": st.topk_seconds / nstage}

    # Recall@k on FRESH queries vs the exact ranking, plus the
    # recall-vs-M curve (candidate sets are nested in M, so it must be
    # monotone non-decreasing — tests/test_retrieval.py asserts that).
    fresh = [next(stream)["query"] for _ in range(2 * n_queries)]
    exact = server.search(fresh, k=k, mode="exact")

    def recall_at(mm):
        got = server.search(fresh, k=k, mode="two_stage", prefilter_m=mm)
        return float(np.mean([
            len(set(g[0].tolist()) & set(e[0].tolist()))
            / max(len(e[0]), 1)
            for g, e in zip(got, exact)]))

    recall = recall_at(m)
    curve = {str(mm): round(recall_at(mm), 4)
             for mm in (8, 16, 32, 64, 128) if mm <= n_corpus}

    # M = N parity: the shortlist is the whole corpus (in ascending
    # order), so scores and ranking must be BIT-identical to exact.
    ex = server.search(fresh[:2], k=k, mode="exact")
    ts = server.search(fresh[:2], k=k, mode="two_stage",
                       prefilter_m=n_corpus)
    mn_bit_identical = all(
        np.array_equal(e[0], t[0])
        and np.asarray(e[1]).tobytes() == np.asarray(t[1]).tobytes()
        for e, t in zip(ex, ts))

    records = []
    speedup = seconds["exact_scan"] / max(seconds["two_stage"], 1e-12)
    for name in policies:
        rec = {"bench": "search", "mode": "retrieval", "stream": "zipf",
               "policy": name, "n_corpus": n_corpus,
               "n_queries": n_queries, "k": k,
               "seconds_per_call": round(seconds[name], 6),
               "ms_per_query": round(1e3 * seconds[name] / n_queries, 4)}
        if name == "two_stage":
            rec.update(prefilter_m=m,
                       block_cols=retrieval_block_cols(
                           n_corpus, shard_rows=server.shard_rows),
                       proxy=calib["proxy"], calib_r2=calib.get("r2"),
                       recall_linear=calib.get("recall_linear"),
                       **{kk: round(v, 6) for kk, v in stage.items()})
        records.append(rec)
        print("BENCH " + json.dumps(rec))

    summary = {"bench": "search", "mode": "retrieval", "stream": "zipf",
               "policy": "retrieval_summary", "n_corpus": n_corpus,
               "n_queries": n_queries, "k": k, "prefilter_m": m,
               "proxy": calib["proxy"],
               "two_stage_speedup_vs_exact": round(speedup, 3),
               f"recall_at_{k}": round(recall, 4),
               "recall_vs_m": curve,
               "mn_bit_identical": bool(mn_bit_identical),
               "prefilter_degraded": server.stats.prefilter_degraded,
               "index_seconds": round(index_seconds, 6)}
    records.append(summary)
    print("BENCH " + json.dumps(summary))
    return records, summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small batch/corpus, few iters")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero on parity drift or warm speedup "
                         f"< {SPEEDUP_BOUND:g}x vs uncached packed-sparse")
    ap.add_argument("--out", type=str, default=None,
                    help="write BENCH records to this JSON file")
    ap.add_argument("--retrieval", action="store_true",
                    help="two-stage retrieval benchmark (DESIGN.md §14): "
                         "blocked top-M prefilter + exact rerank vs the "
                         "exact full scan; gates recall@10 >= "
                         f"{RECALL_BOUND:g} at M={RETRIEVAL_M}, M=N "
                         "bit-parity, and speedup at corpus >= "
                         f"{RETRIEVAL_GATED_CORPUS}")
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--corpus", type=int, default=None,
                    help="corpus size (default 256; 4096 with --retrieval)")
    ap.add_argument("--cache-size", type=int, default=4096)
    ap.add_argument("--iters", type=int, default=8)
    a = ap.parse_args()
    if a.retrieval:
        if a.tiny:
            records, summary = run_retrieval(n_corpus=128,
                                             n_query_batches=2, iters=2)
        else:
            records, summary = run_retrieval(
                n_corpus=a.corpus or RETRIEVAL_GATED_CORPUS, iters=a.iters)
        failures = []
        if summary["recall_at_10"] < RECALL_BOUND:
            failures.append(
                f"two_stage recall@10 {summary['recall_at_10']} < "
                f"{RECALL_BOUND:g} at M={summary['prefilter_m']} "
                f"(proxy {summary['proxy']})")
        if not summary["mn_bit_identical"]:
            failures.append("M=N two_stage ranking is not bit-identical "
                            "to the exact scan")
        # The speedup is an at-scale contract: below the gated corpus the
        # fixed per-call dispatch overhead drowns the scan term.
        if (summary["n_corpus"] >= RETRIEVAL_GATED_CORPUS
                and summary["two_stage_speedup_vs_exact"] < 1.0):
            failures.append(
                f"two_stage only {summary['two_stage_speedup_vs_exact']}x "
                f"vs the exact scan at corpus {summary['n_corpus']} "
                "(bound 1.0x)")
        finish_check(records, failures, bench="search", out=a.out,
                     check=a.check)
        return
    if a.tiny:
        records, summary = run(batch=48, n_corpus=32, n_query_batches=2,
                               iters=2)
    else:
        records, summary = run(batch=a.batch, n_corpus=a.corpus or 256,
                               iters=a.iters, cache_size=a.cache_size)
    failures = []
    if summary["head_parity"] > PARITY_BOUND:
        failures.append(f"head-stage parity {summary['head_parity']:.2e}"
                        f" > {PARITY_BOUND:.0e}")
    if summary["e2e_parity"] > PARITY_BOUND:
        failures.append(f"warm cached end-to-end parity "
                        f"{summary['e2e_parity']:.2e} > "
                        f"{PARITY_BOUND:.0e}")
    if summary["persist_parity"] > PARITY_BOUND:
        failures.append(f"persisted-index parity {summary['persist_parity']:.2e}"
                        f" > {PARITY_BOUND:.0e} (load != build)")
    # The 5x bound is an at-scale contract (batch 512): at --tiny sizes
    # per-call dispatch overhead dominates every policy equally and the
    # ratio is noise, so tiny checks gate parity only.
    if (not a.tiny
            and summary["warm_speedup_vs_uncached_sparse"] < SPEEDUP_BOUND):
        failures.append(
            "warm cached path only "
            f"{summary['warm_speedup_vs_uncached_sparse']}x vs uncached "
            f"packed-sparse (bound {SPEEDUP_BOUND:g}x)")
    # Loading the verified shards must beat re-embedding the corpus in a
    # fresh process, or persistence buys nothing (DESIGN.md §13). Skipped
    # at --tiny sizes like the other speed gates.
    if not a.tiny and summary["load_vs_rebuild_speedup"] < 1.0:
        failures.append(
            f"persisted-index load ({summary['load_seconds']}s) slower "
            f"than rebuild ({summary['rebuild_seconds']}s)")
    finish_check(records, failures, bench="search", out=a.out, check=a.check)


if __name__ == "__main__":
    main()
