"""Paper Table 6 — SPA-GCN vs CPU and GPU.

The paper's PyG-CPU baseline runs SimGNN as a sequence of separate kernels
with per-stage dispatch (their profiling: 225 kernel launches of ~4.6 KFLOPs
on GPU, <=6% utilization). The analogue pair here:

  pyg_like   : per-stage jit calls, per-layer sync, serial graphs, 64-pad
               (the paper-baseline path from table4)
  spa_gcn    : fused + batched + bucketed pipeline (ours)

measured on the same host CPU, plus the modeled v5e chip. Paper reference
points: 18.2x over 20-core Xeon, 26.9x over V100.
"""

from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from benchmarks.simgnn_cost import per_query_bytes, per_query_flops
from benchmarks.common import HBM_BW, PEAK_FLOPS_BF16
from benchmarks.table4 import baseline_scores, _pad_all
from repro.configs.simgnn_aids import CONFIG as CFG
from repro.core.simgnn import init_simgnn_params
from repro.data.graphs import query_pairs
from repro.serve.batching import simgnn_query_server

BATCH = 256


def run():
    params = init_simgnn_params(jax.random.PRNGKey(0), CFG)
    pairs = query_pairs(31, BATCH)
    lhs, rhs = _pad_all(pairs, 64)

    baseline_scores(params, lhs, rhs)            # warm
    t_pyg = time_fn(lambda: baseline_scores(params, lhs, rhs),
                    warmup=1, iters=5)

    score = simgnn_query_server(params, CFG)
    score(pairs)                                  # warm
    t_ours = time_fn(lambda: score(pairs), warmup=1, iters=5)

    from benchmarks.simgnn_cost import DISPATCH_FLOOR_S, per_query_flops_mxu
    flops_mxu = per_query_flops_mxu(26, BATCH)
    bts = per_query_bytes(26, BATCH)
    t_v5e = max(flops_mxu / PEAK_FLOPS_BF16, bts / HBM_BW,
                DISPATCH_FLOOR_S / BATCH) * BATCH

    emit("table6.pyg_like_cpu", 1e6 * t_pyg / BATCH, "speedup=1.00x")
    emit("table6.spa_gcn_cpu", 1e6 * t_ours / BATCH,
         f"speedup={t_pyg / t_ours:.2f}x")
    emit("table6.spa_gcn_v5e_modeled", 1e6 * t_v5e / BATCH,
         f"speedup={t_pyg / t_v5e:.0f}x_upper_bound_paper_18.2x_cpu_26.9x_gpu")
    return {"t_pyg": t_pyg, "t_ours": t_ours, "t_v5e": t_v5e}


if __name__ == "__main__":
    run()
