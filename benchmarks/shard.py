"""Device-sharded execution benchmark + CI gate (DESIGN.md §16).

Runs the sharded parity matrix on a simulated 8-device host (the opt-in
`--xla_force_host_platform_device_count` flag, armed before jax touches a
backend): packed score and loss_and_grad at device counts {1, 2, 8} against
the single-device engine, the per-shard search scans against the unsharded
two-stage path, and an injected dead shard through the §12 collapse rung.
Emits one `BENCH {json}` line per measurement — multi-device records carry
the planner's per-device tile occupancy. On this CPU-only container the
mesh is simulated, so wall times are trajectory baselines, not speedups.

Usage:  PYTHONPATH=src python benchmarks/shard.py [--check]
            [--batch 96] [--out shard_bench.json]

`--check` (CI gate): non-zero exit if
  * score or grad parity vs single-device drifts above 1e-6 at any of
    device counts {1, 2, 8};
  * the per-shard search top-k is not bit-identical to the unsharded
    two-stage path;
  * an injected dead shard fails the batch instead of degrading (the
    collapse must serve exact scores and be counted on health()).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

if __package__ in (None, ""):   # `python benchmarks/shard.py` support
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.distributed.sharding import force_host_device_count  # noqa: E402

N_DEVICES = force_host_device_count(8)      # before any other jax use

import jax                                   # noqa: E402
import numpy as np                           # noqa: E402

from benchmarks.common import finish_check, time_fn              # noqa: E402
from repro.configs.simgnn_aids import CONFIG as CFG              # noqa: E402
from repro.core.engine import ScoringEngine                      # noqa: E402
from repro.core.simgnn import init_simgnn_params                 # noqa: E402
from repro.data.graphs import random_graph, search_pairs         # noqa: E402
from repro.distributed.sharding import tile_runtime              # noqa: E402
from repro.serve.search import SimilaritySearchServer            # noqa: E402
from repro.testing import faults                                 # noqa: E402

PARITY_BOUND = 1e-6
DEVICE_COUNTS = (1, 2, 8)


def _maxdiff(a, b) -> float:
    return float(np.max(np.abs(np.asarray(a, np.float64)
                               - np.asarray(b, np.float64))))


def _tree_maxdiff(a, b) -> float:
    return max(_maxdiff(x, y) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def run(batch: int = 96, seed: int = 61, iters: int = 3):
    params = init_simgnn_params(jax.random.PRNGKey(0), CFG)
    pairs = search_pairs(seed, batch, avg_degree=2.1)
    targets = np.linspace(0.0, 1.0, batch).astype(np.float32)
    records, failures = [], []

    base = {p: ScoringEngine(params, CFG, path=p)
            for p in ("packed_sparse", "packed_dense")}
    ref_scores = {p: e.score(pairs) for p, e in base.items()}
    ref_sse, ref_grads = base["packed_sparse"].loss_and_grad(pairs, targets)

    for nd in DEVICE_COUNTS:
        if nd > N_DEVICES:
            failures.append(f"host exposes {N_DEVICES} devices, need {nd}")
            continue
        rt = tile_runtime(nd)
        for path in ("packed_sparse", "packed_dense"):
            eng = ScoringEngine(params, CFG, path=path, runtime=rt)
            s = eng.score(pairs)
            wall = time_fn(lambda e=eng: e.score(pairs), warmup=1,
                           iters=iters)
            diff = _maxdiff(s, ref_scores[path])
            ps = eng.last_pack_stats or {}
            rec = {"bench": "shard", "policy": f"score:{path}",
                   "devices": nd, "batch": batch,
                   "wall_s": round(wall, 6), "score_maxdiff": diff,
                   "tiles": ps.get("tiles"),
                   "tiles_padded": ps.get("tiles_padded"),
                   "device_occupancy": ps.get("device_occupancy",
                                              [1.0] * nd)}
            records.append(rec)
            print("BENCH " + json.dumps(rec))
            if diff > PARITY_BOUND:
                failures.append(f"score:{path}@{nd}d parity {diff:.2e} "
                                f"> {PARITY_BOUND:g}")

        eng = ScoringEngine(params, CFG, path="packed_sparse", runtime=rt)
        s, g = eng.loss_and_grad(pairs, targets)
        wall = time_fn(lambda e=eng: e.loss_and_grad(pairs, targets)[0],
                       warmup=1, iters=iters)
        sdiff, gdiff = _maxdiff(s, ref_sse), _tree_maxdiff(g, ref_grads)
        ps = eng.last_pack_stats or {}
        rec = {"bench": "shard", "policy": "train:packed_sparse",
               "devices": nd, "batch": batch, "wall_s": round(wall, 6),
               "loss_maxdiff": sdiff, "grad_maxdiff": gdiff,
               "tiles": ps.get("tiles"),
               "tiles_padded": ps.get("tiles_padded")}
        records.append(rec)
        print("BENCH " + json.dumps(rec))
        if max(sdiff, gdiff) > PARITY_BOUND:
            failures.append(f"train@{nd}d parity loss {sdiff:.2e} / grad "
                            f"{gdiff:.2e} > {PARITY_BOUND:g}")

    # ---- per-shard search scans vs the unsharded two-stage path --------
    rng = np.random.default_rng(seed)
    corpus = [random_graph(rng, int(rng.integers(6, 24)), avg_degree=4)
              for _ in range(128)]
    queries = [random_graph(rng, int(rng.integers(6, 24)), avg_degree=4)
               for _ in range(8)]
    ref_srv = SimilaritySearchServer(params, CFG, shard_rows=16)
    ref_srv.index(corpus)
    want = ref_srv.search(queries, k=10, mode="two_stage", prefilter_m=32)
    nd = min(8, N_DEVICES)
    srv = SimilaritySearchServer(params, CFG, shard_rows=16,
                                 runtime=tile_runtime(nd))
    srv.index(corpus)
    got = srv.search(queries, k=10, mode="two_stage", prefilter_m=32)
    identical = all(np.array_equal(gi, wi) and np.array_equal(gs, ws)
                    for (wi, ws), (gi, gs) in zip(want, got))
    wall = time_fn(lambda: srv.search(queries, k=10, mode="two_stage",
                                      prefilter_m=32)[0][0],
                   warmup=1, iters=iters)
    rec = {"bench": "shard", "policy": "search:two_stage",
           "devices": nd, "spans": srv.health()["prefilter"]["spans"],
           "corpus": len(corpus), "queries": len(queries),
           "wall_s": round(wall, 6), "topk_bit_identical": identical}
    records.append(rec)
    print("BENCH " + json.dumps(rec))
    if not identical:
        failures.append("per-shard search top-k differs from the "
                        "unsharded two-stage path")

    # ---- dead shard degrades, never fails ------------------------------
    nd = min(2, N_DEVICES)
    eng = ScoringEngine(params, CFG, path="packed_sparse",
                        runtime=tile_runtime(nd))
    try:
        with faults.inject("sharded:packed_sparse", "raise", times=1):
            s = eng.score(pairs)
        diff = _maxdiff(s, ref_scores["packed_sparse"])
        counted = (eng.health()["counters"]
                   .get(f"errors:packed_sparse@{nd}d", 0))
        degraded = list(eng.last_plan.degraded_from)
        ok = diff <= PARITY_BOUND and counted >= 1
    except Exception as exc:                          # noqa: BLE001
        diff, counted, degraded, ok = None, 0, [], False
        failures.append(f"dead shard failed the batch: {exc!r}")
    rec = {"bench": "shard", "policy": "fault:dead_shard", "devices": nd,
           "score_maxdiff": diff, "degraded_from": degraded,
           "error_counted": counted, "ok": ok}
    records.append(rec)
    print("BENCH " + json.dumps(rec))
    if not ok and not any("dead shard failed" in f for f in failures):
        failures.append("dead shard did not degrade cleanly "
                        f"(maxdiff {diff}, counted {counted})")

    return records, failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=96)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--seed", type=int, default=61)
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    records, failures = run(batch=args.batch, seed=args.seed,
                            iters=args.iters)
    finish_check(records, failures, bench="shard", out=args.out,
                 check=args.check)


if __name__ == "__main__":
    main()
