"""Training-step policy comparison on the AIDS-like pair stream
(DESIGN.md §11): the engine-routed custom-VJP packed paths against the
dense-reference autodiff step.

Policies (all taking the SAME raw {pairs, target} batch through one full
train step — host prep + forward + backward + AdamW update):

  dense_reference  — `jax.value_and_grad(simgnn_loss)` on the globally
                     padded one-hot dense batch (the pre-§11 training path);
  engine_reference — ScoringEngine path="reference": same math, but
                     size-bucketed by the engine (pad-zero removal only);
  packed_dense     — ScoringEngine path="packed_dense": FFD-packed tiles,
                     dense block-diagonal aggregation, custom-VJP bodies;
  packed_sparse    — ScoringEngine path="packed_sparse": packed-CSR edge
                     aggregation forward AND backward (transpose-aggregate
                     reuses the same edge planes).

Also reports the packed_sparse step at accum_steps=4 — the pack-once /
scan-tile-chunks accumulation mode — and the engine's own auto-dispatch
decision for the stream. Grad parity of both packed paths is measured
against the dense-reference autodiff anchor (max abs error over all param
leaves). On this CPU-only container numbers are the trajectory baseline,
not TPU times. Emits one `BENCH {json}` line per policy.

Usage:  PYTHONPATH=src python benchmarks/train.py [--tiny] [--check]
            [--batch 256] [--avg-degree 2.1] [--out train_bench.json]

`--check` (CI gate): non-zero exit if packed-path grad parity drifts above
1e-5, or if — at measured avg degree <= 4 — the packed-sparse step is not
at least 1.5x faster than the dense reference step.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):   # `python benchmarks/train.py` support
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import finish_check, time_fn
from repro.configs.simgnn_aids import CONFIG as CFG
from repro.core.batching import pad_graphs
from repro.core.engine import ScoringEngine
from repro.core.simgnn import init_simgnn_params, simgnn_loss
from repro.data.graphs import pair_stream
from repro.train.optimizer import adamw_init
from repro.train.step import build_simgnn_train_step

GRAD_PARITY_BOUND = 1e-5
MIN_SPARSE_SPEEDUP = 1.5


def _dense_reference_step(peak_lr: float = 1e-3):
    """The pre-engine training path, kept as the benchmark baseline: pad
    every pair to the global max_nodes, one-hot the labels, autodiff
    `simgnn_loss` — the SAME jitted optimizer apply as the engine-routed
    step (`build_simgnn_apply`), so the comparison isolates the loss+grad
    path."""
    from repro.train.step import build_simgnn_apply

    vg = jax.jit(jax.value_and_grad(simgnn_loss))
    apply = build_simgnn_apply(peak_lr=peak_lr)

    def step(params, opt_state, batch):
        loss, grads = vg(params, _dense_batch(batch))
        return apply(params, opt_state, loss, grads)

    return step, vg


def _dense_batch(batch):
    b1 = pad_graphs([p[0] for p in batch["pairs"]], CFG.n_node_labels,
                    CFG.max_nodes)
    b2 = pad_graphs([p[1] for p in batch["pairs"]], CFG.n_node_labels,
                    CFG.max_nodes)
    return {"adj1": b1.adj, "feats1": b1.feats, "mask1": b1.mask,
            "adj2": b2.adj, "feats2": b2.feats, "mask2": b2.mask,
            "target": jnp.asarray(batch["target"])}


def _max_grad_err(grads, ref_grads) -> float:
    return max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32))))
               for a, b in zip(jax.tree.leaves(grads),
                               jax.tree.leaves(ref_grads)))


def run(batch: int = 256, iters: int = 5, seed: int = 59,
        avg_degree: float | None = None):
    params = init_simgnn_params(jax.random.PRNGKey(0), CFG)
    opt_state = adamw_init(params)
    b = next(pair_stream(seed, batch, avg_degree=avg_degree))
    measured_degree = b["avg_degree"]

    dense_step, dense_vg = _dense_reference_step()
    # validation="off": the stream is a trusted in-process generator, and
    # the per-call adjacency scan would tax every timed step identically to
    # no informational benefit — the speed gate measures executors.
    engines = {name: ScoringEngine(params, CFG, path=path, validation="off")
               for name, path in (("engine_reference", "reference"),
                                  ("packed_dense", "packed_dense"),
                                  ("packed_sparse", "packed_sparse"))}
    steps = {"dense_reference": dense_step}
    steps.update((name, build_simgnn_train_step(eng))
                 for name, eng in engines.items())
    sparse_accum_step = build_simgnn_train_step(engines["packed_sparse"],
                                                accum_steps=4)

    # Grad parity vs the dense-reference autodiff anchor (identical batch).
    ref_loss, ref_grads = dense_vg(params, _dense_batch(b))
    parity = {"dense_reference": 0.0}
    loss_err = {"dense_reference": 0.0}
    for name, eng in engines.items():
        loss, grads = eng.loss_and_grad(b["pairs"], b["target"],
                                        params=params)
        parity[name] = _max_grad_err(grads, ref_grads)
        loss_err[name] = abs(float(loss) - float(ref_loss))

    # The engine's own train-mode decision for this stream.
    auto_plan = ScoringEngine(params, CFG).plan(b["pairs"], train=True)

    records, seconds = [], {}
    for name, step in list(steps.items()) + [("packed_sparse_accum4",
                                              sparse_accum_step)]:
        fn = lambda step=step: step(params, opt_state, b)
        seconds[name] = time_fn(fn, warmup=1, iters=iters)
        rec = {"bench": "train", "stream": "pair", "batch": batch,
               "policy": name,
               "measured_avg_degree": round(measured_degree, 3),
               "seconds_per_step": round(seconds[name], 6),
               "pairs_per_s": round(batch / seconds[name], 1),
               "max_grad_err_vs_dense_autodiff":
                   parity.get(name.replace("_accum4", ""), None),
               "loss_err_vs_dense_autodiff":
                   loss_err.get(name.replace("_accum4", ""), None)}
        eng = engines.get(name.replace("_accum4", ""))
        if eng is not None and eng.last_pack_stats:
            st = eng.last_pack_stats
            rec.update(n_tiles=st["n_tiles"],
                       occupancy=round(st["occupancy_lhs"], 4))
            if "edge_budget" in st:
                rec.update(edge_budget=st["edge_budget"],
                           overflow_budget=st["overflow_budget"],
                           edge_occupancy=round(st["edge_occupancy"], 4))
        if name == "packed_sparse_accum4":
            rec["accum_steps"] = 4
        records.append(rec)
        print("BENCH " + json.dumps(rec))

    summary = {"bench": "train", "stream": "pair", "batch": batch,
               "policy": "summary",
               "measured_avg_degree": round(measured_degree, 3),
               "engine_auto_train_path": auto_plan.path,
               "engine_reason": auto_plan.reason,
               "sparse_step_speedup_vs_dense_reference":
                   round(seconds["dense_reference"]
                         / seconds["packed_sparse"], 3),
               "packed_dense_step_speedup_vs_dense_reference":
                   round(seconds["dense_reference"]
                         / seconds["packed_dense"], 3),
               "accum4_step_speedup_vs_dense_reference":
                   round(seconds["dense_reference"]
                         / seconds["packed_sparse_accum4"], 3),
               "worst_packed_grad_parity": max(parity["packed_dense"],
                                               parity["packed_sparse"]),
               "worst_loss_err": max(loss_err.values())}
    records.append(summary)
    print("BENCH " + json.dumps(summary))
    return records, summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small batch, few iters")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero on packed grad-parity drift or "
                         "packed-sparse step slower than 1.5x dense "
                         "reference at avg degree <= 4")
    ap.add_argument("--out", type=str, default=None,
                    help="write BENCH records to this JSON file")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--avg-degree", type=float, default=None,
                    help="target stream degree (AIDS-like ~2.1 default)")
    ap.add_argument("--iters", type=int, default=5)
    a = ap.parse_args()
    if a.tiny:
        records, summary = run(batch=32, iters=2, avg_degree=a.avg_degree)
    else:
        records, summary = run(batch=a.batch, iters=a.iters,
                               avg_degree=a.avg_degree)
    failures = []
    if summary["worst_packed_grad_parity"] > GRAD_PARITY_BOUND:
        failures.append(
            f"packed-path grad parity "
            f"{summary['worst_packed_grad_parity']:.2e} > "
            f"{GRAD_PARITY_BOUND:.0e} vs dense-reference autodiff")
    # The speed gate is calibrated for serving-scale batches (the §11
    # acceptance point is batch 256): below ~64 pairs the per-batch
    # packing cost cannot amortize and the parity gate alone applies.
    if (summary["batch"] >= 64
            and summary["measured_avg_degree"] <= 4.0
            and summary["sparse_step_speedup_vs_dense_reference"]
            < MIN_SPARSE_SPEEDUP):
        failures.append(
            "packed-sparse train step only "
            f"{summary['sparse_step_speedup_vs_dense_reference']}x the "
            f"dense reference (< {MIN_SPARSE_SPEEDUP}x) at degree "
            f"{summary['measured_avg_degree']}")
    finish_check(records, failures, bench="train", out=a.out, check=a.check)


if __name__ == "__main__":
    main()
