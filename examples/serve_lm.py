"""Serve a (reduced) LM: prefill + KV-cache greedy decode, the serving path
the decode_32k / long_500k dry-run cells exercise at production shapes.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-9b --new 12
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.distributed.sharding import Runtime
from repro.models import lm
from repro.models.init import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--new", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    rt = Runtime(mesh=None)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    last, caches, pos = jax.jit(
        lambda p, t: lm.prefill(p, cfg, rt, t, cache_len=8 + args.new)
    )(params, prompt)
    decode = jax.jit(lambda p, t, c, s: lm.decode_step(p, cfg, rt, t, c, s))
    toks = [jnp.argmax(last, -1)]
    for _ in range(args.new - 1):
        logits, caches, pos = decode(params, toks[-1][:, None], caches, pos)
        toks.append(jnp.argmax(logits, -1))
    out = jnp.stack(toks, 1)
    print(f"arch={args.arch} (reduced) prompt={prompt.tolist()}")
    print(f"greedy continuation: {out.tolist()}")


if __name__ == "__main__":
    main()
