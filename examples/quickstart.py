"""Quickstart: score graph pairs with SimGNN on the SPA-GCN stack.

Runs on CPU in seconds:
    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs.simgnn_aids import CONFIG as CFG
from repro.core.simgnn import init_simgnn_params, pair_score, simgnn_loss
from repro.data.graphs import pair_stream
from repro.kernels.ops import simgnn_pair_score_kernel


def main():
    params = init_simgnn_params(jax.random.PRNGKey(0), CFG)
    batch = next(pair_stream(seed=0, batch=8))
    args = [jnp.asarray(batch[k]) for k in
            ("adj1", "feats1", "mask1", "adj2", "feats2", "mask2")]

    scores = jax.jit(pair_score)(params, *args)
    print("similarity scores (jnp path):   ",
          [f"{s:.4f}" for s in scores.tolist()])

    scores_k = simgnn_pair_score_kernel(params, *args)
    print("similarity scores (Pallas path):",
          [f"{s:.4f}" for s in scores_k.tolist()])
    print("GED targets:                    ",
          [f"{t:.4f}" for t in batch["target"].tolist()])

    dense_keys = ("adj1", "feats1", "mask1", "adj2", "feats2", "mask2",
                  "target")
    loss = simgnn_loss(params, {k: jnp.asarray(batch[k]) for k in dense_keys})
    print(f"untrained MSE vs exp(-nGED) targets: {float(loss):.4f}")
    print("run `python -m repro.launch.train --model simgnn` to train it.")


if __name__ == "__main__":
    main()
