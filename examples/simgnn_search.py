"""The paper's end-to-end workload: a graph-similarity search service.

Streams query pairs (AIDS-like synthetic compounds), scores them through the
unified scoring engine (core/engine.py, DESIGN.md §9) and reports throughput
— the queries/s metric of paper Tables 5/6 and Fig. 11. The engine measures
each batch's density and picks a path (packed-sparse on the AIDS-like
default stream); `--path` forces any of the six paths, `--avg-degree`
changes the stream's sparsity to see the dispatch flip.

`--topk` switches to the 1-vs-N service (DESIGN.md §10): index a fixed
corpus once through `serve.search.SimilaritySearchServer`, then serve
top-k queries from the Zipf-skewed stream — each query pays one embedding
plus the fused NTN+FCN head over the corpus, and the report shows the
cache hit rate and per-stage time split.

`--mode two_stage` (with `--topk`) serves through the blocked streaming
top-M prefilter + exact rerank instead of the full scan (DESIGN.md §14);
`--topm` sets the shortlist size M. The report adds the per-stage
prefilter/gather/rerank split, the calibration ladder's chosen proxy,
and the sampled recall vs the exact scan (every 4th query is also served
exactly and the top-k overlap recorded).

    PYTHONPATH=src python examples/simgnn_search.py --queries 2000 --batch 256
    PYTHONPATH=src python examples/simgnn_search.py --kernels --path auto
    PYTHONPATH=src python examples/simgnn_search.py --topk 5 --corpus 256
    PYTHONPATH=src python examples/simgnn_search.py --topk 5 --corpus 4096 \
        --mode two_stage --topm 64
"""

import argparse
import time

import jax

from repro.configs.simgnn_aids import CONFIG as CFG
from repro.core.engine import PATHS
from repro.core.simgnn import init_simgnn_params
from repro.data.graphs import query_pairs, search_pairs, zipf_corpus, \
    zipf_query_stream
from repro.serve.batching import simgnn_query_server
from repro.serve.search import SimilaritySearchServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--kernels", action="store_true",
                    help="use the fused Pallas paths (interpret mode on CPU)")
    ap.add_argument("--path", default=None, choices=("auto",) + PATHS,
                    help="force a scoring path (default: flags -> engine)")
    ap.add_argument("--avg-degree", type=float, default=None,
                    help="stream degree knob (AIDS-like ~2.1 default); "
                         "switches to the independent-size search stream")
    ap.add_argument("--topk", type=int, default=None,
                    help="1-vs-N mode: index a corpus once, serve top-k "
                         "queries through the embedding cache (§10)")
    ap.add_argument("--corpus", type=int, default=256,
                    help="corpus size for --topk mode")
    ap.add_argument("--mode", default="exact",
                    choices=("exact", "two_stage"),
                    help="--topk query path: exact full-head scan, or the "
                         "blocked top-M prefilter + exact rerank (§14)")
    ap.add_argument("--topm", type=int, default=64,
                    help="two_stage shortlist size M (clamped to corpus)")
    ap.add_argument("--index-dir", default=None,
                    help="persist/reload the corpus index here (§13): "
                         "loads the verified shard store if present "
                         "(selectively re-embedding bad shards), else "
                         "builds the index once and saves it")
    args = ap.parse_args()

    params = init_simgnn_params(jax.random.PRNGKey(0), CFG)
    if args.topk is not None:
        run_topk(params, args)
        return
    if args.avg_degree is None:
        pairs = query_pairs(seed=1, n_pairs=args.queries)
    else:
        pairs = search_pairs(seed=1, n_pairs=args.queries,
                             avg_degree=args.avg_degree)
    score = simgnn_query_server(params, CFG, use_kernels=args.kernels,
                                path=args.path)

    # warmup (compile the engine's executables for this shape set)
    score(pairs[: args.batch])
    plan = score.last_plan
    print(f"engine plan: path={plan.path} ({plan.reason}); "
          f"{len(plan.fit_idx)} packed / {len(plan.over_idx)} bucketed")

    t0 = time.time()
    results = []
    for i in range(0, len(pairs), args.batch):
        results.append(score(pairs[i:i + args.batch]))
    dt = time.time() - t0
    qps = len(pairs) / dt
    print(f"scored {len(pairs)} queries in {dt:.2f}s -> {qps:,.0f} query/s "
          f"(batch={args.batch}, kernels={args.kernels}, "
          f"path={score.last_plan.path})")
    if score.last_pack_stats:
        st = score.last_pack_stats
        print(f"last pack: {st['n_tiles']} tiles, occupancy "
              f"{st['occupancy_lhs']:.2f}/{st['occupancy_rhs']:.2f}"
              + (f", edge occupancy {st['edge_occupancy']:.2f}"
                 if "edge_occupancy" in st else ""))
    print(f"first scores: {[f'{s:.3f}' for s in results[0][:6]]}")


def run_topk(params, args):
    """1-vs-N similarity search through the embedding cache (§10), with
    optional durable-index persist/reload (§13) and the two-stage
    prefilter+rerank query path (§14)."""
    from repro.core.store import StoreError

    two_stage = args.mode == "two_stage"
    server = SimilaritySearchServer(
        params, CFG, embed_with_kernels=args.kernels,
        # Sampled recall: every 4th two-stage query is ALSO served
        # exactly and the top-k overlap recorded on stats (§14).
        recall_sample_every=4 if two_stage else 0)
    corpus = zipf_corpus(seed=1, n_corpus=args.corpus,
                         avg_degree=args.avg_degree)
    loaded = False
    if args.index_dir:
        t0 = time.time()
        try:
            server.load(args.index_dir, corpus)
            st = server.stats
            print(f"loaded persisted index from {args.index_dir} in "
                  f"{time.time() - t0:.2f}s ({st.shards_loaded} shards "
                  f"verified, {st.shards_recovered} recovered, "
                  f"{st.rows_reembedded} rows re-embedded)")
            loaded = True
        except StoreError as exc:
            print(f"persisted index unusable ({exc}); rebuilding")
    if not loaded:
        t0 = time.time()
        server.index(corpus)
        print(f"indexed {len(corpus)} corpus graphs in "
              f"{time.time() - t0:.2f}s (embeddings resident, LRU "
              f"{server.engine.cache.stats()['size']} entries)")
        if args.index_dir:
            server.save(args.index_dir)
            print(f"saved index shards + manifest to {args.index_dir}")

    stream = zipf_query_stream(seed=1, batch=args.batch,
                               n_corpus=args.corpus,
                               avg_degree=args.avg_degree)
    n_queries = max(1, args.queries // args.batch)
    kw = ({"mode": "two_stage", "prefilter_m": args.topm}
          if two_stage else {})
    server.topk(next(stream)["query"], k=args.topk, **kw)  # compile warmup
    t0 = time.time()
    last = None
    for _ in range(n_queries):
        last = server.topk(next(stream)["query"], k=args.topk, **kw)
    dt = time.time() - t0
    st = server.stats
    pairs_s = st.pairs_scored / dt if dt else float("inf")
    print(f"served {n_queries} {args.mode} top-{args.topk} queries vs "
          f"corpus of {args.corpus} in {dt:.2f}s -> "
          f"{n_queries / dt:,.1f} query/s ({pairs_s:,.0f} pair-scores/s)")
    if two_stage:
        pf = server.health()["prefilter"]
        busy = (st.embed_seconds + st.prefilter_seconds + st.gather_seconds
                + st.rerank_seconds + st.topk_seconds)
        if busy:
            print(f"stage split: embed {st.embed_seconds / busy:.0%}, "
                  f"prefilter {st.prefilter_seconds / busy:.0%}, "
                  f"gather {st.gather_seconds / busy:.0%}, "
                  f"rerank {st.rerank_seconds / busy:.0%}, "
                  f"topk {st.topk_seconds / busy:.0%} "
                  f"(M={args.topm}, block {pf['block_cols']}, "
                  f"proxy {pf['proxy']})")
        if st.recall_samples:
            print(f"sampled recall vs exact: {st.recall_mean:.4f} over "
                  f"{st.recall_samples} samples "
                  f"({st.prefilter_degraded} degraded to exact)")
    else:
        busy = st.embed_seconds + st.head_seconds + st.topk_seconds
        if busy:
            # Corpus embeddings are served from the resident index matrix,
            # so the LRU hit rate only moves when clients repeat queries.
            print(f"stage split: embed {st.embed_seconds / busy:.0%}, "
                  f"head {st.head_seconds / busy:.0%}, "
                  f"topk {st.topk_seconds / busy:.0%}; "
                  f"repeated-query hit rate {server.hit_rate:.0%}")
    idx, scores = last
    print("top results: " + ", ".join(
        f"#{i}={s:.3f}" for i, s in zip(idx, scores)))


if __name__ == "__main__":
    main()
