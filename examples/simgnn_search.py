"""The paper's end-to-end workload: a graph-similarity search service.

Streams query pairs (AIDS-like synthetic compounds), scores them with the
batched + size-bucketed SPA-GCN pipeline, and reports throughput — the
queries/s metric of paper Tables 5/6 and Fig. 11.

    PYTHONPATH=src python examples/simgnn_search.py --queries 2000 --batch 256
"""

import argparse
import time

import jax

from repro.configs.simgnn_aids import CONFIG as CFG
from repro.core.simgnn import init_simgnn_params
from repro.data.graphs import query_pairs
from repro.serve.batching import simgnn_query_server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--kernels", action="store_true",
                    help="use the fused Pallas path (interpret mode on CPU)")
    args = ap.parse_args()

    params = init_simgnn_params(jax.random.PRNGKey(0), CFG)
    pairs = query_pairs(seed=1, n_pairs=args.queries)
    score = simgnn_query_server(params, CFG, use_kernels=args.kernels)

    # warmup (compile one executable per size bucket)
    score(pairs[: args.batch])

    t0 = time.time()
    results = []
    for i in range(0, len(pairs), args.batch):
        results.append(score(pairs[i:i + args.batch]))
    dt = time.time() - t0
    qps = len(pairs) / dt
    print(f"scored {len(pairs)} queries in {dt:.2f}s -> {qps:,.0f} query/s "
          f"(batch={args.batch}, kernels={args.kernels})")
    print(f"first scores: {[f'{s:.3f}' for s in results[0][:6]]}")


if __name__ == "__main__":
    main()
