"""The paper's end-to-end workload: a graph-similarity search service.

Streams query pairs (AIDS-like synthetic compounds), scores them through the
unified scoring engine (core/engine.py, DESIGN.md §9) and reports throughput
— the queries/s metric of paper Tables 5/6 and Fig. 11. The engine measures
each batch's density and picks a path (packed-sparse on the AIDS-like
default stream); `--path` forces any of the five paths, `--avg-degree`
changes the stream's sparsity to see the dispatch flip.

    PYTHONPATH=src python examples/simgnn_search.py --queries 2000 --batch 256
    PYTHONPATH=src python examples/simgnn_search.py --kernels --path auto
"""

import argparse
import time

import jax

from repro.configs.simgnn_aids import CONFIG as CFG
from repro.core.engine import PATHS
from repro.core.simgnn import init_simgnn_params
from repro.data.graphs import query_pairs, search_pairs
from repro.serve.batching import simgnn_query_server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--kernels", action="store_true",
                    help="use the fused Pallas paths (interpret mode on CPU)")
    ap.add_argument("--path", default=None, choices=("auto",) + PATHS,
                    help="force a scoring path (default: flags -> engine)")
    ap.add_argument("--avg-degree", type=float, default=None,
                    help="stream degree knob (AIDS-like ~2.1 default); "
                         "switches to the independent-size search stream")
    args = ap.parse_args()

    params = init_simgnn_params(jax.random.PRNGKey(0), CFG)
    if args.avg_degree is None:
        pairs = query_pairs(seed=1, n_pairs=args.queries)
    else:
        pairs = search_pairs(seed=1, n_pairs=args.queries,
                             avg_degree=args.avg_degree)
    score = simgnn_query_server(params, CFG, use_kernels=args.kernels,
                                path=args.path)

    # warmup (compile the engine's executables for this shape set)
    score(pairs[: args.batch])
    plan = score.last_plan
    print(f"engine plan: path={plan.path} ({plan.reason}); "
          f"{len(plan.fit_idx)} packed / {len(plan.over_idx)} bucketed")

    t0 = time.time()
    results = []
    for i in range(0, len(pairs), args.batch):
        results.append(score(pairs[i:i + args.batch]))
    dt = time.time() - t0
    qps = len(pairs) / dt
    print(f"scored {len(pairs)} queries in {dt:.2f}s -> {qps:,.0f} query/s "
          f"(batch={args.batch}, kernels={args.kernels}, "
          f"path={score.last_plan.path})")
    if score.last_pack_stats:
        st = score.last_pack_stats
        print(f"last pack: {st['n_tiles']} tiles, occupancy "
              f"{st['occupancy_lhs']:.2f}/{st['occupancy_rhs']:.2f}"
              + (f", edge occupancy {st['edge_occupancy']:.2f}"
                 if "edge_occupancy" in st else ""))
    print(f"first scores: {[f'{s:.3f}' for s in results[0][:6]]}")


if __name__ == "__main__":
    main()
