"""Train an assigned LM architecture (reduced config) end-to-end on CPU:
data pipeline -> train_step (AdamW, remat) -> checkpoint/restart loop.

    PYTHONPATH=src python examples/train_lm.py --arch qwen1.5-4b --steps 30

Any of the 10 assigned ids works (see repro/configs). On a real fleet drop
--reduced and pass --mesh single|multi.
"""

import argparse

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()
    train.main(["--model", args.arch, "--reduced", "--steps", str(args.steps),
                "--batch", "8", "--seq-len", "128", "--lr", "3e-3",
                "--ckpt-dir", "/tmp/repro_lm_ckpt"])


if __name__ == "__main__":
    main()
