"""Elastic restart demo: train, checkpoint, crash, resume — then restore the
same checkpoint under a *different* mesh layout (the fleet-resize path).

    PYTHONPATH=src python examples/elastic_restart.py
"""

import os
import shutil

import jax
import jax.numpy as jnp

from repro.ckpt import manager as ckpt
from repro.configs import reduced_config
from repro.distributed.sharding import Runtime
from repro.data.tokens import batch_for_step
from repro.models.init import init_params
from repro.train import loop
from repro.train.optimizer import adamw_init
from repro.train.step import build_train_step

CKPT = "/tmp/repro_elastic_demo"


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = reduced_config("gemma2-9b")
    rt = Runtime(mesh=None)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    step = jax.jit(build_train_step(cfg, rt, peak_lr=3e-3))

    def batch_fn(s):
        b = batch_for_step(cfg, s, global_batch=8, seq_len=64)
        return {k: jnp.asarray(v) for k, v in b.items()}

    print("== phase 1: train 6 steps, checkpoint every 3")
    loop.run(step, params, opt, batch_fn, n_steps=6, ckpt_dir=CKPT,
             ckpt_every=3, resume=None, log_every=2)

    print("== phase 2: 'crash' and resume (auto picks up step 6)")
    p2, o2, hist = loop.run(step, params, opt, batch_fn, n_steps=10,
                            ckpt_dir=CKPT, ckpt_every=3, resume="auto",
                            log_every=2)
    print(f"resumed and reached step {int(o2.step)}")

    print("== phase 3: elastic restore (same ckpt, new device layout)")
    last = ckpt.latest_step(CKPT)
    # On a resized fleet this would pass the new mesh's NamedShardings;
    # off-mesh the restore just re-materializes on the local device.
    p3, o3 = ckpt.restore(CKPT, last, (p2, o2))
    diff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32))))
               for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p3)))
    print(f"restored step {last}; max param diff after round trip: {diff:.1e}")


if __name__ == "__main__":
    main()
